//! `cargo bench` entry (harness=false; criterion is unavailable offline —
//! timing comes from munit::util::bench).
//!
//! Two groups:
//!  - `hot:*`  — microbenches of the L3 hot path (fp8 casts, data
//!    generation, literal packing, step latency per model size);
//!  - `paper:*` — one bench per paper table/figure that regenerates the
//!    figure's data series (training-backed figures are benchmarked via
//!    their unit of work, a single train step, so `cargo bench` stays
//!    minutes, not hours; `munit figure all` produces the full series).
//!
//! Filter with `cargo bench -- <substring>`.

use std::time::Duration;

use munit::analysis::{
    activation_underflow, activations::Activation, attention_sigma_iid, AttentionKind,
    InputDist,
};
use munit::config::ModelConfig;
use munit::coordinator::trainer::Trainer;
use munit::data::{Batcher, CorpusSpec};
use munit::fp8::E4M3;
use munit::perfmodel::{fig8, Hw};
use munit::runtime::{lit_f32, Engine};
use munit::scaling::comparison_matrix;
use munit::util::bench::{bench, header, quick, BenchResult};
use munit::util::json::Json;
use munit::util::rng::Rng;

fn main() {
    // cargo bench invokes the harness with `--bench` (and possibly other
    // libtest-ish flags); only a bare positional counts as a filter.
    let filter = std::env::args()
        .skip(1)
        .find(|a| !a.starts_with('-'))
        .unwrap_or_default();
    let mut results: Vec<BenchResult> = Vec::new();
    let mut run = |name: &str, f: &mut dyn FnMut()| {
        if !filter.is_empty() && !name.contains(&filter) {
            return;
        }
        eprintln!("running {name}…");
        results.push(quick(name, f));
    };

    // ---- hot path -------------------------------------------------------
    let mut rng = Rng::new(0);
    let mut buf = vec![0f32; 1 << 16];
    rng.fill_normal(&mut buf, 1.0);
    run("hot:fp8_quantize_64k_elems", &mut || {
        let mut b = buf.clone();
        std::hint::black_box(E4M3.quantize_slice(&mut b));
    });
    run("hot:fp8_underflow_fraction_64k", &mut || {
        std::hint::black_box(E4M3.underflow_fraction(&buf));
    });

    let spec = CorpusSpec::default();
    let mut batcher = Batcher::new(spec.clone(), 0, 0, 1, 4, 128);
    run("hot:data_batch_4x128", &mut || {
        std::hint::black_box(batcher.next_batch());
    });

    run("hot:literal_pack_512x64_f32", &mut || {
        std::hint::black_box(lit_f32(&buf[..512 * 64], &[512, 64]).unwrap());
    });

    let manifest_text = std::fs::read_to_string("artifacts/manifest.json").ok();
    if let Some(text) = &manifest_text {
        run("hot:manifest_json_parse", &mut || {
            std::hint::black_box(Json::parse(text).unwrap());
        });
    }

    // ---- per-figure/table ------------------------------------------------
    run("paper:fig1_table3_scheme_matrix", &mut || {
        std::hint::black_box(comparison_matrix());
    });
    run("paper:fig8_throughput_model", &mut || {
        std::hint::black_box(fig8(&Hw::default()));
    });
    let mut rng2 = Rng::new(2);
    run("paper:fig2_attention_sigma_sim", &mut || {
        std::hint::black_box(attention_sigma_iid(
            &[4, 64, 256],
            16,
            50,
            AttentionKind::Standard,
            &mut rng2,
        ));
    });
    let mut rng3 = Rng::new(3);
    run("paper:fig10_underflow_mc", &mut || {
        for act in Activation::all() {
            std::hint::black_box(activation_underflow(
                act,
                InputDist::StdNormal,
                E4M3,
                20_000,
                &mut rng3,
            ));
        }
    });

    // training-backed figures: benchmark the unit of work (one train step)
    // at each proxy size the figures use
    if let Ok(engine) = Engine::new("artifacts") {
        for (w, d, tag) in [
            (32usize, 4usize, "fig6_w32"),
            (64, 4, "fig6_fig9_fig11_w64"),
            (128, 6, "fig2_fig3_fig7_fig12_M"),
            (256, 8, "fig7_table5_L"),
            (64, 24, "fig4b_fig5_deep"),
        ] {
            let name = format!("paper:train_step_{tag}_w{w}d{d}");
            if !filter.is_empty() && !name.contains(&filter) {
                continue;
            }
            let cfg = ModelConfig { width: w, depth: d, ..ModelConfig::default() };
            let Ok(trainer) = Trainer::new(&engine, &cfg) else { continue };
            let mut state = trainer.init(0).unwrap();
            let mut b = Batcher::new(spec.clone(), 0, 0, 1, cfg.batch, cfg.seq_len);
            let tokens = b.next_batch();
            // warmup includes the XLA compile
            trainer.step(&mut state, &tokens, 1e-3, 1e-4, 0.4).unwrap();
            eprintln!("running {name}…");
            results.push(bench(&name, 1, 3, Duration::from_secs(3), || {
                let tokens = b.next_batch();
                std::hint::black_box(
                    trainer.step(&mut state, &tokens, 1e-3, 1e-4, 0.4).unwrap(),
                );
            }));
        }
    } else {
        eprintln!("artifacts not built; skipping train-step benches");
    }

    println!("\n{}", header());
    for r in &results {
        println!("{}", r.report());
    }
}
