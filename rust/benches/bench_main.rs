//! `cargo bench` entry (harness=false; criterion is unavailable offline —
//! timing comes from munit::util::bench).
//!
//! Two groups:
//!  - `hot:*`  — microbenches of the L3 hot path (fp8 casts, data
//!    generation, tensor packing, step latency per model size);
//!  - `paper:*` — one bench per paper table/figure that regenerates the
//!    figure's data series (training-backed figures are benchmarked via
//!    their unit of work, a single train step, so `cargo bench` stays
//!    minutes, not hours; `munit figure all` produces the full series).
//!
//! The train-step group runs on whatever backend `open_backend` finds
//! (PJRT artifacts or the pure-Rust reference) and emits
//! `BENCH_step.json` — steps/sec, tokens/sec, and the Session's per-step
//! host-transfer accounting — so the perf trajectory of the
//! state-residency design is tracked across PRs.
//!
//! Filter with `cargo bench -- <substring>`.

use std::time::Duration;

use munit::analysis::{
    activation_underflow, activations::Activation, attention_sigma_iid, AttentionKind,
    InputDist,
};
use munit::config::presets::paper_table4;
use munit::config::ModelConfig;
use munit::coordinator::collective::WireFormat;
use munit::coordinator::trainer::Trainer;
use munit::coordinator::{checkpoint, shard};
use munit::data::{Batcher, CorpusSpec};
use munit::fp8::E4M3;
use munit::perfmodel::{
    self, decode_step_time, fig8, shard_comm_bytes_per_step, step_time, Hw, MeasuredKernel,
    Mode,
};
use munit::repro::proxy_tc;
use munit::runtime::{open_backend, tensor_f32, Backend, InferSession, StatePrecision};
use munit::scaling::{comparison_matrix, recommended_tau};
use munit::util::bench::{bench, header, quick, BenchResult};
use munit::util::json::Json;
use munit::util::rng::Rng;

fn main() {
    // cargo bench invokes the harness with `--bench` (and possibly other
    // libtest-ish flags); only a bare positional counts as a filter.
    let filter = std::env::args()
        .skip(1)
        .find(|a| !a.starts_with('-'))
        .unwrap_or_default();
    let mut results: Vec<BenchResult> = Vec::new();
    let mut run = |name: &str, f: &mut dyn FnMut()| {
        if !filter.is_empty() && !name.contains(&filter) {
            return;
        }
        eprintln!("running {name}…");
        results.push(quick(name, f));
    };

    // ---- hot path -------------------------------------------------------
    let mut rng = Rng::new(0);
    let mut buf = vec![0f32; 1 << 16];
    rng.fill_normal(&mut buf, 1.0);
    run("hot:fp8_quantize_64k_elems", &mut || {
        let mut b = buf.clone();
        std::hint::black_box(E4M3.quantize_slice(&mut b));
    });
    let fast = E4M3.fast_caster();
    run("hot:fp8_fast_quantize_64k_elems", &mut || {
        let mut b = buf.clone();
        fast.quantize_slice(&mut b);
        std::hint::black_box(&b);
    });
    run("hot:fp8_underflow_fraction_64k", &mut || {
        std::hint::black_box(E4M3.underflow_fraction(&buf));
    });

    // telemetry-sink primitives: the deterministic RMS reduction every
    // recorded op pays when a capture is active, and the per-op FP8
    // cast-health pass (both zero-cost when telemetry is off)
    run("hot:telemetry_sum_sq_64k", &mut || {
        std::hint::black_box(munit::runtime::gemm::sum_sq(&buf));
    });
    run("hot:fp8_cast_health_64k", &mut || {
        std::hint::black_box(E4M3.cast_health(&buf, 1.0));
    });

    let spec = CorpusSpec::default();
    let mut batcher = Batcher::new(spec.clone(), 0, 0, 1, 4, 128);
    run("hot:data_batch_4x128", &mut || {
        std::hint::black_box(batcher.next_batch());
    });

    run("hot:tensor_pack_512x64_f32", &mut || {
        std::hint::black_box(tensor_f32(&buf[..512 * 64], &[512, 64]).unwrap());
    });

    // the batched interpreter's GEMM kernel (deterministic 8-lane dot),
    // on the runtime-dispatched kernel path (AVX2 where the host has it)
    let mut ga = vec![0f32; 256 * 256];
    let mut gb = vec![0f32; 256 * 256];
    let mut gc = vec![0f32; 256 * 256];
    rng.fill_normal(&mut ga, 1.0);
    rng.fill_normal(&mut gb, 1.0);
    run("hot:gemm_bt_256cubed", &mut || {
        munit::runtime::gemm::matmul_bt(&ga, &gb, &mut gc, 256, 256, 256, 1.0);
        std::hint::black_box(&gc);
    });
    // the same GEMM forced onto the portable (no-intrinsics) kernels:
    // the ratio to the row above is the realized SIMD speedup. Both
    // paths are bit-identical by contract, so only the clock differs.
    {
        let guard = munit::runtime::gemm::kernel_path_lock();
        guard.force_portable(true);
        run("hot:gemm_bt_256cubed_portable", &mut || {
            munit::runtime::gemm::matmul_bt(&ga, &gb, &mut gc, 256, 256, 256, 1.0);
            std::hint::black_box(&gc);
        });
    }
    // fused cast-into-GEMM entry point: FP8 quantization runs inside the
    // per-panel pack loop instead of as a separate pass over A. Restore
    // the unquantized operand every iteration — quantization is
    // idempotent, so reusing the mutated buffer would time the
    // already-on-grid fast path instead of a fresh activation cast.
    let pack = |p: &mut [f32]| fast.quantize_slice(p);
    let ga_src = ga.clone();
    run("hot:gemm_bt_quant_fused_256cubed", &mut || {
        ga.copy_from_slice(&ga_src);
        munit::runtime::gemm::matmul_bt_quant(&mut ga, &gb, &mut gc, 256, 256, 256, 1.0, pack);
        std::hint::black_box(&gc);
    });

    // the op-level block's per-head causal attention kernel (fwd + bwd)
    let (s_a, dh_a) = (128usize, 64usize);
    let mut qa = vec![0f32; s_a * dh_a];
    let mut ka = vec![0f32; s_a * dh_a];
    let mut va = vec![0f32; s_a * dh_a];
    rng.fill_normal(&mut qa, 1.0);
    rng.fill_normal(&mut ka, 1.0);
    rng.fill_normal(&mut va, 1.0);
    let attn_scale = 1.0 / (dh_a as f32).sqrt();
    let mut probs_a = vec![0f32; s_a * s_a];
    let mut oa = vec![0f32; s_a * dh_a];
    run("hot:attention_causal_fwd_s128_dh64", &mut || {
        munit::runtime::gemm::attn_forward_causal(
            &qa, &ka, &va, &mut probs_a, &mut oa, s_a, dh_a, attn_scale,
        );
        std::hint::black_box(&oa);
    });
    let (mut dqa, mut dka, mut dva) =
        (vec![0f32; s_a * dh_a], vec![0f32; s_a * dh_a], vec![0f32; s_a * dh_a]);
    run("hot:attention_causal_bwd_s128_dh64", &mut || {
        munit::runtime::gemm::attn_backward_causal(
            &oa, &probs_a, &qa, &ka, &va, &mut dqa, &mut dka, &mut dva, s_a, dh_a, attn_scale,
        );
        std::hint::black_box(&dqa);
    });

    // the decode path's single-query cached-attention kernel: one query
    // against a 256-position paged KV history, on both store codecs
    // (BF16 2 B/value vs E4M3 1 B/value — the FP8 KV cache streams half
    // the bytes per gathered position)
    {
        use munit::runtime::gemm::{attn_decode_cached, f32_to_bf16_bits, KvCodec};
        let (ctx, dh_d, page) = (256usize, 64usize, 32usize);
        let mut kv = vec![0f32; 2 * ctx * dh_d];
        rng.fill_normal(&mut kv, 1.0);
        let bf16_bytes: Vec<u8> =
            kv.iter().flat_map(|&v| f32_to_bf16_bits(v).to_le_bytes()).collect();
        let fp8_bytes: Vec<u8> = kv.iter().map(|&v| E4M3.encode(v) as u8).collect();
        let lut = E4M3.decode_lut8();
        let mut qd = vec![0f32; dh_d];
        rng.fill_normal(&mut qd, 1.0);
        let scale_d = 1.0 / (dh_d as f32).sqrt();
        let (mut kf, mut vf) = (vec![0f32; ctx * dh_d], vec![0f32; ctx * dh_d]);
        let mut scores_d = vec![0f32; ctx];
        let mut od = vec![0f32; dh_d];
        for (tag, bytes, bpv) in
            [("bf16", &bf16_bytes, 2usize), ("fp8", &fp8_bytes, 1usize)]
        {
            let (k_b, v_b) = bytes.split_at(ctx * dh_d * bpv);
            let k_pages: Vec<&[u8]> = k_b.chunks(page * dh_d * bpv).collect();
            let v_pages: Vec<&[u8]> = v_b.chunks(page * dh_d * bpv).collect();
            let codec = if bpv == 2 { KvCodec::Bf16 } else { KvCodec::Fp8E4m3(&lut) };
            run(&format!("hot:attention_decode_cached_{tag}_ctx256_dh64"), &mut || {
                attn_decode_cached(
                    &qd, &k_pages, &v_pages, ctx, dh_d, scale_d, codec, &mut kf, &mut vf,
                    &mut scores_d, &mut od,
                );
                std::hint::black_box(&od);
            });
        }
    }

    let manifest_text = std::fs::read_to_string("artifacts/manifest.json").ok();
    if let Some(text) = &manifest_text {
        run("hot:manifest_json_parse", &mut || {
            std::hint::black_box(Json::parse(text).unwrap());
        });
    }

    // static-analysis layer: the full symbolic µS verification at the
    // smoke geometry (what `munit verify-numerics` and CI pay per run),
    // and one linter pass over the largest hot file
    run("hot:static_verify_smoke_mus", &mut || {
        std::hint::black_box(
            munit::analysis::static_numerics::verify(
                &munit::analysis::static_numerics::VerifySpec::smoke(),
                "mus",
            )
            .unwrap(),
        );
    });
    let lint_src = std::fs::read_to_string("rust/src/runtime/infer.rs").ok();
    if let Some(src) = &lint_src {
        run("hot:lint_one_hot_file", &mut || {
            std::hint::black_box(munit::analysis::lint::lint_source("runtime/infer.rs", src));
        });
    }

    // ---- per-figure/table ------------------------------------------------
    run("paper:fig1_table3_scheme_matrix", &mut || {
        std::hint::black_box(comparison_matrix());
    });
    run("paper:fig8_throughput_model", &mut || {
        std::hint::black_box(fig8(&Hw::default()));
    });
    let mut rng2 = Rng::new(2);
    run("paper:fig2_attention_sigma_sim", &mut || {
        std::hint::black_box(attention_sigma_iid(
            &[4, 64, 256],
            16,
            50,
            AttentionKind::Standard,
            &mut rng2,
        ));
    });
    let mut rng3 = Rng::new(3);
    run("paper:fig10_underflow_mc", &mut || {
        for act in Activation::all() {
            std::hint::black_box(activation_underflow(
                act,
                InputDist::StdNormal,
                E4M3,
                20_000,
                &mut rng3,
            ));
        }
    });

    // training-backed figures: benchmark the unit of work (one train step)
    // at each proxy size the figures use; also feeds BENCH_step.json
    let mut step_rows: Vec<Json> = Vec::new();
    let backend = match open_backend("artifacts") {
        Ok(b) => b,
        Err(e) => {
            eprintln!("no backend available ({e:#}); skipping train-step benches");
            print_report(&results);
            return;
        }
    };
    eprintln!("train-step benches on backend: {}", backend.platform());
    let mut step_cfgs: Vec<(ModelConfig, String)> = [
        (32usize, 4usize, "fig6_w32"),
        (64, 4, "fig6_fig9_fig11_w64"),
        (128, 6, "fig2_fig3_fig7_fig12_M"),
        (256, 8, "fig7_table5_L"),
        (64, 24, "fig4b_fig5_deep"),
    ]
    .into_iter()
    .map(|(w, d, tag)| (ModelConfig { width: w, depth: d, ..ModelConfig::default() }, tag.into()))
    .collect();
    // the width-384 roster shape — the batched-interpreter acceptance
    // config (vocab 2048, seq 256, batch 8); tokens/sec lands in
    // BENCH_step.json so the perf trajectory is tracked across PRs
    step_cfgs.push((
        ModelConfig {
            width: 384,
            depth: 6,
            head_dim: 64,
            vocab: 2048,
            seq_len: 256,
            batch: 8,
            ..ModelConfig::default()
        },
        "roster_w384".into(),
    ));
    // attention-bearing shape: long sequence relative to width, so the
    // causal-attention kernels dominate the step (CI asserts this row is
    // present in BENCH_step.json)
    step_cfgs.push((
        ModelConfig {
            width: 128,
            depth: 4,
            head_dim: 32,
            vocab: 512,
            seq_len: 256,
            batch: 4,
            ..ModelConfig::default()
        },
        "attention_s256".into(),
    ));
    for (cfg, tag) in step_cfgs {
        let (w, d) = (cfg.width, cfg.depth);
        let name = format!("paper:train_step_{tag}_w{w}d{d}");
        if !filter.is_empty() && !name.contains(&filter) {
            continue;
        }
        let Ok(trainer) = Trainer::new(backend.as_ref(), &cfg) else { continue };
        let Ok(mut session) = trainer.init(0) else { continue };
        let mut b = Batcher::new(spec.clone(), 0, 0, 1, cfg.batch, cfg.seq_len);
        let tokens = b.next_batch();
        // warmup includes any artifact compile
        session.step(&tokens, 1e-3, 1e-4, 0.4).unwrap();
        eprintln!("running {name}…");
        let r = bench(&name, 1, 3, Duration::from_secs(3), || {
            let tokens = b.next_batch();
            std::hint::black_box(session.step(&tokens, 1e-3, 1e-4, 0.4).unwrap());
        });
        // per-step accounting from the Session (covers warmup + bench)
        let s = session.stats();
        let calls = s.calls.max(1);
        let per_step_s = r.mean.as_secs_f64();
        step_rows.push(Json::obj(vec![
            ("config", Json::str(&cfg.name())),
            ("bench", Json::str(&name)),
            ("width", Json::num(w as f64)),
            ("depth", Json::num(d as f64)),
            ("n_params", Json::num(cfg.n_params() as f64)),
            ("steps_per_sec", Json::num(1.0 / per_step_s.max(1e-12))),
            (
                "tokens_per_sec",
                Json::num((cfg.batch * cfg.seq_len) as f64 / per_step_s.max(1e-12)),
            ),
            (
                "execute_ms_per_step",
                Json::num(s.execute_time.as_secs_f64() * 1e3 / calls as f64),
            ),
            (
                "host_transfer_ms_per_step",
                Json::num(s.transfer_time.as_secs_f64() * 1e3 / calls as f64),
            ),
            (
                "host_transfer_bytes_per_step",
                Json::num((s.transfer_bytes / calls as u64) as f64),
            ),
            ("state_bytes_per_param", Json::num(s.state_bytes_per_param)),
        ]));
        results.push(r);
    }

    // ---- state-precision lanes (BENCH_step.json `state_precision`) -------
    // The proxy config trained under each `StatePrecision` lane. Every row
    // carries the live counters next to the perfmodel closed forms: the
    // session's state gauge (8 vs 3 B/param), real v1/v2 checkpoint file
    // sizes, and a tp=2 FP8-wire sharded run's comm bytes (the FP8-state
    // lane ships momenta as native scaled-E4M3 with zero amax syncs). CI
    // gates the exact matches plus the checkpoint + momentum-wire
    // halvings, so the state-residency contract is tracked across PRs.
    let mut state_rows: Vec<Json> = Vec::new();
    for sp in [StatePrecision::F32, StatePrecision::Fp8] {
        let cfg = ModelConfig::default();
        let name = format!("state:train_step_{}_w{}d{}", sp.label(), cfg.width, cfg.depth);
        if !filter.is_empty() && !name.contains(&filter) {
            continue;
        }
        let Ok(trainer) = Trainer::with_state_precision(backend.as_ref(), &cfg, sp) else {
            continue;
        };
        let Ok(mut session) = trainer.init(0) else { continue };
        let mut b = Batcher::new(spec.clone(), 0, 0, 1, cfg.batch, cfg.seq_len);
        let tokens = b.next_batch();
        session.step(&tokens, 1e-3, 1e-4, 0.4).unwrap();
        eprintln!("running {name}…");
        let r = bench(&name, 1, 3, Duration::from_secs(2), || {
            let tokens = b.next_batch();
            std::hint::black_box(session.step(&tokens, 1e-3, 1e-4, 0.4).unwrap());
        });
        results.push(r);
        let live = session.stats().clone();
        let state_model = perfmodel::state_bytes(&cfg, sp);
        // real checkpoint files in both codecs, against the byte forms
        let state = session.read_back().unwrap();
        let meta = backend.resolve("train_step", &cfg).unwrap();
        let specs = &meta.inputs[..state.tensors.len()];
        let dir = std::env::temp_dir();
        let p1 = dir.join(format!("munit_bench_{}.ckpt1", sp.label()));
        let p2 = dir.join(format!("munit_bench_{}.ckpt2", sp.label()));
        checkpoint::save(&p1, &state, specs).unwrap();
        checkpoint::save_v2(&p2, &state, specs, sp).unwrap();
        let v1_file = std::fs::metadata(&p1).map(|m| m.len()).unwrap_or(0);
        let v2_file = std::fs::metadata(&p2).map(|m| m.len()).unwrap_or(0);
        let _ = std::fs::remove_file(&p1);
        let _ = std::fs::remove_file(&p2);
        let (v1_model, v2_model) =
            (perfmodel::checkpoint_v1_bytes(&cfg), perfmodel::checkpoint_v2_bytes(&cfg, sp));
        // tp=2 FP8-wire sharded run: measured comm vs the closed form
        let (tp, stages) = (2usize, 1usize);
        let wire = WireFormat::Fp8;
        let stc = proxy_tc(3, 1.0 / 64.0, 2.0 / 16384.0, recommended_tau(cfg.depth), 0);
        let sspec = shard::ShardSpec::new(tp, stages);
        let opts = shard::ShardOpts::new(sspec, wire).with_state_precision(sp);
        let sr = shard::train_sharded(backend.as_ref(), &cfg, &stc, &spec, &opts).unwrap();
        let comm_measured = sr.comm.bytes_per_step();
        let mom_fp8 = perfmodel::momentum_wire_bytes_per_step(&cfg, tp, wire, sp);
        let mom_master =
            perfmodel::momentum_wire_bytes_per_step(&cfg, tp, WireFormat::Master, sp);
        let comm_model = perfmodel::param_wire_bytes_per_step(&cfg, tp, wire)
            + mom_fp8
            + perfmodel::pipeline_activation_bytes_per_step(&cfg, stages);
        let exact = live.state_bytes == state_model
            && v1_file == v1_model
            && v2_file == v2_model
            && comm_measured == comm_model;
        state_rows.push(Json::obj(vec![
            ("config", Json::str(&cfg.name())),
            ("lane", Json::str(sp.label())),
            ("state_bytes", Json::num(live.state_bytes as f64)),
            ("state_bytes_model", Json::num(state_model as f64)),
            ("state_bytes_per_param", Json::num(live.state_bytes_per_param)),
            ("ckpt_v1_bytes", Json::num(v1_file as f64)),
            ("ckpt_v1_model", Json::num(v1_model as f64)),
            ("ckpt_v2_bytes", Json::num(v2_file as f64)),
            ("ckpt_v2_model", Json::num(v2_model as f64)),
            ("comm_bytes_per_step", Json::num(comm_measured as f64)),
            ("model_bytes_per_step", Json::num(comm_model as f64)),
            ("momentum_wire_fp8_model", Json::num(mom_fp8 as f64)),
            ("momentum_wire_master_model", Json::num(mom_master as f64)),
            ("amax_syncs", Json::num(sr.comm.amax_syncs as f64)),
            ("exact_match", Json::num(if exact { 1.0 } else { 0.0 })),
        ]));
    }

    if !step_rows.is_empty() || !state_rows.is_empty() {
        // Microbench the kernels the interpreter actually dispatched
        // (always, independent of the bench filter, so every
        // BENCH_step.json carries them) and feed the rates through the
        // perfmodel measured-throughput hook: the `measured` block holds
        // the raw GFLOP/s / GB/s on both kernel paths, and the roofline
        // block holds `step_time`/`decode_step_time` predictions from
        // the calibrated Hw — recomputable from the `measured` fields
        // exactly (the calibration is bit-exact by construction; see
        // `perfmodel::MeasuredKernel`).
        let (mk, portable_gflops, path) = measure_kernels();
        let hw = mk.calibrate(&Hw::default());
        let p1 = &paper_table4()[0];
        let st = step_time(&hw, p1, Mode::Fp8Mus);
        let dt = decode_step_time(&hw, p1, Mode::Fp8Mus, 1024, 8);
        let doc = Json::obj(vec![
            ("backend", Json::str(&backend.platform())),
            (
                "measured",
                Json::obj(vec![
                    ("kernel_path", Json::str(path)),
                    ("gemm_gflops", Json::num(mk.gemm_gflops)),
                    ("portable_gemm_gflops", Json::num(portable_gflops)),
                    (
                        "simd_speedup",
                        Json::num(mk.gemm_gflops / portable_gflops.max(1e-12)),
                    ),
                    ("stream_gbps", Json::num(mk.stream_gbps)),
                ]),
            ),
            (
                "roofline_local_1b",
                Json::obj(vec![
                    ("step_s_fp8_mus", Json::num(st.total())),
                    ("gemm_s_fp8_mus", Json::num(st.gemm)),
                    ("decode_step_s_fp8_mus_b8_ctx1024", Json::num(dt.total())),
                ]),
            ),
            ("configs", Json::Arr(step_rows)),
            ("state_precision", Json::Arr(state_rows)),
        ]);
        match std::fs::write("BENCH_step.json", format!("{doc}\n")) {
            Ok(()) => eprintln!("wrote BENCH_step.json"),
            Err(e) => eprintln!("could not write BENCH_step.json: {e}"),
        }
    }

    // ---- inference benches: prefill + steady-state decode ---------------
    // (BENCH_decode.json — CI asserts nonzero decode tokens/sec, so the
    // serving-path perf trajectory is tracked across PRs like the step
    // path). Names contain "decode" so `cargo bench -- decode` selects
    // the whole group.
    let mut decode_rows: Vec<Json> = Vec::new();
    let decode_cfgs: Vec<(ModelConfig, &str)> = vec![
        (ModelConfig::default(), "proxy_w64"),
        (
            ModelConfig {
                width: 128,
                depth: 4,
                head_dim: 32,
                vocab: 512,
                seq_len: 256,
                batch: 4,
                ..ModelConfig::default()
            },
            "attention_s256",
        ),
    ];
    for (cfg, tag) in decode_cfgs {
        let group = format!("decode:{tag}_w{}d{}", cfg.width, cfg.depth);
        if !filter.is_empty() && !group.contains(&filter) {
            continue;
        }
        let Ok(trainer) = Trainer::new(backend.as_ref(), &cfg) else { continue };
        let Ok(session) = trainer.init(0) else { continue };
        let Ok(params) = session.params_host() else { continue };
        let Ok(mut infer) = InferSession::new(&cfg, &params, 0.4) else { continue };
        let cap = infer.context_capacity();
        let prompt_len = (cap / 2).max(1);
        let prompt: Vec<i32> = (0..prompt_len).map(|i| (i % cfg.vocab) as i32).collect();

        // prefill throughput: fresh sequence per iteration
        eprintln!("running {group} (prefill)…");
        let r_prefill = bench(&format!("{group}_prefill"), 1, 3, Duration::from_secs(2), || {
            let id = infer.add_sequence();
            std::hint::black_box(infer.prefill(id, &prompt).unwrap());
            infer.free_sequence(id).unwrap();
        });
        let prefill_tps = prompt_len as f64 / r_prefill.mean.as_secs_f64().max(1e-12);

        // steady-state decode at batch 1 and batch 8: sequences are
        // re-prefilled when they hit context capacity (amortized away
        // over the cap/2 decode steps between refills)
        let mut decode_tps = [0f64; 2];
        for (bi, &batch) in [1usize, 8].iter().enumerate() {
            let short: Vec<i32> = prompt[..4.min(prompt_len)].to_vec();
            let mut ids = Vec::with_capacity(batch);
            for _ in 0..batch {
                let id = infer.add_sequence();
                infer.prefill(id, &short).unwrap();
                ids.push(id);
            }
            let mut tok = 0i32;
            eprintln!("running {group} (decode b{batch})…");
            let r = bench(
                &format!("{group}_steady_b{batch}"),
                2,
                3,
                Duration::from_secs(2),
                || {
                    for id in ids.iter_mut() {
                        if infer.sequence_len(*id).unwrap() >= cap {
                            infer.free_sequence(*id).unwrap();
                            *id = infer.add_sequence();
                            infer.prefill(*id, &short).unwrap();
                        }
                    }
                    tok = (tok + 1) % cfg.vocab as i32;
                    let items: Vec<_> = ids.iter().map(|&id| (id, tok)).collect();
                    std::hint::black_box(infer.decode_batch(&items).unwrap());
                },
            );
            decode_tps[bi] = batch as f64 / r.mean.as_secs_f64().max(1e-12);
            for id in &ids {
                infer.free_sequence(*id).unwrap();
            }
            results.push(r);
        }
        results.push(r_prefill);
        decode_rows.push(Json::obj(vec![
            ("config", Json::str(&cfg.name())),
            ("bench", Json::str(&group)),
            ("context_capacity", Json::num(cap as f64)),
            ("prefill_tokens_per_sec", Json::num(prefill_tps)),
            ("decode_tokens_per_sec_b1", Json::num(decode_tps[0])),
            ("decode_tokens_per_sec_b8", Json::num(decode_tps[1])),
            (
                "kv_bytes_per_token",
                Json::num(cfg.kv_cache_bytes_per_token() as f64),
            ),
        ]));
    }
    if !decode_rows.is_empty() {
        let doc = Json::obj(vec![
            ("backend", Json::str(&backend.platform())),
            ("configs", Json::Arr(decode_rows)),
        ]);
        match std::fs::write("BENCH_decode.json", format!("{doc}\n")) {
            Ok(()) => eprintln!("wrote BENCH_decode.json"),
            Err(e) => eprintln!("could not write BENCH_decode.json: {e}"),
        }
    }

    // ---- sharded-execution benches (BENCH_shard.json) --------------------
    // TP ∈ {1,2,4} × stages ∈ {1,2} over the 4-head proxy config, on the
    // FP8 wire. Each row carries the measured comm bytes/step next to the
    // perfmodel closed form (CI asserts the exact match plus nonzero
    // tokens/sec, so the sharded-path perf AND the comm-model contract
    // are tracked across PRs). Names contain "shard" for filtering.
    let mut shard_rows: Vec<Json> = Vec::new();
    let shard_cfg = ModelConfig::default(); // 4 heads: admits tp 1/2/4
    let shard_tc =
        proxy_tc(3, 1.0 / 64.0, 2.0 / 16384.0, recommended_tau(shard_cfg.depth), 0);
    let wire = WireFormat::Fp8;
    for tp in [1usize, 2, 4] {
        for stages in [1usize, 2] {
            let name = format!("shard:tp{tp}_pp{stages}_fp8wire");
            if !filter.is_empty() && !name.contains(&filter) {
                continue;
            }
            let sspec = shard::ShardSpec::new(tp, stages);
            let opts = shard::ShardOpts::new(sspec, wire);
            let mut last: Option<shard::ShardRun> = None;
            eprintln!("running {name}…");
            let r = bench(&name, 1, 2, Duration::from_secs(2), || {
                let sr =
                    shard::train_sharded(backend.as_ref(), &shard_cfg, &shard_tc, &spec, &opts)
                        .unwrap();
                last = Some(std::hint::black_box(sr));
            });
            let sr = last.unwrap();
            let measured = sr.comm.bytes_per_step();
            let modeled = shard_comm_bytes_per_step(
                &shard_cfg,
                tp,
                stages,
                wire.bytes_per_elem() as usize,
            );
            shard_rows.push(Json::obj(vec![
                ("config", Json::str(&shard_cfg.name())),
                ("bench", Json::str(&name)),
                ("tp", Json::num(tp as f64)),
                ("stages", Json::num(stages as f64)),
                ("wire", Json::str(wire.label())),
                ("steps", Json::num(sr.run.steps_done as f64)),
                ("tokens_per_sec", Json::num(sr.run.tokens_per_sec)),
                ("comm_bytes_per_step", Json::num(measured as f64)),
                ("model_bytes_per_step", Json::num(modeled as f64)),
                ("exact_match", Json::num(if measured == modeled { 1.0 } else { 0.0 })),
                ("amax_syncs", Json::num(sr.comm.amax_syncs as f64)),
            ]));
            results.push(r);
        }
    }
    if !shard_rows.is_empty() {
        let doc = Json::obj(vec![
            ("backend", Json::str(&backend.platform())),
            ("configs", Json::Arr(shard_rows)),
        ]);
        match std::fs::write("BENCH_shard.json", format!("{doc}\n")) {
            Ok(()) => eprintln!("wrote BENCH_shard.json"),
            Err(e) => eprintln!("could not write BENCH_shard.json: {e}"),
        }
    }

    // ---- serving-tier benches (BENCH_serve.json) -------------------------
    // One seeded Zipf/Poisson workload (prefix reuse + mixed lengths)
    // drained through four scheduler tiers on identically pre-trained
    // weights. Rows carry p50/p99 queue/first-token/total latency,
    // goodput, prefix-hit rate and KV bytes; CI gates goodput floors and
    // asserts the tier contracts (prefix hits > 0, FP8 KV high-water
    // exactly half of BF16, chunked p99 first-token below unchunked,
    // zero FP8 saturation). Names contain "serve" for filtering.
    {
        use munit::coordinator::serve::{serve, ServeConfig};
        use munit::coordinator::traffic::{self, TrafficConfig};
        use munit::runtime::KvStoreMode;
        let serve_cfg = ModelConfig::default();
        let tc = TrafficConfig::default();
        let workload = traffic::generate(&serve_cfg, &tc).unwrap();
        let max_batch = 4usize;
        let tiers: [(&str, ServeConfig, KvStoreMode); 4] = [
            (
                "serve:baseline",
                ServeConfig { max_batch, ..Default::default() },
                KvStoreMode::Bf16,
            ),
            (
                "serve:prefix_cache",
                ServeConfig { max_batch, prefix_cache: true, ..Default::default() },
                KvStoreMode::Bf16,
            ),
            (
                "serve:chunked_prefill",
                ServeConfig { max_batch, prefill_chunk: Some(8), ..Default::default() },
                KvStoreMode::Bf16,
            ),
            // identical schedule to baseline, E4M3 KV store: same slab
            // peak, half the bytes — CI asserts the exact 2x
            (
                "serve:fp8_kv",
                ServeConfig { max_batch, ..Default::default() },
                KvStoreMode::Fp8E4m3,
            ),
        ];
        let mut serve_rows: Vec<Json> = Vec::new();
        let mut fp8_saturated = 0u64;
        let mut params_for_serve: Option<Vec<Vec<f32>>> = None;
        if let Ok(trainer) = Trainer::new(backend.as_ref(), &serve_cfg) {
            if let Ok(session) = trainer.init(0) {
                params_for_serve = session.params_host().ok();
            }
        }
        for (name, sc, mode) in &tiers {
            if !filter.is_empty() && !name.contains(&filter) {
                continue;
            }
            let Some(params) = params_for_serve.as_ref() else { continue };
            let Ok(mut infer) = InferSession::new(&serve_cfg, params, 0.4) else { continue };
            if infer.set_kv_store_mode(*mode).is_err() {
                continue;
            }
            let mut last = None;
            eprintln!("running {name}…");
            let r = bench(name, 1, 2, Duration::from_secs(2), || {
                // the drain resets its own prefix/pool state; each
                // iteration replays the identical workload
                let report = serve(&mut infer, &workload, sc).unwrap();
                last = Some(std::hint::black_box(traffic::assess(&report)));
            });
            let tr = last.unwrap();
            if *mode == KvStoreMode::Fp8E4m3 {
                fp8_saturated = infer.fp8_kv_health().saturated;
            }
            serve_rows.push(traffic::report_json(&serve_cfg.name(), name, &tr));
            results.push(r);
        }
        if !serve_rows.is_empty() {
            let doc = Json::obj(vec![
                ("backend", Json::str(&backend.platform())),
                ("n_requests", Json::num(tc.n_requests as f64)),
                ("fp8_kv_saturated", Json::num(fp8_saturated as f64)),
                ("configs", Json::Arr(serve_rows)),
            ]);
            match std::fs::write("BENCH_serve.json", format!("{doc}\n")) {
                Ok(()) => eprintln!("wrote BENCH_serve.json"),
                Err(e) => eprintln!("could not write BENCH_serve.json: {e}"),
            }
        }
    }

    print_report(&results);
}

/// Microbench the dispatched and forced-portable GEMM kernels plus the
/// streaming reduction, for BENCH_step.json's `measured` block. Returns
/// the [`MeasuredKernel`] rates (dispatched path), the portable-path
/// GEMM GFLOP/s, and the dispatched path's name.
fn measure_kernels() -> (MeasuredKernel, f64, &'static str) {
    let mut rng = Rng::new(7);
    let mut a = vec![0f32; 256 * 256];
    let mut b = vec![0f32; 256 * 256];
    let mut c = vec![0f32; 256 * 256];
    rng.fill_normal(&mut a, 1.0);
    rng.fill_normal(&mut b, 1.0);
    let path = munit::runtime::gemm::kernel_path().name();
    let gemm_flops = 2.0 * 256f64 * 256.0 * 256.0;
    eprintln!("measuring kernel rates (path={path})…");
    let auto = quick("measure:gemm_dispatched", || {
        munit::runtime::gemm::matmul_bt(&a, &b, &mut c, 256, 256, 256, 1.0);
        std::hint::black_box(&c);
    });
    let portable = {
        let guard = munit::runtime::gemm::kernel_path_lock();
        guard.force_portable(true);
        quick("measure:gemm_portable", || {
            munit::runtime::gemm::matmul_bt(&a, &b, &mut c, 256, 256, 256, 1.0);
            std::hint::black_box(&c);
        })
    };
    let mut s = vec![0f32; 1 << 20];
    rng.fill_normal(&mut s, 1.0);
    let stream = quick("measure:sum_sq_stream", || {
        std::hint::black_box(munit::runtime::gemm::sum_sq(&s));
    });
    let mk = MeasuredKernel {
        gemm_gflops: gemm_flops / auto.mean.as_secs_f64().max(1e-12) / 1e9,
        stream_gbps: (s.len() * 4) as f64 / stream.mean.as_secs_f64().max(1e-12) / 1e9,
    };
    let portable_gflops = gemm_flops / portable.mean.as_secs_f64().max(1e-12) / 1e9;
    (mk, portable_gflops, path)
}

fn print_report(results: &[BenchResult]) {
    println!("\n{}", header());
    for r in results {
        println!("{}", r.report());
    }
}
