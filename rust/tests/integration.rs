//! End-to-end integration over real AOT artifacts: the full
//! python-AOT -> HLO text -> rust-PJRT bridge.
//!
//! These tests need `make artifacts` to have run; they skip (with a note)
//! when artifacts are missing so `cargo test` stays green on a fresh tree.

use munit::config::{ModelConfig, Schedule, TrainConfig};
use munit::coordinator::{checkpoint, ddp, trainer::Trainer};
use munit::data::{Batcher, CorpusSpec};
use munit::fp8;
use munit::runtime::{lit_f32, scalar_f32, to_f32_vec, Engine};

fn engine() -> Option<Engine> {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    if !std::path::Path::new(dir).join("manifest.json").exists() {
        eprintln!("SKIP: artifacts not built");
        return None;
    }
    Some(Engine::new(dir).expect("engine"))
}

fn proxy_cfg() -> ModelConfig {
    ModelConfig::default() // mus_fp8_w64_d4_v512_s128_b4 — in the core set
}

fn quick_tc(steps: usize) -> TrainConfig {
    TrainConfig {
        steps,
        lr: 1.0 / 256.0,
        wd: 1e-4,
        tau: 0.4,
        schedule: Schedule::Constant,
        ..Default::default()
    }
}

#[test]
fn kernels_demo_round_trip_matches_rust_fp8() {
    let Some(engine) = engine() else { return };
    // inputs per manifest: x[64,32], g[32], b[32], q/k/v[2,64,16]
    let mut vals = Vec::new();
    let mut rng = munit::util::rng::Rng::new(42);
    for _ in 0..64 * 32 {
        vals.push(rng.normal_f32() * 100.0); // wide range exercises clipping
    }
    let x = lit_f32(&vals, &[64, 32]).unwrap();
    let g = lit_f32(&vec![1.0; 32], &[32]).unwrap();
    let b = lit_f32(&vec![0.0; 32], &[32]).unwrap();
    let mut qkv = Vec::new();
    for _ in 0..3 {
        let mut v = vec![0f32; 2 * 64 * 16];
        rng.fill_normal(&mut v, 1.0);
        qkv.push(lit_f32(&v, &[2, 64, 16]).unwrap());
    }
    let outs = engine
        .run("kernels_demo", &[x, g, b, qkv.remove(0), qkv.remove(0), qkv.remove(0)])
        .unwrap();
    assert_eq!(outs.len(), 5);

    // cast_transpose output vs the rust fp8 module. XLA 0.5.1's CPU f32->f8
    // convert double-rounds through bf16 (measured; DESIGN.md §Numerics),
    // so near-tie inputs may land on the *adjacent* representable value.
    // Require: exact match, or a neighboring e4m3 value with the input
    // close to the midpoint.
    let ct = to_f32_vec(&outs[1]).unwrap();
    let mut near_tie = 0usize;
    for (i, (&orig, &got)) in vals.iter().zip(&ct).enumerate() {
        let want = fp8::E4M3.quantize(orig);
        if got == want {
            continue;
        }
        let q = fp8::E4M3;
        assert_eq!(q.quantize(got), got, "elem {i}: {got} not representable");
        let step = (want - got).abs();
        let mid = (want + got) / 2.0;
        let rel = ((orig.clamp(-448.0, 448.0) - mid) / step).abs();
        assert!(
            rel < 0.01,
            "elem {i}: pallas {got} vs rust {want} (input {orig}) not a near-tie"
        );
        near_tie += 1;
    }
    assert!(near_tie < vals.len() / 100, "too many mismatches: {near_tie}");
    // and ctT is the exact transpose
    let ctt = to_f32_vec(&outs[2]).unwrap();
    for r in 0..64 {
        for c in 0..32 {
            assert_eq!(ct[r * 32 + c], ctt[c * 64 + r]);
        }
    }
    // layernorm: rows ~ zero mean / unit std (gain 1, bias 0)
    let ln = to_f32_vec(&outs[0]).unwrap();
    for r in 0..64 {
        let row = &ln[r * 32..(r + 1) * 32];
        let mean: f32 = row.iter().sum::<f32>() / 32.0;
        let var: f32 = row.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / 32.0;
        assert!(mean.abs() < 1e-3, "row {r} mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "row {r} var {var}");
    }
    // sqrt-softmax attention outputs have HIGHER late-position std than
    // standard attention (Fig 2 mechanics, iid inputs)
    let std_of_tail = |v: &[f32]| {
        let tail = &v[(64 - 8) * 16 * 1..]; // last positions of last head
        munit::util::stats::std(tail)
    };
    let a_std = to_f32_vec(&outs[3]).unwrap();
    let a_sqrt = to_f32_vec(&outs[4]).unwrap();
    assert!(std_of_tail(&a_sqrt) > std_of_tail(&a_std));
}

#[test]
fn train_loop_loss_decreases_and_is_stable() {
    let Some(engine) = engine() else { return };
    let cfg = proxy_cfg();
    let trainer = Trainer::new(&engine, &cfg).unwrap();
    let mut state = trainer.init(0).unwrap();
    // overfit a single batch: loss must drop from ~ln(512)=6.24
    let mut batcher = Batcher::new(CorpusSpec::default(), 7, 0, 1, cfg.batch, cfg.seq_len);
    let tokens = batcher.next_batch();
    let mut first = None;
    let mut last = 0f32;
    for _ in 0..40 {
        let (loss, gnorm) = trainer.step(&mut state, &tokens, 1.0 / 64.0, 1e-4, 0.4).unwrap();
        assert!(loss.is_finite() && gnorm.is_finite());
        first.get_or_insert(loss);
        last = loss;
    }
    let first = first.unwrap();
    assert!((first - 6.24).abs() < 0.5, "init loss {first}");
    assert!(last < first - 1.0, "no learning: {first} -> {last}");
}

#[test]
fn run_with_schedule_and_metrics() {
    let Some(engine) = engine() else { return };
    let cfg = proxy_cfg();
    let trainer = Trainer::new(&engine, &cfg).unwrap();
    let mut batcher = Batcher::new(CorpusSpec::default(), 3, 0, 1, cfg.batch, cfg.seq_len);
    let tc = TrainConfig {
        steps: 8,
        schedule: Schedule::Cosine { final_frac: 0.1, warmup: 2 },
        ..quick_tc(8)
    };
    let mut lrs = Vec::new();
    let r = trainer
        .run_with(&tc, &mut batcher, |m, _| lrs.push(m.lr))
        .unwrap();
    assert_eq!(r.steps_done, 8);
    assert!(!r.diverged);
    assert!(r.tokens_per_sec > 0.0);
    assert_eq!(lrs.len(), 8);
    assert!(lrs[0] < lrs[1]); // warmup
    assert!(lrs[7] < lrs[2]); // decay
}

#[test]
fn checkpoint_roundtrip_resumes_identically() {
    let Some(engine) = engine() else { return };
    let cfg = proxy_cfg();
    let trainer = Trainer::new(&engine, &cfg).unwrap();
    let mut batcher = Batcher::new(CorpusSpec::default(), 11, 0, 1, cfg.batch, cfg.seq_len);
    let mut state = trainer.init(1).unwrap();
    let tokens = batcher.next_batch();
    trainer.step(&mut state, &tokens, 1.0 / 256.0, 1e-4, 0.4).unwrap();

    let meta = engine.manifest.find_for("train_step", &cfg).unwrap();
    let specs = &meta.inputs[..2 * trainer.n_params_tensors()];
    let path = std::env::temp_dir().join("munit_ckpt_test.bin");
    checkpoint::save(&path, &state, specs).unwrap();
    let mut restored = checkpoint::load(&path, specs).unwrap();

    // stepping both with the same batch must produce identical losses
    let tokens2 = batcher.next_batch();
    let (l1, _) = trainer.step(&mut state, &tokens2, 1.0 / 256.0, 1e-4, 0.4).unwrap();
    let (l2, _) = trainer.step(&mut restored, &tokens2, 1.0 / 256.0, 1e-4, 0.4).unwrap();
    assert_eq!(l1, l2);
    std::fs::remove_file(&path).ok();
}

#[test]
fn ddp_single_worker_matches_plain_trainer() {
    let Some(engine) = engine() else { return };
    let cfg = proxy_cfg();
    let tc = quick_tc(3);
    let corpus = CorpusSpec::default();
    let r_ddp = ddp::train_ddp(&engine, &cfg, &tc, &corpus, 1).unwrap();
    let trainer = Trainer::new(&engine, &cfg).unwrap();
    let mut batcher = Batcher::new(corpus, tc.seed, 0, 1, cfg.batch, cfg.seq_len);
    let r_plain = trainer.run(&tc, &mut batcher).unwrap();
    assert_eq!(r_ddp.losses, r_plain.losses);
}

#[test]
fn ddp_two_workers_trains() {
    let Some(engine) = engine() else { return };
    let cfg = proxy_cfg();
    let r = ddp::train_ddp(&engine, &cfg, &quick_tc(3), &CorpusSpec::default(), 2).unwrap();
    assert_eq!(r.steps_done, 3);
    assert!(!r.diverged);
    assert!(r.losses.iter().all(|l| l.is_finite()));
}

#[test]
fn engine_rejects_wrong_arity() {
    let Some(engine) = engine() else { return };
    let res = engine.run("kernels_demo", &[scalar_f32(1.0)]);
    let err = match res {
        Ok(_) => panic!("arity check did not fire"),
        Err(e) => e,
    };
    assert!(err.to_string().contains("expects"));
}

#[test]
fn deterministic_training_same_seed() {
    let Some(engine) = engine() else { return };
    let cfg = proxy_cfg();
    let trainer = Trainer::new(&engine, &cfg).unwrap();
    let corpus = CorpusSpec::default();
    let run = |seed| {
        let mut b = Batcher::new(corpus.clone(), seed, 0, 1, cfg.batch, cfg.seq_len);
        trainer.run(&quick_tc(3), &mut b).unwrap().losses
    };
    assert_eq!(run(5), run(5));
    assert_ne!(run(5), run(6));
}

#[test]
fn sp_baseline_artifact_trains() {
    let Some(engine) = engine() else { return };
    let cfg = ModelConfig {
        variant: "sp".into(),
        precision: "bf16".into(),
        residual: "standard".into(),
        ..ModelConfig::default()
    };
    let trainer = Trainer::new(&engine, &cfg).unwrap();
    let mut batcher = Batcher::new(CorpusSpec::default(), 1, 0, 1, cfg.batch, cfg.seq_len);
    // SP sweeps lr directly; 2^-8 at base width
    let tc = TrainConfig { lr: 1.0 / 256.0, ..quick_tc(5) };
    let r = trainer.run(&tc, &mut batcher).unwrap();
    assert!(!r.diverged);
    assert!(r.losses[0] > 5.0 && r.losses[0] < 7.5);
}

#[test]
fn eval_suite_on_fresh_model_is_near_chance() {
    let Some(engine) = engine() else { return };
    // quad-L config has a fwd artifact; eval a freshly-initialized model
    let cfg = ModelConfig { width: 256, depth: 8, ..ModelConfig::default() };
    let trainer = Trainer::new(&engine, &cfg).unwrap();
    let state = trainer.init(3).unwrap();
    let corpus = CorpusSpec { vocab: cfg.vocab, ..CorpusSpec::default() };
    let r = munit::eval::evaluate(&engine, &cfg, state.params(), 0.35, &corpus, 1, 5).unwrap();
    // untrained: NLL near ln(512)=6.24, accuracies near chance but finite
    assert!((r.avg_nll - 6.24).abs() < 0.6, "nll {}", r.avg_nll);
    assert!(r.next_token_acc < 0.2);
    assert!(r.positions_scored > 0);
    assert!(r.induction_acc <= 1.0 && r.bigram_cloze_acc <= 1.0);
}

#[test]
fn probe_artifact_outputs_are_sane() {
    let Some(engine) = engine() else { return };
    let cfg = proxy_cfg(); // w64 d4 has a probe artifact (actfn set, gelu)
    let trainer = Trainer::new(&engine, &cfg).unwrap();
    let state = trainer.init(0).unwrap();
    let meta = engine.manifest.find_for("probe", &cfg).expect("probe artifact");
    let name = meta.name.clone();
    let mut batcher = Batcher::new(CorpusSpec::default(), 1, 0, 1, cfg.batch, cfg.seq_len);
    let tokens = batcher.next_batch();
    let tok = munit::runtime::lit_i32(&tokens, &[cfg.batch, cfg.seq_len]).unwrap();
    let tau = scalar_f32(0.4);
    let mut inputs: Vec<&xla::Literal> = state.params().iter().collect();
    inputs.push(&tok);
    inputs.push(&tau);
    let outs = engine.run(&name, &inputs).unwrap();
    // per manifest: attn_std, attn_sqrt_std, vcos, resid_std, underflow,
    // hist_in, hist_out, loss
    assert_eq!(outs.len(), 8);
    let resid_std = to_f32_vec(&outs[3]).unwrap();
    assert!(resid_std.iter().all(|v| *v > 0.5 && *v < 2.0), "stream not unit scale");
    let hist_in = to_f32_vec(&outs[5]).unwrap();
    let nb = hist_in.len() / cfg.depth;
    for l in 0..cfg.depth {
        let s: f32 = hist_in[l * nb..(l + 1) * nb].iter().sum();
        assert!((s - 1.0).abs() < 1e-3, "layer {l} hist sums to {s}");
    }
    let under = to_f32_vec(&outs[4]).unwrap();
    assert!(under.iter().all(|v| (0.0..=1.0).contains(v)));
}
