//! Integration tests over the runtime `Backend`/`Session` API.
//!
//! Two groups:
//!
//!  - **reference** (always run): drive the full L3 stack — session
//!    residency, trainer, threaded sweeps, DDP, checkpoints, eval — over
//!    the pure-Rust reference backend. No artifacts, no Python.
//!  - **artifact-gated** (`--features pjrt` + `make artifacts`): the
//!    python-AOT -> HLO text -> PJRT bridge. Each test skips with a clear
//!    message when the prerequisites are missing, so `cargo test -q`
//!    passes on a fresh clone.

use munit::config::{ModelConfig, Schedule, TrainConfig};
use munit::coordinator::collective::WireFormat;
use munit::coordinator::pipeline::DataPipeline;
use munit::coordinator::{checkpoint, ddp, shard, sweep, trainer::Trainer};
use munit::data::{Batcher, CorpusSpec};
use munit::perfmodel;
use munit::runtime::{micro_config, Backend, ReferenceBackend, StatePrecision};

fn quick_tc(steps: usize) -> TrainConfig {
    TrainConfig {
        steps,
        lr: 1.0 / 256.0,
        wd: 1e-4,
        tau: 0.4,
        schedule: Schedule::Constant,
        ..Default::default()
    }
}

fn micro_corpus(cfg: &ModelConfig) -> CorpusSpec {
    CorpusSpec { vocab: cfg.vocab, ..CorpusSpec::default() }
}

fn reference_backend() -> ReferenceBackend {
    ReferenceBackend::new(&[micro_config()]).expect("micro config is valid")
}

// ---------------------------------------------------------------------------
// reference backend: always run

#[test]
fn session_step_transfers_no_full_state() {
    // Acceptance: a Session step must not move the parameter state across
    // the host boundary — per-step transfers are the token batch (in) and
    // loss/gnorm (out); hyperparameter scalars cross only when their value
    // changes (constant-scalar handles are cached on the device).
    let be = reference_backend();
    let cfg = micro_config();
    let trainer = Trainer::new(&be, &cfg).unwrap();
    let mut session = trainer.init(0).unwrap();
    let mut batcher = Batcher::new(micro_corpus(&cfg), 1, 0, 1, cfg.batch, cfg.seq_len);
    let steps = 5;
    for _ in 0..steps {
        let tokens = batcher.next_batch();
        let (loss, gnorm) = session.step(&tokens, 0.01, 1e-4, 0.4).unwrap();
        assert!(loss.is_finite() && gnorm.is_finite());
    }
    let stats = session.stats();
    assert_eq!(stats.calls, steps);
    // exact accounting: tokens (4 bytes each) + loss/gnorm every step;
    // lr/wd/tau uploaded once (constant across steps here)
    let per_step = (cfg.batch * cfg.seq_len * 4 + 2 * 4) as u64;
    assert_eq!(stats.transfer_bytes, steps as u64 * per_step + 3 * 4);
    // a changed scalar moves again (and only the changed one)
    let before = stats.transfer_bytes;
    let tokens = batcher.next_batch();
    session.step(&tokens, 0.02, 1e-4, 0.4).unwrap();
    assert_eq!(session.stats().transfer_bytes, before + per_step + 4);
    // the full state is far larger than what crossed per step
    let state_bytes: usize =
        session.read_back().unwrap().tensors.iter().map(|t| t.byte_len()).sum();
    assert!(
        (per_step as usize) < state_bytes / 4,
        "per-step transfer {per_step} vs state {state_bytes}"
    );
}

#[test]
fn train_loop_loss_decreases_reference() {
    let be = reference_backend();
    let cfg = micro_config();
    let trainer = Trainer::new(&be, &cfg).unwrap();
    let mut session = trainer.init(0).unwrap();
    // overfit a single batch: loss must drop from ~ln(vocab)
    let mut batcher = Batcher::new(micro_corpus(&cfg), 7, 0, 1, cfg.batch, cfg.seq_len);
    let tokens = batcher.next_batch();
    let mut first = None;
    let mut last = 0f32;
    for _ in 0..60 {
        let (loss, gnorm) = session.step(&tokens, 0.01, 0.0, 0.4).unwrap();
        assert!(loss.is_finite() && gnorm.is_finite());
        first.get_or_insert(loss);
        last = loss;
    }
    let first = first.unwrap();
    let ln_v = (cfg.vocab as f32).ln();
    assert!((first - ln_v).abs() < 0.8, "init loss {first} vs ln|V| {ln_v}");
    assert!(last < first - 0.02, "no learning: {first} -> {last}");
}

#[test]
fn run_with_schedule_and_metrics_reference() {
    let be = reference_backend();
    let cfg = micro_config();
    let trainer = Trainer::new(&be, &cfg).unwrap();
    let mut batcher = Batcher::new(micro_corpus(&cfg), 3, 0, 1, cfg.batch, cfg.seq_len);
    let tc = TrainConfig {
        steps: 8,
        schedule: Schedule::Cosine { final_frac: 0.1, warmup: 2 },
        ..quick_tc(8)
    };
    let mut lrs = Vec::new();
    let r = trainer.run_with(&tc, &mut batcher, |m, _| lrs.push(m.lr)).unwrap();
    assert_eq!(r.steps_done, 8);
    assert!(!r.diverged);
    assert!(r.tokens_per_sec > 0.0);
    assert_eq!(lrs.len(), 8);
    assert!(lrs[0] < lrs[1]); // warmup
    assert!(lrs[7] < lrs[2]); // decay
}

#[test]
fn sweep_threads_match_sequential() {
    // Acceptance: >= 2 in-process worker threads, identical results to
    // the sequential path.
    let be = reference_backend();
    let cfg = micro_config();
    let corpus = micro_corpus(&cfg);
    let tc = quick_tc(3);
    let points = sweep::grid(&[1.0 / 256.0, 1.0 / 128.0, 1.0 / 64.0], &[1e-4, 2e-4], &[0.4]);
    assert!(points.len() >= 6);
    let seq = sweep::run_sequential(&be, &cfg, &tc, &corpus, &points, false).unwrap();
    let par = sweep::run_parallel(&be, &cfg, &tc, &corpus, &points, 3, false).unwrap();
    assert_eq!(seq.len(), par.len());
    for (s, p) in seq.iter().zip(&par) {
        assert_eq!(s.point, p.point);
        assert_eq!(s.final_loss, p.final_loss, "threaded sweep diverged from sequential");
        assert_eq!(s.diverged, p.diverged);
        assert_eq!(s.spikes, p.spikes);
    }
}

#[test]
fn checkpoint_roundtrip_resumes_identically_reference() {
    let be = reference_backend();
    let cfg = micro_config();
    let trainer = Trainer::new(&be, &cfg).unwrap();
    let mut batcher = Batcher::new(micro_corpus(&cfg), 11, 0, 1, cfg.batch, cfg.seq_len);
    let mut session = trainer.init(1).unwrap();
    let tokens = batcher.next_batch();
    session.step(&tokens, 1.0 / 256.0, 1e-4, 0.4).unwrap();

    let meta = be.resolve("train_step", &cfg).unwrap();
    let specs = &meta.inputs[..2 * trainer.n_params_tensors()];
    let state = session.read_back().unwrap();
    let path = std::env::temp_dir().join("munit_ckpt_ref_test.bin");
    checkpoint::save(&path, &state, specs).unwrap();
    let restored = checkpoint::load(&path, specs).unwrap();
    let mut resumed = trainer.session_from(&restored).unwrap();

    // stepping both with the same batch must produce identical losses
    let tokens2 = batcher.next_batch();
    let (l1, _) = session.step(&tokens2, 1.0 / 256.0, 1e-4, 0.4).unwrap();
    let (l2, _) = resumed.step(&tokens2, 1.0 / 256.0, 1e-4, 0.4).unwrap();
    assert_eq!(l1, l2);
    std::fs::remove_file(&path).ok();
}

#[test]
fn checkpoint_mid_run_resume_is_bit_identical_both_fp8_lanes() {
    // Save at step 3 of 6, reload into a FRESH session, continue — the
    // final state must be bit-identical to the uninterrupted run, for
    // both FP8 lanes (µS static E4M3/E5M2 and SP TE-style dynamic).
    for (variant, residual, lr) in
        [("mus", "fixed", 1.0 / 128.0), ("sp", "standard", 1.0 / 256.0)]
    {
        let cfg = ModelConfig {
            variant: variant.into(),
            precision: "fp8".into(),
            residual: residual.into(),
            ..micro_config()
        };
        let be = ReferenceBackend::new(&[cfg.clone()]).unwrap();
        let trainer = Trainer::new(&be, &cfg).unwrap();
        let corpus = micro_corpus(&cfg);
        let (wd, tau) = (1e-4, 0.4);

        // uninterrupted: 6 steps straight through
        let mut batcher = Batcher::new(corpus.clone(), 21, 0, 1, cfg.batch, cfg.seq_len);
        let mut straight = trainer.init(2).unwrap();
        let mut losses_straight = Vec::new();
        for _ in 0..6 {
            losses_straight.push(straight.step(&batcher.next_batch(), lr, wd, tau).unwrap().0);
        }
        let final_straight = straight.read_back().unwrap();

        // interrupted: 3 steps, checkpoint to disk, reload into a fresh
        // session, 3 more steps on the continuing data stream
        let mut batcher = Batcher::new(corpus.clone(), 21, 0, 1, cfg.batch, cfg.seq_len);
        let mut first_half = trainer.init(2).unwrap();
        let mut losses_resumed = Vec::new();
        for _ in 0..3 {
            losses_resumed.push(first_half.step(&batcher.next_batch(), lr, wd, tau).unwrap().0);
        }
        let meta = be.resolve("train_step", &cfg).unwrap();
        let specs = &meta.inputs[..2 * trainer.n_params_tensors()];
        let path = std::env::temp_dir().join(format!("munit_ckpt_midrun_{variant}.bin"));
        checkpoint::save(&path, &first_half.read_back().unwrap(), specs).unwrap();
        drop(first_half);
        let restored = checkpoint::load(&path, specs).unwrap();
        std::fs::remove_file(&path).ok();
        let mut resumed = trainer.session_from(&restored).unwrap();
        for _ in 0..3 {
            losses_resumed.push(resumed.step(&batcher.next_batch(), lr, wd, tau).unwrap().0);
        }
        let final_resumed = resumed.read_back().unwrap();

        assert_eq!(losses_straight, losses_resumed, "{variant}+fp8: losses diverged");
        assert_eq!(
            final_straight.tensors.len(),
            final_resumed.tensors.len(),
            "{variant}+fp8: tensor count"
        );
        for (i, (a, b)) in
            final_straight.tensors.iter().zip(&final_resumed.tensors).enumerate()
        {
            assert_eq!(a, b, "{variant}+fp8: tensor {i} not bit-identical after resume");
        }
    }
}

#[test]
fn ddp_single_worker_matches_plain_trainer_reference() {
    let be = reference_backend();
    let cfg = micro_config();
    let tc = quick_tc(3);
    let corpus = micro_corpus(&cfg);
    let r_ddp = ddp::train_ddp(&be, &cfg, &tc, &corpus, 1).unwrap();
    let trainer = Trainer::new(&be, &cfg).unwrap();
    let mut batcher = Batcher::new(corpus, tc.seed, 0, 1, cfg.batch, cfg.seq_len);
    let r_plain = trainer.run(&tc, &mut batcher).unwrap();
    assert_eq!(r_ddp.losses, r_plain.losses);
}

#[test]
fn ddp_two_workers_train_reference() {
    let be = reference_backend();
    let cfg = micro_config();
    let r = ddp::train_ddp(&be, &cfg, &quick_tc(3), &micro_corpus(&cfg), 2).unwrap();
    assert_eq!(r.steps_done, 3);
    assert!(!r.diverged);
    assert!(r.losses.iter().all(|l| l.is_finite()));
}

#[test]
fn deterministic_training_same_seed_reference() {
    let be = reference_backend();
    let cfg = micro_config();
    let trainer = Trainer::new(&be, &cfg).unwrap();
    let corpus = micro_corpus(&cfg);
    let run = |seed| {
        let mut b = Batcher::new(corpus.clone(), seed, 0, 1, cfg.batch, cfg.seq_len);
        trainer.run(&quick_tc(3), &mut b).unwrap().losses
    };
    assert_eq!(run(5), run(5));
    assert_ne!(run(5), run(6));
}

#[test]
fn eval_suite_on_fresh_model_is_near_chance_reference() {
    let be = reference_backend();
    let cfg = micro_config();
    let trainer = Trainer::new(&be, &cfg).unwrap();
    let session = trainer.init(3).unwrap();
    let params = session.params_host().unwrap();
    let corpus = micro_corpus(&cfg);
    let r = munit::eval::evaluate(&be, &cfg, &params, 0.4, &corpus, 1, 5).unwrap();
    let ln_v = (cfg.vocab as f64).ln();
    assert!((r.avg_nll - ln_v).abs() < 0.8, "nll {} vs ln|V| {ln_v}", r.avg_nll);
    assert!(r.next_token_acc < 0.35);
    assert!(r.positions_scored > 0);
    assert!(r.induction_acc <= 1.0 && r.bigram_cloze_acc <= 1.0);
}

#[test]
fn trainer_is_bit_identical_across_interpreter_thread_counts() {
    // The batched interpreter parallelizes internally; the determinism
    // contract says any worker-thread budget produces bit-identical runs.
    let cfg = ModelConfig {
        width: 64,
        depth: 2,
        head_dim: 8,
        vocab: 128,
        seq_len: 32,
        batch: 4,
        ..ModelConfig::default()
    };
    let corpus = CorpusSpec { vocab: cfg.vocab, ..CorpusSpec::default() };
    let run = |threads: usize| {
        munit::util::parallel::with_max_threads(threads, || {
            let be = ReferenceBackend::new(&[cfg.clone()]).unwrap();
            let trainer = Trainer::new(&be, &cfg).unwrap();
            let mut b = Batcher::new(corpus.clone(), 9, 0, 1, cfg.batch, cfg.seq_len);
            trainer.run(&quick_tc(3), &mut b).unwrap().losses
        })
    };
    let l1 = run(1);
    assert_eq!(l1, run(2), "2-thread interpreter drifted from sequential");
    assert_eq!(l1, run(4), "4-thread interpreter drifted from sequential");
}

#[test]
fn fp8_lanes_bit_identical_across_thread_counts_through_simd_kernels() {
    // Trainer-level determinism through the SIMD-dispatched fused
    // cast-GEMM kernels, for BOTH FP8 lanes: µS static (E4M3/E5M2
    // quantization fused into the GEMM pack step) and SP dynamic
    // (TE-style amax pre-pass + fused scale-cast-rescale). Full losses,
    // not just the last step, must match bitwise at 1/2/4 interpreter
    // threads — and the auto-dispatched path (AVX2 where present) must
    // match the forced-portable kernels bitwise, which is the
    // kernel-level bit-identity contract observed end to end.
    for (variant, residual, lr) in
        [("mus", "fixed", 1.0 / 128.0), ("sp", "standard", 1.0 / 256.0)]
    {
        let cfg = ModelConfig {
            variant: variant.into(),
            precision: "fp8".into(),
            residual: residual.into(),
            ..micro_config()
        };
        let corpus = micro_corpus(&cfg);
        // the portable-path override is process-global and the test
        // harness is concurrent: hold the kernel-path lock for the whole
        // sweep and toggle through the guard
        let guard = munit::runtime::gemm::kernel_path_lock();
        let run = |threads: usize, portable: bool| {
            guard.force_portable(portable);
            let losses = munit::util::parallel::with_max_threads(threads, || {
                let be = ReferenceBackend::new(&[cfg.clone()]).unwrap();
                let trainer = Trainer::new(&be, &cfg).unwrap();
                let tc = TrainConfig { lr, ..quick_tc(3) };
                let mut b = Batcher::new(corpus.clone(), 11, 0, 1, cfg.batch, cfg.seq_len);
                trainer.run(&tc, &mut b).unwrap().losses
            });
            guard.force_portable(false);
            losses
        };
        let base = run(1, false);
        assert!(base.iter().all(|l| l.is_finite()), "{variant}+fp8 non-finite: {base:?}");
        for threads in [2usize, 4] {
            assert_eq!(
                base,
                run(threads, false),
                "{variant}+fp8 drifted at {threads} interpreter threads"
            );
        }
        assert_eq!(
            base,
            run(1, true),
            "{variant}+fp8: auto kernel path is not bit-identical to portable"
        );
    }
}

#[test]
fn fp8_precision_lanes_train_reference() {
    // Always-run step coverage for both FP8 lanes over the full trainer
    // path: µS static (E4M3/E5M2) and SP dynamic (TE-style) scaling.
    for (variant, residual, lr) in
        [("mus", "fixed", 1.0 / 128.0), ("sp", "standard", 1.0 / 256.0)]
    {
        let cfg = ModelConfig {
            variant: variant.into(),
            precision: "fp8".into(),
            residual: residual.into(),
            ..micro_config()
        };
        let be = ReferenceBackend::new(&[cfg.clone()]).unwrap();
        let trainer = Trainer::new(&be, &cfg).unwrap();
        let tc = TrainConfig { lr, ..quick_tc(5) };
        let mut b = Batcher::new(micro_corpus(&cfg), 2, 0, 1, cfg.batch, cfg.seq_len);
        let r = trainer.run(&tc, &mut b).unwrap();
        assert!(!r.diverged, "{variant}+fp8 diverged");
        assert!(
            r.losses.iter().all(|l| l.is_finite()),
            "{variant}+fp8 non-finite: {:?}",
            r.losses
        );
    }
}

#[test]
fn sp_variant_trains_reference() {
    let be = reference_backend();
    let cfg = ModelConfig {
        variant: "sp".into(),
        precision: "bf16".into(),
        residual: "standard".into(),
        ..micro_config()
    };
    let trainer = Trainer::new(&be, &cfg).unwrap();
    let mut batcher = Batcher::new(micro_corpus(&cfg), 1, 0, 1, cfg.batch, cfg.seq_len);
    let tc = TrainConfig { lr: 1.0 / 256.0, ..quick_tc(5) };
    let r = trainer.run(&tc, &mut batcher).unwrap();
    assert!(!r.diverged);
    assert!(r.losses.iter().all(|l| l.is_finite()));
}

#[test]
fn trained_model_serves_through_continuous_batching() {
    // The full serving path over the public API: train on the reference
    // backend, lift the parameters into an InferSession, drain a
    // synthetic request set through the continuous-batching scheduler.
    use munit::coordinator::serve;
    use munit::runtime::InferSession;
    let be = reference_backend();
    let cfg = micro_config();
    let trainer = Trainer::new(&be, &cfg).unwrap();
    let mut batcher = Batcher::new(micro_corpus(&cfg), 5, 0, 1, cfg.batch, cfg.seq_len);
    let mut session = trainer.init(4).unwrap();
    for _ in 0..3 {
        session.step(&batcher.next_batch(), 1.0 / 128.0, 1e-4, 0.4).unwrap();
    }
    let params = session.params_host().unwrap();
    let mut infer = InferSession::new(&cfg, &params, 0.4).unwrap();
    let mut requests = serve::synthetic_requests(&cfg, 5, 3);
    for r in &mut requests {
        // guarantee real decode traffic whatever the sampled lengths
        r.max_new_tokens = r.max_new_tokens.max(3);
    }
    let sc = serve::ServeConfig { max_batch: 2, max_steps: 2_000 };
    let report = serve::serve(&mut infer, &requests, &sc).unwrap();
    assert_eq!(report.completions.len(), requests.len());
    assert!(report.decode_tokens > 0 && report.decode_tokens_per_sec > 0.0);
    assert_eq!(infer.kv_slabs_in_use(), 0, "serve must recycle every KV page");
    let s = infer.stats();
    assert_eq!(s.decode_tokens, report.decode_tokens);
    assert_eq!(s.prefill_tokens, report.prefill_tokens);
}

#[test]
fn telemetry_capture_is_non_perturbing_and_off_hot_path() {
    // Satellite acceptance: with the sink disabled (the default) the
    // recording hooks reduce to a thread-local flag check and training is
    // bit-identical to the pre-telemetry interpreter; with a capture
    // active, recording is read-only — so traced and untraced runs must
    // produce bit-identical TrainStates and losses, for both FP8 lanes,
    // at 1/2/4 interpreter worker threads.
    for (variant, residual, lr) in
        [("mus", "fixed", 1.0 / 128.0), ("sp", "standard", 1.0 / 256.0)]
    {
        let cfg = ModelConfig {
            variant: variant.into(),
            precision: "fp8".into(),
            residual: residual.into(),
            ..micro_config()
        };
        let be = ReferenceBackend::new(&[cfg.clone()]).unwrap();
        let trainer = Trainer::new(&be, &cfg).unwrap();
        let corpus = micro_corpus(&cfg);
        for threads in [1usize, 2, 4] {
            munit::util::parallel::with_max_threads(threads, || {
                let run = |traced: bool| {
                    let mut session = trainer.init(5).unwrap();
                    let mut batcher =
                        Batcher::new(corpus.clone(), 13, 0, 1, cfg.batch, cfg.seq_len);
                    let mut losses = Vec::new();
                    let mut reports = Vec::new();
                    for _ in 0..4 {
                        let tokens = batcher.next_batch();
                        if traced {
                            let (loss, _, rep) =
                                session.step_traced(&tokens, lr, 1e-4, 0.4).unwrap();
                            losses.push(loss.to_bits());
                            reports.push(rep);
                        } else {
                            assert!(!munit::telemetry::enabled());
                            let (loss, _) = session.step(&tokens, lr, 1e-4, 0.4).unwrap();
                            losses.push(loss.to_bits());
                        }
                    }
                    (losses, session.read_back().unwrap(), reports)
                };
                let (l_plain, s_plain, _) = run(false);
                let (l_traced, s_traced, reports) = run(true);
                assert_eq!(
                    l_plain, l_traced,
                    "{variant}+fp8 @ {threads} threads: tracing changed the losses"
                );
                assert_eq!(s_plain.tensors.len(), s_traced.tensors.len());
                for (i, (a, b)) in s_plain.tensors.iter().zip(&s_traced.tensors).enumerate() {
                    assert_eq!(
                        a, b,
                        "{variant}+fp8 @ {threads} threads: tensor {i} perturbed by tracing"
                    );
                }
                // the traces themselves are real: every step recorded
                // forward + backward RMS and FP8 cast health, and the
                // recorded values are thread-count invariant
                for rep in &reports {
                    assert!(!rep.is_empty(), "{variant}: empty telemetry report");
                    for op in ["qkv", "resid2", "final_norm", "d_qkv", "d_resid"] {
                        let Some(rms) = rep.op_rms(op) else {
                            panic!("{variant}: no '{op}' telemetry");
                        };
                        assert!(rms.is_finite() && rms > 0.0, "{variant} {op}: rms {rms}");
                    }
                    assert!(
                        rep.cast_totals("qkv").unwrap().total > 0,
                        "{variant}: no qkv cast telemetry"
                    );
                }
            });
        }
    }
}

#[test]
fn telemetry_reports_bit_identical_across_thread_counts() {
    // The recorded numbers themselves obey the determinism contract: the
    // RMS reductions fold fixed chunks in fixed order, so a traced step's
    // report is identical at any worker-thread budget.
    let cfg = ModelConfig {
        width: 64,
        depth: 2,
        head_dim: 8,
        vocab: 128,
        seq_len: 32,
        batch: 4,
        ..ModelConfig::default()
    };
    let corpus = CorpusSpec { vocab: cfg.vocab, ..CorpusSpec::default() };
    let run = |threads: usize| {
        munit::util::parallel::with_max_threads(threads, || {
            let be = ReferenceBackend::new(&[cfg.clone()]).unwrap();
            let trainer = Trainer::new(&be, &cfg).unwrap();
            let mut session = trainer.init(1).unwrap();
            let mut batcher = Batcher::new(corpus.clone(), 3, 0, 1, cfg.batch, cfg.seq_len);
            let (_, _, rep) =
                session.step_traced(&batcher.next_batch(), 1.0 / 128.0, 1e-4, 0.4).unwrap();
            rep
        })
    };
    let r1 = run(1);
    assert!(!r1.is_empty());
    for threads in [2usize, 4] {
        assert_eq!(r1, run(threads), "telemetry drifted at {threads} threads");
    }
}

#[test]
fn backend_rejects_wrong_arity_reference() {
    let be = reference_backend();
    let cfg = micro_config();
    let name = format!("train_{}", cfg.name());
    let res = be.run(&name, &[munit::runtime::scalar_f32(1.0)]);
    let err = match res {
        Ok(_) => panic!("arity check did not fire"),
        Err(e) => e,
    };
    assert!(err.to_string().contains("expects"));
}

// ---------------------------------------------------------------------------
// sharded execution: tensor + pipeline parallelism over FP8 collectives

/// A 4-head FP8 config so every TP degree in {1, 2, 4} is head-aligned.
fn shard_test_cfg(variant: &str, residual: &str) -> ModelConfig {
    ModelConfig {
        width: 32,
        depth: 2,
        head_dim: 8,
        vocab: 64,
        seq_len: 16,
        batch: 4,
        variant: variant.into(),
        precision: "fp8".into(),
        residual: residual.into(),
        ..ModelConfig::default()
    }
}

/// Sequential single-worker reference: same init seed, same data stream,
/// same LR schedule as `train_sharded` — losses plus the final state.
fn sequential_run(
    be: &ReferenceBackend,
    cfg: &ModelConfig,
    tc: &TrainConfig,
) -> (Vec<f32>, munit::coordinator::TrainState) {
    let trainer = Trainer::new(be, cfg).unwrap();
    let mut session = trainer.init(tc.init_seed).unwrap();
    let mut b = Batcher::new(micro_corpus(cfg), tc.seed, 0, 1, cfg.batch, cfg.seq_len);
    let mut losses = Vec::new();
    for step in 0..tc.steps {
        let lr = tc.schedule.lr_at(tc.lr, step, tc.steps);
        losses.push(session.step(&b.next_batch(), lr, tc.wd, tc.tau).unwrap().0);
    }
    (losses, session.read_back().unwrap())
}

#[test]
fn sharded_master_wire_is_bit_identical_to_sequential_both_fp8_lanes() {
    // The tentpole oracle: under the lossless master wire, a sharded run
    // at ANY tensor-parallel degree, stage count, and interpreter thread
    // budget is bit-identical to the plain sequential trainer — for both
    // the µS-static and SP-dynamic FP8 compute lanes.
    for (variant, residual, lr) in
        [("mus", "fixed", 1.0 / 128.0), ("sp", "standard", 1.0 / 256.0)]
    {
        let cfg = shard_test_cfg(variant, residual);
        let tc = TrainConfig { lr, ..quick_tc(3) };
        let be = ReferenceBackend::new(&[cfg.clone()]).unwrap();
        let (seq_losses, seq_state) = sequential_run(&be, &cfg, &tc);
        for tp in [2usize, 4] {
            for stages in [1usize, 2] {
                for threads in [1usize, 2, 4] {
                    munit::util::parallel::with_max_threads(threads, || {
                        let be = ReferenceBackend::new(&[cfg.clone()]).unwrap();
                        let opts = shard::ShardOpts::new(
                            shard::ShardSpec::new(tp, stages),
                            WireFormat::Master,
                        );
                        let r = shard::train_sharded(&be, &cfg, &tc, &micro_corpus(&cfg), &opts)
                            .unwrap();
                        let tag = format!("{variant} tp{tp} pp{stages} threads{threads}");
                        assert_eq!(r.run.losses, seq_losses, "{tag}: losses drifted");
                        assert_eq!(r.comm.amax_syncs, 0, "{tag}: amax exchanged");
                        for (i, (a, b)) in
                            seq_state.tensors.iter().zip(&r.final_state.tensors).enumerate()
                        {
                            assert_eq!(a, b, "{tag}: tensor {i} not bit-identical");
                        }
                    });
                }
            }
        }
    }
}

#[test]
fn fp8_wire_divergence_is_bounded_with_zero_amax_exchange() {
    // Under the FP8 wire the exchanged shards really are E4M3/E5M2
    // values, so the run measurably diverges from the master-wire run —
    // but stays finite and bounded, and (the µS headline) needs ZERO
    // cross-shard amax/scale synchronization to do it.
    let cfg = shard_test_cfg("mus", "fixed");
    let tc = TrainConfig { lr: 1.0 / 128.0, ..quick_tc(4) };
    let be = ReferenceBackend::new(&[cfg.clone()]).unwrap();
    let corpus = micro_corpus(&cfg);
    let spec = shard::ShardSpec::new(2, 1);
    let master = shard::train_sharded(
        &be,
        &cfg,
        &tc,
        &corpus,
        &shard::ShardOpts::new(spec, WireFormat::Master),
    )
    .unwrap();
    let fp8 = shard::train_sharded(
        &be,
        &cfg,
        &tc,
        &corpus,
        &shard::ShardOpts::new(spec, WireFormat::Fp8),
    )
    .unwrap();
    assert!(!fp8.run.diverged, "FP8 wire destabilized training");
    assert!(fp8.run.losses.iter().all(|l| l.is_finite()));
    assert!(fp8.comm.health.total > 0, "no wire casts recorded");
    assert_eq!(fp8.comm.amax_syncs, 0, "static µS scales must need no amax exchange");
    assert_ne!(fp8.run.losses, master.run.losses, "FP8 wire quantization was a no-op");
    let d = (fp8.run.losses.last().unwrap() - master.run.losses.last().unwrap()).abs();
    assert!(d < 0.5, "unbounded FP8-wire divergence: {d}");
    // the compressed wire moves exactly 4x fewer state bytes
    assert_eq!(fp8.comm.allgather_bytes * 4, master.comm.allgather_bytes);
    assert_eq!(fp8.comm.reduce_scatter_bytes * 4, master.comm.reduce_scatter_bytes);
}

#[test]
fn shard_comm_counters_match_perfmodel_closed_forms_exactly() {
    let cfg = shard_test_cfg("mus", "fixed");
    let tc = TrainConfig { lr: 1.0 / 128.0, ..quick_tc(2) };
    let be = ReferenceBackend::new(&[cfg.clone()]).unwrap();
    let corpus = micro_corpus(&cfg);
    for wire in [WireFormat::Master, WireFormat::Fp8] {
        for tp in [1usize, 2, 4] {
            for stages in [1usize, 2] {
                let opts = shard::ShardOpts::new(shard::ShardSpec::new(tp, stages), wire);
                let r = shard::train_sharded(&be, &cfg, &tc, &corpus, &opts).unwrap();
                let steps = r.comm.steps as u64;
                let wb = wire.bytes_per_elem() as usize;
                let tag = format!("{} tp{tp} pp{stages}", wire.label());
                assert_eq!(
                    r.comm.allgather_bytes,
                    steps * perfmodel::shard_allgather_bytes_per_step(&cfg, tp, wb),
                    "{tag}: allgather"
                );
                assert_eq!(
                    r.comm.reduce_scatter_bytes,
                    steps * perfmodel::shard_reduce_scatter_bytes_per_step(&cfg, tp, wb),
                    "{tag}: reduce-scatter"
                );
                assert_eq!(
                    r.comm.activation_bytes,
                    steps * perfmodel::pipeline_activation_bytes_per_step(&cfg, stages),
                    "{tag}: activations"
                );
                assert_eq!(
                    r.comm.bytes_per_step(),
                    perfmodel::shard_comm_bytes_per_step(&cfg, tp, stages, wb),
                    "{tag}: total"
                );
                if tp == 1 && stages == 1 {
                    assert_eq!(r.comm.total_bytes(), 0, "unsharded run moved bytes");
                }
            }
        }
    }
    // activation volume is microbatch-count independent (the closed form
    // has no m): 2 vs 4 microbatches at the same geometry, same bytes
    let mut a_bytes = Vec::new();
    for mb in [2usize, 4] {
        let spec = shard::ShardSpec::new(2, 2).with_microbatches(mb);
        let opts = shard::ShardOpts::new(spec, WireFormat::Master);
        let r = shard::train_sharded(&be, &cfg, &tc, &corpus, &opts).unwrap();
        a_bytes.push(r.comm.activation_bytes);
    }
    assert_eq!(a_bytes[0], a_bytes[1], "activation bytes depend on microbatch count");
}

#[test]
fn sharded_checkpoint_resume_is_bit_identical_and_rejects_wrong_spec() {
    for wire in [WireFormat::Master, WireFormat::Fp8] {
        let cfg = shard_test_cfg("mus", "fixed");
        let tc6 = TrainConfig { lr: 1.0 / 128.0, ..quick_tc(6) };
        let be = ReferenceBackend::new(&[cfg.clone()]).unwrap();
        let corpus = micro_corpus(&cfg);
        let spec = shard::ShardSpec::new(2, 2);

        // uninterrupted: 6 steps straight through
        let straight = shard::train_sharded(
            &be,
            &cfg,
            &tc6,
            &corpus,
            &shard::ShardOpts::new(spec, wire),
        )
        .unwrap();

        // interrupted: save the sharded state at step 3, resume from
        // disk, finish — losses and final state must match bitwise
        // (under the FP8 wire too: owners hold wire-precision shards and
        // re-quantization is idempotent)
        let path = std::env::temp_dir().join(format!("munit_shard_ckpt_{}.bin", wire.label()));
        let tc3 = TrainConfig { steps: 3, ..tc6.clone() };
        let mut save_opts = shard::ShardOpts::new(spec, wire);
        save_opts.save_at = Some((3, path.clone()));
        let first = shard::train_sharded(&be, &cfg, &tc3, &corpus, &save_opts).unwrap();
        let mut resume_opts = shard::ShardOpts::new(spec, wire);
        resume_opts.resume_from = Some(path.clone());
        let resumed = shard::train_sharded(&be, &cfg, &tc6, &corpus, &resume_opts).unwrap();

        let mut all = first.run.losses.clone();
        all.extend(&resumed.run.losses);
        assert_eq!(all, straight.run.losses, "{}: losses diverged on resume", wire.label());
        for (i, (a, b)) in
            straight.final_state.tensors.iter().zip(&resumed.final_state.tensors).enumerate()
        {
            assert_eq!(a, b, "{}: tensor {i} not bit-identical after resume", wire.label());
        }

        // a different ShardSpec must be rejected with a contextual error
        let err = match shard::load_checkpoint(&path, &cfg, &shard::ShardSpec::new(4, 1)) {
            Ok(_) => panic!("wrong-spec resume was accepted"),
            Err(e) => e,
        };
        let msg = format!("{err:#}");
        assert!(msg.contains("cannot resume under"), "unhelpful error: {msg}");
        assert!(msg.contains("tp=2") && msg.contains("tp=4"), "error lacks geometry: {msg}");
        std::fs::remove_file(&path).ok();
    }
}

#[test]
fn state_precision_f32_lane_is_bit_identical_to_default_trainer() {
    // the f32 lane is the bit-compat default: a Trainer built through
    // the new state-precision constructor must train bitwise-identically
    // to the pre-PR `Trainer::new` path, and its state gauge reads the
    // classic 8 B/param (f32 master + f32 momentum)
    let be = reference_backend();
    let cfg = micro_config();
    let corpus = micro_corpus(&cfg);
    let t_def = Trainer::new(&be, &cfg).unwrap();
    let t_f32 = Trainer::with_state_precision(&be, &cfg, StatePrecision::F32).unwrap();
    let run = |t: &Trainer| {
        let mut b = Batcher::new(corpus.clone(), 9, 0, 1, cfg.batch, cfg.seq_len);
        let mut s = t.init(4).unwrap();
        let mut losses = Vec::new();
        for _ in 0..4 {
            losses.push(s.step(&b.next_batch(), 1.0 / 256.0, 1e-4, 0.4).unwrap().0);
        }
        assert_eq!(s.stats().state_bytes_per_param, 8.0);
        (losses, s.read_back().unwrap())
    };
    let (l_def, st_def) = run(&t_def);
    let (l_f32, st_f32) = run(&t_f32);
    assert_eq!(l_def, l_f32, "f32 state lane changed training");
    for (i, (a, b)) in st_def.tensors.iter().zip(&st_f32.tensors).enumerate() {
        assert_eq!(a, b, "tensor {i} not bit-identical on the f32 lane");
    }
}

#[test]
fn checkpoint_v2_roundtrips_through_sessions_both_precisions() {
    // satellite 3: the v2 codec is bitwise-lossless for live session
    // state under both policies (FP8-lane state is on-grid by the
    // session's normalization contract), and a session resumed from the
    // round-tripped state steps identically
    let be = reference_backend();
    let cfg = micro_config();
    for sp in [StatePrecision::F32, StatePrecision::Fp8] {
        let trainer = Trainer::with_state_precision(&be, &cfg, sp).unwrap();
        let mut b = Batcher::new(micro_corpus(&cfg), 13, 0, 1, cfg.batch, cfg.seq_len);
        let mut s = trainer.init(3).unwrap();
        for _ in 0..2 {
            s.step(&b.next_batch(), 1.0 / 256.0, 1e-4, 0.4).unwrap();
        }
        let meta = be.resolve("train_step", &cfg).unwrap();
        let specs = &meta.inputs[..2 * trainer.n_params_tensors()];
        let state = s.read_back().unwrap();
        let path = std::env::temp_dir().join(format!("munit_ckpt_v2_{}.bin", sp.label()));
        checkpoint::save_v2(&path, &state, specs, sp).unwrap();
        let restored = checkpoint::load(&path, specs).unwrap();
        std::fs::remove_file(&path).ok();
        for (i, (a, b)) in state.tensors.iter().zip(&restored.tensors).enumerate() {
            assert_eq!(a, b, "{}: tensor {i} not bit-exact through v2", sp.label());
        }
        let mut resumed = trainer.session_from(&restored).unwrap();
        let tokens = b.next_batch();
        let (l1, _) = s.step(&tokens, 1.0 / 256.0, 1e-4, 0.4).unwrap();
        let (l2, _) = resumed.step(&tokens, 1.0 / 256.0, 1e-4, 0.4).unwrap();
        assert_eq!(l1, l2, "{}: resumed session diverged", sp.label());
    }
}

#[test]
fn v1_checkpoint_loads_into_an_fp8_state_session() {
    // satellite 3: a pre-PR (v1, full-f32) checkpoint loads into an
    // FP8-state session through the same entry point — load_state snaps
    // masters/momenta onto their grids, training continues
    // deterministically, and the snapped state survives a v2 round trip
    // bit-exactly (proof it landed on-grid)
    let be = reference_backend();
    let cfg = micro_config();
    let f32_trainer = Trainer::new(&be, &cfg).unwrap();
    let mut b = Batcher::new(micro_corpus(&cfg), 17, 0, 1, cfg.batch, cfg.seq_len);
    let mut s = f32_trainer.init(6).unwrap();
    for _ in 0..2 {
        s.step(&b.next_batch(), 1.0 / 256.0, 1e-4, 0.4).unwrap();
    }
    let meta = be.resolve("train_step", &cfg).unwrap();
    let specs = &meta.inputs[..2 * f32_trainer.n_params_tensors()];
    let path = std::env::temp_dir().join("munit_ckpt_v1_to_fp8.bin");
    checkpoint::save(&path, &s.read_back().unwrap(), specs).unwrap();
    let restored = checkpoint::load(&path, specs).unwrap();
    std::fs::remove_file(&path).ok();

    let fp8_trainer = Trainer::with_state_precision(&be, &cfg, StatePrecision::Fp8).unwrap();
    let run = |state| {
        let mut sess = fp8_trainer.session_from(state).unwrap();
        let mut bb = Batcher::new(micro_corpus(&cfg), 19, 0, 1, cfg.batch, cfg.seq_len);
        let mut losses = Vec::new();
        for _ in 0..3 {
            let (l, g) = sess.step(&bb.next_batch(), 1.0 / 256.0, 1e-4, 0.4).unwrap();
            assert!(l.is_finite() && g.is_finite());
            losses.push(l);
        }
        (losses, sess.read_back().unwrap())
    };
    let (l1, st1) = run(&restored);
    let (l2, st2) = run(&restored);
    assert_eq!(l1, l2, "v1 -> fp8-state resume not deterministic");
    for (i, (a, b)) in st1.tensors.iter().zip(&st2.tensors).enumerate() {
        assert_eq!(a, b, "tensor {i} differs across identical v1 -> fp8 resumes");
    }
    let p2 = std::env::temp_dir().join("munit_ckpt_v1_to_fp8_v2.bin");
    checkpoint::save_v2(&p2, &st1, specs, StatePrecision::Fp8).unwrap();
    let rt = checkpoint::load(&p2, specs).unwrap();
    std::fs::remove_file(&p2).ok();
    for (i, (a, b)) in st1.tensors.iter().zip(&rt.tensors).enumerate() {
        assert_eq!(a, b, "tensor {i} off-grid after v1 load into fp8-state session");
    }
}

#[test]
fn fp8_state_mid_run_resume_is_bit_identical() {
    // satellite 3: save at step 3 of 6 under Fp8 state on the µS FP8
    // lane; the v2 checkpoint resume must be bit-identical to the
    // uninterrupted run (the on-grid contract makes save/load lossless)
    let cfg = ModelConfig {
        variant: "mus".into(),
        precision: "fp8".into(),
        residual: "fixed".into(),
        ..micro_config()
    };
    let be = ReferenceBackend::new(&[cfg.clone()]).unwrap();
    let trainer = Trainer::with_state_precision(&be, &cfg, StatePrecision::Fp8).unwrap();
    let corpus = micro_corpus(&cfg);
    let (lr, wd, tau) = (1.0 / 128.0, 1e-4, 0.4);

    let mut batcher = Batcher::new(corpus.clone(), 23, 0, 1, cfg.batch, cfg.seq_len);
    let mut straight = trainer.init(2).unwrap();
    let mut losses_straight = Vec::new();
    for _ in 0..6 {
        losses_straight.push(straight.step(&batcher.next_batch(), lr, wd, tau).unwrap().0);
    }
    let final_straight = straight.read_back().unwrap();

    let mut batcher = Batcher::new(corpus.clone(), 23, 0, 1, cfg.batch, cfg.seq_len);
    let mut first_half = trainer.init(2).unwrap();
    let mut losses_resumed = Vec::new();
    for _ in 0..3 {
        losses_resumed.push(first_half.step(&batcher.next_batch(), lr, wd, tau).unwrap().0);
    }
    let meta = be.resolve("train_step", &cfg).unwrap();
    let specs = &meta.inputs[..2 * trainer.n_params_tensors()];
    let path = std::env::temp_dir().join("munit_ckpt_midrun_fp8state.bin");
    checkpoint::save_v2(&path, &first_half.read_back().unwrap(), specs, StatePrecision::Fp8)
        .unwrap();
    drop(first_half);
    let restored = checkpoint::load(&path, specs).unwrap();
    std::fs::remove_file(&path).ok();
    let mut resumed = trainer.session_from(&restored).unwrap();
    for _ in 0..3 {
        losses_resumed.push(resumed.step(&batcher.next_batch(), lr, wd, tau).unwrap().0);
    }
    let final_resumed = resumed.read_back().unwrap();
    assert_eq!(losses_straight, losses_resumed, "fp8-state mid-run resume diverged");
    for (i, (a, b)) in final_straight.tensors.iter().zip(&final_resumed.tensors).enumerate() {
        assert_eq!(a, b, "fp8-state tensor {i} not bit-identical after resume");
    }
}

#[test]
fn sharded_fp8_state_resume_is_bit_identical_with_native_momentum_wire() {
    // Fp8 state + FP8 wire at tp=2/pp=2: the mid-run MUSSHRD2 save and
    // resume is bitwise lossless, comm bytes match the state-aware
    // perfmodel closed forms exactly, and the native scaled-E4M3
    // momentum leg derives its scales locally (zero amax syncs)
    let cfg = shard_test_cfg("mus", "fixed");
    let tc6 = TrainConfig { lr: 1.0 / 128.0, ..quick_tc(6) };
    let be = ReferenceBackend::new(&[cfg.clone()]).unwrap();
    let corpus = micro_corpus(&cfg);
    let spec = shard::ShardSpec::new(2, 2);
    let wire = WireFormat::Fp8;
    let opts = || shard::ShardOpts::new(spec, wire).with_state_precision(StatePrecision::Fp8);

    let straight = shard::train_sharded(&be, &cfg, &tc6, &corpus, &opts()).unwrap();
    assert_eq!(straight.comm.amax_syncs, 0, "native momentum leg synced an amax");
    let (tp, stages) = (2usize, 2usize);
    let per_step = perfmodel::param_wire_bytes_per_step(&cfg, tp, wire)
        + perfmodel::momentum_wire_bytes_per_step(&cfg, tp, wire, StatePrecision::Fp8)
        + perfmodel::pipeline_activation_bytes_per_step(&cfg, stages);
    assert_eq!(straight.comm.bytes_per_step(), per_step, "comm bytes diverge from model");

    let path = std::env::temp_dir().join("munit_shard_ckpt_fp8state.bin");
    let tc3 = TrainConfig { steps: 3, ..tc6.clone() };
    let mut save_opts = opts();
    save_opts.save_at = Some((3, path.clone()));
    let first = shard::train_sharded(&be, &cfg, &tc3, &corpus, &save_opts).unwrap();
    let mut resume_opts = opts();
    resume_opts.resume_from = Some(path.clone());
    let resumed = shard::train_sharded(&be, &cfg, &tc6, &corpus, &resume_opts).unwrap();
    std::fs::remove_file(&path).ok();

    let mut all = first.run.losses.clone();
    all.extend(&resumed.run.losses);
    assert_eq!(all, straight.run.losses, "fp8-state sharded resume diverged");
    for (i, (a, b)) in
        straight.final_state.tensors.iter().zip(&resumed.final_state.tensors).enumerate()
    {
        assert_eq!(a, b, "fp8-state shard tensor {i} not bit-identical after resume");
    }
}

#[test]
fn ddp_fp8_state_single_worker_matches_plain_fp8_trainer() {
    // the allreduce mean of one worker is the identity and the post-
    // collective re-snap is a no-op on on-grid state, so DDP x1 under
    // Fp8 state tracks the plain Fp8-state trainer bitwise; a 2-worker
    // fleet trains to finite losses on the same lane
    let be = reference_backend();
    let cfg = micro_config();
    let tc = quick_tc(3);
    let corpus = micro_corpus(&cfg);
    let sp = StatePrecision::Fp8;
    let r_ddp = ddp::train_ddp_with_precision(&be, &cfg, &tc, &corpus, 1, sp).unwrap();
    let trainer = Trainer::with_state_precision(&be, &cfg, sp).unwrap();
    let mut batcher = Batcher::new(corpus.clone(), tc.seed, 0, 1, cfg.batch, cfg.seq_len);
    let r_plain = trainer.run(&tc, &mut batcher).unwrap();
    assert_eq!(r_ddp.losses, r_plain.losses, "ddp x1 diverged from the plain fp8-state run");
    let r2 = ddp::train_ddp_with_precision(&be, &cfg, &tc, &corpus, 2, sp).unwrap();
    assert_eq!(r2.steps_done, 3);
    assert!(!r2.diverged);
    assert!(r2.losses.iter().all(|l| l.is_finite()));
}

#[test]
fn ddp_nan_in_one_worker_halts_all_in_lockstep() {
    // Divergence contract: a non-finite loss in ONE worker stops the
    // whole fleet with diverged=true BEFORE the allreduce, so the
    // healthy worker never averages in the poisoned state and every
    // session has stepped the same number of times.
    let be = reference_backend();
    let cfg = micro_config();
    let tc = quick_tc(4);
    let corpus = micro_corpus(&cfg);
    let trainer = Trainer::new(&be, &cfg).unwrap();
    let mut sessions = vec![trainer.init(0).unwrap(), trainer.init(0).unwrap()];
    let mut poisoned = sessions[1].read_back().unwrap();
    let shape = poisoned.tensors[0].shape().to_vec();
    let elems: usize = shape.iter().product();
    poisoned.tensors[0] =
        munit::runtime::tensor_f32(&vec![f32::NAN; elems], &shape).unwrap();
    sessions[1].load_state(&poisoned).unwrap();
    let pipelines: Vec<DataPipeline> = (0..sessions.len())
        .map(|w| {
            DataPipeline::spawn(
                corpus.clone(),
                tc.seed,
                w,
                sessions.len(),
                cfg.batch,
                cfg.seq_len,
                2,
                Some(tc.steps),
            )
        })
        .collect();
    let r = ddp::run_lockstep(&mut sessions, &pipelines, &tc).unwrap();
    assert!(r.diverged, "poisoned worker did not stop the run");
    assert_eq!(r.steps_done, 1, "run did not halt at the first poisoned step");
    assert!(r.losses[0].is_nan(), "averaged loss should carry the NaN");
    let healthy = sessions[0].read_back().unwrap();
    for (i, t) in healthy.tensors.iter().enumerate() {
        assert!(
            t.as_f32().unwrap().iter().all(|v| v.is_finite()),
            "poison leaked into healthy worker tensor {i}"
        );
    }
}

// ---------------------------------------------------------------------------
// artifact-gated: need `--features pjrt` + `make artifacts`

#[cfg(feature = "pjrt")]
mod pjrt_gated {
    use super::*;
    use munit::fp8;
    use munit::runtime::{scalar_f32, tensor_f32, to_f32_vec, PjrtBackend};

    fn backend() -> Option<PjrtBackend> {
        let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
        if !std::path::Path::new(dir).join("manifest.json").exists() {
            eprintln!("SKIP: artifacts not built (run `make artifacts`)");
            return None;
        }
        Some(PjrtBackend::new(dir).expect("backend"))
    }

    fn proxy_cfg() -> ModelConfig {
        ModelConfig::default() // mus_fp8_w64_d4_v512_s128_b4 — in the core set
    }

    #[test]
    fn kernels_demo_round_trip_matches_rust_fp8() {
        let Some(be) = backend() else { return };
        // inputs per manifest: x[64,32], g[32], b[32], q/k/v[2,64,16]
        let mut vals = Vec::new();
        let mut rng = munit::util::rng::Rng::new(42);
        for _ in 0..64 * 32 {
            vals.push(rng.normal_f32() * 100.0); // wide range exercises clipping
        }
        let x = tensor_f32(&vals, &[64, 32]).unwrap();
        let g = tensor_f32(&vec![1.0; 32], &[32]).unwrap();
        let b = tensor_f32(&vec![0.0; 32], &[32]).unwrap();
        let mut qkv = Vec::new();
        for _ in 0..3 {
            let mut v = vec![0f32; 2 * 64 * 16];
            rng.fill_normal(&mut v, 1.0);
            qkv.push(tensor_f32(&v, &[2, 64, 16]).unwrap());
        }
        let outs = be
            .run("kernels_demo", &[x, g, b, qkv.remove(0), qkv.remove(0), qkv.remove(0)])
            .unwrap();
        assert_eq!(outs.len(), 5);

        // cast_transpose output vs the rust fp8 module. XLA 0.5.1's CPU
        // f32->f8 convert double-rounds through bf16 (measured; DESIGN.md
        // §Numerics), so near-tie inputs may land on the *adjacent*
        // representable value.
        let ct = to_f32_vec(&outs[1]).unwrap();
        let mut near_tie = 0usize;
        for (i, (&orig, &got)) in vals.iter().zip(&ct).enumerate() {
            let want = fp8::E4M3.quantize(orig);
            if got == want {
                continue;
            }
            let q = fp8::E4M3;
            assert_eq!(q.quantize(got), got, "elem {i}: {got} not representable");
            let step = (want - got).abs();
            let mid = (want + got) / 2.0;
            let rel = ((orig.clamp(-448.0, 448.0) - mid) / step).abs();
            assert!(
                rel < 0.01,
                "elem {i}: pallas {got} vs rust {want} (input {orig}) not a near-tie"
            );
            near_tie += 1;
        }
        assert!(near_tie < vals.len() / 100, "too many mismatches: {near_tie}");
        // and ctT is the exact transpose
        let ctt = to_f32_vec(&outs[2]).unwrap();
        for r in 0..64 {
            for c in 0..32 {
                assert_eq!(ct[r * 32 + c], ctt[c * 64 + r]);
            }
        }
        // layernorm: rows ~ zero mean / unit std (gain 1, bias 0)
        let ln = to_f32_vec(&outs[0]).unwrap();
        for r in 0..64 {
            let row = &ln[r * 32..(r + 1) * 32];
            let mean: f32 = row.iter().sum::<f32>() / 32.0;
            let var: f32 = row.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / 32.0;
            assert!(mean.abs() < 1e-3, "row {r} mean {mean}");
            assert!((var - 1.0).abs() < 0.05, "row {r} var {var}");
        }
        // sqrt-softmax attention outputs have HIGHER late-position std than
        // standard attention (Fig 2 mechanics, iid inputs)
        let std_of_tail = |v: &[f32]| {
            let tail = &v[(64 - 8) * 16..]; // last positions of last head
            munit::util::stats::std(tail)
        };
        let a_std = to_f32_vec(&outs[3]).unwrap();
        let a_sqrt = to_f32_vec(&outs[4]).unwrap();
        assert!(std_of_tail(&a_sqrt) > std_of_tail(&a_std));
    }

    #[test]
    fn train_loop_loss_decreases_and_is_stable() {
        let Some(be) = backend() else { return };
        let cfg = proxy_cfg();
        let trainer = Trainer::new(&be, &cfg).unwrap();
        let mut session = trainer.init(0).unwrap();
        // overfit a single batch: loss must drop from ~ln(512)=6.24
        let mut batcher =
            Batcher::new(CorpusSpec::default(), 7, 0, 1, cfg.batch, cfg.seq_len);
        let tokens = batcher.next_batch();
        let mut first = None;
        let mut last = 0f32;
        for _ in 0..40 {
            let (loss, gnorm) = session.step(&tokens, 1.0 / 64.0, 1e-4, 0.4).unwrap();
            assert!(loss.is_finite() && gnorm.is_finite());
            first.get_or_insert(loss);
            last = loss;
        }
        let first = first.unwrap();
        assert!((first - 6.24).abs() < 0.5, "init loss {first}");
        assert!(last < first - 1.0, "no learning: {first} -> {last}");
    }

    #[test]
    fn checkpoint_roundtrip_resumes_identically() {
        let Some(be) = backend() else { return };
        let cfg = proxy_cfg();
        let trainer = Trainer::new(&be, &cfg).unwrap();
        let mut batcher =
            Batcher::new(CorpusSpec::default(), 11, 0, 1, cfg.batch, cfg.seq_len);
        let mut session = trainer.init(1).unwrap();
        let tokens = batcher.next_batch();
        session.step(&tokens, 1.0 / 256.0, 1e-4, 0.4).unwrap();

        let meta = be.manifest().find_for("train_step", &cfg).unwrap().clone();
        let specs = &meta.inputs[..2 * trainer.n_params_tensors()];
        let state = session.read_back().unwrap();
        let path = std::env::temp_dir().join("munit_ckpt_test.bin");
        checkpoint::save(&path, &state, specs).unwrap();
        let restored = checkpoint::load(&path, specs).unwrap();
        let mut resumed = trainer.session_from(&restored).unwrap();

        let tokens2 = batcher.next_batch();
        let (l1, _) = session.step(&tokens2, 1.0 / 256.0, 1e-4, 0.4).unwrap();
        let (l2, _) = resumed.step(&tokens2, 1.0 / 256.0, 1e-4, 0.4).unwrap();
        assert_eq!(l1, l2);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn probe_artifact_outputs_are_sane() {
        let Some(be) = backend() else { return };
        let cfg = proxy_cfg(); // w64 d4 has a probe artifact (actfn set, gelu)
        let trainer = Trainer::new(&be, &cfg).unwrap();
        let session = trainer.init(0).unwrap();
        let meta = be.manifest().find_for("probe", &cfg).expect("probe artifact").clone();
        let mut batcher =
            Batcher::new(CorpusSpec::default(), 1, 0, 1, cfg.batch, cfg.seq_len);
        let tokens = batcher.next_batch();
        let mut inputs = session.params_host().unwrap();
        inputs.push(munit::runtime::tensor_i32(&tokens, &[cfg.batch, cfg.seq_len]).unwrap());
        inputs.push(scalar_f32(0.4));
        let outs = be.run(&meta.name, &inputs).unwrap();
        // per manifest: attn_std, attn_sqrt_std, vcos, resid_std, underflow,
        // hist_in, hist_out, loss
        assert_eq!(outs.len(), 8);
        let resid_std = to_f32_vec(&outs[3]).unwrap();
        assert!(resid_std.iter().all(|v| *v > 0.5 && *v < 2.0), "stream not unit scale");
        let hist_in = to_f32_vec(&outs[5]).unwrap();
        let nb = hist_in.len() / cfg.depth;
        for l in 0..cfg.depth {
            let s: f32 = hist_in[l * nb..(l + 1) * nb].iter().sum();
            assert!((s - 1.0).abs() < 1e-3, "layer {l} hist sums to {s}");
        }
        let under = to_f32_vec(&outs[4]).unwrap();
        assert!(under.iter().all(|v| (0.0..=1.0).contains(v)));
    }
}
