//! Integration gates for the static-analysis layer (`munit lint` /
//! `munit verify-numerics`): every lint rule must fire on its negative
//! fixture, the real tree must be clean, the verifier's mutation
//! self-tests must flag every corrupted rule set, and the hardened
//! decode path must return contextual errors instead of panicking.

use std::collections::BTreeSet;
use std::path::{Path, PathBuf};

use munit::analysis::lint;
use munit::analysis::static_numerics as sn;
use munit::coordinator::trainer::Trainer;
use munit::runtime::{InferSession, ReferenceBackend};

fn fixture_path(name: &str) -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("rust/tests/lint_fixtures").join(name)
}

fn fixture(name: &str) -> String {
    let path = fixture_path(name);
    std::fs::read_to_string(&path).unwrap_or_else(|e| panic!("reading {}: {e}", path.display()))
}

/// Every rule in [`lint::RULES`] has a negative fixture that trips it
/// when linted under an in-scope file label.
#[test]
fn every_lint_rule_fires_on_its_fixture() {
    let cases = [
        ("f32-accumulator", "telemetry/mod.rs", "f32_accum.rs"),
        ("hashmap-iteration", "runtime/infer.rs", "hashmap_iter.rs"),
        ("hot-path-unwrap", "runtime/infer.rs", "hot_unwrap.rs"),
        ("unpaired-cast", "runtime/infer.rs", "unpaired_cast.rs"),
        ("kernel-entropy", "runtime/gemm/kernels.rs", "kernel_entropy.rs"),
        ("stray-intrinsic", "runtime/infer.rs", "stray_intrinsic.rs"),
        ("missing-scalar-twin", "runtime/gemm/kernels.rs", "missing_scalar_twin.rs"),
    ];
    let mut covered = BTreeSet::new();
    for (rule, label, file) in cases {
        let fired: BTreeSet<&'static str> =
            lint::lint_source(label, &fixture(file)).into_iter().map(|v| v.rule).collect();
        assert!(fired.contains(rule), "{file} under {label}: expected {rule}, fired {fired:?}");
        covered.insert(rule);
    }
    let all: BTreeSet<&'static str> = lint::RULES.iter().map(|r| r.name).collect();
    assert_eq!(covered, all, "fixture set must exercise every registered rule");
}

/// The path-scoped rules stay silent when the same sources carry an
/// out-of-scope label — scope is part of the contract, not decoration.
#[test]
fn fixtures_are_clean_outside_their_rule_scope() {
    assert!(
        lint::lint_source("repro/mod.rs", &fixture("hashmap_iter.rs")).is_empty(),
        "hashmap iteration is allowed outside the numerics paths"
    );
    assert!(
        lint::lint_source("util/mod.rs", &fixture("hot_unwrap.rs")).is_empty(),
        "unwrap is allowed outside the hot files"
    );
    assert!(
        lint::lint_source("util/mod.rs", &fixture("kernel_entropy.rs")).is_empty(),
        "timing is allowed outside kernel files"
    );
    assert!(
        lint::lint_source("runtime/gemm/mod.rs", &fixture("f32_accum.rs")).is_empty(),
        "gemm's f32 folds are blessed"
    );
    assert!(
        lint::lint_source("runtime/gemm/kernels.rs", &fixture("stray_intrinsic.rs")).is_empty(),
        "intrinsics are allowed in the blessed kernel file"
    );
}

/// The actual source tree satisfies its own determinism contract —
/// this is the same scan `munit lint` runs in CI.
#[test]
fn the_real_tree_is_lint_clean() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("rust/src");
    let (files, violations) = lint::lint_tree(&root).expect("lint_tree");
    assert!(files > 20, "unexpectedly few files scanned: {files}");
    assert!(
        violations.is_empty(),
        "determinism-contract violations:\n{}",
        violations
            .iter()
            .map(|v| format!("  {} {}:{}  {}", v.rule, v.file, v.line, v.excerpt))
            .collect::<Vec<_>>()
            .join("\n")
    );
}

/// The verifier passes on the real rules and flags every mutation —
/// exercised through the same public API the CLI uses.
#[test]
fn verifier_passes_real_rules_and_flags_every_mutation() {
    let spec = sn::VerifySpec::smoke();
    assert!(sn::verify(&spec, "mus").expect("verify mus").pass);
    assert!(sn::verify(&spec, "sp").expect("verify sp").pass);
    for m in sn::MUTATIONS {
        let v = sn::verify_with(&spec, "mus", m).expect("verify_with");
        assert!(!v.pass, "mutation {} was not flagged", m.name());
        assert!(v.checks.iter().any(|c| !c.pass), "mutation {} fired no check", m.name());
    }
}

/// Regression for the hot-path hardening: unknown/freed sequence ids in
/// the decode path must come back as contextual errors, never panics.
#[test]
fn decode_path_errors_are_contextual_not_panics() {
    let spec = sn::VerifySpec::smoke();
    let cfg = spec.model("mus", spec.widths[0]).expect("model");
    let backend = ReferenceBackend::new(&[]).expect("backend");
    let trainer = Trainer::new(&backend, &cfg).expect("trainer");
    let session = trainer.init(0).expect("init");
    let params = session.params_host().expect("params");
    let mut infer = InferSession::new(&cfg, &params, spec.tau as f32).expect("infer session");

    let id = infer.add_sequence();
    infer.free_sequence(id).expect("first free succeeds");
    let err = infer.free_sequence(id).expect_err("double free must fail");
    assert!(format!("{err:#}").contains("unknown sequence"), "uncontextual error: {err:#}");
    let err = infer.decode_step(id, 1).expect_err("decode on freed id must fail");
    assert!(format!("{err:#}").contains("unknown sequence"), "uncontextual error: {err:#}");
    let err = infer.prefill(id, &[1, 2, 3]).expect_err("prefill on freed id must fail");
    assert!(format!("{err:#}").contains("unknown sequence"), "uncontextual error: {err:#}");
}
