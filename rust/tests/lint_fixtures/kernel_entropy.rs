//! Lint fixture (never compiled): wall-clock reads inside a kernel
//! file — kernels must be pure functions of their inputs. Expected:
//! `kernel-entropy` fires on the timing line.

pub fn timed_matmul(a: &[f32], b: &[f32]) -> u128 {
    let t0 = std::time::Instant::now();
    let _ = (a.len(), b.len());
    t0.elapsed().as_nanos()
}
