//! Lint fixture (never compiled): a SIMD intrinsic called outside the
//! blessed `runtime/gemm/kernels.rs` — intrinsics anywhere else bypass
//! the scalar-twin review. Expected: `stray-intrinsic` fires on the
//! `_mm256_` line (and the `core::arch` import line).

use core::arch::x86_64::_mm256_setzero_ps;

pub fn sneaky_simd_sum(a: &[f32]) -> f32 {
    let _acc = unsafe { _mm256_setzero_ps() };
    a.len() as f32
}
