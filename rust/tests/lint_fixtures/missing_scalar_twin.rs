//! Lint fixture (never compiled): a `#[target_feature]` kernel with no
//! `*_scalar` twin in the file — the bit-equality suite would have no
//! reference to diff it against, and non-x86 builds no fallback.
//! Expected: `missing-scalar-twin` fires on the `fn sum8_avx2` line.

#[target_feature(enable = "avx2")]
pub unsafe fn sum8_avx2(a: &[f32]) -> f32 {
    a.len() as f32
}
