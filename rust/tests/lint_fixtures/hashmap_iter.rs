//! Lint fixture (never compiled): iterating a HashMap inside a numerics
//! path — iteration order is not deterministic. Expected:
//! `hashmap-iteration` fires on the `.iter()` loop.

use std::collections::HashMap;

pub fn occupancy() -> usize {
    let mut seqs: HashMap<u64, usize> = HashMap::new();
    seqs.insert(1, 4);
    let mut total = 0usize;
    for (_id, len) in seqs.iter() {
        total += len;
    }
    total
}
