//! Lint fixture (never compiled): a quantization-slot read with no
//! CastHealth pairing in the preceding window. Expected:
//! `unpaired-cast` fires on the `plan.qkv` line. (This mention of
//! observe_cast lives in a comment, which the code view blanks — it
//! must NOT count as the pairing.)

pub fn forward_qkv(x: &[f32], prep: &Prepared) -> Vec<f32> {
    op_linear(x, prep.plan.qkv)
}
