//! Lint fixture (never compiled): an f32 running sum outside the
//! blessed gemm/collective folds. Expected: `f32-accumulator` fires on
//! the `+=` line.

pub fn mean(xs: &[f32]) -> f32 {
    let mut acc = 0f32;
    for &x in xs {
        acc += x;
    }
    acc / xs.len().max(1) as f32
}
