//! Lint fixture (never compiled): panicking Option/Result handling in a
//! step/decode hot file. Expected: `hot-path-unwrap` fires on both the
//! `.unwrap()` and the `.expect(` lines.

pub fn last_token(tokens: &[i32]) -> i32 {
    *tokens.last().unwrap()
}

pub fn first_token(tokens: &[i32]) -> i32 {
    *tokens.first().expect("empty token buffer")
}
