// Round-trip smoke: load an HLO text file, compile on PJRT CPU, run with
// fixed 2x2 f32 inputs, print the outputs.
//
// Findings encoded here (see rust/src/runtime):
//  - executables return ONE tuple buffer (PJRT 0.5.1 does not untuple);
//  - a tuple Literal must be decompose_tuple()'d — to_vec on it aborts.
use xla::{HloModuleProto, Literal, PjRtClient, Shape, XlaComputation};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let path = std::env::args().nth(1).expect("usage: hlo_check <hlo.txt>");
    let client = PjRtClient::cpu()?;
    let proto = HloModuleProto::from_text_file(&path)?;
    let comp = XlaComputation::from_proto(&proto);
    let exe = client.compile(&comp)?;
    let x = Literal::vec1(&[1f32, 2., 3., 4.]).reshape(&[2, 2])?;
    let w = Literal::vec1(&[1f32, 1., 1., 1.]).reshape(&[2, 2])?;
    let res = exe.execute::<Literal>(&[x, w])?;
    println!("n_replicas={} n_outputs={}", res.len(), res[0].len());
    let mut lit = res[0][0].to_literal_sync()?;
    let parts = match lit.shape()? {
        Shape::Tuple(_) => lit.decompose_tuple()?,
        _ => vec![lit],
    };
    for (j, p) in parts.iter().enumerate() {
        println!("out[{j}] shape={:?} vals={:?}", p.shape()?, p.to_vec::<f32>()?);
    }
    Ok(())
}
