//! Analytic H100 training-throughput model (Fig 8 substitute).
//!
//! We cannot benchmark 64 H100s, so Fig 8 is reproduced from a first-
//! principles cost model whose *structure* encodes exactly the effects the
//! paper describes in §3.3:
//!
//!  - hidden-linear GEMMs run at the BF16 or FP8 tensor-core rate;
//!  - attention, layernorms, residuals, optimizer stay BF16 (same cost in
//!    every variant);
//!  - FP8 paths pay a fused clip+cast+transpose pass per GEMM operand
//!    (the paper's Triton kernel; same for TE and µS);
//!  - **TE additionally pays a per-tensor amax reduction** (a full memory
//!    pass over every weight/activation/gradient tensor) plus per-tensor
//!    scale bookkeeping — the overhead µS's static scaling deletes;
//!  - gradient allreduce over the DDP group is identical across variants.
//!
//! Peak numbers are public H100 SXM specs; efficiency factors are set to
//! realistic MFU values and the *ratios* (what Fig 8 reports) are robust to
//! them (tested).

use crate::config::presets::PaperConfig;
use crate::config::ModelConfig;
use crate::coordinator::collective::WireFormat;
use crate::runtime::{block, kvcache, StatePrecision};

/// Hardware description (H100 SXM defaults).
#[derive(Debug, Clone)]
pub struct Hw {
    /// Peak dense BF16 tensor-core TFLOP/s.
    pub bf16_tflops: f64,
    /// Peak dense FP8 tensor-core TFLOP/s.
    pub fp8_tflops: f64,
    /// Peak HBM bandwidth, TB/s.
    pub hbm_tbps: f64,
    /// Achievable fraction of peak for large GEMMs.
    pub gemm_eff_bf16: f64,
    /// FP8 GEMMs reach a smaller fraction of their (2x) peak.
    pub gemm_eff_fp8: f64,
    /// Achievable fraction of HBM bandwidth for streaming kernels.
    pub mem_eff: f64,
    /// Fixed cost per extra kernel launch (bookkeeping), seconds.
    pub launch_s: f64,
    /// Allreduce bus bandwidth per GPU (NVLink ring), bytes/s.
    pub allreduce_bps: f64,
    /// GPUs in the data-parallel group.
    pub n_gpus: usize,
}

impl Default for Hw {
    fn default() -> Self {
        Hw {
            bf16_tflops: 989.0,
            fp8_tflops: 1979.0,
            hbm_tbps: 3.35,
            // Measured reality on H100: large BF16 GEMMs reach ~72% of
            // peak; FP8 cublasLt GEMMs reach only ~53% of their 2x peak
            // (epilogue + accumulation limits), i.e. a ~1.47x realized
            // GEMM speedup — which, after the BF16-resident attention/head
            // and cast traffic, bounds the end-to-end gain at the paper's
            // 25-33%.
            gemm_eff_bf16: 0.72,
            gemm_eff_fp8: 0.53,
            mem_eff: 0.75,
            launch_s: 4e-6,
            allreduce_bps: 200e9,
            n_gpus: 64,
        }
    }
}

/// Precision/scaling mode of a training run (Fig 8's three bars).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    /// BF16 mixed precision (no FP8 anywhere).
    Bf16,
    /// FP8 with TransformerEngine-style dynamic (amax) scaling.
    Fp8Te,
    /// FP8 with µS static scaling.
    Fp8Mus,
}

impl Mode {
    /// Bar label used by the Fig 8 tables.
    pub fn label(&self) -> &'static str {
        match self {
            Mode::Bf16 => "BF16",
            Mode::Fp8Te => "FP8 (TE)",
            Mode::Fp8Mus => "FP8 (µS)",
        }
    }
}

/// Per-step time breakdown (seconds).
#[derive(Debug, Clone)]
pub struct StepTime {
    /// Hidden-linear GEMMs (the FP8-eligible compute).
    pub gemm: f64,
    /// Attention score/value + embedding/head GEMMs (always BF16).
    pub attention: f64,
    /// FP8 operand cast passes (zero in BF16 mode).
    pub cast: f64,
    /// TE-only per-tensor amax reductions.
    pub amax: f64,
    /// TE-only per-tensor scale bookkeeping launches.
    pub bookkeeping: f64,
    /// Norms/residuals/RoPE/softmax/activation/optimizer memory traffic.
    pub elementwise: f64,
    /// Gradient allreduce over the DDP group.
    pub allreduce: f64,
}

impl StepTime {
    /// Total modeled step time (sum of every term).
    pub fn total(&self) -> f64 {
        self.gemm + self.attention + self.cast + self.amax + self.bookkeeping
            + self.elementwise + self.allreduce
    }
}

/// Model one training step of a paper-scale config under `mode`.
pub fn step_time(hw: &Hw, p: &PaperConfig, mode: Mode) -> StepTime {
    let m = crate::config::presets::paper_model(p);
    let d = p.width as f64;
    let f = 4.0 * d;
    let l = p.depth as f64;
    let s = p.seq_len as f64;
    let tokens_per_gpu = (p.batch as f64 * s) / hw.n_gpus as f64;
    let seqs_per_gpu = p.batch as f64 / hw.n_gpus as f64;

    // --- hidden GEMMs: qkv, attn-out, ffn-up, ffn-down; fwd + dgrad +
    // wgrad. The per-token forward count is enumerated from the runtime
    // block's *actual* GEMM shapes (tested equal to the ModelConfig
    // closed-form).
    let gemm_flops_per_tok = block::hidden_gemm_flops_per_token_fwd(&m) as f64; // fwd
    let gemm_flops = 3.0 * gemm_flops_per_tok * tokens_per_gpu * l;
    let gemm_rate = match mode {
        Mode::Bf16 => hw.bf16_tflops * hw.gemm_eff_bf16,
        _ => hw.fp8_tflops * hw.gemm_eff_fp8,
    } * 1e12;
    let gemm = gemm_flops / gemm_rate;

    // --- attention score/value GEMMs AND the embedding/LM-head GEMMs stay
    // BF16 in all modes (paper: only hidden linear layers are FP8); the
    // per-sequence count is the exact causal sum 2·d·s·(s+1)
    let vocab = m.vocab as f64;
    let attn_flops = 3.0 * (block::attn_gemm_flops_per_seq_fwd(&m) as f64) * seqs_per_gpu * l;
    let head_flops = 3.0 * (2.0 * d * vocab) * tokens_per_gpu;
    let attention =
        (attn_flops + head_flops) / (hw.bf16_tflops * hw.gemm_eff_bf16 * 1e12);

    // --- FP8 casts: each of the 4 hidden GEMMs needs its two operands in
    // FP8, in both layouts across fwd/bwd. Fused clip+cast+transpose does
    // one read (bf16) + two writes (fp8) per tensor, and for activations/
    // gradients half the reads fold into the producing kernel's epilogue
    // (the fusion both TE and µS implement, §3.3) — net ~2 bytes/elem.
    let act_bytes = |elems: f64| elems * 2.0; // amortized epilogue-fused cost
    let act_elems_per_tok = d + d + d + f; // inputs of qkv/o/up/down
    let grad_elems_per_tok = 3.0 * d + d + f + d; // grads at outputs
    let weight_elems = d * 3.0 * d + d * d + d * f + f * d;
    let cast_bytes = (act_bytes(act_elems_per_tok * tokens_per_gpu)
        + act_bytes(grad_elems_per_tok * tokens_per_gpu)
        + act_bytes(weight_elems))
        * l;
    let mem_rate = hw.hbm_tbps * 1e12 * hw.mem_eff;
    let cast = match mode {
        Mode::Bf16 => 0.0,
        _ => cast_bytes / mem_rate,
    };

    // --- TE-only: amax reduction = one full bf16 read per FP8 tensor, plus
    // scale bookkeeping launches (8 act/grad tensors + 4 weights per layer)
    let (amax, bookkeeping) = if mode == Mode::Fp8Te {
        let amax_bytes = ((act_elems_per_tok + grad_elems_per_tok) * tokens_per_gpu * 2.0
            + weight_elems * 2.0)
            * l;
        let n_tensors = 12.0 * l;
        (amax_bytes / mem_rate, n_tensors * hw.launch_s)
    } else {
        (0.0, 0.0)
    };

    // --- elementwise BF16 traffic (LN x2, residual x2, rope, softmax,
    // activation, optimizer): ~16 read+write passes over [tokens, d] per
    // layer plus the Lion update over all params
    let ew_bytes = 16.0 * (tokens_per_gpu * d * 4.0) * l
        + 3.0 * 4.0 * paper_params(p) / hw.n_gpus as f64;
    let elementwise = ew_bytes / mem_rate;

    // --- gradient allreduce (bf16), ring: 2x bytes over bus bw
    let allreduce = 2.0 * (paper_params(p) * 2.0 / hw.n_gpus as f64) / hw.allreduce_bps;

    StepTime { gemm, attention, cast, amax, bookkeeping, elementwise, allreduce }
}

fn paper_params(p: &PaperConfig) -> f64 {
    p.params_b * 1e9
}

/// Throughput in tokens/s across the whole cluster.
pub fn throughput(hw: &Hw, p: &PaperConfig, mode: Mode) -> f64 {
    let t = step_time(hw, p, mode).total();
    (p.batch as f64 * p.seq_len as f64) / t
}

/// One Fig 8 row.
#[derive(Debug, Clone)]
pub struct Fig8Row {
    /// Model-size label (Table 4 row name).
    pub size: &'static str,
    /// Cluster tokens/s under BF16.
    pub bf16: f64,
    /// Cluster tokens/s under TE-style dynamic FP8.
    pub te: f64,
    /// Cluster tokens/s under µS static FP8.
    pub mus: f64,
}

impl Fig8Row {
    /// µS speedup over the BF16 baseline (paper: 25-33%).
    pub fn mus_over_bf16(&self) -> f64 {
        self.mus / self.bf16
    }
    /// µS speedup over TE dynamic scaling (paper: 1-6%).
    pub fn mus_over_te(&self) -> f64 {
        self.mus / self.te
    }
}

/// Reproduce Fig 8 over the paper's Table 4 configs.
pub fn fig8(hw: &Hw) -> Vec<Fig8Row> {
    crate::config::presets::paper_table4()
        .iter()
        .map(|p| Fig8Row {
            size: p.name,
            bf16: throughput(hw, p, Mode::Bf16),
            te: throughput(hw, p, Mode::Fp8Te),
            mus: throughput(hw, p, Mode::Fp8Mus),
        })
        .collect()
}

// ---------------------------------------------------------------------------
// Decode-step cost model (the inference roofline)
//
// Autoregressive decode does ~2 FLOPs per weight per token but must
// stream every weight byte and the whole KV cache each step — at serving
// batch sizes it is bandwidth-bound, not compute-bound. The per-token
// work is consumed from the SAME op-level enumerations the runtime
// executes (block hidden-GEMM shapes, the single-query attention kernel
// shape, the kvcache byte layout) — nothing re-derived here, and a test
// pins each term to the `ModelConfig` closed forms exactly, mirroring
// how the training FLOPs were pinned.

/// FLOPs for ONE decode token at context length `ctx`: the four hidden
/// GEMVs per block + single-query attention per block + the LM head.
pub fn decode_flops_per_token(cfg: &ModelConfig, ctx: usize) -> u64 {
    let l = cfg.depth as u64;
    let hidden = block::hidden_gemm_flops_per_token_fwd(cfg) * l;
    let attn = block::attn_decode_flops_per_token(cfg, ctx) * l;
    let head = 2 * (cfg.width * cfg.vocab) as u64;
    hidden + attn + head
}

/// KV-cache bytes READ by one decode token at context `ctx` (BF16 pages,
/// every layer's full K and V — the `runtime::kvcache` layout).
pub fn decode_kv_bytes_per_token(cfg: &ModelConfig, ctx: usize) -> u64 {
    kvcache::kv_bytes_read_per_token(cfg, ctx)
}

/// [`decode_kv_bytes_per_token`] at `bytes_per_value` bytes per stored
/// cache value (2 = BF16, 1 = the E4M3 KV-cache mode — which therefore
/// halves the decode roofline's KV-streaming term).
pub fn decode_kv_bytes_per_token_at(cfg: &ModelConfig, ctx: usize, bytes_per_value: usize) -> u64 {
    kvcache::kv_bytes_read_per_token_at(cfg, ctx, bytes_per_value)
}

/// FLOPs of a prefill pass computing `new_tokens` prompt positions on
/// top of `cached` positions already in the KV cache (prefix-cache
/// adoption): the four hidden GEMMs per new token per layer, causal
/// attention where new row `i` scores and mixes `cached + i + 1` keys
/// over all heads, and the LM head per new row. At `cached = 0`,
/// `new = s`, the attention term telescopes to the training tower's
/// `2·d·s·(s+1)` per layer — whole-prompt, chunked, and prefix-adopted
/// prefill all sum to this same closed form, and the runtime's op-site
/// counter (`InferStats::prefill_flops`) is pinned to it exactly.
pub fn prefill_flops(cfg: &ModelConfig, new_tokens: usize, cached: usize) -> u64 {
    let l = cfg.depth as u64;
    let (n, p) = (new_tokens as u64, cached as u64);
    let d = cfg.width as u64;
    let hidden = block::hidden_gemm_flops_per_token_fwd(cfg) * n * l;
    let attn = 4 * d * (n * p + n * (n + 1) / 2) * l;
    let head = 2 * d * cfg.vocab as u64 * n;
    hidden + attn + head
}

/// KV-cache bytes READ by a chunked/adopted prefill of `new_tokens`
/// rows on `cached` positions at `bytes_per_value` bytes per value: row
/// `i` gathers `cached + i + 1` K and V rows per (layer, head). Zero
/// for the whole-prompt tower prefill, which attends from activations
/// rather than the cache.
pub fn prefill_kv_bytes_read(
    cfg: &ModelConfig,
    new_tokens: usize,
    cached: usize,
    bytes_per_value: usize,
) -> u64 {
    let (n, p) = (new_tokens as u64, cached as u64);
    kvcache::kv_bytes_written_per_token_at(cfg, bytes_per_value) * (n * p + n * (n + 1) / 2)
}

/// Weight bytes streamed per decode step (read once per step, amortized
/// across the batch): the four hidden linears at their storage width
/// (FP8 = 1 byte in the FP8 modes, BF16 = 2 otherwise), embedding / head
/// / norm gains at BF16 in every mode (paper Table 1).
pub fn decode_weight_bytes(cfg: &ModelConfig, mode: Mode) -> u64 {
    let (d, f, v, l) = (cfg.width, cfg.ffn_width(), cfg.vocab, cfg.depth);
    let hidden_elems = (l * (d * 3 * d + d * d + d * f + f * d)) as u64;
    let other_elems = (cfg.n_params() - (hidden_elems as usize)) as u64;
    let hidden_bytes = match mode {
        Mode::Bf16 => 2,
        _ => 1,
    };
    hidden_elems * hidden_bytes + other_elems * 2
}

/// Per-step decode time breakdown (seconds) for one GPU serving `batch`
/// live sequences at context `ctx`.
#[derive(Debug, Clone)]
pub struct DecodeTime {
    /// Compute term: GEMV + attention FLOPs at the mode's tensor-core rate.
    pub compute: f64,
    /// Weight streaming (read once per step, all live sequences share it).
    pub weight_read: f64,
    /// KV-cache streaming (scales with batch × context).
    pub kv_read: f64,
    /// TE-only per-tensor scale bookkeeping launches (µS deletes these at
    /// serving time too — static scales ship with the weights).
    pub bookkeeping: f64,
}

impl DecodeTime {
    /// Roofline total: compute overlaps memory; bookkeeping does not.
    pub fn total(&self) -> f64 {
        self.compute.max(self.weight_read + self.kv_read) + self.bookkeeping
    }
}

/// Model one batched decode step of a paper-scale config under `mode`.
pub fn decode_step_time(
    hw: &Hw,
    p: &PaperConfig,
    mode: Mode,
    ctx: usize,
    batch: usize,
) -> DecodeTime {
    let m = crate::config::presets::paper_model(p);
    let flops = decode_flops_per_token(&m, ctx) as f64 * batch as f64;
    let rate = match mode {
        Mode::Bf16 => hw.bf16_tflops * hw.gemm_eff_bf16,
        _ => hw.fp8_tflops * hw.gemm_eff_fp8,
    } * 1e12;
    let mem_rate = hw.hbm_tbps * 1e12 * hw.mem_eff;
    let bookkeeping = if mode == Mode::Fp8Te {
        // per-tensor amax/scale updates on the 8 act tensors per layer
        (8 * p.depth) as f64 * hw.launch_s
    } else {
        0.0
    };
    DecodeTime {
        compute: flops / rate,
        weight_read: decode_weight_bytes(&m, mode) as f64 / mem_rate,
        kv_read: (decode_kv_bytes_per_token(&m, ctx) as f64 * batch as f64) / mem_rate,
        bookkeeping,
    }
}

/// Steady-state generated tokens/sec for one GPU at (`ctx`, `batch`).
pub fn decode_tokens_per_sec(
    hw: &Hw,
    p: &PaperConfig,
    mode: Mode,
    ctx: usize,
    batch: usize,
) -> f64 {
    batch as f64 / decode_step_time(hw, p, mode, ctx, batch).total()
}

/// Per-GPU memory estimate (bytes) under FSDP full sharding: bf16 params +
/// bf16 grads + f32 master + f32 Lion momentum all sharded, plus activation
/// checkpoints (one bf16 residual-stream tensor per layer per local batch).
pub fn memory_per_gpu(p: &PaperConfig, n_gpus: usize) -> f64 {
    let params = paper_params(p);
    let sharded = params * (2.0 + 2.0 + 4.0 + 4.0) / n_gpus as f64;
    let acts = (p.batch as f64 / n_gpus as f64)
        * p.seq_len as f64
        * p.width as f64
        * 2.0
        * p.depth as f64;
    sharded + acts
}

// ---------------------------------------------------------------------------
// Sharded-execution communication model
//
// Closed forms for the wire traffic of `coordinator::shard::train_sharded`,
// exact-match tested against that module's runtime byte counters (the
// counters iterate actual shard tensors and the actual GPipe slot table;
// these formulas are derived independently from the model geometry, so
// agreement is a real cross-check, not a tautology).

/// Elements of the TP-sharded tensors — the four hidden linears across
/// all layers: `depth · (4d² + 2df)` with `f = ffn_width`. Everything
/// else (embedding, head, norm gains) is replicated, never on the wire.
pub fn tp_sharded_param_elems(cfg: &ModelConfig) -> u64 {
    let (d, f) = (cfg.width as u64, cfg.ffn_width() as u64);
    cfg.depth as u64 * (4 * d * d + 2 * d * f)
}

/// Allgather wire bytes per training step at TP degree `tp` with
/// `wire_bytes` per element (4 = master, 1 = FP8): every rank receives
/// the other `tp-1` ranks' shards for BOTH the parameter and the
/// momentum copy of each sharded tensor, and the `tp` shards of one
/// tensor partition it exactly — so the sum telescopes to
/// `(tp-1) · 2 · P_s · wire_bytes`, independent of how the shards are
/// sliced. Zero at `tp = 1` (nothing to exchange).
pub fn shard_allgather_bytes_per_step(cfg: &ModelConfig, tp: usize, wire_bytes: usize) -> u64 {
    if tp <= 1 {
        return 0;
    }
    (tp as u64 - 1) * 2 * tp_sharded_param_elems(cfg) * wire_bytes as u64
}

/// Reduce-scatter wire bytes per training step — same volume as the
/// allgather (each element crosses the wire once per non-owner rank).
pub fn shard_reduce_scatter_bytes_per_step(cfg: &ModelConfig, tp: usize, wire_bytes: usize) -> u64 {
    shard_allgather_bytes_per_step(cfg, tp, wire_bytes)
}

/// Pipeline stage-boundary activation bytes per step: the GPipe
/// timetable crosses a boundary `2·m·(stages-1)` times (once forward,
/// once backward per microbatch per interior boundary), each carrying a
/// `[batch/m, seq, width]` f32 activation — the microbatch count `m`
/// cancels: `2 · (stages-1) · batch · seq · width · 4`.
pub fn pipeline_activation_bytes_per_step(cfg: &ModelConfig, stages: usize) -> u64 {
    if stages <= 1 {
        return 0;
    }
    2 * (stages as u64 - 1) * (cfg.batch * cfg.seq_len * cfg.width) as u64 * 4
}

/// Total sharded-run wire bytes per step: TP collectives (both legs)
/// plus pipeline activations. Exactly zero at `tp = 1, stages = 1`.
pub fn shard_comm_bytes_per_step(
    cfg: &ModelConfig,
    tp: usize,
    stages: usize,
    wire_bytes: usize,
) -> u64 {
    shard_allgather_bytes_per_step(cfg, tp, wire_bytes)
        + shard_reduce_scatter_bytes_per_step(cfg, tp, wire_bytes)
        + pipeline_activation_bytes_per_step(cfg, stages)
}

// ---------------------------------------------------------------------------
// State-precision byte model
//
// Closed forms for what `runtime::StatePrecision` costs, derived from
// the model geometry alone and exact-match tested against the live
// counters: the session's `ExecStats` state gauges, real checkpoint
// file sizes (`std::fs::metadata`), and the `Collectives` wire byte
// counters. Per-tensor scale exponents are O(n_tensors) metadata — the
// state gauge excludes them (they live in no per-element array), while
// the checkpoint and wire forms count them where they become real
// bytes on disk / on the wire.

/// Total parameter elements, enumerated from the runtime block's param
/// specs (the same list sessions and checkpoints iterate).
pub fn total_param_elems(cfg: &ModelConfig) -> u64 {
    block::param_specs(cfg).iter().map(|s| s.elements() as u64).sum()
}

/// Optimizer + master state bytes a session holds under `sp`: every
/// parameter element carries a master copy and a Lion momentum copy
/// (f32+f32 = 8 B, or BF16+E4M3 = 3 B under FP8 state). Exactly the
/// session's `ExecStats::state_bytes` gauge.
pub fn state_bytes(cfg: &ModelConfig, sp: StatePrecision) -> u64 {
    total_param_elems(cfg) * sp.bytes_per_param_elem()
}

/// On-disk bytes of a v1 (`MUSCKPT1`) checkpoint: 8 B magic + 4 B count,
/// then params and their `m_`-prefixed momenta each at
/// `4 + name + 4 + 8·ndim` of header and 4 B/elem of payload.
pub fn checkpoint_v1_bytes(cfg: &ModelConfig) -> u64 {
    let mut total = 8 + 4;
    for s in block::param_specs(cfg) {
        let header = 4 + s.name.len() as u64 + 4 + 8 * s.shape.len() as u64;
        let m_header = header + 2; // the "m_" prefix
        total += header + m_header + 2 * 4 * s.elements() as u64;
    }
    total
}

/// On-disk bytes of a v2 (`MUSCKPT2`) checkpoint under `sp`: 9 B magic +
/// precision + 4 B count, per-tensor headers gain a codec byte, and
/// payloads shrink to their native width — 2 B/elem BF16 masters and
/// `4 + 1 B/elem` scaled-E4M3 momenta under FP8 state.
pub fn checkpoint_v2_bytes(cfg: &ModelConfig, sp: StatePrecision) -> u64 {
    let (master_payload, momentum_payload): (u64, u64) = match sp {
        StatePrecision::F32 => (4, 4),
        StatePrecision::Fp8 => (2, 1),
    };
    let momentum_scale = if sp == StatePrecision::Fp8 { 4 } else { 0 };
    let mut total = 8 + 1 + 4;
    for s in block::param_specs(cfg) {
        let header = 4 + s.name.len() as u64 + 4 + 8 * s.shape.len() as u64 + 1;
        let elems = s.elements() as u64;
        total += header + master_payload * elems;
        total += header + 2 + momentum_payload * elems + momentum_scale;
    }
    total
}

/// TP-sharded *momentum* tensor count across all ranks: each rank owns a
/// shard of the 4 hidden linears per layer.
fn sharded_momentum_tensors(cfg: &ModelConfig, tp: usize) -> u64 {
    tp as u64 * 4 * cfg.depth as u64
}

/// Parameter-half wire bytes per sharded training step (both collective
/// legs): `2 · (tp-1) · P_s · wire_bytes` — unchanged by the state
/// policy, since parameters always cross as static-scale E4M3 on the
/// FP8 wire.
pub fn param_wire_bytes_per_step(cfg: &ModelConfig, tp: usize, wire: WireFormat) -> u64 {
    if tp <= 1 {
        return 0;
    }
    2 * (tp as u64 - 1) * tp_sharded_param_elems(cfg) * wire.bytes_per_elem()
}

/// Momentum-half wire bytes per sharded training step (both legs).
/// Under the FP8 wire this is 1 B/elem regardless of state policy —
/// f32 state re-casts to E5M2, FP8 state ships its native scaled-E4M3
/// bytes — but the native leg adds 4 B of locally-derived scale
/// exponent per sharded momentum tensor per receiving rank (and still
/// zero amax syncs). Exactly the `Collectives` counters' momentum share.
pub fn momentum_wire_bytes_per_step(
    cfg: &ModelConfig,
    tp: usize,
    wire: WireFormat,
    sp: StatePrecision,
) -> u64 {
    if tp <= 1 {
        return 0;
    }
    let payload = 2 * (tp as u64 - 1) * tp_sharded_param_elems(cfg) * wire.bytes_per_elem();
    match (wire, sp) {
        (WireFormat::Fp8, StatePrecision::Fp8) => {
            payload + 2 * (tp as u64 - 1) * 4 * sharded_momentum_tensors(cfg, tp)
        }
        _ => payload,
    }
}

// ---------------------------------------------------------------------------
// Measured-throughput calibration (the bench-harness roofline hook)
//
// Everything above prices steps against public H100 peaks. The bench
// harness instead microbenches THIS interpreter's hot kernels (the
// SIMD-dispatched `runtime::gemm` path) and records the sustained rates
// in BENCH_step.json's `measured` block; [`MeasuredKernel::calibrate`]
// rebuilds an [`Hw`] around those rates so the very same `step_time` /
// `decode_step_time` formulas predict *local interpreter* wall-clock
// instead of cluster wall-clock. Strictly opt-in: `Hw::default()` and
// every analytic consumer above are untouched.

/// Sustained kernel rates microbenched by `munit bench step` — the
/// `measured` block of BENCH_step.json.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MeasuredKernel {
    /// Sustained `runtime::gemm::matmul_bt` throughput on the runtime-
    /// dispatched kernel path, GFLOP/s.
    pub gemm_gflops: f64,
    /// Sustained streaming-reduction bandwidth (the `sum_sq` class of
    /// telemetry sweeps), GB/s.
    pub stream_gbps: f64,
}

impl MeasuredKernel {
    /// The GEMM roofline denominator, FLOP/s. This is *textually* the
    /// same expression a calibrated [`Hw`] produces inside `step_time` /
    /// `decode_step_time` (`peak/1e3 × eff × 1e12` with eff folded to
    /// exactly 1.0), so measured rates reach the roofline with zero
    /// floating-point drift — the calibration test pins bit-equality.
    pub fn gemm_flops_per_sec(&self) -> f64 {
        self.gemm_gflops / 1e3 * 1e12
    }

    /// The streaming roofline denominator, bytes/s (same exactness
    /// contract as [`Self::gemm_flops_per_sec`]).
    pub fn stream_bytes_per_sec(&self) -> f64 {
        self.stream_gbps / 1e3 * 1e12
    }

    /// Rebuild `base` so that `peak × efficiency` reproduces the
    /// measured rates exactly: efficiencies fold to 1.0 and the peaks
    /// take the measured numbers. FP8 compute takes the SAME rate as
    /// BF16 — the interpreter emulates FP8 storage around f32
    /// arithmetic, so locally there is no tensor-core 2x (the bandwidth
    /// saving of 1-byte weights is still real and still modeled).
    /// Launch cost and interconnect terms keep `base`'s values.
    pub fn calibrate(&self, base: &Hw) -> Hw {
        Hw {
            bf16_tflops: self.gemm_gflops / 1e3,
            fp8_tflops: self.gemm_gflops / 1e3,
            hbm_tbps: self.stream_gbps / 1e3,
            gemm_eff_bf16: 1.0,
            gemm_eff_fp8: 1.0,
            mem_eff: 1.0,
            ..base.clone()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::paper_table4;

    /// The bench-harness hook's exactness contract: a calibrated `Hw`
    /// feeds `step_time` and `decode_step_time` denominators that are
    /// bit-identical to the closed-form rates derived from the
    /// BENCH_step.json `measured` fields — so every roofline number the
    /// bench emits can be recomputed from the JSON exactly, term by
    /// term, with `==` and no tolerance.
    #[test]
    fn measured_calibration_feeds_rooflines_exactly() {
        let mk = MeasuredKernel { gemm_gflops: 17.3, stream_gbps: 9.81 };
        let hw = mk.calibrate(&Hw::default());
        for p in paper_table4() {
            let m = crate::config::presets::paper_model(&p);
            // training GEMM term: flops / measured rate, bit-exact
            let st = step_time(&hw, &p, Mode::Bf16);
            let s = p.seq_len as f64;
            let tokens_per_gpu = (p.batch as f64 * s) / hw.n_gpus as f64;
            let gemm_flops = 3.0
                * block::hidden_gemm_flops_per_token_fwd(&m) as f64
                * tokens_per_gpu
                * p.depth as f64;
            assert_eq!(st.gemm, gemm_flops / mk.gemm_flops_per_sec(), "{}", p.name);
            // decode terms: compute, weight stream, kv stream
            let dt = decode_step_time(&hw, &p, Mode::Fp8Mus, 512, 4);
            let flops = decode_flops_per_token(&m, 512) as f64 * 4.0;
            assert_eq!(dt.compute, flops / mk.gemm_flops_per_sec(), "{}", p.name);
            assert_eq!(
                dt.weight_read,
                decode_weight_bytes(&m, Mode::Fp8Mus) as f64 / mk.stream_bytes_per_sec(),
                "{}",
                p.name
            );
            assert_eq!(
                dt.kv_read,
                (decode_kv_bytes_per_token(&m, 512) as f64 * 4.0) / mk.stream_bytes_per_sec(),
                "{}",
                p.name
            );
        }
        // strictly opt-in: calibration copies, never mutates, the base
        let base = Hw::default();
        let _ = mk.calibrate(&base);
        assert_eq!(base.gemm_eff_bf16, Hw::default().gemm_eff_bf16);
        assert_eq!(base.bf16_tflops, Hw::default().bf16_tflops);
    }

    #[test]
    fn fig8_shape_matches_paper() {
        let rows = fig8(&Hw::default());
        assert_eq!(rows.len(), 4);
        for r in &rows {
            let vs_bf16 = r.mus_over_bf16();
            let vs_te = r.mus_over_te();
            // paper: 25-33% over BF16 (we accept 1.22-1.36), 1-6% over TE
            assert!(vs_bf16 > 1.22 && vs_bf16 < 1.36, "{}: vs bf16 {vs_bf16}", r.size);
            assert!(vs_te > 1.005 && vs_te < 1.08, "{}: vs te {vs_te}", r.size);
            // ordering: µS > TE > BF16
            assert!(r.mus > r.te && r.te > r.bf16, "{}", r.size);
        }
    }

    #[test]
    fn ratios_robust_to_efficiency_constants() {
        // the claim must not hinge on the exact MFU guesses
        for eff in [0.55, 0.65, 0.75] {
            let hw = Hw { gemm_eff_bf16: eff + 0.05, gemm_eff_fp8: eff - 0.05, ..Hw::default() };
            for r in fig8(&hw) {
                assert!(r.mus_over_bf16() > 1.1, "{} {eff}", r.size);
                assert!(r.mus_over_te() > 1.0, "{} {eff}", r.size);
            }
        }
    }

    #[test]
    fn bf16_mfu_realistic() {
        // sanity: the model's BF16 step lands at a plausible MFU (30-60%)
        let hw = Hw::default();
        for p in paper_table4() {
            let t = step_time(&hw, &p, Mode::Bf16).total();
            let total_flops = 6.0 * p.params_b * 1e9 * (p.batch as f64 * p.seq_len as f64);
            let mfu = total_flops / (t * hw.n_gpus as f64 * hw.bf16_tflops * 1e12);
            assert!(mfu > 0.25 && mfu < 0.72, "{}: mfu {mfu}", p.name);
        }
    }

    #[test]
    fn flops_split_agrees_with_block_op_level_shapes() {
        // The perf model consumes the runtime block's op-level FLOP
        // enumeration directly; these asserts pin that enumeration to the
        // ModelConfig closed-form — exact equality on the hidden GEMMs
        // and on the causal attention score/value count.
        for p in paper_table4() {
            let m = crate::config::presets::paper_model(&p);
            assert_eq!(
                block::hidden_gemm_flops_per_token_fwd(&m),
                m.hidden_flops_per_token_fwd(),
                "{}: hidden GEMM flops",
                p.name
            );
            assert_eq!(
                block::attn_gemm_flops_per_seq_fwd(&m),
                m.attn_flops_per_seq_fwd(),
                "{}: attention GEMM flops",
                p.name
            );
            // the four shapes are exactly the paper's hidden linears
            let shapes = block::hidden_gemm_shapes(&m);
            assert_eq!(shapes.len(), 4);
            let names: Vec<&str> = shapes.iter().map(|s| s.0).collect();
            assert_eq!(names, ["qkv", "attn_out", "ffn_up", "ffn_down"]);
        }
    }

    /// The decode satellite's exact-match pin, mirroring the training
    /// FLOPs test: every decode cost term equals the `ModelConfig`
    /// closed form — the perf model consumes the runtime's op-level
    /// GEMM/attention shapes and the kvcache byte layout verbatim.
    #[test]
    fn decode_cost_matches_op_level_enumeration_exactly() {
        let mut models: Vec<ModelConfig> =
            paper_table4().iter().map(|p| crate::config::presets::paper_model(p)).collect();
        models.push(ModelConfig::default());
        for m in &models {
            let l = m.depth as u64;
            for ctx in [1usize, 128, 4096] {
                assert_eq!(
                    decode_flops_per_token(m, ctx),
                    m.hidden_flops_per_token_fwd() * l
                        + m.attn_decode_flops_per_token(ctx) * l
                        + 2 * (m.width * m.vocab) as u64,
                    "decode FLOPs, ctx {ctx}"
                );
                assert_eq!(
                    decode_kv_bytes_per_token(m, ctx),
                    m.kv_cache_bytes_read_per_token(ctx),
                    "KV bytes, ctx {ctx}"
                );
            }
            // weight streaming: FP8 modes carry the hidden linears at one
            // byte, BF16 at two; everything else is BF16 in every mode
            let per_block = m.width * 3 * m.width + m.width * m.width + 2 * m.width * m.ffn_width();
            let hidden = (m.depth * per_block) as u64;
            let other = m.n_params() as u64 - hidden;
            assert_eq!(decode_weight_bytes(m, Mode::Fp8Mus), hidden + 2 * other);
            assert_eq!(decode_weight_bytes(m, Mode::Fp8Te), hidden + 2 * other);
            assert_eq!(decode_weight_bytes(m, Mode::Bf16), 2 * hidden + 2 * other);
        }
    }

    /// The prefill closed form is consistent three ways: at zero cache
    /// it is exactly the training tower's per-sequence count; it
    /// telescopes under chunking (n then q rows == n+q rows); and its
    /// KV-read companion scales linearly in bytes-per-value.
    #[test]
    fn prefill_flops_reduce_to_tower_and_telescope() {
        let mut models: Vec<ModelConfig> =
            paper_table4().iter().map(|p| crate::config::presets::paper_model(p)).collect();
        models.push(ModelConfig::default());
        for m in &models {
            let (s, l) = (m.seq_len as u64, m.depth as u64);
            assert_eq!(
                prefill_flops(m, m.seq_len, 0),
                m.hidden_flops_per_token_fwd() * s * l
                    + m.attn_flops_per_seq_fwd() * l
                    + 2 * (m.width * m.vocab) as u64 * s,
                "{}: tower reduction",
                m.name()
            );
            // chunk split point must not change the total
            assert_eq!(
                prefill_flops(m, 3, 5) + prefill_flops(m, 4, 8),
                prefill_flops(m, 7, 5),
                "{}: chunk telescope",
                m.name()
            );
            assert_eq!(
                prefill_kv_bytes_read(m, 3, 5, 2) + prefill_kv_bytes_read(m, 4, 8, 2),
                prefill_kv_bytes_read(m, 7, 5, 2)
            );
            // FP8 KV halves both streaming closed forms exactly
            assert_eq!(prefill_kv_bytes_read(m, 7, 5, 1) * 2, prefill_kv_bytes_read(m, 7, 5, 2));
            assert_eq!(
                decode_kv_bytes_per_token_at(m, 128, 1) * 2,
                decode_kv_bytes_per_token_at(m, 128, 2)
            );
        }
    }

    /// Decode is bandwidth-bound at serving batch sizes — the roofline's
    /// memory term dominates compute by orders of magnitude.
    #[test]
    fn decode_is_bandwidth_bound() {
        let hw = Hw::default();
        for p in paper_table4() {
            for mode in [Mode::Bf16, Mode::Fp8Mus] {
                let t = decode_step_time(&hw, &p, mode, 2048, 1);
                assert!(
                    t.weight_read + t.kv_read > 10.0 * t.compute,
                    "{} {:?}: mem {} vs compute {}",
                    p.name,
                    mode,
                    t.weight_read + t.kv_read,
                    t.compute
                );
            }
        }
    }

    #[test]
    fn decode_throughput_scales_with_batch_and_context() {
        let hw = Hw::default();
        let p = &paper_table4()[0]; // 1b
        // batching amortizes the weight stream → more tokens/sec
        let b1 = decode_tokens_per_sec(&hw, p, Mode::Fp8Mus, 1024, 1);
        let b8 = decode_tokens_per_sec(&hw, p, Mode::Fp8Mus, 1024, 8);
        assert!(b8 > 2.0 * b1, "batch 8 {b8} vs batch 1 {b1}");
        // longer context reads more KV → fewer tokens/sec
        let short = decode_tokens_per_sec(&hw, p, Mode::Fp8Mus, 256, 8);
        let long = decode_tokens_per_sec(&hw, p, Mode::Fp8Mus, 4096, 8);
        assert!(short > long, "ctx 256 {short} vs ctx 4096 {long}");
        // FP8 weights halve the stream → µS beats BF16; static scaling
        // skips TE's per-tensor bookkeeping → µS beats TE. (TE vs BF16 is
        // deliberately NOT pinned: at serving batch sizes the dynamic
        // bookkeeping launches can cost more than the halved weight
        // stream saves — the serving-side overhead µS deletes.)
        let mus = decode_tokens_per_sec(&hw, p, Mode::Fp8Mus, 1024, 8);
        let te = decode_tokens_per_sec(&hw, p, Mode::Fp8Te, 1024, 8);
        let bf16 = decode_tokens_per_sec(&hw, p, Mode::Bf16, 1024, 8);
        assert!(mus > te, "mus {mus} vs te {te}");
        assert!(mus > bf16, "mus {mus} vs bf16 {bf16}");
    }

    #[test]
    fn te_overhead_is_amax_plus_launches() {
        let hw = Hw::default();
        let p = &paper_table4()[2]; // 7b
        let te = step_time(&hw, p, Mode::Fp8Te);
        let mus = step_time(&hw, p, Mode::Fp8Mus);
        assert_eq!(te.gemm, mus.gemm);
        assert_eq!(te.cast, mus.cast);
        assert!(te.amax > 0.0 && mus.amax == 0.0);
        assert!(te.total() > mus.total());
    }

    #[test]
    fn memory_fits_h100_at_paper_scale() {
        for p in paper_table4() {
            let gb = memory_per_gpu(&p, 64) / 1e9;
            assert!(gb < 80.0, "{}: {gb} GB", p.name);
            assert!(gb > 1.0, "{}: {gb} GB", p.name);
        }
    }

    /// The comm model's `P_s` term is pinned to the runtime block's
    /// actual tensor enumeration: summing `elements()` over exactly the
    /// specs `block::shard_axis` marks sharded must equal the closed
    /// form — same pattern as the FLOPs pins above.
    #[test]
    fn sharded_elems_match_block_enumeration_exactly() {
        let mut models: Vec<ModelConfig> =
            paper_table4().iter().map(|p| crate::config::presets::paper_model(p)).collect();
        models.push(ModelConfig::default());
        models.push(crate::runtime::micro_config());
        for m in &models {
            let enumerated: u64 = block::param_specs(m)
                .iter()
                .enumerate()
                .filter(|(idx, _)| block::shard_axis(block::role_of(m, *idx)).is_some())
                .map(|(_, s)| s.elements() as u64)
                .sum();
            assert_eq!(enumerated, tp_sharded_param_elems(m), "{}", m.name());
        }
    }

    #[test]
    fn shard_comm_is_zero_without_sharding_and_scales_with_tp() {
        let m = ModelConfig::default();
        assert_eq!(shard_comm_bytes_per_step(&m, 1, 1, 4), 0);
        assert_eq!(pipeline_activation_bytes_per_step(&m, 1), 0);
        assert_eq!(shard_allgather_bytes_per_step(&m, 1, 1), 0);
        // tp=2 master wire: (2-1) · 2 · P_s · 4 per leg
        let ps = tp_sharded_param_elems(&m);
        assert_eq!(shard_allgather_bytes_per_step(&m, 2, 4), 2 * ps * 4);
        assert_eq!(
            shard_reduce_scatter_bytes_per_step(&m, 2, 4),
            shard_allgather_bytes_per_step(&m, 2, 4)
        );
        // FP8 wire is exactly 4x cheaper than the f32 master wire
        assert_eq!(
            shard_allgather_bytes_per_step(&m, 4, 4),
            4 * shard_allgather_bytes_per_step(&m, 4, 1)
        );
        // activations: interior boundaries only, microbatch-independent
        let a2 = pipeline_activation_bytes_per_step(&m, 2);
        assert_eq!(a2, 2 * (m.batch * m.seq_len * m.width * 4) as u64);
        assert_eq!(pipeline_activation_bytes_per_step(&m, 4), 3 * a2);
    }

    /// The state-precision byte model's exactness contract, part 1: the
    /// `state_bytes` closed form equals the live session gauges with
    /// `==` — 8 B/param under f32 state, 3 B/param under FP8 state.
    #[test]
    fn state_byte_form_matches_live_session_gauges_exactly() {
        let cfg = crate::runtime::micro_config();
        let be = crate::runtime::ReferenceBackend::new(std::slice::from_ref(&cfg)).unwrap();
        for (sp, bpp) in [(StatePrecision::F32, 8.0), (StatePrecision::Fp8, 3.0)] {
            let mut s = crate::runtime::Session::with_precision(&be, &cfg, sp).unwrap();
            s.init(3).unwrap();
            assert_eq!(s.stats().state_bytes, state_bytes(&cfg, sp), "{}", sp.label());
            assert_eq!(s.stats().state_bytes_per_param, bpp, "{}", sp.label());
        }
        // and the closed form itself: total elems x policy constant
        let p = total_param_elems(&cfg);
        assert_eq!(state_bytes(&cfg, StatePrecision::F32), 8 * p);
        assert_eq!(state_bytes(&cfg, StatePrecision::Fp8), 3 * p);
    }

    /// Part 2: the checkpoint byte forms equal real file sizes from
    /// `std::fs::metadata`, and v2-fp8 is less than half of v1.
    #[test]
    fn checkpoint_byte_forms_match_real_files_exactly() {
        use crate::coordinator::checkpoint;
        let cfg = crate::runtime::micro_config();
        let be = crate::runtime::ReferenceBackend::new(std::slice::from_ref(&cfg)).unwrap();
        let mut s =
            crate::runtime::Session::with_precision(&be, &cfg, StatePrecision::Fp8).unwrap();
        s.init(5).unwrap();
        let state = s.read_back().unwrap();
        use crate::runtime::Backend;
        let meta = be.resolve("train_step", &cfg).unwrap();
        let specs = meta.inputs[..state.tensors.len()].to_vec();
        let dir = std::env::temp_dir();
        let p1 = dir.join("munit_perfmodel_ckpt_v1.bin");
        let p2 = dir.join("munit_perfmodel_ckpt_v2.bin");
        checkpoint::save(&p1, &state, &specs).unwrap();
        checkpoint::save_v2(&p2, &state, &specs, StatePrecision::Fp8).unwrap();
        let (s1, s2) =
            (std::fs::metadata(&p1).unwrap().len(), std::fs::metadata(&p2).unwrap().len());
        assert_eq!(s1, checkpoint_v1_bytes(&cfg));
        assert_eq!(s2, checkpoint_v2_bytes(&cfg, StatePrecision::Fp8));
        assert!(2 * s2 < s1, "v2 fp8 ({s2} B) not under half of v1 ({s1} B)");
        std::fs::remove_file(&p1).ok();
        std::fs::remove_file(&p2).ok();
    }

    /// Part 3: the momentum wire form equals the `Collectives` byte
    /// counters over the exact collective sequence `train_sharded`
    /// issues for the momentum half (allgather + reduce-scatter of
    /// every rank's sharded momenta), for all wire x state lanes.
    #[test]
    fn momentum_wire_form_matches_collective_counters_exactly() {
        use crate::coordinator::collective::{Collectives, Payload};
        use crate::coordinator::shard::{partition_state, ShardSpec};
        let cfg = crate::runtime::micro_config();
        let be = crate::runtime::ReferenceBackend::new(std::slice::from_ref(&cfg)).unwrap();
        let mut s = crate::runtime::Session::new(&be, &cfg).unwrap();
        s.init(9).unwrap();
        let state = s.read_back().unwrap();
        let tp = 2usize;
        let spec = ShardSpec::new(tp, 1);
        let n = state.n_params;
        let lanes = [
            (WireFormat::Master, StatePrecision::F32),
            (WireFormat::Fp8, StatePrecision::F32),
            (WireFormat::Fp8, StatePrecision::Fp8),
        ];
        for (wire, sp) in lanes {
            let shards = partition_state(&cfg, &state, &spec).unwrap();
            let mut coll = Collectives::with_state(wire, sp);
            for (rank, st) in shards.iter().enumerate() {
                for idx in n..2 * n {
                    let t = &st.tensors[idx];
                    if t.shape() == state.tensors[idx].shape() {
                        continue; // replicated, never on the wire
                    }
                    let mut v = t.as_f32().unwrap().to_vec();
                    coll.allgather_shard(&mut v, Payload::Momentum, tp, rank);
                    coll.reduce_scatter_shard(&mut v, Payload::Momentum, tp, rank);
                }
            }
            let modeled = momentum_wire_bytes_per_step(&cfg, tp, wire, sp);
            assert_eq!(coll.total_bytes(), modeled, "{} wire / {} state", wire.label(), sp.label());
            assert_eq!(coll.amax_syncs, 0);
        }
        // the native-momentum lane costs only the scale metadata over the
        // plain FP8 wire, and both are exactly 4x under the master wire
        let f32_lane = momentum_wire_bytes_per_step(&cfg, tp, WireFormat::Fp8, StatePrecision::F32);
        let fp8_lane = momentum_wire_bytes_per_step(&cfg, tp, WireFormat::Fp8, StatePrecision::Fp8);
        let master =
            momentum_wire_bytes_per_step(&cfg, tp, WireFormat::Master, StatePrecision::F32);
        assert_eq!(master, 4 * f32_lane);
        let scale_overhead = 2 * (tp as u64 - 1) * 4 * (tp as u64 * 4 * cfg.depth as u64);
        assert_eq!(fp8_lane - f32_lane, scale_overhead);
        assert_eq!(momentum_wire_bytes_per_step(&cfg, 1, WireFormat::Fp8, StatePrecision::Fp8), 0);
    }

    #[test]
    fn throughput_scales_down_with_model_size() {
        let hw = Hw::default();
        let rows = fig8(&hw);
        for w in rows.windows(2) {
            assert!(w[0].mus > w[1].mus, "{} vs {}", w[0].size, w[1].size);
        }
    }
}
