//! Tiny benchmark harness (criterion is unavailable offline).
//!
//! Used by `cargo bench` targets declared with `harness = false`: warmup,
//! timed iterations, robust stats, aligned report lines.

use std::time::{Duration, Instant};

/// Timing statistics of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// Benchmark name (the report's row label).
    pub name: String,
    /// Measured iterations.
    pub iters: usize,
    /// Mean per-iteration time.
    pub mean: Duration,
    /// Fastest iteration.
    pub min: Duration,
    /// Median iteration.
    pub p50: Duration,
    /// 95th-percentile iteration.
    pub p95: Duration,
}

impl BenchResult {
    /// One aligned report line (pair with [`header`]).
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>10} {:>12} {:>12} {:>12}  ({} iters)",
            self.name,
            fmt_dur(self.min),
            fmt_dur(self.p50),
            fmt_dur(self.mean),
            fmt_dur(self.p95),
            self.iters
        )
    }
}

/// Column-header line matching [`BenchResult::report`].
pub fn header() -> String {
    format!(
        "{:<44} {:>10} {:>12} {:>12} {:>12}",
        "benchmark", "min", "p50", "mean", "p95"
    )
}

fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns}ns")
    } else if ns < 1_000_000 {
        format!("{:.2}µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2}ms", ns as f64 / 1e6)
    } else {
        format!("{:.2}s", ns as f64 / 1e9)
    }
}

/// Run `f` repeatedly: `warmup` unmeasured calls, then measured calls until
/// `budget` elapses (at least `min_iters`). Returns timing stats.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, min_iters: usize, budget: Duration, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples: Vec<Duration> = Vec::new();
    let start = Instant::now();
    while samples.len() < min_iters || (start.elapsed() < budget && samples.len() < 10_000) {
        let t = Instant::now();
        f();
        samples.push(t.elapsed());
    }
    samples.sort();
    let total: Duration = samples.iter().sum();
    BenchResult {
        name: name.to_string(),
        iters: samples.len(),
        mean: total / samples.len() as u32,
        min: samples[0],
        p50: samples[samples.len() / 2],
        p95: samples[(samples.len() as f64 * 0.95) as usize - if samples.len() > 1 { 1 } else { 0 }],
    }
}

/// Convenience wrapper with sane defaults for sub-ms benches.
pub fn quick<F: FnMut()>(name: &str, f: F) -> BenchResult {
    bench(name, 3, 10, Duration::from_millis(500), f)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn collects_samples_and_orders_percentiles() {
        let r = bench("t", 1, 5, Duration::from_millis(5), || {
            std::hint::black_box((0..100).sum::<u64>());
        });
        assert!(r.iters >= 5);
        assert!(r.min <= r.p50 && r.p50 <= r.p95);
    }

    #[test]
    fn fmt_is_human() {
        assert!(fmt_dur(Duration::from_nanos(12)).ends_with("ns"));
        assert!(fmt_dur(Duration::from_micros(12)).ends_with("µs"));
        assert!(fmt_dur(Duration::from_millis(12)).ends_with("ms"));
    }
}
