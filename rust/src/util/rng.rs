//! Deterministic PRNG substrate (no `rand` crate offline): SplitMix64 core
//! with normal / uniform / Zipf samplers. Determinism matters: the data
//! pipeline's shard contents are a function of (seed, shard id) only, so
//! sweep runs and distributed workers are exactly reproducible.

/// SplitMix64: tiny, fast, passes BigCrush for our purposes; splittable by
/// construction (`fork`), which gives per-shard independence.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// Stream seeded by `seed` (identical seeds ⇒ identical streams).
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9E3779B97F4A7C15) }
    }

    /// Independent stream derived from this one (stable w.r.t. call order).
    pub fn fork(&self, stream: u64) -> Rng {
        let mut r = Rng::new(self.state ^ stream.wrapping_mul(0xA24BAED4963EE407));
        r.next_u64();
        r
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Standard normal, f32.
    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Fill `out` with iid N(0, std²) samples.
    pub fn fill_normal(&mut self, out: &mut [f32], std: f32) {
        for v in out.iter_mut() {
            *v = self.normal_f32() * std;
        }
    }
}

/// Zipf(s) sampler over {0..n-1} via precomputed CDF + binary search.
/// Token frequencies in natural text are approximately Zipfian — this is
/// what creates the repeated-token correlation the paper's Fig 3 shows.
#[derive(Debug, Clone)]
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    /// Zipf(s) distribution over ranks `{0..n-1}`.
    pub fn new(n: usize, s: f64) -> Self {
        assert!(n > 0);
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(s);
            cdf.push(acc);
        }
        let total = acc;
        for v in cdf.iter_mut() {
            *v /= total;
        }
        Zipf { cdf }
    }

    /// Draw one rank.
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.f64();
        self.cdf.partition_point(|&c| c < u)
    }

    /// Support size.
    pub fn n(&self) -> usize {
        self.cdf.len()
    }

    /// Probability of rank k (0-based).
    pub fn pmf(&self, k: usize) -> f64 {
        if k == 0 {
            self.cdf[0]
        } else {
            self.cdf[k] - self.cdf[k - 1]
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn forks_are_independent_of_parent_consumption() {
        let r = Rng::new(3);
        let f1 = r.fork(1);
        let mut r2 = Rng::new(3);
        r2.next_u64();
        let f2 = r2.fork(1);
        // fork depends only on current state; cloned path matches
        assert_ne!(f1.clone().next_u64_owned(), f2.clone().next_u64_owned());
    }

    #[test]
    fn uniform_mean() {
        let mut r = Rng::new(1);
        let m: f64 = (0..10_000).map(|_| r.f64()).sum::<f64>() / 10_000.0;
        assert!((m - 0.5).abs() < 0.02, "{m}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(2);
        let xs: Vec<f64> = (0..20_000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
        assert!(mean.abs() < 0.03, "{mean}");
        assert!((var - 1.0).abs() < 0.05, "{var}");
    }

    #[test]
    fn zipf_head_heavier_than_tail() {
        let z = Zipf::new(100, 1.1);
        let mut r = Rng::new(4);
        let mut counts = vec![0usize; 100];
        for _ in 0..20_000 {
            counts[z.sample(&mut r)] += 1;
        }
        assert!(counts[0] > counts[50] * 5);
        assert!(counts[0] > counts[99]);
        // pmf sums to ~1
        let total: f64 = (0..100).map(|k| z.pmf(k)).sum();
        assert!((total - 1.0).abs() < 1e-9);
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(5);
        for _ in 0..1000 {
            assert!(r.below(17) < 17);
        }
    }

    impl Rng {
        fn next_u64_owned(mut self) -> u64 {
            self.next_u64()
        }
    }
}
