//! Small numeric helpers shared by analysis / eval / benches.

/// Mean of a slice (0 for empty).
pub fn mean(xs: &[f32]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().map(|&x| x as f64).sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn std(xs: &[f32]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|&x| (x as f64 - m).powi(2)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Minimum (`inf` for empty input).
pub fn min(xs: &[f32]) -> f32 {
    xs.iter().copied().fold(f32::INFINITY, f32::min)
}

/// Maximum (`-inf` for empty input).
pub fn max(xs: &[f32]) -> f32 {
    xs.iter().copied().fold(f32::NEG_INFINITY, f32::max)
}

/// In-place numerically-stable softmax.
pub fn softmax_inplace(xs: &mut [f32]) {
    let m = max(xs);
    let mut sum = 0.0f64;
    for x in xs.iter_mut() {
        *x = (*x - m).exp();
        sum += *x as f64;
    }
    for x in xs.iter_mut() {
        *x = (*x as f64 / sum) as f32;
    }
}

/// Percentile (nearest-rank) of an unsorted slice.
pub fn percentile(xs: &[f32], p: f64) -> f32 {
    assert!(!xs.is_empty());
    let mut v: Vec<f32> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = ((p / 100.0) * (v.len() - 1) as f64).round() as usize;
    v[idx.min(v.len() - 1)]
}

/// Cosine similarity of two equal-length vectors.
pub fn cosine(a: &[f32], b: &[f32]) -> f64 {
    let dot: f64 = a.iter().zip(b).map(|(&x, &y)| x as f64 * y as f64).sum();
    let na: f64 = a.iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt();
    let nb: f64 = b.iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt();
    if na == 0.0 || nb == 0.0 {
        0.0
    } else {
        dot / (na * nb)
    }
}

/// Exponential moving average tracker.
#[derive(Debug, Clone)]
pub struct Ema {
    alpha: f64,
    value: Option<f64>,
}

impl Ema {
    /// Tracker with smoothing factor `alpha` (1 = no smoothing).
    pub fn new(alpha: f64) -> Self {
        Ema { alpha, value: None }
    }
    /// Fold in an observation; returns the updated average.
    pub fn update(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(v) => v + self.alpha * (x - v),
        };
        self.value = Some(v);
        v
    }
    /// Current average (`None` before the first update).
    pub fn get(&self) -> Option<f64> {
        self.value
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std_basic() {
        let xs = [1.0f32, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-9);
        assert!((std(&xs) - 1.118_033_988).abs() < 1e-6);
    }

    #[test]
    fn softmax_sums_to_one_and_is_stable() {
        let mut xs = [1000.0f32, 1001.0, 999.0];
        softmax_inplace(&mut xs);
        let s: f32 = xs.iter().sum();
        assert!((s - 1.0).abs() < 1e-5);
        assert!(xs[1] > xs[0] && xs[0] > xs[2]);
    }

    #[test]
    fn percentile_nearest_rank() {
        let xs = [5.0f32, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
    }

    #[test]
    fn cosine_bounds() {
        let a = [1.0f32, 0.0];
        assert!((cosine(&a, &[2.0, 0.0]) - 1.0).abs() < 1e-9);
        assert!((cosine(&a, &[0.0, 1.0])).abs() < 1e-9);
        assert!((cosine(&a, &[-1.0, 0.0]) + 1.0).abs() < 1e-9);
    }

    #[test]
    fn ema_converges() {
        let mut e = Ema::new(0.5);
        for _ in 0..20 {
            e.update(10.0);
        }
        assert!((e.get().unwrap() - 10.0).abs() < 1e-3);
    }
}
