//! Shared substrates: JSON, deterministic RNG, numeric helpers, tables,
//! bench harness, property-testing helper. Everything here is hand-rolled
//! because the build is fully offline (see DESIGN.md).

/// Tiny benchmark harness (criterion replacement).
pub mod bench;
/// Error substrate (anyhow replacement): context chains + macros.
pub mod error;
/// Minimal JSON parser/serializer (serde_json replacement).
pub mod json;
/// Deterministic scoped-thread parallelism (bit-identical at any count).
pub mod parallel;
/// Seeded property-testing helper (proptest replacement).
pub mod proptest;
/// Deterministic SplitMix64 RNG + Zipf sampler (rand replacement).
pub mod rng;
/// Small numeric helpers: mean/std/softmax/percentile/cosine/EMA.
pub mod stats;
/// Aligned plain-text table rendering.
pub mod table;
