//! Shared substrates: JSON, deterministic RNG, numeric helpers, tables,
//! bench harness, property-testing helper. Everything here is hand-rolled
//! because the build is fully offline (see DESIGN.md).

pub mod bench;
pub mod error;
pub mod json;
pub mod parallel;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod table;
