//! Property-testing helper (the `proptest` crate is unavailable offline).
//!
//! `check` runs a property over `n` seeded random cases; on failure it
//! reports the failing case index and seed so the case can be replayed
//! deterministically. No shrinking — cases are kept small instead.

use super::rng::Rng;

/// Run `prop(rng, case)` for `n` cases. Panics with a replayable seed on the
/// first failure (a returned Err(msg)).
pub fn check<F>(name: &str, n: usize, mut prop: F)
where
    F: FnMut(&mut Rng, usize) -> Result<(), String>,
{
    let base_seed = 0xC0FFEE_u64;
    for case in 0..n {
        let seed = base_seed.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng, case) {
            panic!("property '{name}' failed at case {case} (seed {seed:#x}): {msg}");
        }
    }
}

/// Assert helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check("u64 parity", 50, |rng, _| {
            let x = rng.next_u64();
            if x % 2 == (x & 1) {
                Ok(())
            } else {
                Err("math broke".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn reports_failure() {
        check("always fails", 3, |_, _| Err("nope".into()));
    }
}
