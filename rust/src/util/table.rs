//! Plain-text table rendering for figure/table reproductions and benches.

/// Render rows as an aligned ASCII table with a header.
pub fn render(header: &[&str], rows: &[Vec<String>]) -> String {
    let ncol = header.len();
    let mut widths: Vec<usize> = header.iter().map(|h| h.chars().count()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate().take(ncol) {
            widths[i] = widths[i].max(cell.chars().count());
        }
    }
    let mut out = String::new();
    let fmt_row = |cells: &[String], widths: &[usize]| -> String {
        let mut line = String::from("|");
        for (i, w) in widths.iter().enumerate() {
            let empty = String::new();
            let c = cells.get(i).unwrap_or(&empty);
            let pad = w - c.chars().count();
            line.push(' ');
            line.push_str(c);
            line.push_str(&" ".repeat(pad + 1));
            line.push('|');
        }
        line
    };
    let hdr: Vec<String> = header.iter().map(|s| s.to_string()).collect();
    out.push_str(&fmt_row(&hdr, &widths));
    out.push('\n');
    let mut sep = String::from("|");
    for w in &widths {
        sep.push_str(&"-".repeat(w + 2));
        sep.push('|');
    }
    out.push_str(&sep);
    out.push('\n');
    for row in rows {
        out.push_str(&fmt_row(row, &widths));
        out.push('\n');
    }
    out
}

/// f64 with fixed decimals, for table cells.
pub fn f(v: f64, decimals: usize) -> String {
    format!("{v:.decimals$}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aligns_columns() {
        let t = render(
            &["name", "val"],
            &[
                vec!["a".into(), "1.00".into()],
                vec!["longer".into(), "2".into()],
            ],
        );
        let lines: Vec<&str> = t.lines().collect();
        assert_eq!(lines.len(), 4);
        let w = lines[0].len();
        assert!(lines.iter().all(|l| l.len() == w), "{t}");
        assert!(t.contains("longer"));
    }

    #[test]
    fn fmt_helper() {
        assert_eq!(f(1.23456, 2), "1.23");
    }
}
