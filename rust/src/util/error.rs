//! Error substrate (the `anyhow` crate is unavailable offline).
//!
//! Mirrors the subset of the anyhow API this codebase uses: an opaque
//! [`Error`] carrying a context chain, a [`Result`] alias, a [`Context`]
//! extension trait for `Result`/`Option`, and the [`bail!`]/[`err!`]
//! macros. `{e}` displays the outermost context; `{e:#}` displays the full
//! chain separated by `": "` (matching anyhow's alternate formatting).

use std::fmt;

/// Opaque error: a chain of context strings, outermost first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Error from a plain message (the root of a context chain).
    pub fn msg(m: impl Into<String>) -> Error {
        Error { chain: vec![m.into()] }
    }

    /// Wrap with an outer context frame.
    pub fn context(mut self, m: impl Into<String>) -> Error {
        self.chain.insert(0, m.into());
        self
    }

    /// The context chain, outermost first.
    pub fn frames(&self) -> &[String] {
        &self.chain
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or("unknown error"))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or("unknown error"))?;
        for c in self.chain.iter().skip(1) {
            write!(f, "\n  caused by: {c}")?;
        }
        Ok(())
    }
}

// Deliberately NOT `impl std::error::Error for Error` — that is what makes
// the blanket conversion below coherent (same trick anyhow uses).
impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// Crate-wide result alias over [`Error`] (mirrors `anyhow::Result`).
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `.context("...")` / `.with_context(|| ...)` on `Result` and `Option`.
pub trait Context<T> {
    /// Wrap an error (or `None`) with a fixed context message.
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    /// Wrap with a lazily-built context message (only on the error path).
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| e.into().context(c.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f().to_string()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f().to_string()))
    }
}

/// Early-return with a formatted error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::util::error::Error::msg(format!($($arg)*)))
    };
}

/// Construct an [`Error`] from a format string (anyhow's `anyhow!`).
#[macro_export]
macro_rules! err {
    ($($arg:tt)*) => {
        $crate::util::error::Error::msg(format!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read_to_string("/definitely/not/a/file")?;
        Ok(())
    }

    #[test]
    fn context_chain_and_alternate_display() {
        let e = io_fail().context("loading config").unwrap_err();
        assert!(e.to_string().contains("loading config"));
        let full = format!("{e:#}");
        assert!(full.starts_with("loading config: "), "{full}");
        assert!(e.frames().len() >= 2);
    }

    #[test]
    fn option_context() {
        let x: Option<u32> = None;
        let e = x.with_context(|| format!("missing {}", "thing")).unwrap_err();
        assert_eq!(e.to_string(), "missing thing");
        assert_eq!(Some(3u32).context("fine").unwrap(), 3);
    }

    #[test]
    fn bail_and_err_macros() {
        fn f(fail: bool) -> Result<u32> {
            if fail {
                bail!("bad value {}", 7);
            }
            Ok(1)
        }
        assert_eq!(f(true).unwrap_err().to_string(), "bad value 7");
        assert_eq!(f(false).unwrap(), 1);
        let e: Error = err!("x = {}", 2);
        assert_eq!(e.to_string(), "x = 2");
    }
}
