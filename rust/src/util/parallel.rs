//! Deterministic scoped-thread parallelism for the reference interpreter.
//!
//! The determinism contract: **results are bit-identical for any thread
//! count**, including 1. Every primitive here partitions work into chunks
//! whose boundaries depend only on `(n, chunk_len)` — never on the thread
//! count — and either
//!
//!  - writes disjoint output chunks ([`par_chunks_mut`], [`par_join2`]):
//!    each output element is produced by exactly one chunk, with the same
//!    arithmetic regardless of which thread runs it; or
//!  - reduces per-chunk partials **in ascending chunk order**
//!    ([`par_map_reduce`]): the fold sequence is fixed even though chunk
//!    computation is concurrent.
//!
//! Threads are scoped (`std::thread::scope`), spawned per call, and chunks
//! are striped over workers — no pool, no atomics, no unsafe. Callers gate
//! spawning by work size via [`threads_for`], so tiny problems (the micro
//! test config) stay single-threaded and pay zero spawn overhead, with
//! identical results either way. This is what preserves the PR-1 sweep
//! `threaded == sequential` guarantee while the interpreter itself is
//! internally parallel.

use std::cell::Cell;
use std::ops::Range;

thread_local! {
    /// Test/benchmark hook: per-thread cap on worker threads.
    static FORCED_THREADS: Cell<Option<usize>> = const { Cell::new(None) };
}

/// Worker-thread budget for the calling thread: the forced override if one
/// is active (see [`with_max_threads`]), else the machine's available
/// parallelism.
pub fn max_threads() -> usize {
    if let Some(n) = FORCED_THREADS.with(|f| f.get()) {
        return n.max(1);
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Run `f` with the calling thread's worker budget capped at `n`.
/// Thread-local, so concurrent tests (or sweep workers) don't race; used
/// by the determinism tests to compare 1-thread vs N-thread execution.
pub fn with_max_threads<R>(n: usize, f: impl FnOnce() -> R) -> R {
    let prev = FORCED_THREADS.with(|c| c.replace(Some(n.max(1))));
    let out = f();
    FORCED_THREADS.with(|c| c.set(prev));
    out
}

/// Scalar-op threshold below which spawning threads costs more than it
/// saves (measured in "fused multiply-add"-sized operations).
const PAR_MIN_OPS: u64 = 1 << 19;

/// Thread budget for a job of roughly `ops` scalar operations: 1 (inline)
/// below [`PAR_MIN_OPS`], else [`max_threads`].
pub fn threads_for(ops: u64) -> usize {
    if ops < PAR_MIN_OPS {
        1
    } else {
        max_threads()
    }
}

fn chunk_range(i: usize, chunk_len: usize, n: usize) -> Range<usize> {
    i * chunk_len..((i + 1) * chunk_len).min(n)
}

/// Process `data` in fixed `chunk_len` chunks, possibly in parallel.
/// `f(chunk_index, chunk)` — chunk `i` covers elements
/// `i*chunk_len .. (i+1)*chunk_len`. Chunks are disjoint `&mut` slices, so
/// no reduction is needed and results cannot depend on scheduling.
pub fn par_chunks_mut<T, F>(data: &mut [T], chunk_len: usize, threads: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    assert!(chunk_len > 0, "chunk_len must be positive");
    if data.is_empty() {
        return;
    }
    let n_chunks = data.len().div_ceil(chunk_len);
    let threads = threads.clamp(1, n_chunks);
    if threads <= 1 {
        for (i, c) in data.chunks_mut(chunk_len).enumerate() {
            f(i, c);
        }
        return;
    }
    // Stripe chunks over workers; assignment affects only *who* computes a
    // chunk, never what it computes.
    let mut buckets: Vec<Vec<(usize, &mut [T])>> = (0..threads).map(|_| Vec::new()).collect();
    for (i, c) in data.chunks_mut(chunk_len).enumerate() {
        buckets[i % threads].push((i, c));
    }
    let f = &f;
    std::thread::scope(|scope| {
        let mut buckets = buckets.into_iter();
        let mine = buckets.next().expect("threads >= 1");
        for bucket in buckets {
            scope.spawn(move || {
                for (i, c) in bucket {
                    f(i, c);
                }
            });
        }
        for (i, c) in mine {
            f(i, c);
        }
    });
}

/// Like [`par_chunks_mut`] over two parallel buffers: chunk `i` of `a`
/// (length `a_chunk`) is processed together with chunk `i` of `b` (length
/// `b_chunk`). Use when one row-parallel pass must write two outputs
/// (e.g. d-logits and the per-row loss), or when a pass pairs an output
/// chunk with the *input* panel that produces it — the fused
/// pack+GEMM entry point (`runtime::gemm::matmul_bt_quant`) pairs each
/// C row-chunk with its A row-panel so the quantization sweep and the
/// matmul share one traversal. Chunk boundaries stay a function of the
/// buffer lengths alone, so the pairing inherits the bit-determinism
/// contract unchanged.
pub fn par_join2<A, B, F>(
    a: &mut [A],
    b: &mut [B],
    a_chunk: usize,
    b_chunk: usize,
    threads: usize,
    f: F,
) where
    A: Send,
    B: Send,
    F: Fn(usize, &mut [A], &mut [B]) + Sync,
{
    assert!(a_chunk > 0 && b_chunk > 0, "chunk lengths must be positive");
    assert_eq!(
        a.len().div_ceil(a_chunk),
        b.len().div_ceil(b_chunk),
        "par_join2: buffers disagree on chunk count"
    );
    if a.is_empty() {
        return;
    }
    let n_chunks = a.len().div_ceil(a_chunk);
    let threads = threads.clamp(1, n_chunks);
    let pairs = a.chunks_mut(a_chunk).zip(b.chunks_mut(b_chunk)).enumerate();
    if threads <= 1 {
        for (i, (ca, cb)) in pairs {
            f(i, ca, cb);
        }
        return;
    }
    let mut buckets: Vec<Vec<(usize, &mut [A], &mut [B])>> =
        (0..threads).map(|_| Vec::new()).collect();
    for (i, (ca, cb)) in pairs {
        buckets[i % threads].push((i, ca, cb));
    }
    let f = &f;
    std::thread::scope(|scope| {
        let mut buckets = buckets.into_iter();
        let mine = buckets.next().expect("threads >= 1");
        for bucket in buckets {
            scope.spawn(move || {
                for (i, ca, cb) in bucket {
                    f(i, ca, cb);
                }
            });
        }
        for (i, ca, cb) in mine {
            f(i, ca, cb);
        }
    });
}

/// Map fixed chunks of `0..n` (possibly in parallel), then fold the
/// per-chunk partials **in ascending chunk order** on the calling thread.
/// Chunk boundaries and fold order are thread-count-independent, so the
/// result is bit-deterministic (used for the loss and grad-norm
/// reductions).
pub fn par_map_reduce<R, M, F>(
    n: usize,
    chunk_len: usize,
    threads: usize,
    map: M,
    mut fold: F,
    init: R,
) -> R
where
    R: Send,
    M: Fn(usize, Range<usize>) -> R + Sync,
    F: FnMut(R, R) -> R,
{
    assert!(chunk_len > 0, "chunk_len must be positive");
    if n == 0 {
        return init;
    }
    let n_chunks = n.div_ceil(chunk_len);
    let threads = threads.clamp(1, n_chunks);
    let mut partials: Vec<(usize, R)> = Vec::with_capacity(n_chunks);
    if threads <= 1 {
        for i in 0..n_chunks {
            partials.push((i, map(i, chunk_range(i, chunk_len, n))));
        }
    } else {
        let map = &map;
        std::thread::scope(|scope| {
            let mut handles = Vec::with_capacity(threads - 1);
            for t in 1..threads {
                handles.push(scope.spawn(move || {
                    let mut out = Vec::new();
                    let mut i = t;
                    while i < n_chunks {
                        out.push((i, map(i, chunk_range(i, chunk_len, n))));
                        i += threads;
                    }
                    out
                }));
            }
            let mut i = 0;
            while i < n_chunks {
                partials.push((i, map(i, chunk_range(i, chunk_len, n))));
                i += threads;
            }
            for h in handles {
                partials.extend(h.join().expect("parallel worker panicked"));
            }
        });
        partials.sort_by_key(|(i, _)| *i);
    }
    let mut acc = init;
    for (_, r) in partials {
        acc = fold(acc, r);
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunks_cover_disjointly_any_thread_count() {
        for threads in [1usize, 2, 3, 8] {
            let mut v = vec![0u32; 103];
            par_chunks_mut(&mut v, 10, threads, |i, c| {
                for (j, x) in c.iter_mut().enumerate() {
                    *x = (i * 10 + j) as u32 + 1;
                }
            });
            // every element written exactly once with its own index
            for (k, x) in v.iter().enumerate() {
                assert_eq!(*x, k as u32 + 1, "threads={threads}");
            }
        }
    }

    #[test]
    fn join2_pairs_chunks_by_index() {
        for threads in [1usize, 4] {
            let mut a = vec![0u32; 12];
            let mut b = vec![0u32; 3];
            par_join2(&mut a, &mut b, 4, 1, threads, |i, ca, cb| {
                cb[0] = i as u32;
                for x in ca.iter_mut() {
                    *x = i as u32;
                }
            });
            assert_eq!(b, vec![0, 1, 2]);
            assert_eq!(&a[..4], &[0, 0, 0, 0]);
            assert_eq!(&a[8..], &[2, 2, 2, 2]);
        }
    }

    #[test]
    fn map_reduce_is_thread_count_invariant() {
        // f32 partial sums: chunked fold order must make the result
        // bit-identical across thread counts (the determinism contract)
        let xs: Vec<f32> = (0..1000).map(|i| (i as f32).sin() * 1e-3).collect();
        let sum_with = |threads| {
            par_map_reduce(
                xs.len(),
                64,
                threads,
                |_, r| xs[r].iter().sum::<f32>(),
                |a, b| a + b,
                0f32,
            )
        };
        let s1 = sum_with(1);
        for threads in [2usize, 3, 7] {
            assert_eq!(s1.to_bits(), sum_with(threads).to_bits());
        }
    }

    #[test]
    fn forced_thread_budget_is_scoped_and_thread_local() {
        assert!(max_threads() >= 1);
        let inside = with_max_threads(1, max_threads);
        assert_eq!(inside, 1);
        let nested = with_max_threads(4, || with_max_threads(2, max_threads));
        assert_eq!(nested, 2);
        assert!(max_threads() >= 1); // restored
    }

    #[test]
    fn threads_for_gates_small_work() {
        assert_eq!(threads_for(16), 1);
        assert_eq!(threads_for(u64::MAX), max_threads());
    }

    #[test]
    fn empty_and_degenerate_inputs() {
        let mut v: Vec<u8> = Vec::new();
        par_chunks_mut(&mut v, 4, 8, |_, _| panic!("no chunks expected"));
        let r = par_map_reduce(0, 4, 8, |_, _| 1u64, |a, b| a + b, 0u64);
        assert_eq!(r, 0);
    }
}
