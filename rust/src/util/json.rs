//! Minimal JSON parser/serializer.
//!
//! Substrate note: the offline crate set has no `serde`/`serde_json`, so the
//! manifest/config/checkpoint-metadata plumbing uses this hand-rolled
//! implementation. It supports the full JSON grammar we emit (objects,
//! arrays, strings with escapes, f64 numbers, bool, null) and round-trips
//! everything aot.py writes.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value (numbers are f64, objects are ordered maps).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number.
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object (keys sorted — serialization is deterministic).
    Obj(BTreeMap<String, Json>),
}

/// Parse failure with the byte offset it occurred at.
#[derive(Debug)]
pub struct JsonError {
    /// Byte position of the failure in the input.
    pub pos: usize,
    /// What the parser expected/found.
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    /// Parse a complete JSON document (trailing data is an error).
    ///
    /// ```
    /// use munit::util::json::Json;
    /// let j = Json::parse(r#"{"loss": 2.5, "ok": true}"#).unwrap();
    /// assert_eq!(j.f64_or("loss", 0.0), 2.5);
    /// assert_eq!(j.get("ok").unwrap().as_bool(), Some(true));
    /// ```
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- typed accessors -------------------------------------------------

    /// Object field lookup (`None` on non-objects / missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }
    /// String payload, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    /// Numeric payload, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    /// Number truncated to usize, if this is a number.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }
    /// Array elements, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    /// Boolean payload, if this is a bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    /// Convenience: `obj.str_or(key, default)`.
    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).and_then(|v| v.as_str()).unwrap_or(default)
    }
    /// `obj[key]` as f64, or `default`.
    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.as_f64()).unwrap_or(default)
    }
    /// `obj[key]` as usize, or `default`.
    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.as_usize()).unwrap_or(default)
    }

    // -- construction helpers --------------------------------------------

    /// Object from (key, value) pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    /// Number value.
    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }
    /// String value (copied).
    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }
    /// Array of f64 numbers.
    pub fn arr_f64(v: &[f64]) -> Json {
        Json::Arr(v.iter().map(|x| Json::Num(*x)).collect())
    }
    /// Array of f32 numbers (widened to f64).
    pub fn arr_f32(v: &[f32]) -> Json {
        Json::Arr(v.iter().map(|x| Json::Num(*x as f64)).collect())
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                // JSON has no NaN/inf literals; emitting them would make
                // the document unparseable (a diverged run's loss is the
                // realistic path here) — serialize non-finite as null
                if !n.is_finite() {
                    write!(f, "null")
                } else if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }
    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }
    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }
    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }
    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }
    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }
    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        std::str::from_utf8(&self.b[start..self.pos])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }
    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.pos + 1..self.pos + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // copy a run of plain bytes (UTF-8 passes through)
                    let start = self.pos;
                    while matches!(self.peek(), Some(c) if c != b'"' && c != b'\\') {
                        self.pos += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.pos])
                            .map_err(|_| self.err("bad utf8"))?,
                    );
                }
            }
        }
    }
    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }
    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            out.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse(r#""a\nb""#).unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2].str_or("b", ""),
            "c"
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("[1] x").is_err());
        assert!(Json::parse("'single'").is_err());
    }

    #[test]
    fn nonfinite_numbers_serialize_as_null() {
        // regression: a diverged run's NaN loss used to produce "NaN" —
        // not JSON — breaking every downstream parser of the report files
        let j = Json::obj(vec![
            ("nan", Json::num(f64::NAN)),
            ("inf", Json::num(f64::INFINITY)),
            ("ok", Json::num(2.0)),
        ]);
        let text = j.to_string();
        let parsed = Json::parse(&text).unwrap();
        assert_eq!(parsed.get("nan").unwrap(), &Json::Null);
        assert_eq!(parsed.get("inf").unwrap(), &Json::Null);
        assert_eq!(parsed.f64_or("ok", 0.0), 2.0);
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"name":"µS \"fp8\"","shape":[2,3],"ok":true,"x":-0.125}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse(r#""µS""#).unwrap(), Json::Str("µS".into()));
    }

    #[test]
    fn accessors() {
        let j = Json::parse(r#"{"s":"x","n":7,"b":false}"#).unwrap();
        assert_eq!(j.str_or("s", "d"), "x");
        assert_eq!(j.usize_or("n", 0), 7);
        assert_eq!(j.get("b").unwrap().as_bool(), Some(false));
        assert_eq!(j.str_or("missing", "d"), "d");
    }
}
