//! Synthetic text-corpus substrate.
//!
//! The paper's experiments need *natural-language-like* token statistics —
//! in particular the repeated/correlated value tokens that drive Fig 3 (and
//! through it the attention-variance behavior of Fig 2). We do not have the
//! authors' corpus, so we build a generator with the two properties that
//! matter (DESIGN.md substitution table):
//!
//!   1. **Zipfian unigram frequencies** (token rank-frequency ~ 1/k^s), the
//!      root cause of repeated tokens in any real corpus;
//!   2. **Markov (bigram) structure** so sequences are predictable enough
//!      for a language model to learn (loss decreases) and carry non-trivial
//!      in-context statistics for the eval tasks;
//!   3. an explicit **repetition mixture**: with probability `repeat_p`, the
//!      next token is copied from a recent window, mimicking the burstiness
//!      of real text (Church-style adaptation).
//!
//! Everything is deterministic in (seed, shard): worker `i` of `n` sees a
//! disjoint, reproducible stream — the property DDP data loading needs.

use crate::util::rng::{Rng, Zipf};

/// Corpus generation parameters.
#[derive(Debug, Clone)]
pub struct CorpusSpec {
    /// Vocabulary size (token ids are `0..vocab`).
    pub vocab: usize,
    /// Zipf exponent for rank-frequency (1.0-1.2 is text-like).
    pub zipf_s: f64,
    /// Probability of copying a token from the recent window.
    pub repeat_p: f64,
    /// Recent-window size for repetition.
    pub window: usize,
    /// Probability that a freshly sampled token comes from the *global*
    /// Zipf distribution (function words) rather than the bigram table
    /// (content structure). Keeps the unigram marginal Zipf-headed while
    /// per-state continuations stay strongly predictable.
    pub global_p: f64,
    /// Corpus identity: different seeds give different bigram tables.
    pub corpus_seed: u64,
}

impl Default for CorpusSpec {
    fn default() -> Self {
        CorpusSpec {
            vocab: 512,
            zipf_s: 1.1,
            repeat_p: 0.15,
            window: 32,
            global_p: 0.3,
            corpus_seed: 0xC0DE,
        }
    }
}

impl CorpusSpec {
    /// Per-state affine bijection rank -> token. The multiplier is odd
    /// (vocab is a power of two in all presets), making the map invertible
    /// so each state's conditional distribution is a permuted Zipf.
    fn rank_to_token(&self, prev: usize, rank: usize) -> usize {
        let h = prev
            .wrapping_mul(0x9E37_79B9)
            .wrapping_add(self.corpus_seed as usize)
            .wrapping_mul(0x85EB_CA6B);
        (rank.wrapping_mul(0x0001_0DCD) ^ h) % self.vocab
    }

    /// Global (state-independent) Zipf rank -> token bijection: the
    /// "function word" component that gives the corpus its Zipfian
    /// unigram head.
    fn global_token(&self, rank: usize) -> usize {
        (rank.wrapping_mul(0x0002_4F0B) ^ (self.corpus_seed as usize).wrapping_mul(3)) % self.vocab
    }

    /// Most likely continuation of `prev` under the pure-bigram component
    /// (rank 0). Ground truth for the bigram-cloze eval task.
    pub fn most_likely_next(&self, prev: usize) -> usize {
        self.rank_to_token(prev, 0)
    }

    /// Entropy (nats) of the Zipf rank distribution — a lower bound on the
    /// achievable next-token loss for the bigram component.
    pub fn zipf_entropy_nats(&self) -> f64 {
        let z = Zipf::new(self.vocab, self.zipf_s);
        -(0..self.vocab)
            .map(|k| {
                let p = z.pmf(k);
                if p > 0.0 {
                    p * p.ln()
                } else {
                    0.0
                }
            })
            .sum::<f64>()
    }
}

/// Infinite deterministic token stream for one shard.
pub struct TokenStream {
    spec: CorpusSpec,
    zipf: Zipf,
    rng: Rng,
    recent: Vec<u32>,
    prev: usize,
}

impl TokenStream {
    /// Stream for shard `shard` of `n_shards` under `seed` (disjoint,
    /// reproducible shards — the DDP loading property).
    pub fn new(spec: CorpusSpec, seed: u64, shard: usize, n_shards: usize) -> Self {
        assert!(shard < n_shards.max(1));
        let rng = Rng::new(seed).fork(0x5AD0 + shard as u64);
        let zipf = Zipf::new(spec.vocab, spec.zipf_s);
        TokenStream { spec, zipf, rng, recent: Vec::new(), prev: 0 }
    }

    /// Draw the next token (repetition / global-Zipf / bigram mixture).
    pub fn next_token(&mut self) -> u32 {
        let tok = if !self.recent.is_empty() && self.rng.f64() < self.spec.repeat_p {
            // burst repetition: copy from the recent window
            let i = self.rng.below(self.recent.len());
            self.recent[i]
        } else {
            let rank = self.zipf.sample(&mut self.rng);
            if self.rng.f64() < self.spec.global_p {
                self.spec.global_token(rank) as u32 // global Zipf head
            } else {
                self.spec.rank_to_token(self.prev, rank) as u32
            }
        };
        self.prev = tok as usize;
        self.recent.push(tok);
        if self.recent.len() > self.spec.window {
            self.recent.remove(0);
        }
        tok
    }

    /// Fill a buffer with consecutive stream tokens.
    pub fn fill(&mut self, out: &mut [i32]) {
        for v in out.iter_mut() {
            *v = self.next_token() as i32;
        }
    }
}

/// Deterministic batch producer: yields `[batch * seq_len]` i32 buffers.
pub struct Batcher {
    stream: TokenStream,
    /// Sequences per batch.
    pub batch: usize,
    /// Tokens per sequence.
    pub seq_len: usize,
    produced: usize,
}

impl Batcher {
    /// Batcher over one shard's [`TokenStream`].
    pub fn new(spec: CorpusSpec, seed: u64, shard: usize, n_shards: usize,
               batch: usize, seq_len: usize) -> Self {
        Batcher {
            stream: TokenStream::new(spec, seed, shard, n_shards),
            batch,
            seq_len,
            produced: 0,
        }
    }

    /// Produce the next `[batch * seq_len]` token buffer.
    pub fn next_batch(&mut self) -> Vec<i32> {
        let mut out = vec![0i32; self.batch * self.seq_len];
        self.stream.fill(&mut out);
        self.produced += 1;
        out
    }

    /// Batches produced so far.
    pub fn batches_produced(&self) -> usize {
        self.produced
    }

    /// Tokens per batch (`batch * seq_len`).
    pub fn tokens_per_batch(&self) -> usize {
        self.batch * self.seq_len
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::proptest::check;

    #[test]
    fn deterministic_per_seed_and_shard() {
        let spec = CorpusSpec::default();
        let mut a = TokenStream::new(spec.clone(), 7, 0, 2);
        let mut b = TokenStream::new(spec.clone(), 7, 0, 2);
        for _ in 0..500 {
            assert_eq!(a.next_token(), b.next_token());
        }
        let mut c = TokenStream::new(spec, 7, 1, 2);
        let same = (0..500).filter(|_| a.next_token() == c.next_token()).count();
        assert!(same < 250, "shards should differ ({same}/500 equal)");
    }

    #[test]
    fn tokens_in_vocab() {
        let spec = CorpusSpec { vocab: 128, ..Default::default() };
        let mut s = TokenStream::new(spec, 1, 0, 1);
        for _ in 0..2000 {
            assert!((s.next_token() as usize) < 128);
        }
    }

    #[test]
    fn zipfian_head_dominates() {
        let spec = CorpusSpec { repeat_p: 0.0, ..Default::default() };
        let mut s = TokenStream::new(spec.clone(), 2, 0, 1);
        let mut counts = vec![0usize; spec.vocab];
        for _ in 0..50_000 {
            counts[s.next_token() as usize] += 1;
        }
        let mut sorted = counts.clone();
        sorted.sort_unstable_by(|a, b| b.cmp(a));
        // top-16 tokens hold far more than the uniform 16/512 = 3.1% share
        // (global Zipf head + the bigram tables' own rank-0 concentration)
        let top: usize = sorted[..16].iter().sum();
        assert!(top as f64 > 0.10 * 50_000.0, "top16 share {top}");
    }

    #[test]
    fn repetition_raises_adjacent_duplicate_rate() {
        let base = CorpusSpec { repeat_p: 0.0, ..Default::default() };
        let bursty = CorpusSpec { repeat_p: 0.5, ..Default::default() };
        let dup_rate = |spec: CorpusSpec| {
            let mut s = TokenStream::new(spec, 3, 0, 1);
            let mut prev = s.next_token();
            let mut dups = 0;
            for _ in 0..20_000 {
                let t = s.next_token();
                if t == prev {
                    dups += 1;
                }
                prev = t;
            }
            dups as f64 / 20_000.0
        };
        assert!(dup_rate(bursty) > 2.0 * dup_rate(base).max(1e-4));
    }

    #[test]
    fn bigram_structure_learnable() {
        // conditioned on prev, the rank-0 token must be the modal next token
        let spec = CorpusSpec { repeat_p: 0.0, ..Default::default() };
        let mut s = TokenStream::new(spec.clone(), 4, 0, 1);
        let prev_target = 5usize;
        let want = spec.most_likely_next(prev_target);
        let mut counts = std::collections::HashMap::new();
        let mut prev = s.next_token() as usize;
        for _ in 0..200_000 {
            let t = s.next_token() as usize;
            if prev == prev_target {
                *counts.entry(t).or_insert(0usize) += 1;
            }
            prev = t;
        }
        let modal = counts.iter().max_by_key(|(_, c)| **c).map(|(t, _)| *t).unwrap();
        assert_eq!(modal, want);
    }

    #[test]
    fn batcher_shapes_and_counter() {
        let mut b = Batcher::new(CorpusSpec::default(), 0, 0, 1, 4, 128);
        let batch = b.next_batch();
        assert_eq!(batch.len(), 4 * 128);
        assert!(batch.iter().all(|&t| t >= 0 && (t as usize) < 512));
        b.next_batch();
        assert_eq!(b.batches_produced(), 2);
    }

    #[test]
    fn prop_rank_map_is_bijective() {
        check("rank_to_token bijective per state", 20, |rng, _| {
            let spec = CorpusSpec {
                vocab: 256,
                corpus_seed: rng.next_u64(),
                ..Default::default()
            };
            let prev = rng.below(256);
            let mut seen = vec![false; 256];
            for rank in 0..256 {
                let t = spec.rank_to_token(prev, rank);
                prop_assert!(!seen[t], "collision at rank {rank} state {prev}");
                seen[t] = true;
            }
            Ok(())
        });
    }

    #[test]
    fn prop_streams_reproducible_after_batching() {
        check("batcher determinism", 10, |rng, _| {
            let seed = rng.next_u64();
            let mut a = Batcher::new(CorpusSpec::default(), seed, 0, 4, 2, 64);
            let mut b = Batcher::new(CorpusSpec::default(), seed, 0, 4, 2, 64);
            for _ in 0..3 {
                prop_assert!(a.next_batch() == b.next_batch(), "batches diverged");
            }
            Ok(())
        });
    }

    #[test]
    fn entropy_bound_sane() {
        let spec = CorpusSpec::default();
        let h = spec.zipf_entropy_nats();
        // between 0 and ln(vocab)
        assert!(h > 1.0 && h < (spec.vocab as f64).ln(), "{h}");
    }
}
