//! In-context evaluation harness (Table 5 substitute).
//!
//! The paper evaluates on the Databricks Gauntlet; offline we build
//! synthetic tasks with known ground truth over the same corpus generator
//! the models were trained on, all computed from a `fwd` artifact's logits
//! (so FP8 inference numerics — the "training-inference match" claim —
//! are exercised end to end):
//!
//!  - **next-token accuracy / NLL** on held-out corpus shards (the
//!    language-modeling analog of the Gauntlet's aggregate score);
//!  - **bigram cloze**: accuracy on positions whose generator-modal
//!    continuation is well-defined (`CorpusSpec::most_likely_next`);
//!  - **repetition**: accuracy on positions whose target already appeared
//!    in the recent window (tests the induction-y behavior real text
//!    rewards, cf. Fig 3);
//!  - **copy/induction**: synthetic `prefix ++ prefix` prompts, scored on
//!    the repeated half (pure in-context recall).

use crate::bail;
use crate::config::ModelConfig;
use crate::data::{Batcher, CorpusSpec};
use crate::runtime::{scalar_f32, tensor_i32, Backend, InferSession, Tensor, TensorHandle};
use crate::util::error::{Context, Result};
use crate::util::rng::Rng;

/// Aggregate scores of the in-context eval suite.
#[derive(Debug, Clone, Default)]
pub struct EvalReport {
    /// Greedy next-token accuracy on held-out shards.
    pub next_token_acc: f64,
    /// Mean next-token negative log-likelihood.
    pub avg_nll: f64,
    /// Accuracy restricted to positions whose modal continuation is
    /// defined by the corpus bigram table.
    pub bigram_cloze_acc: f64,
    /// Accuracy on positions whose target already appeared recently.
    pub repeat_acc: f64,
    /// Accuracy on the repeated half of `prefix ++ prefix` prompts.
    pub induction_acc: f64,
    /// Held-out positions behind `next_token_acc` / `avg_nll`.
    pub positions_scored: usize,
}

/// Run the full suite. `params` are the model's parameter tensors (from a
/// `TrainState` / `Session::params_host`), `tau` the residual coefficient
/// it was trained with.
pub fn evaluate(
    backend: &dyn Backend,
    cfg: &ModelConfig,
    params: &[Tensor],
    tau: f64,
    corpus: &CorpusSpec,
    n_batches: usize,
    seed: u64,
) -> Result<EvalReport> {
    let meta = backend
        .resolve("fwd", cfg)
        .with_context(|| format!("no fwd artifact for {}", cfg.name()))?;
    let fwd_name = meta.name.clone();
    if params.len() != meta.inputs.len() - 2 {
        bail!("expected {} param tensors, got {}", meta.inputs.len() - 2, params.len());
    }

    // upload the parameters once; every forward batch reuses the
    // device-resident handles (the whole point of the handle API)
    let mut fwd = FwdRunner::upload(backend, &fwd_name, params, tau)?;

    let mut report = EvalReport::default();
    let mut nll_sum = 0f64;
    let mut nt_hits = 0usize;
    let mut nt_total = 0usize;
    let mut cloze_hits = 0usize;
    let mut cloze_total = 0usize;
    let mut rep_hits = 0usize;
    let mut rep_total = 0usize;

    // held-out shard: use a shard id outside the training range
    let mut batcher = Batcher::new(corpus.clone(), seed, 7, 8, cfg.batch, cfg.seq_len);
    for _ in 0..n_batches {
        let tokens = batcher.next_batch();
        let logits = fwd.logits(cfg, &tokens)?;
        score_lm(cfg, corpus, &tokens, &logits, &mut nll_sum, &mut nt_hits, &mut nt_total,
                 &mut cloze_hits, &mut cloze_total, &mut rep_hits, &mut rep_total);
    }

    // induction prompts: [prefix, prefix] with uniform-random prefix
    let mut rng = Rng::new(seed ^ 0xABCD);
    let mut ind_hits = 0usize;
    let mut ind_total = 0usize;
    {
        let half = cfg.seq_len / 2;
        let mut tokens = vec![0i32; cfg.batch * cfg.seq_len];
        for b in 0..cfg.batch {
            for t in 0..half {
                let v = rng.below(cfg.vocab) as i32;
                tokens[b * cfg.seq_len + t] = v;
                tokens[b * cfg.seq_len + half + t] = v;
            }
        }
        let logits = fwd.logits(cfg, &tokens)?;
        let v = cfg.vocab;
        for b in 0..cfg.batch {
            // score predictions inside the repeated half
            for t in half..cfg.seq_len - 1 {
                let row = &logits[(b * cfg.seq_len + t) * v..(b * cfg.seq_len + t + 1) * v];
                let pred = argmax(row);
                if pred == tokens[b * cfg.seq_len + t + 1] as usize {
                    ind_hits += 1;
                }
                ind_total += 1;
            }
        }
    }

    report.next_token_acc = nt_hits as f64 / nt_total.max(1) as f64;
    report.avg_nll = nll_sum / nt_total.max(1) as f64;
    report.bigram_cloze_acc = cloze_hits as f64 / cloze_total.max(1) as f64;
    report.repeat_acc = rep_hits as f64 / rep_total.max(1) as f64;
    report.induction_acc = ind_hits as f64 / ind_total.max(1) as f64;
    report.positions_scored = nt_total;
    Ok(report)
}

/// Device-resident forward runner: parameters (and the tau scalar) are
/// uploaded once; each `logits` call only moves a token batch in and the
/// logits out. Handles are freed on drop.
struct FwdRunner<'b> {
    backend: &'b dyn Backend,
    fwd_name: String,
    param_handles: Vec<TensorHandle>,
    tau_handle: TensorHandle,
}

impl<'b> FwdRunner<'b> {
    fn upload(
        backend: &'b dyn Backend,
        fwd_name: &str,
        params: &[Tensor],
        tau: f64,
    ) -> Result<FwdRunner<'b>> {
        let mut param_handles = Vec::with_capacity(params.len());
        for t in params {
            match backend.upload(t) {
                Ok(h) => param_handles.push(h),
                Err(e) => {
                    for h in &param_handles {
                        backend.free(h);
                    }
                    return Err(e.context("uploading eval params"));
                }
            }
        }
        let tau_handle = match backend.upload(&scalar_f32(tau as f32)) {
            Ok(h) => h,
            Err(e) => {
                for h in &param_handles {
                    backend.free(h);
                }
                return Err(e.context("uploading eval tau scalar"));
            }
        };
        Ok(FwdRunner { backend, fwd_name: fwd_name.to_string(), param_handles, tau_handle })
    }

    fn logits(&mut self, cfg: &ModelConfig, tokens: &[i32]) -> Result<Vec<f32>> {
        let tok = tensor_i32(tokens, &[cfg.batch, cfg.seq_len])?;
        let tok_h = self.backend.upload(&tok)?;
        let mut inputs = self.param_handles.clone();
        inputs.push(tok_h.clone());
        inputs.push(self.tau_handle.clone());
        let result = self.backend.execute(&self.fwd_name, &inputs);
        self.backend.free(&tok_h);
        let outs = result?;
        let logits = outs
            .first()
            .map(|h| self.backend.download(h))
            .unwrap_or_else(|| Err(crate::err!("fwd '{}' produced no outputs", self.fwd_name)))
            .and_then(|t| t.to_f32_vec());
        for h in &outs {
            self.backend.free(h);
        }
        logits
    }
}

impl Drop for FwdRunner<'_> {
    fn drop(&mut self) {
        for h in &self.param_handles {
            self.backend.free(h);
        }
        self.backend.free(&self.tau_handle);
    }
}

#[allow(clippy::too_many_arguments)]
fn score_lm(
    cfg: &ModelConfig,
    corpus: &CorpusSpec,
    tokens: &[i32],
    logits: &[f32],
    nll_sum: &mut f64,
    nt_hits: &mut usize,
    nt_total: &mut usize,
    cloze_hits: &mut usize,
    cloze_total: &mut usize,
    rep_hits: &mut usize,
    rep_total: &mut usize,
) {
    let v = cfg.vocab;
    for b in 0..cfg.batch {
        for t in 0..cfg.seq_len - 1 {
            let base = (b * cfg.seq_len + t) * v;
            let row = &logits[base..base + v];
            let target = tokens[b * cfg.seq_len + t + 1] as usize;
            let pred = argmax(row);
            *nll_sum += nll_of(row, target);
            if pred == target {
                *nt_hits += 1;
            }
            *nt_total += 1;
            // bigram cloze: score positions where the target IS the modal
            // continuation (the model should recover the bigram table)
            let prev = tokens[b * cfg.seq_len + t] as usize;
            if corpus.most_likely_next(prev) == target {
                if pred == target {
                    *cloze_hits += 1;
                }
                *cloze_total += 1;
            }
            // repetition: target already appeared in the recent window
            let w0 = t.saturating_sub(corpus.window);
            let seen = (w0..=t).any(|i| tokens[b * cfg.seq_len + i] as usize == target);
            if seen {
                if pred == target {
                    *rep_hits += 1;
                }
                *rep_total += 1;
            }
        }
    }
}

/// Mean next-token NLL of one token sequence through the **incremental
/// decode path**: the sequence is fed one token per step through the KV
/// cache and each step's logits score the next token. The
/// training-inference numerics-match check: under the static-FP8 and
/// BF16 plans every decode step's logits are bit-identical to the
/// corresponding `fwd` row, so this equals [`fwd_nll`] *exactly* (tested
/// — not within a tolerance).
pub fn decode_nll(infer: &mut InferSession, tokens: &[i32]) -> Result<f64> {
    if tokens.len() < 2 {
        bail!("decode_nll needs at least 2 tokens, got {}", tokens.len());
    }
    // the final token is only scored, never fed — decode_step's own
    // validation would miss it, and nll_of would index out of bounds
    check_vocab(tokens, infer.config().vocab)?;
    if tokens.len() - 1 > infer.context_capacity() {
        bail!(
            "decode_nll: {} tokens need {} decode steps, beyond context capacity {}",
            tokens.len(),
            tokens.len() - 1,
            infer.context_capacity()
        );
    }
    let id = infer.add_sequence();
    // free the sequence on every path — a mid-loop decode error must not
    // leave it holding KV pages in a long-lived session
    let scored = (|| -> Result<f64> {
        let mut nll = 0f64;
        let mut logits = infer.decode_step(id, tokens[0])?;
        for t in 1..tokens.len() {
            nll += nll_of(&logits, tokens[t] as usize);
            if t + 1 < tokens.len() {
                logits = infer.decode_step(id, tokens[t])?;
            }
        }
        Ok(nll / (tokens.len() - 1) as f64)
    })();
    let freed = infer.free_sequence(id);
    let nll = scored?;
    freed?;
    Ok(nll)
}

/// Mean next-token NLL of one sequence from full-sequence logits
/// (`[seq_len, vocab]`, a `fwd` artifact row block) — the same scoring
/// [`decode_nll`] applies step by step.
pub fn fwd_nll(cfg: &ModelConfig, logits: &[f32], tokens: &[i32]) -> Result<f64> {
    let (s, v) = (tokens.len(), cfg.vocab);
    if s < 2 || logits.len() != s * v {
        bail!("fwd_nll: {} logits for {} tokens of vocab {}", logits.len(), s, v);
    }
    check_vocab(tokens, v)?;
    let mut nll = 0f64;
    for t in 0..s - 1 {
        nll += nll_of(&logits[t * v..(t + 1) * v], tokens[t + 1] as usize);
    }
    Ok(nll / (s - 1) as f64)
}

// one shared token-range check across train/infer/eval entry points
use crate::runtime::block::check_tokens as check_vocab;

fn argmax(row: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in row.iter().enumerate() {
        if x > row[best] {
            best = i;
        }
    }
    best
}

fn nll_of(row: &[f32], target: usize) -> f64 {
    let m = row.iter().copied().fold(f32::NEG_INFINITY, f32::max) as f64;
    let z: f64 = row.iter().map(|&x| ((x as f64) - m).exp()).sum();
    -((row[target] as f64 - m) - z.ln())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::block;

    /// The numerics-match claim at the eval layer: NLL computed token by
    /// token through the KV-cache decode path equals NLL from the
    /// full-sequence forward EXACTLY (f64 bit equality — both score
    /// bit-identical logits with the same `nll_of`), for the µS
    /// static-FP8 and BF16 plans.
    #[test]
    fn nll_via_decode_matches_nll_via_fwd_exactly() {
        for precision in ["fp8", "bf16"] {
            let cfg = ModelConfig {
                width: 16,
                depth: 2,
                head_dim: 8,
                vocab: 64,
                seq_len: 12,
                batch: 2,
                precision: precision.into(),
                ..ModelConfig::default()
            };
            let params = block::init_params(&cfg, 13);
            let prep = crate::runtime::block::Prepared::new(&cfg, 0.4).unwrap();
            let tokens: Vec<i32> = (0..cfg.batch * cfg.seq_len)
                .map(|i| ((i * 7 + 2) % cfg.vocab) as i32)
                .collect();
            let full = block::forward_logits(&cfg, &prep, &params, &tokens).unwrap();
            let (s, v) = (cfg.seq_len, cfg.vocab);
            let mut infer = InferSession::from_params(&cfg, params, 0.4).unwrap();
            for b in 0..cfg.batch {
                let seq_toks = &tokens[b * s..(b + 1) * s];
                let via_fwd =
                    fwd_nll(&cfg, &full[b * s * v..(b + 1) * s * v], seq_toks).unwrap();
                let via_decode = decode_nll(&mut infer, seq_toks).unwrap();
                assert_eq!(
                    via_decode.to_bits(),
                    via_fwd.to_bits(),
                    "mus+{precision} seq {b}: decode NLL {via_decode} vs fwd NLL {via_fwd}"
                );
            }
        }
    }

    #[test]
    fn argmax_and_nll() {
        let row = [0.0f32, 2.0, -1.0];
        assert_eq!(argmax(&row), 1);
        let p1 = nll_of(&row, 1);
        let p0 = nll_of(&row, 0);
        assert!(p1 < p0);
        // probabilities sum to 1 => exp(-nll) over all targets sums to 1
        let total: f64 = (0..3).map(|t| (-nll_of(&row, t)).exp()).sum();
        assert!((total - 1.0).abs() < 1e-9);
    }
}
