//! Table reproductions: Fig 1 / Table 3 (scheme comparison), Table 2
//! (scaling rules), Table 4 (configs + memory plan), Table 5 (evals).

use super::{corpus_for, proxy_tc, train_with_state, Ctx};
use crate::runtime::Backend;
use crate::util::error::Result;
use crate::config::presets::{paper_model, paper_table4};
use crate::config::ModelConfig;
use crate::eval::evaluate;
use crate::perfmodel::memory_per_gpu;
use crate::scaling::{comparison_matrix, ParamKind, Scheme};
use crate::util::table;

/// Fig 1 + Table 3: method comparison matrix + hyperparameter counts.
pub fn table3(_ctx: &Ctx) -> Result<String> {
    let rows: Vec<Vec<String>> = comparison_matrix()
        .iter()
        .map(|r| {
            vec![
                r.scheme.name().to_string(),
                if r.uses_fp8 { "yes" } else { "no" }.into(),
                if r.hp_transfer { "yes" } else { "no" }.into(),
                r.n_hparams.to_string(),
                if r.no_dynamic_scaling { "yes" } else { "no" }.into(),
                if r.train_infer_match { "yes" } else { "no" }.into(),
                format!("{:.0}%", r.scheme.fp8_hidden_fraction() * 100.0),
            ]
        })
        .collect();
    let t = table::render(
        &["scheme", "FP8", "HP transfer", "#hparams", "static scales", "train=infer", "FP8 hidden FLOPs"],
        &rows,
    );
    let mut hp = String::new();
    for s in [Scheme::Mus, Scheme::Sp, Scheme::Mup, Scheme::Ump] {
        hp.push_str(&format!("  {:<28} {}\n", s.name(), s.hyperparameters().join(", ")));
    }
    Ok(format!("Fig 1 / Table 3 — scheme comparison\n{t}\nhyperparameters:\n{hp}"))
}

/// Table 2: µS scaling rules as implemented.
pub fn table2(_ctx: &Ctx) -> Result<String> {
    let f = 1024usize;
    let rows = vec![
        vec![
            "init var".into(),
            format!("{}", Scheme::Mus.init_std(ParamKind::Input, f, 0.0).powi(2)),
            format!("{}", Scheme::Mus.init_std(ParamKind::Hidden, f, 0.0).powi(2)),
            format!("{}", Scheme::Mus.init_std(ParamKind::Output, f, 0.0).powi(2)),
        ],
        vec![
            "output mult".into(),
            format!("{}", Scheme::Mus.output_mult(ParamKind::Input, f)),
            "1/√fan_in".into(),
            "1/fan_in".into(),
        ],
        vec![
            "η transfer (d_base→d)".into(),
            "1".into(),
            "√(d_base/d)".into(),
            "1".into(),
        ],
        vec!["λ transfer".into(), "1".into(), "1".into(), "1".into()],
    ];
    let t = table::render(&["rule", "input (embed)", "hidden", "output (head)"], &rows);
    Ok(format!("Table 2 — µS scaling rules (as implemented in configs.py + scaling/)\n{t}"))
}

/// Table 4: production configs, parameter counts, memory plan.
pub fn table4(_ctx: &Ctx) -> Result<String> {
    let rows: Vec<Vec<String>> = paper_table4()
        .iter()
        .map(|p| {
            let m = paper_model(p);
            vec![
                p.name.to_string(),
                format!("{:.1}B", m.n_params() as f64 / 1e9),
                format!("{:.1}B", p.tokens_b),
                format!("{:.1}", p.tokens_b / p.params_b),
                p.steps.to_string(),
                p.batch.to_string(),
                p.seq_len.to_string(),
                p.width.to_string(),
                p.depth.to_string(),
                p.n_heads.to_string(),
                format!("{:.1}", p.tau),
                format!("{:.1}GB", memory_per_gpu(p, 64) / 1e9),
            ]
        })
        .collect();
    let t = table::render(
        &["model", "params", "tokens", "TPR", "steps", "batch", "seq", "width", "depth", "heads", "τ", "mem/GPU"],
        &rows,
    );
    Ok(format!("Table 4 — model training configurations (+ ZeRO-1 memory plan, 64 GPUs)\n{t}"))
}

/// Table 5: eval suite over the four (variant, precision) quad-L models.
pub fn table5(ctx: &Ctx) -> Result<String> {
    let steps = ctx.steps(240);
    let (w, d) = (256usize, 8usize);
    let tau = crate::scaling::recommended_tau(d);
    let mut rows = Vec::new();
    for (variant, precision) in [("sp", "bf16"), ("sp", "fp8"), ("mus", "bf16"), ("mus", "fp8")] {
        let cfg = ModelConfig {
            width: w,
            depth: d,
            variant: variant.into(),
            precision: precision.into(),
            residual: if variant == "mus" { "fixed".into() } else { "standard".into() },
            ..ModelConfig::default()
        };
        let lr = if variant == "mus" { super::figures::MUS_LR } else { super::figures::SP_LR };
        let (sum, state) = train_with_state(ctx, &cfg, &proxy_tc(steps, lr, super::figures::WD, tau, 5))?;
        // eval needs a fwd artifact for this exact graph; skip the eval
        // columns when the backend has none
        let has_fwd = ctx.backend().resolve("fwd", &cfg).is_ok();
        let (nt, nll, cloze, rep, ind) = if has_fwd {
            let corpus = corpus_for(&cfg);
            let e = evaluate(ctx.backend(), &cfg, state.params(), tau, &corpus, 4, 77)?;
            (
                format!("{:.1}%", e.next_token_acc * 100.0),
                format!("{:.3}", e.avg_nll),
                format!("{:.1}%", e.bigram_cloze_acc * 100.0),
                format!("{:.1}%", e.repeat_acc * 100.0),
                format!("{:.1}%", e.induction_acc * 100.0),
            )
        } else {
            ("-".into(), "-".into(), "-".into(), "-".into(), "-".into())
        };
        rows.push(vec![
            format!("{variant} {precision}"),
            format!("{:.4}", sum.final_loss),
            nt,
            nll,
            cloze,
            rep,
            ind,
        ]);
    }
    let t = table::render(
        &["model", "final loss", "next-tok acc", "eval NLL", "bigram cloze", "repetition", "induction"],
        &rows,
    );
    Ok(format!(
        "Table 5 — eval suite (synthetic Gauntlet substitute, quad-L proxies)\n\
         Expect: µS ≥ SP quality; FP8 ≈ BF16 within noise.\n{t}"
    ))
}
