//! One driver per paper figure. Every driver prints the same rows/series
//! the paper plots and returns them as a report string (recorded in
//! EXPERIMENTS.md). Proxy shapes per DESIGN.md §2 hardware-adaptation.

use super::{proxy_tc, run_probe, train_cached, train_with_state, Ctx};
use crate::runtime::Backend;
use crate::util::error::{Context, Result};
use crate::config::TrainConfig;

/// Cache-aware sweep: one `train_cached` run per grid point (so figure
/// reruns are incremental, unlike `sweep::run_sequential`).
fn sweep_cached(
    ctx: &Ctx,
    cfg: &ModelConfig,
    base: &TrainConfig,
    points: &[sweep::SweepPoint],
) -> Result<Vec<sweep::SweepOutcome>> {
    let mut out = Vec::with_capacity(points.len());
    for (i, p) in points.iter().enumerate() {
        let tc = TrainConfig { lr: p.lr, wd: p.wd, tau: p.tau, ..base.clone() };
        let r = train_cached(ctx, cfg, &tc)?;
        eprintln!(
            "  [{}/{}] lr=2^{:.0} wd={:.4} tau={:.2} -> loss {:.4}{}",
            i + 1, points.len(), p.lr.log2(), p.wd, p.tau, r.final_loss,
            if r.diverged { " DIVERGED" } else { "" }
        );
        out.push(sweep::SweepOutcome {
            point: *p,
            final_loss: r.final_loss,
            diverged: r.diverged,
            spikes: r.spikes,
        });
    }
    Ok(out)
}
use crate::analysis::{
    activation_underflow, activations::Activation, attention_sigma2_theory,
    attention_sigma_iid, hist_tail_mass, iid_cosine_baseline, AttentionKind, InputDist,
};
use crate::config::ModelConfig;
use crate::coordinator::sweep;
use crate::fp8::E4M3;
use crate::perfmodel::{fig8 as perf_fig8, Hw};
use crate::scaling::recommended_tau;
use crate::util::rng::Rng;
use crate::util::table;

fn proxy(width: usize, depth: usize) -> ModelConfig {
    ModelConfig { width, depth, ..ModelConfig::default() }
}

fn sp_proxy(width: usize, depth: usize) -> ModelConfig {
    ModelConfig {
        width,
        depth,
        variant: "sp".into(),
        precision: "bf16".into(),
        residual: "standard".into(),
        ..ModelConfig::default()
    }
}

/// Default µS base learning rate for proxy training (found by the fig6
/// sweep; stable for µS by construction).
pub const MUS_LR: f64 = 1.0 / 64.0;
/// Default SP base learning rate for proxy training.
pub const SP_LR: f64 = 1.0 / 256.0;
/// Default weight decay for proxy training.
pub const WD: f64 = 2f64 / 16384.0;

/// Fig 2: attention output sigma vs sequence position — iid simulation
/// (rust Monte Carlo) + observed in a trained µS model (probe artifact).
pub fn fig2(ctx: &Ctx) -> Result<String> {
    let positions = [2usize, 4, 8, 16, 32, 64, 96, 127];
    let mut rng = Rng::new(2);
    let sim_std = attention_sigma_iid(&positions, 16, 400, AttentionKind::Standard, &mut rng);
    let sim_sqrt =
        attention_sigma_iid(&positions, 16, 400, AttentionKind::SqrtSoftmax, &mut rng);

    // observed: probe a briefly-trained µS model (w128 d6)
    let cfg = proxy(128, 6);
    if ctx.backend().resolve("probe", &cfg).is_err() {
        // no probe artifacts on this backend: report the simulation/theory
        // columns only (the trained columns need the AOT probe catalogue)
        let mut rows = Vec::new();
        for (i, &k) in positions.iter().enumerate() {
            rows.push(vec![
                k.to_string(),
                table::f(sim_std[i].1, 3),
                table::f(attention_sigma2_theory(k).sqrt(), 3),
                table::f(sim_sqrt[i].1, 3),
            ]);
        }
        let t = table::render(&["pos k", "sim std", "theory(√(e/k))", "sim sqrt"], &rows);
        return Ok(format!(
            "Fig 2 — attention output σ vs position (iid sim + Prop 2.1 theory)\n\
             Trained-probe columns skipped: no probe artifacts on this backend\n\
             (build with `make artifacts` and --features pjrt).\n{t}"
        ));
    }
    let tau = recommended_tau(cfg.depth);
    let tc = proxy_tc(ctx.steps(150), MUS_LR, WD, tau, 1);
    let (_sum, state) = train_with_state(ctx, &cfg, &tc)?;
    let probe = run_probe(ctx, &cfg, state.params(), tau, 99)?;
    let get = |k: &str| probe.iter().find(|(n, _)| n == k).map(|(_, v)| v.clone()).unwrap();
    let attn_std = get("attn_std"); // [L, S] flattened
    let attn_sqrt_std = get("attn_sqrt_std");
    let s = cfg.seq_len;
    let mid_layer = cfg.depth / 2;
    let mut rows = Vec::new();
    for (i, &k) in positions.iter().enumerate() {
        rows.push(vec![
            k.to_string(),
            table::f(sim_std[i].1, 3),
            table::f(attention_sigma2_theory(k).sqrt(), 3),
            table::f(sim_sqrt[i].1, 3),
            table::f(attn_std[mid_layer * s + k] as f64, 3),
            table::f(attn_sqrt_std[mid_layer * s + k] as f64, 3),
        ]);
    }
    let t = table::render(
        &["pos k", "sim std", "theory(√(e/k))", "sim sqrt", "trained std", "trained sqrt"],
        &rows,
    );
    Ok(format!(
        "Fig 2 — attention output σ vs position (iid sim, Prop 2.1 theory, trained probe layer {mid_layer})\n\
         Expect: sim/trained standard σ decay with k; sqrt-softmax flat (sim) and\n\
         rising for trained (correlated real values, Fig 3 mechanism).\n{t}"
    ))
}

/// Fig 3: value-token cosine similarity, trained model vs iid baseline.
pub fn fig3(ctx: &Ctx) -> Result<String> {
    let cfg = proxy(128, 6);
    if ctx.backend().resolve("probe", &cfg).is_err() {
        return Ok("Fig 3 — value-token cosine similarity: needs probe artifacts \
                   (build with `make artifacts` and --features pjrt); skipped on \
                   this backend.\n"
            .into());
    }
    let tau = recommended_tau(cfg.depth);
    let tc = proxy_tc(ctx.steps(150), MUS_LR, WD, tau, 1);
    let (_s, state) = train_with_state(ctx, &cfg, &tc)?;
    let probe = run_probe(ctx, &cfg, state.params(), tau, 99)?;
    let vcos = &probe.iter().find(|(n, _)| n == "vcos").unwrap().1;
    let s = cfg.seq_len;
    let baseline = iid_cosine_baseline(cfg.head_dim);
    let mut rows = Vec::new();
    for &k in &[4usize, 16, 48, 96, 127] {
        let mean_layers: f64 = (0..cfg.depth).map(|l| vcos[l * s + k] as f64).sum::<f64>()
            / cfg.depth as f64;
        rows.push(vec![
            k.to_string(),
            table::f(mean_layers, 4),
            table::f(baseline, 4),
            table::f(mean_layers / baseline, 2),
        ]);
    }
    let t = table::render(&["pos k", "observed cos", "iid baseline", "ratio"], &rows);
    Ok(format!(
        "Fig 3 — value-token cosine similarity (trained µS probe vs iid N(0,1))\n\
         Expect: observed ≫ iid baseline (repeated tokens in text-like data).\n{t}"
    ))
}

/// Fig 4b: deep-model convergence, µS Res-Post-LN (fp8) vs SP Pre-LN (bf16).
pub fn fig4b(ctx: &Ctx) -> Result<String> {
    let steps = ctx.steps(300);
    let mus = proxy(64, 24);
    let sp = sp_proxy(64, 24);
    let tau = recommended_tau(24);
    let r_mus = train_cached(ctx, &mus, &proxy_tc(steps, MUS_LR, WD, tau, 3))?;
    let r_sp = train_cached(ctx, &sp, &proxy_tc(steps, SP_LR, WD, 0.0, 3))?;
    let mut rows = Vec::new();
    for frac in [0.1, 0.25, 0.5, 0.75, 1.0] {
        let i = ((steps as f64 * frac) as usize).min(r_mus.losses.len() - 1).min(r_sp.losses.len() - 1);
        rows.push(vec![
            format!("{}", i),
            table::f(r_mus.losses[i] as f64, 4),
            table::f(r_sp.losses[i] as f64, 4),
        ]);
    }
    let t = table::render(&["step", "µS res-post-LN (FP8)", "SP pre-LN (BF16)"], &rows);
    Ok(format!(
        "Fig 4b — deep ({}L proxy for 100L) convergence: µS vs SP\n\
         Expect: nearly identical convergence (final Δ small).\n{t}\n\
         final: µS {:.4} vs SP {:.4} (Δ {:+.4})\n",
        24, r_mus.final_loss, r_sp.final_loss, r_mus.final_loss - r_sp.final_loss
    ))
}

/// Fig 5: fixed vs running-mean residual scheme on the deep proxy.
pub fn fig5(ctx: &Ctx) -> Result<String> {
    let steps = ctx.steps(300);
    let tau = 0.1; // the paper's Fig 5 uses tau = 0.1 on 100 layers
    let fixed = proxy(64, 24);
    let running = ModelConfig { residual: "running_mean".into(), ..proxy(64, 24) };
    let r_fix = train_cached(ctx, &fixed, &proxy_tc(steps, MUS_LR, WD, tau, 4))?;
    let r_run = train_cached(ctx, &running, &proxy_tc(steps, MUS_LR, WD, tau, 4))?;
    let mut rows = Vec::new();
    for frac in [0.25, 0.5, 0.75, 1.0] {
        let i = ((steps as f64 * frac) as usize).min(r_fix.losses.len() - 1).min(r_run.losses.len() - 1);
        rows.push(vec![
            format!("{i}"),
            table::f(r_fix.losses[i] as f64, 4),
            table::f(r_run.losses[i] as f64, 4),
        ]);
    }
    let t = table::render(&["step", "fixed(τ=0.1)", "running-mean"], &rows);
    Ok(format!(
        "Fig 5 — residual modification schemes (deep µS proxy)\n\
         Expect: fixed converges at least as well as running-mean.\n{t}\n\
         final: fixed {:.4} vs running-mean {:.4}\n",
        r_fix.final_loss, r_run.final_loss
    ))
}

/// Fig 6: η* and λ* vs width for µS (stable) and SP (η* ~ 1/width).
///
/// Two-stage sweep per (width, variant), matching the paper's panels (each
/// curve holds the other hyperparameter at its optimum): stage 1 sweeps η
/// over powers of two at λ = WD; stage 2 sweeps λ at η*.
pub fn fig6(ctx: &Ctx) -> Result<String> {
    let widths = [32usize, 64, 128, 256];
    let steps = ctx.steps(120);
    let lr_axis = sweep::pow2_axis(-9, -5);
    let wd_axis = [WD / 8.0, WD, WD * 8.0];
    let mut report = String::from(
        "Fig 6 — optimal η* and λ* across widths (base width 32; lr axis means η at d_base)\n\
         Expect: µS η*/λ* flat; SP's effective per-layer LR shifts ~1/width\n\
         (the artifact bakes the transfer rule, so a FLAT η* column here means\n\
         the rule is correct — for SP we also report the implied raw LR).\n",
    );
    for (variant, lr_mul_note) in [("mus", "√(32/w)"), ("sp", "32/w")] {
        let mut rows = Vec::new();
        for &w in &widths {
            let cfg = if variant == "mus" { proxy(w, 4) } else { sp_proxy(w, 4) };
            let tau = 0.4;
            let base_tc = proxy_tc(steps, 0.0, 0.0, tau, 6);
            // stage 1: eta sweep at fixed lambda
            let pts1 = sweep::grid(&lr_axis, &[WD], &[tau]);
            let out1 = sweep_cached(ctx, &cfg, &base_tc, &pts1)?;
            let best1 = sweep::best(&out1).context("all eta runs diverged")?;
            let eta_star = best1.point.lr;
            // stage 2: lambda sweep at eta*
            let pts2 = sweep::grid(&[eta_star], &wd_axis, &[tau]);
            let out2 = sweep_cached(ctx, &cfg, &base_tc, &pts2)?;
            let best2 = sweep::best(&out2).context("all lambda runs diverged")?;
            rows.push(vec![
                w.to_string(),
                format!("2^{:.0}", eta_star.log2()),
                format!("2^{:.0}", (eta_star * (cfg.d_base as f64 / w as f64)).log2()),
                format!("{:.5}", best2.point.wd),
                table::f(best2.final_loss, 4),
                format!("{}", out1.iter().chain(&out2).filter(|o| o.diverged).count()),
            ]);
        }
        report.push_str(&format!(
            "\n{} (per-layer mult {}):\n{}",
            if variant == "mus" { "µnit Scaling (FP8)" } else { "SP (BF16)" },
            lr_mul_note,
            table::render(
                &["width", "η* (base)", "η*·d_base/w (raw SP)", "λ*", "loss", "diverged"],
                &rows
            )
        ));
    }
    Ok(report)
}

/// Fig 7: loss curves for SP/µS x BF16/FP8 across proxy sizes.
pub fn fig7(ctx: &Ctx) -> Result<String> {
    let sizes = [(64usize, 4usize, "S"), (128, 6, "M"), (256, 8, "L")];
    let steps = ctx.steps(240);
    let mut report = String::from(
        "Fig 7 — convergence of SP/µS in BF16/FP8 (proxy sizes; final train loss)\n\
         Expect: µS-FP8 ≈ µS-BF16 ≈ SP-BF16; SP-FP8 (dynamic scaling) close but\n\
         with more spikes at scale.\n",
    );
    let mut rows = Vec::new();
    for (w, d, label) in sizes {
        let tau = recommended_tau(d);
        let mut cells = vec![label.to_string()];
        for (variant, precision) in
            [("sp", "bf16"), ("sp", "fp8"), ("mus", "bf16"), ("mus", "fp8")]
        {
            let cfg = ModelConfig {
                width: w,
                depth: d,
                variant: variant.into(),
                precision: precision.into(),
                residual: if variant == "mus" { "fixed".into() } else { "standard".into() },
                ..ModelConfig::default()
            };
            let lr = if variant == "mus" { MUS_LR } else { SP_LR };
            let r = train_cached(ctx, &cfg, &proxy_tc(steps, lr, WD, tau, 5))?;
            cells.push(format!(
                "{:.4}{}{}",
                r.final_loss,
                if r.spikes > 0 { format!(" ({}sp)", r.spikes) } else { String::new() },
                if r.diverged { " DIV" } else { "" },
            ));
        }
        rows.push(cells);
    }
    report.push_str(&table::render(
        &["size", "SP BF16", "SP FP8(TE)", "µS BF16", "µS FP8"],
        &rows,
    ));
    Ok(report)
}

/// Fig 8: throughput model over the paper's Table 4 shapes.
pub fn fig8(_ctx: &Ctx) -> Result<String> {
    let hw = Hw::default();
    let rows: Vec<Vec<String>> = perf_fig8(&hw)
        .iter()
        .map(|r| {
            vec![
                r.size.to_string(),
                format!("{:.2}M", r.bf16 / 1e6),
                format!("{:.2}M", r.te / 1e6),
                format!("{:.2}M", r.mus / 1e6),
                format!("{:+.1}%", (r.mus_over_bf16() - 1.0) * 100.0),
                format!("{:+.1}%", (r.mus_over_te() - 1.0) * 100.0),
            ]
        })
        .collect();
    let t = table::render(
        &["model", "BF16 tok/s", "FP8 TE tok/s", "FP8 µS tok/s", "µS vs BF16", "µS vs TE"],
        &rows,
    );
    Ok(format!(
        "Fig 8 — training throughput, 64xH100 analytic model (DESIGN.md §2)\n\
         Paper: µS 25-33% over BF16, 1-6% over TE.\n{t}"
    ))
}

/// Fig 9: optimal τ vs depth (optimal-subset mean, App. A.2 method).
pub fn fig9(ctx: &Ctx) -> Result<String> {
    let depths = [4usize, 8, 16, 24];
    let steps = ctx.steps(120);
    let taus = [0.05, 0.1, 0.2, 0.3, 0.5, 0.7];
    let mut rows = Vec::new();
    for &d in &depths {
        let cfg = proxy(64, d);
        let points = sweep::grid(&[MUS_LR], &[WD], &taus);
        let outcomes = sweep_cached(ctx, &cfg, &proxy_tc(steps, 0.0, 0.0, 0.0, 8), &points)?;
        let subset = sweep::optimal_subset(&outcomes, 0.0025);
        let tau_star: f64 =
            subset.iter().map(|o| o.point.tau).sum::<f64>() / subset.len().max(1) as f64;
        let best = sweep::best(&outcomes).context("diverged")?;
        rows.push(vec![
            d.to_string(),
            table::f(tau_star, 3),
            table::f(best.point.tau, 2),
            table::f(best.final_loss, 4),
            table::f(recommended_tau(d), 2),
        ]);
    }
    let t = table::render(
        &["depth", "τ* (subset mean)", "τ best", "loss", "recommended"],
        &rows,
    );
    Ok(format!(
        "Fig 9 — optimal residual coefficient τ* vs depth\n\
         Expect: τ* decreases as depth increases.\n{t}"
    ))
}

/// Fig 10: FP8 underflow of GELU/SiLU/ReLU outputs (pure rust MC over the
/// software fp8 substrate).
pub fn fig10(_ctx: &Ctx) -> Result<String> {
    let mut rng = Rng::new(10);
    let n = 400_000;
    let mut rows = Vec::new();
    for act in Activation::all() {
        let un = activation_underflow(act, InputDist::StdNormal, E4M3, n, &mut rng);
        let uu = activation_underflow(act, InputDist::Uniform128, E4M3, n, &mut rng);
        rows.push(vec![
            act.name().to_string(),
            format!("{:.4}%", un * 100.0),
            format!("{:.4}%", uu * 100.0),
        ]);
    }
    let t = table::render(&["activation", "N(0,1) underflow", "Unif(-128,128) underflow"], &rows);
    Ok(format!(
        "Fig 10 — BF16→FP8(e4m3) underflow of activation outputs\n\
         Expect: SiLU > GELU ≫ ReLU (≈0).\n{t}"
    ))
}

/// Fig 11: underflow during training + low-precision convergence error per
/// activation function.
pub fn fig11(ctx: &Ctx) -> Result<String> {
    let steps = ctx.steps(150);
    let mut rows = Vec::new();
    for act in ["gelu", "silu", "relu"] {
        let mk = |precision: &str| ModelConfig {
            activation: act.into(),
            precision: precision.into(),
            ..proxy(64, 4)
        };
        let tau = 0.4;
        let (r8, state8) = train_with_state(ctx, &mk("fp8"), &proxy_tc(steps, MUS_LR, WD, tau, 11))?;
        let r16 = train_cached(ctx, &mk("bf16"), &proxy_tc(steps, MUS_LR, WD, tau, 11))?;
        // probe the trained fp8 model's act-output underflow (col 3 of the
        // probe's underflow block); "-" when the backend has no probes
        let under_cell = if ctx.backend().resolve("probe", &mk("fp8")).is_ok() {
            let probe = run_probe(ctx, &mk("fp8"), state8.params(), tau, 99)?;
            let u = probe
                .iter()
                .find(|(n, _)| n == "underflow")
                .map(|(_, v)| v.clone())
                .context("probe output missing 'underflow' block")?;
            if u.len() < 4 * 5 {
                return Err(crate::err!(
                    "probe 'underflow' block has {} entries, expected at least {} \
                     (probe built for a different depth?)",
                    u.len(),
                    4 * 5
                ));
            }
            let act_under: f64 = (0..4).map(|l| u[l * 5 + 3] as f64).sum::<f64>() / 4.0;
            format!("{:.4}%", act_under * 100.0)
        } else {
            "-".to_string()
        };
        let conv_err = (r8.final_loss - r16.final_loss) / r16.final_loss * 100.0;
        rows.push(vec![
            act.to_string(),
            under_cell,
            table::f(r8.final_loss, 4),
            table::f(r16.final_loss, 4),
            format!("{:+.3}%", conv_err),
        ]);
    }
    let t = table::render(
        &["activation", "act-out underflow", "FP8 loss", "BF16 loss", "conv. error"],
        &rows,
    );
    Ok(format!(
        "Fig 11 — training-time FP8 underflow & low-precision convergence error\n\
         Expect: relu ≈ 0 underflow and smallest |conv. error|; gelu/silu higher.\n{t}"
    ))
}

/// Fig 12: activation outliers — µS vs SP block input/output tail mass.
pub fn fig12(ctx: &Ctx) -> Result<String> {
    let steps = ctx.steps(150);
    let mus = proxy(128, 6);
    let sp = sp_proxy(128, 6);
    if ctx.backend().resolve("probe", &mus).is_err()
        || ctx.backend().resolve("probe", &sp).is_err()
    {
        return Ok("Fig 12 — activation outlier tail mass: needs probe artifacts \
                   for both the µS and SP configs (build with `make artifacts` \
                   and --features pjrt); skipped on this backend.\n"
            .into());
    }
    let tau = recommended_tau(6);
    let (_rm, sm) = train_with_state(ctx, &mus, &proxy_tc(steps, MUS_LR, WD, tau, 12))?;
    let (_rs, ss) = train_with_state(ctx, &sp, &proxy_tc(steps, SP_LR, WD, 0.0, 12))?;
    let pm = run_probe(ctx, &mus, sm.params(), tau, 99)?;
    let ps = run_probe(ctx, &sp, ss.params(), 0.0, 99)?;
    let lo = crate::analysis::HIST_LO_EXP;
    let tail = |probe: &[(String, Vec<f32>)], key: &str, layer: usize| -> f64 {
        let h = &probe.iter().find(|(n, _)| n == key).unwrap().1;
        let nb = h.len() / 6;
        hist_tail_mass(&h[layer * nb..(layer + 1) * nb], lo, 16.0)
    };
    let mut rows = Vec::new();
    for l in 0..6 {
        rows.push(vec![
            l.to_string(),
            format!("{:.2e}", tail(&ps, "hist_in", l)),
            format!("{:.2e}", tail(&pm, "hist_in", l)),
            format!("{:.2e}", tail(&ps, "hist_out", l)),
            format!("{:.2e}", tail(&pm, "hist_out", l)),
        ]);
    }
    let t = table::render(
        &["layer", "SP in>16", "µS in>16", "SP out>16", "µS out>16"],
        &rows,
    );
    Ok(format!(
        "Fig 12 — activation outlier tail mass (fraction of |x| ≥ 16)\n\
         Expect: SP block inputs grow heavy right tails; µS stays clean.\n{t}"
    ))
}
