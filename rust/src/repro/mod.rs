//! Experiment reproduction drivers: one entry point per paper table/figure
//! (DESIGN.md §4 index). Each driver trains/loads what it needs, prints an
//! aligned table mirroring the paper's rows/series, and persists raw data
//! under `results/` (JSON) so reruns are incremental.

/// One driver per paper figure (fig2..fig12).
pub mod figures;
/// One driver per paper table (table2..table5 / fig1).
pub mod tables;

use std::path::{Path, PathBuf};

use crate::config::{ModelConfig, Schedule, TrainConfig};
use crate::coordinator::checkpoint;
use crate::coordinator::trainer::{TrainState, Trainer};
use crate::data::{Batcher, CorpusSpec};
use crate::runtime::{open_backend, scalar_f32, tensor_i32, to_f32_vec, Backend, Tensor};
use crate::util::error::{Context, Result};
use crate::util::json::Json;

/// Shared driver context.
pub struct Ctx {
    /// Execution backend every driver runs against.
    pub backend: Box<dyn Backend>,
    /// Results directory (runs/, reports/ live under it).
    pub results: PathBuf,
    /// Fast mode: fewer steps / smaller grids (CI-sized).
    pub fast: bool,
}

impl Ctx {
    /// Open the backend for `artifact_dir` and ensure `results/runs/`.
    pub fn new(artifact_dir: &Path, results: &Path, fast: bool) -> Result<Ctx> {
        std::fs::create_dir_all(results.join("runs"))?;
        Ok(Ctx { backend: open_backend(artifact_dir)?, results: results.to_path_buf(), fast })
    }

    /// Borrow the driver backend.
    pub fn backend(&self) -> &dyn Backend {
        self.backend.as_ref()
    }

    /// Step budget: `full`, or a third of it (min 30) in fast mode.
    pub fn steps(&self, full: usize) -> usize {
        if self.fast {
            (full / 3).max(30)
        } else {
            full
        }
    }
}

/// Summary of one cached training run.
#[derive(Debug, Clone)]
pub struct RunSummary {
    /// Per-step losses.
    pub losses: Vec<f32>,
    /// Tail-averaged final loss (the convergence metric).
    pub final_loss: f64,
    /// Divergence-guard verdict.
    pub diverged: bool,
    /// Loss spikes counted over the run.
    pub spikes: usize,
    /// Training throughput of the (possibly cached) run.
    pub tokens_per_sec: f64,
}

impl RunSummary {
    fn from_json(j: &Json) -> Option<RunSummary> {
        let losses = j
            .get("losses")?
            .as_arr()?
            .iter()
            .map(|v| v.as_f64().unwrap_or(f64::NAN) as f32)
            .collect();
        Some(RunSummary {
            losses,
            final_loss: j.f64_or("final_loss", f64::NAN),
            diverged: j.get("diverged")?.as_bool()?,
            spikes: j.usize_or("spikes", 0),
            tokens_per_sec: j.f64_or("tokens_per_sec", 0.0),
        })
    }
}

/// Stable cache key for a (config, hyperparameters) training run.
pub fn run_key(cfg: &ModelConfig, tc: &TrainConfig) -> String {
    format!(
        "{}_s{}_lr{:.6}_wd{:.6}_tau{:.3}_seed{}",
        cfg.name(),
        tc.steps,
        tc.lr,
        tc.wd,
        tc.tau,
        tc.seed
    )
}

/// Train (or load from cache) one run. The trained state is checkpointed
/// alongside the summary so probes/evals can reuse the weights.
pub fn train_cached(ctx: &Ctx, cfg: &ModelConfig, tc: &TrainConfig) -> Result<RunSummary> {
    let key = run_key(cfg, tc);
    let json_path = ctx.results.join("runs").join(format!("{key}.json"));
    if let Ok(text) = std::fs::read_to_string(&json_path) {
        if let Ok(j) = Json::parse(&text) {
            if let Some(s) = RunSummary::from_json(&j) {
                return Ok(s);
            }
        }
    }
    train_with_state(ctx, cfg, tc).map(|(s, _)| s)
}

/// Like train_cached but also returns the trained state (checkpointed as
/// `<key>.ckpt` for cache hits). The state is read back from the device
/// exactly once, at the end of the run.
pub fn train_with_state(
    ctx: &Ctx,
    cfg: &ModelConfig,
    tc: &TrainConfig,
) -> Result<(RunSummary, TrainState)> {
    let key = run_key(cfg, tc);
    let ckpt_path = ctx.results.join("runs").join(format!("{key}.ckpt"));
    let meta = ctx
        .backend()
        .resolve("train_step", cfg)
        .with_context(|| format!("no train artifact for {}", cfg.name()))?;
    let specs = meta.inputs[..meta.inputs.len() - 4].to_vec();
    if ckpt_path.exists() {
        if let Ok(summary) = train_cached(ctx, cfg, tc) {
            if let Ok(state) = checkpoint::load(&ckpt_path, &specs) {
                return Ok((summary, state));
            }
        }
    }
    let trainer = Trainer::new(ctx.backend(), cfg)?;
    let mut batcher = corpus_batcher(cfg, tc.seed);
    let (result, state) = trainer.run_capture(tc, &mut batcher, |m, _| {
        if m.step % 50 == 0 {
            eprintln!("    [{key}] step {} loss {:.4}", m.step, m.loss);
        }
    })?;
    checkpoint::save(&ckpt_path, &state, &specs)?;
    let summary = crate::coordinator::metrics::summary_json(&key, &result);
    std::fs::write(ctx.results.join("runs").join(format!("{key}.json")), summary.to_string())?;
    Ok((RunSummary::from_json(&summary).context("summary json roundtrip")?, state))
}

/// Batcher over the standard corpus at a config's vocab/batch geometry.
pub fn corpus_batcher(cfg: &ModelConfig, seed: u64) -> Batcher {
    let spec = CorpusSpec { vocab: cfg.vocab, ..CorpusSpec::default() };
    Batcher::new(spec, seed, 0, 1, cfg.batch, cfg.seq_len)
}

/// The standard corpus spec at a config's vocabulary.
pub fn corpus_for(cfg: &ModelConfig) -> CorpusSpec {
    CorpusSpec { vocab: cfg.vocab, ..CorpusSpec::default() }
}

/// Run a probe artifact on a trained state; returns the named outputs.
/// Probe artifacts exist only in the AOT catalogue (feature `pjrt`).
pub fn run_probe(
    ctx: &Ctx,
    cfg: &ModelConfig,
    params: &[Tensor],
    tau: f64,
    seed: u64,
) -> Result<Vec<(String, Vec<f32>)>> {
    let meta = ctx
        .backend()
        .resolve("probe", cfg)
        .with_context(|| format!("no probe artifact for {}", cfg.name()))?;
    let name = meta.name.clone();
    let out_names: Vec<String> = meta.outputs.iter().map(|o| o.name.clone()).collect();
    let mut batcher = corpus_batcher(cfg, seed);
    let tokens = batcher.next_batch();
    let mut inputs: Vec<Tensor> = params.to_vec();
    inputs.push(tensor_i32(&tokens, &[cfg.batch, cfg.seq_len])?);
    inputs.push(scalar_f32(tau as f32));
    let outs = ctx.backend().run(&name, &inputs)?;
    Ok(out_names
        .into_iter()
        .zip(outs.iter().map(|t| to_f32_vec(t).unwrap_or_default()))
        .collect())
}

/// Standard quick TrainConfig for proxy experiments.
pub fn proxy_tc(steps: usize, lr: f64, wd: f64, tau: f64, seed: u64) -> TrainConfig {
    TrainConfig {
        steps,
        lr,
        wd,
        tau,
        schedule: Schedule::Cosine { final_frac: 0.1, warmup: steps / 20 + 1 },
        seed,
        init_seed: 0,
        max_loss: 20.0,
        spike_threshold: 1.0,
        log_every: 50,
    }
}
