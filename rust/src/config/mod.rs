//! Typed configuration system: model shapes, training runs, presets.
//!
//! `ModelConfig` mirrors `python/compile/configs.py` (the manifest carries
//! the python-side dict; `ModelConfig::from_json` parses it back, and the
//! integration tests check the two agree). `TrainConfig` adds the L3-side
//! knobs: steps, schedule, hyperparameters, seeds, divergence policy.
//! `presets` includes both the paper's Table 4 production shapes (used by
//! the perf model and memory planner) and the CPU-scale proxies the repro
//! experiments actually train.

use crate::scaling::Scheme;
use crate::util::json::Json;

/// Model shape + numerics recipe (mirrors `python/compile/configs.py`).
#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    /// Model (residual stream) width `d`.
    pub width: usize,
    /// Number of transformer blocks.
    pub depth: usize,
    /// Per-head dimension (heads = `width / head_dim`).
    pub head_dim: usize,
    /// Vocabulary size.
    pub vocab: usize,
    /// Training sequence length (also the RoPE-table / context range).
    pub seq_len: usize,
    /// Sequences per training batch.
    pub batch: usize,
    /// FFN expansion factor (`ffn_width = width * ffn_ratio`).
    pub ffn_ratio: usize,
    /// Reference width the base hyperparameters were tuned at (the
    /// scheme's LR-transfer rules scale relative to this).
    pub d_base: usize,
    /// Parametrization variant: `"mus"` | `"sp"`.
    pub variant: String,
    /// Hidden-linear compute precision: `"fp8"` | `"bf16"`.
    pub precision: String,
    /// Residual scheme: `"fixed"` | `"running_mean"` | `"standard"`.
    pub residual: String,
    /// FFN activation: `"gelu"` | `"silu"` | `"relu"`.
    pub activation: String,
}

impl Default for ModelConfig {
    fn default() -> Self {
        ModelConfig {
            width: 64,
            depth: 4,
            head_dim: 16,
            vocab: 512,
            seq_len: 128,
            batch: 4,
            ffn_ratio: 4,
            d_base: 32,
            variant: "mus".into(),
            precision: "fp8".into(),
            residual: "fixed".into(),
            activation: "gelu".into(),
        }
    }
}

impl ModelConfig {
    /// Attention head count, `width / head_dim`.
    pub fn n_heads(&self) -> usize {
        self.width / self.head_dim
    }

    /// FFN hidden width, `width * ffn_ratio`.
    pub fn ffn_width(&self) -> usize {
        self.width * self.ffn_ratio
    }

    /// Total parameter count (matches python `ModelConfig.n_params` and
    /// the reference runtime's per-block tensor layout: w_qkv, w_o, w_up,
    /// w_down plus two gain-only RMS norms per block, one final gain).
    pub fn n_params(&self) -> usize {
        let (d, f, v, l) = (self.width, self.ffn_width(), self.vocab, self.depth);
        let per_layer = d * 3 * d + d * d + d * f + f * d + 2 * d;
        v * d + l * per_layer + d + d * v
    }

    /// Hidden-linear FLOPs for one token, forward pass (2*M*N*K per GEMM;
    /// the runtime's op-level shapes are tested to agree exactly).
    pub fn hidden_flops_per_token_fwd(&self) -> u64 {
        let d = self.width as u64;
        let f = self.ffn_width() as u64;
        2 * (d * 3 * d + d * d + d * f + f * d)
    }

    /// Attention score+value GEMM FLOPs for one *sequence*, forward pass,
    /// with causal masking: query i touches i+1 keys and i+1 values at
    /// 2·head_dim FLOPs each over all heads → `2·d·s·(s+1)`.
    pub fn attn_flops_per_seq_fwd(&self) -> u64 {
        let (d, s) = (self.width as u64, self.seq_len as u64);
        2 * d * s * (s + 1)
    }

    /// Single-query cached-attention FLOPs for one decode token at
    /// context length `ctx`, per block: the query scores `ctx` keys and
    /// mixes `ctx` values at 2·head_dim FLOPs each over all heads →
    /// `4·d·ctx` (the runtime's decode kernel shape is tested to agree
    /// exactly).
    pub fn attn_decode_flops_per_token(&self, ctx: usize) -> u64 {
        4 * self.width as u64 * ctx as u64
    }

    /// KV-cache bytes appended per decoded token across all layers at
    /// `bytes_per_value` bytes per stored value: one K row and one V
    /// row of `width` values per layer. BF16 stores 2 bytes/value; the
    /// FP8 (E4M3) KV-cache mode stores 1, halving the cache footprint.
    pub fn kv_cache_bytes_per_token_at(&self, bytes_per_value: usize) -> u64 {
        (self.depth * 2 * self.width * bytes_per_value) as u64
    }

    /// BF16 specialization of [`ModelConfig::kv_cache_bytes_per_token_at`].
    pub fn kv_cache_bytes_per_token(&self) -> u64 {
        self.kv_cache_bytes_per_token_at(2)
    }

    /// KV-cache bytes READ by one decode token at context length `ctx`
    /// and `bytes_per_value` bytes per stored value: every layer streams
    /// its full cached K and V (`ctx · width` values each) — the
    /// bandwidth term of the decode roofline.
    pub fn kv_cache_bytes_read_per_token_at(&self, ctx: usize, bytes_per_value: usize) -> u64 {
        self.kv_cache_bytes_per_token_at(bytes_per_value) * ctx as u64
    }

    /// BF16 specialization of
    /// [`ModelConfig::kv_cache_bytes_read_per_token_at`].
    pub fn kv_cache_bytes_read_per_token(&self, ctx: usize) -> u64 {
        self.kv_cache_bytes_read_per_token_at(ctx, 2)
    }

    /// The scaling scheme this config trains under: µS, SP+TE-style
    /// dynamic FP8, or plain SP mixed precision. Assumes a config that
    /// passed [`ModelConfig::validate`] — unknown variant strings fall
    /// into the SP family, so the interpreter entry points (`init`,
    /// `Prepared::new`) validate before consulting this.
    pub fn scheme(&self) -> Scheme {
        match (self.variant.as_str(), self.precision.as_str()) {
            ("mus", _) => Scheme::Mus,
            (_, "fp8") => Scheme::SpTe,
            _ => Scheme::Sp,
        }
    }

    /// Canonical artifact-name fragment (matches python `name()`).
    pub fn name(&self) -> String {
        let res = if self.residual == "fixed" { String::new() } else { format!("_{}", self.residual) };
        let act = if self.activation == "gelu" { String::new() } else { format!("_{}", self.activation) };
        format!(
            "{}_{}_w{}_d{}_v{}_s{}_b{}{}{}",
            self.variant, self.precision, self.width, self.depth, self.vocab,
            self.seq_len, self.batch, res, act
        )
    }

    /// Parse a manifest/checkpoint config object (missing optional keys
    /// take this crate's defaults).
    pub fn from_json(j: &Json) -> Option<ModelConfig> {
        Some(ModelConfig {
            width: j.get("width")?.as_usize()?,
            depth: j.get("depth")?.as_usize()?,
            head_dim: j.usize_or("head_dim", 16),
            vocab: j.get("vocab")?.as_usize()?,
            seq_len: j.get("seq_len")?.as_usize()?,
            batch: j.get("batch")?.as_usize()?,
            ffn_ratio: j.usize_or("ffn_ratio", 4),
            d_base: j.usize_or("d_base", 32),
            variant: j.str_or("variant", "mus").to_string(),
            precision: j.str_or("precision", "fp8").to_string(),
            residual: j.str_or("residual", "fixed").to_string(),
            activation: j.str_or("activation", "gelu").to_string(),
        })
    }

    /// Serialize as the manifest's config object ([`ModelConfig::from_json`]
    /// round-trips it).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("width", Json::num(self.width as f64)),
            ("depth", Json::num(self.depth as f64)),
            ("head_dim", Json::num(self.head_dim as f64)),
            ("vocab", Json::num(self.vocab as f64)),
            ("seq_len", Json::num(self.seq_len as f64)),
            ("batch", Json::num(self.batch as f64)),
            ("ffn_ratio", Json::num(self.ffn_ratio as f64)),
            ("d_base", Json::num(self.d_base as f64)),
            ("variant", Json::str(&self.variant)),
            ("precision", Json::str(&self.precision)),
            ("residual", Json::str(&self.residual)),
            ("activation", Json::str(&self.activation)),
        ])
    }

    /// Reject shape/recipe combinations the interpreter cannot train
    /// (indivisible widths, odd head dims, unknown variant/precision/
    /// residual strings, SP with fixed residuals).
    pub fn validate(&self) -> Result<(), String> {
        if self.width % self.head_dim != 0 {
            return Err(format!("width {} not divisible by head_dim {}", self.width, self.head_dim));
        }
        if self.head_dim % 2 != 0 {
            return Err("head_dim must be even (RoPE halves it)".into());
        }
        if self.seq_len == 0 {
            return Err("seq_len must be positive".into());
        }
        if !matches!(self.variant.as_str(), "mus" | "sp") {
            return Err(format!("unknown variant {}", self.variant));
        }
        if !matches!(self.precision.as_str(), "fp8" | "bf16") {
            return Err(format!("unknown precision {}", self.precision));
        }
        if !matches!(self.residual.as_str(), "fixed" | "running_mean" | "standard") {
            return Err(format!("unknown residual {}", self.residual));
        }
        if self.variant == "sp" && self.residual == "fixed" {
            return Err("SP uses standard residuals".into());
        }
        Ok(())
    }
}

/// Learning-rate schedule (paper: cosine decaying to 10% of max).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Schedule {
    /// Flat LR for the whole run.
    Constant,
    /// Cosine from peak to `final_frac * peak` over the run, with linear
    /// warmup for the first `warmup` steps.
    Cosine {
        /// Fraction of the peak LR the cosine decays to.
        final_frac: f64,
        /// Linear-warmup steps before the cosine begins.
        warmup: usize,
    },
}

impl Schedule {
    /// Learning rate at `step` of a `total`-step run with peak LR `base`.
    pub fn lr_at(&self, base: f64, step: usize, total: usize) -> f64 {
        match *self {
            Schedule::Constant => base,
            Schedule::Cosine { final_frac, warmup } => {
                if step < warmup {
                    return base * (step + 1) as f64 / warmup as f64;
                }
                let t = (step - warmup) as f64 / (total.saturating_sub(warmup)).max(1) as f64;
                let t = t.clamp(0.0, 1.0);
                let cos = 0.5 * (1.0 + (std::f64::consts::PI * t).cos());
                base * (final_frac + (1.0 - final_frac) * cos)
            }
        }
    }
}

/// L3-side training-run description.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Optimizer steps to run.
    pub steps: usize,
    /// Base-width learning rate (the artifact applies transfer multipliers).
    pub lr: f64,
    /// Fully-decoupled weight decay.
    pub wd: f64,
    /// Fixed residual coefficient (µS only; ignored by SP artifacts).
    pub tau: f64,
    /// Learning-rate schedule applied to `lr`.
    pub schedule: Schedule,
    /// Data-stream seed (the batcher is deterministic in it).
    pub seed: u64,
    /// Parameter-init seed (fed to the `init` artifact).
    pub init_seed: i32,
    /// Abort when loss exceeds this (divergence guard).
    pub max_loss: f64,
    /// Count a "loss spike" when loss jumps by more than this over EMA.
    pub spike_threshold: f64,
    /// Print/emit a metrics line every this many steps (CLI policy).
    pub log_every: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            steps: 100,
            lr: 1.0 / 128.0,
            wd: 1e-4,
            tau: 0.4,
            schedule: Schedule::Cosine { final_frac: 0.1, warmup: 10 },
            seed: 0,
            init_seed: 0,
            max_loss: 20.0,
            spike_threshold: 1.0,
            log_every: 10,
        }
    }
}

/// Named presets: the paper's production shapes (Table 4) and this repo's
/// CPU proxies. Production shapes are exercised by the perf model, memory
/// planner, and scaling-rule tests — not trained on CPU.
pub mod presets {
    use super::ModelConfig;
    use crate::scaling::recommended_tau;

    /// Paper Table 4 rows: (name, params, width, depth, heads, batch, seq, tau).
    pub struct PaperConfig {
        /// Row label ("1b" … "13b").
        pub name: &'static str,
        /// Reported parameter count, billions.
        pub params_b: f64,
        /// Training tokens, billions.
        pub tokens_b: f64,
        /// Optimizer steps of the production run.
        pub steps: usize,
        /// Global batch (sequences).
        pub batch: usize,
        /// Sequence length.
        pub seq_len: usize,
        /// Model width.
        pub width: usize,
        /// Transformer blocks.
        pub depth: usize,
        /// Attention heads.
        pub n_heads: usize,
        /// Fixed-residual τ the paper trained with.
        pub tau: f64,
    }

    /// The four production configurations of paper Table 4.
    pub fn paper_table4() -> Vec<PaperConfig> {
        vec![
            PaperConfig { name: "1b", params_b: 1.6, tokens_b: 31.5, steps: 7_500,
                batch: 1024, seq_len: 4096, width: 2048, depth: 24, n_heads: 16, tau: 0.3 },
            PaperConfig { name: "3b", params_b: 3.0, tokens_b: 62.9, steps: 15_000,
                batch: 1024, seq_len: 4096, width: 2560, depth: 32, n_heads: 20, tau: 0.3 },
            PaperConfig { name: "7b", params_b: 7.3, tokens_b: 140.0, steps: 16_700,
                batch: 2048, seq_len: 4096, width: 4096, depth: 32, n_heads: 32, tau: 0.3 },
            PaperConfig { name: "13b", params_b: 13.6, tokens_b: 260.1, steps: 31_000,
                batch: 2048, seq_len: 4096, width: 5120, depth: 40, n_heads: 40, tau: 0.2 },
        ]
    }

    /// ModelConfig for a paper shape (vocab from the paper's tokenizer era).
    pub fn paper_model(p: &PaperConfig) -> ModelConfig {
        ModelConfig {
            width: p.width,
            depth: p.depth,
            head_dim: p.width / p.n_heads,
            vocab: 32_768,
            seq_len: p.seq_len,
            batch: p.batch,
            ffn_ratio: 4,
            d_base: 256,
            variant: "mus".into(),
            precision: "fp8".into(),
            residual: "fixed".into(),
            activation: "gelu".into(),
        }
    }

    /// CPU proxy shapes used by the repro experiments (must match aot.py).
    pub fn proxy(width: usize, depth: usize) -> ModelConfig {
        ModelConfig { width, depth, ..ModelConfig::default() }
    }

    /// Recommended fixed-residual τ for a config's depth (paper Fig 9).
    pub fn tau_for(cfg: &ModelConfig) -> f64 {
        recommended_tau(cfg.depth)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn n_params_matches_python_formula() {
        // mus_fp8 w384 d6 v2048 (the e2e config): ~12.2M. Per-block
        // tensors: qkv + attn-out + ffn-up + ffn-down + two RMS gains
        // (gain-only norms — matches python param_specs and the runtime
        // block layout, which is tested to sum to n_params()).
        let c = ModelConfig {
            width: 384, depth: 6, head_dim: 64, vocab: 2048, seq_len: 256,
            batch: 8, ..Default::default()
        };
        let d = 384usize;
        let f = 4 * d;
        let per = d * 3 * d + d * d + d * f + f * d + 2 * d;
        assert_eq!(c.n_params(), 2048 * d + 6 * per + d + d * 2048);
        assert!(c.n_params() > 10_000_000 && c.n_params() < 14_000_000);
    }

    #[test]
    fn scheme_mapping() {
        assert_eq!(ModelConfig::default().scheme(), Scheme::Mus);
        let sp8 = ModelConfig {
            variant: "sp".into(),
            precision: "fp8".into(),
            residual: "standard".into(),
            ..Default::default()
        };
        assert_eq!(sp8.scheme(), Scheme::SpTe);
        let sp16 = ModelConfig { precision: "bf16".into(), ..sp8 };
        assert_eq!(sp16.scheme(), Scheme::Sp);
    }

    #[test]
    fn name_matches_python_convention() {
        let c = ModelConfig::default();
        assert_eq!(c.name(), "mus_fp8_w64_d4_v512_s128_b4");
        let mut c2 = ModelConfig::default();
        c2.variant = "sp".into();
        c2.precision = "bf16".into();
        c2.residual = "standard".into();
        assert_eq!(c2.name(), "sp_bf16_w64_d4_v512_s128_b4_standard");
        let mut c3 = ModelConfig::default();
        c3.activation = "relu".into();
        assert_eq!(c3.name(), "mus_fp8_w64_d4_v512_s128_b4_relu");
    }

    #[test]
    fn json_roundtrip() {
        let c = ModelConfig { width: 128, depth: 6, ..Default::default() };
        let j = c.to_json();
        let c2 = ModelConfig::from_json(&j).unwrap();
        assert_eq!(c, c2);
    }

    #[test]
    fn validation_rejects_bad_configs() {
        let mut c = ModelConfig::default();
        c.width = 65; // not divisible by head_dim
        assert!(c.validate().is_err());
        let mut c = ModelConfig::default();
        c.seq_len = 0;
        assert!(c.validate().is_err());
        let mut c = ModelConfig::default();
        c.variant = "frob".into();
        assert!(c.validate().is_err());
        let mut c = ModelConfig::default();
        c.variant = "sp".into(); // still residual=fixed
        assert!(c.validate().is_err());
        assert!(ModelConfig::default().validate().is_ok());
    }

    #[test]
    fn cosine_schedule_shape() {
        let s = Schedule::Cosine { final_frac: 0.1, warmup: 10 };
        let base = 1.0;
        assert!(s.lr_at(base, 0, 100) < 0.2); // warming up
        assert!((s.lr_at(base, 9, 100) - 1.0).abs() < 1e-9); // peak at end of warmup
        assert!((s.lr_at(base, 100, 100) - 0.1).abs() < 1e-9); // decays to 10%
        // monotone decreasing after warmup
        let mut prev = f64::INFINITY;
        for step in 10..100 {
            let lr = s.lr_at(base, step, 100);
            assert!(lr <= prev + 1e-12);
            prev = lr;
        }
    }

    #[test]
    fn paper_table4_consistency() {
        let t4 = presets::paper_table4();
        assert_eq!(t4.len(), 4);
        for p in &t4 {
            let m = presets::paper_model(p);
            assert!(m.validate().is_ok(), "{}", p.name);
            // parameter count within 25% of the paper's reported size
            let ratio = m.n_params() as f64 / (p.params_b * 1e9);
            assert!(ratio > 0.75 && ratio < 1.35, "{}: {ratio}", p.name);
            // tokens-per-parameter ratio ~20x (compute-optimal)
            let tpr = p.tokens_b / p.params_b;
            assert!(tpr > 18.0 && tpr < 22.0, "{}: {tpr}", p.name);
        }
    }

    #[test]
    fn flops_accounting() {
        let c = ModelConfig::default(); // d=64, f=256
        let d = 64u64;
        assert_eq!(
            c.hidden_flops_per_token_fwd(),
            2 * (d * 3 * d + d * d + d * 256 + 256 * d)
        );
    }
}
