//! Numerics telemetry: per-op RMS records + FP8 cast-health counters.
//!
//! The paper's first-principles claim is that µS keeps **every hidden
//! tensor near unit scale**, which is exactly why static FP8 casts work
//! without FP8-LM/TE-style dynamic amax machinery. This module turns the
//! reference interpreter into an instrument for checking that claim: when
//! a sink is installed, the op-level block pipeline
//! (`runtime::block`) records
//!
//!  - the RMS and absolute maximum of every tensor in the tower — the
//!    embedding, post-norm outputs, qkv projections, post-RoPE heads,
//!    attention mix, attn-out, ffn-up/act/down, both residual streams,
//!    the final norm, the logits, and each of their gradients — keyed by
//!    `(op, layer)`;
//!  - [`crate::fp8::CastHealth`] counters for every FP8-quantized operand
//!    (weights, activations, gradients): underflow-to-zero, saturation,
//!    overflow, and subnormal hit rates per quantized op.
//!
//! **Zero overhead when off.** The sink is a *thread-local scope*
//! ([`capture`]), mirroring `util::parallel::with_max_threads`: outside a
//! capture the recording hooks reduce to one thread-local flag check and
//! touch no tensor data, so training with telemetry off is bit-identical
//! to (and as fast as) the uninstrumented interpreter — asserted by the
//! integration test `telemetry_capture_is_non_perturbing_and_off_hot_path`.
//! When ON, recording only *reads* tensors (deterministic fixed-chunk
//! reductions, `runtime::gemm::sum_sq`/`abs_max`), so captured training is
//! bit-identical too — the instrument never perturbs the experiment.
//!
//! Scope: the sink is per-thread, and the reference backend interprets on
//! the calling thread, so wrapping [`crate::runtime::Session::step`] (or
//! using [`crate::runtime::Session::step_traced`]) captures that step's
//! telemetry. Work dispatched to other threads (sweep workers, a real
//! device backend) records nothing.
//!
//! ```
//! let (sum, report) = munit::telemetry::capture(|| 2 + 2);
//! assert_eq!(sum, 4);
//! assert!(report.ops.is_empty()); // nothing instrumented ran
//! ```
//!
//! The width-transfer harness (`coordinator::transfer`) consumes these
//! reports to run the paper's coordinate checks and LR-transfer sweeps;
//! `docs/NUMERICS.md` documents how to read the numbers.

use std::cell::RefCell;
use std::collections::BTreeMap;

use crate::fp8::CastHealth;
use crate::util::json::Json;

thread_local! {
    static SINK: RefCell<Option<Store>> = const { RefCell::new(None) };
}

#[derive(Default)]
struct Store {
    ops: BTreeMap<(&'static str, usize), OpAccum>,
    casts: BTreeMap<(&'static str, usize), CastAccum>,
}

#[derive(Default, Clone, Copy)]
struct OpAccum {
    records: u64,
    elems: u64,
    sum_sq: f64,
    abs_max: f64,
}

#[derive(Default, Clone)]
struct CastAccum {
    format: &'static str,
    health: CastHealth,
}

/// Is a telemetry sink installed on the calling thread? The recording
/// hooks in `runtime::block` consult this before touching any tensor, so
/// the answer decides between "free" and "one read-only pass".
pub fn enabled() -> bool {
    SINK.with(|s| s.borrow().is_some())
}

/// Run `f` with a fresh telemetry sink installed on this thread and
/// return its result together with everything recorded. Nesting replaces
/// the outer sink for the inner scope and restores it afterwards (also on
/// panic — the guard restores in `Drop`).
pub fn capture<R>(f: impl FnOnce() -> R) -> (R, TelemetryReport) {
    struct Guard {
        prev: Option<Store>,
    }
    impl Drop for Guard {
        fn drop(&mut self) {
            SINK.with(|s| *s.borrow_mut() = self.prev.take());
        }
    }
    let mut guard = Guard { prev: None };
    guard.prev = SINK.with(|s| s.borrow_mut().replace(Store::default()));
    let out = f();
    let store = SINK.with(|s| s.borrow_mut().take()).unwrap_or_default();
    drop(guard); // restores the previous sink (if any)
    (out, TelemetryReport::from_store(store))
}

/// Record the RMS / abs-max of one tensor under `(op, layer)`. No-op
/// without an installed sink. The reductions are the deterministic
/// fixed-chunk folds of `runtime::gemm`, so recorded values are
/// bit-identical at any worker-thread count.
pub(crate) fn record_rms(op: &'static str, layer: usize, xs: &[f32]) {
    if xs.is_empty() {
        return;
    }
    SINK.with(|s| {
        let mut sink = s.borrow_mut();
        let Some(store) = sink.as_mut() else { return };
        let (sum_sq, abs_max) = crate::runtime::gemm::sum_sq_abs_max(xs);
        let a = store.ops.entry((op, layer)).or_default();
        a.records += 1;
        a.elems += xs.len() as u64;
        a.sum_sq += sum_sq;
        a.abs_max = a.abs_max.max(abs_max as f64);
    });
}

/// Accumulate the cast-health counters of one quantized operand under
/// `(op, layer)`. No-op without an installed sink.
pub(crate) fn record_cast(op: &'static str, layer: usize, format: &'static str, h: CastHealth) {
    SINK.with(|s| {
        let mut sink = s.borrow_mut();
        let Some(store) = sink.as_mut() else { return };
        let a = store.casts.entry((op, layer)).or_default();
        a.format = format;
        a.health.merge(&h);
    });
}

/// Aggregated RMS record for one `(op, layer)` site.
#[derive(Debug, Clone, PartialEq)]
pub struct OpRecord {
    /// Pipeline-stage name (e.g. `"qkv"`, `"resid2"`, `"d_ffn_down"`).
    pub op: String,
    /// Block index (0 for per-model sites like `"logits"`).
    pub layer: usize,
    /// Tensors recorded at this site (e.g. one per captured step).
    pub records: u64,
    /// Total elements across those tensors.
    pub elems: u64,
    /// Σx² across all recorded elements (f64, deterministic fold order).
    pub sum_sq: f64,
    /// Largest |x| seen at this site.
    pub abs_max: f64,
}

impl OpRecord {
    /// Root-mean-square over every element recorded at this site.
    pub fn rms(&self) -> f64 {
        if self.elems == 0 {
            0.0
        } else {
            (self.sum_sq / self.elems as f64).sqrt()
        }
    }
}

/// Aggregated cast-health record for one quantized `(op, layer)` site.
#[derive(Debug, Clone, PartialEq)]
pub struct CastRecord {
    /// Quantized-op name (e.g. `"qkv"`, `"w_qkv"`, `"d_ffn_up"`).
    pub op: String,
    /// Block index.
    pub layer: usize,
    /// FP8 format name the op casts into (`"e4m3"` / `"e5m2"`).
    pub format: String,
    /// Accumulated counters across every recorded cast at this site.
    pub health: CastHealth,
}

/// Everything one [`capture`] scope recorded, sorted by `(op, layer)`.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TelemetryReport {
    /// Per-site RMS records (forward activations and backward gradients).
    pub ops: Vec<OpRecord>,
    /// Per-site FP8 cast-health records (BF16 round-trips are not casts
    /// in the FP8 sense and are not recorded).
    pub casts: Vec<CastRecord>,
}

impl TelemetryReport {
    fn from_store(store: Store) -> TelemetryReport {
        TelemetryReport {
            ops: store
                .ops
                .into_iter()
                .map(|((op, layer), a)| OpRecord {
                    op: op.to_string(),
                    layer,
                    records: a.records,
                    elems: a.elems,
                    sum_sq: a.sum_sq,
                    abs_max: a.abs_max,
                })
                .collect(),
            casts: store
                .casts
                .into_iter()
                .map(|((op, layer), a)| CastRecord {
                    op: op.to_string(),
                    layer,
                    format: a.format.to_string(),
                    health: a.health,
                })
                .collect(),
        }
    }

    /// True when nothing was recorded (no instrumented code ran).
    pub fn is_empty(&self) -> bool {
        self.ops.is_empty() && self.casts.is_empty()
    }

    /// RMS of an op aggregated across layers (element-weighted: the
    /// square root of the pooled Σx²/Σn), or `None` if never recorded.
    pub fn op_rms(&self, op: &str) -> Option<f64> {
        let mut sum_sq = 0f64;
        let mut elems = 0u64;
        for r in self.ops.iter().filter(|r| r.op == op) {
            sum_sq += r.sum_sq;
            elems += r.elems;
        }
        if elems == 0 {
            None
        } else {
            Some((sum_sq / elems as f64).sqrt())
        }
    }

    /// Pooled RMS for one `(op, layer)` site, or `None` if it was never
    /// recorded. Unlike [`TelemetryReport::op_rms`] this does not pool
    /// across layers, so the static verifier can compare its per-layer
    /// predictions against exactly the site that produced them.
    pub fn op_layer_rms(&self, op: &str, layer: usize) -> Option<f64> {
        let mut sum_sq = 0f64;
        let mut elems = 0u64;
        for r in self.ops.iter().filter(|r| r.op == op && r.layer == layer) {
            sum_sq += r.sum_sq;
            elems += r.elems;
        }
        if elems == 0 {
            None
        } else {
            Some((sum_sq / elems as f64).sqrt())
        }
    }

    /// Distinct op names with RMS records, in sorted order.
    pub fn op_names(&self) -> Vec<String> {
        let mut names: Vec<String> = self.ops.iter().map(|r| r.op.clone()).collect();
        names.dedup(); // ops is sorted by (op, layer)
        names
    }

    /// Cast-health of an op merged across layers, or `None` if the op
    /// never cast to FP8 under this capture.
    pub fn cast_totals(&self, op: &str) -> Option<CastHealth> {
        let mut total = CastHealth::default();
        let mut seen = false;
        for r in self.casts.iter().filter(|r| r.op == op) {
            total.merge(&r.health);
            seen = true;
        }
        if seen {
            Some(total)
        } else {
            None
        }
    }

    /// Fold another report into this one (used to aggregate per-step
    /// captures over a training run).
    pub fn merge(&mut self, other: &TelemetryReport) {
        let mut ops: BTreeMap<(String, usize), OpRecord> =
            self.ops.drain(..).map(|r| ((r.op.clone(), r.layer), r)).collect();
        for r in &other.ops {
            let e = ops.entry((r.op.clone(), r.layer)).or_insert_with(|| OpRecord {
                op: r.op.clone(),
                layer: r.layer,
                records: 0,
                elems: 0,
                sum_sq: 0.0,
                abs_max: 0.0,
            });
            e.records += r.records;
            e.elems += r.elems;
            e.sum_sq += r.sum_sq;
            e.abs_max = e.abs_max.max(r.abs_max);
        }
        self.ops = ops.into_values().collect();
        let mut casts: BTreeMap<(String, usize), CastRecord> =
            self.casts.drain(..).map(|r| ((r.op.clone(), r.layer), r)).collect();
        for r in &other.casts {
            let e = casts.entry((r.op.clone(), r.layer)).or_insert_with(|| CastRecord {
                op: r.op.clone(),
                layer: r.layer,
                format: r.format.clone(),
                health: CastHealth::default(),
            });
            e.health.merge(&r.health);
        }
        self.casts = casts.into_values().collect();
    }

    /// JSON projection (consumed by `REPORT_coordcheck.json` /
    /// `REPORT_transfer.json` and the CI report checks).
    pub fn to_json(&self) -> Json {
        let ops = self
            .ops
            .iter()
            .map(|r| {
                Json::obj(vec![
                    ("op", Json::str(&r.op)),
                    ("layer", Json::num(r.layer as f64)),
                    ("records", Json::num(r.records as f64)),
                    ("elems", Json::num(r.elems as f64)),
                    ("rms", Json::num(r.rms())),
                    ("abs_max", Json::num(r.abs_max)),
                ])
            })
            .collect();
        let casts = self
            .casts
            .iter()
            .map(|r| {
                Json::obj(vec![
                    ("op", Json::str(&r.op)),
                    ("layer", Json::num(r.layer as f64)),
                    ("format", Json::str(&r.format)),
                    ("total", Json::num(r.health.total as f64)),
                    ("nonzero", Json::num(r.health.nonzero as f64)),
                    ("underflow_to_zero", Json::num(r.health.underflow_to_zero as f64)),
                    ("saturated", Json::num(r.health.saturated as f64)),
                    ("overflow_nonfinite", Json::num(r.health.overflow_nonfinite as f64)),
                    ("subnormal", Json::num(r.health.subnormal as f64)),
                    ("underflow_rate", Json::num(r.health.underflow_rate())),
                ])
            })
            .collect();
        Json::obj(vec![("ops", Json::Arr(ops)), ("casts", Json::Arr(casts))])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn off_by_default_and_recording_is_scoped() {
        assert!(!enabled());
        record_rms("never", 0, &[1.0, 2.0]); // silently dropped
        let ((), report) = capture(|| {
            assert!(enabled());
            record_rms("a", 0, &[3.0, 4.0]);
            record_rms("a", 0, &[0.0]);
            record_rms("a", 1, &[1.0]);
        });
        assert!(!enabled());
        assert_eq!(report.ops.len(), 2);
        let a0 = &report.ops[0];
        assert_eq!((a0.op.as_str(), a0.layer, a0.records, a0.elems), ("a", 0, 2, 3));
        // pooled rms over {3,4,0}: sqrt(25/3)
        assert!((a0.rms() - (25f64 / 3.0).sqrt()).abs() < 1e-12);
        assert_eq!(a0.abs_max, 4.0);
        // aggregate across layers: {3,4,0,1} -> sqrt(26/4)
        assert!((report.op_rms("a").unwrap() - (26f64 / 4.0).sqrt()).abs() < 1e-12);
        assert!(report.op_rms("missing").is_none());
        assert_eq!(report.op_names(), vec!["a".to_string()]);
    }

    #[test]
    fn nested_capture_restores_outer_sink() {
        let ((), outer) = capture(|| {
            record_rms("outer", 0, &[1.0]);
            let ((), inner) = capture(|| record_rms("inner", 0, &[2.0]));
            assert_eq!(inner.ops.len(), 1);
            assert_eq!(inner.ops[0].op, "inner");
            // the outer sink is live again
            record_rms("outer", 0, &[1.0]);
        });
        assert_eq!(outer.ops.len(), 1);
        assert_eq!(outer.ops[0].records, 2, "inner capture must not eat outer records");
    }

    #[test]
    fn cast_records_merge_per_site() {
        use crate::fp8::E4M3;
        let ((), report) = capture(|| {
            record_cast("qkv", 0, "e4m3", E4M3.cast_health(&[1.0, 1e-6], 1.0));
            record_cast("qkv", 0, "e4m3", E4M3.cast_health(&[1000.0], 1.0));
        });
        assert_eq!(report.casts.len(), 1);
        let c = &report.casts[0];
        assert_eq!(c.format, "e4m3");
        assert_eq!(c.health.total, 3);
        assert_eq!(c.health.underflow_to_zero, 1);
        assert_eq!(c.health.saturated, 1);
        let t = report.cast_totals("qkv").unwrap();
        assert_eq!(t.total, 3);
        assert!(report.cast_totals("nope").is_none());
    }

    #[test]
    fn report_merge_and_json_roundtrip() {
        let ((), mut a) = capture(|| record_rms("x", 0, &[1.0, 1.0]));
        let ((), b) = capture(|| {
            record_rms("x", 0, &[1.0]);
            record_rms("y", 2, &[2.0]);
            record_cast("x", 0, "e5m2", crate::fp8::E5M2.cast_health(&[1.0], 1.0));
        });
        a.merge(&b);
        assert_eq!(a.ops.len(), 2);
        assert_eq!(a.ops[0].elems, 3);
        assert_eq!(a.casts.len(), 1);
        let j = a.to_json();
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.get("ops").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(parsed.get("casts").unwrap().as_arr().unwrap().len(), 1);
        let op0 = &parsed.get("ops").unwrap().as_arr().unwrap()[0];
        assert_eq!(op0.str_or("op", ""), "x");
        assert!((op0.f64_or("rms", 0.0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn op_layer_rms_is_per_site() {
        let ((), report) = capture(|| {
            record_rms("x", 0, &[1.0, 1.0]);
            record_rms("x", 1, &[2.0]);
        });
        assert!((report.op_layer_rms("x", 0).unwrap() - 1.0).abs() < 1e-12);
        assert!((report.op_layer_rms("x", 1).unwrap() - 2.0).abs() < 1e-12);
        assert!(report.op_layer_rms("x", 2).is_none());
        // pooled op_rms mixes both layers: sqrt((1+1+4)/3)
        assert!((report.op_rms("x").unwrap() - (2f64).sqrt()).abs() < 1e-12);
    }
}
