//! # µnit Scaling (µS) — FP8 LLM training, reproduced
//!
//! Rust + JAX + Pallas three-layer reproduction of *"µnit Scaling: Simple
//! and Scalable FP8 LLM Training"* (Narayan et al., 2025).
//!
//! Layer map (see DESIGN.md and README.md §Runtime):
//!
//! - **L3 (this crate)** — the training framework, split at the runtime
//!   boundary:
//!   - [`runtime`]: the execution API. A [`runtime::Backend`] trait
//!     (`upload`/`execute`/`download` over opaque tensor handles) with two
//!     implementations — the pure-Rust [`runtime::ReferenceBackend`]
//!     (a *batched* interpreter over the op-level transformer block of
//!     `runtime::block`: RMS-norm → qkv → RoPE → multi-head causal
//!     attention → attn-out → residual → RMS-norm → ffn-up → act →
//!     ffn-down → residual per block, full backward, per-op FP8 plan on
//!     the four hidden linears; activations run as `[batch·seq, d]`
//!     matrices through the cache-blocked, bit-deterministic,
//!     SIMD-dispatched GEMM and attention kernels of [`runtime::gemm`] —
//!     runtime AVX2 detection with a bit-identical portable fallback,
//!     and FP8 quantization fused into the GEMM pack step
//!     (`gemm::matmul_bt_quant`; see `docs/KERNELS.md`) — with µS/SP
//!     numerics emulated via [`fp8`] and its bit-twiddling `FastCast`;
//!     scaling rules consumed from [`scaling`]; no artifacts needed) and the PJRT
//!     CPU path over AOT HLO-text artifacts (feature `pjrt`, `xla` crate).
//!     [`runtime::Session`] owns the *device-resident* `2·n_params` train
//!     state between steps: per-step host traffic is tokens in, loss/gnorm
//!     out (constant lr/wd/tau handles are cached on-device); full-state
//!     transfers happen only at checkpoint/probe boundaries (`read_back`).
//!     [`runtime::StatePrecision`] is the storage policy for that state —
//!     f32 (8 B/param, bit-compat default) or FP8 (BF16 masters +
//!     per-tensor power-of-two scaled E4M3 Lion momentum, 3 B/param,
//!     kept on-grid so checkpoints and the collective wire round-trip
//!     bit-exactly; `ExecStats` gauges the bytes, `perfmodel` prices
//!     them in closed form).
//!     The **inference layer** rides the same op pipeline:
//!     [`runtime::InferSession`] quantizes params once (the training
//!     casts), prefills through the training forward (bit-identical
//!     logits, whole-prompt or chunked), and decodes incrementally over
//!     a paged KV cache (`runtime::kvcache` — fixed-size slabs,
//!     free-list recycling + trim, memory ∝ live tokens; BF16 or E4M3
//!     at the µS static scale 1.0 with cast-health witnesses;
//!     refcounted slab sharing behind a token-verified `PrefixIndex`
//!     with copy-on-extend) via the shared single-query attention
//!     kernel; greedy + seeded top-k sampling. See `docs/SERVING.md`.
//!   - [`coordinator`]: trainer (schedules, divergence guard, probes),
//!     thread-parallel sweep engine (workers share one `Send + Sync`
//!     backend), simulated DDP, checkpoints, continuous-batching serve
//!     loop (`coordinator::serve`: staggered admissions, between-step
//!     evictions, one batched decode execute per step, prefix-cache
//!     adoption, chunked prefill interleaved with decode, KV trimming,
//!     per-request latency + tokens/sec accounting) with its seeded
//!     load generator (`coordinator::traffic`: Zipf prefix reuse,
//!     Poisson arrivals → `BENCH_serve.json`), metrics, data pipeline, and the
//!     **measurement layer**: [`coordinator::transfer`] runs the paper's
//!     coordinate checks (per-op RMS O(1) across width for µS, drift for
//!     SP) and LR-transfer sweeps (`munit coordcheck` / `munit transfer`
//!     → `REPORT_coordcheck.json` / `REPORT_transfer.json`).
//!   - [`telemetry`]: thread-scoped numerics sink — when a
//!     [`telemetry::capture`] is active, the block pipeline records per-op
//!     forward/backward RMS for every tensor in the tower and
//!     [`fp8::CastHealth`] counters (underflow/saturation/subnormal rates)
//!     for every FP8-quantized operand; zero overhead and bit-identical
//!     training when off (see `docs/NUMERICS.md`).
//!   - [`config`], [`data`], [`scaling`], [`analysis`], [`perfmodel`],
//!     [`eval`], [`repro`], [`util`]: configs/presets, synthetic corpus,
//!     parametrization rules, numerics analyses, throughput model, eval
//!     suite, figure/table drivers, offline substrates (JSON / RNG /
//!     error / bench / proptest / `util::parallel`, the deterministic
//!     scoped-thread substrate — fixed chunking, fixed-order reductions,
//!     bit-identical results at any thread count).
//! - **L2** (`python/compile/model.py`): µS/SP transformer fwd/bwd + Lion,
//!   AOT-lowered to HLO text artifacts (the `pjrt` catalogue).
//! - **L1** (`python/compile/kernels/`): Pallas FP8 GEMM / cast-transpose /
//!   attention / layernorm kernels (interpret=True).
//!
//! Python never runs on the step path: the binary executes either the AOT
//! artifacts via PJRT or the reference interpreter, both behind the same
//! `Backend` API.

// Style/complexity lints are relaxed crate-wide: the numeric kernels are
// written as explicit index loops on purpose (they mirror the math), and
// CI runs clippy with -D warnings.
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::manual_memcpy,
    clippy::field_reassign_with_default,
    clippy::new_without_default,
    clippy::uninlined_format_args
)]
// Every public item carries documentation; CI enforces it via
// `cargo doc --no-deps` with RUSTDOCFLAGS="-D warnings" (and clippy's
// -D warnings promotes this lint too).
#![warn(missing_docs)]

/// Numerics analyses: attention variance (Fig 2), value-token correlation
/// (Fig 3), activation-function FP8 underflow (Fig 10), outlier metrics.
pub mod analysis;
/// Typed configuration: model shapes, training runs, paper presets.
pub mod config;
/// L3 training framework: trainer, sweeps, DDP, checkpoints, serve loop,
/// metrics, data pipeline, and the width-transfer measurement harness.
pub mod coordinator;
/// Deterministic synthetic corpus (Zipfian bigram streams) + batching.
pub mod data;
/// In-context evaluation suite (Table 5 substitute) and NLL scoring.
pub mod eval;
/// Software E4M3/E5M2/BF16 emulation, bit-exact with `ml_dtypes`.
pub mod fp8;
/// Analytic H100 throughput model (Fig 8) + decode roofline.
pub mod perfmodel;
/// Paper figure/table reproduction drivers.
pub mod repro;
/// Execution runtime: `Backend` trait, sessions, reference interpreter,
/// inference engine, KV cache, GEMM/attention kernels.
pub mod runtime;
/// Parametrization & hyperparameter-transfer rule library (Tables 1-3).
pub mod scaling;
/// Per-op RMS + FP8 cast-health telemetry (thread-scoped capture sink).
pub mod telemetry;
/// Offline substrates: JSON, RNG, errors, stats, tables, bench harness,
/// property testing, deterministic scoped-thread parallelism.
pub mod util;
