//! # µnit Scaling (µS) — FP8 LLM training, reproduced
//!
//! Rust + JAX + Pallas three-layer reproduction of *"µnit Scaling: Simple
//! and Scalable FP8 LLM Training"* (Narayan et al., 2025).
//!
//! Layer map (see DESIGN.md):
//! - **L3 (this crate)**: training coordinator — config, data pipeline,
//!   PJRT runtime, trainer/sweep engine, analysis, perf model, eval.
//! - **L2** (`python/compile/model.py`): µS/SP transformer fwd/bwd + Lion,
//!   AOT-lowered to HLO text artifacts.
//! - **L1** (`python/compile/kernels/`): Pallas FP8 GEMM / cast-transpose /
//!   attention / layernorm kernels (interpret=True).
//!
//! Python never runs on the step path: the binary executes AOT artifacts
//! via the PJRT CPU client (`xla` crate).

pub mod analysis;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod fp8;
pub mod perfmodel;
pub mod repro;
pub mod runtime;
pub mod scaling;
pub mod util;
