//! # µnit Scaling (µS) — FP8 LLM training, reproduced
//!
//! Rust + JAX + Pallas three-layer reproduction of *"µnit Scaling: Simple
//! and Scalable FP8 LLM Training"* (Narayan et al., 2025).
//!
//! Layer map (see DESIGN.md and README.md §Runtime):
//!
//! - **L3 (this crate)** — the training framework, split at the runtime
//!   boundary:
//!   - [`runtime`]: the execution API. A [`runtime::Backend`] trait
//!     (`upload`/`execute`/`download` over opaque tensor handles) with two
//!     implementations — the pure-Rust [`runtime::ReferenceBackend`]
//!     (a *batched* interpreter over the op-level transformer block of
//!     `runtime::block`: RMS-norm → qkv → RoPE → multi-head causal
//!     attention → attn-out → residual → RMS-norm → ffn-up → act →
//!     ffn-down → residual per block, full backward, per-op FP8 plan on
//!     the four hidden linears; activations run as `[batch·seq, d]`
//!     matrices through the cache-blocked, bit-deterministic GEMM and
//!     attention kernels of [`runtime::gemm`], with µS/SP numerics
//!     emulated via [`fp8`] and its bit-twiddling `FastCast`; scaling
//!     rules consumed from [`scaling`]; no artifacts needed) and the PJRT
//!     CPU path over AOT HLO-text artifacts (feature `pjrt`, `xla` crate).
//!     [`runtime::Session`] owns the *device-resident* `2·n_params` train
//!     state between steps: per-step host traffic is tokens in, loss/gnorm
//!     out (constant lr/wd/tau handles are cached on-device); full-state
//!     transfers happen only at checkpoint/probe boundaries (`read_back`).
//!     The **inference layer** rides the same op pipeline:
//!     [`runtime::InferSession`] quantizes params once (the training
//!     casts), prefills through the training forward (bit-identical
//!     logits), and decodes incrementally over a paged BF16 KV cache
//!     (`runtime::kvcache` — fixed-size slabs, free-list recycling, memory
//!     ∝ live tokens) via the shared single-query attention kernel;
//!     greedy + seeded top-k sampling.
//!   - [`coordinator`]: trainer (schedules, divergence guard, probes),
//!     thread-parallel sweep engine (workers share one `Send + Sync`
//!     backend), simulated DDP, checkpoints, continuous-batching serve
//!     loop (`coordinator::serve`: staggered admissions, between-step
//!     evictions, one batched decode execute per step, per-request
//!     latency + tokens/sec accounting), metrics, data pipeline.
//!   - [`config`], [`data`], [`scaling`], [`analysis`], [`perfmodel`],
//!     [`eval`], [`repro`], [`util`]: configs/presets, synthetic corpus,
//!     parametrization rules, numerics analyses, throughput model, eval
//!     suite, figure/table drivers, offline substrates (JSON / RNG /
//!     error / bench / proptest / `util::parallel`, the deterministic
//!     scoped-thread substrate — fixed chunking, fixed-order reductions,
//!     bit-identical results at any thread count).
//! - **L2** (`python/compile/model.py`): µS/SP transformer fwd/bwd + Lion,
//!   AOT-lowered to HLO text artifacts (the `pjrt` catalogue).
//! - **L1** (`python/compile/kernels/`): Pallas FP8 GEMM / cast-transpose /
//!   attention / layernorm kernels (interpret=True).
//!
//! Python never runs on the step path: the binary executes either the AOT
//! artifacts via PJRT or the reference interpreter, both behind the same
//! `Backend` API.

// Style/complexity lints are relaxed crate-wide: the numeric kernels are
// written as explicit index loops on purpose (they mirror the math), and
// CI runs clippy with -D warnings.
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::manual_memcpy,
    clippy::field_reassign_with_default,
    clippy::new_without_default,
    clippy::uninlined_format_args
)]

pub mod analysis;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod fp8;
pub mod perfmodel;
pub mod repro;
pub mod runtime;
pub mod scaling;
pub mod util;
