//! Activation functions (f32), matching the jax definitions used in L2.
//!
//! GELU is the exact erf form (`jax.nn.gelu(approximate=False)`); erf is
//! evaluated with the Abramowitz–Stegun 7.1.26 rational approximation
//! (|err| < 1.5e-7, far below bf16 resolution — the comparisons in Fig 10
//! are made after a bf16 round-trip anyway).

/// FFN activation functions studied by the Fig 10 underflow analysis.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Activation {
    /// Exact (erf-form) GELU.
    Gelu,
    /// SiLU / swish.
    Silu,
    /// ReLU.
    Relu,
}

impl Activation {
    /// Config-string name ("gelu" / "silu" / "relu").
    pub fn name(&self) -> &'static str {
        match self {
            Activation::Gelu => "gelu",
            Activation::Silu => "silu",
            Activation::Relu => "relu",
        }
    }

    /// Evaluate the activation at `x`.
    pub fn apply(&self, x: f32) -> f32 {
        match self {
            Activation::Gelu => gelu(x),
            Activation::Silu => silu(x),
            Activation::Relu => x.max(0.0),
        }
    }

    /// Every variant, in Fig 10's plotting order.
    pub fn all() -> [Activation; 3] {
        [Activation::Gelu, Activation::Silu, Activation::Relu]
    }
}

/// erf via Abramowitz–Stegun 7.1.26.
pub fn erf(x: f64) -> f64 {
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.3275911 * x);
    let y = 1.0
        - (((((1.061405429 * t - 1.453152027) * t) + 1.421413741) * t - 0.284496736) * t
            + 0.254829592)
            * t
            * (-x * x).exp();
    sign * y
}

/// Exact GELU: 0.5 x (1 + erf(x / sqrt(2))).
pub fn gelu(x: f32) -> f32 {
    let xf = x as f64;
    (0.5 * xf * (1.0 + erf(xf / std::f64::consts::SQRT_2))) as f32
}

/// SiLU / swish: x * sigmoid(x).
pub fn silu(x: f32) -> f32 {
    let xf = x as f64;
    (xf / (1.0 + (-xf).exp())) as f32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn erf_reference_points() {
        assert!((erf(0.0)).abs() < 1e-9);
        assert!((erf(1.0) - 0.8427007929).abs() < 1e-6);
        assert!((erf(-1.0) + 0.8427007929).abs() < 1e-6);
        assert!((erf(3.0) - 0.9999779095).abs() < 1e-6);
    }

    #[test]
    fn gelu_reference_points() {
        // values from jax.nn.gelu(approximate=False)
        assert!((gelu(0.0)).abs() < 1e-7);
        assert!((gelu(1.0) - 0.841345).abs() < 1e-4);
        assert!((gelu(-1.0) + 0.158655).abs() < 1e-4);
        assert!((gelu(-4.0)).abs() < 2e-4); // deep negative tail ~ -1.3e-4
    }

    #[test]
    fn silu_reference_points() {
        assert!((silu(0.0)).abs() < 1e-9);
        assert!((silu(1.0) - 0.731059).abs() < 1e-5);
        assert!((silu(-1.0) + 0.268941).abs() < 1e-5);
    }

    #[test]
    fn tails_order_silu_slowest() {
        // |silu(x)| > |gelu(x)| for deep negative x (why SiLU underflows
        // over a *wider* input range but GELU's outputs get smaller sooner)
        for x in [-6.0f32, -8.0, -10.0] {
            assert!(silu(x).abs() > gelu(x).abs(), "{x}");
        }
        assert_eq!(Activation::Relu.apply(-5.0), 0.0);
    }
}
