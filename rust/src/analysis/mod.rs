//! Numerics analysis: the paper's §2.1 attention-variance study (Fig 2),
//! value-token correlation baseline (Fig 3), activation-function FP8
//! underflow (Fig 10 / App. A.5), and activation-outlier metrics (Fig 12).
//!
//! The *simulated* curves here are pure rust Monte Carlo over the software
//! FP8 substrate; the *observed-in-training* curves come from probe
//! artifacts (see `python/compile/model.py::probe_fn`) and are only
//! post-processed here.
//!
//! Static analysis lives alongside the Monte Carlo: [`static_numerics`]
//! proves the µS FP8 band/width-flatness claims symbolically over the
//! runtime's own op graph (`munit verify-numerics`), and [`lint`]
//! enforces the repo's determinism contracts at the source level
//! (`munit lint`).

/// Exact-GELU / SiLU / ReLU reference implementations (f32).
pub mod activations;

/// Determinism-contract linter (`munit lint`).
pub mod lint;

/// Symbolic RMS/variance propagation over the op graph
/// (`munit verify-numerics`).
pub mod static_numerics;

/// log10 exponent of the first probe-histogram bin edge (must match
/// `python/compile/configs.py::HIST_LO_EXP`).
pub const HIST_LO_EXP: i32 = -10;

use crate::fp8::Format;
use crate::util::rng::Rng;
use crate::util::stats;

/// Softmax transform used by the attention simulation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AttentionKind {
    /// Standard softmax scores.
    Standard,
    /// Square-Root Softmax (paper Eq. 9): scores = sqrt(softmax(logits)).
    SqrtSoftmax,
}

/// Monte-Carlo sigma of self-attention outputs at given sequence positions
/// with iid N(0,1) logits and iid N(0,1) value entries (paper Prop. 2.1
/// setting; the "simulated" curves of Fig 2).
///
/// Returns (position, sigma) pairs.
pub fn attention_sigma_iid(
    positions: &[usize],
    dh: usize,
    trials: usize,
    kind: AttentionKind,
    rng: &mut Rng,
) -> Vec<(usize, f64)> {
    positions
        .iter()
        .map(|&k| {
            let k = k.max(1);
            let mut samples = Vec::with_capacity(trials * dh);
            let mut logits = vec![0f32; k];
            let mut acc = vec![0f32; dh];
            for _ in 0..trials {
                for l in logits.iter_mut() {
                    *l = rng.normal_f32();
                }
                stats::softmax_inplace(&mut logits);
                if kind == AttentionKind::SqrtSoftmax {
                    for l in logits.iter_mut() {
                        *l = l.sqrt();
                    }
                }
                acc.iter_mut().for_each(|a| *a = 0.0);
                for &s in logits.iter() {
                    // one iid value row per score
                    for a in acc.iter_mut() {
                        *a += s * rng.normal_f32();
                    }
                }
                samples.extend_from_slice(&acc);
            }
            (k, stats::std(&samples))
        })
        .collect()
}

/// Theoretical sigma^2 of standard attention output under Prop. 2.1:
/// e/k - (e-1)/k^2 (the paper's first-order result, Eq. 6).
pub fn attention_sigma2_theory(k: usize) -> f64 {
    let k = k.max(1) as f64;
    let e = std::f64::consts::E;
    e / k - (e - 1.0) / (k * k)
}

/// Expected |cosine| between two iid N(0,1) vectors in dimension d —
/// the "random" baseline of Fig 3: E|cos| ~ sqrt(2/(pi*d)).
pub fn iid_cosine_baseline(d: usize) -> f64 {
    (2.0 / (std::f64::consts::PI * d as f64)).sqrt()
}

/// Monte-Carlo check of the same quantity.
pub fn iid_cosine_mc(d: usize, trials: usize, rng: &mut Rng) -> f64 {
    let mut acc = 0.0;
    let mut a = vec![0f32; d];
    let mut b = vec![0f32; d];
    for _ in 0..trials {
        rng.fill_normal(&mut a, 1.0);
        rng.fill_normal(&mut b, 1.0);
        acc += stats::cosine(&a, &b).abs();
    }
    acc / trials as f64
}

/// Input distributions for the Fig 10 underflow study.
#[derive(Debug, Clone, Copy)]
pub enum InputDist {
    /// Standard normal (the unit-scaled regime µS maintains).
    StdNormal,
    /// Uniform(-128, 128) (the paper's wide-range control).
    Uniform128,
}

/// FP8 underflow fraction of an activation function's outputs (Fig 10):
/// sample x from `dist`, compute act(x), round-trip bf16 -> e4m3, count
/// nonzero values flushed to zero.
pub fn activation_underflow(
    act: activations::Activation,
    dist: InputDist,
    fmt: Format,
    n: usize,
    rng: &mut Rng,
) -> f64 {
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let x = match dist {
            InputDist::StdNormal => rng.normal_f32(),
            InputDist::Uniform128 => rng.range_f64(-128.0, 128.0) as f32,
        };
        let y = act.apply(x);
        // paper metric counts "BF16 -> FP8" flushes
        out.push(crate::fp8::BF16.quantize(y));
    }
    fmt.underflow_fraction(&out)
}

/// Outlier score from a probe histogram (Fig 12): fraction of probability
/// mass at |x| >= `threshold`, given the probe's half-decade log10 bins
/// starting at 10^lo_exp (bin 0 = below 10^lo_exp).
pub fn hist_tail_mass(hist: &[f32], lo_exp: i32, threshold: f64) -> f64 {
    let mut mass = 0.0;
    for (i, &h) in hist.iter().enumerate() {
        let lo_edge = if i == 0 {
            0.0
        } else {
            10f64.powf(lo_exp as f64 + (i as f64 - 1.0) * 0.5)
        };
        if lo_edge >= threshold {
            mass += h as f64;
        }
    }
    mass
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fp8::E4M3;

    #[test]
    fn fig2_standard_attention_sigma_decays_as_sqrt_k() {
        let mut rng = Rng::new(1);
        let r = attention_sigma_iid(&[4, 64, 256], 8, 200, AttentionKind::Standard, &mut rng);
        // sigma ~ sqrt(e/k): ratio between k=4 and k=256 is ~8
        let ratio = r[0].1 / r[2].1;
        assert!(ratio > 4.0 && ratio < 14.0, "ratio {ratio}");
        // matches first-order theory within 25%
        for (k, s) in r {
            let th = attention_sigma2_theory(k).sqrt();
            assert!((s / th - 1.0).abs() < 0.25, "k={k} sim {s} theory {th}");
        }
    }

    #[test]
    fn fig2_sqrt_softmax_sigma_flat() {
        let mut rng = Rng::new(2);
        let r = attention_sigma_iid(&[4, 64, 256], 8, 200, AttentionKind::SqrtSoftmax, &mut rng);
        for (k, s) in r {
            assert!((s - 1.0).abs() < 0.15, "k={k} sigma {s}");
        }
    }

    #[test]
    fn fig3_iid_baseline_matches_mc() {
        let mut rng = Rng::new(3);
        let d = 16;
        let mc = iid_cosine_mc(d, 4000, &mut rng);
        let th = iid_cosine_baseline(d);
        assert!((mc / th - 1.0).abs() < 0.1, "mc {mc} th {th}");
    }

    #[test]
    fn fig10_normal_inputs_gelu_silu_exceed_relu() {
        use activations::Activation::*;
        let mut rng = Rng::new(4);
        let n = 400_000;
        let g = activation_underflow(Gelu, InputDist::StdNormal, E4M3, n, &mut rng);
        let s = activation_underflow(Silu, InputDist::StdNormal, E4M3, n, &mut rng);
        let r = activation_underflow(Relu, InputDist::StdNormal, E4M3, n, &mut rng);
        // N(0,1): gelu/silu shrink small inputs (≈x/2), widening the
        // underflow band relative to relu's identity-on-positives
        assert!(g > 1.5 * r, "gelu {g} vs relu {r}");
        assert!(s > 1.2 * r, "silu {s} vs relu {r}");
        assert!(r < 2e-3, "relu {r}");
    }

    #[test]
    fn fig10_uniform_inputs_silu_worst_relu_clean() {
        use activations::Activation::*;
        let mut rng = Rng::new(5);
        let n = 200_000;
        let g = activation_underflow(Gelu, InputDist::Uniform128, E4M3, n, &mut rng);
        let s = activation_underflow(Silu, InputDist::Uniform128, E4M3, n, &mut rng);
        let r = activation_underflow(Relu, InputDist::Uniform128, E4M3, n, &mut rng);
        // paper Fig 10: SiLU approaches 0 slowest -> widest underflow range
        assert!(s > 5.0 * g, "silu {s} vs gelu {g}");
        assert!(g > 0.01, "gelu {g}");
        assert!(r < 1e-4, "relu {r}");
    }

    #[test]
    fn tail_mass_sums_correctly() {
        // 34 bins starting at 10^-10, half-decade each; mass at both ends
        let mut h = vec![0f32; 34];
        h[33] = 0.5;
        h[0] = 0.5;
        let m = hist_tail_mass(&h, -10, 10.0);
        assert!((m - 0.5).abs() < 1e-9);
        assert_eq!(hist_tail_mass(&h, -10, 1e9), 0.0);
    }
}
