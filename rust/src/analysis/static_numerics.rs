//! Static numerics verifier: symbolic RMS/variance propagation over the
//! shared op graph.
//!
//! µS's core claim (PAPER.md §2–3) is that a *first-principles* analysis
//! of every transformer op suffices to keep each FP8 operand inside the
//! representable band with **static** scales — no measured amax, no
//! dynamic rescaling. This module turns that analysis into an executable
//! abstract interpreter: it walks the op enumeration the runtime itself
//! exports ([`crate::runtime::block`]'s `op_graph`, one node per
//! telemetry observation site, in execution order) and propagates the
//! *predicted* RMS of every activation and activation-gradient tensor
//! as a closed-form function of `(width, depth, seq, vocab, scheme)` —
//! consuming the same [`crate::scaling::Scheme`] rules
//! (`init_std` / `output_mult` / `grad_rms_width_exponent` /
//! `shard_output_mult`) the trainer consumes, so the rules being checked
//! are the rules being run.
//!
//! What it proves, before a single training step executes:
//!
//! - **unit band (µS):** every forward tensor's predicted RMS is O(1)
//!   (the head's `1/fan_in` multiplier puts logits on `1/√d` *by
//!   design*, so they are excluded);
//! - **width flatness (µS):** predictions are flat across ≥ 3 widths —
//!   forward directly, backward after compensating by the scheme's
//!   `(w/w₀)^β` gradient power law;
//! - **FP8 band fit (µS):** every operand the static plan quantizes
//!   (E4M3 weights/activations, E5M2 gradients) sits inside the format's
//!   representable band with a logged log2 margin on both sides;
//! - **shard invariance:** per-rank [`crate::scaling::ShardDim`]
//!   geometry reproduces the full-tensor multipliers at tp ∈ {2,4,8},
//!   and the runtime's own `Prepared` plan + `validate_scales` agree
//!   with the rule library (a defaulted scheme cannot slip through);
//! - **drift (SP):** the √d / d activation growth `munit coordcheck`
//!   measures is *predicted* (log2-slope ≈ 0.5 on qkv, ≈ 1.0 on
//!   ffn-down).
//!
//! [`cross_check`] closes the loop against reality: it compares the
//! per-`(op, layer)` predictions with a live `step_traced` telemetry
//! capture at documented log2 tolerances. [`Mutation`] self-tests prove
//! the verifier is not vacuous — each deliberately corrupted scheme
//! variant must be flagged. The derivation behind every propagation
//! rule is docs/NUMERICS.md §Static verification; the CLI surface is
//! `munit verify-numerics` → `REPORT_static_numerics.json`.

use crate::analysis::{activations::erf, attention_sigma2_theory};
use crate::config::ModelConfig;
use crate::coordinator::shard::{validate_scales, ShardSpec};
use crate::runtime::block::{self, OpKind, QuantMode, Role};
use crate::scaling::{ParamKind, Scheme, ShardDim};
use crate::telemetry::TelemetryReport;
use crate::util::error::{Error, Result};
use crate::util::json::Json;
use crate::util::table;
use crate::{bail, err};

/// O(1) band every µS forward tensor's predicted RMS must sit in
/// (tighter than telemetry's empirical `transfer::ACT_BAND` — the
/// symbolic predictions carry no sampling noise).
pub const UNIT_BAND: (f64, f64) = (0.3, 1.5);

/// Max across-width ratio of µS forward predictions (theory says
/// exactly 1; the slack absorbs activation-moment quadrature error).
pub const FWD_FLAT_TOL: f64 = 1.05;

/// Max across-width ratio of µS gradient predictions after `(w/w₀)^β`
/// compensation (β from [`Scheme::grad_rms_width_exponent`]).
pub const GRAD_FLAT_TOL: f64 = 1.25;

/// Extra log2 headroom demanded between a predicted RMS and the
/// format's `max_finite`: an RMS-1 Gaussian tensor has essentially no
/// mass beyond `8·rms`, so 3 octaves above the RMS must still fit.
pub const TAIL_LOG2: f64 = 3.0;

/// Sentinel `err_log2` for a cross-check row whose measured value is
/// missing or zero (kept finite so reports stay valid JSON).
pub const MISSING_ERR_LOG2: f64 = 99.0;

// ---------------------------------------------------------------------------
// Spec + mutations

/// Geometry the verifier sweeps: the model family is fixed except for
/// `width` (head_dim constant, so heads scale with width — the same
/// µP-style family `coordcheck` measures).
///
/// The default is the smoke geometry on purpose: the verifier itself
/// discovered that at-init E5M2 *gradient* RMS under µS scales as `1/d`
/// and exits the subnormal band near d ≈ 256 at standard depth — see
/// docs/NUMERICS.md §Static verification for the finding and why
/// training still works (gradients grow after the first steps).
#[derive(Debug, Clone)]
pub struct VerifySpec {
    /// Widths to verify, ascending; `widths[0]` doubles as µS's d_base.
    pub widths: Vec<usize>,
    /// Transformer blocks.
    pub depth: usize,
    /// Per-head dimension (fixed across widths).
    pub head_dim: usize,
    /// Vocabulary size.
    pub vocab: usize,
    /// Sequence length.
    pub seq_len: usize,
    /// Sequences per batch (enters the `d_logits` closed form).
    pub batch: usize,
    /// Fixed-residual coefficient of the µS lane.
    pub tau: f64,
}

impl VerifySpec {
    /// The smoke geometry — kept field-for-field in sync with
    /// `transfer::HarnessConfig::smoke()` (tested) so static predictions
    /// and live coordcheck measurements describe the same models.
    pub fn smoke() -> VerifySpec {
        VerifySpec {
            widths: vec![16, 32, 64],
            depth: 2,
            head_dim: 8,
            vocab: 128,
            seq_len: 32,
            batch: 2,
            tau: 0.4,
        }
    }

    /// The verified model at one width. `variant` is `"mus"`
    /// (static-FP8, fixed residuals, Res-Post norms) or `"sp"` (BF16,
    /// standard residuals, Pre norms).
    pub fn model(&self, variant: &str, width: usize) -> Result<ModelConfig> {
        let (precision, residual) = match variant {
            "mus" => ("fp8", "fixed"),
            "sp" => ("bf16", "standard"),
            other => bail!("unknown verifier variant '{other}' (mus | sp)"),
        };
        let d_base = if variant == "mus" {
            self.widths.first().copied().unwrap_or(width)
        } else {
            width
        };
        let cfg = ModelConfig {
            width,
            depth: self.depth,
            head_dim: self.head_dim,
            vocab: self.vocab,
            seq_len: self.seq_len,
            batch: self.batch,
            ffn_ratio: 4,
            d_base,
            variant: variant.into(),
            precision: precision.into(),
            residual: residual.into(),
            activation: "gelu".into(),
        };
        cfg.validate().map_err(Error::msg)?;
        Ok(cfg)
    }
}

/// A deliberately corrupted scaling rule, used by the self-tests that
/// prove the verifier is not vacuous: `verify_with` under any mutation
/// (on the µS lane) must fail at least one check.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mutation {
    /// The correct rules (the default).
    None,
    /// ffn-down output multiplier 1.0 instead of `1/√fan_in` — the
    /// classic un-scaled wide-linear bug (flagged by the plan
    /// comparison *and* the unit band: resid inputs grow with √f).
    WrongFfnDownMult,
    /// Hidden init std `σ = 0.02` (SP's value) instead of unit variance
    /// (flagged by the unit band: qkv RMS collapses to ~0.02).
    WrongInitStd,
    /// Residual coefficients (1,1) instead of `(√(1−τ), √τ)` (flagged
    /// by the plan comparison and the unit band: stream RMS compounds
    /// past 1.5 within two blocks).
    DroppedResidualCoeff,
    /// Gradient width exponent `1−β` instead of β (flagged by the
    /// compensated gradient-flatness check: a 4× span over a 4× width
    /// range where the law predicts flat).
    WrongGradExponent,
}

/// All corrupted variants, for "every mutation is flagged" sweeps.
pub const MUTATIONS: [Mutation; 4] = [
    Mutation::WrongFfnDownMult,
    Mutation::WrongInitStd,
    Mutation::DroppedResidualCoeff,
    Mutation::WrongGradExponent,
];

impl Mutation {
    /// Stable snake_case label used in reports.
    pub fn name(&self) -> &'static str {
        match self {
            Mutation::None => "none",
            Mutation::WrongFfnDownMult => "wrong_ffn_down_mult",
            Mutation::WrongInitStd => "wrong_init_std",
            Mutation::DroppedResidualCoeff => "dropped_residual_coeff",
            Mutation::WrongGradExponent => "wrong_grad_exponent",
        }
    }
}

/// The rule set the interpreter propagates with: the real
/// [`Scheme`] rules, optionally overridden by one [`Mutation`].
struct Rules {
    scheme: Scheme,
    mutation: Mutation,
}

impl Rules {
    fn init_std(&self, kind: ParamKind, fan_in: usize) -> f64 {
        if self.mutation == Mutation::WrongInitStd && kind == ParamKind::Hidden {
            return block::SIGMA_INIT;
        }
        self.scheme.init_std(kind, fan_in, block::SIGMA_INIT)
    }

    /// Output multiplier of one linear's role (`d` = model width,
    /// `f` = ffn width — the two fan-ins the tower uses).
    fn alpha(&self, role: Role, d: usize, f: usize) -> f64 {
        if self.mutation == Mutation::WrongFfnDownMult && role == Role::FfnDown {
            return 1.0;
        }
        match role {
            Role::Qkv | Role::AttnOut | Role::FfnUp => {
                self.scheme.output_mult(ParamKind::Hidden, d)
            }
            Role::FfnDown => self.scheme.output_mult(ParamKind::Hidden, f),
            Role::Head => self.scheme.output_mult(ParamKind::Output, d),
            _ => 1.0,
        }
    }

    fn residual(
        &self,
        cfg: &ModelConfig,
        tau: f64,
        layer: usize,
        branch: usize,
    ) -> Result<(f64, f64)> {
        if self.mutation == Mutation::DroppedResidualCoeff {
            return Ok((1.0, 1.0));
        }
        let (a, b) = block::residual_coeffs(cfg, tau as f32, layer, branch)?;
        Ok((a as f64, b as f64))
    }

    fn grad_exponent(&self) -> f64 {
        let beta = self.scheme.grad_rms_width_exponent();
        if self.mutation == Mutation::WrongGradExponent {
            return 1.0 - beta;
        }
        beta
    }
}

// ---------------------------------------------------------------------------
// Activation moments (f64 mirrors of `block::Act`, integrated under a
// Gaussian input — trapezoid over z ∈ [−10, 10], N = 2000)

fn gelu(z: f64) -> f64 {
    const K: f64 = 0.797_884_56; // sqrt(2/pi), the runtime's constant
    let u = K * (z + 0.044715 * z * z * z);
    0.5 * z * (1.0 + u.tanh())
}

fn gelu_deriv(z: f64) -> f64 {
    const K: f64 = 0.797_884_56;
    let u = K * (z + 0.044715 * z * z * z);
    let t = u.tanh();
    0.5 * (1.0 + t) + 0.5 * z * (1.0 - t * t) * K * (1.0 + 3.0 * 0.044715 * z * z)
}

fn silu(z: f64) -> f64 {
    z / (1.0 + (-z).exp())
}

fn silu_deriv(z: f64) -> f64 {
    let s = 1.0 / (1.0 + (-z).exp());
    s * (1.0 + z * (1.0 - s))
}

fn relu(z: f64) -> f64 {
    z.max(0.0)
}

fn relu_deriv(z: f64) -> f64 {
    if z > 0.0 {
        1.0
    } else {
        0.0
    }
}

/// `(rms of act(z), rms of act'(z))` for `z ~ N(0, r²)` — the factor a
/// nonlinearity applies to a Gaussian stream's forward RMS and to the
/// chain-rule gradient passing back through it.
fn act_moments(name: &str, r: f64) -> Result<(f64, f64)> {
    if r <= 0.0 {
        return Ok((0.0, 0.0));
    }
    let (f, fd): (fn(f64) -> f64, fn(f64) -> f64) = match name {
        "gelu" => (gelu, gelu_deriv),
        "silu" => (silu, silu_deriv),
        "relu" => (relu, relu_deriv),
        other => return Err(err!("unknown activation '{other}' (gelu | silu | relu)")),
    };
    const N: usize = 2000;
    const LIM: f64 = 10.0;
    let h = 2.0 * LIM / N as f64;
    let norm = 1.0 / (2.0 * std::f64::consts::PI).sqrt();
    let mut s2 = 0.0f64;
    let mut s2d = 0.0f64;
    for i in 0..=N {
        let u = -LIM + i as f64 * h;
        let w = if i == 0 || i == N { 0.5 } else { 1.0 };
        let phi = w * (-0.5 * u * u).exp() * norm;
        let z = r * u;
        s2 += f(z) * f(z) * phi;
        s2d += fd(z) * fd(z) * phi;
    }
    Ok(((s2 * h).sqrt(), (s2d * h).sqrt()))
}

// ---------------------------------------------------------------------------
// The abstract interpreter

/// Predicted RMS of one `(op, layer)` telemetry site.
#[derive(Debug, Clone)]
pub struct OpPrediction {
    /// `observe_rms` op name (shared vocabulary with telemetry).
    pub op: String,
    /// Block index (0 for global sites).
    pub layer: usize,
    /// Predicted root-mean-square of the tensor at this site.
    pub rms: f64,
}

/// Band-fit prediction for one FP8-quantized operand.
#[derive(Debug, Clone)]
pub struct QuantPrediction {
    /// `observe_cast` site name (`qkv`, `w_qkv`, `d_ffn_up`, …).
    pub op: String,
    /// Block index.
    pub layer: usize,
    /// Target format name (`e4m3` / `e5m2`).
    pub format: String,
    /// Predicted RMS of the operand entering the cast.
    pub rms: f64,
    /// `log2(rms / min_subnormal)` — > 0 means a typical element stays
    /// representable (the hard gate).
    pub margin_lo_log2: f64,
    /// `log2(max_finite / rms)` — gated at > [`TAIL_LOG2`] so the
    /// distribution tail cannot saturate.
    pub margin_hi_log2: f64,
    /// `log2(rms / min_normal)` — informational: negative means typical
    /// elements land in the (coarser) subnormal range.
    pub margin_normal_log2: f64,
    /// Predicted flush-to-zero fraction of a Gaussian tensor at this
    /// RMS: `erf((min_subnormal/2) / (√2·rms))` (informational).
    pub underflow_frac: f64,
}

/// Every prediction for one model (one width of the family).
#[derive(Debug, Clone)]
pub struct WidthPrediction {
    /// Model width.
    pub width: usize,
    /// Per-site RMS predictions, in op-graph (execution) order.
    pub ops: Vec<OpPrediction>,
    /// Band-fit predictions for every statically quantized operand.
    pub quants: Vec<QuantPrediction>,
}

/// Forward quantities the backward sweep of one layer needs.
struct LayerState {
    r_in: f64,
    r_mid: f64,
    r_zo: f64,
    r_zdown: f64,
    r_actd: f64,
    r_out: f64,
}

/// Predict every op-site RMS for one model under the *correct* rules.
/// `tau` is the fixed-residual coefficient (ignored by SP's standard
/// residuals).
pub fn predict(cfg: &ModelConfig, tau: f64) -> Result<WidthPrediction> {
    predict_with(cfg, tau, &Rules { scheme: cfg.scheme(), mutation: Mutation::None })
}

fn predict_with(cfg: &ModelConfig, tau: f64, rules: &Rules) -> Result<WidthPrediction> {
    cfg.validate().map_err(Error::msg)?;
    let (d, f, v, s) = (cfg.width, cfg.ffn_width(), cfg.vocab, cfg.seq_len);
    let (df, ff) = (d as f64, f as f64);
    let plan = block::plan_for(cfg);
    let graph = block::op_graph(cfg);
    let res_post = block::placement_for(cfg) == block::NormPlacement::ResPost;
    // mean over causal positions k = 1..s of the attention output
    // variance e/k − (e−1)/k² (paper Eq. 6, pooled like telemetry pools)
    let sig2m = (1..=s).map(attention_sigma2_theory).sum::<f64>() / s as f64;
    let sw_hd = rules.init_std(ParamKind::Hidden, d);
    let sw_hf = rules.init_std(ParamKind::Hidden, f);
    let sw_out = rules.init_std(ParamKind::Output, d);

    let mut ops: Vec<OpPrediction> = Vec::with_capacity(graph.len());
    let mut quants: Vec<QuantPrediction> = Vec::new();
    let mut layers: Vec<LayerState> = Vec::with_capacity(cfg.depth);

    // forward state (updated in op-graph order)
    let mut r_x = 0.0f64; // residual stream
    let mut r_qkv = 0.0f64;
    let mut r_mix = 0.0f64;
    let mut r_mid = 0.0f64;
    let mut r_up = 0.0f64;
    let mut r_act = 0.0f64;
    let mut r_actd = 0.0f64;
    let mut r_zo = 0.0f64;
    let mut r_zdown = 0.0f64;
    // backward state
    let mut dxn = 0.0f64; // grad on a block's output residual stream
    let mut dxmid = 0.0f64;
    let mut dz_down = 0.0f64;
    let mut dz_up = 0.0f64;
    let mut dz_o = 0.0f64;
    let mut dz_qkv = 0.0f64;

    for node in &graph {
        let l = node.layer;
        // (output rms, quantized-input rms, quantized-weight rms)
        let (rms, cast_rms, weight_rms) = match node.kind {
            OpKind::Embed => {
                r_x = rules.init_std(ParamKind::Input, d);
                (r_x, None, None)
            }
            OpKind::Norm => (1.0, None, None),
            OpKind::Rope => (r_qkv, None, None),
            OpKind::Attention => {
                r_mix = r_qkv * sig2m.sqrt();
                (r_mix, None, None)
            }
            OpKind::Activation => {
                (r_act, r_actd) = act_moments(&cfg.activation, r_up)?;
                (r_act, None, None)
            }
            OpKind::Linear(Role::Qkv) => {
                let input = if res_post { r_x } else { 1.0 };
                r_qkv = rules.alpha(Role::Qkv, d, f) * sw_hd * df.sqrt() * input;
                (r_qkv, Some(input), Some(sw_hd))
            }
            OpKind::Linear(Role::AttnOut) => {
                r_zo = rules.alpha(Role::AttnOut, d, f) * sw_hd * df.sqrt() * r_mix;
                (r_zo, Some(r_mix), Some(sw_hd))
            }
            OpKind::Linear(Role::FfnUp) => {
                let input = if res_post { r_mid } else { 1.0 };
                r_up = rules.alpha(Role::FfnUp, d, f) * sw_hd * df.sqrt() * input;
                (r_up, Some(input), Some(sw_hd))
            }
            OpKind::Linear(Role::FfnDown) => {
                r_zdown = rules.alpha(Role::FfnDown, d, f) * sw_hf * ff.sqrt() * r_act;
                (r_zdown, Some(r_act), Some(sw_hf))
            }
            OpKind::Linear(other) => bail!("op graph emitted unexpected linear {other:?}"),
            OpKind::Residual(0) => {
                let (a, b) = rules.residual(cfg, tau, l, 0)?;
                // Res-Post adds the *normed* branch (RMS 1); Pre adds the
                // raw linear output. Independent streams sum in variance.
                let branch = if res_post { 1.0 } else { r_zo };
                r_mid = ((a * r_x).powi(2) + (b * branch).powi(2)).sqrt();
                (r_mid, None, None)
            }
            OpKind::Residual(_) => {
                let (a, b) = rules.residual(cfg, tau, l, 1)?;
                let branch = if res_post { 1.0 } else { r_zdown };
                let r_out = ((a * r_mid).powi(2) + (b * branch).powi(2)).sqrt();
                layers.push(LayerState { r_in: r_x, r_mid, r_zo, r_zdown, r_actd, r_out });
                r_x = r_out;
                (r_out, None, None)
            }
            OpKind::Head => {
                // final_norm puts RMS 1 into the head
                (rules.alpha(Role::Head, d, f) * sw_out * df.sqrt(), None, None)
            }
            OpKind::GradLogits => {
                // dL/dlogits = (softmax − onehot)/scored on scored rows,
                // 0 on each sequence's last row; near-uniform softmax at
                // init gives mean-square (1 − 1/v)/v per scored element.
                let rows = (cfg.batch * s) as f64;
                let scored = (cfg.batch * (s - 1)) as f64;
                let vv = v as f64;
                (((1.0 - 1.0 / vv) / (scored * rows * vv)).sqrt(), None, None)
            }
            OpKind::GradHead => {
                let rms_dl = ops
                    .last()
                    .map(|o| o.rms)
                    .ok_or_else(|| err!("op graph emitted d_final before d_logits"))?;
                let dy = rules.alpha(Role::Head, d, f) * sw_out * (v as f64).sqrt() * rms_dl;
                let r_last = layers.last().map(|ls| ls.r_out).unwrap_or(1.0);
                dxn = dy / r_last; // final rmsnorm backward divides by its input RMS
                (dy, None, None)
            }
            OpKind::GradLinear(Role::FfnDown) => {
                let ls = &layers[l];
                let (_, b2) = rules.residual(cfg, tau, l, 1)?;
                // Res-Post: the branch grad passes back through the norm
                // (divide by the norm *input* RMS, the ffn-down output)
                dz_down = if res_post { b2 * dxn / ls.r_zdown } else { b2 * dxn };
                (dz_down, Some(dz_down), None)
            }
            OpKind::GradLinear(Role::FfnUp) => {
                let ls = &layers[l];
                // dgrad through w_down (fan-out d), then the activation
                // derivative gates the chain rule
                let d_a = rules.alpha(Role::FfnDown, d, f) * sw_hf * df.sqrt() * dz_down;
                dz_up = d_a * ls.r_actd;
                (dz_up, Some(dz_up), None)
            }
            OpKind::GradLinear(Role::AttnOut) => {
                let ls = &layers[l];
                let (a2, _) = rules.residual(cfg, tau, l, 1)?;
                let (_, b1) = rules.residual(cfg, tau, l, 0)?;
                // grad reaching the mid-stream: skip path + ffn path
                let t_d = rules.alpha(Role::FfnUp, d, f) * sw_hd * ff.sqrt() * dz_up;
                dxmid = if res_post {
                    ((a2 * dxn).powi(2) + t_d.powi(2)).sqrt()
                } else {
                    ((a2 * dxn).powi(2) + (t_d / ls.r_mid).powi(2)).sqrt()
                };
                dz_o = if res_post { b1 * dxmid / ls.r_zo } else { b1 * dxmid };
                (dz_o, Some(dz_o), None)
            }
            OpKind::GradLinear(Role::Qkv) => {
                // dgrad through w_o, spread back over heads by the same
                // softmax mixing factor the forward applied
                let d_merge = rules.alpha(Role::AttnOut, d, f) * sw_hd * df.sqrt() * dz_o;
                dz_qkv = d_merge * sig2m.sqrt();
                (dz_qkv, Some(dz_qkv), None)
            }
            OpKind::GradLinear(other) => {
                bail!("op graph emitted unexpected grad linear {other:?}")
            }
            OpKind::GradResidual => {
                let ls = &layers[l];
                let (a1, _) = rules.residual(cfg, tau, l, 0)?;
                // qkv dgrad contracts the packed 3d fan-out
                let t_d2 = rules.alpha(Role::Qkv, d, f) * sw_hd * (3.0 * df).sqrt() * dz_qkv;
                dxn = if res_post {
                    ((a1 * dxmid).powi(2) + t_d2.powi(2)).sqrt()
                } else {
                    ((a1 * dxmid).powi(2) + (t_d2 / ls.r_in).powi(2)).sqrt()
                };
                (dxn, None, None)
            }
        };
        ops.push(OpPrediction { op: node.name.to_string(), layer: l, rms });
        if let Some(QuantMode::StaticFp8(fmt)) = block::node_mode(node, &plan) {
            for (site, site_rms) in [(node.cast, cast_rms), (node.weight_cast, weight_rms)] {
                let (Some(name), Some(r)) = (site, site_rms) else { continue };
                let (lo, hi) = fmt.rms_margins(r);
                quants.push(QuantPrediction {
                    op: name.to_string(),
                    layer: l,
                    format: fmt.name.to_string(),
                    rms: r,
                    margin_lo_log2: lo,
                    margin_hi_log2: hi,
                    margin_normal_log2: (r / fmt.min_normal()).log2(),
                    underflow_frac: erf(fmt.min_subnormal() / 2.0 / (r * 2.0f64.sqrt())),
                });
            }
        }
    }
    Ok(WidthPrediction { width: d, ops, quants })
}

// ---------------------------------------------------------------------------
// Checks

/// One named gate of a [`Verification`].
#[derive(Debug, Clone)]
pub struct Check {
    /// Stable check name (`plan`, `unit_band`, `fwd_width_flat`, …).
    pub name: &'static str,
    /// Did the gate hold?
    pub pass: bool,
    /// Human-readable margin / first offenders.
    pub detail: String,
}

/// Result of verifying one variant across the spec's widths.
#[derive(Debug, Clone)]
pub struct Verification {
    /// `"mus"` or `"sp"`.
    pub variant: String,
    /// Which [`Mutation`] (by name) the rules carried (`"none"` = real).
    pub mutation: &'static str,
    /// Per-width predictions, ascending width.
    pub widths: Vec<WidthPrediction>,
    /// Every gate, with pass/fail and detail.
    pub checks: Vec<Check>,
    /// All checks passed.
    pub pass: bool,
}

fn fail_check(name: &'static str, fails: Vec<String>, ok_detail: String) -> Check {
    if fails.is_empty() {
        return Check { name, pass: true, detail: ok_detail };
    }
    let shown = fails.iter().take(3).cloned().collect::<Vec<_>>().join("; ");
    let more = fails.len().saturating_sub(3);
    let detail =
        if more > 0 { format!("{shown} (+{more} more)") } else { shown };
    Check { name, pass: false, detail }
}

/// The runtime's own plan (`block::Prepared`) and shard validation must
/// agree with the rule set being verified — this is the gate that stops
/// a defaulted or drifted scheme from slipping through, and the one a
/// wrong output multiplier trips immediately.
fn check_plan(cfgs: &[ModelConfig], tau: f64, rules: &Rules) -> Check {
    let mut fails = Vec::new();
    for cfg in cfgs {
        let (d, f) = (cfg.width, cfg.ffn_width());
        let prep = match block::Prepared::new(cfg, tau as f32) {
            Ok(p) => p,
            Err(e) => {
                fails.push(format!("w{}: plan build failed: {e:#}", d));
                continue;
            }
        };
        let alphas = [
            ("alpha_qkv", prep.alpha_qkv as f64, rules.alpha(Role::Qkv, d, f)),
            ("alpha_attn_out", prep.alpha_attn_out as f64, rules.alpha(Role::AttnOut, d, f)),
            ("alpha_ffn_up", prep.alpha_ffn_up as f64, rules.alpha(Role::FfnUp, d, f)),
            ("alpha_ffn_down", prep.alpha_ffn_down as f64, rules.alpha(Role::FfnDown, d, f)),
            ("alpha_head", prep.alpha_head as f64, rules.alpha(Role::Head, d, f)),
        ];
        for (name, got, want) in alphas {
            if (got - want).abs() > 1e-6 * want.abs().max(1.0) {
                fails.push(format!("w{d}: {name} runtime {got:.4e} vs rules {want:.4e}"));
            }
        }
        for (l, co) in prep.coeffs.iter().enumerate() {
            for branch in 0..2 {
                match rules.residual(cfg, tau, l, branch) {
                    Err(e) => fails.push(format!("w{d} l{l}: {e:#}")),
                    Ok((a, b)) => {
                        let (ga, gb) = co[branch];
                        if (ga as f64 - a).abs() > 1e-6 || (gb as f64 - b).abs() > 1e-6 {
                            fails.push(format!(
                                "w{d} l{l} b{branch}: got ({ga:.4},{gb:.4}) want ({a:.4},{b:.4})"
                            ));
                        }
                    }
                }
            }
        }
        for tp in [1usize, 2] {
            let spec = ShardSpec::new(tp, 1);
            if let Err(e) = spec.validate(cfg).and_then(|_| validate_scales(cfg, &spec)) {
                fails.push(format!("w{d} tp{tp}: {e:#}"));
            }
        }
    }
    fail_check("plan", fails, "runtime Prepared/validate_scales agree with the rule set".into())
}

/// Per-rank shard geometry must reproduce the full-tensor multipliers —
/// the closed-form reason µS needs no cross-rank scale exchange.
fn check_shard_invariance(cfgs: &[ModelConfig], rules: &Rules) -> Check {
    let mut fails = Vec::new();
    for cfg in cfgs {
        let scheme = rules.scheme;
        for fan in [cfg.width, cfg.ffn_width()] {
            for kind in [ParamKind::Hidden, ParamKind::Output] {
                let full_mult = scheme.output_mult(kind, fan);
                let full_std = scheme.init_std(kind, fan, block::SIGMA_INIT);
                for tp in [2usize, 4, 8] {
                    if fan % tp != 0 {
                        continue;
                    }
                    let cases = [
                        (ShardDim::FanOut, fan),
                        (ShardDim::FanIn, fan / tp),
                    ];
                    for (dim, local) in cases {
                        if scheme.shard_output_mult(kind, dim, local, tp) != full_mult
                            || scheme.shard_init_std(kind, dim, local, tp, block::SIGMA_INIT)
                                != full_std
                        {
                            fails.push(format!(
                                "w{} {kind:?} {dim:?} tp{tp}: sharded rule != full-tensor rule",
                                cfg.width
                            ));
                        }
                    }
                }
            }
        }
    }
    fail_check(
        "shard_invariance",
        fails,
        "per-rank ShardDim geometry reproduces full-tensor multipliers at tp 2/4/8".into(),
    )
}

fn check_unit_band(preds: &[WidthPrediction]) -> Check {
    let mut fails = Vec::new();
    for wp in preds {
        for op in &wp.ops {
            if op.op.starts_with("d_") || op.op == "logits" {
                continue;
            }
            if op.rms < UNIT_BAND.0 || op.rms > UNIT_BAND.1 {
                fails.push(format!("w{} {}[{}] rms {:.4}", wp.width, op.op, op.layer, op.rms));
            }
        }
    }
    fail_check(
        "unit_band",
        fails,
        format!("every forward op predicted in [{}, {}]", UNIT_BAND.0, UNIT_BAND.1),
    )
}

/// Across-width flatness of one op family: forward ops raw (`beta` = 0),
/// gradient ops after multiplying by `(w/w₀)^beta`.
fn check_flat(
    preds: &[WidthPrediction],
    grads: bool,
    beta: f64,
    tol: f64,
    name: &'static str,
) -> Check {
    let w0 = preds[0].width as f64;
    let mut worst = 1.0f64;
    let mut worst_site = String::from("-");
    for (i, op) in preds[0].ops.iter().enumerate() {
        if op.op.starts_with("d_") != grads || op.op == "logits" || op.op == "d_logits" {
            continue;
        }
        let mut lo = f64::INFINITY;
        let mut hi = 0.0f64;
        for wp in preds {
            let comp = if grads { (wp.width as f64 / w0).powf(beta) } else { 1.0 };
            let r = wp.ops[i].rms * comp;
            lo = lo.min(r);
            hi = hi.max(r);
        }
        let ratio = hi / lo;
        if ratio > worst {
            worst = ratio;
            worst_site = format!("{}[{}]", op.op, op.layer);
        }
    }
    Check {
        name,
        pass: worst <= tol,
        detail: format!(
            "worst across-width ratio {worst:.4} at {worst_site} (tol {tol}, beta {beta})"
        ),
    }
}

fn check_fp8_band(preds: &[WidthPrediction]) -> Check {
    let mut fails = Vec::new();
    let mut min_lo = f64::INFINITY;
    let mut min_hi = f64::INFINITY;
    let mut n = 0usize;
    for wp in preds {
        for q in &wp.quants {
            n += 1;
            min_lo = min_lo.min(q.margin_lo_log2);
            min_hi = min_hi.min(q.margin_hi_log2);
            if q.margin_lo_log2 <= 0.0 || q.margin_hi_log2 <= TAIL_LOG2 {
                fails.push(format!(
                    "w{} {}[{}] {} rms {:.3e} margins ({:.2}, {:.2})",
                    wp.width, q.op, q.layer, q.format, q.rms, q.margin_lo_log2, q.margin_hi_log2
                ));
            }
        }
    }
    if n == 0 {
        return Check {
            name: "fp8_band",
            pass: false,
            detail: "no statically quantized sites (not an FP8 plan?)".into(),
        };
    }
    fail_check(
        "fp8_band",
        fails,
        format!("{n} quant sites in band; worst margins lo {min_lo:.2}, hi {min_hi:.2} log2"),
    )
}

fn fit_slope(preds: &[WidthPrediction], op: &str, layer: usize) -> Option<f64> {
    let mut xs = Vec::with_capacity(preds.len());
    let mut ys = Vec::with_capacity(preds.len());
    for wp in preds {
        let r = wp.ops.iter().find(|o| o.op == op && o.layer == layer)?.rms;
        xs.push((wp.width as f64).log2());
        ys.push(r.log2());
    }
    let n = xs.len() as f64;
    let sx: f64 = xs.iter().sum();
    let sy: f64 = ys.iter().sum();
    let sxy: f64 = xs.iter().zip(&ys).map(|(a, b)| a * b).sum();
    let sxx: f64 = xs.iter().map(|a| a * a).sum();
    let den = n * sxx - sx * sx;
    if den == 0.0 {
        return None;
    }
    Some((n * sxy - sx * sy) / den)
}

/// SP has no static multipliers, so its activations must *drift*: the
/// verifier predicts the same √d (qkv) and d (ffn-down, two stacked
/// √fan_in factors) log-slopes the coordcheck harness measures.
fn check_sp_drift(preds: &[WidthPrediction]) -> Check {
    let mut fails = Vec::new();
    let mut detail = Vec::new();
    for (op, lo, hi) in [("qkv", 0.35, 0.65), ("ffn_down", 0.8, 1.2)] {
        match fit_slope(preds, op, 0) {
            None => fails.push(format!("{op}[0]: missing prediction")),
            Some(s) => {
                detail.push(format!("{op} slope {s:.3}"));
                if s < lo || s > hi {
                    fails.push(format!("{op}[0] slope {s:.3} outside [{lo}, {hi}]"));
                }
            }
        }
    }
    fail_check("sp_drift", fails, format!("predicted drift: {}", detail.join(", ")))
}

/// Verify one variant across the spec's widths under the correct rules.
pub fn verify(spec: &VerifySpec, variant: &str) -> Result<Verification> {
    verify_with(spec, variant, Mutation::None)
}

/// Verify under a (possibly corrupted) rule set — the mutation
/// self-test entrypoint. With [`Mutation::None`] this is [`verify`].
pub fn verify_with(spec: &VerifySpec, variant: &str, mutation: Mutation) -> Result<Verification> {
    if spec.widths.len() < 3 {
        bail!("static verification needs >= 3 widths, got {:?}", spec.widths);
    }
    let cfgs = spec
        .widths
        .iter()
        .map(|&w| spec.model(variant, w))
        .collect::<Result<Vec<_>>>()?;
    let rules = Rules { scheme: cfgs[0].scheme(), mutation };
    let widths = cfgs
        .iter()
        .map(|cfg| predict_with(cfg, spec.tau, &rules))
        .collect::<Result<Vec<_>>>()?;
    let mut checks = vec![
        check_plan(&cfgs, spec.tau, &rules),
        check_shard_invariance(&cfgs, &rules),
    ];
    if variant == "mus" {
        checks.push(check_unit_band(&widths));
        checks.push(check_flat(&widths, false, 0.0, FWD_FLAT_TOL, "fwd_width_flat"));
        let gexp = rules.grad_exponent();
        checks.push(check_flat(&widths, true, gexp, GRAD_FLAT_TOL, "grad_width_flat"));
        checks.push(check_fp8_band(&widths));
    } else {
        checks.push(check_sp_drift(&widths));
    }
    let pass = checks.iter().all(|c| c.pass);
    Ok(Verification {
        variant: variant.to_string(),
        mutation: mutation.name(),
        widths,
        checks,
        pass,
    })
}

// ---------------------------------------------------------------------------
// Cross-check against live telemetry

/// One `(op, layer)` comparison of prediction vs traced measurement.
#[derive(Debug, Clone)]
pub struct CrossCheckRow {
    /// Telemetry op name.
    pub op: String,
    /// Block index.
    pub layer: usize,
    /// Predicted RMS.
    pub predicted: f64,
    /// Measured RMS from the traced step (0 if the site is missing).
    pub measured: f64,
    /// `|log2(predicted / measured)|` ([`MISSING_ERR_LOG2`] if absent).
    pub err_log2: f64,
    /// Allowed log2 error for this op class.
    pub tol_log2: f64,
    /// `err_log2 <= tol_log2` and the site was measured.
    pub pass: bool,
}

/// Prediction-vs-measurement comparison for one width.
#[derive(Debug, Clone)]
pub struct CrossCheck {
    /// Model width.
    pub width: usize,
    /// One row per predicted op site.
    pub rows: Vec<CrossCheckRow>,
    /// All rows passed.
    pub pass: bool,
}

/// Documented log2 tolerance per op class (docs/NUMERICS.md §Static
/// verification): exact closed forms get 1 octave (CLT + FP8 rounding
/// noise); the attention-mixing approximation gets 1.5; gradients stack
/// more approximations (2.0), and the qkv/residual gradient sites also
/// carry the head-merge spread approximation (2.5).
pub fn tol_log2_for(op: &str) -> f64 {
    match op {
        "attn_mix" | "attn_out" => 1.5,
        "d_qkv" | "d_resid" => 2.5,
        _ if op.starts_with("d_") => 2.0,
        _ => 1.0,
    }
}

/// Compare one width's predictions against a live `step_traced`
/// capture. Every predicted site must be measured and agree within
/// [`tol_log2_for`] octaves.
pub fn cross_check(pred: &WidthPrediction, report: &TelemetryReport) -> CrossCheck {
    let mut rows = Vec::with_capacity(pred.ops.len());
    let mut pass = true;
    for op in &pred.ops {
        let tol = tol_log2_for(&op.op);
        let (measured, err, ok) = match report.op_layer_rms(&op.op, op.layer) {
            Some(m) if m > 0.0 && op.rms > 0.0 => {
                let e = (op.rms / m).log2().abs();
                (m, e, e <= tol)
            }
            Some(m) => (m, MISSING_ERR_LOG2, false),
            None => (0.0, MISSING_ERR_LOG2, false),
        };
        pass &= ok;
        rows.push(CrossCheckRow {
            op: op.op.clone(),
            layer: op.layer,
            predicted: op.rms,
            measured,
            err_log2: err,
            tol_log2: tol,
            pass: ok,
        });
    }
    CrossCheck { width: pred.width, rows, pass }
}

// ---------------------------------------------------------------------------
// Reports

fn width_json(wp: &WidthPrediction) -> Json {
    Json::obj(vec![
        ("width", Json::num(wp.width as f64)),
        (
            "ops",
            Json::Arr(
                wp.ops
                    .iter()
                    .map(|o| {
                        Json::obj(vec![
                            ("op", Json::str(&o.op)),
                            ("layer", Json::num(o.layer as f64)),
                            ("rms", Json::num(o.rms)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "quants",
            Json::Arr(
                wp.quants
                    .iter()
                    .map(|q| {
                        Json::obj(vec![
                            ("op", Json::str(&q.op)),
                            ("layer", Json::num(q.layer as f64)),
                            ("format", Json::str(&q.format)),
                            ("rms", Json::num(q.rms)),
                            ("margin_lo_log2", Json::num(q.margin_lo_log2)),
                            ("margin_hi_log2", Json::num(q.margin_hi_log2)),
                            ("margin_normal_log2", Json::num(q.margin_normal_log2)),
                            ("underflow_frac", Json::num(q.underflow_frac)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

impl Verification {
    /// JSON payload (one entry of `REPORT_static_numerics.json`).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("variant", Json::str(&self.variant)),
            ("mutation", Json::str(self.mutation)),
            ("pass", Json::Bool(self.pass)),
            (
                "checks",
                Json::Arr(
                    self.checks
                        .iter()
                        .map(|c| {
                            Json::obj(vec![
                                ("name", Json::str(c.name)),
                                ("pass", Json::Bool(c.pass)),
                                ("detail", Json::str(&c.detail)),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("widths", Json::Arr(self.widths.iter().map(width_json).collect())),
        ])
    }

    /// Aligned text rendering: the checks, then per-op predictions at
    /// every width, then the quantized-site margins at the widest model.
    pub fn table(&self) -> String {
        let mut out = format!(
            "static numerics — {} ({}): {}\n",
            self.variant,
            self.mutation,
            if self.pass { "PASS" } else { "FAIL" }
        );
        let rows: Vec<Vec<String>> = self
            .checks
            .iter()
            .map(|c| {
                vec![
                    c.name.to_string(),
                    if c.pass { "pass".into() } else { "FAIL".into() },
                    c.detail.clone(),
                ]
            })
            .collect();
        out.push_str(&table::render(&["check", "result", "detail"], &rows));
        if let Some(first) = self.widths.first() {
            let mut header = vec!["op".to_string(), "layer".to_string()];
            header.extend(self.widths.iter().map(|w| format!("rms@w{}", w.width)));
            let header_refs: Vec<&str> = header.iter().map(String::as_str).collect();
            let rows: Vec<Vec<String>> = first
                .ops
                .iter()
                .enumerate()
                .map(|(i, o)| {
                    let mut row = vec![o.op.clone(), o.layer.to_string()];
                    row.extend(self.widths.iter().map(|wp| format!("{:.4e}", wp.ops[i].rms)));
                    row
                })
                .collect();
            out.push('\n');
            out.push_str(&table::render(&header_refs, &rows));
        }
        if let Some(last) = self.widths.last() {
            if !last.quants.is_empty() {
                let rows: Vec<Vec<String>> = last
                    .quants
                    .iter()
                    .map(|q| {
                        vec![
                            q.op.clone(),
                            q.layer.to_string(),
                            q.format.clone(),
                            format!("{:.4e}", q.rms),
                            format!("{:.2}", q.margin_lo_log2),
                            format!("{:.2}", q.margin_hi_log2),
                            format!("{:.2e}", q.underflow_frac),
                        ]
                    })
                    .collect();
                out.push('\n');
                let w = last.width;
                out.push_str(&format!("quantized operands at w{w} (margins in log2):\n"));
                out.push_str(&table::render(
                    &["site", "layer", "fmt", "rms", "m_lo", "m_hi", "underflow"],
                    &rows,
                ));
            }
        }
        out
    }
}

impl CrossCheck {
    /// JSON payload for the cross-check section of the report.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("width", Json::num(self.width as f64)),
            ("pass", Json::Bool(self.pass)),
            (
                "rows",
                Json::Arr(
                    self.rows
                        .iter()
                        .map(|r| {
                            Json::obj(vec![
                                ("op", Json::str(&r.op)),
                                ("layer", Json::num(r.layer as f64)),
                                ("predicted", Json::num(r.predicted)),
                                ("measured", Json::num(r.measured)),
                                ("err_log2", Json::num(r.err_log2)),
                                ("tol_log2", Json::num(r.tol_log2)),
                                ("pass", Json::Bool(r.pass)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Aligned text rendering of the per-site comparison.
    pub fn table(&self) -> String {
        let rows: Vec<Vec<String>> = self
            .rows
            .iter()
            .map(|r| {
                vec![
                    r.op.clone(),
                    r.layer.to_string(),
                    format!("{:.4e}", r.predicted),
                    format!("{:.4e}", r.measured),
                    format!("{:.2}", r.err_log2),
                    format!("{:.2}", r.tol_log2),
                    if r.pass { "pass".into() } else { "FAIL".into() },
                ]
            })
            .collect();
        format!(
            "cross-check vs traced step at w{} ({}):\n{}",
            self.width,
            if self.pass { "PASS" } else { "FAIL" },
            table::render(&["op", "layer", "predicted", "measured", "err", "tol", "result"], &rows)
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::trainer::Trainer;
    use crate::coordinator::transfer::HarnessConfig;
    use crate::data::{Batcher, CorpusSpec};
    use crate::runtime::ReferenceBackend;
    use std::collections::BTreeSet;

    #[test]
    fn verify_spec_mirrors_the_coordcheck_smoke_geometry() {
        let vs = VerifySpec::smoke();
        let hc = HarnessConfig::smoke();
        assert_eq!(vs.widths, hc.widths);
        assert_eq!(vs.depth, hc.depth);
        assert_eq!(vs.head_dim, hc.head_dim);
        assert_eq!(vs.vocab, hc.vocab);
        assert_eq!(vs.seq_len, hc.seq_len);
        assert_eq!(vs.batch, hc.batch);
        assert_eq!(vs.tau, hc.tau);
    }

    #[test]
    fn mus_smoke_passes_every_static_gate() {
        let v = verify(&VerifySpec::smoke(), "mus").unwrap();
        for c in &v.checks {
            assert!(c.pass, "{}: {}", c.name, c.detail);
        }
        assert!(v.pass);
        let names: Vec<_> = v.checks.iter().map(|c| c.name).collect();
        let want_names = [
            "plan",
            "shard_invariance",
            "unit_band",
            "fwd_width_flat",
            "grad_width_flat",
            "fp8_band",
        ];
        for want in want_names {
            assert!(names.contains(&want), "missing check {want}");
        }
    }

    #[test]
    fn sp_smoke_predicts_the_measured_drift_slopes() {
        let v = verify(&VerifySpec::smoke(), "sp").unwrap();
        assert!(v.pass, "{:?}", v.checks);
        let drift = v.checks.iter().find(|c| c.name == "sp_drift").unwrap();
        assert!(drift.pass, "{}", drift.detail);
    }

    #[test]
    fn mus_predictions_match_the_closed_forms() {
        let spec = VerifySpec::smoke();
        let cfg = spec.model("mus", 16).unwrap();
        let p = predict(&cfg, spec.tau).unwrap();
        let rms = |op: &str, l: usize| {
            p.ops.iter().find(|o| o.op == op && o.layer == l).unwrap().rms
        };
        // alpha · sigma_w · sqrt(d) · 1 = (1/4)·1·4 = 1 on a unit stream
        assert!((rms("qkv", 0) - 1.0).abs() < 1e-9);
        // softmax mixing: sqrt(mean_k e/k − (e−1)/k²) at s=32
        assert!((rms("attn_mix", 0) - 0.508).abs() < 2e-3, "{}", rms("attn_mix", 0));
        // gelu on a unit Gaussian
        assert!((rms("ffn_act", 0) - 0.652).abs() < 2e-3, "{}", rms("ffn_act", 0));
        // head multiplier 1/d puts logits on 1/sqrt(d)
        assert!((rms("logits", 0) - 0.25).abs() < 1e-9);
        // d_logits closed form at v=128, batch=2, s=32
        let (v, rows, scored) = (128f64, 64f64, 62f64);
        let want = ((1.0 - 1.0 / v) / (scored * rows * v)).sqrt();
        assert!((rms("d_logits", 0) - want).abs() < 1e-12);
        // fixed residuals keep the stream at exactly 1
        assert!((rms("resid2", 1) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn mus_grads_follow_the_one_over_d_law_exactly() {
        let spec = VerifySpec::smoke();
        let mut per_w = Vec::new();
        for &w in &spec.widths {
            let cfg = spec.model("mus", w).unwrap();
            per_w.push(predict(&cfg, spec.tau).unwrap());
        }
        for (i, op) in per_w[0].ops.iter().enumerate() {
            if !op.op.starts_with("d_") || op.op == "d_logits" {
                continue;
            }
            for wp in &per_w[1..] {
                let scale = per_w[0].width as f64 / wp.width as f64;
                let ratio = wp.ops[i].rms / (per_w[0].ops[i].rms * scale);
                assert!(
                    (ratio - 1.0).abs() < 0.15,
                    "{}[{}] w{}: compensated ratio {ratio}",
                    op.op,
                    op.layer,
                    wp.width
                );
            }
        }
    }

    #[test]
    fn every_mutation_is_flagged_with_the_expected_check() {
        let spec = VerifySpec::smoke();
        let expected: &[(Mutation, &str)] = &[
            (Mutation::WrongFfnDownMult, "plan"),
            (Mutation::WrongFfnDownMult, "unit_band"),
            (Mutation::WrongInitStd, "unit_band"),
            (Mutation::DroppedResidualCoeff, "plan"),
            (Mutation::DroppedResidualCoeff, "unit_band"),
            (Mutation::WrongGradExponent, "grad_width_flat"),
        ];
        for m in MUTATIONS {
            let v = verify_with(&spec, "mus", m).unwrap();
            assert!(!v.pass, "mutation {} slipped through the verifier", m.name());
            for (mm, check) in expected.iter().filter(|(mm, _)| *mm == m) {
                let c = v.checks.iter().find(|c| c.name == *check).unwrap();
                assert!(!c.pass, "{} should trip {check}: {}", mm.name(), c.detail);
            }
        }
    }

    #[test]
    fn quant_sites_cover_both_formats_with_positive_margins() {
        let v = verify(&VerifySpec::smoke(), "mus").unwrap();
        for wp in &v.widths {
            // 4 linears x (input + weight) forward + 4 grads, per layer
            assert_eq!(wp.quants.len(), 12 * VerifySpec::smoke().depth);
            let fmts: BTreeSet<&str> = wp.quants.iter().map(|q| q.format.as_str()).collect();
            assert!(fmts.contains("e4m3") && fmts.contains("e5m2"), "{fmts:?}");
            for q in &wp.quants {
                assert!(q.margin_lo_log2 > 0.0, "{}[{}] lo {}", q.op, q.layer, q.margin_lo_log2);
                let hi = q.margin_hi_log2;
                assert!(hi > TAIL_LOG2, "{}[{}] hi {}", q.op, q.layer, hi);
                assert!(q.underflow_frac < 0.05, "{}[{}] uf {}", q.op, q.layer, q.underflow_frac);
            }
        }
    }

    /// The acceptance loop-closer: predictions match a real traced step
    /// at documented tolerances, and the op-graph coverage is exact in
    /// both directions (no runtime site the verifier misses, no
    /// predicted site the runtime lacks).
    #[test]
    fn predictions_match_a_live_traced_step() {
        let be = ReferenceBackend::new(&[]).unwrap();
        let spec = VerifySpec::smoke();
        let cfg = spec.model("mus", spec.widths[1]).unwrap();
        let pred = predict(&cfg, spec.tau).unwrap();
        let trainer = Trainer::new(&be, &cfg).unwrap();
        let mut session = trainer.init(0).unwrap();
        let corpus = CorpusSpec { vocab: cfg.vocab, ..CorpusSpec::default() };
        let mut batcher = Batcher::new(corpus, 0, 0, 1, cfg.batch, cfg.seq_len);
        let tokens = batcher.next_batch();
        let (loss, _, report) = session.step_traced(&tokens, 1.0 / 64.0, 0.0, spec.tau).unwrap();
        assert!(loss.is_finite());
        let predicted: BTreeSet<(String, usize)> =
            pred.ops.iter().map(|o| (o.op.clone(), o.layer)).collect();
        let traced: BTreeSet<(String, usize)> =
            report.ops.iter().map(|r| (r.op.clone(), r.layer)).collect();
        assert_eq!(predicted, traced, "op-graph coverage drifted from the runtime");
        let cc = cross_check(&pred, &report);
        for row in &cc.rows {
            assert!(
                row.pass,
                "{}[{}]: predicted {:.4e} measured {:.4e} err {:.2} > tol {:.2}",
                row.op, row.layer, row.predicted, row.measured, row.err_log2, row.tol_log2
            );
        }
        assert!(cc.pass);
    }

    #[test]
    fn report_json_round_trips() {
        let v = verify(&VerifySpec::smoke(), "mus").unwrap();
        let j = Json::parse(&v.to_json().to_string()).unwrap();
        assert_eq!(j.str_or("variant", ""), "mus");
        assert_eq!(j.get("pass").unwrap().as_bool(), Some(true));
        let widths = j.get("widths").unwrap().as_arr().unwrap();
        assert_eq!(widths.len(), 3);
        let q0 = &widths[0].get("quants").unwrap().as_arr().unwrap()[0];
        assert!(q0.f64_or("margin_lo_log2", -1.0) > 0.0);
        assert!(!v.table().is_empty());
    }

    #[test]
    fn act_moments_match_known_values() {
        // gelu on a unit Gaussian: rms 0.6521, deriv rms 0.6751
        let (a, ad) = act_moments("gelu", 1.0).unwrap();
        assert!((a - 0.6521).abs() < 1e-3, "{a}");
        assert!((ad - 0.6751).abs() < 1e-3, "{ad}");
        // relu keeps half the mass: rms 1/sqrt(2), deriv rms 1/sqrt(2)
        let (r, rd) = act_moments("relu", 1.0).unwrap();
        assert!((r - 0.5f64.sqrt()).abs() < 1e-6, "{r}");
        assert!((rd - 0.5f64.sqrt()).abs() < 1e-6, "{rd}");
        assert!(act_moments("nope", 1.0).is_err());
    }
}
