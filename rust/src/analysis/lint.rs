//! Determinism-contract linter: a zero-dependency token-level scan of
//! the Rust tree for patterns that break the repo's bit-determinism and
//! numerics-telemetry contracts.
//!
//! The runtime's guarantees (bit-identical steps at any thread count,
//! every FP8 cast visible to telemetry, no panics on the serve path)
//! are invariants of *code shape*, not behavior a unit test can pin —
//! a `HashMap` iteration or an f32 running sum is deterministic on the
//! machine that runs the test and silently order-dependent on the next.
//! This linter encodes each contract as a source-level rule:
//!
//! 1. **f32-accumulator** — no `let mut x = 0f32; … x += …` running
//!    sums outside the blessed gemm/collective folds (those implement
//!    fixed-shape pairwise/chunked reductions on purpose). Scalar f32
//!    accumulation is order-sensitive; use f64 or a blessed fold.
//! 2. **hashmap-iteration** — no iteration over `HashMap` contents in
//!    runtime/coordinator/fp8/telemetry/scaling/data: `HashMap` order
//!    is seeded per-process, so any iteration feeding numerics or
//!    reports is nondeterministic. Key lookups are fine; iterate sorted
//!    structures instead.
//! 3. **hot-path-unwrap** — no `.unwrap()`/`.expect(` in the step and
//!    decode hot files: a malformed request must surface as a
//!    contextual [`crate::util::error::Error`], not a panic that kills
//!    a serve loop.
//! 4. **unpaired-cast** — every read of a `Plan` quantization slot
//!    (`plan.qkv`, `plan.grad`, …) at a quantize site must have an
//!    `observe_cast` call within the preceding 10 lines, so no FP8
//!    cast can be added without CastHealth telemetry.
//! 5. **kernel-entropy** — no time or randomness sources inside kernel
//!    files (gemm/block/kvcache/fp8): kernels must be pure functions
//!    of their inputs or replay and the decode-vs-forward bit-identity
//!    tests lose their meaning.
//! 6. **stray-intrinsic** — `core::arch` SIMD intrinsics are allowed
//!    only in the blessed `runtime/gemm/kernels.rs`: the one file whose
//!    unsafe blocks are reviewed against the scalar reference kernels.
//!    An intrinsic anywhere else bypasses that review and the
//!    scalar-twin pairing below.
//! 7. **missing-scalar-twin** — every `#[target_feature]` fn `x_avx2` /
//!    `x_fma` must have a scalar twin `x_scalar` in the same file, so
//!    the bit-equality suite always has a reference to diff the SIMD
//!    path against (and non-x86 builds have a fallback).
//!
//! The scan works on a *code view* of each file: comments, string
//! contents, char literals and everything from the first
//! `#[cfg(test)]` on are blanked (tests may unwrap freely). Rules are
//! path-scoped, so the linter can state *where* each contract applies.
//! Surfaced as `munit lint`; negative fixtures under
//! `tests/lint_fixtures/` prove every rule fires.

use std::path::Path;

use crate::util::error::{Context, Result};
use crate::util::json::Json;

/// One contract breach found by the scan.
#[derive(Debug, Clone)]
pub struct Violation {
    /// Rule name (matches a [`RULES`] entry).
    pub rule: &'static str,
    /// File label, relative to the scanned root with `/` separators.
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    /// The offending source line, trimmed.
    pub excerpt: String,
}

impl Violation {
    /// JSON payload for `REPORT_lint.json`.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("rule", Json::str(self.rule)),
            ("file", Json::str(&self.file)),
            ("line", Json::num(self.line as f64)),
            ("excerpt", Json::str(&self.excerpt)),
        ])
    }
}

/// Name and one-line statement of one linted contract.
#[derive(Debug, Clone, Copy)]
pub struct Rule {
    /// Stable rule name used in reports and fixtures.
    pub name: &'static str,
    /// What the contract forbids and why.
    pub description: &'static str,
}

/// Every contract the linter enforces.
pub const RULES: [Rule; 7] = [
    Rule {
        name: "f32-accumulator",
        description: "f32 running-sum accumulators outside blessed gemm/collective folds \
                      are summation-order-sensitive; use f64 or a fixed-shape fold",
    },
    Rule {
        name: "hashmap-iteration",
        description: "HashMap iteration order is seeded per-process; numerics/report paths \
                      must iterate sorted structures",
    },
    Rule {
        name: "hot-path-unwrap",
        description: "step/decode hot paths must return contextual errors, not panic",
    },
    Rule {
        name: "unpaired-cast",
        description: "every Plan quantization-slot read at a quantize site needs an \
                      observe_cast within the preceding 10 lines (CastHealth contract)",
    },
    Rule {
        name: "kernel-entropy",
        description: "kernel files must not read time or randomness; kernels are pure \
                      functions of their inputs",
    },
    Rule {
        name: "stray-intrinsic",
        description: "core::arch SIMD intrinsics are allowed only in the blessed \
                      runtime/gemm kernel file, where they are reviewed against the \
                      scalar reference kernels",
    },
    Rule {
        name: "missing-scalar-twin",
        description: "every #[target_feature] fn needs a *_scalar twin in the same file \
                      (the bit-equality reference and the non-x86 fallback)",
    },
];

/// Files whose f32 folds are the *implementation* of deterministic
/// reduction (fixed-shape pairwise/chunked sums) and are exempt from
/// rule 1.
const R1_BLESSED: [&str; 3] =
    ["runtime/gemm/mod.rs", "runtime/gemm/kernels.rs", "coordinator/collective.rs"];

/// Directories where rule 2 (no HashMap iteration) applies — the
/// numerics, telemetry and report paths.
const R2_SCOPE: [&str; 6] =
    ["runtime/", "coordinator/", "fp8/", "telemetry/", "scaling/", "data/"];

/// The step/decode hot files rule 3 keeps panic-free.
const R3_HOT: [&str; 8] = [
    "runtime/block.rs",
    "runtime/session.rs",
    "runtime/infer.rs",
    "runtime/gemm/mod.rs",
    "runtime/gemm/kernels.rs",
    "runtime/gemm/dispatch.rs",
    "runtime/kvcache.rs",
    "coordinator/serve.rs",
];

/// Kernel files rule 5 keeps entropy-free.
const R5_KERNEL: [&str; 6] = [
    "runtime/gemm/mod.rs",
    "runtime/gemm/kernels.rs",
    "runtime/gemm/dispatch.rs",
    "runtime/block.rs",
    "runtime/kvcache.rs",
    "fp8/mod.rs",
];

/// How many preceding lines rule 4 searches for the paired
/// `observe_cast`.
const R4_WINDOW: usize = 10;

/// The ONE file where `core::arch` intrinsics (and the `unsafe` blocks
/// that call them) are allowed — rule 6.
const R6_SIMD_BLESSED: [&str; 1] = ["runtime/gemm/kernels.rs"];

fn is_ident(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

/// Last char of `s`, or a space for an empty prefix (treated as a
/// non-ident boundary).
fn last_char(s: &str) -> char {
    s.chars().next_back().unwrap_or(' ')
}

/// Leading identifier of `s` (empty if it does not start with one).
fn ident_prefix(s: &str) -> String {
    s.chars().take_while(|&c| is_ident(c)).collect()
}

/// Blank out comments, string contents, and char literals (preserving
/// line structure), and drop everything from the first `#[cfg(test)]`
/// on. The rules then scan pure code tokens: a banned pattern inside a
/// doc comment, a format string — or this linter's own pattern tables —
/// never fires.
pub fn code_view(src: &str) -> String {
    let cs: Vec<char> = src.chars().collect();
    let mut out = String::with_capacity(cs.len());
    let mut i = 0;
    let blank = |c: char| if c == '\n' { '\n' } else { ' ' };
    while i < cs.len() {
        let c = cs[i];
        let next = cs.get(i + 1).copied();
        // line comment
        if c == '/' && next == Some('/') {
            while i < cs.len() && cs[i] != '\n' {
                out.push(' ');
                i += 1;
            }
            continue;
        }
        // block comment (nested)
        if c == '/' && next == Some('*') {
            let mut depth = 1usize;
            out.push_str("  ");
            i += 2;
            while i < cs.len() && depth > 0 {
                if cs[i] == '/' && cs.get(i + 1) == Some(&'*') {
                    depth += 1;
                    out.push_str("  ");
                    i += 2;
                } else if cs[i] == '*' && cs.get(i + 1) == Some(&'/') {
                    depth -= 1;
                    out.push_str("  ");
                    i += 2;
                } else {
                    out.push(blank(cs[i]));
                    i += 1;
                }
            }
            continue;
        }
        // raw (and byte-raw) strings: r"…", r#"…"#, br#"…"#
        let prev_ident = i > 0 && is_ident(cs[i - 1]);
        if (c == 'r' || c == 'b') && !prev_ident {
            let mut j = i + 1;
            if c == 'b' && cs.get(j) == Some(&'r') {
                j += 1;
            }
            if c == 'r' || j > i + 1 {
                let mut hashes = 0usize;
                while cs.get(j + hashes) == Some(&'#') {
                    hashes += 1;
                }
                if cs.get(j + hashes) == Some(&'"') {
                    // blank the prefix + opening quote
                    for _ in i..=(j + hashes) {
                        out.push(' ');
                    }
                    i = j + hashes + 1;
                    // scan for `"` followed by `hashes` #'s
                    'raw: while i < cs.len() {
                        if cs[i] == '"' {
                            let mut k = 0usize;
                            while k < hashes && cs.get(i + 1 + k) == Some(&'#') {
                                k += 1;
                            }
                            if k == hashes {
                                for _ in 0..=hashes {
                                    out.push(' ');
                                }
                                i += 1 + hashes;
                                break 'raw;
                            }
                        }
                        out.push(blank(cs[i]));
                        i += 1;
                    }
                    continue;
                }
            }
        }
        // normal (and byte) string literal
        if c == '"' {
            out.push(' ');
            i += 1;
            while i < cs.len() {
                if cs[i] == '\\' {
                    out.push(' ');
                    if i + 1 < cs.len() {
                        out.push(blank(cs[i + 1]));
                    }
                    i += 2;
                    continue;
                }
                if cs[i] == '"' {
                    out.push(' ');
                    i += 1;
                    break;
                }
                out.push(blank(cs[i]));
                i += 1;
            }
            continue;
        }
        // char literal vs lifetime
        if c == '\'' {
            let is_char = next == Some('\\')
                || (next.is_some_and(|n| n != '\'') && cs.get(i + 2) == Some(&'\''));
            if is_char {
                out.push(' ');
                i += 1;
                while i < cs.len() {
                    if cs[i] == '\\' {
                        out.push(' ');
                        if i + 1 < cs.len() {
                            out.push(blank(cs[i + 1]));
                        }
                        i += 2;
                        continue;
                    }
                    if cs[i] == '\'' {
                        out.push(' ');
                        i += 1;
                        break;
                    }
                    out.push(blank(cs[i]));
                    i += 1;
                }
                continue;
            }
            // lifetime marker: keep scanning as code
        }
        out.push(c);
        i += 1;
    }
    if let Some(p) = out.find("#[cfg(test)]") {
        out.truncate(p);
    }
    out
}

fn push(
    out: &mut Vec<Violation>,
    rule: &'static str,
    file: &str,
    line: usize,
    src_lines: &[&str],
) {
    let full = src_lines.get(line - 1).map_or("", |l| l.trim());
    let excerpt: String = full.chars().take(120).collect();
    out.push(Violation { rule, file: file.to_string(), line, excerpt });
}

/// Rule 1: `let mut x` with an explicit-f32 zero init, later `x +=`.
/// Tracked names reset at each `fn` so unrelated functions don't
/// cross-talk; the violation anchors at the `+=` line.
fn rule_f32_accumulator(file: &str, view: &[&str], src: &[&str], out: &mut Vec<Violation>) {
    if R1_BLESSED.contains(&file) {
        return;
    }
    let mut tracked: Vec<String> = Vec::new();
    for (n, line) in view.iter().enumerate() {
        let t = line.trim_start();
        if t.starts_with("fn ") || t.contains(") fn ") || t.starts_with("pub fn ") {
            tracked.clear();
        }
        if let Some(p) = line.find("let mut ") {
            let rest = &line[p + 8..];
            let name = ident_prefix(rest);
            if !name.is_empty() {
                let after = &rest[name.len()..];
                let zeros = ["= 0f32", "= 0.0f32", "= 0_f32", "= 0.0_f32"];
                let explicit = zeros.iter().any(|z| after.contains(z));
                let annotated =
                    after.contains(": f32") && (after.contains("= 0.0") || after.contains("= 0;"));
                if explicit || annotated {
                    tracked.push(name);
                }
            }
        }
        for name in &tracked {
            let pat = format!("{name} +=");
            let mut start = 0usize;
            while let Some(p) = line[start..].find(&pat) {
                let abs = start + p;
                if !is_ident(last_char(&line[..abs])) {
                    push(out, "f32-accumulator", file, n + 1, src);
                    break;
                }
                start = abs + pat.len();
            }
        }
    }
}

/// Rule 2: iteration over an ident bound to (or declared as) a HashMap.
fn rule_hashmap_iteration(file: &str, view: &[&str], src: &[&str], out: &mut Vec<Violation>) {
    if !R2_SCOPE.iter().any(|d| file.starts_with(d)) {
        return;
    }
    let mut maps: Vec<String> = Vec::new();
    for line in view.iter() {
        if !line.contains("HashMap") {
            continue;
        }
        if let Some(p) = line.find("let ") {
            let rest = line[p + 4..].trim_start().trim_start_matches("mut ").trim_start();
            let name = ident_prefix(rest);
            if !name.is_empty() && !maps.contains(&name) {
                maps.push(name);
            }
        } else if let Some(h) = line.find("HashMap<") {
            // annotation form `name: [&[mut ]]HashMap<…>` (param, field,
            // or binding type)
            let mut before = line[..h].trim_end();
            before = before.strip_suffix("mut").unwrap_or(before).trim_end();
            before = before.strip_suffix('&').unwrap_or(before).trim_end();
            if let Some(b) = before.strip_suffix(':') {
                let name: String = b
                    .trim_end()
                    .chars()
                    .rev()
                    .take_while(|&c| is_ident(c))
                    .collect::<String>()
                    .chars()
                    .rev()
                    .collect();
                if !name.is_empty() && !maps.contains(&name) {
                    maps.push(name);
                }
            }
        }
    }
    if maps.is_empty() {
        return;
    }
    for (n, line) in view.iter().enumerate() {
        for name in &maps {
            let methods = [
                ".iter()",
                ".iter_mut()",
                ".keys()",
                ".values()",
                ".drain(",
                ".into_iter()",
                ".retain(",
            ];
            let method_hit = methods.iter().any(|m| {
                let pat = format!("{name}{m}");
                line.match_indices(&pat).any(|(p, _)| !is_ident(last_char(&line[..p])))
            });
            let loop_hit = (line.trim_start().starts_with("for ") || line.contains(" for "))
                && [format!("in &{name}"), format!("in &mut {name}"), format!("in {name} ")]
                    .iter()
                    .any(|pat| {
                        line.match_indices(pat.as_str()).any(|(p, _)| {
                            line[p + pat.len()..].chars().next().is_none_or(|c| !is_ident(c))
                        })
                    });
            if method_hit || loop_hit {
                push(out, "hashmap-iteration", file, n + 1, src);
                break;
            }
        }
    }
}

/// Rule 3: `.unwrap()` / `.expect(` in the hot files.
fn rule_hot_unwrap(file: &str, view: &[&str], src: &[&str], out: &mut Vec<Violation>) {
    if !R3_HOT.contains(&file) {
        return;
    }
    for (n, line) in view.iter().enumerate() {
        if line.contains(".unwrap()") || line.contains(".expect(") {
            push(out, "hot-path-unwrap", file, n + 1, src);
        }
    }
}

/// Rule 4: a `Plan` quantization-slot read with no `observe_cast` in
/// the preceding [`R4_WINDOW`] lines (lines that themselves call
/// `observe_cast` are the pairing, not a violation).
fn rule_unpaired_cast(file: &str, view: &[&str], src: &[&str], out: &mut Vec<Violation>) {
    if !file.starts_with("runtime/") {
        return;
    }
    let slots = ["plan.qkv", "plan.attn_out", "plan.ffn_up", "plan.ffn_down", "plan.grad"];
    for (n, line) in view.iter().enumerate() {
        if line.contains("observe_cast") {
            continue;
        }
        let mut hit = false;
        for pat in slots {
            let mut start = 0usize;
            while let Some(p) = line[start..].find(pat) {
                let abs = start + p;
                let end = abs + pat.len();
                let before_ok = !is_ident(last_char(&line[..abs]));
                let after_ok = line[end..].chars().next().is_none_or(|c| !is_ident(c));
                if before_ok && after_ok {
                    hit = true;
                    break;
                }
                start = end;
            }
            if hit {
                break;
            }
        }
        if !hit {
            continue;
        }
        let lo = n.saturating_sub(R4_WINDOW);
        if !view[lo..n].iter().any(|l| l.contains("observe_cast")) {
            push(out, "unpaired-cast", file, n + 1, src);
        }
    }
}

/// Rule 5: time/entropy sources in kernel files.
fn rule_kernel_entropy(file: &str, view: &[&str], src: &[&str], out: &mut Vec<Violation>) {
    if !R5_KERNEL.contains(&file) {
        return;
    }
    let banned = [
        "Instant::now",
        "SystemTime",
        "std::time",
        "thread_rng",
        "rand::",
        "getrandom",
        "RandomState",
    ];
    for (n, line) in view.iter().enumerate() {
        if banned.iter().any(|b| line.contains(b)) {
            push(out, "kernel-entropy", file, n + 1, src);
        }
    }
}

/// Rule 6: SIMD intrinsic tokens outside the blessed kernel file. Token
/// prefixes, not full names — `_mm256_fmadd_ps`, `_mm_add_ss`, and the
/// `core::arch` import path all count, so a stray intrinsic cannot hide
/// behind an alias.
fn rule_stray_intrinsic(file: &str, view: &[&str], src: &[&str], out: &mut Vec<Violation>) {
    if R6_SIMD_BLESSED.contains(&file) {
        return;
    }
    let banned = ["core::arch", "_mm256_", "_mm512_", "_mm_"];
    for (n, line) in view.iter().enumerate() {
        if banned.iter().any(|b| line.contains(b)) {
            push(out, "stray-intrinsic", file, n + 1, src);
        }
    }
}

/// Rule 7: a `#[target_feature]` fn `x_avx2` / `x_fma` (or any other
/// suffix) whose stem has no `fn x_scalar` in the same file. The twin is
/// what the bit-equality tests diff the SIMD path against and what
/// non-x86 builds run.
fn rule_missing_scalar_twin(file: &str, view: &[&str], src: &[&str], out: &mut Vec<Violation>) {
    for (n, line) in view.iter().enumerate() {
        if !line.contains("#[target_feature") {
            continue;
        }
        // the fn item follows the attribute (possibly after more
        // attributes / doc lines, which the view blanks)
        let Some((fn_line, name)) = view[n..].iter().take(8).enumerate().find_map(|(k, l)| {
            l.find("fn ").map(|p| (n + k, ident_prefix(l[p + 3..].trim_start())))
        }) else {
            continue;
        };
        if name.is_empty() || name.ends_with("_scalar") {
            continue;
        }
        let stem = name
            .strip_suffix("_avx2")
            .or_else(|| name.strip_suffix("_fma"))
            .or_else(|| name.strip_suffix("_avx512"))
            .unwrap_or(&name);
        let twin = format!("fn {stem}_scalar");
        let paired = view.iter().any(|l| {
            l.match_indices(&twin)
                .any(|(p, _)| l[p + twin.len()..].chars().next().is_none_or(|c| !is_ident(c)))
        });
        if !paired {
            push(out, "missing-scalar-twin", file, fn_line + 1, src);
        }
    }
}

/// Lint one file's source under its tree-relative label (e.g.
/// `"runtime/infer.rs"` — the label decides which path-scoped rules
/// apply). Returns every violation, in line order per rule.
pub fn lint_source(file: &str, source: &str) -> Vec<Violation> {
    let view_owned = code_view(source);
    let view: Vec<&str> = view_owned.lines().collect();
    let src: Vec<&str> = source.lines().collect();
    let mut out = Vec::new();
    rule_f32_accumulator(file, &view, &src, &mut out);
    rule_hashmap_iteration(file, &view, &src, &mut out);
    rule_hot_unwrap(file, &view, &src, &mut out);
    rule_unpaired_cast(file, &view, &src, &mut out);
    rule_kernel_entropy(file, &view, &src, &mut out);
    rule_stray_intrinsic(file, &view, &src, &mut out);
    rule_missing_scalar_twin(file, &view, &src, &mut out);
    out.sort_by(|a, b| (a.line, a.rule).cmp(&(b.line, b.rule)));
    out
}

/// Recursively lint every `.rs` file under `root` (sorted walk, labels
/// relative to `root` with `/` separators). Returns
/// `(files_scanned, violations)`.
pub fn lint_tree(root: &Path) -> Result<(usize, Vec<Violation>)> {
    let mut files = Vec::new();
    collect_rs(root, root, &mut files)?;
    files.sort();
    let mut violations = Vec::new();
    for rel in &files {
        let src = std::fs::read_to_string(root.join(rel))
            .with_context(|| format!("reading {rel} under {}", root.display()))?;
        violations.extend(lint_source(rel, &src));
    }
    Ok((files.len(), violations))
}

fn collect_rs(root: &Path, dir: &Path, out: &mut Vec<String>) -> Result<()> {
    let mut entries: Vec<_> = std::fs::read_dir(dir)
        .with_context(|| format!("listing {}", dir.display()))?
        .collect::<std::io::Result<Vec<_>>>()
        .with_context(|| format!("walking {}", dir.display()))?;
    entries.sort_by_key(|e| e.path());
    for e in entries {
        let p = e.path();
        if p.is_dir() {
            collect_rs(root, &p, out)?;
        } else if p.extension().and_then(|x| x.to_str()) == Some("rs") {
            let rel = p
                .strip_prefix(root)
                .unwrap_or(&p)
                .components()
                .map(|c| c.as_os_str().to_string_lossy().into_owned())
                .collect::<Vec<_>>()
                .join("/");
            out.push(rel);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn code_view_blanks_comments_strings_and_tests() {
        let src = concat!(
            "let a = 1; // x.unwrap()\n",
            "let s = \"y.unwrap()\"; /* z.unwrap() */\n",
            "let c = 'u'; let r = r#\"w.unwrap()\"#;\n",
            "#[cfg(test)]\n",
            "mod t { fn f() { x.unwrap(); } }\n"
        );
        let v = code_view(src);
        assert!(!v.contains("unwrap"), "{v}");
        assert!(v.contains("let a = 1;"));
        assert!(v.lines().count() >= 3);
    }

    #[test]
    fn code_view_keeps_lifetimes_and_nested_comments() {
        let src = "fn f<'a>(x: &'a str) {}\n/* outer /* inner */ still comment */ let k = 9;\n";
        let v = code_view(src);
        assert!(v.contains("fn f<'a>(x: &'a str)"));
        assert!(v.contains("let k = 9;"));
        assert!(!v.contains("inner"));
    }

    #[test]
    fn f32_accumulator_fires_and_f64_does_not() {
        let bad = concat!(
            "fn s(xs: &[f32]) -> f32 {\n",
            "    let mut acc = 0f32;\n",
            "    for x in xs { acc += x; }\n",
            "    acc\n}\n"
        );
        let v = lint_source("telemetry/mod.rs", bad);
        assert_eq!(v.len(), 1, "{v:?}");
        assert_eq!(v[0].rule, "f32-accumulator");
        assert_eq!(v[0].line, 3);
        let good = bad.replace("0f32", "0f64");
        assert!(lint_source("telemetry/mod.rs", &good).is_empty());
        // blessed fold files may accumulate
        assert!(lint_source("runtime/gemm/mod.rs", bad).is_empty());
        assert!(lint_source("runtime/gemm/kernels.rs", bad).is_empty());
    }

    #[test]
    fn hashmap_iteration_fires_only_in_scope() {
        let bad = concat!(
            "use std::collections::HashMap;\n",
            "fn f(m: &HashMap<u64, f32>) -> f32 {\n",
            "    m.values().sum()\n}\n"
        );
        let v = lint_source("runtime/infer.rs", bad);
        assert_eq!(v.len(), 1, "{v:?}");
        assert_eq!(v[0].rule, "hashmap-iteration");
        assert!(lint_source("analysis/mod.rs", bad).is_empty());
        // keyed lookup is fine
        let good = concat!(
            "use std::collections::HashMap;\n",
            "fn f(m: &HashMap<u64, f32>) -> f32 {\n",
            "    m.get(&3).copied().unwrap_or(0.0)\n}\n"
        );
        assert!(lint_source("telemetry/mod.rs", good).is_empty());
    }

    #[test]
    fn hot_unwrap_fires_in_hot_files_not_elsewhere_or_tests() {
        let bad = "fn f(x: Option<u8>) -> u8 { x.unwrap() }\n";
        let v = lint_source("runtime/session.rs", bad);
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].rule, "hot-path-unwrap");
        assert!(lint_source("eval/mod.rs", bad).is_empty());
        let test_only = format!("#[cfg(test)]\nmod t {{ {bad} }}\n");
        assert!(lint_source("runtime/session.rs", &test_only).is_empty());
    }

    #[test]
    fn unpaired_cast_fires_without_observe_cast_nearby() {
        let bad = "fn f(prep: &P) {\n    op_linear(x, prep.plan.qkv, w);\n}\n";
        let v = lint_source("runtime/infer.rs", bad);
        assert_eq!(v.len(), 1, "{v:?}");
        assert_eq!(v[0].rule, "unpaired-cast");
        let good = concat!(
            "fn f(prep: &P) {\n",
            "    observe_cast(\"qkv\", l, x, prep.plan.qkv);\n",
            "    op_linear(x, prep.plan.qkv, w);\n}\n"
        );
        assert!(lint_source("runtime/infer.rs", good).is_empty());
        // token boundary: accessor names that merely share the prefix
        let accessor = "fn f(plan: &Plan) -> QuantMode { plan.grad_mode() }\n";
        assert!(lint_source("runtime/block.rs", accessor).is_empty());
    }

    #[test]
    fn kernel_entropy_fires_only_in_kernel_files() {
        let bad = "fn f() -> u64 { let t = std::time::Instant::now(); 0 }\n";
        let v = lint_source("runtime/gemm/kernels.rs", bad);
        assert!(!v.is_empty());
        assert!(v.iter().all(|x| x.rule == "kernel-entropy"));
        assert!(lint_source("coordinator/ddp.rs", bad).is_empty());
    }

    #[test]
    fn stray_intrinsic_fires_outside_the_blessed_kernel_file() {
        let bad = concat!(
            "fn f(a: &[f32]) -> f32 {\n",
            "    unsafe { core::arch::x86_64::_mm256_setzero_ps() };\n",
            "    0.0\n}\n"
        );
        let v = lint_source("runtime/infer.rs", bad);
        assert_eq!(v.len(), 1, "{v:?}");
        assert_eq!(v[0].rule, "stray-intrinsic");
        assert_eq!(v[0].line, 2);
        // the blessed kernel file may use intrinsics
        assert!(lint_source("runtime/gemm/kernels.rs", bad)
            .iter()
            .all(|x| x.rule != "stray-intrinsic"));
        // mention in a comment or string never fires
        let doc = "// _mm256_add_ps is fast\nlet s = \"core::arch\";\n";
        assert!(lint_source("runtime/block.rs", doc).is_empty());
    }

    #[test]
    fn missing_scalar_twin_fires_without_the_twin() {
        let bad = concat!(
            "#[target_feature(enable = \"avx2\")]\n",
            "unsafe fn sum8_avx2(a: &[f32]) -> f32 { 0.0 }\n"
        );
        let v = lint_source("runtime/gemm/kernels.rs", bad);
        assert_eq!(v.len(), 1, "{v:?}");
        assert_eq!(v[0].rule, "missing-scalar-twin");
        assert_eq!(v[0].line, 2);
        let good = format!("{bad}fn sum8_scalar(a: &[f32]) -> f32 {{ 0.0 }}\n");
        assert!(lint_source("runtime/gemm/kernels.rs", &good).is_empty());
        // _fma variants share the _scalar twin of their stem
        let fma = concat!(
            "#[target_feature(enable = \"avx2,fma\")]\n",
            "unsafe fn dot_fma(a: &[f32]) -> f32 { 0.0 }\n",
            "fn dot_scalar(a: &[f32]) -> f32 { 0.0 }\n"
        );
        assert!(lint_source("runtime/gemm/kernels.rs", fma).is_empty());
    }

    #[test]
    fn violation_json_has_all_fields() {
        let v = lint_source("runtime/session.rs", "fn f(x: Option<u8>) -> u8 { x.unwrap() }\n");
        let j = Json::parse(&v[0].to_json().to_string()).unwrap();
        assert_eq!(j.str_or("rule", ""), "hot-path-unwrap");
        assert_eq!(j.str_or("file", ""), "runtime/session.rs");
        assert_eq!(j.usize_or("line", 0), 1);
        assert!(!j.str_or("excerpt", "").is_empty());
    }

    #[test]
    fn the_rule_table_matches_the_implementation() {
        let names: Vec<_> = RULES.iter().map(|r| r.name).collect();
        assert_eq!(
            names,
            [
                "f32-accumulator",
                "hashmap-iteration",
                "hot-path-unwrap",
                "unpaired-cast",
                "kernel-entropy",
                "stray-intrinsic",
                "missing-scalar-twin"
            ]
        );
        assert!(RULES.iter().all(|r| !r.description.is_empty()));
    }
}
