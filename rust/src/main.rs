//! `munit` — µnit Scaling training framework CLI (L3 leader entrypoint).
//!
//! Subcommands (the dispatch table `COMMANDS` below is the single source
//! of truth — the unknown-command help is generated from it, so the list
//! cannot go stale):
//!   info                       list artifacts, platform, presets
//!   train      --config NAME   train one model, JSONL metrics to results/
//!   train-one  --config NAME   one run, JSON summary on stdout (scripting)
//!   sweep      --config NAME   η/λ/τ grid (--workers N = in-process threads)
//!   ddp        --config NAME   simulated multi-worker data-parallel run
//!   shard      --config NAME   sharded run: tensor + pipeline parallel
//!                              (--tp K --stages S --wire master|fp8),
//!                              comm bytes cross-checked vs perfmodel
//!   figure     fig2..fig12     reproduce a paper figure (see DESIGN.md §4)
//!   table      table2..table5  reproduce a paper table
//!   e2e                        headline end-to-end driver (≈12M-param µS FP8)
//!   generate   --config NAME   train briefly, then autoregressive decode
//!                              (--prompt-len N --new M --topk K --steps S)
//!   serve      --config NAME   continuous-batching serve loop over a
//!                              synthetic request set (--requests N
//!                              --max-batch B --steps S), latency report
//!   traffic    --config NAME   Zipf/Poisson synthetic load through four
//!                              serving tiers (baseline, prefix cache,
//!                              chunked prefill, FP8 KV + both):
//!                              p50/p99 latency, goodput, prefix-hit
//!                              rate, KV bytes (--requests N --rate R
//!                              --chunk C --max-batch B)
//!   bench-step --config NAME   per-step latency + host-transfer breakdown
//!   coordcheck                 per-op RMS coordinate check across widths
//!                              (µS O(1) band vs SP drift) via the
//!                              telemetry sink → REPORT_coordcheck.json
//!   transfer                   loss-vs-LR curves per width (µS best-LR
//!                              width-stability) → REPORT_transfer.json
//!   verify-numerics            static verifier: symbolic RMS propagation
//!                              over the op graph (FP8 band margins,
//!                              width-flatness, shard invariance, mutation
//!                              self-tests, live cross-check)
//!                              → REPORT_static_numerics.json
//!   lint                       determinism-contract linter over rust/src
//!                              → REPORT_lint.json
//!
//! Flags: --artifacts DIR (default ./artifacts), --results DIR (default
//! ./results), --backend auto|reference|pjrt (default auto), --fast
//! (shrink steps/grids; coordcheck/transfer also take --widths a,b,c and
//! --steps N). Training commands (train, train-one, ddp, shard,
//! bench-step) take --state-precision f32|fp8: the optimizer + master
//! state storage policy (f32 = 8 B/param bit-compat default; fp8 = BF16
//! masters + scaled-E4M3 Lion momentum, 3 B/param). Without AOT
//! artifacts (or without the `pjrt` feature) everything runs on the
//! pure-Rust reference backend.

#![allow(clippy::uninlined_format_args)]

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use munit::config::{ModelConfig, TrainConfig};
use munit::coordinator::collective::WireFormat;
use munit::coordinator::{ddp, metrics::MetricsLogger, shard, sweep, trainer::Trainer, transfer};
use munit::data::Batcher;
use munit::repro::{self, corpus_for, proxy_tc, Ctx};
use munit::runtime::{open_backend, Backend, ReferenceBackend, StatePrecision};
use munit::scaling::recommended_tau;
use munit::util::error::{Context, Result};

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Tiny argv parser: positionals + `--key value` pairs + `--flag`.
struct Args {
    positional: Vec<String>,
    named: HashMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    fn parse() -> Args {
        let mut positional = Vec::new();
        let mut named = HashMap::new();
        let mut flags = Vec::new();
        let argv: Vec<String> = std::env::args().skip(1).collect();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(key) = a.strip_prefix("--") {
                if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    named.insert(key.to_string(), argv[i + 1].clone());
                    i += 2;
                } else {
                    flags.push(key.to_string());
                    i += 1;
                }
            } else {
                positional.push(a.clone());
                i += 1;
            }
        }
        Args { positional, named, flags }
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.named.get(key).map(|s| s.as_str())
    }
    fn f64_or(&self, key: &str, d: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(d)
    }
    fn usize_or(&self, key: &str, d: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(d)
    }
    fn has(&self, flag: &str) -> bool {
        self.flags.iter().any(|f| f == flag)
    }
}

/// Parsed invocation: arguments plus the artifact/results directories.
/// Every command handler receives this (and only this), so the dispatch
/// table below can hold plain `fn` pointers.
struct Cli {
    args: Args,
    artifacts: PathBuf,
    results: PathBuf,
}

impl Cli {
    fn backend(&self) -> Result<Box<dyn Backend>> {
        backend_for(&self.args, &self.artifacts)
    }

    /// Resolve `--config NAME` against the backend's catalogue.
    fn named_config(&self, backend: &dyn Backend) -> Result<ModelConfig> {
        let name = self.args.get("config").context("--config required")?;
        config_by_name(backend, name)
    }
}

/// One CLI subcommand: its name IS the dispatch key, and the
/// unknown-command help is generated from this table (regression: the
/// old hand-maintained help string had drifted — it omitted `train-one`).
struct Cmd {
    name: &'static str,
    run: fn(&Cli) -> Result<()>,
}

/// The dispatch table. Adding a command here is the whole registration.
const COMMANDS: &[Cmd] = &[
    Cmd { name: "info", run: cmd_info },
    Cmd { name: "train", run: cmd_train },
    Cmd { name: "train-one", run: cmd_train_one },
    Cmd { name: "sweep", run: cmd_sweep },
    Cmd { name: "ddp", run: cmd_ddp },
    Cmd { name: "shard", run: cmd_shard },
    Cmd { name: "figure", run: cmd_repro },
    Cmd { name: "table", run: cmd_repro },
    Cmd { name: "e2e", run: cmd_e2e },
    Cmd { name: "generate", run: cmd_generate },
    Cmd { name: "serve", run: cmd_serve },
    Cmd { name: "traffic", run: cmd_traffic },
    Cmd { name: "bench-step", run: cmd_bench_step },
    Cmd { name: "coordcheck", run: cmd_coordcheck },
    Cmd { name: "transfer", run: cmd_transfer },
    Cmd { name: "verify-numerics", run: cmd_verify_numerics },
    Cmd { name: "lint", run: cmd_lint },
];

/// Space-separated command list for help/error text — derived from
/// [`COMMANDS`] so it cannot go stale.
fn command_list() -> String {
    COMMANDS.iter().map(|c| c.name).collect::<Vec<_>>().join(" ")
}

/// Open the execution backend per --backend (auto|reference|pjrt).
fn backend_for(args: &Args, artifacts: &Path) -> Result<Box<dyn Backend>> {
    match args.get("backend").unwrap_or("auto") {
        "auto" => open_backend(artifacts),
        "reference" => Ok(Box::new(ReferenceBackend::with_standard_roster())),
        "pjrt" => {
            #[cfg(feature = "pjrt")]
            {
                Ok(Box::new(munit::runtime::PjrtBackend::new(artifacts)?))
            }
            #[cfg(not(feature = "pjrt"))]
            {
                let _ = artifacts;
                Err(munit::err!(
                    "this build has no PJRT support (rebuild with --features pjrt)"
                ))
            }
        }
        other => Err(munit::err!("unknown backend '{other}' (auto|reference|pjrt)")),
    }
}

/// Resolve a config by canonical name from the backend's catalogue.
fn config_by_name(backend: &dyn Backend, name: &str) -> Result<ModelConfig> {
    backend
        .manifest()
        .artifacts
        .iter()
        .filter_map(|a| a.config.as_ref())
        .find(|c| c.name() == name)
        .cloned()
        .with_context(|| {
            format!("no artifact config named '{name}' (see `munit info` for the list)")
        })
}

fn run() -> Result<()> {
    let args = Args::parse();
    let cli = Cli {
        artifacts: PathBuf::from(args.get("artifacts").unwrap_or("artifacts")),
        results: PathBuf::from(args.get("results").unwrap_or("results")),
        args,
    };
    let cmd = cli.args.positional.first().map(|s| s.as_str()).unwrap_or("info");
    match COMMANDS.iter().find(|c| c.name == cmd) {
        Some(c) => (c.run)(&cli),
        None => Err(munit::err!("unknown command '{cmd}' (try: {})", command_list())),
    }
}

fn cmd_info(cli: &Cli) -> Result<()> {
    let backend = cli.backend()?;
    println!("platform: {}", backend.platform());
    println!("commands: {}", command_list());
    println!("artifacts ({}):", backend.manifest().artifacts.len());
    let mut names: Vec<String> = backend
        .manifest()
        .artifacts
        .iter()
        .filter_map(|a| a.config.as_ref())
        .map(|c| c.name())
        .collect();
    names.sort();
    names.dedup();
    for n in names {
        println!("  {n}");
    }
    Ok(())
}

fn cmd_train(cli: &Cli) -> Result<()> {
    let backend = cli.backend()?;
    let cfg = cli.named_config(backend.as_ref())?;
    let name = cfg.name();
    let tc = tc_from_args(&cli.args, &cfg);
    let sp = state_precision_from_args(&cli.args)?;
    let trainer = Trainer::with_state_precision(backend.as_ref(), &cfg, sp)?;
    let mut batcher = Batcher::new(corpus_for(&cfg), tc.seed, 0, 1, cfg.batch, cfg.seq_len);
    let mut log = MetricsLogger::create(&cli.results, &format!("train_{name}"))?;
    let log_every = tc.log_every;
    let r = trainer.run_with(&tc, &mut batcher, |m, _| {
        let _ = log.log_step(m);
        if m.step % log_every == 0 {
            println!(
                "step {:>5} loss {:.4} gnorm {:.3} lr {:.5}",
                m.step, m.loss, m.gnorm, m.lr
            );
        }
    })?;
    log.log_summary(&name, &r)?;
    println!(
        "done: {} steps, final loss {:.4}, {:.0} tok/s{} (state {} = {} B/param)",
        r.steps_done,
        r.final_loss(10),
        r.tokens_per_sec,
        if r.diverged { " [DIVERGED]" } else { "" },
        sp.label(),
        sp.bytes_per_param_elem()
    );
    Ok(())
}

fn cmd_train_one(cli: &Cli) -> Result<()> {
    let backend = cli.backend()?;
    let cfg = cli.named_config(backend.as_ref())?;
    let tc = tc_from_args(&cli.args, &cfg);
    let sp = state_precision_from_args(&cli.args)?;
    let trainer = Trainer::with_state_precision(backend.as_ref(), &cfg, sp)?;
    let mut batcher = Batcher::new(corpus_for(&cfg), tc.seed, 0, 1, cfg.batch, cfg.seq_len);
    let r = trainer.run(&tc, &mut batcher)?;
    println!("{}", munit::coordinator::metrics::summary_json(&cfg.name(), &r));
    Ok(())
}

fn cmd_sweep(cli: &Cli) -> Result<()> {
    let backend = cli.backend()?;
    let cfg = cli.named_config(backend.as_ref())?;
    let tc = tc_from_args(&cli.args, &cfg);
    let (lo, hi) = parse_range(cli.args.get("lr-exp").unwrap_or("-9:-5"))?;
    let lrs = sweep::pow2_axis(lo, hi);
    let wds: Vec<f64> = [0.5, 1.0, 4.0].iter().map(|m| m * tc.wd).collect();
    let taus = vec![tc.tau];
    let points = sweep::grid(&lrs, &wds, &taus);
    println!("sweep: {} points over {}", points.len(), cfg.name());
    // --workers N runs N in-process threads over the shared backend
    // (--procs kept as a legacy alias)
    let workers = cli.args.usize_or("workers", cli.args.usize_or("procs", 1));
    let corpus = corpus_for(&cfg);
    let outcomes = if workers > 1 {
        sweep::run_parallel(backend.as_ref(), &cfg, &tc, &corpus, &points, workers, true)?
    } else {
        sweep::run_sequential(backend.as_ref(), &cfg, &tc, &corpus, &points, true)?
    };
    if let Some(b) = sweep::best(&outcomes) {
        println!(
            "best: lr=2^{:.0} wd={:.5} tau={:.2} loss={:.4}",
            b.point.lr.log2(),
            b.point.wd,
            b.point.tau,
            b.final_loss
        );
        for o in sweep::optimal_subset(&outcomes, 0.0025) {
            println!(
                "  within 0.25%: lr=2^{:.0} wd={:.5} tau={:.2} loss={:.4}",
                o.point.lr.log2(),
                o.point.wd,
                o.point.tau,
                o.final_loss
            );
        }
    } else {
        println!("all runs diverged");
    }
    Ok(())
}

fn cmd_ddp(cli: &Cli) -> Result<()> {
    let backend = cli.backend()?;
    let cfg = cli.named_config(backend.as_ref())?;
    let tc = tc_from_args(&cli.args, &cfg);
    let workers = cli.args.usize_or("workers", 2);
    let sp = state_precision_from_args(&cli.args)?;
    let corpus = corpus_for(&cfg);
    let r = ddp::train_ddp_with_precision(backend.as_ref(), &cfg, &tc, &corpus, workers, sp)?;
    println!(
        "ddp x{}: {} steps, final loss {:.4}, {:.0} tok/s (aggregate)",
        workers,
        r.steps_done,
        r.final_loss(10),
        r.tokens_per_sec
    );
    Ok(())
}

fn cmd_shard(cli: &Cli) -> Result<()> {
    let backend = cli.backend()?;
    let cfg = cli.named_config(backend.as_ref())?;
    let tc = tc_from_args(&cli.args, &cfg);
    let tp = cli.args.usize_or("tp", 2);
    let stages = cli.args.usize_or("stages", 1);
    let mb = cli.args.usize_or("microbatches", stages.max(1));
    let spec = shard::ShardSpec::new(tp, stages).with_microbatches(mb);
    let wire_name = cli.args.get("wire").unwrap_or("master");
    let wire = WireFormat::by_name(wire_name)
        .with_context(|| format!("unknown wire '{wire_name}' (master|fp8)"))?;
    let sp = state_precision_from_args(&cli.args)?;
    let opts = shard::ShardOpts::new(spec, wire).with_state_precision(sp);
    let r = shard::train_sharded(backend.as_ref(), &cfg, &tc, &corpus_for(&cfg), &opts)?;
    println!(
        "shard {} wire={} state={}: {} steps, final loss {:.4}, {:.0} tok/s{}",
        spec.describe(),
        wire.label(),
        sp.label(),
        r.run.steps_done,
        r.run.final_loss(10),
        r.run.tokens_per_sec,
        if r.run.diverged { " (diverged)" } else { "" }
    );
    let modeled = munit::perfmodel::param_wire_bytes_per_step(&cfg, tp, wire)
        + munit::perfmodel::momentum_wire_bytes_per_step(&cfg, tp, wire, sp)
        + munit::perfmodel::pipeline_activation_bytes_per_step(&cfg, stages);
    let measured = r.comm.bytes_per_step();
    println!(
        "  comm/step: allgather {} B, reduce-scatter {} B, activations {} B -> {} B \
         (perfmodel {} B, {})",
        r.comm.allgather_bytes / r.comm.steps.max(1) as u64,
        r.comm.reduce_scatter_bytes / r.comm.steps.max(1) as u64,
        r.comm.activation_bytes / r.comm.steps.max(1) as u64,
        measured,
        modeled,
        if measured == modeled { "exact match" } else { "MISMATCH" }
    );
    println!(
        "  wire health: {} casts, underflow {:.2e}, saturation {:.2e}, amax syncs {}",
        r.comm.health.total,
        r.comm.health.underflow_rate(),
        r.comm.health.saturation_rate(),
        r.comm.amax_syncs
    );
    Ok(())
}

/// Shared handler of `figure` and `table` (the repro driver key decides).
fn cmd_repro(cli: &Cli) -> Result<()> {
    let which = cli.args.positional.get(1).context("which figure/table?")?.clone();
    let ctx = Ctx::new(&cli.artifacts, &cli.results, cli.args.has("fast"))?;
    let report = dispatch_repro(&ctx, &which)?;
    println!("{report}");
    save_report(&cli.results, &format!("{which}.txt"), &report)
}

fn cmd_e2e(cli: &Cli) -> Result<()> {
    let ctx = Ctx::new(&cli.artifacts, &cli.results, cli.args.has("fast"))?;
    let steps = cli.args.usize_or("steps", if cli.args.has("fast") { 60 } else { 300 });
    let report = e2e(&ctx, steps)?;
    println!("{report}");
    save_report(&cli.results, "e2e.txt", &report)
}

fn cmd_generate(cli: &Cli) -> Result<()> {
    let backend = cli.backend()?;
    let cfg = cli.named_config(backend.as_ref())?;
    generate_cmd(backend.as_ref(), &cfg, &cli.args)
}

fn cmd_serve(cli: &Cli) -> Result<()> {
    let backend = cli.backend()?;
    let cfg = cli.named_config(backend.as_ref())?;
    serve_cmd(backend.as_ref(), &cfg, &cli.args)
}

fn cmd_traffic(cli: &Cli) -> Result<()> {
    let backend = cli.backend()?;
    let cfg = cli.named_config(backend.as_ref())?;
    traffic_cmd(backend.as_ref(), &cfg, &cli.args)
}

fn cmd_bench_step(cli: &Cli) -> Result<()> {
    let backend = cli.backend()?;
    let cfg = cli.named_config(backend.as_ref())?;
    let sp = state_precision_from_args(&cli.args)?;
    bench_step(backend.as_ref(), &cfg, cli.args.usize_or("steps", 20), sp)
}

/// Harness shape for coordcheck/transfer: `--fast` picks the smoke
/// config; `--widths a,b,c` and `--steps N` override either.
fn harness_from_args(args: &Args) -> Result<transfer::HarnessConfig> {
    let mut hc = if args.has("fast") {
        transfer::HarnessConfig::smoke()
    } else {
        transfer::HarnessConfig::standard()
    };
    if let Some(ws) = args.get("widths") {
        let mut widths = ws
            .split(',')
            .map(|w| w.trim().parse::<usize>().map_err(|e| munit::err!("bad width '{w}': {e}")))
            .collect::<Result<Vec<_>>>()?;
        // the harness requires ascending unique widths (widths[0] is µS's
        // d_base and the shift statistics are signed smallest→largest)
        widths.sort_unstable();
        widths.dedup();
        hc.widths = widths;
    }
    if let Some(steps) = args.get("steps") {
        let steps: usize = steps.parse()?;
        hc.coord_steps = steps;
        hc.transfer_steps = steps;
    }
    Ok(hc)
}

fn cmd_coordcheck(cli: &Cli) -> Result<()> {
    let backend = cli.backend()?;
    let hc = harness_from_args(&cli.args)?;
    let report = transfer::coordcheck(backend.as_ref(), &hc)?;
    let text = transfer::coordcheck_table(&report);
    println!("{text}");
    save_report(&cli.results, "coordcheck.txt", &text)?;
    let json = transfer::coordcheck_json(&report);
    std::fs::write("REPORT_coordcheck.json", format!("{json}\n"))
        .context("writing REPORT_coordcheck.json")?;
    eprintln!("wrote REPORT_coordcheck.json");
    Ok(())
}

fn cmd_transfer(cli: &Cli) -> Result<()> {
    let backend = cli.backend()?;
    let hc = harness_from_args(&cli.args)?;
    let report = transfer::lr_transfer(backend.as_ref(), &hc)?;
    let text = transfer::transfer_table(&report);
    println!("{text}");
    save_report(&cli.results, "transfer.txt", &text)?;
    let json = transfer::transfer_json(&report);
    std::fs::write("REPORT_transfer.json", format!("{json}\n"))
        .context("writing REPORT_transfer.json")?;
    eprintln!("wrote REPORT_transfer.json");
    Ok(())
}

/// `munit verify-numerics`: static symbolic-RMS verification of the
/// scaling scheme (tentpole of the static-analysis layer). Runs the µS
/// and SP verifiers, the mutation self-tests, and a live per-width
/// cross-check of predictions against one traced training step; the
/// REPORT is written before failing so CI can inspect partial results.
fn cmd_verify_numerics(cli: &Cli) -> Result<()> {
    use munit::analysis::static_numerics as sn;
    use munit::util::json::Json;

    let mut spec = sn::VerifySpec::smoke();
    if let Some(ws) = cli.args.get("widths") {
        let mut widths = ws
            .split(',')
            .map(|w| w.trim().parse::<usize>().map_err(|e| munit::err!("bad width '{w}': {e}")))
            .collect::<Result<Vec<_>>>()?;
        // ascending unique: widths[0] is µS's d_base, flatness fits are
        // signed smallest→largest (same contract as coordcheck/transfer)
        widths.sort_unstable();
        widths.dedup();
        spec.widths = widths;
    }

    let mus = sn::verify(&spec, "mus")?;
    let sp = sn::verify(&spec, "sp")?;

    // self-tests: every deliberately corrupted rule set must trip a gate,
    // otherwise the verifier is vacuous
    let mut text = mus.table();
    text.push('\n');
    text.push_str(&sp.table());
    text.push_str("\nmutation self-tests (each corrupted rule set must be flagged):\n");
    let mut mutations: Vec<(&'static str, bool, String)> = Vec::new();
    for m in sn::MUTATIONS {
        let v = sn::verify_with(&spec, "mus", m)?;
        let flagged = !v.pass;
        let fired: Vec<&str> = v.checks.iter().filter(|c| !c.pass).map(|c| c.name).collect();
        text.push_str(&format!(
            "  {:<24} {} ({})\n",
            m.name(),
            if flagged { "flagged" } else { "MISSED" },
            if fired.is_empty() { "no check fired".into() } else { fired.join(", ") },
        ));
        mutations.push((m.name(), flagged, fired.join(",")));
    }

    // live cross-check: one traced µS step per width vs the predictions
    let backend = cli.backend()?;
    let mut crosses = Vec::new();
    text.push('\n');
    for &w in &spec.widths {
        let cfg = spec.model("mus", w)?;
        let pred = sn::predict(&cfg, spec.tau)?;
        let trainer = Trainer::new(backend.as_ref(), &cfg)?;
        let mut session = trainer.init(0)?;
        let mut batcher = Batcher::new(corpus_for(&cfg), 0, 0, 1, cfg.batch, cfg.seq_len);
        let tokens = batcher.next_batch();
        let (_, _, report) = session.step_traced(&tokens, 1.0 / 64.0, 0.0, spec.tau)?;
        let cc = sn::cross_check(&pred, &report);
        text.push_str(&cc.table());
        text.push('\n');
        crosses.push(cc);
    }

    let pass = mus.pass
        && sp.pass
        && crosses.iter().all(|c| c.pass)
        && mutations.iter().all(|(_, flagged, _)| *flagged);
    text.push_str(&format!("static numerics: {}\n", if pass { "PASS" } else { "FAIL" }));

    println!("{text}");
    save_report(&cli.results, "static_numerics.txt", &text)?;
    let json = Json::obj(vec![
        ("kind", Json::str("static_numerics")),
        (
            "spec",
            Json::obj(vec![
                ("widths", Json::Arr(spec.widths.iter().map(|&w| Json::num(w as f64)).collect())),
                ("depth", Json::num(spec.depth as f64)),
                ("head_dim", Json::num(spec.head_dim as f64)),
                ("vocab", Json::num(spec.vocab as f64)),
                ("seq_len", Json::num(spec.seq_len as f64)),
                ("batch", Json::num(spec.batch as f64)),
                ("tau", Json::num(spec.tau)),
            ]),
        ),
        ("mus", mus.to_json()),
        ("sp", sp.to_json()),
        ("cross_check", Json::Arr(crosses.iter().map(|c| c.to_json()).collect())),
        (
            "mutations",
            Json::Arr(
                mutations
                    .iter()
                    .map(|(name, flagged, fired)| {
                        Json::obj(vec![
                            ("mutation", Json::str(name)),
                            ("flagged", Json::Bool(*flagged)),
                            ("failed_checks", Json::str(fired)),
                        ])
                    })
                    .collect(),
            ),
        ),
        ("pass", Json::Bool(pass)),
    ]);
    std::fs::write("REPORT_static_numerics.json", format!("{json}\n"))
        .context("writing REPORT_static_numerics.json")?;
    eprintln!("wrote REPORT_static_numerics.json");
    if !pass {
        return Err(munit::err!("static numerics verification failed (see report above)"));
    }
    Ok(())
}

/// `munit lint`: determinism-contract scan of the Rust tree. Any
/// violation fails the command (the REPORT is written first).
fn cmd_lint(cli: &Cli) -> Result<()> {
    use munit::analysis::lint;
    use munit::util::json::Json;

    let root = if Path::new("rust/src").is_dir() {
        Path::new("rust/src")
    } else {
        Path::new("src")
    };
    let (files, violations) = lint::lint_tree(root)?;
    let mut text = format!(
        "determinism-contract lint: {} files under {} — {} violation(s)\n",
        files,
        root.display(),
        violations.len()
    );
    for v in &violations {
        text.push_str(&format!("  {:<18} {}:{}  {}\n", v.rule, v.file, v.line, v.excerpt));
    }
    if !violations.is_empty() {
        text.push_str("\nrules:\n");
        for r in &lint::RULES {
            text.push_str(&format!("  {:<18} {}\n", r.name, r.description));
        }
    }
    println!("{text}");
    save_report(&cli.results, "lint.txt", &text)?;
    let json = Json::obj(vec![
        ("kind", Json::str("lint")),
        ("files", Json::num(files as f64)),
        ("violations", Json::Arr(violations.iter().map(|v| v.to_json()).collect())),
        ("pass", Json::Bool(violations.is_empty())),
    ]);
    std::fs::write("REPORT_lint.json", format!("{json}\n"))
        .context("writing REPORT_lint.json")?;
    eprintln!("wrote REPORT_lint.json");
    if !violations.is_empty() {
        return Err(munit::err!(
            "{} determinism-contract violation(s)",
            violations.len()
        ));
    }
    Ok(())
}

/// Persist a text report under `results/reports/`.
fn save_report(results: &Path, file: &str, text: &str) -> Result<()> {
    std::fs::create_dir_all(results.join("reports"))?;
    std::fs::write(results.join("reports").join(file), text)?;
    Ok(())
}

/// Train `--steps` quick steps so generation isn't pure noise, then hand
/// the parameters to an `InferSession`.
fn infer_session_for(
    backend: &dyn Backend,
    cfg: &ModelConfig,
    args: &Args,
) -> Result<munit::runtime::InferSession> {
    let steps = args.usize_or("steps", 30);
    let tc = tc_from_args(args, cfg);
    let trainer = Trainer::new(backend, cfg)?;
    let mut session = trainer.init(tc.init_seed)?;
    let mut batcher = Batcher::new(corpus_for(cfg), tc.seed, 0, 1, cfg.batch, cfg.seq_len);
    eprintln!("pre-training {steps} steps on {}…", cfg.name());
    for step in 0..steps {
        let lr = tc.schedule.lr_at(tc.lr, step, steps);
        session.step(&batcher.next_batch(), lr, tc.wd, tc.tau)?;
    }
    let params = session.params_host()?;
    munit::runtime::InferSession::new(cfg, &params, tc.tau as f32)
}

/// `munit generate`: prefill a corpus prompt, decode autoregressively.
fn generate_cmd(backend: &dyn Backend, cfg: &ModelConfig, args: &Args) -> Result<()> {
    use munit::coordinator::serve::{generate_one, Sampling};
    let mut infer = infer_session_for(backend, cfg, args)?;
    let prompt_len =
        args.usize_or("prompt-len", (cfg.seq_len / 4).max(2)).clamp(1, cfg.seq_len - 1);
    let max_new =
        args.usize_or("new", cfg.seq_len / 2).clamp(1, cfg.seq_len - prompt_len);
    let topk = args.usize_or("topk", 0);
    let sampling = if topk > 1 {
        Sampling::TopK {
            k: topk,
            temperature: args.f64_or("temperature", 1.0) as f32,
            seed: args.usize_or("seed", 0) as u64,
        }
    } else {
        Sampling::Greedy
    };
    let mut batcher = Batcher::new(corpus_for(cfg), 1234, 7, 8, 1, prompt_len);
    let prompt = batcher.next_batch();
    let t0 = std::time::Instant::now();
    let out = generate_one(&mut infer, &prompt, max_new, None, sampling)?;
    let dt = t0.elapsed();
    println!("prompt ({} tokens):    {:?}", prompt.len(), prompt);
    println!("generated ({} tokens): {:?}", out.len(), out);
    let s = infer.stats();
    println!(
        "prefill: {} tokens in {:?} | decode: {} tokens in {:?} ({:.0} tok/s end-to-end)",
        s.prefill_tokens,
        s.prefill_time,
        s.decode_tokens,
        s.decode_time,
        out.len() as f64 / dt.as_secs_f64().max(1e-9),
    );
    Ok(())
}

/// `munit serve`: drain a synthetic request set through the
/// continuous-batching scheduler and print the latency table.
fn serve_cmd(backend: &dyn Backend, cfg: &ModelConfig, args: &Args) -> Result<()> {
    use munit::coordinator::serve;
    let mut infer = infer_session_for(backend, cfg, args)?;
    let n_requests = args.usize_or("requests", 8);
    let sc = serve::ServeConfig {
        max_batch: args.usize_or("max-batch", 4),
        ..Default::default()
    };
    let requests = serve::synthetic_requests(cfg, n_requests, args.usize_or("seed", 0) as u64);
    let report = serve::serve(&mut infer, &requests, &sc)?;
    println!(
        "served {} requests in {} steps ({:?} wall, mean batch occupancy {:.2})",
        report.completions.len(),
        report.steps,
        report.wall,
        report.mean_batch_occupancy
    );
    println!(
        "prefill {:.0} tok/s ({} tokens) | decode {:.0} tok/s ({} tokens)",
        report.prefill_tokens_per_sec,
        report.prefill_tokens,
        report.decode_tokens_per_sec,
        report.decode_tokens
    );
    print!("{}", serve::latency_table(&report));
    Ok(())
}

/// `munit traffic`: one Zipf/Poisson workload through the four serving
/// tiers (same request set, same pre-trained weights), summarized per
/// tier. The CLI face of the `BENCH_serve` harness.
fn traffic_cmd(backend: &dyn Backend, cfg: &ModelConfig, args: &Args) -> Result<()> {
    use munit::coordinator::serve::{serve, ServeConfig};
    use munit::coordinator::traffic::{self, TrafficConfig};
    use munit::runtime::KvStoreMode;
    let mut infer = infer_session_for(backend, cfg, args)?;
    let tc = TrafficConfig {
        n_requests: args.usize_or("requests", 32),
        arrival_rate: args.f64_or("rate", 1.5),
        prefix_pool: args.usize_or("prefix-pool", 4),
        zipf_s: args.f64_or("zipf", 1.2),
        prefix_len: args.usize_or("prefix-len", (cfg.seq_len / 3).max(1)),
        suffix_max: args.usize_or("suffix-max", (cfg.seq_len / 16).max(2)),
        max_new: args.usize_or("new", (cfg.seq_len / 16).max(2)),
        seed: args.usize_or("seed", 17) as u64,
    };
    let requests = traffic::generate(cfg, &tc)?;
    let max_batch = args.usize_or("max-batch", 4);
    let chunk = args.usize_or("chunk", 8).max(1);
    println!(
        "{} requests (rate {:.2}/step, {} prefixes, zipf {:.2}) on {}",
        requests.len(),
        tc.arrival_rate,
        tc.prefix_pool,
        tc.zipf_s,
        cfg.name()
    );
    let runs: [(&str, ServeConfig, KvStoreMode); 4] = [
        (
            "baseline",
            ServeConfig { max_batch, ..Default::default() },
            KvStoreMode::Bf16,
        ),
        (
            "prefix_cache",
            ServeConfig { max_batch, prefix_cache: true, ..Default::default() },
            KvStoreMode::Bf16,
        ),
        (
            "chunked_prefill",
            ServeConfig { max_batch, prefill_chunk: Some(chunk), ..Default::default() },
            KvStoreMode::Bf16,
        ),
        (
            "fp8_kv_all",
            ServeConfig {
                max_batch,
                prefix_cache: true,
                prefill_chunk: Some(chunk),
                kv_trim_slabs: Some(0),
                ..Default::default()
            },
            KvStoreMode::Fp8E4m3,
        ),
    ];
    for (label, sc, mode) in runs {
        // the mode switch also resets the pool, so per-tier KV
        // accounting (high-water, health) starts clean
        infer.set_kv_store_mode(mode)?;
        let report = serve(&mut infer, &requests, &sc)?;
        print!("{}", traffic::summary_table(label, &traffic::assess(&report)));
        if mode == KvStoreMode::Fp8E4m3 {
            let h = infer.fp8_kv_health();
            println!(
                "    fp8 kv casts {} (saturated {}, underflowed-to-zero {})",
                h.total, h.saturated, h.underflow_to_zero
            );
        }
    }
    Ok(())
}

fn parse_range(s: &str) -> Result<(i32, i32)> {
    let (a, b) = s.split_once(':').context("expected lo:hi")?;
    Ok((a.parse()?, b.parse()?))
}

/// Parse `--state-precision f32|fp8` (default f32, the bit-compat lane).
fn state_precision_from_args(args: &Args) -> Result<StatePrecision> {
    let name = args.get("state-precision").unwrap_or("f32");
    StatePrecision::by_name(name)
        .with_context(|| format!("unknown state precision '{name}' (f32|fp8)"))
}

fn tc_from_args(args: &Args, cfg: &ModelConfig) -> TrainConfig {
    let default_lr = if cfg.variant == "mus" { 1.0 / 64.0 } else { 1.0 / 256.0 };
    let mut tc = proxy_tc(
        args.usize_or("steps", 100),
        args.f64_or("lr", default_lr),
        args.f64_or("wd", 2.0 / 16384.0),
        args.f64_or("tau", recommended_tau(cfg.depth)),
        args.usize_or("seed", 0) as u64,
    );
    tc.init_seed = args.usize_or("init-seed", 0) as i32;
    tc
}

fn dispatch_repro(ctx: &Ctx, which: &str) -> Result<String> {
    use munit::repro::{figures as f, tables as t};
    match which {
        "fig2" => f::fig2(ctx),
        "fig3" => f::fig3(ctx),
        "fig4b" => f::fig4b(ctx),
        "fig5" => f::fig5(ctx),
        "fig6" => f::fig6(ctx),
        "fig7" => f::fig7(ctx),
        "fig8" => f::fig8(ctx),
        "fig9" => f::fig9(ctx),
        "fig10" => f::fig10(ctx),
        "fig11" => f::fig11(ctx),
        "fig12" => f::fig12(ctx),
        "table2" => t::table2(ctx),
        "table3" | "fig1" => t::table3(ctx),
        "table4" => t::table4(ctx),
        "table5" => t::table5(ctx),
        "all" => {
            let mut out = String::new();
            for w in [
                "table3", "table2", "table4", "fig2", "fig3", "fig4b", "fig5", "fig6",
                "fig7", "fig8", "fig9", "fig10", "fig11", "fig12", "table5",
            ] {
                eprintln!("== {w} ==");
                out.push_str(&dispatch_repro(ctx, w)?);
                out.push('\n');
            }
            Ok(out)
        }
        other => Err(munit::err!("unknown figure/table '{other}'")),
    }
}

/// Headline end-to-end driver: µS FP8 vs µS BF16 on the e2e model
/// (w384 d6, ~12M params — the CPU-feasible stand-in for the paper's 1B+;
/// see DESIGN.md substitution table).
fn e2e(ctx: &Ctx, steps: usize) -> Result<String> {
    let cfg8 = ModelConfig {
        width: 384,
        depth: 6,
        head_dim: 64,
        vocab: 2048,
        seq_len: 256,
        batch: 8,
        ..ModelConfig::default()
    };
    let cfg16 = ModelConfig { precision: "bf16".into(), ..cfg8.clone() };
    let tau = recommended_tau(cfg8.depth);
    let tc = proxy_tc(steps, 1.0 / 64.0, 2.0 / 16384.0, tau, 42);
    eprintln!("e2e: training µS FP8 ({} params) for {steps} steps…", cfg8.n_params());
    let (r8, state8) = repro::train_with_state(ctx, &cfg8, &tc)?;
    eprintln!("e2e: training µS BF16 baseline…");
    let r16 = repro::train_cached(ctx, &cfg16, &tc)?;
    let corpus = corpus_for(&cfg8);
    let ev = munit::eval::evaluate(ctx.backend(), &cfg8, state8.params(), tau, &corpus, 3, 7)?;
    // training-inference numerics match: NLL scored through the KV-cache
    // decode path must equal NLL from the full forward (bit-exact under
    // the µS static-FP8 plan)
    let mut infer = munit::runtime::InferSession::new(&cfg8, state8.params(), tau as f32)?;
    let mut held_out = Batcher::new(corpus.clone(), 99, 7, 8, 1, cfg8.seq_len);
    let seq_toks = held_out.next_batch();
    let via_fwd = {
        let id = infer.add_sequence();
        let logits = infer.prefill(id, &seq_toks)?;
        let r = munit::eval::fwd_nll(&cfg8, &logits, &seq_toks)?;
        infer.free_sequence(id)?;
        r
    };
    let via_decode = munit::eval::decode_nll(&mut infer, &seq_toks)?;
    let bucket = (steps / 12).max(1);
    let mut curve = String::new();
    for (i, chunk) in r8.losses.chunks(bucket).enumerate() {
        let mean: f32 = chunk.iter().sum::<f32>() / chunk.len() as f32;
        curve.push_str(&format!("  step {:>5}  fp8 {:.4}\n", i * bucket, mean));
    }
    Ok(format!(
        "E2E — µS FP8 end-to-end training ({} params, {} tokens)\n\
         loss curve (mean per bucket):\n{curve}\
         final loss: FP8 {:.4} vs BF16 {:.4} (rel. conv. error {:+.3}%)\n\
         spikes: fp8 {}, bf16 {} | diverged: {} / {}\n\
         throughput (this CPU): {:.0} tok/s\n\
         eval (FP8 weights+activations, W8A8-analog inference):\n\
         \u{20}\u{20}next-token acc {:.1}% | NLL {:.3} | cloze {:.1}% | repeat {:.1}% | induction {:.1}%\n\
         training-inference match: NLL via fwd {:.6} vs via KV-cache decode {:.6} (bit-equal: {})\n",
        cfg8.n_params(),
        steps * cfg8.batch * cfg8.seq_len,
        r8.final_loss,
        r16.final_loss,
        (r8.final_loss - r16.final_loss) / r16.final_loss * 100.0,
        r8.spikes,
        r16.spikes,
        r8.diverged,
        r16.diverged,
        r8.tokens_per_sec,
        ev.next_token_acc * 100.0,
        ev.avg_nll,
        ev.bigram_cloze_acc * 100.0,
        ev.repeat_acc * 100.0,
        ev.induction_acc * 100.0,
        via_fwd,
        via_decode,
        via_fwd.to_bits() == via_decode.to_bits(),
    ))
}

/// Per-step latency + host-transfer breakdown (L3 perf tooling). The
/// transfer column is the Session's per-step accounting: tokens in,
/// loss/gnorm out — full state never crosses the host boundary.
fn bench_step(
    backend: &dyn Backend,
    cfg: &ModelConfig,
    steps: usize,
    sp: StatePrecision,
) -> Result<()> {
    let trainer = Trainer::with_state_precision(backend, cfg, sp)?;
    let mut session = trainer.init(0)?;
    let mut batcher = Batcher::new(corpus_for(cfg), 0, 0, 1, cfg.batch, cfg.seq_len);
    // warmup (includes any artifact compile)
    let tokens = batcher.next_batch();
    session.step(&tokens, 1e-3, 1e-4, 0.3)?;
    let t0 = std::time::Instant::now();
    let mut gen_time = std::time::Duration::ZERO;
    for _ in 0..steps {
        let tg = std::time::Instant::now();
        let tokens = batcher.next_batch();
        gen_time += tg.elapsed();
        session.step(&tokens, 1e-3, 1e-4, 0.3)?;
    }
    let total = t0.elapsed();
    let s = session.stats().clone();
    let compile = backend
        .stats(trainer.train_artifact())
        .map(|a| a.compile_time)
        .unwrap_or_default();
    println!("config: {} ({} params)", cfg.name(), cfg.n_params());
    println!("steps: {steps}  total {:?}  per-step {:?}", total, total / steps as u32);
    println!(
        "  execute       {:?}/step\n  host-transfer {:?}/step ({} bytes/step)\n  data-gen      {:?}/step\n  compile       {:?} (once)",
        s.per_call_execute(),
        s.per_call_transfer(),
        s.transfer_bytes / s.calls.max(1) as u64,
        gen_time / steps as u32,
        compile
    );
    println!(
        "  state: {} ({} bytes = {:.1} B/param)",
        sp.label(),
        s.state_bytes,
        s.state_bytes_per_param
    );
    println!(
        "  tokens/s: {:.0}",
        (steps * cfg.batch * cfg.seq_len) as f64 / total.as_secs_f64()
    );
    Ok(())
}
