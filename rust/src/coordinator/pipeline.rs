//! Background data pipeline: batch synthesis off the step critical path.
//!
//! A producer thread runs the deterministic `Batcher` and pushes batches
//! into a bounded channel (`sync_channel`), giving natural backpressure:
//! the producer stalls when `depth` batches are queued. The trainer then
//! overlaps token generation with artifact execution — the same structure
//! a real ingestion pipeline (paper: MosaicML Streaming) has.

use std::sync::mpsc::{sync_channel, Receiver};
use std::thread::JoinHandle;

use crate::data::{Batcher, CorpusSpec};

/// Handle to a background batch producer (bounded queue + thread).
pub struct DataPipeline {
    rx: Receiver<Vec<i32>>,
    handle: Option<JoinHandle<()>>,
    tokens_per_batch: usize,
}

impl DataPipeline {
    /// Spawn a producer for `total` batches (None = unbounded) with a
    /// queue depth of `depth`.
    pub fn spawn(
        spec: CorpusSpec,
        seed: u64,
        shard: usize,
        n_shards: usize,
        batch: usize,
        seq_len: usize,
        depth: usize,
        total: Option<usize>,
    ) -> DataPipeline {
        let (tx, rx) = sync_channel(depth.max(1));
        let tokens_per_batch = batch * seq_len;
        let handle = std::thread::spawn(move || {
            let mut b = Batcher::new(spec, seed, shard, n_shards, batch, seq_len);
            let mut produced = 0usize;
            loop {
                if let Some(t) = total {
                    if produced >= t {
                        break;
                    }
                }
                let batch = b.next_batch();
                if tx.send(batch).is_err() {
                    break; // consumer dropped
                }
                produced += 1;
            }
        });
        DataPipeline { rx, handle: Some(handle), tokens_per_batch }
    }

    /// Blocking fetch of the next batch (None when the producer finished).
    pub fn next(&self) -> Option<Vec<i32>> {
        self.rx.recv().ok()
    }

    /// Tokens per produced batch (`batch * seq_len`).
    pub fn tokens_per_batch(&self) -> usize {
        self.tokens_per_batch
    }
}

impl Drop for DataPipeline {
    fn drop(&mut self) {
        // closing rx unblocks the producer's send; then join
        if let Some(h) = self.handle.take() {
            // drain quickly so a blocked producer can observe the hangup
            while self.rx.try_recv().is_ok() {}
            drop(std::mem::replace(&mut self.rx, {
                let (_tx, rx) = sync_channel(1);
                rx
            }));
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn produces_identical_batches_to_direct_batcher() {
        let spec = CorpusSpec::default();
        let pipe = DataPipeline::spawn(spec.clone(), 9, 0, 1, 2, 32, 4, Some(5));
        let mut direct = Batcher::new(spec, 9, 0, 1, 2, 32);
        for _ in 0..5 {
            assert_eq!(pipe.next().unwrap(), direct.next_batch());
        }
        assert!(pipe.next().is_none());
    }

    #[test]
    fn bounded_queue_applies_backpressure() {
        // with depth 2 and a slow consumer, the producer can be at most
        // depth+1 batches ahead; after consuming everything we still get
        // exactly `total` batches.
        let pipe = DataPipeline::spawn(CorpusSpec::default(), 1, 0, 1, 1, 16, 2, Some(10));
        std::thread::sleep(std::time::Duration::from_millis(50));
        let mut n = 0;
        while pipe.next().is_some() {
            n += 1;
        }
        assert_eq!(n, 10);
    }

    #[test]
    fn drop_mid_stream_terminates_producer() {
        let pipe = DataPipeline::spawn(CorpusSpec::default(), 2, 0, 1, 1, 16, 1, None);
        let _ = pipe.next();
        drop(pipe); // must not hang
    }
}
