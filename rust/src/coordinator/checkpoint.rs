//! Binary checkpointing for `TrainState`.
//!
//! Own format (no serde offline): little-endian, versioned, with tensor
//! names + shapes so loads are validated against the manifest ABI.
//!
//! ```text
//! magic "MUSCKPT1" | u32 n_tensors | n_tensors x {
//!     u32 name_len | name bytes | u32 ndim | u64 dims... | f32 data... }
//! ```

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use crate::bail;
use crate::coordinator::trainer::TrainState;
use crate::runtime::{Tensor, TensorSpec};
use crate::util::error::{Context, Result};

const MAGIC: &[u8; 8] = b"MUSCKPT1";

/// Serialize a state. `specs` supplies names/shapes (params then momentum,
/// as in the train artifact's input list).
pub fn save(path: &Path, state: &TrainState, specs: &[TensorSpec]) -> Result<()> {
    if specs.len() != state.tensors.len() {
        bail!("{} specs for {} tensors", specs.len(), state.tensors.len());
    }
    let f = File::create(path).with_context(|| format!("creating {}", path.display()))?;
    let mut w = BufWriter::new(f);
    w.write_all(MAGIC)?;
    w.write_all(&(specs.len() as u32).to_le_bytes())?;
    for (spec, tensor) in specs.iter().zip(&state.tensors) {
        let data = tensor.as_f32().with_context(|| format!("tensor {}", spec.name))?;
        if data.len() != spec.elements() {
            bail!("tensor {}: {} elements, spec says {}", spec.name, data.len(), spec.elements());
        }
        w.write_all(&(spec.name.len() as u32).to_le_bytes())?;
        w.write_all(spec.name.as_bytes())?;
        w.write_all(&(spec.shape.len() as u32).to_le_bytes())?;
        for &d in &spec.shape {
            w.write_all(&(d as u64).to_le_bytes())?;
        }
        // bulk f32 write
        let bytes: &[u8] = unsafe {
            std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
        };
        w.write_all(bytes)?;
    }
    w.flush()?;
    Ok(())
}

/// Load a checkpoint, validating names/shapes against `specs`.
pub fn load(path: &Path, specs: &[TensorSpec]) -> Result<TrainState> {
    let f = File::open(path).with_context(|| format!("opening {}", path.display()))?;
    let mut r = BufReader::new(f);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("{} is not a µS checkpoint", path.display());
    }
    let n = read_u32(&mut r)? as usize;
    if n != specs.len() {
        bail!("checkpoint has {n} tensors, expected {}", specs.len());
    }
    let mut tensors = Vec::with_capacity(n);
    for spec in specs {
        let name_len = read_u32(&mut r)? as usize;
        let mut name = vec![0u8; name_len];
        r.read_exact(&mut name)?;
        let name = String::from_utf8(name)?;
        if name != spec.name {
            bail!("tensor order mismatch: got {name}, expected {}", spec.name);
        }
        let ndim = read_u32(&mut r)? as usize;
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            let mut b = [0u8; 8];
            r.read_exact(&mut b)?;
            shape.push(u64::from_le_bytes(b) as usize);
        }
        if shape != spec.shape {
            bail!("tensor {name}: shape {shape:?}, expected {:?}", spec.shape);
        }
        let count: usize = shape.iter().product();
        let mut data = vec![0f32; count];
        let bytes: &mut [u8] = unsafe {
            std::slice::from_raw_parts_mut(data.as_mut_ptr() as *mut u8, count * 4)
        };
        r.read_exact(bytes)?;
        tensors.push(Tensor::f32(data, &shape)?);
    }
    Ok(TrainState { n_params: n / 2, tensors })
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}
