//! Binary checkpointing for `TrainState`.
//!
//! Own format (no serde offline): little-endian, versioned, with tensor
//! names + shapes so loads are validated against the manifest ABI.
//!
//! ```text
//! magic "MUSCKPT1" | u32 n_tensors | n_tensors x {
//!     u32 name_len | name bytes | u32 ndim | u64 dims... | f32 data... }
//! ```
//!
//! Sharded (tensor-parallel) runs use a container that embeds one state
//! block per rank plus the shard geometry, so a resume under a
//! different `ShardSpec` is rejected up front instead of producing a
//! silently re-partitioned run:
//!
//! ```text
//! magic "MUSSHRD1" | u32 tp | u32 stages | u32 step | u32 n_ranks |
//!     n_ranks x { u32 n_tensors | tensors... }
//! ```
//!
//! **Version 2** stores state in its *native* [`StatePrecision`] instead
//! of always-f32 payloads: under FP8 state, masters serialize as BF16
//! bit patterns (2 B/elem) and momenta as E4M3 bytes with one i32
//! power-of-two scale exponent per tensor (1 B/elem + 4 B) — about half
//! the v1 file. Because a session's FP8 state is already *on-grid*
//! (values lie exactly on the BF16 / scaled-E4M3 grids), the v2
//! round-trip is bit-exact. A per-tensor codec byte keeps the format
//! self-describing; [`load`] / [`load_sharded`] dispatch on the magic, so
//! v1 files remain loadable forever:
//!
//! ```text
//! magic "MUSCKPT2" | u8 precision | u32 n_tensors | n_tensors x {
//!     u32 name_len | name bytes | u32 ndim | u64 dims... |
//!     u8 codec | payload }
//! codec 0 = f32 raw | 1 = bf16 u16 bits | 2 = i32 scale_exp + e4m3 u8
//!
//! magic "MUSSHRD2" | u8 precision | u32 tp | u32 stages | u32 step |
//!     u32 n_ranks | n_ranks x v2 state block
//! ```

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use crate::bail;
use crate::coordinator::trainer::TrainState;
use crate::runtime::state::{self, StatePrecision};
use crate::runtime::{Tensor, TensorSpec};
use crate::util::error::{Context, Result};

const MAGIC: &[u8; 8] = b"MUSCKPT1";
const SHARD_MAGIC: &[u8; 8] = b"MUSSHRD1";
const MAGIC2: &[u8; 8] = b"MUSCKPT2";
const SHARD_MAGIC2: &[u8; 8] = b"MUSSHRD2";

/// Per-tensor payload encodings of the v2 format.
const CODEC_F32: u8 = 0;
const CODEC_BF16: u8 = 1;
const CODEC_E4M3: u8 = 2;

/// Write one state block (`u32 n_tensors` + named tensors) to `w`.
/// `specs` supplies names/shapes (params then momenta, as in the train
/// artifact's input list).
fn write_state(w: &mut impl Write, state: &TrainState, specs: &[TensorSpec]) -> Result<()> {
    if specs.len() != state.tensors.len() {
        bail!("{} specs for {} tensors", specs.len(), state.tensors.len());
    }
    w.write_all(&(specs.len() as u32).to_le_bytes())?;
    for (spec, tensor) in specs.iter().zip(&state.tensors) {
        let data = tensor.as_f32().with_context(|| format!("tensor {}", spec.name))?;
        if data.len() != spec.elements() {
            bail!("tensor {}: {} elements, spec says {}", spec.name, data.len(), spec.elements());
        }
        w.write_all(&(spec.name.len() as u32).to_le_bytes())?;
        w.write_all(spec.name.as_bytes())?;
        w.write_all(&(spec.shape.len() as u32).to_le_bytes())?;
        for &d in &spec.shape {
            w.write_all(&(d as u64).to_le_bytes())?;
        }
        // bulk f32 write
        let bytes: &[u8] = unsafe {
            std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
        };
        w.write_all(bytes)?;
    }
    Ok(())
}

/// Read one state block from `r`, validating names/shapes against
/// `specs` (same order contract as [`write_state`]).
fn read_state(r: &mut impl Read, specs: &[TensorSpec]) -> Result<TrainState> {
    let n = read_u32(r)? as usize;
    if n != specs.len() {
        bail!("checkpoint has {n} tensors, expected {}", specs.len());
    }
    let mut tensors = Vec::with_capacity(n);
    for spec in specs {
        let name_len = read_u32(r)? as usize;
        let mut name = vec![0u8; name_len];
        r.read_exact(&mut name)?;
        let name = String::from_utf8(name)?;
        if name != spec.name {
            bail!("tensor order mismatch: got {name}, expected {}", spec.name);
        }
        let ndim = read_u32(r)? as usize;
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            let mut b = [0u8; 8];
            r.read_exact(&mut b)?;
            shape.push(u64::from_le_bytes(b) as usize);
        }
        if shape != spec.shape {
            bail!("tensor {name}: shape {shape:?}, expected {:?}", spec.shape);
        }
        let count: usize = shape.iter().product();
        let mut data = vec![0f32; count];
        let bytes: &mut [u8] = unsafe {
            std::slice::from_raw_parts_mut(data.as_mut_ptr() as *mut u8, count * 4)
        };
        r.read_exact(bytes)?;
        tensors.push(Tensor::f32(data, &shape)?);
    }
    Ok(TrainState { n_params: n / 2, tensors })
}

/// The v2 codec byte for tensor `idx` of a state under `precision`
/// (params then momenta, as in the train artifact's input list).
fn codec_for(precision: StatePrecision, idx: usize, n_params: usize) -> u8 {
    match precision {
        StatePrecision::F32 => CODEC_F32,
        StatePrecision::Fp8 if idx < n_params => CODEC_BF16,
        StatePrecision::Fp8 => CODEC_E4M3,
    }
}

/// Write one v2 state block (`u32 n_tensors` + named tensors with a
/// per-tensor codec byte) to `w`. Momentum scale exponents are
/// re-derived from each tensor's amax at encode time — on-grid data
/// (what sessions hold) reproduces the live scale, so no side channel
/// is needed.
fn write_state_v2(
    w: &mut impl Write,
    state: &TrainState,
    specs: &[TensorSpec],
    precision: StatePrecision,
) -> Result<()> {
    if specs.len() != state.tensors.len() {
        bail!("{} specs for {} tensors", specs.len(), state.tensors.len());
    }
    w.write_all(&(specs.len() as u32).to_le_bytes())?;
    for (idx, (spec, tensor)) in specs.iter().zip(&state.tensors).enumerate() {
        let data = tensor.as_f32().with_context(|| format!("tensor {}", spec.name))?;
        if data.len() != spec.elements() {
            bail!("tensor {}: {} elements, spec says {}", spec.name, data.len(), spec.elements());
        }
        w.write_all(&(spec.name.len() as u32).to_le_bytes())?;
        w.write_all(spec.name.as_bytes())?;
        w.write_all(&(spec.shape.len() as u32).to_le_bytes())?;
        for &d in &spec.shape {
            w.write_all(&(d as u64).to_le_bytes())?;
        }
        let codec = codec_for(precision, idx, state.n_params);
        w.write_all(&[codec])?;
        match codec {
            CODEC_BF16 => {
                let mut bytes = Vec::with_capacity(data.len() * 2);
                for &x in data {
                    bytes.extend_from_slice(&state::encode_master(x).to_le_bytes());
                }
                w.write_all(&bytes)?;
            }
            CODEC_E4M3 => {
                let (scale_exp, bytes) = state::encode_momentum(data);
                w.write_all(&scale_exp.to_le_bytes())?;
                w.write_all(&bytes)?;
            }
            _ => {
                // bulk f32 write (same layout as v1)
                let bytes: &[u8] = unsafe {
                    std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
                };
                w.write_all(bytes)?;
            }
        }
    }
    Ok(())
}

/// Read one v2 state block from `r`, validating names/shapes against
/// `specs` and decoding each tensor's codec back to f32 host tensors.
fn read_state_v2(r: &mut impl Read, specs: &[TensorSpec]) -> Result<TrainState> {
    let n = read_u32(r)? as usize;
    if n != specs.len() {
        bail!("checkpoint has {n} tensors, expected {}", specs.len());
    }
    let mut tensors = Vec::with_capacity(n);
    for spec in specs {
        let name_len = read_u32(r)? as usize;
        if name_len > 4096 {
            bail!("tensor name length {name_len} is implausible (corrupt header?)");
        }
        let mut name = vec![0u8; name_len];
        r.read_exact(&mut name)
            .with_context(|| format!("reading name of tensor '{}' (truncated?)", spec.name))?;
        let name = String::from_utf8(name)?;
        if name != spec.name {
            bail!("tensor order mismatch: got {name}, expected {}", spec.name);
        }
        let ndim = read_u32(r)? as usize;
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            let mut b = [0u8; 8];
            r.read_exact(&mut b)
                .with_context(|| format!("reading shape of tensor '{name}' (truncated?)"))?;
            shape.push(u64::from_le_bytes(b) as usize);
        }
        if shape != spec.shape {
            bail!("tensor {name}: shape {shape:?}, expected {:?}", spec.shape);
        }
        let count: usize = shape.iter().product();
        let mut codec = [0u8; 1];
        r.read_exact(&mut codec)
            .with_context(|| format!("reading codec byte of tensor '{name}' (truncated?)"))?;
        let data = match codec[0] {
            CODEC_F32 => {
                let mut data = vec![0f32; count];
                let bytes: &mut [u8] = unsafe {
                    std::slice::from_raw_parts_mut(data.as_mut_ptr() as *mut u8, count * 4)
                };
                r.read_exact(bytes)
                    .with_context(|| format!("reading f32 payload of tensor '{name}'"))?;
                data
            }
            CODEC_BF16 => {
                let mut bytes = vec![0u8; count * 2];
                r.read_exact(&mut bytes)
                    .with_context(|| format!("reading bf16 payload of tensor '{name}'"))?;
                bytes
                    .chunks_exact(2)
                    .map(|c| state::decode_master(u16::from_le_bytes([c[0], c[1]])))
                    .collect()
            }
            CODEC_E4M3 => {
                let mut b = [0u8; 4];
                r.read_exact(&mut b)
                    .with_context(|| format!("reading e4m3 scale of tensor '{name}'"))?;
                let scale_exp = i32::from_le_bytes(b);
                if !(-126..=120).contains(&scale_exp) {
                    bail!("tensor {name}: e4m3 scale exp {scale_exp} out of range [-126, 120]");
                }
                let mut bytes = vec![0u8; count];
                r.read_exact(&mut bytes)
                    .with_context(|| format!("reading e4m3 payload of tensor '{name}'"))?;
                state::decode_momentum(scale_exp, &bytes)
            }
            c => bail!("tensor {name}: unknown v2 codec byte {c}"),
        };
        tensors.push(Tensor::f32(data, &shape)?);
    }
    Ok(TrainState { n_params: n / 2, tensors })
}

/// Read + validate a v2 precision byte (0 = f32, 1 = fp8).
fn read_precision(r: &mut impl Read, path: &Path) -> Result<StatePrecision> {
    let mut b = [0u8; 1];
    r.read_exact(&mut b)
        .with_context(|| format!("reading precision byte of {} (truncated?)", path.display()))?;
    match b[0] {
        0 => Ok(StatePrecision::F32),
        1 => Ok(StatePrecision::Fp8),
        p => bail!("{}: unknown state-precision byte {p} (file corrupt?)", path.display()),
    }
}

/// Serialize a state. `specs` supplies names/shapes (params then momentum,
/// as in the train artifact's input list).
pub fn save(path: &Path, state: &TrainState, specs: &[TensorSpec]) -> Result<()> {
    let f = File::create(path).with_context(|| format!("creating {}", path.display()))?;
    let mut w = BufWriter::new(f);
    w.write_all(MAGIC)?;
    write_state(&mut w, state, specs)?;
    w.flush()?;
    Ok(())
}

/// Serialize a state in the v2 format, storing tensors in their native
/// `precision` (f32 raw, or BF16 masters + scaled-E4M3 momenta — about
/// half the v1 size). Bit-exact round-trip when the state is on-grid,
/// i.e. produced by a session running under the same policy.
pub fn save_v2(
    path: &Path,
    state: &TrainState,
    specs: &[TensorSpec],
    precision: StatePrecision,
) -> Result<()> {
    let f = File::create(path).with_context(|| format!("creating {}", path.display()))?;
    let mut w = BufWriter::new(f);
    w.write_all(MAGIC2)?;
    w.write_all(&[precision as u8])?;
    write_state_v2(&mut w, state, specs, precision)?;
    w.flush()?;
    Ok(())
}

/// Load a checkpoint, validating names/shapes against `specs`. Both the
/// v1 (`MUSCKPT1`, always-f32) and v2 (`MUSCKPT2`, native-precision)
/// formats load through this one entry point — the magic selects the
/// decoder, and v2 payloads are decoded back to f32 host tensors.
pub fn load(path: &Path, specs: &[TensorSpec]) -> Result<TrainState> {
    let f = File::open(path).with_context(|| format!("opening {}", path.display()))?;
    let mut r = BufReader::new(f);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)
        .with_context(|| format!("reading magic of {} (truncated?)", path.display()))?;
    if &magic == MAGIC {
        return read_state(&mut r, specs);
    }
    if &magic == MAGIC2 {
        let _precision = read_precision(&mut r, path)?;
        return read_state_v2(&mut r, specs)
            .with_context(|| format!("loading v2 checkpoint {}", path.display()));
    }
    bail!("{} is not a µS checkpoint", path.display());
}

/// Serialize a sharded run: one state block per TP rank plus the shard
/// geometry (`tp`, `stages`) and the step the checkpoint was taken at.
/// `specs_per_rank[r]` names rank r's tensors (shard-suffixed).
pub fn save_sharded(
    path: &Path,
    shards: &[TrainState],
    specs_per_rank: &[Vec<TensorSpec>],
    tp: u32,
    stages: u32,
    step: u32,
) -> Result<()> {
    if shards.len() != specs_per_rank.len() || shards.len() != tp as usize {
        bail!("{} shard states / {} spec sets for tp={tp}", shards.len(), specs_per_rank.len());
    }
    let f = File::create(path).with_context(|| format!("creating {}", path.display()))?;
    let mut w = BufWriter::new(f);
    w.write_all(SHARD_MAGIC)?;
    for v in [tp, stages, step, shards.len() as u32] {
        w.write_all(&v.to_le_bytes())?;
    }
    for (state, specs) in shards.iter().zip(specs_per_rank) {
        write_state(&mut w, state, specs)?;
    }
    w.flush()?;
    Ok(())
}

/// [`save_sharded`] in the v2 format: rank blocks store their tensors in
/// native `precision` (see [`save_v2`]), roughly halving the file under
/// FP8 state.
pub fn save_sharded_v2(
    path: &Path,
    shards: &[TrainState],
    specs_per_rank: &[Vec<TensorSpec>],
    tp: u32,
    stages: u32,
    step: u32,
    precision: StatePrecision,
) -> Result<()> {
    if shards.len() != specs_per_rank.len() || shards.len() != tp as usize {
        bail!("{} shard states / {} spec sets for tp={tp}", shards.len(), specs_per_rank.len());
    }
    let f = File::create(path).with_context(|| format!("creating {}", path.display()))?;
    let mut w = BufWriter::new(f);
    w.write_all(SHARD_MAGIC2)?;
    w.write_all(&[precision as u8])?;
    for v in [tp, stages, step, shards.len() as u32] {
        w.write_all(&v.to_le_bytes())?;
    }
    for (state, specs) in shards.iter().zip(specs_per_rank) {
        write_state_v2(&mut w, state, specs, precision)?;
    }
    w.flush()?;
    Ok(())
}

/// Load a sharded checkpoint, rejecting a geometry mismatch: the file's
/// `(tp, stages)` must equal the requested ones — resuming under a
/// different `ShardSpec` requires an explicit repartition via a full
/// (unsharded) checkpoint, not a silent reinterpretation of rank blobs.
/// Returns the per-rank states and the saved step count.
pub fn load_sharded(
    path: &Path,
    specs_per_rank: &[Vec<TensorSpec>],
    tp: u32,
    stages: u32,
) -> Result<(Vec<TrainState>, u32)> {
    let f = File::open(path).with_context(|| format!("opening {}", path.display()))?;
    let mut r = BufReader::new(f);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)
        .with_context(|| format!("reading magic of {} (truncated?)", path.display()))?;
    let v2 = &magic == SHARD_MAGIC2;
    if !v2 && &magic != SHARD_MAGIC {
        bail!("{} is not a sharded µS checkpoint", path.display());
    }
    if v2 {
        let _precision = read_precision(&mut r, path)?;
    }
    let (file_tp, file_stages) = (read_u32(&mut r)?, read_u32(&mut r)?);
    let (step, n_ranks) = (read_u32(&mut r)?, read_u32(&mut r)?);
    if file_tp != tp || file_stages != stages {
        bail!(
            "{} was saved with tp={file_tp}, stages={file_stages}; cannot resume under \
             tp={tp}, stages={stages} (repartition via a full checkpoint instead)",
            path.display()
        );
    }
    if n_ranks as usize != specs_per_rank.len() {
        bail!("checkpoint has {n_ranks} ranks, expected {}", specs_per_rank.len());
    }
    let mut shards = Vec::with_capacity(n_ranks as usize);
    for specs in specs_per_rank {
        shards.push(if v2 { read_state_v2(&mut r, specs)? } else { read_state(&mut r, specs)? });
    }
    Ok((shards, step))
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Dtype;
    use crate::util::rng::Rng;

    fn spec(name: &str, shape: &[usize]) -> TensorSpec {
        TensorSpec { name: name.to_string(), shape: shape.to_vec(), dtype: Dtype::F32 }
    }

    /// A 2-tensor (1 param + 1 momentum) on-grid state: masters on the
    /// BF16 grid, momenta on the scaled-E4M3 grid — what a session
    /// running under FP8 state actually holds.
    fn on_grid_state(count: usize, seed: u64) -> (TrainState, Vec<TensorSpec>) {
        let mut rng = Rng::new(seed);
        let mut w = vec![0f32; count];
        let mut m = vec![0f32; count];
        rng.fill_normal(&mut w, 1.0);
        rng.fill_normal(&mut m, 0.02);
        state::snap_master(&mut w);
        state::snap_momentum(&mut m);
        let specs = vec![spec("w", &[count]), spec("m_w", &[count])];
        let tensors = vec![
            Tensor::f32(w, &[count]).unwrap(),
            Tensor::f32(m, &[count]).unwrap(),
        ];
        (TrainState { n_params: 1, tensors }, specs)
    }

    fn bits_of(state: &TrainState) -> Vec<Vec<u32>> {
        state
            .tensors
            .iter()
            .map(|t| t.as_f32().unwrap().iter().map(|x| x.to_bits()).collect())
            .collect()
    }

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("munit_ckpt_v2_{name}.bin"))
    }

    #[test]
    fn v2_roundtrips_bit_exact_for_both_precisions() {
        for (precision, tag) in [(StatePrecision::F32, "f32"), (StatePrecision::Fp8, "fp8")] {
            let (state, specs) = on_grid_state(33, 7);
            let path = tmp(&format!("rt_{tag}"));
            save_v2(&path, &state, &specs, precision).unwrap();
            let loaded = load(&path, &specs).unwrap();
            assert_eq!(loaded.n_params, 1);
            assert_eq!(bits_of(&loaded), bits_of(&state), "{tag} round-trip not bit-exact");
            std::fs::remove_file(&path).ok();
        }
    }

    #[test]
    fn v2_fp8_file_is_less_than_half_the_v1_size() {
        let (state, specs) = on_grid_state(4096, 11);
        let (p1, p2) = (tmp("size_v1"), tmp("size_v2"));
        save(&p1, &state, &specs).unwrap();
        save_v2(&p2, &state, &specs, StatePrecision::Fp8).unwrap();
        let (s1, s2) = (
            std::fs::metadata(&p1).unwrap().len(),
            std::fs::metadata(&p2).unwrap().len(),
        );
        // payload ratio is (2+1)/(4+4) = 0.375; headers are O(1)
        assert!(2 * s2 <= s1, "v2 ({s2} B) is not half of v1 ({s1} B)");
        std::fs::remove_file(&p1).ok();
        std::fs::remove_file(&p2).ok();
    }

    #[test]
    fn v1_files_still_load_through_the_same_entry_point() {
        let (state, specs) = on_grid_state(17, 13);
        let path = tmp("v1_compat");
        save(&path, &state, &specs).unwrap();
        let loaded = load(&path, &specs).unwrap();
        assert_eq!(bits_of(&loaded), bits_of(&state));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn v2_rejects_corruption_with_contextual_errors() {
        let count = 8usize;
        let (state, specs) = on_grid_state(count, 17);
        let path = tmp("corrupt");
        save_v2(&path, &state, &specs, StatePrecision::Fp8).unwrap();
        let good = std::fs::read(&path).unwrap();
        // v2 layout: magic(8) precision(1) n(4), then per-tensor blocks of
        //   name_len(4) name ndim(4) dims(8*nd) codec(1) payload.
        let codec0 = 8 + 1 + 4 + (4 + 1 + 4 + 8); // first tensor "w"
        let block0 = 4 + 1 + 4 + 8 + 1 + 2 * count; // bf16 payload
        let scale1 = 8 + 1 + 4 + block0 + (4 + 3 + 4 + 8) + 1; // "m_w" scale
        let cases: [(&str, usize, u8, &str); 3] = [
            ("precision byte", 8, 9, "unknown state-precision byte 9"),
            ("codec byte", codec0, 7, "unknown v2 codec byte 7"),
            ("scale exponent", scale1, 127, "out of range"),
        ];
        for (what, offset, value, needle) in cases {
            let mut bad = good.clone();
            bad[offset] = value;
            std::fs::write(&path, &bad).unwrap();
            let err = load(&path, &specs).unwrap_err().to_string();
            assert!(err.contains(needle), "{what}: error '{err}' lacks '{needle}'");
        }
        // truncation mid-payload names the tensor being read
        std::fs::write(&path, &good[..good.len() - 3]).unwrap();
        let err = load(&path, &specs).unwrap_err().to_string();
        assert!(err.contains("m_w"), "truncation error '{err}' does not name the tensor");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn sharded_v2_roundtrips_and_rejects_geometry_mismatch() {
        let (s0, specs0) = on_grid_state(12, 19);
        let (s1, specs1) = on_grid_state(12, 23);
        let shards = vec![s0, s1];
        let specs = vec![specs0, specs1];
        let path = tmp("shard");
        save_sharded_v2(&path, &shards, &specs, 2, 1, 5, StatePrecision::Fp8).unwrap();
        let (loaded, step) = load_sharded(&path, &specs, 2, 1).unwrap();
        assert_eq!(step, 5);
        for (l, s) in loaded.iter().zip(&shards) {
            assert_eq!(bits_of(l), bits_of(s));
        }
        let err = load_sharded(&path, &specs, 4, 1).unwrap_err().to_string();
        assert!(err.contains("tp=2"), "geometry error '{err}' lacks the saved tp");
        std::fs::remove_file(&path).ok();
    }
}
