//! Binary checkpointing for `TrainState`.
//!
//! Own format (no serde offline): little-endian, versioned, with tensor
//! names + shapes so loads are validated against the manifest ABI.
//!
//! ```text
//! magic "MUSCKPT1" | u32 n_tensors | n_tensors x {
//!     u32 name_len | name bytes | u32 ndim | u64 dims... | f32 data... }
//! ```
//!
//! Sharded (tensor-parallel) runs use a container that embeds one state
//! block per rank plus the shard geometry, so a resume under a
//! different `ShardSpec` is rejected up front instead of producing a
//! silently re-partitioned run:
//!
//! ```text
//! magic "MUSSHRD1" | u32 tp | u32 stages | u32 step | u32 n_ranks |
//!     n_ranks x { u32 n_tensors | tensors... }
//! ```

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use crate::bail;
use crate::coordinator::trainer::TrainState;
use crate::runtime::{Tensor, TensorSpec};
use crate::util::error::{Context, Result};

const MAGIC: &[u8; 8] = b"MUSCKPT1";
const SHARD_MAGIC: &[u8; 8] = b"MUSSHRD1";

/// Write one state block (`u32 n_tensors` + named tensors) to `w`.
/// `specs` supplies names/shapes (params then momenta, as in the train
/// artifact's input list).
fn write_state(w: &mut impl Write, state: &TrainState, specs: &[TensorSpec]) -> Result<()> {
    if specs.len() != state.tensors.len() {
        bail!("{} specs for {} tensors", specs.len(), state.tensors.len());
    }
    w.write_all(&(specs.len() as u32).to_le_bytes())?;
    for (spec, tensor) in specs.iter().zip(&state.tensors) {
        let data = tensor.as_f32().with_context(|| format!("tensor {}", spec.name))?;
        if data.len() != spec.elements() {
            bail!("tensor {}: {} elements, spec says {}", spec.name, data.len(), spec.elements());
        }
        w.write_all(&(spec.name.len() as u32).to_le_bytes())?;
        w.write_all(spec.name.as_bytes())?;
        w.write_all(&(spec.shape.len() as u32).to_le_bytes())?;
        for &d in &spec.shape {
            w.write_all(&(d as u64).to_le_bytes())?;
        }
        // bulk f32 write
        let bytes: &[u8] = unsafe {
            std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
        };
        w.write_all(bytes)?;
    }
    Ok(())
}

/// Read one state block from `r`, validating names/shapes against
/// `specs` (same order contract as [`write_state`]).
fn read_state(r: &mut impl Read, specs: &[TensorSpec]) -> Result<TrainState> {
    let n = read_u32(r)? as usize;
    if n != specs.len() {
        bail!("checkpoint has {n} tensors, expected {}", specs.len());
    }
    let mut tensors = Vec::with_capacity(n);
    for spec in specs {
        let name_len = read_u32(r)? as usize;
        let mut name = vec![0u8; name_len];
        r.read_exact(&mut name)?;
        let name = String::from_utf8(name)?;
        if name != spec.name {
            bail!("tensor order mismatch: got {name}, expected {}", spec.name);
        }
        let ndim = read_u32(r)? as usize;
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            let mut b = [0u8; 8];
            r.read_exact(&mut b)?;
            shape.push(u64::from_le_bytes(b) as usize);
        }
        if shape != spec.shape {
            bail!("tensor {name}: shape {shape:?}, expected {:?}", spec.shape);
        }
        let count: usize = shape.iter().product();
        let mut data = vec![0f32; count];
        let bytes: &mut [u8] = unsafe {
            std::slice::from_raw_parts_mut(data.as_mut_ptr() as *mut u8, count * 4)
        };
        r.read_exact(bytes)?;
        tensors.push(Tensor::f32(data, &shape)?);
    }
    Ok(TrainState { n_params: n / 2, tensors })
}

/// Serialize a state. `specs` supplies names/shapes (params then momentum,
/// as in the train artifact's input list).
pub fn save(path: &Path, state: &TrainState, specs: &[TensorSpec]) -> Result<()> {
    let f = File::create(path).with_context(|| format!("creating {}", path.display()))?;
    let mut w = BufWriter::new(f);
    w.write_all(MAGIC)?;
    write_state(&mut w, state, specs)?;
    w.flush()?;
    Ok(())
}

/// Load a checkpoint, validating names/shapes against `specs`.
pub fn load(path: &Path, specs: &[TensorSpec]) -> Result<TrainState> {
    let f = File::open(path).with_context(|| format!("opening {}", path.display()))?;
    let mut r = BufReader::new(f);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("{} is not a µS checkpoint", path.display());
    }
    read_state(&mut r, specs)
}

/// Serialize a sharded run: one state block per TP rank plus the shard
/// geometry (`tp`, `stages`) and the step the checkpoint was taken at.
/// `specs_per_rank[r]` names rank r's tensors (shard-suffixed).
pub fn save_sharded(
    path: &Path,
    shards: &[TrainState],
    specs_per_rank: &[Vec<TensorSpec>],
    tp: u32,
    stages: u32,
    step: u32,
) -> Result<()> {
    if shards.len() != specs_per_rank.len() || shards.len() != tp as usize {
        bail!("{} shard states / {} spec sets for tp={tp}", shards.len(), specs_per_rank.len());
    }
    let f = File::create(path).with_context(|| format!("creating {}", path.display()))?;
    let mut w = BufWriter::new(f);
    w.write_all(SHARD_MAGIC)?;
    for v in [tp, stages, step, shards.len() as u32] {
        w.write_all(&v.to_le_bytes())?;
    }
    for (state, specs) in shards.iter().zip(specs_per_rank) {
        write_state(&mut w, state, specs)?;
    }
    w.flush()?;
    Ok(())
}

/// Load a sharded checkpoint, rejecting a geometry mismatch: the file's
/// `(tp, stages)` must equal the requested ones — resuming under a
/// different `ShardSpec` requires an explicit repartition via a full
/// (unsharded) checkpoint, not a silent reinterpretation of rank blobs.
/// Returns the per-rank states and the saved step count.
pub fn load_sharded(
    path: &Path,
    specs_per_rank: &[Vec<TensorSpec>],
    tp: u32,
    stages: u32,
) -> Result<(Vec<TrainState>, u32)> {
    let f = File::open(path).with_context(|| format!("opening {}", path.display()))?;
    let mut r = BufReader::new(f);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic != SHARD_MAGIC {
        bail!("{} is not a sharded µS checkpoint", path.display());
    }
    let (file_tp, file_stages) = (read_u32(&mut r)?, read_u32(&mut r)?);
    let (step, n_ranks) = (read_u32(&mut r)?, read_u32(&mut r)?);
    if file_tp != tp || file_stages != stages {
        bail!(
            "{} was saved with tp={file_tp}, stages={file_stages}; cannot resume under \
             tp={tp}, stages={stages} (repartition via a full checkpoint instead)",
            path.display()
        );
    }
    if n_ranks as usize != specs_per_rank.len() {
        bail!("checkpoint has {n_ranks} ranks, expected {}", specs_per_rank.len());
    }
    let mut shards = Vec::with_capacity(n_ranks as usize);
    for specs in specs_per_rank {
        shards.push(read_state(&mut r, specs)?);
    }
    Ok((shards, step))
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}
