//! JSONL run logging: one line per step / per run summary, consumed by the
//! figure-reproduction binaries and EXPERIMENTS.md tables.

use std::fs::{create_dir_all, File, OpenOptions};
use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};

use crate::coordinator::trainer::{RunResult, StepMetrics};
use crate::util::error::Result;
use crate::util::json::Json;

/// JSONL writer: one line per step plus a summary line per run.
pub struct MetricsLogger {
    writer: BufWriter<File>,
    /// Path of the `.jsonl` file being written.
    pub path: PathBuf,
}

impl MetricsLogger {
    /// Create (truncate) `dir/<run_name>.jsonl`.
    pub fn create(dir: &Path, run_name: &str) -> Result<MetricsLogger> {
        create_dir_all(dir)?;
        let path = dir.join(format!("{run_name}.jsonl"));
        let f = OpenOptions::new().create(true).write(true).truncate(true).open(&path)?;
        Ok(MetricsLogger { writer: BufWriter::new(f), path })
    }

    /// Append one step record.
    pub fn log_step(&mut self, m: &StepMetrics) -> Result<()> {
        let j = Json::obj(vec![
            ("kind", Json::str("step")),
            ("step", Json::num(m.step as f64)),
            ("loss", Json::num(m.loss as f64)),
            ("gnorm", Json::num(m.gnorm as f64)),
            ("lr", Json::num(m.lr)),
            ("step_ms", Json::num(m.step_time.as_secs_f64() * 1e3)),
        ]);
        writeln!(self.writer, "{j}")?;
        Ok(())
    }

    /// Append the run-summary record and flush.
    pub fn log_summary(&mut self, run_name: &str, r: &RunResult) -> Result<()> {
        let j = summary_json(run_name, r);
        writeln!(self.writer, "{j}")?;
        self.writer.flush()?;
        Ok(())
    }
}

/// Run-summary JSON object (the `train-one` stdout format).
pub fn summary_json(run_name: &str, r: &RunResult) -> Json {
    Json::obj(vec![
        ("kind", Json::str("summary")),
        ("run", Json::str(run_name)),
        ("steps", Json::num(r.steps_done as f64)),
        ("final_loss", Json::num(r.final_loss(10) as f64)),
        ("diverged", Json::Bool(r.diverged)),
        ("spikes", Json::num(r.spikes as f64)),
        ("wall_s", Json::num(r.wall.as_secs_f64())),
        ("tokens_per_sec", Json::num(r.tokens_per_sec)),
        ("losses", Json::arr_f32(&r.losses)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn dummy_result() -> RunResult {
        RunResult {
            losses: vec![3.0, 2.0, 1.0],
            gnorms: vec![1.0; 3],
            steps_done: 3,
            diverged: false,
            spikes: 1,
            wall: Duration::from_secs(1),
            tokens_per_sec: 42.0,
        }
    }

    #[test]
    fn jsonl_roundtrip() {
        let dir = std::env::temp_dir().join("munit_metrics_test");
        let mut log = MetricsLogger::create(&dir, "r1").unwrap();
        log.log_step(&StepMetrics {
            step: 0,
            loss: 3.0,
            gnorm: 1.0,
            lr: 0.01,
            step_time: Duration::from_millis(5),
        })
        .unwrap();
        log.log_summary("r1", &dummy_result()).unwrap();
        let text = std::fs::read_to_string(&log.path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        let step = Json::parse(lines[0]).unwrap();
        assert_eq!(step.str_or("kind", ""), "step");
        let sum = Json::parse(lines[1]).unwrap();
        assert_eq!(sum.f64_or("final_loss", 0.0), 2.0);
        assert_eq!(sum.get("losses").unwrap().as_arr().unwrap().len(), 3);
    }

    #[test]
    fn final_loss_tail_mean() {
        let r = dummy_result();
        assert!((r.final_loss(2) - 1.5).abs() < 1e-6);
        assert!((r.final_loss(100) - 2.0).abs() < 1e-6);
    }
}
