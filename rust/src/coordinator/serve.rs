//! Continuous-batching serve loop over an [`InferSession`].
//!
//! The scheduler the ROADMAP's "serve heavy traffic" goal needs, at
//! reference scale: requests arrive at arbitrary steps, get admitted
//! into a bounded decode batch as slots free up, and every live sequence
//! advances one token per step through **one batched decode execute**
//! ([`InferSession::decode_batch`]). Sequences leave the batch the step
//! they finish (max tokens or stop token) and their KV pages recycle
//! immediately — admissions and evictions happen *between* decode steps,
//! never by restarting the batch.
//!
//! Three serving-throughput knobs layer on top (see `docs/SERVING.md`):
//!
//!  - **Prefix caching** ([`ServeConfig::prefix_cache`]): finished
//!    prompts are indexed by token-chain hash; an admitted request
//!    adopts the longest cached prefix (full KV slabs shared by
//!    refcount, a partial tail copied) and prefills only its suffix.
//!  - **Chunked prefill** ([`ServeConfig::prefill_chunk`]): long prompts
//!    advance one fixed-size chunk per scheduler step, interleaved with
//!    the decode pass, so a long admission no longer stalls every live
//!    sequence's next token.
//!  - **KV trimming** ([`ServeConfig::kv_trim_slabs`]): free slab
//!    buffers are released between steps, so one long burst no longer
//!    pins peak memory; high-water vs current bytes are reported.
//!
//! Because batched decode is row-local under static-FP8/BF16 plans (see
//! `runtime::infer`) and chunked prefill is bit-identical to the whole-
//! prompt tower, a request's generated tokens are identical whatever
//! batch it shared and however its prompt was chunked or adopted —
//! tested against isolated one-request runs. Accounting follows
//! `ExecStats` practice: per-request queue/admission/first-token/finish
//! latencies, plus aggregate prefill/decode tokens-per-sec, prefix-hit
//! and KV-memory counters in the [`ServeReport`].

use std::time::{Duration, Instant};

use crate::config::ModelConfig;
use crate::bail;
use crate::runtime::{sample_greedy, sample_topk, InferSession, SeqId};
use crate::util::error::Result;
use crate::util::rng::Rng;

/// Per-request sampling policy.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Sampling {
    /// Deterministic argmax (lowest index on ties).
    Greedy,
    /// Seeded top-k at a temperature: deterministic per request,
    /// independent of batch composition (each request owns its RNG).
    TopK {
        /// Candidates kept per draw (`k <= 1` degenerates to greedy).
        k: usize,
        /// Softmax temperature over the kept candidates.
        temperature: f32,
        /// Per-request RNG seed.
        seed: u64,
    },
}

/// One generation request.
#[derive(Debug, Clone)]
pub struct Request {
    /// Caller-chosen request id (echoed in the completion).
    pub id: u64,
    /// Prompt token ids.
    pub prompt: Vec<i32>,
    /// Generation budget (the request finishes when it is reached).
    pub max_new_tokens: usize,
    /// Serve step at which the request becomes visible to the scheduler.
    pub arrival_step: usize,
    /// Generating this token finishes the request early (eviction).
    pub stop_token: Option<i32>,
    /// Per-request sampling policy.
    pub sampling: Sampling,
}

/// Scheduler knobs. The defaults reproduce the original scheduler
/// exactly: whole-prompt prefill at admission, no prefix cache, no KV
/// trimming.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Maximum live sequences per decode step.
    pub max_batch: usize,
    /// Hard cap on scheduler steps (guards non-terminating request sets).
    pub max_steps: usize,
    /// `Some(c)` prefills at most `c` prompt positions per live request
    /// per step, interleaved with decode; `None` prefills the whole
    /// prompt inline at admission.
    pub prefill_chunk: Option<usize>,
    /// Share KV slabs between requests with a common prompt prefix.
    pub prefix_cache: bool,
    /// Cached prefixes held before FIFO eviction (used when
    /// [`ServeConfig::prefix_cache`] is on).
    pub prefix_capacity: usize,
    /// `Some(n)` trims free KV slab buffers down to `n` after every
    /// step; `None` keeps them pooled at the high-water mark.
    pub kv_trim_slabs: Option<usize>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            max_batch: 8,
            max_steps: 10_000,
            prefill_chunk: None,
            prefix_cache: false,
            prefix_capacity: 32,
            kv_trim_slabs: None,
        }
    }
}

/// One finished request.
#[derive(Debug, Clone)]
pub struct Completion {
    /// The originating request's id.
    pub id: u64,
    /// Generated tokens (stop token included when one fired).
    pub tokens: Vec<i32>,
    /// Prompt length of the originating request.
    pub prompt_len: usize,
    /// True when a stop token ended generation before `max_new_tokens`.
    pub stopped_early: bool,
    /// Scheduler step the request became visible.
    pub arrival_step: usize,
    /// Scheduler step the request was admitted (prefill ran).
    pub admitted_step: usize,
    /// Scheduler step the request finished.
    pub finished_step: usize,
    /// Wall time from becoming visible to the scheduler to admission
    /// (time spent queued waiting for a batch slot).
    pub queue_latency: Duration,
    /// Wall time from admission (prefill start) to the first token.
    pub first_token_latency: Duration,
    /// Wall time from admission to the instant the finishing token was
    /// sampled (not when the scheduler later evicted the sequence).
    pub total_latency: Duration,
}

/// Aggregate outcome of draining a request set.
#[derive(Debug, Clone)]
pub struct ServeReport {
    /// Every finished request, in completion order.
    pub completions: Vec<Completion>,
    /// Scheduler steps taken to drain the request set.
    pub steps: usize,
    /// Prompt tokens actually COMPUTED by prefill — positions adopted
    /// from the prefix cache are excluded, so with sharing on this is
    /// strictly below the summed prompt lengths.
    pub prefill_tokens: u64,
    /// Total tokens decoded.
    pub decode_tokens: u64,
    /// Wall time of the whole drain.
    pub wall: Duration,
    /// Generated tokens per second over the time actually spent in
    /// decode executes (from [`InferSession`]'s per-phase accounting).
    pub decode_tokens_per_sec: f64,
    /// Prompt tokens per second over the time actually spent in prefill.
    pub prefill_tokens_per_sec: f64,
    /// Mean live sequences per decode step (batching effectiveness).
    pub mean_batch_occupancy: f64,
    /// Prefix-cache adoptions during the drain.
    pub prefix_hits: u64,
    /// Prompt positions served from shared KV slabs instead of compute.
    pub prefix_hit_tokens: u64,
    /// Largest KV byte footprint the pool reached during (or before)
    /// the drain.
    pub kv_high_water_bytes: usize,
    /// KV bytes still materialized after the drain (with
    /// [`ServeConfig::kv_trim_slabs`] set this stays near zero).
    pub kv_current_bytes: usize,
}

struct Live {
    req: usize,
    seq: SeqId,
    rng: Rng,
    admitted_step: usize,
    queue_latency: Duration,
    admitted_at: Instant,
    /// Stamped when the first token is sampled (prompt fully prefilled).
    first_token_at: Option<Instant>,
    /// Stamped the instant the finishing token is sampled, so the
    /// completion's total latency excludes scheduler eviction overhead.
    finished_at: Option<Instant>,
    /// Prompt positions already in the KV cache (adopted + prefilled);
    /// below `prompt.len()` the request is still prefilling.
    prefilled: usize,
    /// Generated so far; the last entry is the token to feed next step.
    tokens: Vec<i32>,
    stopped_early: bool,
}

/// The one sampling dispatch — shared by the serve loop and
/// [`generate_one`], so batched and isolated generation cannot diverge
/// on how a policy is applied.
fn draw(sampling: Sampling, logits: &[f32], rng: &mut Rng) -> i32 {
    match sampling {
        Sampling::Greedy => sample_greedy(logits),
        Sampling::TopK { k, temperature, .. } => sample_topk(logits, k, temperature, rng),
    }
}

fn sample(req: &Request, live: &mut Live, logits: &[f32]) -> i32 {
    draw(req.sampling, logits, &mut live.rng)
}

fn finished(req: &Request, live: &Live) -> bool {
    live.stopped_early || live.tokens.len() >= req.max_new_tokens
}

/// Push a sampled token and, the instant the request's finish condition
/// becomes true (stop token or generation budget), stamp `finished_at` —
/// the completion's total latency is measured to this instant, not to
/// the scheduler's later eviction pass.
fn push_token(req: &Request, live: &mut Live, tok: i32) {
    live.tokens.push(tok);
    if req.stop_token == Some(tok) {
        live.stopped_early = true;
    }
    if finished(req, live) {
        live.finished_at = Some(Instant::now());
    }
}

/// Move every finished live sequence into `completions`, freeing its KV
/// pages. Runs before admission (so finished sequences release their
/// batch slots the step they finish) and again after admission (so a
/// request whose first sampled token already stops never enters a
/// decode).
fn evict_finished(
    infer: &mut InferSession,
    requests: &[Request],
    live: &mut Vec<Live>,
    completions: &mut Vec<Completion>,
    step: usize,
) -> Result<()> {
    let mut i = 0;
    while i < live.len() {
        let req = &requests[live[i].req];
        if finished(req, &live[i]) {
            let l = live.remove(i);
            infer.free_sequence(l.seq)?;
            completions.push(Completion {
                id: req.id,
                tokens: l.tokens,
                prompt_len: req.prompt.len(),
                stopped_early: l.stopped_early,
                arrival_step: req.arrival_step,
                admitted_step: l.admitted_step,
                finished_step: step,
                queue_latency: l.queue_latency,
                first_token_latency: l.first_token_at.unwrap_or(l.admitted_at) - l.admitted_at,
                total_latency: l.finished_at.unwrap_or_else(Instant::now) - l.admitted_at,
            });
        } else {
            i += 1;
        }
    }
    Ok(())
}

/// Drain `requests` through the continuous-batching loop. Requests are
/// admitted in `(arrival_step, id)` order as batch slots free up; every
/// request must fit the session's context capacity
/// (`prompt + max_new_tokens ≤ capacity`). Returns per-request
/// completions (sorted by id) and aggregate throughput.
pub fn serve(
    infer: &mut InferSession,
    requests: &[Request],
    sc: &ServeConfig,
) -> Result<ServeReport> {
    if sc.max_batch == 0 {
        bail!("serve: max_batch must be positive");
    }
    let cap = infer.context_capacity();
    for r in requests {
        if r.prompt.is_empty() {
            bail!("request {}: empty prompt", r.id);
        }
        if r.max_new_tokens == 0 {
            bail!("request {}: max_new_tokens must be positive", r.id);
        }
        if r.prompt.len() + r.max_new_tokens > cap {
            bail!(
                "request {}: prompt {} + max_new {} exceeds context capacity {cap}",
                r.id,
                r.prompt.len(),
                r.max_new_tokens
            );
        }
    }
    if sc.prefill_chunk == Some(0) {
        bail!("serve: prefill_chunk must be positive when set");
    }
    if sc.prefix_cache {
        infer.enable_prefix_cache(sc.prefix_capacity);
    }
    // admission queue: arrival order, id as the deterministic tiebreak
    let mut queue: Vec<usize> = (0..requests.len()).collect();
    queue.sort_by_key(|&i| (requests[i].arrival_step, requests[i].id));
    let mut next_admit = 0usize;
    let mut live: Vec<Live> = Vec::new();
    let mut completions: Vec<Completion> = Vec::new();
    let mut arrived_at: Vec<Option<Instant>> = vec![None; requests.len()];
    let mut decode_tokens = 0u64;
    let mut occupancy_sum = 0u64;
    let mut decode_steps = 0usize;
    let vocab = infer.config().vocab;
    // per-phase baselines (the session may have served before)
    let stats0 = infer.stats().clone();
    let t0 = Instant::now();
    let mut step = 0usize;

    while completions.len() < requests.len() {
        if step >= sc.max_steps {
            bail!(
                "serve: {} of {} requests unfinished after max_steps {}",
                requests.len() - completions.len(),
                requests.len(),
                sc.max_steps
            );
        }
        // ---- stamp requests becoming visible this step (queue time) ----
        for &ri in &queue[next_admit..] {
            if requests[ri].arrival_step > step {
                break; // queue is sorted by arrival step
            }
            if arrived_at[ri].is_none() {
                arrived_at[ri] = Some(Instant::now());
            }
        }
        // ---- evict sequences that finished last step, freeing slots ----
        evict_finished(infer, requests, &mut live, &mut completions, step)?;

        // ---- admit: fill free batch slots with arrived requests --------
        while next_admit < queue.len()
            && live.len() < sc.max_batch
            && requests[queue[next_admit]].arrival_step <= step
        {
            let ri = queue[next_admit];
            next_admit += 1;
            let req = &requests[ri];
            let admitted_at = Instant::now();
            let seq = infer.add_sequence();
            // longest cached prefix first: shared slabs, zero compute
            let adopted = infer.adopt_prefix(seq, &req.prompt)?;
            let mut l = Live {
                req: ri,
                seq,
                rng: match req.sampling {
                    // the request's own seed, untouched by batch state —
                    // identical draws whether served batched or alone
                    Sampling::TopK { seed, .. } => Rng::new(seed),
                    Sampling::Greedy => Rng::new(req.id),
                },
                admitted_step: step,
                queue_latency: admitted_at - arrived_at[ri].unwrap_or(admitted_at),
                admitted_at,
                first_token_at: None,
                finished_at: None,
                prefilled: adopted,
                tokens: Vec::with_capacity(req.max_new_tokens),
                stopped_early: false,
            };
            if sc.prefill_chunk.is_none() {
                // whole remaining prompt inline, first token this step
                let rest = &req.prompt[l.prefilled..];
                let logits = if l.prefilled == 0 {
                    infer.prefill(seq, rest)?
                } else {
                    infer.prefill_chunk(seq, rest)?
                };
                l.prefilled = req.prompt.len();
                if sc.prefix_cache {
                    infer.insert_prefix(seq, &req.prompt)?;
                }
                let tok = sample(req, &mut l, &logits[(rest.len() - 1) * vocab..]);
                l.first_token_at = Some(Instant::now());
                push_token(req, &mut l, tok);
            }
            live.push(l);
        }

        // ---- chunked prefill: each still-prefilling request advances
        // at most one chunk, so long prompts interleave with decode ----
        if let Some(chunk) = sc.prefill_chunk {
            for l in live.iter_mut() {
                let req = &requests[l.req];
                if l.prefilled >= req.prompt.len() {
                    continue;
                }
                let end = (l.prefilled + chunk).min(req.prompt.len());
                let logits = infer.prefill_chunk(l.seq, &req.prompt[l.prefilled..end])?;
                let n = end - l.prefilled;
                l.prefilled = end;
                if l.prefilled == req.prompt.len() {
                    // prompt complete: index it, sample the first token
                    if sc.prefix_cache {
                        infer.insert_prefix(l.seq, &req.prompt)?;
                    }
                    let tok = sample(req, l, &logits[(n - 1) * vocab..]);
                    l.first_token_at = Some(Instant::now());
                    push_token(req, l, tok);
                }
            }
        }

        // ---- evict requests whose first sampled token already finished
        // them (instant stop / max_new == 1), before any decode ---------
        evict_finished(infer, requests, &mut live, &mut completions, step)?;

        // ---- one batched decode over every token-bearing sequence
        // (still-prefilling requests hold their slot but do not decode) --
        let mut items: Vec<(SeqId, i32)> = Vec::with_capacity(live.len());
        let mut rows: Vec<usize> = Vec::with_capacity(live.len());
        for (i, l) in live.iter().enumerate() {
            if let Some(&tok) = l.tokens.last() {
                items.push((l.seq, tok));
                rows.push(i);
            }
        }
        if !items.is_empty() {
            let outs = infer.decode_batch(&items)?;
            decode_tokens += outs.len() as u64;
            occupancy_sum += items.len() as u64;
            decode_steps += 1;
            for (&i, logits) in rows.iter().zip(&outs) {
                let l = &mut live[i];
                let req = &requests[l.req];
                let tok = sample(req, l, logits);
                push_token(req, l, tok);
            }
        } else if live.is_empty() && next_admit >= queue.len() {
            // nothing live and nothing left to admit: the eviction pass
            // above has drained everything
            debug_assert_eq!(completions.len(), requests.len());
        }
        // ---- release free KV slab buffers between steps ----------------
        if let Some(target) = sc.kv_trim_slabs {
            infer.kv_trim(target);
        }
        step += 1;
    }

    let wall = t0.elapsed();
    completions.sort_by_key(|c| c.id);
    let stats1 = infer.stats().clone();
    let prefill_tokens = stats1.prefill_tokens - stats0.prefill_tokens;
    let prefill_secs = (stats1.prefill_time - stats0.prefill_time).as_secs_f64().max(1e-9);
    let decode_secs = (stats1.decode_time - stats0.decode_time).as_secs_f64().max(1e-9);
    Ok(ServeReport {
        steps: step,
        prefill_tokens,
        decode_tokens,
        wall,
        decode_tokens_per_sec: decode_tokens as f64 / decode_secs,
        prefill_tokens_per_sec: prefill_tokens as f64 / prefill_secs,
        mean_batch_occupancy: occupancy_sum as f64 / decode_steps.max(1) as f64,
        prefix_hits: stats1.prefix_hits - stats0.prefix_hits,
        prefix_hit_tokens: stats1.prefix_hit_tokens - stats0.prefix_hit_tokens,
        kv_high_water_bytes: infer.kv_high_water_bytes(),
        kv_current_bytes: infer.kv_materialized_bytes(),
        completions,
    })
}

/// Generate one sequence in isolation (no batching): prefill the prompt,
/// then feed sampled tokens until `max_new_tokens` or the stop token.
/// The per-sequence oracle the continuous-batching test compares against,
/// and the engine behind the CLI `generate` subcommand.
pub fn generate_one(
    infer: &mut InferSession,
    prompt: &[i32],
    max_new_tokens: usize,
    stop_token: Option<i32>,
    sampling: Sampling,
) -> Result<Vec<i32>> {
    if prompt.is_empty() || max_new_tokens == 0 {
        bail!("generate: prompt and max_new_tokens must be non-empty");
    }
    let vocab = infer.config().vocab;
    let seq = infer.add_sequence();
    let logits = infer.prefill(seq, prompt)?;
    let mut rng = match sampling {
        Sampling::TopK { seed, .. } => Rng::new(seed),
        Sampling::Greedy => Rng::new(0),
    };
    let mut tok = draw(sampling, &logits[(prompt.len() - 1) * vocab..], &mut rng);
    let mut out = vec![tok];
    while out.len() < max_new_tokens && stop_token != Some(tok) {
        let l = infer.decode_step(seq, tok)?;
        tok = draw(sampling, &l, &mut rng);
        out.push(tok);
    }
    infer.free_sequence(seq)?;
    Ok(out)
}

/// Synthetic mixed-length request set for benches, the CLI, and tests:
/// staggered arrivals, varied prompt/generation lengths, an early-stop
/// token on every third request.
pub fn synthetic_requests(cfg: &ModelConfig, n: usize, seed: u64) -> Vec<Request> {
    let cap = cfg.seq_len;
    let mut rng = Rng::new(seed ^ 0x5E4E);
    (0..n as u64)
        .map(|id| {
            let prompt_len = 2 + rng.below((cap / 4).max(2) - 1);
            let max_new = 1 + rng.below((cap - prompt_len).min(cap / 3).max(1));
            Request {
                id,
                prompt: (0..prompt_len).map(|_| rng.below(cfg.vocab) as i32).collect(),
                max_new_tokens: max_new,
                arrival_step: rng.below(6),
                stop_token: if id % 3 == 2 { Some(rng.below(cfg.vocab) as i32) } else { None },
                sampling: if id % 2 == 0 {
                    Sampling::Greedy
                } else {
                    Sampling::TopK { k: 4, temperature: 1.0, seed: 0xC0DE ^ id }
                },
            }
        })
        .collect()
}

/// Format a per-request latency table (CLI / e2e reporting).
pub fn latency_table(report: &ServeReport) -> String {
    let mut out = String::from(
        "  req  prompt  new  arrive  admit  finish  first-tok   total\n",
    );
    for c in &report.completions {
        out.push_str(&format!(
            "  {:>3}  {:>6}  {:>3}  {:>6}  {:>5}  {:>6}  {:>8.2?}  {:>6.2?}{}\n",
            c.id,
            c.prompt_len,
            c.tokens.len(),
            c.arrival_step,
            c.admitted_step,
            c.finished_step,
            c.first_token_latency,
            c.total_latency,
            if c.stopped_early { "  [stop]" } else { "" },
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::InferSession;

    fn lane_cfg() -> ModelConfig {
        ModelConfig {
            width: 16,
            depth: 2,
            head_dim: 8,
            vocab: 64,
            seq_len: 24,
            batch: 2,
            ..ModelConfig::default()
        }
    }

    fn session(cfg: &ModelConfig, seed: i32) -> InferSession {
        let params = crate::runtime::block::init_params(cfg, seed);
        InferSession::from_params(cfg, params, 0.4).unwrap()
    }

    /// Acceptance: a mixed-length request set with staggered admissions
    /// and early evictions drains to the SAME per-sequence tokens as
    /// running each request alone (µS static FP8 — row-local decode).
    /// The set is handcrafted so the scheduler properties hold by
    /// construction: five prompt lengths, three arrival steps, uneven
    /// generation lengths (so sequences leave the batch while others are
    /// mid-flight), stop tokens, and both sampling modes.
    #[test]
    fn continuous_batching_matches_isolated_generation() {
        let cfg = lane_cfg();
        let topk = |seed: u64| Sampling::TopK { k: 4, temperature: 1.0, seed };
        let mk = |id, prompt: &[i32], max_new, arrival, stop| Request {
            id,
            prompt: prompt.to_vec(),
            max_new_tokens: max_new,
            arrival_step: arrival,
            stop_token: stop,
            sampling: if id % 2 == 0 { Sampling::Greedy } else { topk(100 + id) },
        };
        let requests = vec![
            mk(0, &[1, 2], 6, 0, None),
            mk(1, &[3, 4, 5], 5, 0, None),
            mk(2, &[6, 7, 8, 9], 8, 2, Some(11)),
            mk(3, &[2, 3], 3, 3, None),
            mk(4, &[1, 2, 3, 4, 5, 6], 7, 5, Some(0)),
        ];

        let mut batched = session(&cfg, 5);
        let sc = ServeConfig { max_batch: 3, max_steps: 5_000, ..Default::default() };
        let report = serve(&mut batched, &requests, &sc).unwrap();
        assert_eq!(report.completions.len(), requests.len());
        assert!(batched.live_sequences() == 0, "serve must drain every sequence");
        assert_eq!(batched.kv_slabs_in_use(), 0, "all KV pages recycled");
        assert!(report.decode_tokens_per_sec > 0.0);
        assert!(report.mean_batch_occupancy >= 1.0);

        for c in &report.completions {
            let req = requests.iter().find(|r| r.id == c.id).unwrap();
            let mut solo = session(&cfg, 5);
            let alone = generate_one(
                &mut solo,
                &req.prompt,
                req.max_new_tokens,
                req.stop_token,
                req.sampling,
            )
            .unwrap();
            assert_eq!(
                c.tokens, alone,
                "request {} diverged under batching (batched {:?} vs alone {:?})",
                c.id, c.tokens, alone
            );
        }
    }

    /// Simultaneous arrivals genuinely share decode steps: three equal
    /// requests admitted at step 0 ride every decode execute together.
    #[test]
    fn simultaneous_requests_share_decode_steps() {
        let cfg = lane_cfg();
        let mut sess = session(&cfg, 4);
        let requests: Vec<Request> = (0..3u64)
            .map(|id| Request {
                id,
                prompt: (0..2 + id as usize).map(|t| (t as i32 + 1) % cfg.vocab as i32).collect(),
                max_new_tokens: 6,
                arrival_step: 0,
                stop_token: None,
                sampling: Sampling::Greedy,
            })
            .collect();
        let sc = ServeConfig { max_batch: 3, max_steps: 100, ..Default::default() };
        let report = serve(&mut sess, &requests, &sc).unwrap();
        // each request samples once at admission + 5 decode steps; all
        // three stay live for every decode step → occupancy is exactly 3
        assert!(
            report.mean_batch_occupancy > 2.9,
            "expected full batches, got occupancy {}",
            report.mean_batch_occupancy
        );
        assert!(report.completions.iter().all(|c| c.tokens.len() == 6));
    }

    #[test]
    fn early_stop_evicts_and_frees_pages() {
        let cfg = lane_cfg();
        let mut sess = session(&cfg, 9);
        // force the stop token to be whatever greedy produces first:
        // run once to discover it, then serve with it as the stop token
        let probe = generate_one(&mut sess, &[1, 2, 3], 4, None, Sampling::Greedy).unwrap();
        let req = Request {
            id: 0,
            prompt: vec![1, 2, 3],
            max_new_tokens: 10,
            arrival_step: 0,
            stop_token: Some(probe[0]),
            sampling: Sampling::Greedy,
        };
        let report = serve(&mut sess, &[req], &ServeConfig::default()).unwrap();
        let c = &report.completions[0];
        assert!(c.stopped_early);
        assert_eq!(c.tokens.len(), 1, "stop token generated at the first sample");
        assert_eq!(sess.kv_slabs_in_use(), 0);
    }

    #[test]
    fn serve_rejects_oversized_and_degenerate_requests() {
        let cfg = lane_cfg();
        let mut sess = session(&cfg, 1);
        let mut r = synthetic_requests(&cfg, 1, 0);
        r[0].prompt = vec![0; cfg.seq_len];
        r[0].max_new_tokens = 1;
        assert!(serve(&mut sess, &r, &ServeConfig::default()).is_err(), "over capacity");
        let mut r = synthetic_requests(&cfg, 1, 0);
        r[0].prompt.clear();
        assert!(serve(&mut sess, &r, &ServeConfig::default()).is_err(), "empty prompt");
        let r = synthetic_requests(&cfg, 2, 0);
        let sc = ServeConfig { max_batch: 1, max_steps: 1, ..Default::default() };
        assert!(serve(&mut sess, &r, &sc).is_err(), "max_steps guard");
    }

    #[test]
    fn latency_accounting_is_ordered() {
        let cfg = lane_cfg();
        let mut sess = session(&cfg, 2);
        let requests = synthetic_requests(&cfg, 4, 77);
        let report = serve(&mut sess, &requests, &ServeConfig::default()).unwrap();
        for c in &report.completions {
            assert!(c.admitted_step >= c.arrival_step);
            assert!(c.finished_step >= c.admitted_step);
            assert!(c.total_latency >= c.first_token_latency);
            assert!(!c.tokens.is_empty());
        }
        // ids sorted, one completion per request
        let ids: Vec<u64> = report.completions.iter().map(|c| c.id).collect();
        let mut sorted = ids.clone();
        sorted.sort_unstable();
        assert_eq!(ids, sorted);
        assert!(report.steps > 0);
        assert_eq!(
            report.prefill_tokens,
            requests.iter().map(|r| r.prompt.len() as u64).sum::<u64>()
        );
    }

    /// Satellite acceptance: an overlapping-prefix request set generates
    /// IDENTICAL tokens with the prefix cache on and off, chunked and
    /// unchunked, and batched equals isolated in every mode. With the
    /// cache on, every adopted position is exactly one prompt position
    /// not computed; refcounted eviction of donors never breaks later
    /// adopters (the drain finishes with all slabs recycled).
    #[test]
    fn prefix_cache_and_chunked_prefill_preserve_tokens() {
        let cfg = ModelConfig { seq_len: 48, ..lane_cfg() };
        let shared: Vec<i32> = (0..36).map(|i| ((i * 5 + 1) % cfg.vocab) as i32).collect();
        let mk = |id, prompt: Vec<i32>, max_new, arrival| Request {
            id,
            prompt,
            max_new_tokens: max_new,
            arrival_step: arrival,
            stop_token: None,
            sampling: if id % 2 == 0 {
                Sampling::Greedy
            } else {
                Sampling::TopK { k: 4, temperature: 1.0, seed: 100 + id }
            },
        };
        let with_tail = |t: &[i32]| {
            let mut p = shared.clone();
            p.extend_from_slice(t);
            p
        };
        let requests = vec![
            mk(0, shared.clone(), 4, 0),        // donor: indexes the prefix
            mk(1, with_tail(&[7]), 4, 1),       // full-slab share + tail copy
            mk(2, with_tail(&[9, 11]), 3, 1),   // second adopter
            mk(3, vec![2, 3, 4], 4, 2),         // no shared prefix
        ];
        let run = |sc: &ServeConfig| {
            let mut sess = session(&cfg, 7);
            let report = serve(&mut sess, &requests, sc).unwrap();
            if sc.prefix_cache {
                // the index still holds refcounts on indexed prompts;
                // dropping it must release every slab (satellite: donor
                // eviction mid-drain never freed shared slabs)
                assert!(sess.prefix_entries() > 0);
                sess.enable_prefix_cache(0);
            }
            assert_eq!(sess.kv_slabs_in_use(), 0, "drain must recycle all slabs");
            report
        };
        let base = run(&ServeConfig { max_batch: 2, ..Default::default() });
        let tokens =
            |r: &ServeReport| r.completions.iter().map(|c| c.tokens.clone()).collect::<Vec<_>>();
        // batched equals isolated for the baseline
        for c in &base.completions {
            let req = requests.iter().find(|r| r.id == c.id).unwrap();
            let mut solo = session(&cfg, 7);
            let alone =
                generate_one(&mut solo, &req.prompt, req.max_new_tokens, None, req.sampling)
                    .unwrap();
            assert_eq!(c.tokens, alone, "request {} diverged from isolated run", c.id);
        }
        // prefix cache: same tokens, strictly fewer prompt tokens computed
        let cached =
            run(&ServeConfig { max_batch: 2, prefix_cache: true, ..Default::default() });
        assert_eq!(tokens(&cached), tokens(&base), "prefix cache changed generation");
        assert!(cached.prefix_hits >= 2, "adopters must hit, got {}", cached.prefix_hits);
        assert!(cached.prefill_tokens < base.prefill_tokens);
        assert_eq!(
            base.prefill_tokens - cached.prefill_tokens,
            cached.prefix_hit_tokens,
            "every adopted position is exactly one position not computed"
        );
        // chunked prefill: same tokens, same computed prompt tokens
        let chunked = run(&ServeConfig {
            max_batch: 2,
            prefill_chunk: Some(5),
            ..Default::default()
        });
        assert_eq!(tokens(&chunked), tokens(&base), "chunking changed generation");
        assert_eq!(chunked.prefill_tokens, base.prefill_tokens);
        // both together
        let both = run(&ServeConfig {
            max_batch: 2,
            prefill_chunk: Some(5),
            prefix_cache: true,
            ..Default::default()
        });
        assert_eq!(tokens(&both), tokens(&base), "chunk+cache changed generation");
        assert_eq!(base.prefill_tokens - both.prefill_tokens, both.prefix_hit_tokens);
    }

    /// Satellite acceptance: `kv_trim_slabs` bounds resident KV bytes
    /// between steps without touching results; the report carries the
    /// high-water vs current split.
    #[test]
    fn kv_trim_bounds_resident_bytes_without_changing_tokens() {
        let cfg = lane_cfg();
        let requests = synthetic_requests(&cfg, 5, 42);
        let mut keep = session(&cfg, 3);
        let pooled = serve(&mut keep, &requests, &ServeConfig::default()).unwrap();
        assert!(pooled.kv_high_water_bytes > 0);
        assert!(
            pooled.kv_current_bytes > 0,
            "without trimming, free slabs stay materialized after the drain"
        );
        let mut trim = session(&cfg, 3);
        let sc = ServeConfig { kv_trim_slabs: Some(0), ..Default::default() };
        let trimmed = serve(&mut trim, &requests, &sc).unwrap();
        assert_eq!(trimmed.kv_current_bytes, 0, "trim(0) releases every free buffer");
        assert!(trimmed.kv_high_water_bytes > 0, "high-water mark survives trimming");
        assert!(trimmed.kv_high_water_bytes >= trimmed.kv_current_bytes);
        let toks = |r: &ServeReport| {
            r.completions.iter().map(|c| c.tokens.clone()).collect::<Vec<_>>()
        };
        assert_eq!(toks(&trimmed), toks(&pooled), "trimming changed generation");
    }

    /// Satellite 1 regression: total latency is stamped when the
    /// finishing token is sampled, so it can never exceed the wall time
    /// of the whole drain and still bounds the first-token latency.
    #[test]
    fn total_latency_excludes_scheduler_overhead() {
        let cfg = lane_cfg();
        let mut sess = session(&cfg, 6);
        let requests = synthetic_requests(&cfg, 4, 11);
        let report = serve(&mut sess, &requests, &ServeConfig::default()).unwrap();
        for c in &report.completions {
            assert!(c.total_latency >= c.first_token_latency);
            assert!(c.total_latency <= report.wall);
        }
    }
}
