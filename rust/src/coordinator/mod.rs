//! L3 coordinator: the training framework over the runtime `Backend` API.
//!
//! - [`trainer`]: single-model training loop over device-resident
//!   `Session`s (cosine LR, divergence guard, loss-spike tracking, probe
//!   hooks at read-back boundaries).
//! - [`sweep`]: hyperparameter grid engine with optimal-subset extraction
//!   (paper App. A.2 methodology); parallel workers are in-process
//!   *threads* over one shared thread-safe backend.
//! - [`checkpoint`]: binary checkpoint save/load for `TrainState`.
//! - [`pipeline`]: background data generation with bounded-channel
//!   backpressure, keeping batch synthesis off the step critical path.
//! - [`ddp`]: simulated multi-worker data parallelism (sharded streams +
//!   periodic parameter averaging), exercising the distributed code path
//!   µS claims compatibility with (no per-tensor amax collectives needed).
//! - [`collective`]: the collective layer those paths share — a
//!   deterministic (order-fixed, partition-invariant) mean fold plus
//!   allgather/reduce-scatter wire formats (lossless master or FP8 at
//!   static µS scales) with byte + cast-health accounting.
//! - [`gpipe`]: the GPipe fill/drain microbatch schedule (slot table,
//!   makespan/bubble closed forms) used by the sharded trainer.
//! - [`shard`]: sharded execution layer — Megatron-style tensor
//!   parallelism (column-split QKV/up, row-split out/down) composed with
//!   pipeline stages, per-shard µS scale validation, sharded
//!   checkpoints, and comm accounting against `perfmodel` closed forms.
//! - [`serve`]: continuous-batching inference scheduler over
//!   `runtime::InferSession` — staggered admissions, between-step
//!   evictions, one batched decode execute per step, prefix-cache KV
//!   sharing, chunked prefill, KV trimming, per-request latency
//!   accounting.
//! - [`traffic`]: seeded synthetic serving load (Zipf prompt-prefix
//!   reuse, Poisson arrivals, mixed lengths) plus the latency/goodput
//!   assessment behind `BENCH_serve.json` and `munit traffic`.
//! - [`transfer`]: width-transfer measurement harness — coordinate
//!   checks (per-op RMS across widths via the telemetry sink) and
//!   LR-transfer sweeps; backs `munit coordcheck` / `munit transfer` and
//!   their `REPORT_*.json` outputs.
//! - [`metrics`]: JSONL run logging.

/// Binary checkpoint save/load for `TrainState`.
pub mod checkpoint;
/// Collective primitives: deterministic folds + wire formats with byte
/// and FP8-health accounting.
pub mod collective;
/// Simulated multi-worker data parallelism.
pub mod ddp;
/// GPipe fill/drain microbatch schedule over pipeline stages.
pub mod gpipe;
/// JSONL run logging.
pub mod metrics;
/// Background data generation with bounded-channel backpressure.
pub mod pipeline;
/// Continuous-batching inference scheduler.
pub mod serve;
/// Sharded execution: tensor + pipeline parallelism with FP8 collectives.
pub mod shard;
/// Hyperparameter grid engine (threaded workers, optimal subsets).
pub mod sweep;
/// Synthetic serving traffic (Zipf prefixes, Poisson arrivals).
pub mod traffic;
/// Single-model training loop over device-resident sessions.
pub mod trainer;
/// Width-transfer measurement harness (coordinate checks + LR sweeps).
pub mod transfer;

pub use shard::{ShardOpts, ShardRun, ShardSpec};
pub use trainer::{RunResult, TrainState, Trainer};
