//! Synthetic serving traffic: Zipf prompt-prefix reuse, Poisson
//! arrivals, mixed lengths — the workload behind `BENCH_serve` and the
//! CLI `traffic` subcommand.
//!
//! Real serving load has two structures the uniform
//! [`synthetic_requests`](super::serve::synthetic_requests) set lacks:
//! prompt prefixes repeat (system prompts, few-shot preambles) with a
//! heavy-tailed popularity distribution, and arrivals cluster. The
//! generator models both — a pool of `prefix_pool` distinct prefixes
//! drawn by Zipf rank per request, and inter-arrival gaps drawn from an
//! exponential via inverse-CDF over the crate's [`Rng`] — so the serve
//! loop's prefix cache and chunked prefill face the load they were
//! built for. Every third request is a short prefix-free prompt, so a
//! mixed-length tail rides along.
//!
//! Everything is seeded and wall-clock-free: the same
//! [`TrafficConfig`] always yields the same request set (the
//! determinism-contract linter bans entropy sources in kernels; the
//! generator follows the same discipline so benches replay exactly).
//! [`assess`] folds a [`ServeReport`] into the latency/goodput summary
//! (`p50`/`p99` over nearest-rank [`percentile`]) that the bench
//! baselines gate on.

use std::time::Duration;

use crate::bail;
use crate::config::ModelConfig;
use crate::coordinator::serve::{Request, Sampling, ServeReport};
use crate::util::error::Result;
use crate::util::json::Json;
use crate::util::rng::{Rng, Zipf};
use crate::util::stats::percentile;

/// Synthetic workload knobs. Defaults fit the reference
/// `ModelConfig::default()` context (128 positions).
#[derive(Debug, Clone)]
pub struct TrafficConfig {
    /// Requests in the workload.
    pub n_requests: usize,
    /// Mean arrivals per scheduler step (Poisson; higher = burstier
    /// queues).
    pub arrival_rate: f64,
    /// Distinct shared prompt prefixes in the pool.
    pub prefix_pool: usize,
    /// Zipf skew over prefix popularity (1.0–1.5 is web-like reuse).
    pub zipf_s: f64,
    /// Tokens per shared prefix.
    pub prefix_len: usize,
    /// Longest private suffix appended after a shared prefix.
    pub suffix_max: usize,
    /// Largest per-request generation budget.
    pub max_new: usize,
    /// Workload seed (requests, lengths, arrivals all derive from it).
    pub seed: u64,
}

impl Default for TrafficConfig {
    fn default() -> Self {
        TrafficConfig {
            n_requests: 32,
            arrival_rate: 1.5,
            prefix_pool: 4,
            zipf_s: 1.2,
            prefix_len: 40,
            suffix_max: 8,
            max_new: 8,
            seed: 17,
        }
    }
}

/// Generate the seeded request set. Fails when the longest possible
/// request (`prefix_len + suffix_max + max_new`) exceeds the model's
/// context capacity, rather than silently truncating the workload.
pub fn generate(cfg: &ModelConfig, tc: &TrafficConfig) -> Result<Vec<Request>> {
    if tc.n_requests == 0 || tc.prefix_pool == 0 || tc.max_new == 0 || tc.prefix_len == 0 {
        bail!("traffic: n_requests, prefix_pool, prefix_len and max_new must be positive");
    }
    if tc.arrival_rate <= 0.0 || !tc.arrival_rate.is_finite() {
        bail!("traffic: arrival_rate must be positive, got {}", tc.arrival_rate);
    }
    let longest = tc.prefix_len + tc.suffix_max + tc.max_new;
    if longest > cfg.seq_len {
        bail!(
            "traffic: prefix {} + suffix {} + max_new {} exceeds context capacity {}",
            tc.prefix_len,
            tc.suffix_max,
            tc.max_new,
            cfg.seq_len
        );
    }
    let mut rng = Rng::new(tc.seed ^ 0x7AFF_1C);
    let zipf = Zipf::new(tc.prefix_pool, tc.zipf_s);
    // the shared prefix pool: distinct by construction (first token
    // encodes the pool index)
    let prefixes: Vec<Vec<i32>> = (0..tc.prefix_pool)
        .map(|p| {
            (0..tc.prefix_len)
                .map(|t| if t == 0 { (p % cfg.vocab) as i32 } else { rng.below(cfg.vocab) as i32 })
                .collect()
        })
        .collect();
    let mut arrival = 0.0f64;
    let requests = (0..tc.n_requests as u64)
        .map(|id| {
            // Poisson process: exponential inter-arrival via inverse CDF
            arrival += -(1.0 - rng.f64()).ln() / tc.arrival_rate;
            let prompt = if id % 3 == 2 {
                // mixed-length tail: short prompt; its first token
                // (vocab−1) stays off every pool prefix's first token
                let n = 2 + rng.below(tc.suffix_max.max(1));
                let mut p = vec![(cfg.vocab - 1) as i32; n];
                for v in p.iter_mut().skip(1) {
                    *v = rng.below(cfg.vocab) as i32;
                }
                p
            } else {
                let mut p = prefixes[zipf.sample(&mut rng)].clone();
                let n = 1 + rng.below(tc.suffix_max.max(1));
                p.extend((0..n).map(|_| rng.below(cfg.vocab) as i32));
                p
            };
            Request {
                id,
                prompt,
                max_new_tokens: 1 + rng.below(tc.max_new),
                arrival_step: arrival as usize,
                stop_token: None,
                sampling: if id % 2 == 0 {
                    Sampling::Greedy
                } else {
                    Sampling::TopK { k: 4, temperature: 1.0, seed: tc.seed ^ (0xC0DE + id) }
                },
            }
        })
        .collect();
    Ok(requests)
}

/// Latency/goodput summary of one drained workload — the row shape
/// `BENCH_serve.json` gates on.
#[derive(Debug, Clone)]
pub struct TrafficReport {
    /// Requests drained.
    pub n_requests: usize,
    /// Scheduler steps taken.
    pub steps: usize,
    /// Median wall time queued before admission (ms).
    pub p50_queue_ms: f32,
    /// 99th-percentile queue time (ms).
    pub p99_queue_ms: f32,
    /// Median arrival→first-token wall time (queue + prefill + first
    /// sample, ms).
    pub p50_first_token_ms: f32,
    /// 99th-percentile arrival→first-token time (ms) — the latency
    /// chunked prefill exists to bound.
    pub p99_first_token_ms: f32,
    /// Median arrival→finish wall time (ms).
    pub p50_total_ms: f32,
    /// 99th-percentile arrival→finish time (ms).
    pub p99_total_ms: f32,
    /// Generated tokens per second of drain wall time.
    pub goodput_tok_per_sec: f64,
    /// Fraction of prompt tokens served from shared KV slabs.
    pub prefix_hit_rate: f64,
    /// Prompt tokens actually computed (cache hits excluded).
    pub prefill_tokens: u64,
    /// Tokens decoded.
    pub decode_tokens: u64,
    /// Peak resident KV bytes.
    pub kv_high_water_bytes: usize,
    /// Resident KV bytes after the drain.
    pub kv_current_bytes: usize,
}

fn ms(d: Duration) -> f32 {
    (d.as_secs_f64() * 1e3) as f32
}

/// Fold a [`ServeReport`] into the latency/goodput summary. Latencies
/// are measured from request arrival (the instant the scheduler first
/// saw it), so queueing delay counts against first-token and total.
pub fn assess(report: &ServeReport) -> TrafficReport {
    let queue: Vec<f32> = report.completions.iter().map(|c| ms(c.queue_latency)).collect();
    let first: Vec<f32> = report
        .completions
        .iter()
        .map(|c| ms(c.queue_latency + c.first_token_latency))
        .collect();
    let total: Vec<f32> =
        report.completions.iter().map(|c| ms(c.queue_latency + c.total_latency)).collect();
    let prompt_tokens: u64 = report.completions.iter().map(|c| c.prompt_len as u64).sum();
    let generated: u64 = report.completions.iter().map(|c| c.tokens.len() as u64).sum();
    let pct = |xs: &[f32], p: f64| if xs.is_empty() { 0.0 } else { percentile(xs, p) };
    TrafficReport {
        n_requests: report.completions.len(),
        steps: report.steps,
        p50_queue_ms: pct(&queue, 50.0),
        p99_queue_ms: pct(&queue, 99.0),
        p50_first_token_ms: pct(&first, 50.0),
        p99_first_token_ms: pct(&first, 99.0),
        p50_total_ms: pct(&total, 50.0),
        p99_total_ms: pct(&total, 99.0),
        goodput_tok_per_sec: generated as f64 / report.wall.as_secs_f64().max(1e-9),
        prefix_hit_rate: report.prefix_hit_tokens as f64 / prompt_tokens.max(1) as f64,
        prefill_tokens: report.prefill_tokens,
        decode_tokens: report.decode_tokens,
        kv_high_water_bytes: report.kv_high_water_bytes,
        kv_current_bytes: report.kv_current_bytes,
    }
}

/// One `BENCH_serve.json` row for a labeled serving configuration.
pub fn report_json(config: &str, label: &str, r: &TrafficReport) -> Json {
    Json::obj(vec![
        ("config", Json::str(config)),
        ("bench", Json::str(label)),
        ("n_requests", Json::num(r.n_requests as f64)),
        ("steps", Json::num(r.steps as f64)),
        ("p50_queue_ms", Json::num(r.p50_queue_ms as f64)),
        ("p99_queue_ms", Json::num(r.p99_queue_ms as f64)),
        ("p50_first_token_ms", Json::num(r.p50_first_token_ms as f64)),
        ("p99_first_token_ms", Json::num(r.p99_first_token_ms as f64)),
        ("p50_total_ms", Json::num(r.p50_total_ms as f64)),
        ("p99_total_ms", Json::num(r.p99_total_ms as f64)),
        ("goodput_tok_per_sec", Json::num(r.goodput_tok_per_sec)),
        ("prefix_hit_rate", Json::num(r.prefix_hit_rate)),
        ("prefill_tokens", Json::num(r.prefill_tokens as f64)),
        ("decode_tokens", Json::num(r.decode_tokens as f64)),
        ("kv_high_water_bytes", Json::num(r.kv_high_water_bytes as f64)),
        ("kv_current_bytes", Json::num(r.kv_current_bytes as f64)),
    ])
}

/// Human-readable one-workload summary (CLI `traffic`).
pub fn summary_table(label: &str, r: &TrafficReport) -> String {
    format!(
        "  {label}\n    requests {:>4}  steps {:>5}  goodput {:>9.1} tok/s\n    \
         queue p50/p99 {:>8.2}/{:>8.2} ms   first-token p50/p99 {:>8.2}/{:>8.2} ms\n    \
         total p50/p99 {:>8.2}/{:>8.2} ms   prefix-hit {:>5.1}%  computed prefill {:>6}\n    \
         kv high-water {:>8} B  resident {:>8} B\n",
        r.n_requests,
        r.steps,
        r.goodput_tok_per_sec,
        r.p50_queue_ms,
        r.p99_queue_ms,
        r.p50_first_token_ms,
        r.p99_first_token_ms,
        r.p50_total_ms,
        r.p99_total_ms,
        100.0 * r.prefix_hit_rate,
        r.prefill_tokens,
        r.kv_high_water_bytes,
        r.kv_current_bytes,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::serve::{serve, ServeConfig};
    use crate::runtime::InferSession;

    fn cfg() -> ModelConfig {
        ModelConfig {
            width: 16,
            depth: 2,
            head_dim: 8,
            vocab: 64,
            seq_len: 96,
            batch: 2,
            ..ModelConfig::default()
        }
    }

    fn tc() -> TrafficConfig {
        TrafficConfig {
            n_requests: 12,
            prefix_len: 40,
            suffix_max: 6,
            max_new: 4,
            ..TrafficConfig::default()
        }
    }

    fn session(cfg: &ModelConfig, seed: i32) -> InferSession {
        let params = crate::runtime::block::init_params(cfg, seed);
        InferSession::from_params(cfg, params, 0.4).unwrap()
    }

    #[test]
    fn generator_is_seeded_and_structured() {
        let cfg = cfg();
        let a = generate(&cfg, &tc()).unwrap();
        let b = generate(&cfg, &tc()).unwrap();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.prompt, y.prompt, "same seed must replay the same workload");
            assert_eq!(x.arrival_step, y.arrival_step);
            assert_eq!(x.max_new_tokens, y.max_new_tokens);
        }
        let c = generate(&cfg, &TrafficConfig { seed: 18, ..tc() }).unwrap();
        assert!(
            a.iter().zip(&c).any(|(x, y)| x.prompt != y.prompt),
            "different seeds must differ"
        );
        // arrivals are nondecreasing (a Poisson process, not a shuffle)
        for w in a.windows(2) {
            assert!(w[1].arrival_step >= w[0].arrival_step);
        }
        // the Zipf pool genuinely repeats prefixes, and the mixed-length
        // tail rides along
        let long = a.iter().filter(|r| r.prompt.len() > tc().prefix_len).count();
        let short = a.iter().filter(|r| r.prompt.len() <= tc().suffix_max + 2).count();
        assert!(long >= 2 && short >= 2, "mixed lengths: {long} long, {short} short");
        // pool prefixes are keyed by their first token: with more long
        // requests than pool entries, some prefix must repeat
        let mut counts = vec![0usize; cfg.vocab];
        for r in &a {
            if r.prompt.len() > tc().prefix_len {
                counts[r.prompt[0] as usize] += 1;
            }
        }
        let reuse = counts.iter().copied().max().unwrap_or(0);
        assert!(reuse >= 2, "Zipf pool prefixes must repeat, got max reuse {reuse}");
        // capacity guard rejects oversized workloads
        assert!(generate(&cfg, &TrafficConfig { prefix_len: 96, ..tc() }).is_err());
    }

    /// Tentpole acceptance on the Zipf workload: the prefix cache
    /// strictly reduces prompt tokens computed while generating the
    /// exact same tokens, and the hit rate is positive.
    #[test]
    fn zipf_workload_prefix_cache_reduces_computed_prefill() {
        let cfg = cfg();
        let requests = generate(&cfg, &tc()).unwrap();
        let toks = |r: &ServeReport| {
            r.completions.iter().map(|c| c.tokens.clone()).collect::<Vec<_>>()
        };
        let mut off = session(&cfg, 8);
        let base =
            serve(&mut off, &requests, &ServeConfig { max_batch: 4, ..Default::default() })
                .unwrap();
        let mut on = session(&cfg, 8);
        let sc = ServeConfig { max_batch: 4, prefix_cache: true, ..Default::default() };
        let cached = serve(&mut on, &requests, &sc).unwrap();
        assert_eq!(toks(&cached), toks(&base), "prefix cache changed generation");
        assert!(
            cached.prefill_tokens < base.prefill_tokens,
            "caching must strictly reduce computed prefill: {} vs {}",
            cached.prefill_tokens,
            base.prefill_tokens
        );
        let tr = assess(&cached);
        assert!(tr.prefix_hit_rate > 0.0, "Zipf reuse must produce hits");
        assert_eq!(tr.prefill_tokens + cached.prefix_hit_tokens, base.prefill_tokens);
        assert_eq!(tr.n_requests, requests.len());
        assert!(tr.goodput_tok_per_sec > 0.0);
        assert!(tr.p99_first_token_ms >= tr.p50_first_token_ms);
        assert!(tr.p99_total_ms >= tr.p50_total_ms);
        // the JSON row carries the gated fields
        let row = report_json(&cfg.name(), "serve:prefix_cache", &tr);
        assert!(row.get("goodput_tok_per_sec").and_then(|j| j.as_f64()).unwrap() > 0.0);
        assert!(row.get("prefix_hit_rate").and_then(|j| j.as_f64()).unwrap() > 0.0);
        assert!(summary_table("prefix", &tr).contains("prefix-hit"));
    }
}
