//! Training loop over a `train_step` artifact.
//!
//! The artifact owns the math (fwd/bwd, Lion, transfer multipliers); this
//! loop owns policy: schedules, divergence detection, spike counting,
//! metrics, probes. State lives as host literals between steps (CPU PJRT
//! "device" memory is host memory; `execute` copies in/out — see
//! DESIGN.md §7 for the measured overhead).

use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};
use xla::Literal;

use crate::config::{ModelConfig, TrainConfig};
use crate::data::Batcher;
use crate::runtime::{lit_i32, scalar_f32, scalar_i32, to_f32_scalar, Engine};
use crate::util::stats::Ema;

/// Model + optimizer state: `2 * n_params` literals in manifest order
/// (params then momentum), all f32 master copies.
pub struct TrainState {
    pub literals: Vec<Literal>,
    pub n_params: usize,
}

impl TrainState {
    pub fn params(&self) -> &[Literal] {
        &self.literals[..self.n_params]
    }
}

/// Per-step record.
#[derive(Debug, Clone)]
pub struct StepMetrics {
    pub step: usize,
    pub loss: f32,
    pub gnorm: f32,
    pub lr: f64,
    pub step_time: Duration,
}

/// Outcome of a training run.
#[derive(Debug, Clone)]
pub struct RunResult {
    pub losses: Vec<f32>,
    pub gnorms: Vec<f32>,
    pub steps_done: usize,
    pub diverged: bool,
    pub spikes: usize,
    pub wall: Duration,
    pub tokens_per_sec: f64,
}

impl RunResult {
    /// Final train loss averaged over the last `k` steps (the paper's
    /// convergence metric, §3.2 "avg over last ~40M tokens").
    pub fn final_loss(&self, k: usize) -> f32 {
        if self.losses.is_empty() {
            return f32::NAN;
        }
        let k = k.min(self.losses.len());
        let tail = &self.losses[self.losses.len() - k..];
        tail.iter().sum::<f32>() / k as f32
    }
}

/// Drives one (config, artifact) pair.
pub struct Trainer<'e> {
    pub engine: &'e Engine,
    pub cfg: ModelConfig,
    train_name: String,
    init_name: String,
    n_params: usize,
}

impl<'e> Trainer<'e> {
    pub fn new(engine: &'e Engine, cfg: &ModelConfig) -> Result<Trainer<'e>> {
        let train = engine
            .manifest
            .find_for("train_step", cfg)
            .with_context(|| format!("no train artifact for config {}", cfg.name()))?;
        let init = engine
            .manifest
            .find_for("init", cfg)
            .with_context(|| format!("no init artifact for config {}", cfg.name()))?;
        let n_params = (train.inputs.len() - 4) / 2;
        if train.inputs.len() != 2 * n_params + 4 || train.outputs.len() != 2 * n_params + 2 {
            bail!("unexpected train_step ABI for {}", cfg.name());
        }
        Ok(Trainer {
            engine,
            cfg: cfg.clone(),
            train_name: train.name.clone(),
            init_name: init.name.clone(),
            n_params,
        })
    }

    pub fn n_params_tensors(&self) -> usize {
        self.n_params
    }

    pub fn train_artifact(&self) -> &str {
        &self.train_name
    }

    /// Initialize state by running the `init` artifact (unit-variance or
    /// sigma_init inits happen in-graph — L3 never hand-rolls init math).
    pub fn init(&self, seed: i32) -> Result<TrainState> {
        let outs = self.engine.run(&self.init_name, &[scalar_i32(seed)])?;
        if outs.len() != 2 * self.n_params {
            bail!("init produced {} tensors, expected {}", outs.len(), 2 * self.n_params);
        }
        Ok(TrainState { literals: outs, n_params: self.n_params })
    }

    /// One optimizer step. `lr` is the base-width learning rate for this
    /// step (scheduling already applied); tokens length must be batch*seq.
    pub fn step(
        &self,
        state: &mut TrainState,
        tokens: &[i32],
        lr: f64,
        wd: f64,
        tau: f64,
    ) -> Result<(f32, f32)> {
        let tok = lit_i32(tokens, &[self.cfg.batch, self.cfg.seq_len])?;
        let scalars = [scalar_f32(lr as f32), scalar_f32(wd as f32), scalar_f32(tau as f32)];
        let mut inputs: Vec<&Literal> = Vec::with_capacity(state.literals.len() + 4);
        inputs.extend(state.literals.iter());
        inputs.push(&tok);
        inputs.extend(scalars.iter());
        let mut outs = self.engine.run(&self.train_name, &inputs)?;
        let gnorm = to_f32_scalar(&outs.pop().unwrap())?;
        let loss = to_f32_scalar(&outs.pop().unwrap())?;
        state.literals = outs;
        Ok((loss, gnorm))
    }

    /// Full training run: schedule, divergence guard, spike counter.
    /// `on_step` fires after every step (metrics/probes/checkpoints).
    pub fn run_with<F>(
        &self,
        tc: &TrainConfig,
        batcher: &mut Batcher,
        mut on_step: F,
    ) -> Result<RunResult>
    where
        F: FnMut(&StepMetrics, &TrainState),
    {
        let mut state = self.init(tc.init_seed)?;
        let mut losses = Vec::with_capacity(tc.steps);
        let mut gnorms = Vec::with_capacity(tc.steps);
        let mut ema = Ema::new(0.1);
        let mut spikes = 0usize;
        let mut diverged = false;
        let t0 = Instant::now();
        for step in 0..tc.steps {
            let lr = tc.schedule.lr_at(tc.lr, step, tc.steps);
            let tokens = batcher.next_batch();
            let ts = Instant::now();
            let (loss, gnorm) = self.step(&mut state, &tokens, lr, tc.wd, tc.tau)?;
            let m = StepMetrics { step, loss, gnorm, lr, step_time: ts.elapsed() };
            losses.push(loss);
            gnorms.push(gnorm);
            if let Some(prev) = ema.get() {
                if (loss as f64) > prev + tc.spike_threshold {
                    spikes += 1;
                }
            }
            ema.update(loss as f64);
            on_step(&m, &state);
            if !loss.is_finite() || loss as f64 > tc.max_loss {
                diverged = true;
                break;
            }
        }
        let wall = t0.elapsed();
        let steps_done = losses.len();
        let tokens_per_sec =
            (steps_done * batcher.tokens_per_batch()) as f64 / wall.as_secs_f64().max(1e-9);
        Ok(RunResult { losses, gnorms, steps_done, diverged, spikes, wall, tokens_per_sec })
    }

    /// Convenience: run without a step hook.
    pub fn run(&self, tc: &TrainConfig, batcher: &mut Batcher) -> Result<RunResult> {
        self.run_with(tc, batcher, |_, _| {})
    }
}

