//! Training loop over a `train_step` artifact, via the runtime [`Session`].
//!
//! The artifact owns the math (fwd/bwd, Lion, transfer multipliers); this
//! loop owns policy: schedules, divergence detection, spike counting,
//! metrics, probes. State stays *device-resident* between steps — the
//! per-step host traffic is the token batch + 3 scalars in and two scalars out; use
//! [`Session::read_back`] (available to the `on_step` hook) only at
//! checkpoint/probe boundaries.

use std::time::{Duration, Instant};

use crate::config::{ModelConfig, TrainConfig};
use crate::data::Batcher;
use crate::runtime::{Backend, Session, StatePrecision};
use crate::util::error::Result;
use crate::util::stats::Ema;

pub use crate::runtime::TrainState;

/// Per-step record.
#[derive(Debug, Clone)]
pub struct StepMetrics {
    /// Step index (0-based).
    pub step: usize,
    /// Mean next-token loss of the step's batch.
    pub loss: f32,
    /// Global gradient norm.
    pub gnorm: f32,
    /// Learning rate the schedule applied this step.
    pub lr: f64,
    /// Wall time of the step (host side).
    pub step_time: Duration,
}

/// Outcome of a training run.
#[derive(Debug, Clone)]
pub struct RunResult {
    /// Per-step losses (length = `steps_done`).
    pub losses: Vec<f32>,
    /// Per-step gradient norms.
    pub gnorms: Vec<f32>,
    /// Steps completed (shorter than requested on divergence).
    pub steps_done: usize,
    /// Did the divergence guard fire?
    pub diverged: bool,
    /// Loss spikes counted over the run.
    pub spikes: usize,
    /// Total wall time.
    pub wall: Duration,
    /// Training throughput over the run.
    pub tokens_per_sec: f64,
}

impl RunResult {
    /// Final train loss averaged over the last `k` steps (the paper's
    /// convergence metric, §3.2 "avg over last ~40M tokens").
    pub fn final_loss(&self, k: usize) -> f32 {
        if self.losses.is_empty() {
            return f32::NAN;
        }
        let k = k.min(self.losses.len());
        let tail = &self.losses[self.losses.len() - k..];
        tail.iter().sum::<f32>() / k as f32
    }
}

/// Drives one (config, backend) pair. Thin policy layer: sessions carry
/// the device-resident state, the trainer carries schedule/guard logic.
pub struct Trainer<'b> {
    backend: &'b dyn Backend,
    /// The model configuration this trainer drives.
    pub cfg: ModelConfig,
    train_name: String,
    n_params: usize,
    state_precision: StatePrecision,
}

impl<'b> Trainer<'b> {
    /// Resolve and validate the config's artifacts on `backend`. State is
    /// stored at f32 (the bit-compat default); see
    /// [`Trainer::with_state_precision`] for the FP8 state policy.
    pub fn new(backend: &'b dyn Backend, cfg: &ModelConfig) -> Result<Trainer<'b>> {
        Trainer::with_state_precision(backend, cfg, StatePrecision::F32)
    }

    /// [`Trainer::new`] under an explicit [`StatePrecision`]: every
    /// session this trainer builds stores optimizer + master state under
    /// that policy (`fp8` = E4M3 momentum + BF16 masters, 3 B/param
    /// element, reported by the session's `ExecStats` gauges).
    pub fn with_state_precision(
        backend: &'b dyn Backend,
        cfg: &ModelConfig,
        state_precision: StatePrecision,
    ) -> Result<Trainer<'b>> {
        // Session::with_precision performs artifact resolution + ABI
        // validation for the policy's train-step kind.
        let probe = Session::with_precision(backend, cfg, state_precision)?;
        Ok(Trainer {
            backend,
            cfg: cfg.clone(),
            train_name: probe.train_artifact().to_string(),
            n_params: probe.n_params_tensors(),
            state_precision,
        })
    }

    /// The state-storage policy this trainer's sessions run under.
    pub fn state_precision(&self) -> StatePrecision {
        self.state_precision
    }

    /// The backend this trainer resolves against.
    pub fn backend(&self) -> &'b dyn Backend {
        self.backend
    }

    /// Parameter-tensor count of the model (state = 2x this).
    pub fn n_params_tensors(&self) -> usize {
        self.n_params
    }

    /// Name of the resolved `train_step` artifact.
    pub fn train_artifact(&self) -> &str {
        &self.train_name
    }

    /// Fresh session with state initialized on-device from `seed`.
    pub fn init(&self, seed: i32) -> Result<Session<'b>> {
        let mut s = Session::with_precision(self.backend, &self.cfg, self.state_precision)?;
        s.init(seed)?;
        Ok(s)
    }

    /// Fresh session loaded from a host snapshot (checkpoint resume).
    pub fn session_from(&self, state: &TrainState) -> Result<Session<'b>> {
        let mut s = Session::with_precision(self.backend, &self.cfg, self.state_precision)?;
        s.load_state(state)?;
        Ok(s)
    }

    /// Core loop: returns the metrics and the live session (still holding
    /// the final device-resident state).
    fn run_loop<F>(
        &self,
        tc: &TrainConfig,
        batcher: &mut Batcher,
        mut on_step: F,
    ) -> Result<(RunResult, Session<'b>)>
    where
        F: FnMut(&StepMetrics, &Session<'b>),
    {
        let mut session = self.init(tc.init_seed)?;
        let mut losses = Vec::with_capacity(tc.steps);
        let mut gnorms = Vec::with_capacity(tc.steps);
        let mut ema = Ema::new(0.1);
        let mut spikes = 0usize;
        let mut diverged = false;
        let t0 = Instant::now();
        for step in 0..tc.steps {
            let lr = tc.schedule.lr_at(tc.lr, step, tc.steps);
            let tokens = batcher.next_batch();
            let ts = Instant::now();
            let (loss, gnorm) = session.step(&tokens, lr, tc.wd, tc.tau)?;
            let m = StepMetrics { step, loss, gnorm, lr, step_time: ts.elapsed() };
            losses.push(loss);
            gnorms.push(gnorm);
            if let Some(prev) = ema.get() {
                if (loss as f64) > prev + tc.spike_threshold {
                    spikes += 1;
                }
            }
            ema.update(loss as f64);
            on_step(&m, &session);
            if !loss.is_finite() || loss as f64 > tc.max_loss {
                diverged = true;
                break;
            }
        }
        let wall = t0.elapsed();
        let steps_done = losses.len();
        let tokens_per_sec =
            (steps_done * batcher.tokens_per_batch()) as f64 / wall.as_secs_f64().max(1e-9);
        let result =
            RunResult { losses, gnorms, steps_done, diverged, spikes, wall, tokens_per_sec };
        Ok((result, session))
    }

    /// Full training run: schedule, divergence guard, spike counter.
    /// `on_step` fires after every step; it receives the live session and
    /// may `read_back()` state at probe/checkpoint boundaries.
    pub fn run_with<F>(
        &self,
        tc: &TrainConfig,
        batcher: &mut Batcher,
        on_step: F,
    ) -> Result<RunResult>
    where
        F: FnMut(&StepMetrics, &Session<'b>),
    {
        self.run_loop(tc, batcher, on_step).map(|(r, _)| r)
    }

    /// Convenience: run without a step hook.
    pub fn run(&self, tc: &TrainConfig, batcher: &mut Batcher) -> Result<RunResult> {
        self.run_with(tc, batcher, |_, _| {})
    }

    /// Run and also return the trained state as a host snapshot — exactly
    /// one full-state transfer, at the end of the run. `on_step` fires
    /// after every step, like [`Trainer::run_with`].
    pub fn run_capture<F>(
        &self,
        tc: &TrainConfig,
        batcher: &mut Batcher,
        on_step: F,
    ) -> Result<(RunResult, TrainState)>
    where
        F: FnMut(&StepMetrics, &Session<'b>),
    {
        let (r, session) = self.run_loop(tc, batcher, on_step)?;
        let state = session.read_back()?;
        Ok((r, state))
    }
}
