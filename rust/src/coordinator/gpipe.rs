//! GPipe-style pipeline schedule: fill/drain microbatching over stages.
//!
//! Pipeline parallelism here is a *schedule + traffic model* layered on
//! the sharded trainer: layers are partitioned into contiguous stages,
//! the batch is split into microbatches, and each step is accounted as
//! the classic fill/drain timetable — all forward microbatches flow
//! through the stages, then all backwards drain in reverse (GPipe;
//! activations for the backward are assumed stashed per stage). The
//! scheduler is the source of truth for *when* stage boundaries are
//! crossed, and the sharded trainer counts one activation (or
//! activation-gradient) payload per crossing — which is why the
//! measured bytes match `perfmodel`'s closed form exactly and are
//! independent of the microbatch count (more microbatches = more,
//! proportionally smaller, sends).
//!
//! Useful identities (tested):
//! - slots: `2·m·s` for `m` microbatches over `s` stages;
//! - makespan: `2·(m + s − 1)` stage-ticks;
//! - bubble fraction: `(s − 1) / (m + s − 1)` of each direction idles.

use std::ops::Range;

/// Direction of a scheduled slot.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// Forward microbatch through a stage.
    Fwd,
    /// Backward microbatch through a stage.
    Bwd,
}

/// One (tick, stage) cell of the pipeline timetable.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Slot {
    /// Discrete time index (stage-ticks from step start).
    pub tick: usize,
    /// Pipeline stage executing this slot.
    pub stage: usize,
    /// Microbatch index being processed.
    pub micro: usize,
    /// Forward or backward.
    pub phase: Phase,
}

/// Contiguous layer ranges per stage. `depth` must be divisible by
/// `stages` (validated by `ShardSpec::validate`).
pub fn stage_layers(depth: usize, stages: usize) -> Vec<Range<usize>> {
    debug_assert!(stages >= 1 && depth % stages == 0);
    let per = depth / stages;
    (0..stages).map(|s| s * per..(s + 1) * per).collect()
}

/// The full fill/drain timetable for one step: forward slots for every
/// (microbatch, stage), then backward slots in reverse microbatch and
/// stage order, starting after the forward drain. Sorted by tick.
pub fn schedule(stages: usize, microbatches: usize) -> Vec<Slot> {
    let (s, m) = (stages, microbatches);
    let mut slots = Vec::with_capacity(2 * m * s);
    for j in 0..m {
        for st in 0..s {
            slots.push(Slot { tick: j + st, stage: st, micro: j, phase: Phase::Fwd });
        }
    }
    let bwd0 = m + s - 1; // forward drain complete
    for j in (0..m).rev() {
        for st in (0..s).rev() {
            let tick = bwd0 + (m - 1 - j) + (s - 1 - st);
            slots.push(Slot { tick, stage: st, micro: j, phase: Phase::Bwd });
        }
    }
    slots.sort_by_key(|sl| (sl.tick, sl.stage));
    slots
}

/// Step length in stage-ticks: `2·(m + s − 1)`.
pub fn makespan(stages: usize, microbatches: usize) -> usize {
    2 * (microbatches + stages - 1)
}

/// Fraction of each direction's timetable a stage spends idle waiting
/// for fill/drain: `(s − 1) / (m + s − 1)` — the GPipe bubble.
pub fn bubble_fraction(stages: usize, microbatches: usize) -> f64 {
    (stages - 1) as f64 / (microbatches + stages - 1) as f64
}

/// Stage-boundary crossings in one step: forward sends from every stage
/// but the last, backward sends from every stage but the first —
/// `2·m·(s − 1)` payloads.
pub fn boundary_sends(slots: &[Slot], stages: usize) -> usize {
    slots
        .iter()
        .filter(|sl| match sl.phase {
            Phase::Fwd => sl.stage + 1 < stages,
            Phase::Bwd => sl.stage > 0,
        })
        .count()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn find(slots: &[Slot], stage: usize, micro: usize, phase: Phase) -> usize {
        slots
            .iter()
            .find(|sl| sl.stage == stage && sl.micro == micro && sl.phase == phase)
            .expect("slot present")
            .tick
    }

    #[test]
    fn schedule_respects_dependencies_and_exclusivity() {
        for (s, m) in [(1usize, 1usize), (2, 2), (2, 4), (4, 4), (3, 6)] {
            let slots = schedule(s, m);
            assert_eq!(slots.len(), 2 * m * s);
            // no stage runs two slots in one tick
            let mut seen = std::collections::HashSet::new();
            for sl in &slots {
                assert!(seen.insert((sl.tick, sl.stage)), "stage double-booked: {sl:?}");
                assert!(sl.tick < makespan(s, m));
            }
            for j in 0..m {
                // forward flows downstream, backward upstream, and the
                // backward of a microbatch follows its forward
                for st in 0..s.saturating_sub(1) {
                    assert!(find(&slots, st, j, Phase::Fwd) < find(&slots, st + 1, j, Phase::Fwd));
                    assert!(find(&slots, st + 1, j, Phase::Bwd) < find(&slots, st, j, Phase::Bwd));
                }
                assert!(find(&slots, s - 1, j, Phase::Fwd) < find(&slots, s - 1, j, Phase::Bwd));
            }
        }
    }

    #[test]
    fn boundary_sends_match_closed_form() {
        for (s, m) in [(1usize, 1usize), (1, 4), (2, 2), (2, 8), (4, 4)] {
            let slots = schedule(s, m);
            assert_eq!(boundary_sends(&slots, s), 2 * m * (s - 1));
        }
    }

    #[test]
    fn stage_layers_partition_depth() {
        let ranges = stage_layers(6, 3);
        assert_eq!(ranges, vec![0..2, 2..4, 4..6]);
        assert_eq!(stage_layers(4, 1), vec![0..4]);
    }

    #[test]
    fn single_stage_has_no_bubble() {
        assert_eq!(bubble_fraction(1, 4), 0.0);
        assert!(bubble_fraction(4, 4) > 0.0);
        assert_eq!(makespan(1, 1), 2);
    }
}
