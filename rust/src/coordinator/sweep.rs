//! Hyperparameter sweep engine (paper Fig 6 / Fig 9 methodology).
//!
//! Grids are swept over powers of two for η and λ (as in §3.1) plus a
//! coarse τ axis. Results are reduced with the paper's App. A.2 rule: the
//! *optimal subset* is every run whose final loss is within `tol` of the
//! sweep optimum.
//!
//! Execution: sequential in-process, or parallel with `n_workers`
//! *threads* sharing one `Backend` (backends are `Send + Sync`; each
//! worker drives its own `Session`, so no process forking is needed).
//! Both paths run each grid point through the same deterministic
//! `run_point`, so parallel results are identical to sequential ones.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::config::{ModelConfig, TrainConfig};
use crate::data::{Batcher, CorpusSpec};
use crate::err;
use crate::runtime::Backend;
use crate::util::error::Result;

/// One grid coordinate: (learning rate, weight decay, residual τ).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SweepPoint {
    /// Base learning rate η.
    pub lr: f64,
    /// Fully-decoupled weight decay λ.
    pub wd: f64,
    /// Fixed-residual coefficient τ.
    pub tau: f64,
}

/// Result of training one grid point.
#[derive(Debug, Clone)]
pub struct SweepOutcome {
    /// The grid coordinate trained.
    pub point: SweepPoint,
    /// Tail-averaged final loss.
    pub final_loss: f64,
    /// Divergence-guard verdict.
    pub diverged: bool,
    /// Loss spikes counted during the run.
    pub spikes: usize,
}

/// Cartesian grid.
pub fn grid(lrs: &[f64], wds: &[f64], taus: &[f64]) -> Vec<SweepPoint> {
    let mut out = Vec::with_capacity(lrs.len() * wds.len() * taus.len());
    for &lr in lrs {
        for &wd in wds {
            for &tau in taus {
                out.push(SweepPoint { lr, wd, tau });
            }
        }
    }
    out
}

/// Powers-of-two axis: 2^lo ..= 2^hi (paper §3.1 sweeps η, λ this way).
pub fn pow2_axis(lo: i32, hi: i32) -> Vec<f64> {
    (lo..=hi).map(|e| 2f64.powi(e)).collect()
}

/// Best (non-diverged) outcome.
pub fn best(outcomes: &[SweepOutcome]) -> Option<&SweepOutcome> {
    outcomes
        .iter()
        .filter(|o| !o.diverged && o.final_loss.is_finite())
        .min_by(|a, b| a.final_loss.partial_cmp(&b.final_loss).unwrap())
}

/// Paper App. A.2: all runs within `tol` (relative) of the optimum.
pub fn optimal_subset(outcomes: &[SweepOutcome], tol: f64) -> Vec<&SweepOutcome> {
    match best(outcomes) {
        None => vec![],
        Some(b) => outcomes
            .iter()
            .filter(|o| {
                !o.diverged
                    && o.final_loss.is_finite()
                    && o.final_loss <= b.final_loss * (1.0 + tol)
            })
            .collect(),
    }
}

/// For Fig 6: the optimal η holding other axes at their overall-best value.
pub fn optimum_along<'a, F>(outcomes: &'a [SweepOutcome], axis: F) -> Option<&'a SweepOutcome>
where
    F: Fn(&SweepPoint) -> f64,
{
    let b = best(outcomes)?;
    outcomes
        .iter()
        .filter(|o| !o.diverged && o.final_loss.is_finite())
        .filter(|o| {
            // same coordinates as the best except along `axis`
            let (p, q) = (o.point, b.point);
            let mut same = 0;
            let mut diff_axis = true;
            for (x, y) in [(p.lr, q.lr), (p.wd, q.wd), (p.tau, q.tau)] {
                if (x - y).abs() < 1e-15 {
                    same += 1;
                } else if (axis(&p) - x).abs() > 1e-15 {
                    diff_axis = false;
                }
            }
            same >= 2 && diff_axis
        })
        .min_by(|a, b| a.final_loss.partial_cmp(&b.final_loss).unwrap())
}

/// Train one grid point. Shared by the sequential and threaded paths so
/// their results are bit-identical (deterministic batcher + backend).
fn run_point(
    backend: &dyn Backend,
    cfg: &ModelConfig,
    base: &TrainConfig,
    corpus: &CorpusSpec,
    p: &SweepPoint,
) -> Result<SweepOutcome> {
    use crate::coordinator::trainer::Trainer;
    let trainer = Trainer::new(backend, cfg)?;
    let tc = TrainConfig { lr: p.lr, wd: p.wd, tau: p.tau, ..base.clone() };
    let mut batcher = Batcher::new(corpus.clone(), base.seed, 0, 1, cfg.batch, cfg.seq_len);
    let r = trainer.run(&tc, &mut batcher)?;
    Ok(SweepOutcome {
        point: *p,
        final_loss: r.final_loss(10) as f64,
        diverged: r.diverged,
        spikes: r.spikes,
    })
}

fn report(i: usize, total: usize, o: &SweepOutcome) {
    eprintln!(
        "  [{}/{}] lr=2^{:.0} wd={:.4} tau={:.2} -> loss {:.4}{}",
        i + 1,
        total,
        o.point.lr.log2(),
        o.point.wd,
        o.point.tau,
        o.final_loss,
        if o.diverged { " DIVERGED" } else { "" }
    );
}

/// Run a grid sequentially in-process.
pub fn run_sequential(
    backend: &dyn Backend,
    cfg: &ModelConfig,
    base: &TrainConfig,
    corpus: &CorpusSpec,
    points: &[SweepPoint],
    verbose: bool,
) -> Result<Vec<SweepOutcome>> {
    let mut out = Vec::with_capacity(points.len());
    for (i, p) in points.iter().enumerate() {
        let o = run_point(backend, cfg, base, corpus, p)?;
        if verbose {
            report(i, points.len(), &o);
        }
        out.push(o);
    }
    Ok(out)
}

/// Run a grid with `n_workers` in-process threads over a shared backend.
/// Workers pull points from a shared queue; outcomes land in grid order
/// and are identical to `run_sequential`'s (deterministic runs — the
/// reference interpreter's internal parallelism is bit-identical at any
/// thread budget, so splitting the budget across workers is safe).
pub fn run_parallel(
    backend: &dyn Backend,
    cfg: &ModelConfig,
    base: &TrainConfig,
    corpus: &CorpusSpec,
    points: &[SweepPoint],
    n_workers: usize,
    verbose: bool,
) -> Result<Vec<SweepOutcome>> {
    let n_workers = n_workers.max(1).min(points.len().max(1));
    // divide the interpreter's worker-thread budget across sweep workers so
    // n_workers concurrent train steps don't oversubscribe the CPU by
    // workers x cores (read on the caller thread: respects its override)
    let threads_per_worker = (crate::util::parallel::max_threads() / n_workers).max(1);
    let next = AtomicUsize::new(0);
    let results: Mutex<Vec<Option<Result<SweepOutcome>>>> =
        Mutex::new((0..points.len()).map(|_| None).collect());
    std::thread::scope(|scope| {
        for _ in 0..n_workers {
            scope.spawn(|| {
                crate::util::parallel::with_max_threads(threads_per_worker, || loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= points.len() {
                        break;
                    }
                    let r = run_point(backend, cfg, base, corpus, &points[i]);
                    if verbose {
                        if let Ok(o) = &r {
                            report(i, points.len(), o);
                        }
                    }
                    results.lock().expect("results lock")[i] = Some(r);
                })
            });
        }
    });
    results
        .into_inner()
        .expect("results lock")
        .into_iter()
        .enumerate()
        .map(|(i, o)| o.unwrap_or_else(|| Err(err!("sweep point {i} produced no result"))))
        .collect()
}

/// Verify a point set covers a full cartesian grid (used by tests and the
/// sweep CLI to catch axis typos before burning compute).
pub fn is_full_grid(points: &[SweepPoint]) -> bool {
    let mut lrs: Vec<f64> = points.iter().map(|p| p.lr).collect();
    let mut wds: Vec<f64> = points.iter().map(|p| p.wd).collect();
    let mut taus: Vec<f64> = points.iter().map(|p| p.tau).collect();
    for v in [&mut lrs, &mut wds, &mut taus] {
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        v.dedup();
    }
    points.len() == lrs.len() * wds.len() * taus.len()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::proptest::check;

    fn o(lr: f64, loss: f64, diverged: bool) -> SweepOutcome {
        SweepOutcome {
            point: SweepPoint { lr, wd: 1e-4, tau: 0.3 },
            final_loss: loss,
            diverged,
            spikes: 0,
        }
    }

    #[test]
    fn grid_is_cartesian() {
        let g = grid(&[1.0, 2.0], &[0.1], &[0.3, 0.4, 0.5]);
        assert_eq!(g.len(), 6);
        assert!(is_full_grid(&g));
    }

    #[test]
    fn pow2_axis_values() {
        assert_eq!(pow2_axis(-3, -1), vec![0.125, 0.25, 0.5]);
    }

    #[test]
    fn best_ignores_diverged() {
        let outs = vec![o(1.0, 1.0, true), o(0.5, 2.0, false), o(0.25, 3.0, false)];
        assert_eq!(best(&outs).unwrap().final_loss, 2.0);
    }

    #[test]
    fn best_handles_all_diverged() {
        let outs = vec![o(1.0, f64::NAN, true)];
        assert!(best(&outs).is_none());
        assert!(optimal_subset(&outs, 0.01).is_empty());
    }

    #[test]
    fn optimal_subset_tolerance() {
        let outs = vec![o(1.0, 2.000, false), o(0.5, 2.004, false), o(0.25, 2.2, false)];
        let sub = optimal_subset(&outs, 0.0025);
        assert_eq!(sub.len(), 2);
    }

    #[test]
    fn prop_grid_size_and_membership() {
        check("grid covers cartesian product", 25, |rng, _| {
            let nl = 1 + rng.below(4);
            let nw = 1 + rng.below(3);
            let nt = 1 + rng.below(3);
            let lrs: Vec<f64> = (0..nl).map(|i| 2f64.powi(-(i as i32) - 1)).collect();
            let wds: Vec<f64> = (0..nw).map(|i| 1e-4 * (i + 1) as f64).collect();
            let taus: Vec<f64> = (0..nt).map(|i| 0.1 * (i + 1) as f64).collect();
            let g = grid(&lrs, &wds, &taus);
            prop_assert!(g.len() == nl * nw * nt, "size mismatch");
            prop_assert!(is_full_grid(&g), "not a full grid");
            let probe = SweepPoint { lr: lrs[nl - 1], wd: wds[0], tau: taus[nt - 1] };
            prop_assert!(g.contains(&probe), "missing corner point");
            Ok(())
        });
    }

    #[test]
    fn prop_optimal_subset_always_contains_best() {
        check("optimal subset contains the optimum", 25, |rng, _| {
            let outs: Vec<SweepOutcome> = (0..8)
                .map(|i| o(2f64.powi(-(i as i32)), 2.0 + rng.f64(), rng.f64() < 0.2))
                .collect();
            if let Some(b) = best(&outs) {
                let sub = optimal_subset(&outs, 0.01);
                prop_assert!(
                    sub.iter().any(|s| s.final_loss == b.final_loss),
                    "best excluded"
                );
                for s in sub {
                    prop_assert!(!s.diverged, "diverged run in optimal subset");
                }
            }
            Ok(())
        });
    }
}
