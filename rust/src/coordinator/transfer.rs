//! Width-transfer measurement harness: coordinate checks + LR-transfer
//! sweeps over the numerics telemetry subsystem.
//!
//! Two experiments, mirroring the paper's two transfer claims:
//!
//!  - **Coordinate check** ([`coordcheck`], `munit coordcheck`): train the
//!    same proxy at several widths (head_dim fixed, so heads scale with
//!    width and the attention softmax temperature is width-invariant) and
//!    capture the final step's per-op telemetry. Under µS every hidden
//!    activation's RMS must sit in a documented O(1) band **independent of
//!    width** (that is why static FP8 casts keep working as the model
//!    grows), and hidden-gradient RMS must follow the predicted `1/d`
//!    power law ([`crate::scaling::Scheme::grad_rms_width_exponent`]).
//!    Under SP the same probes drift with width (qkv output RMS grows as
//!    `σ_init·√d`, the FFN-down output as `∝ d`). The checks quantify
//!    both: band membership and across-width max/min RMS ratios.
//!  - **LR-transfer sweep** ([`lr_transfer`], `munit transfer`): loss-vs-
//!    learning-rate curves per width. µS runs with a fixed `d_base` so its
//!    internal `√(d_base/d)` hidden-LR rule is active — the best *base*
//!    LR must be width-stable. SP runs with `d_base = width` (rules
//!    disabled), showing the raw optimum migrate as width grows.
//!
//! Both emit repro-style aligned tables and a JSON report
//! (`REPORT_coordcheck.json` / `REPORT_transfer.json` at the CLI level —
//! CI asserts they are produced and nonzero). Thresholds and the
//! derivations behind them live in `docs/NUMERICS.md`.

use crate::bail;
use crate::config::{ModelConfig, Schedule, TrainConfig};
use crate::coordinator::trainer::Trainer;
use crate::data::{Batcher, CorpusSpec};
use crate::runtime::Backend;
use crate::telemetry::TelemetryReport;
use crate::util::error::{Context, Result};
use crate::util::json::Json;
use crate::util::table;

/// Forward ops whose RMS must stay in [`ACT_BAND`] across widths under µS
/// (every hidden tensor of the tower; the logits are excluded — their RMS
/// scales as `1/√d` *by design*, the `1/fan_in` head multiplier).
pub const ACT_OPS: &[&str] = &[
    "post_norm1",
    "post_norm2",
    "qkv",
    "post_rope",
    "attn_mix",
    "attn_out",
    "resid1",
    "resid2",
    "ffn_up",
    "ffn_act",
    "ffn_down",
    "final_norm",
];

/// Backward (activation-gradient) ops checked for the µS `1/d` power law.
pub const GRAD_OPS: &[&str] = &["d_qkv", "d_attn_out", "d_ffn_up", "d_ffn_down", "d_resid"];

/// The documented O(1) activation band (see docs/NUMERICS.md §Reading
/// telemetry): µS hidden-tensor RMS sits well inside (0.05, 8.0) at any
/// width — softmax mixing puts attention outputs a factor ~√(e/k) below
/// 1, GELU puts the FFN activation near 0.6, everything else is ≈ 1.
pub const ACT_BAND: (f64, f64) = (0.05, 8.0);

/// Maximum allowed across-width RMS ratio (max/min per op) for µS
/// activations. Theory says ≈ 1 (CLT noise only); 1.5 leaves margin.
pub const MUS_ACT_RATIO_MAX: f64 = 1.5;

/// Minimum across-width RMS ratio SP must exhibit on at least one hidden
/// op (the drift signal): qkv output grows as √(width ratio), FFN-down as
/// the full width ratio, so any ≥4x width span clears 1.8 comfortably.
pub const SP_ACT_RATIO_MIN: f64 = 1.8;

/// Maximum allowed across-width ratio for µS gradient RMS after
/// compensating by the predicted `(d/d_base)^β` power law (β from
/// [`crate::scaling::Scheme::grad_rms_width_exponent`]). Looser than the
/// activation bound: gradients stack more quantization noise.
pub const MUS_GRAD_RATIO_MAX: f64 = 2.5;

/// Maximum octaves the µS best base-LR may move across widths for the
/// transfer check to count as width-stable (paper Fig 6: the optimum
/// stays put; one pow2 notch of slack absorbs short-run noise).
pub const MUS_LR_SPREAD_MAX: f64 = 1.0;

/// Proxy-family description for one harness run: the model shape is fixed
/// except for `width`; `head_dim` is constant so the head count scales
/// with width (the µP-style width scaling the paper uses).
#[derive(Debug, Clone)]
pub struct HarnessConfig {
    /// Widths to measure, ascending; `widths[0]` doubles as µS's `d_base`.
    pub widths: Vec<usize>,
    /// Transformer blocks.
    pub depth: usize,
    /// Per-head dimension (fixed across widths).
    pub head_dim: usize,
    /// Vocabulary size.
    pub vocab: usize,
    /// Sequence length.
    pub seq_len: usize,
    /// Sequences per batch.
    pub batch: usize,
    /// Training steps before the coordinate check's traced step.
    pub coord_steps: usize,
    /// Training steps per LR-transfer grid point.
    pub transfer_steps: usize,
    /// Data/corpus seed shared by every run.
    pub seed: u64,
    /// Fixed-residual coefficient for the µS lane.
    pub tau: f64,
    /// Base learning rate of the µS coordinate-check runs.
    pub mus_lr: f64,
    /// Base learning rate of the SP coordinate-check runs.
    pub sp_lr: f64,
    /// `(lo, hi)` pow2 exponents of the µS transfer LR grid.
    pub mus_lr_exps: (i32, i32),
    /// `(lo, hi)` pow2 exponents of the SP transfer LR grid.
    pub sp_lr_exps: (i32, i32),
}

impl HarnessConfig {
    /// Smoke-sized harness (CI / `--fast` / the unit tests): 3 widths
    /// spanning 4x, depth 2, tiny sequences — seconds, not minutes.
    pub fn smoke() -> HarnessConfig {
        HarnessConfig {
            widths: vec![16, 32, 64],
            depth: 2,
            head_dim: 8,
            vocab: 128,
            seq_len: 32,
            batch: 2,
            coord_steps: 4,
            transfer_steps: 6,
            seed: 0,
            tau: 0.4,
            mus_lr: 1.0 / 64.0,
            sp_lr: 1.0 / 256.0,
            mus_lr_exps: (-8, -3),
            sp_lr_exps: (-10, -5),
        }
    }

    /// Release-sized harness (the CLI default): 4 widths spanning 8x.
    pub fn standard() -> HarnessConfig {
        HarnessConfig {
            widths: vec![32, 64, 128, 256],
            depth: 4,
            head_dim: 16,
            vocab: 256,
            seq_len: 64,
            batch: 4,
            coord_steps: 12,
            transfer_steps: 16,
            seed: 0,
            tau: 0.4,
            mus_lr: 1.0 / 64.0,
            sp_lr: 1.0 / 256.0,
            mus_lr_exps: (-9, -3),
            sp_lr_exps: (-11, -5),
        }
    }

    /// The proxy model at one width. `variant` is `"mus"` (static-FP8,
    /// fixed residuals, Res-Post norms) or `"sp"` (BF16, standard
    /// residuals, Pre norms); `d_base` controls the scheme's internal LR
    /// transfer (pass the width itself to disable it).
    pub fn model(&self, variant: &str, width: usize, d_base: usize) -> Result<ModelConfig> {
        let (precision, residual) = match variant {
            "mus" => ("fp8", "fixed"),
            "sp" => ("bf16", "standard"),
            other => bail!("unknown harness variant '{other}' (mus | sp)"),
        };
        let cfg = ModelConfig {
            width,
            depth: self.depth,
            head_dim: self.head_dim,
            vocab: self.vocab,
            seq_len: self.seq_len,
            batch: self.batch,
            ffn_ratio: 4,
            d_base,
            variant: variant.into(),
            precision: precision.into(),
            residual: residual.into(),
            activation: "gelu".into(),
        };
        cfg.validate().map_err(crate::util::error::Error::msg)?;
        Ok(cfg)
    }

    fn corpus(&self) -> CorpusSpec {
        CorpusSpec { vocab: self.vocab, ..CorpusSpec::default() }
    }
}

// ---------------------------------------------------------------------------
// Coordinate check

/// Final-step telemetry of one (variant, width) run.
#[derive(Debug, Clone)]
pub struct WidthTelemetry {
    /// Model width of this run.
    pub width: usize,
    /// Final training loss at the traced step.
    pub final_loss: f64,
    /// The traced step's full telemetry (per-op RMS + cast health).
    pub report: TelemetryReport,
}

/// One variant's coordinate-check series across widths.
#[derive(Debug, Clone)]
pub struct CoordCheck {
    /// `"mus"` or `"sp"`.
    pub variant: String,
    /// `d_base` the runs trained under (µS LR-transfer reference width).
    pub d_base: usize,
    /// Ascending-width telemetry snapshots.
    pub per_width: Vec<WidthTelemetry>,
}

impl CoordCheck {
    /// `(width, rms)` series of one op, aggregated across layers. Widths
    /// where the op was never recorded are skipped.
    pub fn rms_by_width(&self, op: &str) -> Vec<(usize, f64)> {
        self.per_width
            .iter()
            .filter_map(|w| w.report.op_rms(op).map(|r| (w.width, r)))
            .collect()
    }

    /// Largest across-width max/min RMS ratio over `ops`, after
    /// multiplying each RMS by `(width / d_base)^exponent` (pass 0.0 for
    /// raw ratios). Ops with missing or zero RMS at any width are skipped.
    pub fn max_ratio(&self, ops: &[&str], exponent: f64) -> f64 {
        let mut worst = 1.0f64;
        for &op in ops {
            let series = self.rms_by_width(op);
            if series.len() != self.per_width.len() {
                continue;
            }
            let comp: Vec<f64> = series
                .iter()
                .map(|&(w, r)| r * (w as f64 / self.d_base as f64).powf(exponent))
                .collect();
            let (mut lo, mut hi) = (f64::INFINITY, 0f64);
            for &c in &comp {
                lo = lo.min(c);
                hi = hi.max(c);
            }
            if lo > 0.0 && lo.is_finite() {
                worst = worst.max(hi / lo);
            }
        }
        worst
    }

    /// Do all `ops` sit inside `(lo, hi)` at every width?
    pub fn within_band(&self, ops: &[&str], lo: f64, hi: f64) -> bool {
        ops.iter().all(|&op| {
            let series = self.rms_by_width(op);
            !series.is_empty() && series.iter().all(|&(_, r)| r > lo && r < hi)
        })
    }

    /// Does every op in `ops` have a finite, nonzero RMS record at every
    /// width? Guards the ratio checks against passing vacuously: the op
    /// names here are string literals that must match the `observe_rms`
    /// hook labels in `runtime/block.rs` (a renamed/dropped hook would
    /// otherwise just shrink the measured set), and a NaN RMS would slip
    /// through `max_ratio`'s min/max fold (f64::min/max skip NaN), so
    /// non-finite telemetry must fail here, not pass silently.
    pub fn complete(&self, ops: &[&str]) -> bool {
        ops.iter().all(|&op| {
            let series = self.rms_by_width(op);
            series.len() == self.per_width.len()
                && series.iter().all(|&(_, r)| r.is_finite() && r > 0.0)
        })
    }
}

/// Pass/fail summary of a coordinate check (the JSON `checks` block).
#[derive(Debug, Clone)]
pub struct CoordChecks {
    /// Every tracked op recorded at every width in both variants — the
    /// ratio checks below are meaningless (and would pass vacuously at
    /// their 1.0 initializer) without full coverage.
    pub coverage_complete: bool,
    /// Every µS activation op inside [`ACT_BAND`] at every width.
    pub mus_act_within_band: bool,
    /// Worst across-width RMS ratio over µS activation ops.
    pub mus_act_max_ratio: f64,
    /// Worst across-width RMS ratio over SP activation ops (the drift).
    pub sp_act_max_ratio: f64,
    /// Worst across-width ratio of µS gradient RMS after `(d/d_base)^β`
    /// compensation.
    pub mus_grad_max_ratio_compensated: f64,
    /// All criteria hold (coverage + band + µS flat + SP drifting +
    /// grads on the power law).
    pub pass: bool,
}

/// Full coordinate-check outcome: both variants over the same widths.
#[derive(Debug, Clone)]
pub struct CoordCheckReport {
    /// Widths measured (ascending).
    pub widths: Vec<usize>,
    /// Training steps taken before the traced step.
    pub steps: usize,
    /// µS series (static FP8, Res-Post norms, fixed residuals).
    pub mus: CoordCheck,
    /// SP series (BF16, Pre norms, standard residuals).
    pub sp: CoordCheck,
}

impl CoordCheckReport {
    /// Evaluate the documented thresholds against this report.
    pub fn checks(&self) -> CoordChecks {
        let beta = crate::scaling::Scheme::Mus.grad_rms_width_exponent();
        let coverage_complete = self.mus.complete(ACT_OPS)
            && self.mus.complete(GRAD_OPS)
            && self.sp.complete(ACT_OPS)
            && self.sp.complete(GRAD_OPS);
        let mus_act_within_band = self.mus.within_band(ACT_OPS, ACT_BAND.0, ACT_BAND.1);
        let mus_act_max_ratio = self.mus.max_ratio(ACT_OPS, 0.0);
        let sp_act_max_ratio = self.sp.max_ratio(ACT_OPS, 0.0);
        let mus_grad_max_ratio_compensated = self.mus.max_ratio(GRAD_OPS, beta);
        let pass = coverage_complete
            && mus_act_within_band
            && mus_act_max_ratio <= MUS_ACT_RATIO_MAX
            && sp_act_max_ratio >= SP_ACT_RATIO_MIN
            && mus_grad_max_ratio_compensated <= MUS_GRAD_RATIO_MAX;
        CoordChecks {
            coverage_complete,
            mus_act_within_band,
            mus_act_max_ratio,
            sp_act_max_ratio,
            mus_grad_max_ratio_compensated,
            pass,
        }
    }
}

fn run_traced(
    backend: &dyn Backend,
    cfg: &ModelConfig,
    corpus: &CorpusSpec,
    steps: usize,
    lr: f64,
    tau: f64,
    seed: u64,
) -> Result<WidthTelemetry> {
    if steps == 0 {
        bail!("coordinate check needs at least one training step");
    }
    let trainer = Trainer::new(backend, cfg)?;
    let mut session = trainer.init(0)?;
    let mut batcher = Batcher::new(corpus.clone(), seed, 0, 1, cfg.batch, cfg.seq_len);
    for _ in 0..steps - 1 {
        let tokens = batcher.next_batch();
        let (loss, _) = session.step(&tokens, lr, 0.0, tau)?;
        if !loss.is_finite() {
            bail!("{} diverged during the coordinate check warmup", cfg.name());
        }
    }
    let tokens = batcher.next_batch();
    let (loss, _, report) = session.step_traced(&tokens, lr, 0.0, tau)?;
    if !loss.is_finite() {
        bail!("{} diverged at the traced step", cfg.name());
    }
    if report.is_empty() {
        bail!(
            "backend '{}' recorded no telemetry (not the reference interpreter?)",
            backend.platform()
        );
    }
    Ok(WidthTelemetry { width: cfg.width, final_loss: loss as f64, report })
}

/// Run the coordinate check: train each width of both variants for
/// `hc.coord_steps` steps and capture the final step's telemetry. µS
/// trains under its real recipe (`d_base = widths[0]`, static FP8); SP
/// under its own (BF16, its empirical `d_base/d` LR rule, same `d_base`).
pub fn coordcheck(backend: &dyn Backend, hc: &HarnessConfig) -> Result<CoordCheckReport> {
    if hc.widths.len() < 3 {
        bail!("coordinate check needs >= 3 widths, got {:?}", hc.widths);
    }
    let d_base = hc.widths[0];
    let corpus = hc.corpus();
    let mut variants = Vec::with_capacity(2);
    for (variant, lr) in [("mus", hc.mus_lr), ("sp", hc.sp_lr)] {
        let mut per_width = Vec::with_capacity(hc.widths.len());
        for &w in &hc.widths {
            let cfg = hc.model(variant, w, d_base)?;
            eprintln!("  coordcheck: {} ({} steps)…", cfg.name(), hc.coord_steps);
            per_width.push(
                run_traced(backend, &cfg, &corpus, hc.coord_steps, lr, hc.tau, hc.seed)
                    .with_context(|| format!("coordcheck {variant} w{w}"))?,
            );
        }
        variants.push(CoordCheck { variant: variant.to_string(), d_base, per_width });
    }
    let sp = variants.pop().expect("two variants pushed");
    let mus = variants.pop().expect("two variants pushed");
    Ok(CoordCheckReport { widths: hc.widths.clone(), steps: hc.coord_steps, mus, sp })
}

/// Render one aligned RMS table per variant (rows = ops, columns =
/// widths) plus the µS cast-health summary — the repro-style text output
/// of `munit coordcheck`.
pub fn coordcheck_table(r: &CoordCheckReport) -> String {
    let mut out = String::new();
    for check in [&r.mus, &r.sp] {
        out.push_str(&format!(
            "\n{} per-op RMS at step {} (d_base {}):\n",
            if check.variant == "mus" { "µS (static FP8)" } else { "SP (BF16)" },
            r.steps,
            check.d_base
        ));
        let mut header: Vec<String> = vec!["op".into()];
        header.extend(r.widths.iter().map(|w| format!("w{w}")));
        let header_refs: Vec<&str> = header.iter().map(String::as_str).collect();
        let mut rows = Vec::new();
        for &op in ACT_OPS.iter().chain(GRAD_OPS).chain(&["logits", "d_logits"]) {
            let series = check.rms_by_width(op);
            if series.is_empty() {
                continue;
            }
            let mut row = vec![op.to_string()];
            for &w in &r.widths {
                row.push(match series.iter().find(|&&(sw, _)| sw == w) {
                    Some(&(_, rms)) => format!("{rms:.4}"),
                    None => "-".into(),
                });
            }
            rows.push(row);
        }
        out.push_str(&table::render(&header_refs, &rows));
    }
    // µS cast health at the largest width (the FP8 story)
    if let Some(widest) = r.mus.per_width.last() {
        out.push_str(&format!("\nµS FP8 cast health at w{} (per op, all layers):\n", widest.width));
        let mut rows = Vec::new();
        let mut seen: Vec<&str> = Vec::new();
        for c in &widest.report.casts {
            if seen.contains(&c.op.as_str()) {
                continue;
            }
            seen.push(&c.op);
            let h = widest.report.cast_totals(&c.op).expect("op just seen");
            rows.push(vec![
                c.op.clone(),
                c.format.clone(),
                format!("{:.5}", h.underflow_rate()),
                format!("{:.5}", h.saturation_rate()),
                format!("{:.5}", h.subnormal_rate()),
                h.overflow_nonfinite.to_string(),
            ]);
        }
        out.push_str(&table::render(
            &["op", "fmt", "underflow", "saturate", "subnormal", "nonfinite"],
            &rows,
        ));
    }
    let c = r.checks();
    out.push_str(&format!(
        "\nchecks: µS in ({:.2}, {:.2}) band: {} | µS act ratio {:.3} (max {MUS_ACT_RATIO_MAX}) | \
         SP act ratio {:.3} (min {SP_ACT_RATIO_MIN}) | µS grad ratio (compensated) {:.3} \
         (max {MUS_GRAD_RATIO_MAX}) | pass: {}\n",
        ACT_BAND.0, ACT_BAND.1, c.mus_act_within_band, c.mus_act_max_ratio, c.sp_act_max_ratio,
        c.mus_grad_max_ratio_compensated, c.pass
    ));
    out
}

/// JSON projection of a coordinate check (`REPORT_coordcheck.json`).
pub fn coordcheck_json(r: &CoordCheckReport) -> Json {
    let variant_json = |c: &CoordCheck| -> Json {
        let per_width = c
            .per_width
            .iter()
            .map(|w| {
                // to_json always carries both keys; Null is unreachable
                let t = w.report.to_json();
                Json::obj(vec![
                    ("width", Json::num(w.width as f64)),
                    ("final_loss", Json::num(w.final_loss)),
                    ("ops", t.get("ops").cloned().unwrap_or(Json::Null)),
                    ("casts", t.get("casts").cloned().unwrap_or(Json::Null)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("variant", Json::str(&c.variant)),
            ("d_base", Json::num(c.d_base as f64)),
            ("per_width", Json::Arr(per_width)),
        ])
    };
    let c = r.checks();
    Json::obj(vec![
        ("kind", Json::str("coordcheck")),
        ("widths", Json::Arr(r.widths.iter().map(|&w| Json::num(w as f64)).collect())),
        ("steps", Json::num(r.steps as f64)),
        ("act_band", Json::arr_f64(&[ACT_BAND.0, ACT_BAND.1])),
        ("variants", Json::Arr(vec![variant_json(&r.mus), variant_json(&r.sp)])),
        (
            "checks",
            Json::obj(vec![
                ("coverage_complete", Json::Bool(c.coverage_complete)),
                ("mus_act_within_band", Json::Bool(c.mus_act_within_band)),
                ("mus_act_max_ratio", Json::num(c.mus_act_max_ratio)),
                ("sp_act_max_ratio", Json::num(c.sp_act_max_ratio)),
                (
                    "mus_grad_max_ratio_compensated",
                    Json::num(c.mus_grad_max_ratio_compensated),
                ),
                ("pass", Json::Bool(c.pass)),
            ]),
        ),
    ])
}

// ---------------------------------------------------------------------------
// LR-transfer sweep

/// One grid point of a loss-vs-LR curve.
#[derive(Debug, Clone)]
pub struct LrPoint {
    /// Base learning rate of the run.
    pub lr: f64,
    /// Mean loss over the last few steps (the curve's y value).
    pub final_loss: f64,
    /// Divergence-guard verdict for the run.
    pub diverged: bool,
}

/// Loss-vs-LR curve of one width.
#[derive(Debug, Clone)]
pub struct LrCurve {
    /// Model width of this curve.
    pub width: usize,
    /// Grid points in ascending-LR order.
    pub points: Vec<LrPoint>,
}

impl LrCurve {
    /// Center of the optimal subset in log2-LR space: the mean `log2(lr)`
    /// over all non-diverged points within 2% (relative) of the curve
    /// minimum. A continuous statistic, so octave-grid ties do not
    /// produce knife-edge argmin jumps.
    pub fn best_lr_log2(&self) -> Option<f64> {
        let best = self
            .points
            .iter()
            .filter(|p| !p.diverged && p.final_loss.is_finite())
            .map(|p| p.final_loss)
            .fold(f64::INFINITY, f64::min);
        if !best.is_finite() {
            return None;
        }
        let sel: Vec<f64> = self
            .points
            .iter()
            .filter(|p| !p.diverged && p.final_loss.is_finite())
            .filter(|p| p.final_loss <= best * 1.02)
            .map(|p| p.lr.log2())
            .collect();
        Some(sel.iter().sum::<f64>() / sel.len() as f64)
    }
}

/// One variant's LR-transfer outcome across widths.
#[derive(Debug, Clone)]
pub struct VariantTransfer {
    /// `"mus"` or `"sp"`.
    pub variant: String,
    /// `d_base` used (µS: `widths[0]`, rules active; SP: the width itself,
    /// rules disabled — a raw-LR sweep).
    pub d_base: Vec<usize>,
    /// One loss-vs-LR curve per width, ascending width.
    pub curves: Vec<LrCurve>,
    /// `(width, log2 best-lr)` per width (optimal-subset centers).
    pub best_lr_log2: Vec<(usize, f64)>,
}

impl VariantTransfer {
    /// Max − min of the per-width best log2-LRs (octaves of drift; 0 =
    /// perfectly width-stable).
    pub fn best_spread_log2(&self) -> f64 {
        let (mut lo, mut hi) = (f64::INFINITY, f64::NEG_INFINITY);
        for &(_, b) in &self.best_lr_log2 {
            lo = lo.min(b);
            hi = hi.max(b);
        }
        if lo.is_finite() {
            hi - lo
        } else {
            f64::NAN
        }
    }

    /// Signed octave shift from the smallest to the largest width
    /// (positive = the optimum moves to smaller LRs as width grows).
    pub fn shift_log2(&self) -> f64 {
        match (self.best_lr_log2.first(), self.best_lr_log2.last()) {
            (Some(&(_, first)), Some(&(_, last))) => first - last,
            _ => f64::NAN,
        }
    }
}

/// Full LR-transfer outcome: both variants over the same widths.
#[derive(Debug, Clone)]
pub struct TransferReport {
    /// Widths measured (ascending).
    pub widths: Vec<usize>,
    /// Training steps per grid point.
    pub steps: usize,
    /// µS sweep (transfer rules active).
    pub mus: VariantTransfer,
    /// SP sweep (raw LRs, rules disabled).
    pub sp: VariantTransfer,
}

/// Sweep one variant's loss-vs-LR curves across the harness widths.
/// Diverged points are recorded, not fatal; a width whose every point
/// diverges is an error (the grid missed the stable region entirely).
///
/// The LR axis comes from [`crate::coordinator::sweep::pow2_axis`] (the
/// §3.1 methodology), but the per-width optimum is summarized with this
/// module's own [`LrCurve::best_lr_log2`] rather than the sweep engine's
/// `optimal_subset`: transfer runs are short, so the 2% subset-center in
/// log2 space is deliberately coarser than the 0.25% print threshold the
/// long-run sweep CLI uses, and the summary must be a single continuous
/// coordinate (an octave position), not a set of points.
pub fn lr_transfer_variant(
    backend: &dyn Backend,
    hc: &HarnessConfig,
    variant: &str,
) -> Result<VariantTransfer> {
    let (lo, hi) = if variant == "mus" { hc.mus_lr_exps } else { hc.sp_lr_exps };
    if lo > hi {
        bail!("empty LR grid {lo}..{hi} for {variant}");
    }
    let lrs = crate::coordinator::sweep::pow2_axis(lo, hi);
    let corpus = hc.corpus();
    let mut curves = Vec::with_capacity(hc.widths.len());
    let mut d_bases = Vec::with_capacity(hc.widths.len());
    for &w in &hc.widths {
        // µS keeps d_base fixed so its √(d_base/d) rule is live; SP sets
        // d_base = w, disabling its empirical rule -> a raw-LR sweep
        let d_base = if variant == "mus" { hc.widths[0] } else { w };
        d_bases.push(d_base);
        let cfg = hc.model(variant, w, d_base)?;
        let trainer = Trainer::new(backend, &cfg)?;
        let mut points = Vec::with_capacity(lrs.len());
        for &lr in &lrs {
            let tc = TrainConfig {
                steps: hc.transfer_steps,
                lr,
                wd: 0.0,
                tau: hc.tau,
                schedule: Schedule::Constant,
                seed: hc.seed,
                init_seed: 0,
                max_loss: 20.0,
                spike_threshold: 1.0,
                log_every: usize::MAX,
            };
            let mut batcher = Batcher::new(corpus.clone(), hc.seed, 0, 1, cfg.batch, cfg.seq_len);
            let r = trainer
                .run(&tc, &mut batcher)
                .with_context(|| format!("transfer {variant} w{w} lr 2^{:.0}", lr.log2()))?;
            let final_loss = r.final_loss(4) as f64;
            // a NaN tail mean is a divergence even if the guard fired late
            let diverged = r.diverged || !final_loss.is_finite();
            eprintln!(
                "  transfer: {} lr 2^{:.0} -> loss {final_loss:.4}{}",
                cfg.name(),
                lr.log2(),
                if diverged { " DIVERGED" } else { "" }
            );
            points.push(LrPoint { lr, final_loss, diverged });
        }
        let curve = LrCurve { width: w, points };
        if curve.best_lr_log2().is_none() {
            bail!("transfer {variant} w{w}: every LR in 2^{lo}..2^{hi} diverged");
        }
        curves.push(curve);
    }
    let best_lr_log2 = curves
        .iter()
        .map(|c| (c.width, c.best_lr_log2().expect("checked per width above")))
        .collect();
    Ok(VariantTransfer { variant: variant.to_string(), d_base: d_bases, curves, best_lr_log2 })
}

/// Run the LR-transfer sweep for both variants.
pub fn lr_transfer(backend: &dyn Backend, hc: &HarnessConfig) -> Result<TransferReport> {
    if hc.widths.len() < 2 {
        bail!("LR transfer needs >= 2 widths, got {:?}", hc.widths);
    }
    Ok(TransferReport {
        widths: hc.widths.clone(),
        steps: hc.transfer_steps,
        mus: lr_transfer_variant(backend, hc, "mus")?,
        sp: lr_transfer_variant(backend, hc, "sp")?,
    })
}

/// Render the loss-vs-LR curves as aligned tables (rows = LR, columns =
/// widths) — the repro-style text output of `munit transfer`.
pub fn transfer_table(r: &TransferReport) -> String {
    let mut out = String::new();
    for vt in [&r.mus, &r.sp] {
        out.push_str(&format!(
            "\n{} loss vs base LR ({} steps/point):\n",
            if vt.variant == "mus" {
                "µS (√(d_base/d) hidden-LR rule ACTIVE)"
            } else {
                "SP (raw LR, no transfer rule)"
            },
            r.steps
        ));
        let mut header: Vec<String> = vec!["lr".into()];
        header.extend(vt.curves.iter().map(|c| format!("w{}", c.width)));
        let header_refs: Vec<&str> = header.iter().map(String::as_str).collect();
        let n_points = vt.curves.first().map(|c| c.points.len()).unwrap_or(0);
        let mut rows = Vec::new();
        for i in 0..n_points {
            let mut row = vec![format!("2^{:.0}", vt.curves[0].points[i].lr.log2())];
            for c in &vt.curves {
                let p = &c.points[i];
                row.push(if p.diverged {
                    "div".into()
                } else {
                    format!("{:.4}", p.final_loss)
                });
            }
            rows.push(row);
        }
        out.push_str(&table::render(&header_refs, &rows));
        let bests: Vec<String> = vt
            .best_lr_log2
            .iter()
            .map(|(w, b)| format!("w{w}: 2^{b:.2}"))
            .collect();
        out.push_str(&format!(
            "best LR per width: {} (spread {:.2} octaves)\n",
            bests.join("  "),
            vt.best_spread_log2()
        ));
    }
    out.push_str(&format!(
        "\nchecks: µS best-LR spread {:.2} octaves (width-stable: {}, max {MUS_LR_SPREAD_MAX}) | \
         SP raw-LR shift {:.2} octaves small→large width\n",
        r.mus.best_spread_log2(),
        r.mus.best_spread_log2() <= MUS_LR_SPREAD_MAX,
        r.sp.shift_log2()
    ));
    out
}

/// JSON projection of an LR-transfer sweep (`REPORT_transfer.json`).
pub fn transfer_json(r: &TransferReport) -> Json {
    let variant_json = |vt: &VariantTransfer| -> Json {
        let curves = vt
            .curves
            .iter()
            .zip(&vt.d_base)
            .map(|(c, &db)| {
                let points = c
                    .points
                    .iter()
                    .map(|p| {
                        Json::obj(vec![
                            ("lr", Json::num(p.lr)),
                            ("log2_lr", Json::num(p.lr.log2())),
                            ("final_loss", Json::num(p.final_loss)),
                            ("diverged", Json::Bool(p.diverged)),
                        ])
                    })
                    .collect();
                Json::obj(vec![
                    ("width", Json::num(c.width as f64)),
                    ("d_base", Json::num(db as f64)),
                    ("points", Json::Arr(points)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("variant", Json::str(&vt.variant)),
            ("curves", Json::Arr(curves)),
            (
                "best_lr_log2",
                Json::Arr(
                    vt.best_lr_log2
                        .iter()
                        .map(|&(w, b)| Json::arr_f64(&[w as f64, b]))
                        .collect(),
                ),
            ),
            ("best_spread_log2", Json::num(vt.best_spread_log2())),
            ("shift_log2", Json::num(vt.shift_log2())),
        ])
    };
    Json::obj(vec![
        ("kind", Json::str("transfer")),
        ("widths", Json::Arr(r.widths.iter().map(|&w| Json::num(w as f64)).collect())),
        ("steps", Json::num(r.steps as f64)),
        ("variants", Json::Arr(vec![variant_json(&r.mus), variant_json(&r.sp)])),
        (
            "checks",
            Json::obj(vec![
                ("mus_best_spread_log2", Json::num(r.mus.best_spread_log2())),
                (
                    "mus_width_stable",
                    Json::Bool(r.mus.best_spread_log2() <= MUS_LR_SPREAD_MAX),
                ),
                ("sp_shift_log2", Json::num(r.sp.shift_log2())),
            ]),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::ReferenceBackend;

    /// The acceptance criterion: on >= 3 widths, µS per-op activation RMS
    /// stays in the documented O(1) band with a flat across-width profile
    /// and gradients on the predicted `1/d` law, while SP's activations
    /// drift with width. Smoke-sized (seconds).
    #[test]
    fn mus_rms_flat_across_width_while_sp_drifts() {
        let be = ReferenceBackend::new(&[]).unwrap();
        let hc = HarnessConfig::smoke();
        let r = coordcheck(&be, &hc).unwrap();
        assert!(r.widths.len() >= 3);
        let c = r.checks();
        assert!(
            c.coverage_complete,
            "a tracked op went unrecorded — an observe_rms hook label drifted from \
             ACT_OPS/GRAD_OPS"
        );
        assert!(
            c.mus_act_within_band,
            "µS activations left the ({}, {}) band: qkv {:?} resid2 {:?}",
            ACT_BAND.0,
            ACT_BAND.1,
            r.mus.rms_by_width("qkv"),
            r.mus.rms_by_width("resid2"),
        );
        assert!(
            c.mus_act_max_ratio <= MUS_ACT_RATIO_MAX,
            "µS activation RMS not width-flat: ratio {} (qkv {:?}, ffn_down {:?})",
            c.mus_act_max_ratio,
            r.mus.rms_by_width("qkv"),
            r.mus.rms_by_width("ffn_down"),
        );
        assert!(
            c.sp_act_max_ratio >= SP_ACT_RATIO_MIN,
            "SP failed to drift: ratio {} (qkv {:?}, ffn_down {:?})",
            c.sp_act_max_ratio,
            r.sp.rms_by_width("qkv"),
            r.sp.rms_by_width("ffn_down"),
        );
        assert!(
            c.mus_grad_max_ratio_compensated <= MUS_GRAD_RATIO_MAX,
            "µS gradients off the 1/d law: compensated ratio {} (d_qkv {:?})",
            c.mus_grad_max_ratio_compensated,
            r.mus.rms_by_width("d_qkv"),
        );
        assert!(c.pass);

        // the µS lane records FP8 cast health for all four hidden linears
        // and the E5M2 gradient casts
        let widest = r.mus.per_width.last().unwrap();
        for op in ["qkv", "attn_out", "ffn_up", "ffn_down", "w_qkv", "d_qkv"] {
            let Some(h) = widest.report.cast_totals(op) else {
                panic!("no cast telemetry for '{op}'");
            };
            assert!(h.total > 0, "{op}: empty cast record");
            assert_eq!(h.overflow_nonfinite, 0, "{op}: non-finite values in a healthy run");
            assert!(h.underflow_rate() < 0.5, "{op}: implausible underflow");
        }
        // SP (BF16 lane) must have recorded NO fp8 casts
        assert!(r.sp.per_width[0].report.casts.is_empty());

        // JSON report round-trips and carries nonzero RMS rows + checks
        let j = coordcheck_json(&r);
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.str_or("kind", ""), "coordcheck");
        assert!(parsed.get("checks").unwrap().get("pass").unwrap().as_bool().unwrap());
        let variants = parsed.get("variants").unwrap().as_arr().unwrap();
        assert_eq!(variants.len(), 2);
        let ops = variants[0].get("per_width").unwrap().as_arr().unwrap()[0]
            .get("ops")
            .unwrap()
            .as_arr()
            .unwrap();
        assert!(ops.iter().any(|o| o.f64_or("rms", 0.0) > 0.0));
        // text table renders every width column
        let t = coordcheck_table(&r);
        for w in &r.widths {
            assert!(t.contains(&format!("w{w}")), "missing width column in:\n{t}");
        }
    }

    /// The transfer acceptance: µS's best base-LR (optimal-subset center,
    /// log2 space) moves less than one octave across a 4x width span —
    /// the zero-shot transfer claim at smoke scale.
    #[test]
    fn mus_best_lr_is_width_stable() {
        let be = ReferenceBackend::new(&[]).unwrap();
        let hc = HarnessConfig::smoke();
        let vt = lr_transfer_variant(&be, &hc, "mus").unwrap();
        assert_eq!(vt.curves.len(), hc.widths.len());
        for c in &vt.curves {
            assert!(
                c.points.iter().any(|p| !p.diverged && p.final_loss.is_finite()),
                "w{}: no usable grid point",
                c.width
            );
        }
        assert!(
            vt.best_spread_log2() <= MUS_LR_SPREAD_MAX,
            "µS best-LR drifted across widths: {:?} (spread {:.2})",
            vt.best_lr_log2,
            vt.best_spread_log2()
        );
    }

    #[test]
    fn harness_config_validates_variants() {
        let hc = HarnessConfig::smoke();
        assert!(hc.model("mus", 32, 16).is_ok());
        assert!(hc.model("sp", 32, 32).is_ok());
        assert!(hc.model("frob", 32, 16).is_err());
        // width must respect the fixed head_dim
        assert!(hc.model("mus", 20, 16).is_err());
    }

    #[test]
    fn lr_curve_best_center_statistics() {
        let mk = |losses: &[(f64, f64, bool)]| LrCurve {
            width: 64,
            points: losses
                .iter()
                .map(|&(lr, final_loss, diverged)| LrPoint { lr, final_loss, diverged })
                .collect(),
        };
        // unique minimum -> its log2
        let c = mk(&[(0.25, 3.0, false), (0.5, 2.0, false), (1.0, 2.6, false)]);
        assert!((c.best_lr_log2().unwrap() + 1.0).abs() < 1e-12);
        // near-tie within 2% -> mean of the two log2s
        let c = mk(&[(0.25, 2.001, false), (0.5, 2.0, false), (1.0, 4.0, false)]);
        assert!((c.best_lr_log2().unwrap() + 1.5).abs() < 1e-12);
        // diverged points are ignored even if numerically smallest
        let c = mk(&[(0.25, 3.0, false), (0.5, 0.1, true)]);
        assert!((c.best_lr_log2().unwrap() + 2.0).abs() < 1e-12);
        // all diverged -> None
        assert!(mk(&[(0.5, 1.0, true)]).best_lr_log2().is_none());
    }
}
