//! Collectives over in-process worker states: allreduce / allgather /
//! reduce-scatter with byte accounting and an FP8 wire format.
//!
//! Two jobs, deliberately separated:
//!
//! 1. **A deterministic reduction fold.** [`reduce_mean`] accumulates
//!    per element in f64 over workers in ascending worker index and
//!    rounds to f32 exactly once. The contract (tested): the result is
//!    a pure function of the *multiset of inputs in index order* — it
//!    does not depend on worker count beyond the values themselves, and
//!    because the fold is element-wise it is invariant under any
//!    element partitioning, so a reduce-scatter over segments followed
//!    by an allgather is **bitwise identical** to a central allreduce.
//!    `ddp::allreduce_mean` and the sharded trainer both delegate here.
//!
//! 2. **A wire format with accounting.** [`Collectives`] models what
//!    crosses the inter-worker boundary: every shard movement is
//!    counted in bytes (mirrored into an [`ExecStats`]) and, under the
//!    [`WireFormat::Fp8`] wire, actually quantized through
//!    [`crate::fp8::FastCast`] with [`CastHealth`] recorded — so
//!    compressed-comm health is observable through the same telemetry
//!    sink as the compute-path casts (`wire_param` / `wire_mom` ops).
//!
//! The FP8 wire uses **static** per-tensor scales (identically 1.0 for
//! µS: every tensor is unit-variance by construction, the paper's §2
//! claim). The scale is a compile-time constant of the shard spec, so
//! workers exchange **zero** scale/amax bytes — [`Collectives::amax_syncs`]
//! stays 0 and tests assert it. A dynamic-scaling recipe (TE-style
//! delayed scaling) would have to allreduce an amax per tensor per step
//! before any rank could cast; see `docs/NUMERICS.md` §Sharding.

use crate::coordinator::trainer::TrainState;
use crate::fp8::{CastHealth, FastCast, E4M3, E5M2};
use crate::runtime::state::{self, StatePrecision};
use crate::runtime::{ExecStats, Tensor};
use crate::telemetry;
use crate::util::error::Result;

/// Precision of payloads on the inter-worker wire.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WireFormat {
    /// Master-precision wire: shards move as the f32 they are. This is
    /// the repo's stand-in for the paper's BF16-comm baseline — kept
    /// lossless on purpose so the sharded run is *bit-identical* to the
    /// sequential one (the correctness oracle); byte counters report
    /// the 4 B/elem that actually moved.
    Master,
    /// FP8 wire with static scale 1.0: params cross as E4M3, momenta as
    /// E5M2 (the wider-range format — Lion momenta are grad-scale EMAs).
    /// 1 B/elem and zero scale/amax exchange. Under FP8 *state*
    /// ([`Collectives::with_state`]) momenta instead ship **natively**
    /// as the scaled-E4M3 bytes the optimizer already holds — no
    /// re-cast, 1 B/elem + 4 B of per-tensor scale metadata, and still
    /// zero amax syncs (the scale is derived locally from the shard).
    Fp8,
}

impl WireFormat {
    /// Bytes per element on the wire.
    pub fn bytes_per_elem(&self) -> u64 {
        match self {
            WireFormat::Master => 4,
            WireFormat::Fp8 => 1,
        }
    }

    /// Parse a CLI name: `master` (alias `bf16`) or `fp8`.
    pub fn by_name(name: &str) -> Option<WireFormat> {
        match name {
            "master" | "bf16" | "f32" => Some(WireFormat::Master),
            "fp8" => Some(WireFormat::Fp8),
            _ => None,
        }
    }

    /// Stable label for reports/benches.
    pub fn label(&self) -> &'static str {
        match self {
            WireFormat::Master => "master",
            WireFormat::Fp8 => "fp8",
        }
    }
}

/// What a shard payload is — selects the FP8 wire format and the
/// telemetry op name.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Payload {
    /// Parameter shard (E4M3 on the FP8 wire).
    Param,
    /// Optimizer-momentum shard (E5M2 on the FP8 wire).
    Momentum,
}

/// Deterministic mean over `parts` (one slice per worker, equal length),
/// written into `out`.
///
/// Contract: per element, contributions are accumulated in **f64** in
/// ascending worker index and rounded to f32 exactly once. The fold is
/// element-wise, so any partitioning of elements across reducers
/// (reduce-scatter segments) recombines bitwise-identically to a central
/// reduction — tested in this module.
pub fn reduce_mean(parts: &[&[f32]], out: &mut Vec<f32>) {
    let n = parts.len();
    debug_assert!(n > 0, "reduce over zero workers");
    let len = parts[0].len();
    let inv = 1.0f64 / n as f64;
    out.clear();
    out.reserve(len);
    for i in 0..len {
        let mut acc = 0f64;
        for p in parts {
            debug_assert_eq!(p.len(), len);
            acc += p[i] as f64;
        }
        out.push((acc * inv) as f32);
    }
}

/// [`reduce_mean`] over whole train states: one reduced [`TrainState`]
/// from `k` replicas, tensor by tensor, with the deterministic fold.
pub fn reduce_mean_state(states: &[TrainState]) -> Result<TrainState> {
    debug_assert!(!states.is_empty());
    let n_tensors = states[0].tensors.len();
    let mut tensors = Vec::with_capacity(n_tensors);
    let mut acc: Vec<f32> = Vec::new(); // reused across tensors
    let mut parts: Vec<&[f32]> = Vec::with_capacity(states.len());
    for t in 0..n_tensors {
        parts.clear();
        for s in states {
            parts.push(s.tensors[t].as_f32()?);
        }
        reduce_mean(&parts, &mut acc);
        tensors.push(Tensor::f32(acc.clone(), states[0].tensors[t].shape())?);
    }
    Ok(TrainState { tensors, n_params: states[0].n_params })
}

/// Collective engine: applies the wire format to shard payloads and
/// accounts every byte that crosses the worker boundary.
pub struct Collectives {
    wire: WireFormat,
    state: StatePrecision,
    param_cast: FastCast,
    mom_cast: FastCast,
    /// Aggregate transfer accounting (`transfer_bytes` = total wire
    /// bytes, `calls` = collective operations issued).
    pub stats: ExecStats,
    /// Wire bytes spent gathering shards into full tensors.
    pub allgather_bytes: u64,
    /// Wire bytes spent scattering updated shards back to owners.
    pub reduce_scatter_bytes: u64,
    /// Wire bytes spent on pipeline stage-boundary activations.
    pub activation_bytes: u64,
    /// Merged cast health of everything FP8-quantized for the wire.
    pub health: CastHealth,
    /// Cross-shard scale/amax synchronizations performed. Static µS
    /// scales keep this at **zero**; tests assert it.
    pub amax_syncs: u64,
}

impl Collectives {
    /// New engine with the given wire format, f32 state, zeroed counters.
    pub fn new(wire: WireFormat) -> Collectives {
        Collectives::with_state(wire, StatePrecision::F32)
    }

    /// [`Collectives::new`] under an explicit [`StatePrecision`]. With
    /// FP8 state + FP8 wire, momentum legs ship the optimizer's native
    /// scaled-E4M3 representation instead of re-casting to E5M2.
    pub fn with_state(wire: WireFormat, state: StatePrecision) -> Collectives {
        Collectives {
            wire,
            state,
            param_cast: E4M3.fast_caster(),
            mom_cast: E5M2.fast_caster(),
            stats: ExecStats::default(),
            allgather_bytes: 0,
            reduce_scatter_bytes: 0,
            activation_bytes: 0,
            health: CastHealth::default(),
            amax_syncs: 0,
        }
    }

    /// The wire format in use.
    pub fn wire(&self) -> WireFormat {
        self.wire
    }

    /// The state-precision policy the wire serves.
    pub fn state_precision(&self) -> StatePrecision {
        self.state
    }

    /// Total wire bytes across all collective classes.
    pub fn total_bytes(&self) -> u64 {
        self.allgather_bytes + self.reduce_scatter_bytes + self.activation_bytes
    }

    /// Quantize a payload for the wire; returns the per-receiver
    /// metadata overhead in bytes (zero except for the native scaled
    /// momentum leg, whose i32 scale exponent rides along).
    fn apply_wire(&mut self, data: &mut [f32], payload: Payload, rank: usize) -> u64 {
        if self.wire != WireFormat::Fp8 {
            return 0;
        }
        if payload == Payload::Momentum && self.state == StatePrecision::Fp8 {
            // Native momentum leg: the optimizer state is already on a
            // scaled-E4M3 grid, so the wire ships those exact bytes (the
            // requantize below is a bit-exact no-op on on-grid data).
            // The scale exponent is derived *locally* from the shard's
            // amax — amax_syncs stays 0 — and crosses as 4 B of
            // per-tensor metadata next to the 1 B/elem payload.
            let k = state::momentum_scale(data);
            let (scale, inv) = (state::pow2(k), state::pow2(-k));
            let h = E4M3.cast_health(data, inv);
            self.health.merge(&h);
            telemetry::record_cast("wire_mom", rank, "e4m3", h);
            for x in data.iter_mut() {
                *x = self.param_cast.cast(*x * inv) * scale;
            }
            return 4;
        }
        let (fmt, caster, op, name) = match payload {
            Payload::Param => (E4M3, &self.param_cast, "wire_param", "e4m3"),
            Payload::Momentum => (E5M2, &self.mom_cast, "wire_mom", "e5m2"),
        };
        // Static scale 1.0: µS keeps every tensor in the unit-variance
        // band, so no per-step amax is measured and none is exchanged.
        let h = fmt.cast_health(data, 1.0);
        self.health.merge(&h);
        telemetry::record_cast(op, rank, name, h);
        caster.quantize_slice(data);
        0
    }

    /// Allgather leg for one rank's shard of a tensor: every one of the
    /// other `tp - 1` ranks receives this payload over the wire. Under
    /// the FP8 wire the payload is quantized in place (what the
    /// receivers — and the assembled compute — actually see).
    pub fn allgather_shard(&mut self, data: &mut [f32], payload: Payload, tp: usize, rank: usize) {
        if tp <= 1 {
            return;
        }
        let t0 = std::time::Instant::now();
        let overhead = self.apply_wire(data, payload, rank);
        let bytes = (tp as u64 - 1) * (data.len() as u64 * self.wire.bytes_per_elem() + overhead);
        self.allgather_bytes += bytes;
        self.stats.transfer_bytes += bytes;
        self.stats.transfer_time += t0.elapsed();
        self.stats.calls += 1;
    }

    /// Reduce-scatter leg for one rank's updated shard: the shard's new
    /// values reach their owner across the wire (same format as the
    /// gather leg, so owners hold wire-precision shards — the FP8-LM
    /// "FP8 on the wire" discipline, idempotent on re-gather).
    pub fn reduce_scatter_shard(
        &mut self,
        data: &mut [f32],
        payload: Payload,
        tp: usize,
        rank: usize,
    ) {
        if tp <= 1 {
            return;
        }
        let t0 = std::time::Instant::now();
        let overhead = self.apply_wire(data, payload, rank);
        let bytes = (tp as u64 - 1) * (data.len() as u64 * self.wire.bytes_per_elem() + overhead);
        self.reduce_scatter_bytes += bytes;
        self.stats.transfer_bytes += bytes;
        self.stats.transfer_time += t0.elapsed();
        self.stats.calls += 1;
    }

    /// Account one pipeline stage-boundary activation (or activation-
    /// gradient) send of `elems` f32 values. Stage boundaries stay at
    /// master precision (the FP8 wire compresses *state* exchange, the
    /// FP8-LM win; µS would additionally permit FP8 activations).
    pub fn send_activations(&mut self, elems: usize) {
        let bytes = elems as u64 * 4;
        self.activation_bytes += bytes;
        self.stats.transfer_bytes += bytes;
        self.stats.calls += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reduce_mean_is_partition_invariant() {
        // reduce-scatter over arbitrary segments + gather == central
        // allreduce, bitwise — the property ddp and TP both lean on.
        let mut rng = crate::util::rng::Rng::new(7);
        let mut a = vec![0f32; 257];
        let mut b = vec![0f32; 257];
        let mut c = vec![0f32; 257];
        rng.fill_normal(&mut a, 1.0);
        rng.fill_normal(&mut b, 0.3);
        rng.fill_normal(&mut c, 2.0);
        let mut whole = Vec::new();
        reduce_mean(&[&a, &b, &c], &mut whole);
        for chunk in [1usize, 3, 64, 257] {
            let mut pieced = Vec::new();
            let mut seg = Vec::new();
            let mut lo = 0;
            while lo < a.len() {
                let hi = (lo + chunk).min(a.len());
                reduce_mean(&[&a[lo..hi], &b[lo..hi], &c[lo..hi]], &mut seg);
                pieced.extend_from_slice(&seg);
                lo = hi;
            }
            assert_eq!(whole, pieced, "chunk {chunk} changed the reduction");
        }
    }

    #[test]
    fn reduce_mean_single_worker_is_identity_modulo_rounding() {
        let a = vec![1.5f32, -2.25, 0.0, 3.0e-8];
        let mut out = Vec::new();
        reduce_mean(&[&a], &mut out);
        assert_eq!(a, out); // f64 round-trip of an f32 is exact
    }

    #[test]
    fn fp8_wire_counts_bytes_and_health_without_amax_syncs() {
        let mut coll = Collectives::new(WireFormat::Fp8);
        let mut data = vec![0.5f32, -1.0, 1e-6, 600.0];
        coll.allgather_shard(&mut data, Payload::Param, 2, 0);
        assert_eq!(coll.allgather_bytes, 4); // (2-1) ranks x 4 elems x 1 B
        assert_eq!(coll.amax_syncs, 0);
        assert_eq!(coll.health.total, 4);
        assert!(coll.health.saturated > 0, "600 should clip in e4m3");
        // quantization actually happened and is idempotent on re-gather
        assert_eq!(data[3], crate::fp8::E4M3.fast_caster().max_finite());
        let once = data.clone();
        coll.allgather_shard(&mut data, Payload::Param, 2, 0);
        assert_eq!(once, data);
    }

    #[test]
    fn master_wire_is_lossless_and_counts_four_bytes_per_elem() {
        let mut coll = Collectives::new(WireFormat::Master);
        let mut data = vec![0.123456789f32, -7.7e-30, 3.4e38];
        let orig = data.clone();
        coll.allgather_shard(&mut data, Payload::Momentum, 4, 1);
        assert_eq!(orig, data);
        assert_eq!(coll.allgather_bytes, 3 * 3 * 4); // (4-1) x 3 elems x 4 B
        assert_eq!(coll.health.total, 0);
    }

    #[test]
    fn fp8_state_momentum_leg_ships_native_e4m3_without_amax_syncs() {
        let mut coll = Collectives::with_state(WireFormat::Fp8, StatePrecision::Fp8);
        // on-grid momentum (what an FP8-state session holds): the native
        // wire must pass it through bit-exactly, scale derived locally
        let mut rng = crate::util::rng::Rng::new(11);
        let mut data = vec![0f32; 64];
        rng.fill_normal(&mut data, 0.02);
        state::snap_momentum(&mut data);
        let on_grid = data.clone();
        coll.allgather_shard(&mut data, Payload::Momentum, 4, 0);
        let bits = |xs: &[f32]| xs.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&on_grid), bits(&data), "native leg re-cast on-grid momentum");
        // (tp-1) x (64 elems x 1 B + 4 B scale metadata)
        assert_eq!(coll.allgather_bytes, 3 * (64 + 4));
        assert_eq!(coll.amax_syncs, 0);
        assert_eq!(coll.health.total, 64);
        assert_eq!(coll.health.saturated, 0, "scaled grid never saturates");
        // values far below E5M2's subnormal floor survive the scaled leg
        let tiny = state::pow2(-30);
        let mut small = vec![tiny; 8];
        coll.reduce_scatter_shard(&mut small, Payload::Momentum, 2, 1);
        assert!(small.iter().all(|&x| x > 0.0), "scaled e4m3 lost a tiny momentum");
        assert_eq!(coll.reduce_scatter_bytes, 8 + 4);
    }

    #[test]
    fn f32_state_momentum_leg_keeps_the_e5m2_wire_and_byte_counts() {
        let mut coll = Collectives::with_state(WireFormat::Fp8, StatePrecision::F32);
        let mut data = vec![0.5f32, -0.25, 1.5, 2.0];
        coll.allgather_shard(&mut data, Payload::Momentum, 2, 0);
        assert_eq!(coll.allgather_bytes, 4, "f32-state momentum leg must stay 1 B/elem, no scale");
        assert_eq!(coll.amax_syncs, 0);
        // E5M2 wire underflows below its subnormal floor — the contrast
        // the native scaled leg exists to avoid
        let mut tiny = vec![1e-6f32; 4];
        coll.allgather_shard(&mut tiny, Payload::Momentum, 2, 0);
        assert!(tiny.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn tp1_moves_no_bytes() {
        let mut coll = Collectives::new(WireFormat::Fp8);
        let mut data = vec![1.0f32; 8];
        coll.allgather_shard(&mut data, Payload::Param, 1, 0);
        coll.reduce_scatter_shard(&mut data, Payload::Param, 1, 0);
        assert_eq!(coll.total_bytes(), 0);
        assert_eq!(data, vec![1.0f32; 8]); // no wire, no quantization
    }
}
