//! Simulated multi-worker data parallelism.
//!
//! What it models: `k` workers with replicated state, each consuming a
//! disjoint corpus shard, synchronizing every step. Because the train_step
//! artifact fuses fwd/bwd/update, synchronization here averages *parameters
//! and momenta* after each local step (one-step LocalSGD). For Lion's
//! sign-based update this coincides with gradient averaging whenever the
//! workers' update signs agree, and is a standard approximation otherwise
//! — the point of the exercise is the *coordination* path: sharded loaders,
//! lockstep stepping, and an allreduce that (unlike TE-style FP8) needs NO
//! per-tensor amax exchange. See DESIGN.md substitution table.
//!
//! Each worker owns a device-resident [`Session`]; the allreduce is the
//! one deliberate full-state host transfer per step (`read_back` -> mean
//! -> `load_state`), i.e. exactly the collective boundary a single-host
//! multi-worker run has.

use crate::config::{ModelConfig, TrainConfig};
use crate::coordinator::trainer::{RunResult, TrainState, Trainer};
use crate::data::{Batcher, CorpusSpec};
use crate::runtime::{Backend, Tensor};
use crate::util::error::Result;

/// Mean of the workers' states (the "allreduce"). One f32 accumulation
/// buffer is reused across tensors, and ONE reduced `TrainState` comes
/// back: every worker loads it by reference at the `load_state` boundary
/// instead of receiving its own deep clone — the old per-worker
/// `Tensor::clone` fan-out was O(workers × state bytes) of pure copy
/// churn per step on top of the reduction itself.
fn allreduce_mean(states: &[TrainState]) -> Result<TrainState> {
    let n_workers = states.len();
    debug_assert!(n_workers > 1, "allreduce with fewer than two workers is a no-op");
    let n_tensors = states[0].tensors.len();
    let inv = 1.0 / n_workers as f32;
    let mut tensors = Vec::with_capacity(n_tensors);
    let mut acc: Vec<f32> = Vec::new(); // reused across tensors
    for t in 0..n_tensors {
        acc.clear();
        acc.extend_from_slice(states[0].tensors[t].as_f32()?);
        for s in states.iter().skip(1) {
            let v = s.tensors[t].as_f32()?;
            for (a, b) in acc.iter_mut().zip(v) {
                *a += *b;
            }
        }
        for a in acc.iter_mut() {
            *a *= inv;
        }
        tensors.push(Tensor::f32(acc.clone(), states[0].tensors[t].shape())?);
    }
    Ok(TrainState { tensors, n_params: states[0].n_params })
}

/// Train with `k` simulated workers for `tc.steps` synchronized steps.
/// Returns the leader's run metrics (losses averaged across workers).
pub fn train_ddp(
    backend: &dyn Backend,
    cfg: &ModelConfig,
    tc: &TrainConfig,
    corpus: &CorpusSpec,
    n_workers: usize,
) -> Result<RunResult> {
    let trainer = Trainer::new(backend, cfg)?;
    let mut sessions = Vec::with_capacity(n_workers);
    for _ in 0..n_workers {
        sessions.push(trainer.init(tc.init_seed)?);
    }
    let mut batchers: Vec<Batcher> = (0..n_workers)
        .map(|w| Batcher::new(corpus.clone(), tc.seed, w, n_workers, cfg.batch, cfg.seq_len))
        .collect();
    let mut losses = Vec::with_capacity(tc.steps);
    let mut gnorms = Vec::with_capacity(tc.steps);
    let t0 = std::time::Instant::now();
    let mut diverged = false;
    for step in 0..tc.steps {
        let lr = tc.schedule.lr_at(tc.lr, step, tc.steps);
        let mut loss_sum = 0f32;
        let mut gnorm_sum = 0f32;
        for (w, session) in sessions.iter_mut().enumerate() {
            let tokens = batchers[w].next_batch();
            let (loss, gnorm) = session.step(&tokens, lr, tc.wd, tc.tau)?;
            loss_sum += loss;
            gnorm_sum += gnorm;
        }
        if n_workers > 1 {
            // collective boundary: one full-state transfer per worker
            let mut states = Vec::with_capacity(n_workers);
            for session in sessions.iter() {
                states.push(session.read_back()?);
            }
            let reduced = allreduce_mean(&states)?;
            for session in sessions.iter_mut() {
                session.load_state(&reduced)?;
            }
        }
        let loss = loss_sum / n_workers as f32;
        losses.push(loss);
        gnorms.push(gnorm_sum / n_workers as f32);
        if !loss.is_finite() || loss as f64 > tc.max_loss {
            diverged = true;
            break;
        }
    }
    let wall = t0.elapsed();
    let steps_done = losses.len();
    let tokens_per_sec = (steps_done * n_workers * cfg.batch * cfg.seq_len) as f64
        / wall.as_secs_f64().max(1e-9);
    Ok(RunResult {
        losses,
        gnorms,
        steps_done,
        diverged,
        spikes: 0,
        wall,
        tokens_per_sec,
    })
}
