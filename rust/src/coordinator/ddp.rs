//! Simulated multi-worker data parallelism.
//!
//! What it models: `k` workers with replicated state, each consuming a
//! disjoint corpus shard, synchronizing every step. Because the train_step
//! artifact fuses fwd/bwd/update, synchronization here averages *parameters
//! and momenta* after each local step (one-step LocalSGD). For Lion's
//! sign-based update this coincides with gradient averaging whenever the
//! workers' update signs agree, and is a standard approximation otherwise
//! — the point of the exercise is the *coordination* path: sharded loaders,
//! lockstep stepping, and an allreduce that (unlike TE-style FP8) needs NO
//! per-tensor amax exchange. See DESIGN.md substitution table.
//!
//! Each worker owns a device-resident [`Session`]; the allreduce is the
//! one deliberate full-state host transfer per step (`read_back` -> mean
//! -> `load_state`), i.e. exactly the collective boundary a single-host
//! multi-worker run has. Batches come from per-worker background
//! [`DataPipeline`]s, so token synthesis overlaps stepping instead of
//! sitting on the critical path.

use crate::config::{ModelConfig, TrainConfig};
use crate::coordinator::collective;
use crate::coordinator::pipeline::DataPipeline;
use crate::coordinator::trainer::{RunResult, TrainState, Trainer};
use crate::data::CorpusSpec;
use crate::err;
use crate::runtime::{Backend, Session, StatePrecision};
use crate::util::error::Result;

/// Mean of the workers' states (the "allreduce"), via the deterministic
/// fold of [`collective::reduce_mean`]: per element, contributions are
/// accumulated in f64 in **ascending worker index** and rounded to f32
/// once. The reduced state is therefore a pure function of the ordered
/// worker states — it cannot drift with accumulation order or with how
/// elements are segmented across reducers (the old f32 running sum
/// silently depended on both). ONE reduced `TrainState` comes back:
/// every worker loads it by reference at the `load_state` boundary.
pub fn allreduce_mean(states: &[TrainState]) -> Result<TrainState> {
    debug_assert!(states.len() > 1, "allreduce with fewer than two workers is a no-op");
    collective::reduce_mean_state(states)
}

/// The synchronized inner loop over pre-built worker sessions: step all
/// workers, check every worker's LOCAL loss, then allreduce. Exposed so
/// tests can drive it with doctored sessions (e.g. a non-finite state in
/// one worker) and assert the lockstep contract below.
///
/// Divergence contract: local losses are checked **before** the
/// allreduce. If ANY worker produces a non-finite (or over-threshold)
/// loss, the run stops for all workers with `diverged = true` and the
/// poisoned state is never averaged into the others — every session has
/// stepped the same number of times, so the fleet halts in lockstep
/// instead of desynchronizing.
pub fn run_lockstep(
    sessions: &mut [Session<'_>],
    pipelines: &[DataPipeline],
    tc: &TrainConfig,
) -> Result<RunResult> {
    let n_workers = sessions.len();
    debug_assert_eq!(n_workers, pipelines.len());
    let mut losses = Vec::with_capacity(tc.steps);
    let mut gnorms = Vec::with_capacity(tc.steps);
    let t0 = std::time::Instant::now();
    let mut diverged = false;
    for step in 0..tc.steps {
        let lr = tc.schedule.lr_at(tc.lr, step, tc.steps);
        let mut local = Vec::with_capacity(n_workers);
        // f64 running sum: worker order must not perturb the mean
        let mut gnorm_sum = 0f64;
        for (w, session) in sessions.iter_mut().enumerate() {
            let tokens =
                pipelines[w].next().ok_or_else(|| err!("worker {w} data pipeline ended early"))?;
            let (loss, gnorm) = session.step(&tokens, lr, tc.wd, tc.tau)?;
            local.push(loss);
            gnorm_sum += gnorm as f64;
        }
        let loss = local.iter().sum::<f32>() / n_workers as f32;
        losses.push(loss);
        gnorms.push((gnorm_sum / n_workers as f64) as f32);
        let any_bad = local.iter().any(|l| !l.is_finite() || *l as f64 > tc.max_loss);
        if any_bad || !loss.is_finite() || loss as f64 > tc.max_loss {
            diverged = true;
            break; // before the collective: no worker averages in a bad state
        }
        if n_workers > 1 {
            // collective boundary: one full-state transfer per worker
            let mut states = Vec::with_capacity(n_workers);
            for session in sessions.iter() {
                states.push(session.read_back()?);
            }
            let reduced = allreduce_mean(&states)?;
            for session in sessions.iter_mut() {
                session.load_state(&reduced)?;
            }
        }
    }
    let wall = t0.elapsed();
    let steps_done = losses.len();
    let tokens_per_batch: usize = pipelines.iter().map(|p| p.tokens_per_batch()).sum();
    let tokens_per_sec = (steps_done * tokens_per_batch) as f64 / wall.as_secs_f64().max(1e-9);
    Ok(RunResult { losses, gnorms, steps_done, diverged, spikes: 0, wall, tokens_per_sec })
}

/// Train with `k` simulated workers for `tc.steps` synchronized steps.
/// Returns the leader's run metrics (losses averaged across workers).
pub fn train_ddp(
    backend: &dyn Backend,
    cfg: &ModelConfig,
    tc: &TrainConfig,
    corpus: &CorpusSpec,
    n_workers: usize,
) -> Result<RunResult> {
    train_ddp_with_precision(backend, cfg, tc, corpus, n_workers, StatePrecision::F32)
}

/// [`train_ddp`] under an explicit [`StatePrecision`]. Under FP8 state the
/// allreduce mean lands off-grid; each worker's `load_state` re-snaps it
/// onto the E4M3/BF16 grids, so all workers hold bit-identical on-grid
/// state after every collective.
pub fn train_ddp_with_precision(
    backend: &dyn Backend,
    cfg: &ModelConfig,
    tc: &TrainConfig,
    corpus: &CorpusSpec,
    n_workers: usize,
    state_precision: StatePrecision,
) -> Result<RunResult> {
    let trainer = Trainer::with_state_precision(backend, cfg, state_precision)?;
    let mut sessions = Vec::with_capacity(n_workers);
    for _ in 0..n_workers {
        sessions.push(trainer.init(tc.init_seed)?);
    }
    // background producers, one corpus shard per worker (bit-identical
    // streams to direct `Batcher` use — tested in `pipeline`)
    let pipelines: Vec<DataPipeline> = (0..n_workers)
        .map(|w| {
            DataPipeline::spawn(
                corpus.clone(),
                tc.seed,
                w,
                n_workers,
                cfg.batch,
                cfg.seq_len,
                2,
                Some(tc.steps),
            )
        })
        .collect();
    run_lockstep(&mut sessions, &pipelines, tc)
}
