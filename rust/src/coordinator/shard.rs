//! Sharded execution: tensor parallelism (Megatron column/row splits)
//! composed with pipeline stages, over FP8-compressible collectives.
//!
//! ## What is sharded
//!
//! [`ShardSpec`] partitions exactly the four hidden linears the FP8
//! plan quantizes: `w_qkv` and `w_up` are **column**-split (each rank
//! owns whole attention heads / whole FFN neurons), `w_o` and `w_down`
//! are **row**-split (each rank contracts a band of the fan-in) — the
//! geometry comes from `runtime::block::shard_axis`, so the partitioner
//! can never drift from the block pipeline's layout. Embedding, head,
//! and norm gains are replicated. Optimizer momenta shard exactly like
//! their parameters. Pipeline stages partition depth into contiguous
//! layer ranges with a GPipe fill/drain microbatch schedule
//! ([`crate::coordinator::gpipe`]).
//!
//! ## Execution model and the correctness oracle
//!
//! This is *simulated* sharding in the same sense as `coordinator::ddp`:
//! rank states are real host-side shards and every collective leg is
//! real data movement (bytes counted, FP8 wire actually quantizes), but
//! each step's math executes once, on the assembled full state, through
//! the unmodified bit-exact `train_step` artifact. That construction is
//! what makes the repo's standing contract extendable to sharding:
//! with the lossless [`WireFormat::Master`] wire, a sharded run at any
//! TP degree, stage count, or substrate thread count is **bit-identical**
//! to the sequential single-worker run (genuine row-parallel partial-sum
//! recombination could never be — float addition is not associative).
//! Under [`WireFormat::Fp8`] the gathered shards really are E4M3/E5M2
//! values, so the divergence from the master-wire run is a *measured*
//! property, bounded in tests — while [`Collectives::amax_syncs`] stays
//! zero because µS's static scales are constants of the spec
//! (`scaling::Scheme::shard_output_mult`, validated at startup).

use std::path::{Path, PathBuf};

use crate::config::{ModelConfig, TrainConfig};
use crate::coordinator::checkpoint;
use crate::coordinator::collective::{Collectives, Payload, WireFormat};
use crate::coordinator::gpipe::{self, Phase};
use crate::coordinator::pipeline::DataPipeline;
use crate::coordinator::trainer::{RunResult, TrainState};
use crate::data::CorpusSpec;
use crate::fp8::CastHealth;
use crate::runtime::block::{self, ShardAxis};
use crate::runtime::{Backend, Dtype, Session, StatePrecision, Tensor, TensorSpec};
use crate::scaling::ShardDim;
use crate::util::error::{Context, Result};
use crate::util::stats::Ema;
use crate::{bail, err};

/// How a model is sharded: TP degree × pipeline stages × microbatches.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardSpec {
    /// Tensor-parallel degree (must divide `n_heads` and `ffn_width`).
    pub tp: usize,
    /// Pipeline stages over depth (must divide `depth`).
    pub stages: usize,
    /// GPipe microbatches per step (must divide `batch`).
    pub microbatches: usize,
}

impl ShardSpec {
    /// Spec with `microbatches = stages` (the minimal fill/drain split).
    pub fn new(tp: usize, stages: usize) -> ShardSpec {
        ShardSpec { tp, stages, microbatches: stages.max(1) }
    }

    /// Same spec with an explicit microbatch count.
    pub fn with_microbatches(mut self, m: usize) -> ShardSpec {
        self.microbatches = m;
        self
    }

    /// Check divisibility against a concrete model. TP must be
    /// head-aligned (`tp | n_heads` keeps every rank's qkv columns on
    /// whole heads) and divide the FFN width; stages must tile depth;
    /// microbatches must tile the batch.
    pub fn validate(&self, cfg: &ModelConfig) -> Result<()> {
        if self.tp == 0 || self.stages == 0 || self.microbatches == 0 {
            bail!("shard spec must be positive, got {self:?}");
        }
        if cfg.n_heads() % self.tp != 0 {
            bail!("tp={} does not divide n_heads={} of {}", self.tp, cfg.n_heads(), cfg.name());
        }
        if cfg.ffn_width() % self.tp != 0 {
            bail!("tp={} does not divide ffn_width={}", self.tp, cfg.ffn_width());
        }
        if cfg.depth % self.stages != 0 {
            bail!("stages={} does not divide depth={}", self.stages, cfg.depth);
        }
        if cfg.batch % self.microbatches != 0 {
            bail!("microbatches={} does not divide batch={}", self.microbatches, cfg.batch);
        }
        Ok(())
    }

    /// Stable label, e.g. `tp2.pp2.mb4`.
    pub fn describe(&self) -> String {
        format!("tp{}.pp{}.mb{}", self.tp, self.stages, self.microbatches)
    }
}

/// Startup validation that per-shard scaling rules reproduce the
/// unsharded multipliers: every sharded tensor's output-mult and
/// init-std, derived from its rank-LOCAL fan-in via
/// [`crate::scaling::Scheme::shard_output_mult`] /
/// [`crate::scaling::Scheme::shard_init_std`], must equal the
/// full-tensor values the assembled compute path applies. This is the
/// "static scales survive sharding" property executed, and it would
/// catch any drift between the partitioner's geometry and the scaling
/// rules.
pub fn validate_scales(cfg: &ModelConfig, spec: &ShardSpec) -> Result<()> {
    let scheme = cfg.scheme();
    let n_tensors = block::param_specs(cfg).len();
    for idx in 0..n_tensors {
        let role = block::role_of(cfg, idx);
        let Some(axis) = block::shard_axis(role) else { continue };
        let kind = block::param_kind(role);
        let full_fan = block::fan_in(cfg, role);
        let (dim, local_fan) = match axis {
            ShardAxis::Col { .. } => (ShardDim::FanOut, full_fan),
            ShardAxis::Row => (ShardDim::FanIn, full_fan / spec.tp),
        };
        let sharded = scheme.shard_output_mult(kind, dim, local_fan, spec.tp);
        if sharded != scheme.output_mult(kind, full_fan) {
            bail!("shard output-mult mismatch for {:?} (tensor {idx})", role);
        }
        let std_sharded = scheme.shard_init_std(kind, dim, local_fan, spec.tp, block::SIGMA_INIT);
        if std_sharded != scheme.init_std(kind, full_fan, block::SIGMA_INIT) {
            bail!("shard init-std mismatch for {:?} (tensor {idx})", role);
        }
    }
    Ok(())
}

fn shard_shape(shape: &[usize], axis: ShardAxis, tp: usize) -> Vec<usize> {
    match axis {
        ShardAxis::Row => vec![shape[0] / tp, shape[1]],
        ShardAxis::Col { .. } => vec![shape[0], shape[1] / tp],
    }
}

fn shard_slice(data: &[f32], shape: &[usize], axis: ShardAxis, tp: usize, rank: usize) -> Vec<f32> {
    let (rows, cols) = (shape[0], shape[1]);
    match axis {
        ShardAxis::Row => {
            let per = rows / tp;
            data[rank * per * cols..(rank + 1) * per * cols].to_vec()
        }
        ShardAxis::Col { blocks } => {
            let cb = cols / blocks; // columns per packed group (q|k|v)
            let sw = cb / tp; // this rank's columns per group
            let mut v = Vec::with_capacity(rows * cols / tp);
            for row in 0..rows {
                let base = row * cols;
                for b in 0..blocks {
                    let off = base + b * cb + rank * sw;
                    v.extend_from_slice(&data[off..off + sw]);
                }
            }
            v
        }
    }
}

fn unshard_into(
    full: &mut [f32],
    shard: &[f32],
    shape: &[usize],
    axis: ShardAxis,
    tp: usize,
    rank: usize,
) {
    let (rows, cols) = (shape[0], shape[1]);
    match axis {
        ShardAxis::Row => {
            let per = rows / tp;
            full[rank * per * cols..(rank + 1) * per * cols].copy_from_slice(shard);
        }
        ShardAxis::Col { blocks } => {
            let cb = cols / blocks;
            let sw = cb / tp;
            let mut src = 0usize;
            for row in 0..rows {
                let base = row * cols;
                for b in 0..blocks {
                    let off = base + b * cb + rank * sw;
                    full[off..off + sw].copy_from_slice(&shard[src..src + sw]);
                    src += sw;
                }
            }
        }
    }
}

/// Split a full `params ++ momenta` state into `tp` per-rank states.
/// Sharded tensors are sliced per `block::shard_axis`; everything else
/// (embedding, head, norm gains — and their momenta) is replicated.
/// Exact inverse of [`assemble_state`], bitwise.
pub fn partition_state(
    cfg: &ModelConfig,
    state: &TrainState,
    spec: &ShardSpec,
) -> Result<Vec<TrainState>> {
    let n = state.n_params;
    if state.tensors.len() != 2 * n {
        bail!("state has {} tensors for {} params", state.tensors.len(), n);
    }
    let mut ranks: Vec<Vec<Tensor>> = (0..spec.tp).map(|_| Vec::with_capacity(2 * n)).collect();
    for (idx, t) in state.tensors.iter().enumerate() {
        let role = block::role_of(cfg, idx % n);
        match block::shard_axis(role) {
            None => {
                for r in ranks.iter_mut() {
                    r.push(t.clone());
                }
            }
            Some(axis) => {
                let data = t.as_f32()?;
                let sshape = shard_shape(t.shape(), axis, spec.tp);
                for (rank, r) in ranks.iter_mut().enumerate() {
                    let v = shard_slice(data, t.shape(), axis, spec.tp, rank);
                    r.push(Tensor::f32(v, &sshape)?);
                }
            }
        }
    }
    Ok(ranks.into_iter().map(|tensors| TrainState { tensors, n_params: n }).collect())
}

/// Reassemble a full state from `tp` per-rank shards (inverse of
/// [`partition_state`]; replicated tensors are taken from rank 0).
pub fn assemble_state(
    cfg: &ModelConfig,
    shards: &[TrainState],
    spec: &ShardSpec,
) -> Result<TrainState> {
    if shards.len() != spec.tp {
        bail!("{} shard states for tp={}", shards.len(), spec.tp);
    }
    let n = shards[0].n_params;
    let pspecs = block::param_specs(cfg);
    let mut tensors = Vec::with_capacity(2 * n);
    for idx in 0..2 * n {
        let pidx = idx % n;
        let role = block::role_of(cfg, pidx);
        match block::shard_axis(role) {
            None => tensors.push(shards[0].tensors[idx].clone()),
            Some(axis) => {
                let shape = &pspecs[pidx].shape;
                let mut full = vec![0f32; pspecs[pidx].elements()];
                for (rank, s) in shards.iter().enumerate() {
                    unshard_into(
                        &mut full,
                        s.tensors[idx].as_f32()?,
                        shape,
                        axis,
                        spec.tp,
                        rank,
                    );
                }
                tensors.push(Tensor::f32(full, shape)?);
            }
        }
    }
    Ok(TrainState { tensors, n_params: n })
}

/// Tensor specs (names + shapes) of one rank's shard state, params then
/// momenta, mirroring the train artifact's `m_` naming. Sharded tensors
/// are suffixed `@tp{rank}of{tp}` so a checkpoint can never silently
/// load under the wrong geometry.
pub fn shard_state_specs(cfg: &ModelConfig, spec: &ShardSpec, rank: usize) -> Vec<TensorSpec> {
    let pspecs = block::param_specs(cfg);
    let mut out = Vec::with_capacity(2 * pspecs.len());
    let rank_spec = |ps: &TensorSpec, pidx: usize| {
        match block::shard_axis(block::role_of(cfg, pidx)) {
            None => ps.clone(),
            Some(axis) => TensorSpec {
                name: format!("{}@tp{}of{}", ps.name, rank, spec.tp),
                shape: shard_shape(&ps.shape, axis, spec.tp),
                dtype: Dtype::F32,
            },
        }
    };
    for (pidx, ps) in pspecs.iter().enumerate() {
        out.push(rank_spec(ps, pidx));
    }
    for (pidx, ps) in pspecs.iter().enumerate() {
        let mut s = rank_spec(ps, pidx);
        s.name = format!("m_{}", s.name);
        out.push(s);
    }
    out
}

/// Options for a sharded training run.
#[derive(Debug, Clone)]
pub struct ShardOpts {
    /// The sharding geometry.
    pub spec: ShardSpec,
    /// Collective wire format (Master = the bit-identity oracle, Fp8 =
    /// compressed state exchange).
    pub wire: WireFormat,
    /// Save a sharded checkpoint after completing N steps.
    pub save_at: Option<(usize, PathBuf)>,
    /// Resume from a sharded checkpoint (its spec must match).
    pub resume_from: Option<PathBuf>,
    /// Optimizer/master state-storage policy of every rank's session.
    /// Under [`StatePrecision::Fp8`] the momentum collective legs ship
    /// the native scaled-E4M3 bytes and `save_at` writes v2 checkpoints.
    pub state: StatePrecision,
}

impl ShardOpts {
    /// Options with no checkpointing, f32 state.
    pub fn new(spec: ShardSpec, wire: WireFormat) -> ShardOpts {
        ShardOpts { spec, wire, save_at: None, resume_from: None, state: StatePrecision::F32 }
    }

    /// Same options under an explicit [`StatePrecision`].
    pub fn with_state_precision(mut self, state: StatePrecision) -> ShardOpts {
        self.state = state;
        self
    }
}

/// Communication accounting of a sharded run.
#[derive(Debug, Clone)]
pub struct CommReport {
    /// Wire format the run used.
    pub wire: WireFormat,
    /// Steps the counters cover.
    pub steps: usize,
    /// Total allgather wire bytes.
    pub allgather_bytes: u64,
    /// Total reduce-scatter wire bytes.
    pub reduce_scatter_bytes: u64,
    /// Total pipeline stage-boundary activation bytes.
    pub activation_bytes: u64,
    /// Merged FP8 wire-cast health (zero counters on the master wire).
    pub health: CastHealth,
    /// Cross-shard amax/scale synchronizations (always 0 for static µS
    /// scales — asserted in tests).
    pub amax_syncs: u64,
}

impl CommReport {
    /// All wire bytes across collective classes.
    pub fn total_bytes(&self) -> u64 {
        self.allgather_bytes + self.reduce_scatter_bytes + self.activation_bytes
    }

    /// Wire bytes per training step.
    pub fn bytes_per_step(&self) -> u64 {
        if self.steps == 0 {
            0
        } else {
            self.total_bytes() / self.steps as u64
        }
    }
}

/// Outcome of [`train_sharded`]: run metrics, comm accounting, and the
/// final assembled full state (for bit-identity checks / handoff).
pub struct ShardRun {
    /// Trainer-equivalent run metrics.
    pub run: RunResult,
    /// Wire traffic + health accounting.
    pub comm: CommReport,
    /// Final full `params ++ momenta` state, assembled from the shards.
    pub final_state: TrainState,
}

/// Apply one collective leg (allgather or reduce-scatter) to every
/// rank's sharded tensors: wire-transform + byte accounting.
fn wire_leg(
    coll: &mut Collectives,
    shards: &mut [TrainState],
    sharded_idx: &[usize],
    n_params: usize,
    tp: usize,
    gather: bool,
) -> Result<()> {
    if tp <= 1 {
        return Ok(());
    }
    for (rank, st) in shards.iter_mut().enumerate() {
        for &idx in sharded_idx {
            let (mut v, shape) = {
                let t = &st.tensors[idx];
                (t.as_f32()?.to_vec(), t.shape().to_vec())
            };
            let payload = if idx < n_params { Payload::Param } else { Payload::Momentum };
            if gather {
                coll.allgather_shard(&mut v, payload, tp, rank);
            } else {
                coll.reduce_scatter_shard(&mut v, payload, tp, rank);
            }
            st.tensors[idx] = Tensor::f32(v, &shape)?;
        }
    }
    Ok(())
}

/// Train `cfg` sharded per `opts` for `tc.steps` steps.
///
/// Per step: each rank's shards cross the allgather wire (quantized
/// under the FP8 format), the full state is assembled and stepped once
/// through the bit-exact `train_step`, pipeline stage boundaries are
/// charged per the GPipe schedule, and the updated state is
/// reduce-scattered back to its owners. Data comes through the
/// background [`DataPipeline`] (same stream as the sequential trainer's
/// `Batcher`, so master-wire runs are bit-identical to `Trainer::run`).
pub fn train_sharded(
    backend: &dyn Backend,
    cfg: &ModelConfig,
    tc: &TrainConfig,
    corpus: &CorpusSpec,
    opts: &ShardOpts,
) -> Result<ShardRun> {
    opts.spec.validate(cfg)?;
    validate_scales(cfg, &opts.spec)?;
    let spec = opts.spec;
    let mut coll = Collectives::with_state(opts.wire, opts.state);
    let slots = gpipe::schedule(spec.stages, spec.microbatches);
    let send_elems = (cfg.batch / spec.microbatches) * cfg.seq_len * cfg.width;

    let mut session = Session::with_precision(backend, cfg, opts.state)?;
    let n_params = session.n_params_tensors();
    let sharded_idx: Vec<usize> = (0..2 * n_params)
        .filter(|&idx| block::shard_axis(block::role_of(cfg, idx % n_params)).is_some())
        .collect();

    let (mut shards, start_step) = match &opts.resume_from {
        Some(path) => load_checkpoint(path, cfg, &spec)?,
        None => {
            session.init(tc.init_seed)?;
            (partition_state(cfg, &session.read_back()?, &spec)?, 0)
        }
    };
    if start_step >= tc.steps && opts.resume_from.is_some() {
        bail!("checkpoint already at step {start_step}, run asks for {}", tc.steps);
    }

    let pipe = DataPipeline::spawn(
        corpus.clone(),
        tc.seed,
        0,
        1,
        cfg.batch,
        cfg.seq_len,
        2,
        Some(tc.steps),
    );
    for _ in 0..start_step {
        // fast-forward the deterministic stream to the resume point
        pipe.next().ok_or_else(|| err!("data pipeline ended during resume fast-forward"))?;
    }

    let mut losses = Vec::with_capacity(tc.steps - start_step);
    let mut gnorms = Vec::with_capacity(tc.steps - start_step);
    let mut ema = Ema::new(0.1);
    let mut spikes = 0usize;
    let mut diverged = false;
    let t0 = std::time::Instant::now();
    for step in start_step..tc.steps {
        let lr = tc.schedule.lr_at(tc.lr, step, tc.steps);
        let tokens = pipe.next().ok_or_else(|| err!("data pipeline ended early"))?;

        // allgather: every rank's shards reach the compute site
        wire_leg(&mut coll, &mut shards, &sharded_idx, n_params, spec.tp, true)?;
        let full = assemble_state(cfg, &shards, &spec)?;
        session.load_state(&full)?;
        let (loss, gnorm) = session.step(&tokens, lr, tc.wd, tc.tau)?;

        // pipeline stage boundaries, per the actual fill/drain timetable
        for sl in &slots {
            let crosses = match sl.phase {
                Phase::Fwd => sl.stage + 1 < spec.stages,
                Phase::Bwd => sl.stage > 0,
            };
            if crosses {
                coll.send_activations(send_elems);
            }
        }

        // reduce-scatter: updated shards return to their owners
        shards = partition_state(cfg, &session.read_back()?, &spec)?;
        wire_leg(&mut coll, &mut shards, &sharded_idx, n_params, spec.tp, false)?;

        losses.push(loss);
        gnorms.push(gnorm);
        if let Some(prev) = ema.get() {
            if (loss as f64) > prev + tc.spike_threshold {
                spikes += 1;
            }
        }
        ema.update(loss as f64);
        if !loss.is_finite() || loss as f64 > tc.max_loss {
            diverged = true;
            break;
        }
        if let Some((at, path)) = &opts.save_at {
            if step + 1 == *at {
                save_checkpoint(path, cfg, &spec, step + 1, &shards, opts.state)?;
            }
        }
    }
    let wall = t0.elapsed();
    let steps_done = losses.len();
    let tokens_per_sec =
        (steps_done * cfg.batch * cfg.seq_len) as f64 / wall.as_secs_f64().max(1e-9);
    let final_state = assemble_state(cfg, &shards, &spec)?;
    Ok(ShardRun {
        run: RunResult { losses, gnorms, steps_done, diverged, spikes, wall, tokens_per_sec },
        comm: CommReport {
            wire: opts.wire,
            steps: steps_done,
            allgather_bytes: coll.allgather_bytes,
            reduce_scatter_bytes: coll.reduce_scatter_bytes,
            activation_bytes: coll.activation_bytes,
            health: coll.health,
            amax_syncs: coll.amax_syncs,
        },
        final_state,
    })
}

/// Save the per-rank shard states (+ spec + step) as one file: the v1
/// always-f32 container under f32 state, the half-size native v2
/// container under FP8 state.
pub fn save_checkpoint(
    path: &Path,
    cfg: &ModelConfig,
    spec: &ShardSpec,
    step: usize,
    shards: &[TrainState],
    precision: StatePrecision,
) -> Result<()> {
    let specs: Vec<Vec<TensorSpec>> =
        (0..spec.tp).map(|r| shard_state_specs(cfg, spec, r)).collect();
    let (tp, stages, step) = (spec.tp as u32, spec.stages as u32, step as u32);
    match precision {
        StatePrecision::F32 => checkpoint::save_sharded(path, shards, &specs, tp, stages, step),
        StatePrecision::Fp8 => {
            checkpoint::save_sharded_v2(path, shards, &specs, tp, stages, step, precision)
        }
    }
    .with_context(|| format!("saving sharded checkpoint {}", path.display()))
}

/// Load a sharded checkpoint (v1 or v2 — the magic selects the decoder),
/// rejecting any [`ShardSpec`] mismatch with a contextual error. Returns
/// the per-rank states and the step count the checkpoint was taken at.
pub fn load_checkpoint(
    path: &Path,
    cfg: &ModelConfig,
    spec: &ShardSpec,
) -> Result<(Vec<TrainState>, usize)> {
    let specs: Vec<Vec<TensorSpec>> =
        (0..spec.tp).map(|r| shard_state_specs(cfg, spec, r)).collect();
    let (shards, step) =
        checkpoint::load_sharded(path, &specs, spec.tp as u32, spec.stages as u32)
            .with_context(|| format!("resuming sharded checkpoint {}", path.display()))?;
    Ok((shards, step as usize))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{micro_config, ReferenceBackend};

    fn seeded_state(cfg: &ModelConfig) -> TrainState {
        let be = ReferenceBackend::new(std::slice::from_ref(cfg)).unwrap();
        let mut s = Session::new(&be, cfg).unwrap();
        s.init(11).unwrap();
        s.read_back().unwrap()
    }

    #[test]
    fn partition_then_assemble_is_bitwise_identity() {
        let cfg = micro_config(); // 2 heads, ffn 64
        let state = seeded_state(&cfg);
        for tp in [1usize, 2] {
            let spec = ShardSpec::new(tp, 1);
            spec.validate(&cfg).unwrap();
            let shards = partition_state(&cfg, &state, &spec).unwrap();
            assert_eq!(shards.len(), tp);
            let back = assemble_state(&cfg, &shards, &spec).unwrap();
            for (a, b) in state.tensors.iter().zip(&back.tensors) {
                assert_eq!(a.as_f32().unwrap(), b.as_f32().unwrap());
                assert_eq!(a.shape(), b.shape());
            }
        }
    }

    #[test]
    fn column_shards_are_head_aligned_and_row_shards_band_the_fan_in() {
        // 2x2 toy with 2 packed groups: [r0: a0 a1 | b0 b1; r1: ...]
        let data: Vec<f32> = (0..8).map(|x| x as f32).collect();
        let shape = [2usize, 4usize];
        let s0 = shard_slice(&data, &shape, ShardAxis::Col { blocks: 2 }, 2, 0);
        let s1 = shard_slice(&data, &shape, ShardAxis::Col { blocks: 2 }, 2, 1);
        // rank 0 takes column 0 of EACH group, rank 1 column 1 of each
        assert_eq!(s0, vec![0.0, 2.0, 4.0, 6.0]);
        assert_eq!(s1, vec![1.0, 3.0, 5.0, 7.0]);
        let r0 = shard_slice(&data, &shape, ShardAxis::Row, 2, 0);
        assert_eq!(r0, vec![0.0, 1.0, 2.0, 3.0]);
    }

    #[test]
    fn spec_validation_rejects_bad_geometry() {
        let cfg = micro_config(); // n_heads = 2, depth = 2
        assert!(ShardSpec::new(4, 1).validate(&cfg).is_err()); // tp > heads
        assert!(ShardSpec::new(2, 2).validate(&cfg).is_ok());
        assert!(ShardSpec::new(2, 3).validate(&cfg).is_err()); // 3 ∤ depth
        assert!(ShardSpec::new(2, 1).with_microbatches(3).validate(&cfg).is_err());
        assert!(ShardSpec::new(0, 1).validate(&cfg).is_err());
        validate_scales(&cfg, &ShardSpec::new(2, 1)).unwrap();
    }

    #[test]
    fn shard_specs_name_rank_and_geometry() {
        let cfg = micro_config();
        let spec = ShardSpec::new(2, 1);
        let specs = shard_state_specs(&cfg, &spec, 1);
        let n = specs.len() / 2;
        let qkv = specs.iter().find(|s| s.name.starts_with("w_qkv0")).unwrap();
        assert_eq!(qkv.name, "w_qkv0@tp1of2");
        assert_eq!(qkv.shape, vec![cfg.width, 3 * cfg.width / 2]);
        let m_qkv = specs[n..].iter().find(|s| s.name.contains("w_qkv0")).unwrap();
        assert_eq!(m_qkv.name, "m_w_qkv0@tp1of2");
        // replicated tensors keep their plain names
        assert!(specs.iter().any(|s| s.name == "embed"));
        assert!(specs.iter().any(|s| s.name == "m_head"));
    }
}
