//! Parametrization & hyperparameter-transfer rule library.
//!
//! Encodes the comparison in the paper's Fig 1 / Tables 1-3: for each
//! scheme (SP, µP, Unit Scaling / u-µP, TE-style dynamic FP8, and µS), the
//! per-tensor init variance, output multiplier, learning-rate and
//! weight-decay transfer rules, and the hyperparameter set a practitioner
//! must sweep. The trainer and sweep engine consult this module; it is the
//! single source of truth mirrored by `python/compile/configs.py` (tested
//! for agreement via the manifest).
//!
//! The same rules are *proved* self-consistent before training:
//! [`crate::analysis::static_numerics`] propagates them symbolically over
//! the op graph (`munit verify-numerics`) to show every µS FP8 operand
//! lands in-band and width-flat, and that sharded
//! [`Scheme::shard_output_mult`]/[`Scheme::shard_init_std`] geometry
//! reproduces the full-tensor multipliers.

/// Which parametrization scheme a model is trained under.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Scheme {
    /// Standard parametrization, BF16 mixed precision (baseline).
    Sp,
    /// Maximal Update Parametrization (Yang et al. 2021).
    Mup,
    /// Unit Scaling / u-µP (Blake et al. 2023/2024).
    Ump,
    /// SP with TransformerEngine-style dynamically scaled FP8.
    SpTe,
    /// µnit Scaling (this paper).
    Mus,
}

/// Role of a tensor for scaling purposes (paper Table 2 columns).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ParamKind {
    /// Embedding table (input layer).
    Input,
    /// Hidden linear layers: qkv / attn-out / ffn-up / ffn-down.
    Hidden,
    /// LM head (output layer).
    Output,
    /// LayerNorm gains/biases.
    Norm,
}

/// How a matmul weight is split across tensor-parallel ranks (the
/// Megatron decomposition): column-parallel shards divide the fan-out,
/// row-parallel shards divide the fan-in (contraction) dimension.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardDim {
    /// Column-parallel: fan-out split, full contraction on every rank.
    FanOut,
    /// Row-parallel: fan-in split, ranks produce partial sums.
    FanIn,
}

impl Scheme {
    /// Human-readable scheme name (the Fig 1 row label).
    pub fn name(&self) -> &'static str {
        match self {
            Scheme::Sp => "SP (BF16)",
            Scheme::Mup => "µP",
            Scheme::Ump => "Unit Scaling / u-µP",
            Scheme::SpTe => "Dynamically scaled FP8 (TE)",
            Scheme::Mus => "µnit Scaling (ours)",
        }
    }

    /// Hyperparameters one sweeps in practice (paper Table 3).
    pub fn hyperparameters(&self) -> &'static [&'static str] {
        match self {
            Scheme::Sp | Scheme::SpTe => &["eta", "lambda", "sigma_init"],
            Scheme::Mus => &["eta", "lambda", "tau"],
            Scheme::Mup => &[
                "eta", "lambda", "sigma_init", "alpha_res", "alpha_attn", "alpha_out",
            ],
            Scheme::Ump => &[
                "eta", "lambda", "alpha_ffn_act", "alpha_attn_softmax", "alpha_res",
                "alpha_res_attn_ratio", "alpha_loss_softmax",
            ],
        }
    }

    /// Does the scheme use FP8 compute for hidden linears? (Fig 1 col 1)
    /// Returns fraction of hidden matmul FLOPs in FP8.
    pub fn fp8_hidden_fraction(&self) -> f64 {
        match self {
            Scheme::Sp | Scheme::Mup => 0.0,
            // u-µP keeps "critical matmuls" (attn-out, ffn-down) in BF16:
            // with MHA + 4x FFN that is 41.7% of hidden FLOPs (paper §1)
            Scheme::Ump => 1.0 - 0.417,
            Scheme::SpTe | Scheme::Mus => 1.0,
        }
    }

    /// Does the scheme transfer hyperparameters zero-shot across widths?
    pub fn supports_hp_transfer(&self) -> bool {
        matches!(self, Scheme::Mup | Scheme::Ump | Scheme::Mus)
    }

    /// Does the scheme need runtime per-tensor amax scaling (the overhead
    /// µS's static scales delete)?
    pub fn uses_dynamic_scaling(&self) -> bool {
        matches!(self, Scheme::SpTe)
    }

    /// Init std for a tensor. `fan_in` is the matmul contraction dim,
    /// `sigma_init` the SP tuning knob.
    pub fn init_std(&self, kind: ParamKind, fan_in: usize, sigma_init: f64) -> f64 {
        match (self, kind) {
            (_, ParamKind::Norm) => 0.0, // gain=1/bias=0, not random
            (Scheme::Sp | Scheme::SpTe, _) => sigma_init,
            (Scheme::Mup, ParamKind::Hidden | ParamKind::Output) => {
                1.0 / (fan_in as f64).sqrt()
            }
            (Scheme::Mup, ParamKind::Input) => sigma_init,
            (Scheme::Ump | Scheme::Mus, _) => 1.0, // unit variance everywhere
        }
    }

    /// Static output multiplier for a tensor (paper Table 2 for µS).
    pub fn output_mult(&self, kind: ParamKind, fan_in: usize) -> f64 {
        match (self, kind) {
            (Scheme::Mus | Scheme::Ump, ParamKind::Hidden) => 1.0 / (fan_in as f64).sqrt(),
            (Scheme::Mus | Scheme::Ump, ParamKind::Output) => 1.0 / fan_in as f64,
            (Scheme::Mup, ParamKind::Output) => 1.0 / fan_in as f64,
            _ => 1.0,
        }
    }

    /// Zero-shot LR transfer: multiplier on the base learning rate when
    /// growing width from `d_base` to `d_new` (Adam-like optimizers).
    ///
    /// ```
    /// use munit::scaling::{ParamKind, Scheme};
    /// // µS §2.3: hidden LR scales as √(d_base/d); head LR is constant
    /// assert_eq!(Scheme::Mus.lr_transfer(ParamKind::Hidden, 256, 1024), 0.5);
    /// assert_eq!(Scheme::Mus.lr_transfer(ParamKind::Output, 256, 1024), 1.0);
    /// ```
    pub fn lr_transfer(&self, kind: ParamKind, d_base: usize, d_new: usize) -> f64 {
        let ratio = d_base as f64 / d_new as f64;
        match (self, kind) {
            // µS §2.3: hidden layers scale as sqrt(d_base/d_new); embedding,
            // norms and head keep eta constant.
            (Scheme::Mus, ParamKind::Hidden) => ratio.sqrt(),
            (Scheme::Mus, _) => 1.0,
            // µP (Adam): hidden LR ~ 1/width; input/output constant.
            (Scheme::Mup | Scheme::Ump, ParamKind::Hidden) => ratio,
            (Scheme::Mup | Scheme::Ump, _) => 1.0,
            // SP has no principled rule; the paper's empirical recipe is
            // eta_new = eta_base * d_base/d_new for ALL layers (§3.2).
            (Scheme::Sp | Scheme::SpTe, _) => ratio,
        }
    }

    /// Per-tensor fully-decoupled weight-decay mask (mirrors python
    /// `wd_mult`): matrix parameters decay, norm gains/biases do not.
    pub fn wd_mult(&self, kind: ParamKind) -> f64 {
        match kind {
            ParamKind::Norm => 0.0,
            _ => 1.0,
        }
    }

    /// Predicted width-scaling exponent β of hidden activation-GRADIENT
    /// RMS at matched (vocab, batch, seq) inputs: `rms(grad) ∝ (1/d)^β`.
    ///
    /// Under µS (and µP) the LM head's `1/fan_in` output multiplier puts
    /// a `1/d` on `dL/dy`, and every hidden op preserves that scale on
    /// the way down (unit-variance weights × `1/√fan_in` multipliers and
    /// O(1)-divisor norm backwards — the derivation is docs/NUMERICS.md
    /// §Backward), so β = 1: the coordinate-check harness multiplies
    /// recorded grad RMS by `(d/d_base)^β` and asserts the compensated
    /// values are width-flat. SP has no static output multiplier and no
    /// clean power law; it reports β = 0 (no compensation).
    pub fn grad_rms_width_exponent(&self) -> f64 {
        match self {
            Scheme::Mus | Scheme::Mup => 1.0,
            Scheme::Ump => 1.0,
            Scheme::Sp | Scheme::SpTe => 0.0,
        }
    }

    /// The fan-in a tensor-parallel rank must plug into this scheme's
    /// static rules for its shard of a weight split `dim`-wise over `tp`
    /// ranks, given the rank-local contraction dim `local_fan_in`.
    ///
    /// Column-parallel shards keep the full contraction on every rank,
    /// so the local fan-in *is* the effective one. Row-parallel shards
    /// contract only `1/tp` of the input, but each partial output must
    /// still carry the FULL-fan-in multiplier — the sharded op sums
    /// `tp` partials and `α·Σyᵢ = Σα·yᵢ` only for the unsharded α. This
    /// is the closed-form reason µS needs no per-shard re-derivation
    /// (and no runtime statistics): the effective fan-in is a constant
    /// of the shard spec, known before any data flows.
    pub fn shard_fan_in(&self, dim: ShardDim, local_fan_in: usize, tp: usize) -> usize {
        match dim {
            ShardDim::FanOut => local_fan_in,
            ShardDim::FanIn => local_fan_in * tp,
        }
    }

    /// [`Scheme::output_mult`] evaluated from a TP rank's *local* shard
    /// geometry. Equals the unsharded multiplier for every scheme
    /// (tested) — the invariance the sharded trainer validates at
    /// startup.
    pub fn shard_output_mult(
        &self,
        kind: ParamKind,
        dim: ShardDim,
        local_fan_in: usize,
        tp: usize,
    ) -> f64 {
        self.output_mult(kind, self.shard_fan_in(dim, local_fan_in, tp))
    }

    /// [`Scheme::init_std`] evaluated from a TP rank's *local* shard
    /// geometry: a rank can initialize (or re-derive) its shard without
    /// seeing the full tensor.
    pub fn shard_init_std(
        &self,
        kind: ParamKind,
        dim: ShardDim,
        local_fan_in: usize,
        tp: usize,
        sigma_init: f64,
    ) -> f64 {
        self.init_std(kind, self.shard_fan_in(dim, local_fan_in, tp), sigma_init)
    }

    /// Fully-decoupled weight decay transfer (paper §3.2).
    pub fn wd_transfer(&self, d_base: usize, d_new: usize) -> f64 {
        match self {
            // µS: lambda* stays constant across widths.
            Scheme::Mus | Scheme::Mup | Scheme::Ump => 1.0,
            // SP: the paper's large-model recipe halves lambda at transfer.
            Scheme::Sp | Scheme::SpTe => {
                if d_new > d_base {
                    0.5
                } else {
                    1.0
                }
            }
        }
    }
}

/// One row of the paper's Fig 1 comparison matrix.
#[derive(Debug, Clone)]
pub struct SchemeRow {
    /// The scheme this row describes.
    pub scheme: Scheme,
    /// Any hidden matmuls in FP8?
    pub uses_fp8: bool,
    /// Zero-shot hyperparameter transfer?
    pub hp_transfer: bool,
    /// Hyperparameters a practitioner must sweep (Table 3).
    pub n_hparams: usize,
    /// Free of runtime amax machinery?
    pub no_dynamic_scaling: bool,
    /// Training numerics identical to inference numerics?
    pub train_infer_match: bool,
}

/// The Fig 1 matrix, one row per scheme.
pub fn comparison_matrix() -> Vec<SchemeRow> {
    [Scheme::Sp, Scheme::Mup, Scheme::Ump, Scheme::SpTe, Scheme::Mus]
        .into_iter()
        .map(|s| SchemeRow {
            scheme: s,
            uses_fp8: s.fp8_hidden_fraction() > 0.0,
            hp_transfer: s.supports_hp_transfer(),
            n_hparams: s.hyperparameters().len(),
            no_dynamic_scaling: !s.uses_dynamic_scaling(),
            train_infer_match: s.fp8_hidden_fraction() >= 1.0,
        })
        .collect()
}

/// Residual-coefficient recommendation: τ* decreases with depth (paper
/// Fig 9 / App. A.2). Piecewise fit of the published sweep results, used
/// by presets (Table 4 uses 0.3 for 24-32 layers, 0.2 for 40).
pub fn recommended_tau(depth: usize) -> f64 {
    match depth {
        0..=4 => 0.4,
        5..=11 => 0.35,
        12..=23 => 0.3,
        24..=35 => 0.3,
        36..=59 => 0.2,
        _ => 0.1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_hparam_counts() {
        assert_eq!(Scheme::Mus.hyperparameters().len(), 3);
        assert_eq!(Scheme::Sp.hyperparameters().len(), 3);
        assert_eq!(Scheme::Mup.hyperparameters().len(), 6);
        assert_eq!(Scheme::Ump.hyperparameters().len(), 7);
    }

    #[test]
    fn fig1_matrix_mus_has_all_properties() {
        let rows = comparison_matrix();
        let mus = rows.iter().find(|r| r.scheme == Scheme::Mus).unwrap();
        assert!(mus.uses_fp8 && mus.hp_transfer && mus.no_dynamic_scaling);
        assert!(mus.train_infer_match);
        assert_eq!(mus.n_hparams, 3);
        // no other scheme has every property
        for r in &rows {
            if r.scheme != Scheme::Mus {
                let all = r.uses_fp8 && r.hp_transfer && r.no_dynamic_scaling
                    && r.train_infer_match && r.n_hparams <= 3;
                assert!(!all, "{:?}", r.scheme);
            }
        }
    }

    #[test]
    fn mus_lr_transfer_sqrt_rule() {
        // 20x width transfer of the paper: 256 -> 5120
        let m = Scheme::Mus.lr_transfer(ParamKind::Hidden, 256, 5120);
        assert!((m - (256.0f64 / 5120.0).sqrt()).abs() < 1e-12);
        assert_eq!(Scheme::Mus.lr_transfer(ParamKind::Input, 256, 5120), 1.0);
        assert_eq!(Scheme::Mus.lr_transfer(ParamKind::Output, 256, 5120), 1.0);
        assert_eq!(Scheme::Mus.lr_transfer(ParamKind::Norm, 256, 5120), 1.0);
    }

    #[test]
    fn sp_lr_transfer_linear_rule() {
        assert!((Scheme::Sp.lr_transfer(ParamKind::Hidden, 256, 2048) - 0.125).abs() < 1e-12);
        assert!((Scheme::Sp.lr_transfer(ParamKind::Input, 256, 2048) - 0.125).abs() < 1e-12);
    }

    #[test]
    fn grad_exponent_by_scheme() {
        // schemes with a 1/fan_in head multiplier put a clean 1/d on the
        // backward stream; SP families have no compensable power law
        assert_eq!(Scheme::Mus.grad_rms_width_exponent(), 1.0);
        assert_eq!(Scheme::Mup.grad_rms_width_exponent(), 1.0);
        assert_eq!(Scheme::Sp.grad_rms_width_exponent(), 0.0);
        assert_eq!(Scheme::SpTe.grad_rms_width_exponent(), 0.0);
    }

    #[test]
    fn wd_transfer_rules() {
        assert_eq!(Scheme::Mus.wd_transfer(256, 5120), 1.0);
        assert_eq!(Scheme::Sp.wd_transfer(256, 5120), 0.5);
        assert_eq!(Scheme::Sp.wd_transfer(256, 256), 1.0);
    }

    #[test]
    fn wd_mult_excludes_norm_gains() {
        for s in [Scheme::Sp, Scheme::SpTe, Scheme::Mus, Scheme::Mup, Scheme::Ump] {
            assert_eq!(s.wd_mult(ParamKind::Norm), 0.0);
            assert_eq!(s.wd_mult(ParamKind::Hidden), 1.0);
            assert_eq!(s.wd_mult(ParamKind::Input), 1.0);
            assert_eq!(s.wd_mult(ParamKind::Output), 1.0);
        }
    }

    #[test]
    fn mus_output_mults_match_table2() {
        assert!((Scheme::Mus.output_mult(ParamKind::Hidden, 1024) - 1.0 / 32.0).abs() < 1e-12);
        assert!((Scheme::Mus.output_mult(ParamKind::Output, 1024) - 1.0 / 1024.0).abs() < 1e-12);
        assert_eq!(Scheme::Mus.output_mult(ParamKind::Input, 1024), 1.0);
    }

    #[test]
    fn mus_unit_init() {
        assert_eq!(Scheme::Mus.init_std(ParamKind::Hidden, 4096, 0.02), 1.0);
        assert_eq!(Scheme::Mus.init_std(ParamKind::Input, 4096, 0.02), 1.0);
        assert_eq!(Scheme::Sp.init_std(ParamKind::Hidden, 4096, 0.02), 0.02);
    }

    #[test]
    fn ump_partial_fp8() {
        let f = Scheme::Ump.fp8_hidden_fraction();
        assert!(f > 0.5 && f < 1.0);
    }

    #[test]
    fn tau_decreases_with_depth() {
        // paper Table 4: tau 0.3 at depth 24-32, 0.2 at depth 40
        assert!((recommended_tau(24) - 0.3).abs() < 1e-12);
        assert!((recommended_tau(32) - 0.3).abs() < 1e-12);
        assert!((recommended_tau(40) - 0.2).abs() < 1e-12);
        let mut prev = f64::INFINITY;
        for d in [4, 8, 16, 24, 40, 100] {
            let t = recommended_tau(d);
            assert!(t <= prev);
            prev = t;
        }
    }

    #[test]
    fn mup_abc_equivalence_to_mus() {
        // Eq. 15-16: theta = 1/sqrt(fan_in) maps µP's (a=1, b=1/sqrt(f),
        // c=1/f) to µS's (a=1/sqrt(f), b=1, c=1/sqrt(f)).
        let f = 4096usize;
        let theta = 1.0 / (f as f64).sqrt();
        let (a, b, c) = (1.0, 1.0 / (f as f64).sqrt(), 1.0 / f as f64);
        let (a2, b2, c2) = (a * theta, b / theta, c / theta);
        assert!((a2 - Scheme::Mus.output_mult(ParamKind::Hidden, f)).abs() < 1e-15);
        assert!((b2 - Scheme::Mus.init_std(ParamKind::Hidden, f, 0.0)).abs() < 1e-15);
        // c2 = 1/sqrt(f): the sqrt LR rule µS uses
        assert!((c2 - theta).abs() < 1e-15);
    }

    #[test]
    fn shard_rules_reproduce_the_unsharded_multipliers() {
        // every scheme, both split axes: a rank deriving its multiplier
        // from local shard geometry lands exactly on the full-tensor
        // value — no cross-shard exchange needed to agree on scales.
        let d = 1024usize;
        for s in [Scheme::Sp, Scheme::Mup, Scheme::Ump, Scheme::SpTe, Scheme::Mus] {
            for tp in [1usize, 2, 4, 8] {
                for kind in [ParamKind::Hidden, ParamKind::Output] {
                    let full = s.output_mult(kind, d);
                    // column split: local fan_in == d
                    assert_eq!(s.shard_output_mult(kind, ShardDim::FanOut, d, tp), full);
                    // row split: local fan_in == d/tp, mult still α(d)
                    assert_eq!(s.shard_output_mult(kind, ShardDim::FanIn, d / tp, tp), full);
                    let fs = s.init_std(kind, d, 0.02);
                    assert_eq!(s.shard_init_std(kind, ShardDim::FanOut, d, tp, 0.02), fs);
                    assert_eq!(s.shard_init_std(kind, ShardDim::FanIn, d / tp, tp, 0.02), fs);
                }
            }
        }
        // the trap the helper exists to avoid: plugging the row-shard's
        // LOCAL fan-in into the rule directly is wrong under µS…
        let naive = Scheme::Mus.output_mult(ParamKind::Hidden, d / 4);
        assert!(naive != Scheme::Mus.output_mult(ParamKind::Hidden, d));
        // …while µS init_std (unit variance) happens to be fan-independent,
        // which is exactly why sharded *init* needs no re-derivation.
        assert_eq!(
            Scheme::Mus.init_std(ParamKind::Hidden, d / 4, 0.02),
            Scheme::Mus.init_std(ParamKind::Hidden, d, 0.02)
        );
    }
}
