//! Op-level transformer block: the reference interpreter's model math.
//!
//! This module owns everything between "token ids in" and "gradients
//! out" for the reference backend — the paper-faithful decoder block the
//! old residual-MLP tower replaced with a single `[d,d]` matmul:
//!
//! ```text
//! embed → depth × { [norm] → qkv → RoPE → causal MHA → attn-out
//!                   → scaled residual ─ [norm] → ffn-up → act → ffn-down
//!                   → scaled residual }
//!       → final RMS-norm → LM head
//! ```
//!
//! Norm placement follows the paper / L2 python model: µS uses
//! Res-Post-RMSNorm (the norm is the *last* op of each residual branch,
//! Fig 4a), SP uses Pre-RMSNorm. The four hidden linears per block (qkv,
//! attn-out, ffn-up, ffn-down) are quantized **per-op** via [`Plan`]
//! (static E4M3/E5M2 for µS+FP8, TE-style dynamic scaling for SP+FP8,
//! BF16 otherwise) — per-op so that recipes which differ per matmul
//! (u-µP keeps attn-out/ffn-down in BF16; FP8-LM is per-tensor dynamic)
//! are expressible. Attention is never FP8: its operands (the RoPE'd qkv
//! projections) are BF16-rounded and the score/softmax/value arithmetic
//! runs in f32, like the embedding, norms, and LM head (paper Table 1
//! keeps everything but the hidden linears in high precision).
//!
//! **Shared per-op pipeline.** The forward is expressed as reusable
//! per-op functions — [`op_embed`], [`op_rmsnorm`], [`op_linear`],
//! [`rope_rotate`] (via the head marshallers), [`apply_act`],
//! [`residual_combine`], plus the shared single-query attention kernel
//! `gemm::attn_one_query` — consumed by BOTH the full-sequence
//! train/eval forward ([`forward_tower`], geometry-generic over
//! `batch × s`) and the incremental KV-cache decode path
//! (`runtime::infer`). Prefill *is* the training forward called through
//! [`logits_rows`] with an optional per-layer KV sink, so training and
//! inference numerics match by construction: a decode step over the
//! BF16 KV cache reproduces the matching training-forward logits row
//! bit for bit under the static-FP8 and BF16 plans (dynamic SP+FP8
//! scaling computes its amax over whatever tensor it sees, so its decode
//! numerics depend on batch composition — exactly the serving-side
//! overhead the paper's static scaling deletes).
//!
//! Every scaling rule — init std, output multipliers, LR/wd transfer —
//! is consumed from [`crate::scaling::Scheme`]; nothing is re-derived
//! here. Per-step invariants (parsed activation, quantization plan,
//! residual coefficients, RoPE tables, output multipliers) are resolved
//! once per interpreter call into a [`Prepared`] struct.
//!
//! Determinism: all batched passes use fixed chunk boundaries
//! ([`crate::util::parallel`]), attention parallelizes over (batch, head)
//! pairs with a fixed serial kernel per head ([`crate::runtime::gemm`]),
//! and every reduction folds in a fixed order — results are bit-identical
//! at any worker-thread count.
//!
//! **Telemetry**: when a [`crate::telemetry::capture`] is active on the
//! calling thread, `observe_rms`/`observe_cast` hooks record every tower
//! tensor's RMS and every FP8 operand's cast health. The hooks are one
//! thread-local flag check when no sink is installed and strictly
//! read-only when one is — training is bit-identical either way (tested),
//! which is what keeps the instrument honest.

use super::gemm::{
    add_matmul_at_b, attn_backward_causal, attn_forward_causal, matmul_bt, matmul_bt_quant,
    quant_transpose,
};
use super::manifest::{Dtype, TensorSpec};
use crate::config::ModelConfig;
use crate::fp8::{Format, BF16, E4M3, E5M2};
use crate::scaling::ParamKind;
use crate::telemetry;
use crate::util::error::{Error, Result};
use crate::util::parallel;
use crate::util::rng::Rng;
use crate::{bail, err};

// ---------------------------------------------------------------------------
// Telemetry hooks
//
// Both helpers reduce to one thread-local flag check when no telemetry
// sink is installed (the default), and only *read* tensors when one is —
// training is bit-identical with the sink on, off, or absent (tested at
// trainer level). They are called from sequential points of the pipeline
// (never inside parallel kernels, whose worker threads would not see the
// calling thread's sink).

/// Record the RMS/abs-max of one tensor under `(op, layer)`.
fn observe_rms(op: &'static str, layer: usize, xs: &[f32]) {
    if telemetry::enabled() {
        telemetry::record_rms(op, layer, xs);
    }
}

/// Record FP8 cast-health for the tensor `mode` is about to quantize,
/// exactly as the quantizer will see it: static µS casts at scale 1,
/// dynamic TE-style casts per the same [`te_dynamic_scale`] policy
/// `quantize_slice` executes (recomputed read-only here — the quantizer
/// itself is not perturbed; an all-zero dynamic tensor records nothing
/// because no cast runs). BF16 round-trips are not FP8 casts and record
/// nothing.
pub(crate) fn observe_cast(op: &'static str, layer: usize, xs: &[f32], mode: QuantMode) {
    if !telemetry::enabled() || xs.is_empty() {
        return;
    }
    let (fmt, scale) = match mode {
        QuantMode::Bf16 => return,
        QuantMode::StaticFp8(f) => (f, 1.0f32),
        QuantMode::DynamicFp8(f) => {
            let amax = super::gemm::abs_max(xs);
            match te_dynamic_scale(f.fast_caster().max_finite(), amax) {
                DynScale::Skip => return,
                DynScale::Raw => (f, 1.0),
                DynScale::Scale(s) => (f, s),
            }
        }
    };
    telemetry::record_cast(op, layer, fmt.name, fmt.cast_health(xs, scale));
}

/// SP weight-init stddev (the sigma_init knob SP practitioners sweep;
/// matches `python/compile/configs.py`). Which tensors use it is decided
/// by [`crate::scaling::Scheme::init_std`], not here.
pub(crate) const SIGMA_INIT: f64 = 0.02;

/// RoPE base frequency (matches the L2 python model's `rope_theta`).
const ROPE_THETA: f32 = 10_000.0;

/// RMS-norm epsilon inside the per-row divisor `sqrt(mean(x²) + EPS)`.
const RMS_EPS: f64 = 1e-6;

/// Fixed chunk length for parallel elementwise passes (boundaries are a
/// function of buffer length only — thread-count invariant).
pub(crate) const ELEM_CHUNK: usize = 1 << 14;

/// Fixed rows-per-chunk for row-parallel passes.
const ROW_CHUNK: usize = 32;

// ---------------------------------------------------------------------------
// Parameter layout

/// Learnable tensors per block: w_qkv, w_o, w_up, w_down, rms1_g, rms2_g.
pub(crate) const TENSORS_PER_BLOCK: usize = 6;

/// Total parameter-tensor count: embed + 6·depth + final gain + head.
pub(crate) fn n_param_tensors(cfg: &ModelConfig) -> usize {
    TENSORS_PER_BLOCK * cfg.depth + 3
}

pub(crate) fn idx_qkv(l: usize) -> usize {
    1 + TENSORS_PER_BLOCK * l
}
pub(crate) fn idx_o(l: usize) -> usize {
    2 + TENSORS_PER_BLOCK * l
}
pub(crate) fn idx_up(l: usize) -> usize {
    3 + TENSORS_PER_BLOCK * l
}
pub(crate) fn idx_down(l: usize) -> usize {
    4 + TENSORS_PER_BLOCK * l
}
pub(crate) fn idx_g1(l: usize) -> usize {
    5 + TENSORS_PER_BLOCK * l
}
pub(crate) fn idx_g2(l: usize) -> usize {
    6 + TENSORS_PER_BLOCK * l
}
pub(crate) fn idx_gf(cfg: &ModelConfig) -> usize {
    n_param_tensors(cfg) - 2
}
pub(crate) fn idx_head(cfg: &ModelConfig) -> usize {
    n_param_tensors(cfg) - 1
}

/// Role of a parameter tensor in the block pipeline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum Role {
    Embed,
    Qkv,
    AttnOut,
    FfnUp,
    FfnDown,
    Rms1,
    Rms2,
    RmsFinal,
    Head,
}

pub(crate) fn role_of(cfg: &ModelConfig, idx: usize) -> Role {
    let n = n_param_tensors(cfg);
    debug_assert!(idx < n, "param index {idx} out of range {n}");
    if idx == 0 {
        return Role::Embed;
    }
    if idx == n - 1 {
        return Role::Head;
    }
    if idx == n - 2 {
        return Role::RmsFinal;
    }
    match (idx - 1) % TENSORS_PER_BLOCK {
        0 => Role::Qkv,
        1 => Role::AttnOut,
        2 => Role::FfnUp,
        3 => Role::FfnDown,
        4 => Role::Rms1,
        _ => Role::Rms2,
    }
}

/// Scaling-purpose kind of a role (feeds [`crate::scaling::Scheme`] rules).
pub(crate) fn param_kind(role: Role) -> ParamKind {
    match role {
        Role::Embed => ParamKind::Input,
        Role::Qkv | Role::AttnOut | Role::FfnUp | Role::FfnDown => ParamKind::Hidden,
        Role::Rms1 | Role::Rms2 | Role::RmsFinal => ParamKind::Norm,
        Role::Head => ParamKind::Output,
    }
}

/// Matmul contraction dim of a role's tensor. Only Hidden/Output fan-ins
/// feed scaling rules; norm gains and the embedding report the model
/// width (their rules ignore it).
pub(crate) fn fan_in(cfg: &ModelConfig, role: Role) -> usize {
    match role {
        Role::FfnDown => cfg.ffn_width(),
        _ => cfg.width,
    }
}

/// Tensor-parallel split axis of a weight (the Megatron decomposition,
/// stored `[fan_in, fan_out]`): the attention input projection and FFN
/// up-projection are **column**-parallel (fan_out split, each rank owns
/// whole heads / whole FFN neurons), their mirror projections are
/// **row**-parallel (fan_in split, ranks produce partial sums).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum ShardAxis {
    /// Fan-out split into `blocks` independent column groups, each
    /// divided across ranks (`w_qkv` packs q|k|v ⇒ 3 groups, so every
    /// rank gets *its heads'* q, k and v columns).
    Col {
        /// Independent packed column groups in the tensor.
        blocks: usize,
    },
    /// Fan-in split: each rank holds a contiguous row band.
    Row,
}

/// Which axis (if any) tensor parallelism splits this role on. Embedding,
/// head and norm gains are replicated — `None`.
pub(crate) fn shard_axis(role: Role) -> Option<ShardAxis> {
    match role {
        Role::Qkv => Some(ShardAxis::Col { blocks: 3 }),
        Role::FfnUp => Some(ShardAxis::Col { blocks: 1 }),
        Role::AttnOut | Role::FfnDown => Some(ShardAxis::Row),
        _ => None,
    }
}

/// Reference-model parameter tensors in state order. Weights are stored
/// `[fan_in, fan_out]` (the python `param_specs` convention); norms are
/// gain-only RMS norms.
pub(crate) fn param_specs(cfg: &ModelConfig) -> Vec<TensorSpec> {
    let (d, f, v) = (cfg.width, cfg.ffn_width(), cfg.vocab);
    let spec = |name: String, shape: Vec<usize>| TensorSpec { name, shape, dtype: Dtype::F32 };
    let mut specs = Vec::with_capacity(n_param_tensors(cfg));
    specs.push(spec("embed".into(), vec![v, d]));
    for l in 0..cfg.depth {
        specs.push(spec(format!("w_qkv{l}"), vec![d, 3 * d]));
        specs.push(spec(format!("w_o{l}"), vec![d, d]));
        specs.push(spec(format!("w_up{l}"), vec![d, f]));
        specs.push(spec(format!("w_down{l}"), vec![f, d]));
        specs.push(spec(format!("rms1_g{l}"), vec![d]));
        specs.push(spec(format!("rms2_g{l}"), vec![d]));
    }
    specs.push(spec("rmsf_g".into(), vec![d]));
    specs.push(spec("head".into(), vec![d, v]));
    specs
}

/// Initialize all parameter tensors (state order) from a seed: norm gains
/// start at exactly 1 (their [`crate::scaling::Scheme::init_std`] is 0 — deterministic),
/// everything else is N(0, std²) with std from the scheme.
pub(crate) fn init_params(cfg: &ModelConfig, seed: i32) -> Vec<Vec<f32>> {
    let scheme = cfg.scheme();
    let rng = Rng::new(0x5EED_0000_u64 ^ (seed as i64 as u64));
    let specs = param_specs(cfg);
    let mut out = Vec::with_capacity(specs.len());
    for (i, spec) in specs.iter().enumerate() {
        let role = role_of(cfg, i);
        let kind = param_kind(role);
        if kind == ParamKind::Norm {
            out.push(vec![1f32; spec.elements()]);
            continue;
        }
        let std = scheme.init_std(kind, fan_in(cfg, role), SIGMA_INIT) as f32;
        let mut r = rng.fork(0x9A17 + i as u64);
        let mut data = vec![0f32; spec.elements()];
        r.fill_normal(&mut data, std);
        out.push(data);
    }
    out
}

// ---------------------------------------------------------------------------
// FLOP accounting (consumed by the perfmodel agreement test)

/// The four hidden GEMMs' `(name, fan_out, fan_in)` shapes per token —
/// enumerated from the same layout the pipeline executes.
pub(crate) fn hidden_gemm_shapes(cfg: &ModelConfig) -> [(&'static str, usize, usize); 4] {
    let (d, f) = (cfg.width, cfg.ffn_width());
    [("qkv", 3 * d, d), ("attn_out", d, d), ("ffn_up", f, d), ("ffn_down", d, f)]
}

/// Forward hidden-GEMM FLOPs per token per block (2·out·in per GEMM).
pub(crate) fn hidden_gemm_flops_per_token_fwd(cfg: &ModelConfig) -> u64 {
    hidden_gemm_shapes(cfg).iter().map(|&(_, out, inp)| 2 * out as u64 * inp as u64).sum()
}

/// Forward attention score+value GEMM FLOPs per sequence per block:
/// query i touches i+1 keys and i+1 values, 2·dh FLOPs each, over h heads
/// → `h · 4·dh · Σᵢ(i+1)` = `2·d·s·(s+1)`.
pub(crate) fn attn_gemm_flops_per_seq_fwd(cfg: &ModelConfig) -> u64 {
    let (s, dh, h) = (cfg.seq_len as u64, cfg.head_dim as u64, cfg.n_heads() as u64);
    h * 2 * dh * s * (s + 1)
}

/// Single-query cached-attention FLOPs for ONE decode token at context
/// length `ctx` (the token attends to `ctx` cached positions including
/// itself), per block: the query scores `ctx` keys and mixes `ctx`
/// values, 2·dh FLOPs each, over h heads → `h · 4·dh·ctx` = `4·d·ctx`.
/// Enumerated from the same per-head kernel shape the decode path
/// executes (`gemm::attn_one_query` over the gathered cache).
pub(crate) fn attn_decode_flops_per_token(cfg: &ModelConfig, ctx: usize) -> u64 {
    let (dh, h) = (cfg.head_dim as u64, cfg.n_heads() as u64);
    h * 4 * dh * ctx as u64
}

// ---------------------------------------------------------------------------
// Numerics: quantization modes, per-op plan, activations, residuals

#[derive(Debug, Clone, Copy)]
pub(crate) enum QuantMode {
    /// BF16 round-trip (the "high precision" lane of the artifact graphs).
    Bf16,
    /// µS static scaling: clip to max_finite, then cast.
    StaticFp8(Format),
    /// TE-style dynamic scaling: rescale to the format's range by the
    /// tensor's amax, cast, rescale back (the overhead µS deletes).
    DynamicFp8(Format),
}

/// The TE-style dynamic-scaling decision for one tensor, given its
/// (NaN-ignoring) amax. The ONE policy shared by the quantizer
/// ([`quantize_slice`]) and the telemetry observer (`observe_cast`), so
/// cast-health reports always describe the cast that actually ran.
#[derive(Debug, Clone, Copy, PartialEq)]
pub(crate) enum DynScale {
    /// All-zero tensor: TE skips the cast entirely (no 0/0 scale).
    Skip,
    /// Infinite amax: no finite scale exists. Raw-cast at scale 1 so the
    /// overflow propagates (E4M3 -> NaN, E5M2 -> inf) instead of silently
    /// passing inf/NaN activations through unquantized — SP+FP8
    /// divergence must be observable, not masked. (A NaN amax cannot
    /// happen: the NaN-ignoring max skips it, and NaN inputs already
    /// propagate through the cast itself.)
    Raw,
    /// Rescale by `max_finite / amax`, clamped like TE: a deeply-
    /// subnormal amax would give an inf scale, and 0.0 * inf = NaN would
    /// poison exact zeros.
    Scale(f32),
}

pub(crate) fn te_dynamic_scale(max_finite: f32, amax: f32) -> DynScale {
    if amax == 0.0 {
        DynScale::Skip
    } else if !amax.is_finite() {
        DynScale::Raw
    } else {
        DynScale::Scale((max_finite / amax).min(f32::MAX))
    }
}

/// Quantize one (possibly batched) tensor in place via the fast cast.
pub(crate) fn quantize_slice(xs: &mut [f32], mode: QuantMode) {
    let threads = parallel::threads_for(xs.len() as u64 * 8);
    match mode {
        QuantMode::Bf16 => {
            let fc = BF16.fast_caster();
            parallel::par_chunks_mut(xs, ELEM_CHUNK, threads, |_, c| fc.quantize_slice(c));
        }
        QuantMode::StaticFp8(f) => {
            let fc = f.fast_caster();
            parallel::par_chunks_mut(xs, ELEM_CHUNK, threads, |_, c| fc.quantize_slice(c));
        }
        QuantMode::DynamicFp8(f) => {
            let fc = f.fast_caster();
            // TE-style per-tensor amax (f32::max ignores NaN, like TE's
            // amax reduce; chunked fold keeps it thread-count invariant)
            let amax = parallel::par_map_reduce(
                xs.len(),
                ELEM_CHUNK,
                threads,
                |_, r| xs[r].iter().fold(0f32, |m, x| m.max(x.abs())),
                f32::max,
                0f32,
            );
            match te_dynamic_scale(fc.max_finite(), amax) {
                DynScale::Skip => {}
                DynScale::Raw => {
                    parallel::par_chunks_mut(xs, ELEM_CHUNK, threads, |_, c| fc.cast_slice(c));
                }
                DynScale::Scale(scale) => {
                    let inv = 1.0 / scale; // TE dequant: multiply by the inverse scale
                    parallel::par_chunks_mut(xs, ELEM_CHUNK, threads, |_, c| {
                        for x in c.iter_mut() {
                            *x = fc.quantize(*x * scale) * inv;
                        }
                    });
                }
            }
        }
    }
}

/// Per-op quantization plan: each of the four hidden linears carries its
/// own forward mode (weights and input activations), plus one mode for
/// the activation gradients feeding their backward GEMMs. µS and SP+FP8
/// use a uniform recipe across the four ops; the per-op split exists so
/// mixed recipes (u-µP's BF16 attn-out/ffn-down) are expressible.
pub(crate) struct Plan {
    pub qkv: QuantMode,
    pub attn_out: QuantMode,
    pub ffn_up: QuantMode,
    pub ffn_down: QuantMode,
    pub grad: QuantMode,
}

pub(crate) fn plan_for(cfg: &ModelConfig) -> Plan {
    let (hidden, grad) = match (cfg.variant.as_str(), cfg.precision.as_str()) {
        ("mus", "fp8") => (QuantMode::StaticFp8(E4M3), QuantMode::StaticFp8(E5M2)),
        ("sp", "fp8") => (QuantMode::DynamicFp8(E4M3), QuantMode::DynamicFp8(E5M2)),
        _ => (QuantMode::Bf16, QuantMode::Bf16),
    };
    Plan { qkv: hidden, attn_out: hidden, ffn_up: hidden, ffn_down: hidden, grad }
}

// ---------------------------------------------------------------------------
// Op-graph enumeration
//
// The symbolic counterpart of `forward_tower`/`train_grads`: one node per
// telemetry observation site, in execution order. `analysis::
// static_numerics` walks this enumeration to propagate predicted RMS
// through the pipeline, and its coverage tests compare the node set
// against a live traced step — an op added to the runtime without a
// matching node here (or vice versa) fails `cargo test`.

/// Semantic kind of one [`OpNode`] site.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum OpKind {
    /// Embedding-row gather (`embed`).
    Embed,
    /// RMS-norm (+ gain) output (`post_norm1`/`post_norm2`/`final_norm`).
    Norm,
    /// A hidden linear's output, tagged with its [`Role`].
    Linear(Role),
    /// Rotary-embedded qkv heads (`post_rope`).
    Rope,
    /// Merged causal-attention mix (`attn_mix`).
    Attention,
    /// FFN activation output (`ffn_act`).
    Activation,
    /// Residual combine `x' = a·x + b·branch` (0 = attn, 1 = ffn branch).
    Residual(usize),
    /// Pre-softmax logits (`logits`).
    Head,
    /// Loss gradient w.r.t. the logits (`d_logits`).
    GradLogits,
    /// Gradient entering the tower back through the head (`d_final`).
    GradHead,
    /// Activation gradient feeding a hidden linear's backward GEMMs —
    /// the tensor `plan.grad` quantizes — tagged with the linear's role.
    GradLinear(Role),
    /// Residual-stream gradient after a block's combine (`d_resid`).
    GradResidual,
}

/// One symbolic op site of the forward/backward pipeline: the
/// `(op, layer)` key `observe_rms` records it under, its kind, and —
/// when an operand is quantized at this site — the paired
/// `observe_cast` name(s).
#[derive(Debug, Clone, Copy)]
pub(crate) struct OpNode {
    /// `observe_rms` op name.
    pub name: &'static str,
    /// Block index (0 for the global embed/final_norm/logits/grad sites).
    pub layer: usize,
    /// What the op does, for the verifier's propagation rule.
    pub kind: OpKind,
    /// `observe_cast` name of the quantized input activation/gradient.
    pub cast: Option<&'static str>,
    /// `observe_cast` name of the quantized weight (forward linears).
    pub weight_cast: Option<&'static str>,
}

impl OpNode {
    const fn plain(name: &'static str, layer: usize, kind: OpKind) -> OpNode {
        OpNode { name, layer, kind, cast: None, weight_cast: None }
    }
    const fn linear(
        name: &'static str,
        layer: usize,
        role: Role,
        weight_cast: &'static str,
    ) -> OpNode {
        OpNode { name, layer, kind: OpKind::Linear(role), cast: Some(name), weight_cast: Some(weight_cast) }
    }
    const fn grad_linear(name: &'static str, layer: usize, role: Role) -> OpNode {
        OpNode { name, layer, kind: OpKind::GradLinear(role), cast: Some(name), weight_cast: None }
    }
}

impl Plan {
    /// The forward quantization mode of one hidden linear's slot (the
    /// named accessor keeps op-graph consumers off the raw fields — the
    /// lint contract pairs field reads with `observe_cast` call sites).
    pub(crate) fn slot(&self, role: Role) -> Option<QuantMode> {
        match role {
            Role::Qkv => Some(self.qkv),
            Role::AttnOut => Some(self.attn_out),
            Role::FfnUp => Some(self.ffn_up),
            Role::FfnDown => Some(self.ffn_down),
            _ => None,
        }
    }

    /// The backward (activation-gradient) quantization mode.
    pub(crate) fn grad_mode(&self) -> QuantMode {
        self.grad
    }
}

/// The quantization mode governing a node's cast sites under `plan`:
/// forward linears carry their own slot, grad sites share the plan's
/// gradient mode, everything else is unquantized.
pub(crate) fn node_mode(node: &OpNode, plan: &Plan) -> Option<QuantMode> {
    match node.kind {
        OpKind::Linear(role) => plan.slot(role),
        OpKind::GradLinear(_) => Some(plan.grad_mode()),
        _ => None,
    }
}

/// Enumerate every op site of one training step, in execution order.
/// Res-Post (µS) records each branch norm *after* its linear and each
/// residual stream un-normed into the next branch; Pre (SP) records the
/// norm first — the node order mirrors `forward_tower` exactly.
pub(crate) fn op_graph(cfg: &ModelConfig) -> Vec<OpNode> {
    use OpKind::*;
    let res_post = placement_for(cfg) == NormPlacement::ResPost;
    let mut g = vec![OpNode::plain("embed", 0, Embed)];
    for l in 0..cfg.depth {
        if !res_post {
            g.push(OpNode::plain("post_norm1", l, Norm));
        }
        g.push(OpNode::linear("qkv", l, Role::Qkv, "w_qkv"));
        g.push(OpNode::plain("post_rope", l, Rope));
        g.push(OpNode::plain("attn_mix", l, Attention));
        g.push(OpNode::linear("attn_out", l, Role::AttnOut, "w_attn_out"));
        if res_post {
            g.push(OpNode::plain("post_norm1", l, Norm));
        }
        g.push(OpNode::plain("resid1", l, Residual(0)));
        if !res_post {
            g.push(OpNode::plain("post_norm2", l, Norm));
        }
        g.push(OpNode::linear("ffn_up", l, Role::FfnUp, "w_ffn_up"));
        g.push(OpNode::plain("ffn_act", l, Activation));
        g.push(OpNode::linear("ffn_down", l, Role::FfnDown, "w_ffn_down"));
        if res_post {
            g.push(OpNode::plain("post_norm2", l, Norm));
        }
        g.push(OpNode::plain("resid2", l, Residual(1)));
    }
    g.push(OpNode::plain("final_norm", 0, Norm));
    g.push(OpNode::plain("logits", 0, Head));
    g.push(OpNode::plain("d_logits", 0, GradLogits));
    g.push(OpNode::plain("d_final", 0, GradHead));
    for l in (0..cfg.depth).rev() {
        g.push(OpNode::grad_linear("d_ffn_down", l, Role::FfnDown));
        g.push(OpNode::grad_linear("d_ffn_up", l, Role::FfnUp));
        g.push(OpNode::grad_linear("d_attn_out", l, Role::AttnOut));
        g.push(OpNode::grad_linear("d_qkv", l, Role::Qkv));
        g.push(OpNode::plain("d_resid", l, GradResidual));
    }
    g
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum Act {
    Gelu,
    Silu,
    Relu,
}

impl Act {
    pub(crate) fn parse(name: &str) -> Result<Act> {
        match name {
            "gelu" => Ok(Act::Gelu),
            "silu" => Ok(Act::Silu),
            "relu" => Ok(Act::Relu),
            other => Err(err!("unknown activation '{other}'")),
        }
    }

    #[inline]
    pub(crate) fn apply(self, z: f32) -> f32 {
        match self {
            Act::Gelu => {
                const K: f32 = 0.797_884_56; // sqrt(2/pi)
                let u = K * (z + 0.044715 * z * z * z);
                0.5 * z * (1.0 + u.tanh())
            }
            Act::Silu => z / (1.0 + (-z).exp()),
            Act::Relu => z.max(0.0),
        }
    }

    #[inline]
    pub(crate) fn deriv(self, z: f32) -> f32 {
        match self {
            Act::Gelu => {
                const K: f32 = 0.797_884_56;
                let u = K * (z + 0.044715 * z * z * z);
                let t = u.tanh();
                0.5 * (1.0 + t) + 0.5 * z * (1.0 - t * t) * K * (1.0 + 3.0 * 0.044715 * z * z)
            }
            Act::Silu => {
                let s = 1.0 / (1.0 + (-z).exp());
                s * (1.0 + z * (1.0 - s))
            }
            Act::Relu => {
                if z > 0.0 {
                    1.0
                } else {
                    0.0
                }
            }
        }
    }
}

/// Where each block's two RMS-norms sit (matches the L2 python model's
/// `ln_placement`): µS puts the norm *last* on each residual branch
/// (Res-Post, paper Fig 4a); SP norms the branch *input* (Pre).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum NormPlacement {
    Pre,
    ResPost,
}

pub(crate) fn placement_for(cfg: &ModelConfig) -> NormPlacement {
    if cfg.variant == "mus" {
        NormPlacement::ResPost
    } else {
        NormPlacement::Pre
    }
}

/// Residual combination weights (a, b) for branch `branch` (0 = attention,
/// 1 = ffn) of block `layer`: `x' = a·x + b·branch_out`.
/// fixed (Eq. 10): a = √(1−τ), b = √τ. running-mean (Eq. 11), counting
/// branches 1-based across the depth (the embedding is contribution 0):
/// a = √(i/(i+1)), b = √(1/(i+1)) with i = 2·layer + branch + 1.
/// standard (SP): a = b = 1. Unknown schemes are an error — a config that
/// bypassed `validate()` must not silently train the wrong scheme.
pub(crate) fn residual_coeffs(
    cfg: &ModelConfig,
    tau: f32,
    layer: usize,
    branch: usize,
) -> Result<(f32, f32)> {
    match cfg.residual.as_str() {
        "standard" => Ok((1.0, 1.0)),
        "running_mean" => {
            let i = (2 * layer + branch + 1) as f32;
            Ok(((i / (i + 1.0)).sqrt(), (1.0 / (i + 1.0)).sqrt()))
        }
        "fixed" => {
            let t = tau.clamp(0.0, 1.0);
            Ok(((1.0 - t).sqrt(), t.sqrt()))
        }
        other => Err(err!(
            "unknown residual scheme '{other}' (expected fixed | running_mean | standard)"
        )),
    }
}

// ---------------------------------------------------------------------------
// Per-call invariants

/// Everything a step needs that is a pure function of (config, tau) —
/// built once per `execute` call and threaded through forward + backward
/// instead of being re-derived per helper (parsed activation, per-op
/// plan, per-branch residual coefficients, RoPE tables, and the output
/// multipliers / norm placement resolved from [`crate::scaling::Scheme`]).
pub(crate) struct Prepared {
    pub act: Act,
    pub plan: Plan,
    pub placement: NormPlacement,
    /// Per block: [(a,b) attention branch, (a,b) ffn branch].
    pub coeffs: Vec<[(f32, f32); 2]>,
    pub alpha_qkv: f32,
    pub alpha_attn_out: f32,
    pub alpha_ffn_up: f32,
    pub alpha_ffn_down: f32,
    pub alpha_head: f32,
    /// RoPE tables, `[seq_len, head_dim/2]` row-major.
    pub rope_cos: Vec<f32>,
    pub rope_sin: Vec<f32>,
}

impl Prepared {
    pub(crate) fn new(cfg: &ModelConfig, tau: f32) -> Result<Prepared> {
        // The interpreter boundary: a config that skipped validation must
        // not silently train under a defaulted scheme/placement (the same
        // hardening `residual_coeffs` applies to unknown residual names).
        cfg.validate().map_err(Error::msg)?;
        let act = Act::parse(&cfg.activation)?;
        let plan = plan_for(cfg);
        let scheme = cfg.scheme();
        let (d, f) = (cfg.width, cfg.ffn_width());
        let coeffs = (0..cfg.depth)
            .map(|l| -> Result<[(f32, f32); 2]> {
                Ok([residual_coeffs(cfg, tau, l, 0)?, residual_coeffs(cfg, tau, l, 1)?])
            })
            .collect::<Result<Vec<_>>>()?;
        let (dh, s) = (cfg.head_dim, cfg.seq_len);
        let half = dh / 2;
        // freq depends only on the rotary pair index j — hoisted out of
        // the per-position loop
        let freqs: Vec<f32> =
            (0..half).map(|j| ROPE_THETA.powf(-(j as f32) / half as f32)).collect();
        let mut rope_cos = vec![0f32; s * half];
        let mut rope_sin = vec![0f32; s * half];
        for t in 0..s {
            for j in 0..half {
                let ang = t as f32 * freqs[j];
                rope_cos[t * half + j] = ang.cos();
                rope_sin[t * half + j] = ang.sin();
            }
        }
        Ok(Prepared {
            act,
            plan,
            placement: placement_for(cfg),
            coeffs,
            alpha_qkv: scheme.output_mult(ParamKind::Hidden, d) as f32,
            alpha_attn_out: scheme.output_mult(ParamKind::Hidden, d) as f32,
            alpha_ffn_up: scheme.output_mult(ParamKind::Hidden, d) as f32,
            alpha_ffn_down: scheme.output_mult(ParamKind::Hidden, f) as f32,
            alpha_head: scheme.output_mult(ParamKind::Output, d) as f32,
            rope_cos,
            rope_sin,
        })
    }
}

// ---------------------------------------------------------------------------
// Quantized weights

/// Quantized copies of the weight matrices for one step's compute, plus
/// pre-transposed `[fan_out, fan_in]` versions so every forward product
/// runs through the contiguous `A @ Bᵀ` kernel. The un-transposed
/// `[fan_in, fan_out]` quantized copies back the dgrad products
/// (`dz @ Wᵀ`). Norm gains stay unquantized f32 (they are BF16-domain
/// "everything else" in the paper's recipe and tiny).
pub(crate) struct QuantParams {
    pub qkv: Vec<Vec<f32>>,
    pub qkv_t: Vec<Vec<f32>>,
    pub attn_out: Vec<Vec<f32>>,
    pub attn_out_t: Vec<Vec<f32>>,
    pub ffn_up: Vec<Vec<f32>>,
    pub ffn_up_t: Vec<Vec<f32>>,
    pub ffn_down: Vec<Vec<f32>>,
    pub ffn_down_t: Vec<Vec<f32>>,
    /// LM head `[d, v]`, BF16 in every variant (paper Table 1).
    pub head: Vec<f32>,
    /// Transpose of `head`, `[v, d]` (forward logits product).
    pub head_t: Vec<f32>,
}

/// Quantize + transpose one weight matrix in a single fused pass
/// (`gemm::quant_transpose` casts each element once, writing the `[rows,
/// cols]` quantized copy and its transpose from the same register).
/// Elementwise per mode, so the result is bit-identical to the old
/// quantize-then-transpose two-pass.
fn quant_t(w: &[f32], rows: usize, cols: usize, mode: QuantMode) -> (Vec<f32>, Vec<f32>) {
    let mut q = vec![0f32; w.len()];
    let mut t = vec![0f32; w.len()];
    match mode {
        QuantMode::Bf16 => {
            let fc = BF16.fast_caster();
            quant_transpose(w, rows, cols, &mut q, &mut t, |x| fc.quantize(x));
        }
        QuantMode::StaticFp8(f) => {
            let fc = f.fast_caster();
            quant_transpose(w, rows, cols, &mut q, &mut t, |x| fc.quantize(x));
        }
        QuantMode::DynamicFp8(f) => {
            let fc = f.fast_caster();
            // same amax reduction + scale policy as `quantize_slice`
            let amax = super::gemm::abs_max(w);
            match te_dynamic_scale(fc.max_finite(), amax) {
                DynScale::Skip => quant_transpose(w, rows, cols, &mut q, &mut t, |x| x),
                DynScale::Raw => quant_transpose(w, rows, cols, &mut q, &mut t, |x| fc.cast(x)),
                DynScale::Scale(scale) => {
                    let inv = 1.0 / scale;
                    quant_transpose(w, rows, cols, &mut q, &mut t, move |x| {
                        fc.quantize(x * scale) * inv
                    });
                }
            }
        }
    }
    (q, t)
}

/// Quantize all weight matrices. With `with_backward = false` (the `fwd`
/// artifact / eval path) only the forward transposes are retained — the
/// un-transposed copies exist solely for the backward dgrad products, so
/// their vectors stay empty.
pub(crate) fn quantize_params(
    cfg: &ModelConfig,
    params: &[Vec<f32>],
    plan: &Plan,
    with_backward: bool,
) -> QuantParams {
    let (d, f, v) = (cfg.width, cfg.ffn_width(), cfg.vocab);
    let mut qp = QuantParams {
        qkv: Vec::with_capacity(cfg.depth),
        qkv_t: Vec::with_capacity(cfg.depth),
        attn_out: Vec::with_capacity(cfg.depth),
        attn_out_t: Vec::with_capacity(cfg.depth),
        ffn_up: Vec::with_capacity(cfg.depth),
        ffn_up_t: Vec::with_capacity(cfg.depth),
        ffn_down: Vec::with_capacity(cfg.depth),
        ffn_down_t: Vec::with_capacity(cfg.depth),
        head: Vec::new(),
        head_t: Vec::new(),
    };
    for l in 0..cfg.depth {
        // weight-cast health (no-ops unless a telemetry sink is active)
        observe_cast("w_qkv", l, &params[idx_qkv(l)], plan.qkv);
        observe_cast("w_attn_out", l, &params[idx_o(l)], plan.attn_out);
        observe_cast("w_ffn_up", l, &params[idx_up(l)], plan.ffn_up);
        observe_cast("w_ffn_down", l, &params[idx_down(l)], plan.ffn_down);
        let (q, t) = quant_t(&params[idx_qkv(l)], d, 3 * d, plan.qkv);
        qp.qkv_t.push(t);
        let (q2, t) = quant_t(&params[idx_o(l)], d, d, plan.attn_out);
        qp.attn_out_t.push(t);
        let (q3, t) = quant_t(&params[idx_up(l)], d, f, plan.ffn_up);
        qp.ffn_up_t.push(t);
        let (q4, t) = quant_t(&params[idx_down(l)], f, d, plan.ffn_down);
        qp.ffn_down_t.push(t);
        if with_backward {
            qp.qkv.push(q);
            qp.attn_out.push(q2);
            qp.ffn_up.push(q3);
            qp.ffn_down.push(q4);
        }
    }
    let (q, t) = quant_t(&params[idx_head(cfg)], d, v, QuantMode::Bf16);
    if with_backward {
        qp.head = q;
    }
    qp.head_t = t;
    qp
}

// ---------------------------------------------------------------------------
// Workspace

/// Batched activations for one interpreter call. Row `r` of each
/// `[rows, d]` buffer is the residual-stream state of (sequence b = r/s,
/// position t = r%s); `rows` is always `batch · s` (attention couples
/// positions within a sequence, so full sequences flow through). The
/// geometry is explicit — training uses the config's `batch × seq_len`,
/// prefill runs one sequence of prompt length `s ≤ seq_len` through the
/// *same* tower. Everything the backward pass replays is saved here;
/// scratch buffers are allocated once per call and reused across the
/// layer loop.
pub(crate) struct Workspace {
    pub batch: usize,
    pub s: usize,
    pub rows: usize,
    /// Per-layer save indexing stride: 1 for training (block l's saves
    /// live at index l for the backward pass), 0 for forward-only calls
    /// (every block reuses slot 0 — no save is read after its block
    /// finishes, so the fwd/eval path avoids depth× backward-only memory).
    stride: usize,
    /// `x[l]`: stream entering block l; `x[depth]` is the final state.
    pub x: Vec<Vec<f32>>,
    /// Stream between the attention and ffn branches of block l.
    pub xmid: Vec<Vec<f32>>,
    /// Quantized input operand of the qkv linear (saved for wgrad).
    pub xq_attn: Vec<Vec<f32>>,
    /// RMS-norm 1: normalized rows (pre-gain) and per-row divisor.
    /// Pre placement: norm of `x[l]`; Res-Post: norm of the attn-out.
    pub n1: Vec<Vec<f32>>,
    pub r1: Vec<Vec<f32>>,
    /// Post-RoPE q,k and v per (batch, head): `[b·h, 3, s, dh]` chunks.
    pub qkv_heads: Vec<Vec<f32>>,
    /// Softmax weights per (batch, head): `[b·h, s, s]`.
    pub probs: Vec<Vec<f32>>,
    /// Quantized input operand of the attn-out linear.
    pub xq_o: Vec<Vec<f32>>,
    /// Quantized input operand of the ffn-up linear.
    pub xq_up: Vec<Vec<f32>>,
    /// Pre-activation ffn hidden state `[rows, f]` (for act').
    pub z_up: Vec<Vec<f32>>,
    /// Quantized activated state — input operand of ffn-down.
    pub xq_down: Vec<Vec<f32>>,
    /// RMS-norm 2 saves (placement-dependent, like n1/r1).
    pub n2: Vec<Vec<f32>>,
    pub r2: Vec<Vec<f32>>,
    /// Final RMS-norm saves and the (gained, BF16) LM-head input.
    pub nf: Vec<f32>,
    pub rf: Vec<f32>,
    pub y: Vec<f32>,
    // -- scratch (reused per layer) --
    z_qkv: Vec<f32>,
    o_heads: Vec<f32>,
    t_d0: Vec<f32>,
    t_d1: Vec<f32>,
}

impl Workspace {
    /// Training workspace: per-layer saves retained for the backward pass.
    pub(crate) fn new(cfg: &ModelConfig, batch: usize, s: usize) -> Workspace {
        Workspace::with_saves(cfg, batch, s, true)
    }

    /// Forward-only workspace (the `fwd` artifact / eval / prefill path):
    /// one shared save slot reused by every block.
    pub(crate) fn new_forward_only(cfg: &ModelConfig, batch: usize, s: usize) -> Workspace {
        Workspace::with_saves(cfg, batch, s, false)
    }

    fn with_saves(cfg: &ModelConfig, batch: usize, s: usize, keep: bool) -> Workspace {
        let rows = batch * s;
        let (d, f) = (cfg.width, cfg.ffn_width());
        let heads_total = batch * cfg.n_heads();
        let n_save = if keep { cfg.depth } else { 1 };
        let vd = |len: usize| (0..n_save).map(|_| vec![0f32; len]).collect::<Vec<_>>();
        Workspace {
            batch,
            s,
            rows,
            stride: if keep { 1 } else { 0 },
            x: (0..=if keep { cfg.depth } else { 0 }).map(|_| vec![0f32; rows * d]).collect(),
            xmid: vd(rows * d),
            xq_attn: vd(rows * d),
            n1: vd(rows * d),
            r1: vd(rows),
            qkv_heads: vd(3 * rows * d),
            probs: vd(heads_total * s * s),
            xq_o: vd(rows * d),
            xq_up: vd(rows * d),
            z_up: vd(rows * f),
            xq_down: vd(rows * f),
            n2: vd(rows * d),
            r2: vd(rows),
            nf: vec![0f32; rows * d],
            rf: vec![0f32; rows],
            y: vec![0f32; rows * d],
            z_qkv: vec![0f32; rows * 3 * d],
            o_heads: vec![0f32; rows * d],
            t_d0: vec![0f32; rows * d],
            t_d1: vec![0f32; rows * d],
        }
    }
}

// ---------------------------------------------------------------------------
// Shared per-op functions
//
// Each op below is THE implementation of its pipeline stage: the
// full-sequence train/eval forward (`forward_tower`), prefill
// (`logits_rows`), and the incremental decode path (`runtime::infer`)
// all call these same functions — there is no parallel decode copy of
// the norm / linear / activation / residual math to keep in sync.

/// The one token-range check every entry point shares (train unpack,
/// prefill, decode, eval scoring): ids must lie in `0..vocab`.
pub(crate) fn check_tokens(tokens: &[i32], vocab: usize) -> Result<()> {
    for &t in tokens {
        if t < 0 || t as usize >= vocab {
            bail!("token id {t} out of vocab range 0..{vocab}");
        }
    }
    Ok(())
}

/// Token-embedding gather into `[rows, d]`, BF16-rounded (the embedding
/// is BF16 with output multiplier 1 in every variant — paper Table 2).
pub(crate) fn op_embed(embed: &[f32], toks: &[i32], d: usize, out: &mut [f32]) {
    let threads = parallel::threads_for(out.len() as u64 * 8);
    parallel::par_chunks_mut(out, ROW_CHUNK * d, threads, |ci, c| {
        let r0 = ci * ROW_CHUNK;
        for (i, row) in c.chunks_mut(d).enumerate() {
            let tok = toks[r0 + i] as usize;
            row.copy_from_slice(&embed[tok * d..(tok + 1) * d]);
        }
    });
    quantize_slice(out, QuantMode::Bf16);
}

/// Gained RMS-norm over rows: `out[r] = (x[r] / rms(x[r])) ⊙ g`. Saves
/// the normalized rows (`n`) and per-row divisors (`r`) for the backward
/// pass (forward-only callers pass scratch).
pub(crate) fn op_rmsnorm(
    x: &[f32],
    g: &[f32],
    d: usize,
    n: &mut [f32],
    r: &mut [f32],
    out: &mut [f32],
) {
    rms_rows(x, d, r);
    normalize_rows(x, r, d, n);
    scale_by_gain(n, g, d, out);
}

/// Quantized linear: quantize the input activations in place per the
/// op's [`QuantMode`] — fused into the GEMM's A-panel pack step
/// (`gemm::matmul_bt_quant`), so the activations get one read+write
/// sweep instead of a full-tensor quantize pass followed by the GEMM —
/// then `out = alpha · xq @ Wᵀ` (`w_t` is the pre-transposed
/// `[dout, din]` quantized weight). On return `xq` holds the quantized
/// operand (saved for the weight-gradient GEMM), exactly as the unfused
/// pipeline left it: every pack closure is elementwise, so fused and
/// unfused results are bit-identical (tested on the exhaustive fp8
/// grid). Dynamic TE-style scaling needs the whole-tensor amax before
/// any element casts, so it keeps a read-only amax pre-pass (the same
/// `gemm::abs_max` reduction `quantize_slice` uses) and fuses only the
/// elementwise scale-cast-rescale sweep.
#[allow(clippy::too_many_arguments)]
pub(crate) fn op_linear(
    xq: &mut [f32],
    mode: QuantMode,
    w_t: &[f32],
    out: &mut [f32],
    rows: usize,
    dout: usize,
    din: usize,
    alpha: f32,
) {
    match mode {
        QuantMode::Bf16 => {
            let fc = BF16.fast_caster();
            matmul_bt_quant(xq, w_t, out, rows, dout, din, alpha, |p| fc.quantize_slice(p));
        }
        QuantMode::StaticFp8(f) => {
            let fc = f.fast_caster();
            matmul_bt_quant(xq, w_t, out, rows, dout, din, alpha, |p| fc.quantize_slice(p));
        }
        QuantMode::DynamicFp8(f) => {
            let fc = f.fast_caster();
            let amax = super::gemm::abs_max(xq);
            match te_dynamic_scale(fc.max_finite(), amax) {
                // all-zero tensor: TE skips the cast, plain GEMM
                DynScale::Skip => matmul_bt(xq, w_t, out, rows, dout, din, alpha),
                DynScale::Raw => {
                    matmul_bt_quant(xq, w_t, out, rows, dout, din, alpha, |p| fc.cast_slice(p));
                }
                DynScale::Scale(scale) => {
                    let inv = 1.0 / scale;
                    matmul_bt_quant(xq, w_t, out, rows, dout, din, alpha, move |p| {
                        for x in p.iter_mut() {
                            *x = fc.quantize(*x * scale) * inv;
                        }
                    });
                }
            }
        }
    }
}

/// RoPE rotation of one head vector's rotary pairs at one table row:
/// `dst[j] = src[j]·cos[j] − src[half+j]·sin[j]`,
/// `dst[half+j] = src[j]·sin[j] + src[half+j]·cos[j]`.
/// The single rotation implementation behind both head marshallers
/// (training/prefill `split_heads_rope`, decode `split_heads_rope_rows`).
#[inline]
pub(crate) fn rope_rotate(src: &[f32], cos: &[f32], sin: &[f32], half: usize, dst: &mut [f32]) {
    for j in 0..half {
        let (cj, sj) = (cos[j], sin[j]);
        dst[j] = src[j] * cj - src[half + j] * sj;
        dst[half + j] = src[j] * sj + src[half + j] * cj;
    }
}

// ---------------------------------------------------------------------------
// Elementwise / norm helpers (all fixed-chunk parallel)

/// Per-row RMS divisor: `rms[r] = sqrt(mean(x[r]²) + RMS_EPS)`.
fn rms_rows(x: &[f32], d: usize, rms: &mut [f32]) {
    let rows = rms.len();
    let threads = parallel::threads_for((rows * d) as u64 * 2);
    parallel::par_chunks_mut(rms, ROW_CHUNK, threads, |ci, c| {
        let r0 = ci * ROW_CHUNK;
        for (i, o) in c.iter_mut().enumerate() {
            let row = &x[(r0 + i) * d..(r0 + i + 1) * d];
            let ms = row.iter().map(|&w| (w as f64) * (w as f64)).sum::<f64>() / d as f64;
            *o = (ms + RMS_EPS).sqrt() as f32;
        }
    });
}

/// `n[r] = x[r] / rms[r]` per row.
fn normalize_rows(x: &[f32], rms: &[f32], d: usize, n: &mut [f32]) {
    let threads = parallel::threads_for(n.len() as u64 * 2);
    parallel::par_chunks_mut(n, ROW_CHUNK * d, threads, |ci, c| {
        let r0 = ci * ROW_CHUNK;
        for (i, out) in c.chunks_mut(d).enumerate() {
            let r = rms[r0 + i];
            let row = &x[(r0 + i) * d..(r0 + i + 1) * d];
            for (o, &w) in out.iter_mut().zip(row) {
                *o = w / r;
            }
        }
    });
}

/// `out[r,c] = n[r,c] * g[c]` (gain broadcast over rows).
fn scale_by_gain(n: &[f32], g: &[f32], d: usize, out: &mut [f32]) {
    let threads = parallel::threads_for(out.len() as u64 * 2);
    parallel::par_chunks_mut(out, ROW_CHUNK * d, threads, |ci, c| {
        let r0 = ci * ROW_CHUNK;
        for (i, row) in c.chunks_mut(d).enumerate() {
            let src = &n[(r0 + i) * d..(r0 + i + 1) * d];
            for cix in 0..d {
                row[cix] = src[cix] * g[cix];
            }
        }
    });
}

/// Scaled residual combine, `out = a*x + b*br` elementwise — the
/// residual op of both the training forward and the decode path.
pub(crate) fn residual_combine(x: &[f32], br: &[f32], a: f32, b: f32, out: &mut [f32]) {
    let threads = parallel::threads_for(out.len() as u64 * 4);
    parallel::par_chunks_mut(out, ELEM_CHUNK, threads, |ci, c| {
        let off = ci * ELEM_CHUNK;
        for (i, o) in c.iter_mut().enumerate() {
            *o = a * x[off + i] + b * br[off + i];
        }
    });
}

/// `out = c*x` elementwise.
fn scale_into(x: &[f32], cmul: f32, out: &mut [f32]) {
    let threads = parallel::threads_for(out.len() as u64 * 2);
    parallel::par_chunks_mut(out, ELEM_CHUNK, threads, |ci, c| {
        let off = ci * ELEM_CHUNK;
        for (i, o) in c.iter_mut().enumerate() {
            *o = cmul * x[off + i];
        }
    });
}

/// `out += c*x` elementwise.
fn axpy_scaled(x: &[f32], cmul: f32, out: &mut [f32]) {
    let threads = parallel::threads_for(out.len() as u64 * 2);
    parallel::par_chunks_mut(out, ELEM_CHUNK, threads, |ci, c| {
        let off = ci * ELEM_CHUNK;
        for (i, o) in c.iter_mut().enumerate() {
            *o += cmul * x[off + i];
        }
    });
}

/// `out = c*x + y` elementwise.
fn add_scaled(x: &[f32], cmul: f32, y: &[f32], out: &mut [f32]) {
    let threads = parallel::threads_for(out.len() as u64 * 4);
    parallel::par_chunks_mut(out, ELEM_CHUNK, threads, |ci, c| {
        let off = ci * ELEM_CHUNK;
        for (i, o) in c.iter_mut().enumerate() {
            *o = cmul * x[off + i] + y[off + i];
        }
    });
}

/// `out = act(z)` elementwise — the FFN activation op of both the
/// training forward and the decode path.
pub(crate) fn apply_act(z: &[f32], act: Act, out: &mut [f32]) {
    let threads = parallel::threads_for(out.len() as u64 * 8);
    parallel::par_chunks_mut(out, ELEM_CHUNK, threads, |ci, c| {
        let off = ci * ELEM_CHUNK;
        for (i, o) in c.iter_mut().enumerate() {
            *o = act.apply(z[off + i]);
        }
    });
}

/// `out = d_a ⊙ act'(z)` elementwise.
fn act_backward(d_a: &[f32], z: &[f32], act: Act, out: &mut [f32]) {
    let threads = parallel::threads_for(out.len() as u64 * 8);
    parallel::par_chunks_mut(out, ELEM_CHUNK, threads, |ci, c| {
        let off = ci * ELEM_CHUNK;
        for (i, o) in c.iter_mut().enumerate() {
            *o = d_a[off + i] * act.deriv(z[off + i]);
        }
    });
}

/// Backward of `y = (x / rms(x)) · g`: given upstream `dy` and the saved
/// normalized rows `n` and divisors `rms`, overwrites `dx` with
/// `(dy⊙g − n · mean(dy⊙g⊙n)) / rms` and *accumulates* the gain gradient
/// `dg[c] += Σ_r dy[r,c]·n[r,c]`. The dg reduction runs sequentially over
/// rows with f64 accumulators (deterministic; negligible next to the
/// GEMMs), the dx rows in fixed parallel chunks.
fn rmsnorm_backward(
    dy: &[f32],
    n: &[f32],
    rms: &[f32],
    g: &[f32],
    d: usize,
    dx: &mut [f32],
    dg: &mut [f32],
) {
    let rows = rms.len();
    let mut acc = vec![0f64; d];
    for r in 0..rows {
        let dyr = &dy[r * d..(r + 1) * d];
        let nr = &n[r * d..(r + 1) * d];
        for c in 0..d {
            acc[c] += (dyr[c] as f64) * (nr[c] as f64);
        }
    }
    for c in 0..d {
        dg[c] += acc[c] as f32;
    }
    let threads = parallel::threads_for((rows * d) as u64 * 6);
    parallel::par_chunks_mut(dx, ROW_CHUNK * d, threads, |ci, chunk| {
        let r0 = ci * ROW_CHUNK;
        for (i, out) in chunk.chunks_mut(d).enumerate() {
            let r = r0 + i;
            let dyr = &dy[r * d..(r + 1) * d];
            let nr = &n[r * d..(r + 1) * d];
            let mut mdot = 0f64;
            for c in 0..d {
                mdot += (dyr[c] as f64) * (g[c] as f64) * (nr[c] as f64);
            }
            let mdot = (mdot / d as f64) as f32;
            let rr = rms[r];
            for c in 0..d {
                out[c] = (dyr[c] * g[c] - nr[c] * mdot) / rr;
            }
        }
    });
}

// ---------------------------------------------------------------------------
// Attention head marshalling

/// Scatter `z_qkv` `[rows, 3d]` into per-(sequence, head) q/k/v blocks
/// with RoPE applied to q and k ([`rope_rotate`]). Chunk (b,h) of
/// `qkv_heads` is laid out `[q(s,dh), k(s,dh), v(s,dh)]`; position t of
/// sequence b rotates by table row t.
fn split_heads_rope(
    z_qkv: &[f32],
    cfg: &ModelConfig,
    s: usize,
    rope_cos: &[f32],
    rope_sin: &[f32],
    qkv_heads: &mut [f32],
) {
    let (d, dh, h) = (cfg.width, cfg.head_dim, cfg.n_heads());
    let half = dh / 2;
    let unit = 3 * s * dh;
    let threads = parallel::threads_for(z_qkv.len() as u64 * 4);
    parallel::par_chunks_mut(qkv_heads, unit, threads, |bh, chunk| {
        let b = bh / h;
        let hh = bh % h;
        let (qc, rest) = chunk.split_at_mut(s * dh);
        let (kc, vc) = rest.split_at_mut(s * dh);
        for t in 0..s {
            let src = &z_qkv[(b * s + t) * 3 * d..(b * s + t + 1) * 3 * d];
            let qs = &src[hh * dh..(hh + 1) * dh];
            let ks = &src[d + hh * dh..d + (hh + 1) * dh];
            let vs = &src[2 * d + hh * dh..2 * d + (hh + 1) * dh];
            let cos = &rope_cos[t * half..(t + 1) * half];
            let sin = &rope_sin[t * half..(t + 1) * half];
            rope_rotate(qs, cos, sin, half, &mut qc[t * dh..(t + 1) * dh]);
            rope_rotate(ks, cos, sin, half, &mut kc[t * dh..(t + 1) * dh]);
            vc[t * dh..(t + 1) * dh].copy_from_slice(vs);
        }
    });
}

/// Decode-side head marshalling: scatter `z_qkv` `[rows, 3d]` (one row
/// per live sequence) into per-(row, head) q/k/v blocks `[rows·h, dh]`,
/// rotating q and k at each row's absolute position `pos[r]` — the same
/// [`rope_rotate`] the training marshaller applies at position `t`.
/// Sequential: decode rows are few and the work is O(rows·d).
pub(crate) fn split_heads_rope_rows(
    z_qkv: &[f32],
    pos: &[usize],
    cfg: &ModelConfig,
    rope_cos: &[f32],
    rope_sin: &[f32],
    q_heads: &mut [f32],
    k_heads: &mut [f32],
    v_heads: &mut [f32],
) {
    let (d, dh, h) = (cfg.width, cfg.head_dim, cfg.n_heads());
    let half = dh / 2;
    for (r, &t) in pos.iter().enumerate() {
        let src = &z_qkv[r * 3 * d..(r + 1) * 3 * d];
        let cos = &rope_cos[t * half..(t + 1) * half];
        let sin = &rope_sin[t * half..(t + 1) * half];
        for hh in 0..h {
            let o = (r * h + hh) * dh;
            rope_rotate(&src[hh * dh..(hh + 1) * dh], cos, sin, half, &mut q_heads[o..o + dh]);
            rope_rotate(
                &src[d + hh * dh..d + (hh + 1) * dh],
                cos,
                sin,
                half,
                &mut k_heads[o..o + dh],
            );
            v_heads[o..o + dh]
                .copy_from_slice(&src[2 * d + hh * dh..2 * d + (hh + 1) * dh]);
        }
    }
}

/// Merge per-(sequence, head) attention outputs `[b·h, s, dh]` →
/// `[rows, d]`. The decode path calls it with `s = 1` (one output row
/// per live sequence).
pub(crate) fn merge_heads(o_heads: &[f32], cfg: &ModelConfig, s: usize, out: &mut [f32]) {
    let (d, dh, h) = (cfg.width, cfg.head_dim, cfg.n_heads());
    let threads = parallel::threads_for(out.len() as u64 * 2);
    parallel::par_chunks_mut(out, ROW_CHUNK * d, threads, |ci, c| {
        let r0 = ci * ROW_CHUNK;
        for (i, row) in c.chunks_mut(d).enumerate() {
            let r = r0 + i;
            let (b, t) = (r / s, r % s);
            for hh in 0..h {
                let src = &o_heads[((b * h + hh) * s + t) * dh..((b * h + hh) * s + t + 1) * dh];
                row[hh * dh..(hh + 1) * dh].copy_from_slice(src);
            }
        }
    });
}

/// Inverse of [`merge_heads`]: scatter `[rows, d]` → `[b·h, s, dh]`.
fn split_heads_plain(d_merge: &[f32], cfg: &ModelConfig, s: usize, do_heads: &mut [f32]) {
    let (d, dh, h) = (cfg.width, cfg.head_dim, cfg.n_heads());
    let threads = parallel::threads_for(do_heads.len() as u64 * 2);
    parallel::par_chunks_mut(do_heads, s * dh, threads, |bh, chunk| {
        let b = bh / h;
        let hh = bh % h;
        for t in 0..s {
            let src = &d_merge[(b * s + t) * d + hh * dh..(b * s + t) * d + (hh + 1) * dh];
            chunk[t * dh..(t + 1) * dh].copy_from_slice(src);
        }
    });
}

/// Gather `dqkv_heads` `[b·h, 3, s, dh]` back into `dz_qkv` `[rows, 3d]`,
/// applying the transpose RoPE rotation to the q/k gradients.
fn merge_heads_rope_bwd(
    dqkv_heads: &[f32],
    cfg: &ModelConfig,
    s: usize,
    rope_cos: &[f32],
    rope_sin: &[f32],
    dz_qkv: &mut [f32],
) {
    let (d, dh, h) = (cfg.width, cfg.head_dim, cfg.n_heads());
    let half = dh / 2;
    let threads = parallel::threads_for(dz_qkv.len() as u64 * 4);
    parallel::par_chunks_mut(dz_qkv, ROW_CHUNK * 3 * d, threads, |ci, c| {
        let r0 = ci * ROW_CHUNK;
        for (i, row) in c.chunks_mut(3 * d).enumerate() {
            let r = r0 + i;
            let (b, t) = (r / s, r % s);
            let cos = &rope_cos[t * half..(t + 1) * half];
            let sin = &rope_sin[t * half..(t + 1) * half];
            for hh in 0..h {
                let base = (b * h + hh) * 3 * s * dh;
                let dq = &dqkv_heads[base + t * dh..base + (t + 1) * dh];
                let dk = &dqkv_heads[base + s * dh + t * dh..base + s * dh + (t + 1) * dh];
                let dv =
                    &dqkv_heads[base + 2 * s * dh + t * dh..base + 2 * s * dh + (t + 1) * dh];
                for j in 0..half {
                    let (cj, sj) = (cos[j], sin[j]);
                    row[hh * dh + j] = dq[j] * cj + dq[half + j] * sj;
                    row[hh * dh + half + j] = -dq[j] * sj + dq[half + j] * cj;
                    row[d + hh * dh + j] = dk[j] * cj + dk[half + j] * sj;
                    row[d + hh * dh + half + j] = -dk[j] * sj + dk[half + j] * cj;
                }
                row[2 * d + hh * dh..2 * d + (hh + 1) * dh].copy_from_slice(dv);
            }
        }
    });
}

/// Run the causal attention kernel over all (sequence, head) pairs,
/// filling `probs` and `o_heads` (fixed chunk-per-head parallelism).
fn attention_all_heads_fwd(
    qkv_heads: &[f32],
    probs: &mut [f32],
    o_heads: &mut [f32],
    cfg: &ModelConfig,
    batch: usize,
    s: usize,
    scale: f32,
) {
    let (dh, h) = (cfg.head_dim, cfg.n_heads());
    let heads_total = batch * h;
    let unit = 3 * s * dh;
    let threads = parallel::threads_for((heads_total * 2 * s * s * dh) as u64);
    parallel::par_join2(probs, o_heads, s * s, s * dh, threads, |i, pc, oc| {
        let base = i * unit;
        let q = &qkv_heads[base..base + s * dh];
        let k = &qkv_heads[base + s * dh..base + 2 * s * dh];
        let v = &qkv_heads[base + 2 * s * dh..base + 3 * s * dh];
        attn_forward_causal(q, k, v, pc, oc, s, dh, scale);
    });
}

/// Backward over all (sequence, head) pairs: fills `dqkv_heads`.
#[allow(clippy::too_many_arguments)]
fn attention_all_heads_bwd(
    do_heads: &[f32],
    probs: &[f32],
    qkv_heads: &[f32],
    dqkv_heads: &mut [f32],
    cfg: &ModelConfig,
    batch: usize,
    s: usize,
    scale: f32,
) {
    let dh = cfg.head_dim;
    let heads_total = batch * cfg.n_heads();
    let unit = 3 * s * dh;
    let threads = parallel::threads_for((heads_total * 4 * s * s * dh) as u64);
    parallel::par_chunks_mut(dqkv_heads, unit, threads, |i, chunk| {
        let (dq, rest) = chunk.split_at_mut(s * dh);
        let (dk, dv) = rest.split_at_mut(s * dh);
        let base = i * unit;
        let q = &qkv_heads[base..base + s * dh];
        let k = &qkv_heads[base + s * dh..base + 2 * s * dh];
        let v = &qkv_heads[base + 2 * s * dh..base + 3 * s * dh];
        let doi = &do_heads[i * s * dh..(i + 1) * s * dh];
        let pr = &probs[i * s * s..(i + 1) * s * s];
        attn_backward_causal(doi, pr, q, k, v, dq, dk, dv, s, dh, scale);
    });
}

// ---------------------------------------------------------------------------
// Forward

/// Per-layer KV sink for prefill: called once per block with the
/// BF16-rounded post-RoPE `qkv_heads` buffer (`[b·h, 3, s, dh]` chunks)
/// so the inference layer can populate its KV cache from the SAME values
/// the forward attended over.
pub(crate) type KvSink<'a> = &'a mut dyn FnMut(usize, &[f32]);

/// Forward the whole batch through the block pipeline and the final
/// RMS-norm, filling the workspace. `toks[r]` is the input token of row
/// `r` (full sequences: `rows = ws.batch · ws.s`). Training, eval, and
/// prefill all run through this one tower; `kv_sink` (prefill only)
/// observes each layer's attention operands.
pub(crate) fn forward_tower(
    cfg: &ModelConfig,
    prep: &Prepared,
    qp: &QuantParams,
    params: &[Vec<f32>],
    toks: &[i32],
    ws: &mut Workspace,
    mut kv_sink: Option<KvSink<'_>>,
) {
    let (d, f) = (cfg.width, cfg.ffn_width());
    let (rows, batch, s) = (ws.rows, ws.batch, ws.s);
    let attn_scale = 1.0 / (cfg.head_dim as f32).sqrt();
    // save-slot stride: 1 when the backward pass will replay the saves,
    // 0 on forward-only calls (all blocks share slot 0)
    let st = ws.stride;

    // token-embedding gather (output multiplier 1, BF16 — Table 2)
    op_embed(&params[0], toks, d, &mut ws.x[0]);
    observe_rms("embed", 0, &ws.x[0]);

    for l in 0..cfg.depth {
        let [(a1, b1), (a2, b2)] = prep.coeffs[l];
        let (li, ln) = (l * st, (l + 1) * st);

        // ---- attention branch ------------------------------------------
        match prep.placement {
            NormPlacement::Pre => {
                op_rmsnorm(
                    &ws.x[li],
                    &params[idx_g1(l)],
                    d,
                    &mut ws.n1[li],
                    &mut ws.r1[li],
                    &mut ws.xq_attn[li],
                );
                observe_rms("post_norm1", l, &ws.xq_attn[li]);
            }
            NormPlacement::ResPost => {
                let (xq_attn, x) = (&mut ws.xq_attn[li], &ws.x[li]);
                xq_attn.copy_from_slice(x);
            }
        }

        // qkv projection: z_qkv = α_qkv · quant(xq) @ W_qkv
        observe_cast("qkv", l, &ws.xq_attn[li], prep.plan.qkv);
        op_linear(
            &mut ws.xq_attn[li],
            prep.plan.qkv,
            &qp.qkv_t[l],
            &mut ws.z_qkv,
            rows,
            3 * d,
            d,
            prep.alpha_qkv,
        );
        // attention operands are BF16-rounded in every variant (the
        // score/softmax/value arithmetic itself runs in f32): once at the
        // projection output, and again after RoPE so the rotated q/k are
        // exactly what a BF16 KV cache stores — training and decode
        // attend over identical values
        quantize_slice(&mut ws.z_qkv, QuantMode::Bf16);
        observe_rms("qkv", l, &ws.z_qkv);
        split_heads_rope(
            &ws.z_qkv,
            cfg,
            s,
            &prep.rope_cos,
            &prep.rope_sin,
            &mut ws.qkv_heads[li],
        );
        quantize_slice(&mut ws.qkv_heads[li], QuantMode::Bf16);
        observe_rms("post_rope", l, &ws.qkv_heads[li]);
        if let Some(sink) = kv_sink.as_mut() {
            sink(l, &ws.qkv_heads[li]);
        }
        attention_all_heads_fwd(
            &ws.qkv_heads[li],
            &mut ws.probs[li],
            &mut ws.o_heads,
            cfg,
            batch,
            s,
            attn_scale,
        );
        merge_heads(&ws.o_heads, cfg, s, &mut ws.xq_o[li]);
        observe_rms("attn_mix", l, &ws.xq_o[li]);

        // attn-out projection: z_o = α_o · quant(xq_o) @ W_o
        observe_cast("attn_out", l, &ws.xq_o[li], prep.plan.attn_out);
        op_linear(
            &mut ws.xq_o[li],
            prep.plan.attn_out,
            &qp.attn_out_t[l],
            &mut ws.t_d1,
            rows,
            d,
            d,
            prep.alpha_attn_out,
        );
        observe_rms("attn_out", l, &ws.t_d1);

        // scaled residual add #1 → xmid
        match prep.placement {
            NormPlacement::Pre => {
                residual_combine(&ws.x[li], &ws.t_d1, a1, b1, &mut ws.xmid[li]);
            }
            NormPlacement::ResPost => {
                op_rmsnorm(
                    &ws.t_d1,
                    &params[idx_g1(l)],
                    d,
                    &mut ws.n1[li],
                    &mut ws.r1[li],
                    &mut ws.t_d0,
                );
                observe_rms("post_norm1", l, &ws.t_d0);
                residual_combine(&ws.x[li], &ws.t_d0, a1, b1, &mut ws.xmid[li]);
            }
        }
        observe_rms("resid1", l, &ws.xmid[li]);

        // ---- ffn branch ------------------------------------------------
        match prep.placement {
            NormPlacement::Pre => {
                op_rmsnorm(
                    &ws.xmid[li],
                    &params[idx_g2(l)],
                    d,
                    &mut ws.n2[li],
                    &mut ws.r2[li],
                    &mut ws.xq_up[li],
                );
                observe_rms("post_norm2", l, &ws.xq_up[li]);
            }
            NormPlacement::ResPost => {
                let (xq_up, xmid) = (&mut ws.xq_up[li], &ws.xmid[li]);
                xq_up.copy_from_slice(xmid);
            }
        }

        // ffn-up: z_up = α_up · quant(xq_up) @ W_up
        observe_cast("ffn_up", l, &ws.xq_up[li], prep.plan.ffn_up);
        op_linear(
            &mut ws.xq_up[li],
            prep.plan.ffn_up,
            &qp.ffn_up_t[l],
            &mut ws.z_up[li],
            rows,
            f,
            d,
            prep.alpha_ffn_up,
        );
        observe_rms("ffn_up", l, &ws.z_up[li]);

        // activation → ffn-down: z_down = α_down · quant(act(z_up)) @ W_down
        apply_act(&ws.z_up[li], prep.act, &mut ws.xq_down[li]);
        observe_rms("ffn_act", l, &ws.xq_down[li]);
        observe_cast("ffn_down", l, &ws.xq_down[li], prep.plan.ffn_down);
        op_linear(
            &mut ws.xq_down[li],
            prep.plan.ffn_down,
            &qp.ffn_down_t[l],
            &mut ws.t_d1,
            rows,
            d,
            f,
            prep.alpha_ffn_down,
        );
        observe_rms("ffn_down", l, &ws.t_d1);

        // scaled residual add #2 → x[l+1] (slot 0 again when forward-only)
        match prep.placement {
            NormPlacement::Pre => {
                residual_combine(&ws.xmid[li], &ws.t_d1, a2, b2, &mut ws.x[ln]);
            }
            NormPlacement::ResPost => {
                op_rmsnorm(
                    &ws.t_d1,
                    &params[idx_g2(l)],
                    d,
                    &mut ws.n2[li],
                    &mut ws.r2[li],
                    &mut ws.t_d0,
                );
                observe_rms("post_norm2", l, &ws.t_d0);
                residual_combine(&ws.xmid[li], &ws.t_d0, a2, b2, &mut ws.x[ln]);
            }
        }
        observe_rms("resid2", l, &ws.x[ln]);
    }

    // final RMS-norm (gained) → BF16 LM-head input
    op_rmsnorm(
        &ws.x[cfg.depth * st],
        &params[idx_gf(cfg)],
        d,
        &mut ws.nf,
        &mut ws.rf,
        &mut ws.y,
    );
    quantize_slice(&mut ws.y, QuantMode::Bf16);
    observe_rms("final_norm", 0, &ws.y);
}

/// Logits `[batch·s, vocab]` for pre-quantized params over an explicit
/// geometry — the shared entry of the `fwd` artifact (full batch) and
/// `InferSession::prefill` (one sequence, optional KV capture).
pub(crate) fn logits_rows(
    cfg: &ModelConfig,
    prep: &Prepared,
    qp: &QuantParams,
    params: &[Vec<f32>],
    tokens: &[i32],
    batch: usize,
    s: usize,
    kv_sink: Option<KvSink<'_>>,
) -> Vec<f32> {
    let (d, v) = (cfg.width, cfg.vocab);
    let rows = batch * s;
    let mut ws = Workspace::new_forward_only(cfg, batch, s);
    forward_tower(cfg, prep, qp, params, tokens, &mut ws, kv_sink);
    let mut logits = vec![0f32; rows * v];
    matmul_bt(&ws.y, &qp.head_t, &mut logits, rows, v, d, prep.alpha_head);
    logits
}

/// Full-batch logits `[rows, vocab]` (the `fwd` artifact).
pub(crate) fn forward_logits(
    cfg: &ModelConfig,
    prep: &Prepared,
    params: &[Vec<f32>],
    tokens: &[i32],
) -> Result<Vec<f32>> {
    let qp = quantize_params(cfg, params, &prep.plan, false);
    Ok(logits_rows(cfg, prep, &qp, params, tokens, cfg.batch, cfg.seq_len, None))
}

// ---------------------------------------------------------------------------
// Backward

/// Full forward + backward over all scored positions (row (b,t) predicts
/// token (b,t+1); the last position of each sequence only serves as a
/// key/value, its logits are unscored). Returns per-tensor gradients
/// (state order), mean next-token loss, and the global grad norm.
pub(crate) fn train_grads(
    cfg: &ModelConfig,
    prep: &Prepared,
    params: &[Vec<f32>],
    tokens: &[i32],
) -> Result<(Vec<Vec<f32>>, f32, f32)> {
    let (d, v, s) = (cfg.width, cfg.vocab, cfg.seq_len);
    let f = cfg.ffn_width();
    let n = n_param_tensors(cfg);
    if s < 2 || cfg.batch == 0 {
        bail!("batch {} x seq_len {s} too small to score next-token loss", cfg.batch);
    }
    let rows = cfg.batch * s;
    let scored = cfg.batch * (s - 1);
    let qp = quantize_params(cfg, params, &prep.plan, true);
    let mut ws = Workspace::new(cfg, cfg.batch, s);
    forward_tower(cfg, prep, &qp, params, tokens, &mut ws, None);

    // logits, then in place: dlogits = (softmax − onehot) / scored,
    // zeroed on the unscored final position of each sequence
    let mut dlogits = vec![0f32; rows * v];
    matmul_bt(&ws.y, &qp.head_t, &mut dlogits, rows, v, d, prep.alpha_head);
    observe_rms("logits", 0, &dlogits); // still the raw logits here
    let mut loss_rows = vec![0f64; rows];
    let inv = 1.0 / scored as f32;
    let logit_threads = parallel::threads_for((rows * v) as u64 * 8);
    parallel::par_join2(
        &mut dlogits,
        &mut loss_rows,
        ROW_CHUNK * v,
        ROW_CHUNK,
        logit_threads,
        |ci, lc, loss_c| {
            let r0 = ci * ROW_CHUNK;
            for (i, row) in lc.chunks_mut(v).enumerate() {
                let r = r0 + i;
                if r % s == s - 1 {
                    row.fill(0.0);
                    loss_c[i] = 0.0;
                    continue;
                }
                let tgt = tokens[r + 1] as usize;
                // stable cross-entropy per row
                let m = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
                let zden: f64 = row.iter().map(|&o| ((o - m) as f64).exp()).sum();
                let lse = m as f64 + zden.ln();
                loss_c[i] = lse - row[tgt] as f64;
                for (vv, o) in row.iter_mut().enumerate() {
                    let p = (((*o - m) as f64).exp() / zden) as f32;
                    *o = (p - if vv == tgt { 1.0 } else { 0.0 }) * inv;
                }
            }
        },
    );

    let mut grads: Vec<Vec<f32>> = params.iter().map(|p| vec![0f32; p.len()]).collect();
    observe_rms("d_logits", 0, &dlogits);

    // LM head: g_head += α_out · yᵀ @ dlogits; dy = α_out · dlogits @ headᵀ
    add_matmul_at_b(&ws.y, &dlogits, &mut grads[n - 1], rows, d, v, prep.alpha_head);
    let mut dy = vec![0f32; rows * d];
    matmul_bt(&dlogits, &qp.head, &mut dy, rows, d, v, prep.alpha_head);
    drop(dlogits); // the [rows, v] buffer is the largest; release it early
    observe_rms("d_final", 0, &dy);

    // final RMS-norm backward → dxn = dL/dx[depth]
    let mut dxn = vec![0f32; rows * d];
    let gi_f = idx_gf(cfg);
    rmsnorm_backward(&dy, &ws.nf, &ws.rf, &params[gi_f], d, &mut dxn, &mut grads[gi_f]);
    drop(dy);

    // backward scratch, allocated once
    let mut dz_qkv = vec![0f32; rows * 3 * d];
    let mut dqkv_heads = vec![0f32; rows * 3 * d];
    let mut do_heads = vec![0f32; rows * d];
    let mut t_d = vec![0f32; rows * d];
    let mut dz_o = vec![0f32; rows * d];
    let mut d_merge = vec![0f32; rows * d];
    let mut dz_down = vec![0f32; rows * d];
    let mut dz_up = vec![0f32; rows * f];
    let mut d_a = vec![0f32; rows * f];
    let mut dxmid = vec![0f32; rows * d];
    let attn_scale = 1.0 / (cfg.head_dim as f32).sqrt();

    for l in (0..cfg.depth).rev() {
        let [(a1, b1), (a2, b2)] = prep.coeffs[l];

        // ---- ffn branch backward (dxn = dL/dx[l+1]) --------------------
        match prep.placement {
            NormPlacement::Pre => {
                // x[l+1] = a2·xmid + b2·z_down
                scale_into(&dxn, b2, &mut dz_down);
            }
            NormPlacement::ResPost => {
                // x[l+1] = a2·xmid + b2·(norm(z_down)·g2)
                scale_into(&dxn, b2, &mut t_d);
                let gi = idx_g2(l);
                rmsnorm_backward(
                    &t_d,
                    &ws.n2[l],
                    &ws.r2[l],
                    &params[gi],
                    d,
                    &mut dz_down,
                    &mut grads[gi],
                );
            }
        }
        observe_rms("d_ffn_down", l, &dz_down);
        observe_cast("d_ffn_down", l, &dz_down, prep.plan.grad);
        // fused dgrad: quantizes dz in place inside the GEMM pack step;
        // the wgrad below consumes the packed gradient — same operand,
        // same order of effects on dz as the old quantize-then-two-GEMMs.
        op_linear(
            &mut dz_down,
            prep.plan.grad,
            &qp.ffn_down[l],
            &mut d_a,
            rows,
            f,
            d,
            prep.alpha_ffn_down,
        );
        add_matmul_at_b(
            &ws.xq_down[l],
            &dz_down,
            &mut grads[idx_down(l)],
            rows,
            f,
            d,
            prep.alpha_ffn_down,
        );

        act_backward(&d_a, &ws.z_up[l], prep.act, &mut dz_up);
        observe_rms("d_ffn_up", l, &dz_up);
        observe_cast("d_ffn_up", l, &dz_up, prep.plan.grad);
        op_linear(
            &mut dz_up,
            prep.plan.grad,
            &qp.ffn_up[l],
            &mut t_d,
            rows,
            d,
            f,
            prep.alpha_ffn_up,
        );
        add_matmul_at_b(&ws.xq_up[l], &dz_up, &mut grads[idx_up(l)], rows, d, f, prep.alpha_ffn_up);

        match prep.placement {
            NormPlacement::Pre => {
                // up-input was norm(xmid)·g2
                let gi = idx_g2(l);
                rmsnorm_backward(
                    &t_d,
                    &ws.n2[l],
                    &ws.r2[l],
                    &params[gi],
                    d,
                    &mut dxmid,
                    &mut grads[gi],
                );
                axpy_scaled(&dxn, a2, &mut dxmid);
            }
            NormPlacement::ResPost => {
                // up-input was xmid directly
                add_scaled(&dxn, a2, &t_d, &mut dxmid);
            }
        }

        // ---- attention branch backward (dxmid = dL/dxmid) --------------
        match prep.placement {
            NormPlacement::Pre => scale_into(&dxmid, b1, &mut dz_o),
            NormPlacement::ResPost => {
                scale_into(&dxmid, b1, &mut t_d);
                let gi = idx_g1(l);
                rmsnorm_backward(
                    &t_d,
                    &ws.n1[l],
                    &ws.r1[l],
                    &params[gi],
                    d,
                    &mut dz_o,
                    &mut grads[gi],
                );
            }
        }
        observe_rms("d_attn_out", l, &dz_o);
        observe_cast("d_attn_out", l, &dz_o, prep.plan.grad);
        op_linear(
            &mut dz_o,
            prep.plan.grad,
            &qp.attn_out[l],
            &mut d_merge,
            rows,
            d,
            d,
            prep.alpha_attn_out,
        );
        add_matmul_at_b(&ws.xq_o[l], &dz_o, &mut grads[idx_o(l)], rows, d, d, prep.alpha_attn_out);

        split_heads_plain(&d_merge, cfg, s, &mut do_heads);
        attention_all_heads_bwd(
            &do_heads,
            &ws.probs[l],
            &ws.qkv_heads[l],
            &mut dqkv_heads,
            cfg,
            cfg.batch,
            s,
            attn_scale,
        );
        merge_heads_rope_bwd(&dqkv_heads, cfg, s, &prep.rope_cos, &prep.rope_sin, &mut dz_qkv);
        observe_rms("d_qkv", l, &dz_qkv);
        observe_cast("d_qkv", l, &dz_qkv, prep.plan.grad);
        op_linear(
            &mut dz_qkv,
            prep.plan.grad,
            &qp.qkv[l],
            &mut t_d,
            rows,
            d,
            3 * d,
            prep.alpha_qkv,
        );
        add_matmul_at_b(
            &ws.xq_attn[l],
            &dz_qkv,
            &mut grads[idx_qkv(l)],
            rows,
            d,
            3 * d,
            prep.alpha_qkv,
        );

        match prep.placement {
            NormPlacement::Pre => {
                let gi = idx_g1(l);
                rmsnorm_backward(
                    &t_d,
                    &ws.n1[l],
                    &ws.r1[l],
                    &params[gi],
                    d,
                    &mut dxn,
                    &mut grads[gi],
                );
                axpy_scaled(&dxmid, a1, &mut dxn);
            }
            NormPlacement::ResPost => {
                add_scaled(&dxmid, a1, &t_d, &mut dxn);
            }
        }
        // dxn is now dL/dx[l]
        observe_rms("d_resid", l, &dxn);
    }

    // embedding backward: sequential scatter (rows sharing a token collide,
    // and the row-order accumulation keeps it deterministic)
    let g_embed = &mut grads[0];
    for r in 0..rows {
        let src = &dxn[r * d..(r + 1) * d];
        let tok = tokens[r] as usize;
        let dst = &mut g_embed[tok * d..(tok + 1) * d];
        for (o, &x) in dst.iter_mut().zip(src) {
            *o += x;
        }
    }

    // grad norm: fixed-chunk f64 partials folded in chunk order
    let mut gnorm_sq = 0f64;
    for g in &grads {
        gnorm_sq += parallel::par_map_reduce(
            g.len(),
            ELEM_CHUNK,
            parallel::threads_for(g.len() as u64 * 2),
            |_, range| g[range].iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>(),
            |a, b| a + b,
            0f64,
        );
    }
    let loss = (loss_rows.iter().sum::<f64>() / scored as f64) as f32;
    Ok((grads, loss, gnorm_sq.sqrt() as f32))
}

#[cfg(test)]
mod tests {
    use super::*;

    // -----------------------------------------------------------------
    // f64 reference path: an unquantized, scalar-loop transcription of
    // the pipeline used as the finite-difference oracle for the analytic
    // backward pass.

    fn act64(act: Act, z: f64) -> f64 {
        match act {
            Act::Gelu => {
                const K: f64 = 0.797_884_560_802_865_4; // sqrt(2/pi)
                let u = K * (z + 0.044715 * z * z * z);
                0.5 * z * (1.0 + u.tanh())
            }
            Act::Silu => z / (1.0 + (-z).exp()),
            Act::Relu => z.max(0.0),
        }
    }

    fn rmsnorm64(x: &[f64], g: &[f32]) -> Vec<f64> {
        let ms = x.iter().map(|v| v * v).sum::<f64>() / x.len() as f64;
        let r = (ms + RMS_EPS).sqrt();
        x.iter().zip(g).map(|(&v, &gg)| v / r * gg as f64).collect()
    }

    /// `x [s][din] @ w [din, dout] * alpha` in f64.
    fn linear64(x: &[Vec<f64>], w: &[f32], din: usize, dout: usize, alpha: f64) -> Vec<Vec<f64>> {
        x.iter()
            .map(|row| {
                (0..dout)
                    .map(|o| {
                        alpha * (0..din).map(|i| row[i] * w[i * dout + o] as f64).sum::<f64>()
                    })
                    .collect()
            })
            .collect()
    }

    /// Mean next-token loss of the block pipeline, computed without any
    /// quantization in f64 scalar loops. Mirrors `forward_tower` op for
    /// op (placement, multipliers, RoPE, causal softmax, residuals).
    fn naive_loss_f64(cfg: &ModelConfig, params: &[Vec<f32>], tokens: &[i32], tau: f32) -> f64 {
        let (d, v, s) = (cfg.width, cfg.vocab, cfg.seq_len);
        let f = cfg.ffn_width();
        let (h, dh) = (cfg.n_heads(), cfg.head_dim);
        let half = dh / 2;
        let scheme = cfg.scheme();
        let a_hid = scheme.output_mult(ParamKind::Hidden, d);
        let a_down = scheme.output_mult(ParamKind::Hidden, f);
        let a_head = scheme.output_mult(ParamKind::Output, d);
        let act = Act::parse(&cfg.activation).unwrap();
        let placement = placement_for(cfg);
        let rot = |vals: &[f64], t: usize| -> Vec<f64> {
            let mut out = vec![0f64; dh];
            for j in 0..half {
                let freq = 10_000f64.powf(-(j as f64) / half as f64);
                let ang = t as f64 * freq;
                let (cj, sj) = (ang.cos(), ang.sin());
                out[j] = vals[j] * cj - vals[half + j] * sj;
                out[half + j] = vals[j] * sj + vals[half + j] * cj;
            }
            out
        };
        let mut total = 0f64;
        let mut count = 0usize;
        for b in 0..cfg.batch {
            let toks = &tokens[b * s..(b + 1) * s];
            let mut x: Vec<Vec<f64>> = toks
                .iter()
                .map(|&t| {
                    params[0][t as usize * d..(t as usize + 1) * d]
                        .iter()
                        .map(|&w| w as f64)
                        .collect()
                })
                .collect();
            for l in 0..cfg.depth {
                let (a1, b1) = residual_coeffs(cfg, tau, l, 0).unwrap();
                let (a2, b2) = residual_coeffs(cfg, tau, l, 1).unwrap();
                // attention branch
                let inp: Vec<Vec<f64>> = match placement {
                    NormPlacement::Pre => {
                        x.iter().map(|row| rmsnorm64(row, &params[idx_g1(l)])).collect()
                    }
                    NormPlacement::ResPost => x.clone(),
                };
                let zqkv = linear64(&inp, &params[idx_qkv(l)], d, 3 * d, a_hid);
                let mut merged = vec![vec![0f64; d]; s];
                for hh in 0..h {
                    let q: Vec<Vec<f64>> =
                        (0..s).map(|t| rot(&zqkv[t][hh * dh..(hh + 1) * dh], t)).collect();
                    let k: Vec<Vec<f64>> = (0..s)
                        .map(|t| rot(&zqkv[t][d + hh * dh..d + (hh + 1) * dh], t))
                        .collect();
                    let vv: Vec<Vec<f64>> = (0..s)
                        .map(|t| zqkv[t][2 * d + hh * dh..2 * d + (hh + 1) * dh].to_vec())
                        .collect();
                    let scale = 1.0 / (dh as f64).sqrt();
                    for i in 0..s {
                        let logits: Vec<f64> = (0..=i)
                            .map(|j| {
                                scale
                                    * q[i].iter().zip(&k[j]).map(|(a, b)| a * b).sum::<f64>()
                            })
                            .collect();
                        let m = logits.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
                        let den: f64 = logits.iter().map(|&lg| (lg - m).exp()).sum();
                        for j in 0..=i {
                            let p = (logits[j] - m).exp() / den;
                            for c in 0..dh {
                                merged[i][hh * dh + c] += p * vv[j][c];
                            }
                        }
                    }
                }
                let zo = linear64(&merged, &params[idx_o(l)], d, d, a_hid);
                let branch1: Vec<Vec<f64>> = match placement {
                    NormPlacement::Pre => zo,
                    NormPlacement::ResPost => {
                        zo.iter().map(|row| rmsnorm64(row, &params[idx_g1(l)])).collect()
                    }
                };
                let xmid: Vec<Vec<f64>> = x
                    .iter()
                    .zip(&branch1)
                    .map(|(xr, br)| {
                        xr.iter()
                            .zip(br)
                            .map(|(&a, &bb)| a1 as f64 * a + b1 as f64 * bb)
                            .collect()
                    })
                    .collect();
                // ffn branch
                let inp2: Vec<Vec<f64>> = match placement {
                    NormPlacement::Pre => {
                        xmid.iter().map(|row| rmsnorm64(row, &params[idx_g2(l)])).collect()
                    }
                    NormPlacement::ResPost => xmid.clone(),
                };
                let zup = linear64(&inp2, &params[idx_up(l)], d, f, a_hid);
                let aout: Vec<Vec<f64>> = zup
                    .iter()
                    .map(|row| row.iter().map(|&z| act64(act, z)).collect())
                    .collect();
                let zdown = linear64(&aout, &params[idx_down(l)], f, d, a_down);
                let branch2: Vec<Vec<f64>> = match placement {
                    NormPlacement::Pre => zdown,
                    NormPlacement::ResPost => {
                        zdown.iter().map(|row| rmsnorm64(row, &params[idx_g2(l)])).collect()
                    }
                };
                x = xmid
                    .iter()
                    .zip(&branch2)
                    .map(|(xr, br)| {
                        xr.iter()
                            .zip(br)
                            .map(|(&a, &bb)| a2 as f64 * a + b2 as f64 * bb)
                            .collect()
                    })
                    .collect();
            }
            let gf = &params[idx_gf(cfg)];
            let head = &params[idx_head(cfg)];
            for t in 0..s - 1 {
                let y = rmsnorm64(&x[t], gf);
                let logits: Vec<f64> = (0..v)
                    .map(|o| a_head * (0..d).map(|i| y[i] * head[i * v + o] as f64).sum::<f64>())
                    .collect();
                let m = logits.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
                let den: f64 = logits.iter().map(|&lg| (lg - m).exp()).sum();
                let tgt = toks[t + 1] as usize;
                total += (m + den.ln()) - logits[tgt];
                count += 1;
            }
        }
        total / count as f64
    }

    fn gradcheck_cfg(variant: &str, residual: &str) -> ModelConfig {
        ModelConfig {
            width: 16,
            depth: 2,
            head_dim: 8,
            vocab: 32,
            seq_len: 8,
            batch: 2,
            precision: "bf16".into(),
            variant: variant.into(),
            residual: residual.into(),
            ..ModelConfig::default()
        }
    }

    /// Finite-difference gradient check against the f64 reference path.
    ///
    /// Tolerance: the interpreter rounds weights/activations/gradients
    /// through BF16 (rel err ~2⁻⁹ per op) and accumulates in f32, while
    /// the FD oracle is unquantized f64 — the two agree to a few percent.
    /// 12% relative + 3e-4 absolute covers the worst sampled coordinate
    /// with margin; everything is seeded, so the test is deterministic.
    fn grad_check(variant: &str, residual: &str) {
        let cfg = gradcheck_cfg(variant, residual);
        assert!(cfg.depth >= 2 && cfg.n_heads() >= 2);
        let params = init_params(&cfg, 7);
        let tokens: Vec<i32> =
            (0..cfg.batch * cfg.seq_len).map(|i| ((i * 5 + 3) % cfg.vocab) as i32).collect();
        let tau = 0.4f32;
        let prep = Prepared::new(&cfg, tau).unwrap();
        let (grads, loss, gnorm) = train_grads(&cfg, &prep, &params, &tokens).unwrap();
        assert!(gnorm.is_finite() && gnorm > 0.0, "{variant}: gnorm {gnorm}");
        let ref_loss = naive_loss_f64(&cfg, &params, &tokens, tau);
        assert!(
            (loss as f64 - ref_loss).abs() < 0.03 * ref_loss.abs().max(1.0),
            "{variant}: interpreter loss {loss} vs f64 reference {ref_loss}"
        );
        let specs = param_specs(&cfg);
        let mut rng = Rng::new(0xC0FFEE);
        for ti in 0..n_param_tensors(&cfg) {
            for _ in 0..2 {
                let ei = (rng.next_u64() % params[ti].len() as u64) as usize;
                let h = 1e-3f32;
                let mut pp = params.clone();
                pp[ti][ei] += h;
                let mut pm = params.clone();
                pm[ti][ei] -= h;
                // effective step after f32 rounding of the perturbed value
                let h_eff = pp[ti][ei] as f64 - pm[ti][ei] as f64;
                let lp = naive_loss_f64(&cfg, &pp, &tokens, tau);
                let lm = naive_loss_f64(&cfg, &pm, &tokens, tau);
                let fd = (lp - lm) / h_eff;
                let g = grads[ti][ei] as f64;
                assert!(
                    (fd - g).abs() <= 0.12 * fd.abs().max(g.abs()) + 3e-4,
                    "{variant} tensor {ti} ({}) elem {ei}: fd {fd} vs analytic {g}",
                    specs[ti].name
                );
            }
        }
    }

    #[test]
    fn gradients_match_f64_finite_differences_mus_respost() {
        grad_check("mus", "fixed");
    }

    #[test]
    fn gradients_match_f64_finite_differences_sp_pre() {
        grad_check("sp", "standard");
    }

    /// The FP8 lanes' gradient check. A strict finite-difference check is
    /// ill-posed under FP8 quantization: clip-then-cast makes the loss
    /// piecewise constant in any single weight (an E4M3 step near 1.0 is
    /// ~6%), and the analytic gradients are deliberately straight-through.
    /// The lanes instead reuse the exact backward code the BF16 FD check
    /// validates — the only difference is the QuantMode — so here we pin
    /// the FP8 gradients to stay directionally aligned with the BF16 ones
    /// (quantization perturbs each tensor by a few percent at most).
    #[test]
    fn fp8_lane_gradients_track_bf16() {
        for (variant, residual) in [("mus", "fixed"), ("sp", "standard")] {
            let bf = gradcheck_cfg(variant, residual);
            let fp = ModelConfig { precision: "fp8".into(), ..bf.clone() };
            let params = init_params(&bf, 11);
            let tokens: Vec<i32> = (0..bf.batch * bf.seq_len)
                .map(|i| ((i * 7 + 1) % bf.vocab) as i32)
                .collect();
            let gb =
                train_grads(&bf, &Prepared::new(&bf, 0.4).unwrap(), &params, &tokens).unwrap().0;
            let gf =
                train_grads(&fp, &Prepared::new(&fp, 0.4).unwrap(), &params, &tokens).unwrap().0;
            for (ti, (a, b)) in gb.iter().zip(&gf).enumerate() {
                let dot: f64 =
                    a.iter().zip(b).map(|(&x, &y)| x as f64 * y as f64).sum();
                let na: f64 = a.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt();
                let nb: f64 = b.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt();
                if na < 1e-8 || nb < 1e-8 {
                    continue;
                }
                let cos = dot / (na * nb);
                assert!(
                    cos > 0.8,
                    "{variant} tensor {ti}: fp8 grads diverged from bf16 (cos {cos})"
                );
            }
        }
    }

    // -----------------------------------------------------------------
    // layout / FLOPs / residuals / quantization

    #[test]
    fn param_layout_agrees_with_config_n_params() {
        for cfg in [
            ModelConfig::default(),
            ModelConfig { width: 128, depth: 6, head_dim: 32, ..ModelConfig::default() },
        ] {
            let specs = param_specs(&cfg);
            assert_eq!(specs.len(), n_param_tensors(&cfg));
            let total: usize = specs.iter().map(|s| s.elements()).sum();
            assert_eq!(total, cfg.n_params(), "spec elements vs ModelConfig::n_params");
            // role indices round-trip
            assert_eq!(role_of(&cfg, 0), Role::Embed);
            assert_eq!(role_of(&cfg, idx_qkv(1)), Role::Qkv);
            assert_eq!(role_of(&cfg, idx_o(1)), Role::AttnOut);
            assert_eq!(role_of(&cfg, idx_up(0)), Role::FfnUp);
            assert_eq!(role_of(&cfg, idx_down(0)), Role::FfnDown);
            assert_eq!(role_of(&cfg, idx_g1(0)), Role::Rms1);
            assert_eq!(role_of(&cfg, idx_g2(cfg.depth - 1)), Role::Rms2);
            assert_eq!(role_of(&cfg, idx_gf(&cfg)), Role::RmsFinal);
            assert_eq!(role_of(&cfg, idx_head(&cfg)), Role::Head);
            assert_eq!(specs[idx_qkv(0)].shape, vec![cfg.width, 3 * cfg.width]);
            assert_eq!(specs[idx_down(0)].shape, vec![cfg.ffn_width(), cfg.width]);
        }
    }

    #[test]
    fn hidden_gemm_flops_match_config_formula() {
        for cfg in [
            ModelConfig::default(),
            ModelConfig {
                width: 384,
                depth: 6,
                head_dim: 64,
                vocab: 2048,
                seq_len: 256,
                batch: 8,
                ..ModelConfig::default()
            },
        ] {
            assert_eq!(hidden_gemm_flops_per_token_fwd(&cfg), cfg.hidden_flops_per_token_fwd());
            assert_eq!(attn_gemm_flops_per_seq_fwd(&cfg), cfg.attn_flops_per_seq_fwd());
        }
    }

    #[test]
    fn residual_coeffs_preserve_unit_variance() {
        let cfg = ModelConfig::default();
        let (a, b) = residual_coeffs(&cfg, 0.4, 0, 0).unwrap();
        assert!((a * a + b * b - 1.0).abs() < 1e-6);
        let rm = ModelConfig { residual: "running_mean".into(), ..cfg };
        let mut prev_b = f32::INFINITY;
        for l in 0..3 {
            for br in 0..2 {
                let (a, b) = residual_coeffs(&rm, 0.0, l, br).unwrap();
                assert!((a * a + b * b - 1.0).abs() < 1e-6, "layer {l} branch {br}");
                assert!(b < prev_b, "running-mean branch weight must decrease");
                prev_b = b;
            }
        }
    }

    #[test]
    fn unknown_residual_scheme_is_an_error_not_fixed() {
        // Regression: a catch-all `_` arm used to silently train the
        // "fixed" scheme for any unrecognized string (reachable by configs
        // that bypass validate()).
        let cfg = ModelConfig { residual: "bogus".into(), ..ModelConfig::default() };
        let err = residual_coeffs(&cfg, 0.4, 0, 0).unwrap_err().to_string();
        assert!(err.contains("bogus"), "unhelpful error: {err}");
        let err = Prepared::new(&cfg, 0.4).unwrap_err().to_string();
        assert!(err.contains("residual"), "unhelpful error: {err}");
    }

    #[test]
    fn dynamic_fp8_propagates_nonfinite_instead_of_masking() {
        // Regression: an inf in the tensor used to make quantize_slice
        // return early, silently skipping quantization in exactly the
        // SP+FP8 divergence experiment the paper is about.
        let mut xs = vec![1.0f32, -2.5, f32::INFINITY, 0.5];
        quantize_slice(&mut xs, QuantMode::DynamicFp8(E4M3));
        assert!(xs[2].is_nan(), "E4M3 overflow must surface as NaN, got {}", xs[2]);
        // finite elements are still cast onto the E4M3 grid (scale 1)
        assert_eq!(xs[0], 1.0);
        assert_eq!(xs[1], -2.5);
        assert_eq!(xs[3], 0.5);

        // E5M2 keeps IEEE-style inf on overflow
        let mut xs = vec![f32::NEG_INFINITY, 3.0f32];
        quantize_slice(&mut xs, QuantMode::DynamicFp8(E5M2));
        assert_eq!(xs[0], f32::NEG_INFINITY);
        assert_eq!(xs[1], 3.0);

        // NaN elements propagate (amax ignores them; the cast keeps them)
        let mut xs = vec![f32::NAN, 1.0f32];
        quantize_slice(&mut xs, QuantMode::DynamicFp8(E4M3));
        assert!(xs[0].is_nan());
        assert!(xs[1].is_finite());

        // all-zero tensors stay untouched (no 0/0 scale)
        let mut xs = vec![0.0f32; 4];
        quantize_slice(&mut xs, QuantMode::DynamicFp8(E4M3));
        assert!(xs.iter().all(|&x| x == 0.0));

        // deeply-subnormal amax: the scale clamps to f32::MAX instead of
        // overflowing to inf, so exact zeros stay zero (not 0*inf = NaN)
        let mut xs = vec![0.0f32, 1e-40, -1e-40];
        quantize_slice(&mut xs, QuantMode::DynamicFp8(E4M3));
        assert_eq!(xs[0], 0.0);
        assert!(xs.iter().all(|x| !x.is_nan()), "tiny-amax tensor produced NaN: {xs:?}");
    }

    #[test]
    fn te_dynamic_scale_policy_cases() {
        // the ONE policy quantize_slice and observe_cast share
        let maxf = E4M3.max_finite() as f32;
        assert_eq!(te_dynamic_scale(maxf, 0.0), DynScale::Skip);
        assert_eq!(te_dynamic_scale(maxf, f32::INFINITY), DynScale::Raw);
        assert_eq!(te_dynamic_scale(maxf, 448.0 * 1024.0), DynScale::Scale(1.0 / 1024.0));
        // deeply-subnormal amax clamps instead of producing an inf scale
        match te_dynamic_scale(maxf, 1e-43) {
            DynScale::Scale(s) => assert!(s.is_finite()),
            other => panic!("expected clamped scale, got {other:?}"),
        }
    }

    #[test]
    fn init_params_follow_scheme_rules() {
        let cfg = ModelConfig::default(); // mus
        let p = init_params(&cfg, 3);
        // unit-variance embedding, gains exactly 1
        let var =
            p[0].iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>() / p[0].len() as f64;
        assert!((var - 1.0).abs() < 0.15, "mus embed var {var}");
        assert!(p[idx_g1(0)].iter().all(|&g| g == 1.0));
        assert!(p[idx_gf(&cfg)].iter().all(|&g| g == 1.0));
        // SP: sigma_init-scale weights
        let sp = ModelConfig {
            variant: "sp".into(),
            residual: "standard".into(),
            ..ModelConfig::default()
        };
        let p = init_params(&sp, 3);
        let var = p[idx_qkv(0)].iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>()
            / p[idx_qkv(0)].len() as f64;
        assert!((var.sqrt() - SIGMA_INIT).abs() < 0.005, "sp qkv std {}", var.sqrt());
    }

    #[test]
    fn shard_axis_covers_exactly_the_four_hidden_linears() {
        let cfg = ModelConfig::default();
        let specs = param_specs(&cfg);
        let mut sharded = 0usize;
        for idx in 0..specs.len() {
            let role = role_of(&cfg, idx);
            match shard_axis(role) {
                Some(ShardAxis::Col { blocks }) => {
                    sharded += 1;
                    assert!(matches!(role, Role::Qkv | Role::FfnUp));
                    // each packed column group is a multiple of head_dim
                    // wide, so any tp | n_heads split is head-aligned
                    let fan_out = specs[idx].shape[1];
                    assert_eq!(fan_out % blocks, 0);
                    assert_eq!((fan_out / blocks) % cfg.head_dim, 0);
                }
                Some(ShardAxis::Row) => {
                    sharded += 1;
                    assert!(matches!(role, Role::AttnOut | Role::FfnDown));
                    assert_eq!(specs[idx].shape[0], fan_in(&cfg, role));
                }
                None => assert!(!matches!(
                    role,
                    Role::Qkv | Role::AttnOut | Role::FfnUp | Role::FfnDown
                )),
            }
        }
        assert_eq!(sharded, 4 * cfg.depth);
    }

    #[test]
    fn op_graph_enumerates_every_site_once_in_order() {
        for variant in ["mus", "sp"] {
            let mut cfg = ModelConfig::default();
            cfg.variant = variant.into();
            let g = op_graph(&cfg);
            // 12 forward sites + 5 backward sites per layer, plus the 5
            // global sites (embed, final_norm, logits, d_logits, d_final)
            assert_eq!(g.len(), 5 + 17 * cfg.depth, "{variant}");
            let mut seen = std::collections::BTreeSet::new();
            for n in &g {
                assert!(seen.insert((n.name, n.layer)), "duplicate node {:?}", (n.name, n.layer));
            }
            // Pre norms the branch input (norm precedes the linear);
            // Res-Post norms the branch output (linear precedes the norm)
            let pos = |name: &str| g.iter().position(|n| n.name == name && n.layer == 0).unwrap();
            if variant == "mus" {
                assert!(pos("qkv") < pos("post_norm1"));
            } else {
                assert!(pos("post_norm1") < pos("qkv"));
            }
        }
    }

    #[test]
    fn op_graph_cast_sites_carry_the_plan_modes() {
        let cfg = ModelConfig::default(); // mus + fp8
        let plan = plan_for(&cfg);
        let g = op_graph(&cfg);
        let mut fwd_casts = 0;
        let mut grad_casts = 0;
        for n in &g {
            match node_mode(n, &plan) {
                Some(QuantMode::StaticFp8(f)) => {
                    if matches!(n.kind, OpKind::Linear(_)) {
                        assert_eq!(f.name, "e4m3", "{}", n.name);
                        assert!(n.cast.is_some() && n.weight_cast.is_some());
                        fwd_casts += 1;
                    } else {
                        assert_eq!(f.name, "e5m2", "{}", n.name);
                        assert!(n.cast.is_some() && n.weight_cast.is_none());
                        grad_casts += 1;
                    }
                }
                Some(_) => panic!("µS plan must be static: {}", n.name),
                None => assert!(
                    !matches!(n.kind, OpKind::Linear(_) | OpKind::GradLinear(_)),
                    "{} is a linear but carries no mode",
                    n.name
                ),
            }
        }
        assert_eq!(fwd_casts, 4 * cfg.depth);
        assert_eq!(grad_casts, 4 * cfg.depth);
    }
}
