//! PJRT runtime: load AOT HLO-text artifacts, compile once, execute.
//!
//! This is the only module that touches the `xla` crate. Facts this wrapper
//! encodes (verified by `rust/src/bin/hlo_check.rs` and the round-trip
//! integration tests):
//!
//!  - artifacts are HLO *text*; `HloModuleProto::from_text_file` reassigns
//!    instruction ids (jax >= 0.5 emits 64-bit ids that XLA 0.5.1 rejects
//!    in proto form);
//!  - executables built with `return_tuple=True` give back ONE tuple
//!    buffer per replica — PJRT 0.5.1 does not untuple;
//!  - calling `to_vec` on a tuple literal CHECK-fails (aborts), so the
//!    tuple must be `decompose_tuple`d after a single host transfer.

mod manifest;

pub use manifest::{ArtifactMeta, Dtype, Manifest, TensorSpec};

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};
use xla::{HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable, Shape, XlaComputation};

/// Literal constructors for the artifact ABI (f32 / i32 only, by design —
/// FP8/BF16 live *inside* the graphs; master state crosses in f32).
pub fn lit_f32(data: &[f32], shape: &[usize]) -> Result<Literal> {
    let n: usize = shape.iter().product();
    if n != data.len() {
        bail!("lit_f32: {} elements for shape {:?}", data.len(), shape);
    }
    if shape.is_empty() {
        return Ok(Literal::scalar(data[0]));
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(Literal::vec1(data).reshape(&dims)?)
}

pub fn lit_i32(data: &[i32], shape: &[usize]) -> Result<Literal> {
    let n: usize = shape.iter().product();
    if n != data.len() {
        bail!("lit_i32: {} elements for shape {:?}", data.len(), shape);
    }
    if shape.is_empty() {
        return Ok(Literal::scalar(data[0]));
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(Literal::vec1(data).reshape(&dims)?)
}

pub fn scalar_f32(v: f32) -> Literal {
    Literal::scalar(v)
}

pub fn scalar_i32(v: i32) -> Literal {
    Literal::scalar(v)
}

/// Copy a literal's f32 payload out.
pub fn to_f32_vec(lit: &Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

/// Scalar f32 accessor.
pub fn to_f32_scalar(lit: &Literal) -> Result<f32> {
    let v = lit.to_vec::<f32>()?;
    if v.len() != 1 {
        bail!("expected scalar, got {} elements", v.len());
    }
    Ok(v[0])
}

/// Cumulative execution statistics for one executable.
#[derive(Debug, Clone, Default)]
pub struct ExecStats {
    pub calls: usize,
    pub execute_time: Duration,
    pub transfer_time: Duration,
    pub compile_time: Duration,
}

struct CachedExe {
    exe: PjRtLoadedExecutable,
    stats: ExecStats,
}

/// Artifact execution engine: one PJRT CPU client + a compile cache.
///
/// Not `Send` (the `xla` crate's client is `Rc`-based); parallel sweeps use
/// one `Engine` per worker process (`coordinator::sweep`).
pub struct Engine {
    client: PjRtClient,
    pub manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<RefCell<CachedExe>>>>,
}

impl Engine {
    pub fn new(artifact_dir: impl AsRef<Path>) -> Result<Engine> {
        let manifest = Manifest::load(artifact_dir.as_ref())
            .context("loading artifacts/manifest.json (run `make artifacts`)")?;
        let client = PjRtClient::cpu()?;
        Ok(Engine { client, manifest, cache: RefCell::new(HashMap::new()) })
    }

    /// Compile (or fetch from cache) an artifact by manifest name.
    fn cached(&self, name: &str) -> Result<Rc<RefCell<CachedExe>>> {
        if let Some(e) = self.cache.borrow().get(name) {
            return Ok(e.clone());
        }
        let meta = self
            .manifest
            .find(name)
            .with_context(|| format!("artifact '{name}' not in manifest"))?;
        let path = self.manifest.dir.join(&meta.file);
        let t0 = Instant::now();
        let proto = HloModuleProto::from_text_file(path.to_str().unwrap())
            .with_context(|| format!("parsing {}", path.display()))?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        let compile_time = t0.elapsed();
        let cached = Rc::new(RefCell::new(CachedExe {
            exe,
            stats: ExecStats { compile_time, ..Default::default() },
        }));
        self.cache.borrow_mut().insert(name.to_string(), cached.clone());
        Ok(cached)
    }

    /// Warm the compile cache (e.g. before timing).
    pub fn precompile(&self, name: &str) -> Result<()> {
        self.cached(name).map(|_| ())
    }

    /// Execute an artifact: checks input arity against the manifest, runs,
    /// transfers the result tuple to host once, and splits it into one
    /// literal per declared output. Accepts owned or borrowed literals.
    pub fn run<L: std::borrow::Borrow<Literal>>(
        &self,
        name: &str,
        inputs: &[L],
    ) -> Result<Vec<Literal>> {
        let meta = self
            .manifest
            .find(name)
            .with_context(|| format!("artifact '{name}' not in manifest"))?
            .clone();
        if inputs.len() != meta.inputs.len() {
            bail!(
                "artifact '{name}' expects {} inputs, got {}",
                meta.inputs.len(),
                inputs.len()
            );
        }
        let cached = self.cached(name)?;
        let t0 = Instant::now();
        // NOTE: we deliberately avoid `PjRtLoadedExecutable::execute`
        // (literal inputs): its C++ shim `release()`s the device buffers it
        // creates for the inputs and never frees them — a ~full-state leak
        // per training step (measured: 36 GB RSS in an hour-long figure
        // run; see EXPERIMENTS.md §Perf). Instead we create owned buffers
        // and use `execute_b`, which borrows them; they drop right after.
        let bufs = inputs
            .iter()
            .map(|l| self.client.buffer_from_host_literal(None, l.borrow()))
            .collect::<std::result::Result<Vec<_>, _>>()?;
        let result = cached.borrow().exe.execute_b(&bufs)?;
        drop(bufs);
        let t1 = Instant::now();
        let buf = &result[0][0];
        let mut lit = buf.to_literal_sync()?;
        let outs = match lit.shape()? {
            Shape::Tuple(_) => lit.decompose_tuple()?,
            _ => vec![lit],
        };
        let t2 = Instant::now();
        {
            let mut c = cached.borrow_mut();
            c.stats.calls += 1;
            c.stats.execute_time += t1 - t0;
            c.stats.transfer_time += t2 - t1;
        }
        if outs.len() != meta.outputs.len() {
            bail!(
                "artifact '{name}' declared {} outputs, produced {}",
                meta.outputs.len(),
                outs.len()
            );
        }
        Ok(outs)
    }

    pub fn stats(&self, name: &str) -> Option<ExecStats> {
        self.cache.borrow().get(name).map(|c| c.borrow().stats.clone())
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }
}
