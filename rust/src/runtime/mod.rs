//! Runtime: backend-agnostic artifact execution + device-resident state.
//!
//! Layering (see the crate docs in `lib.rs`):
//!
//!  - [`Backend`] — the execution trait: `upload`/`execute`/`download`
//!    over opaque [`TensorHandle`]s, plus the host-level [`Backend::run`]
//!    convenience. All implementations are `Send + Sync`, so sweep
//!    workers run as in-process threads over one backend.
//!  - [`Session`] — owns the device-resident `TrainState` between steps;
//!    per-step host traffic is tokens + 3 scalars in and 2 scalars out,
//!    accounted in [`ExecStats`]. A [`StatePrecision`] policy selects the
//!    state storage: f32 (bit-compat default, 8 B/param element) or
//!    FP8 state — E4M3 Lion momentum with one power-of-two scale per
//!    tensor + BF16 masters, 3 B/param element, quantized on write
//!    inside the fused train step (`runtime::state`).
//!  - [`ReferenceBackend`] — pure-Rust interpreter (fp8 emulation) over
//!    the op-level transformer block in `runtime::block` (real multi-head
//!    causal attention + FFN); runs everywhere, no artifacts required.
//!  - [`InferSession`] — the session layer's inference counterpart:
//!    parameters quantized once (the same static casts training uses),
//!    prefill through the training forward (whole-prompt or chunked),
//!    incremental decode over a paged, refcounted KV cache
//!    (`runtime::kvcache`) with prompt-prefix sharing and a BF16 or
//!    static-scale E4M3 store ([`KvStoreMode`]), greedy / seeded top-k
//!    sampling. Decode logits are bit-identical to the training forward
//!    under static-FP8/BF16 plans — the paper's training-inference match.
//!  - `PjrtBackend` (feature `pjrt`) — AOT HLO-text artifacts on the PJRT
//!    CPU client (`xla` crate; vendored separately).
//!
//! [`open_backend`] picks the best available implementation for a given
//! artifact directory.

mod backend;
pub(crate) mod block;
/// Deterministic GEMM / attention kernels + telemetry reductions.
pub mod gemm;
mod infer;
pub(crate) mod kvcache;
mod manifest;
#[cfg(feature = "pjrt")]
mod pjrt;
mod reference;
mod session;
/// Low-precision optimizer/master-state policy (`StatePrecision`) and
/// its E4M3+scale / BF16 codecs.
pub mod state;
mod tensor;

pub use backend::{Backend, ExecStats, TensorHandle};
pub use infer::{sample_greedy, sample_topk, InferSession, InferStats, SeqId};
pub use kvcache::KvStoreMode;
pub use manifest::{ArtifactMeta, Dtype, Manifest, TensorSpec};
#[cfg(feature = "pjrt")]
pub use pjrt::PjrtBackend;
pub use reference::{micro_config, standard_roster, ReferenceBackend};
pub use session::{Session, TrainState};
pub use state::StatePrecision;
pub use tensor::{Tensor, TensorData};

use std::path::Path;

use crate::util::error::Result;

/// Host-tensor constructors/accessors, kept as free functions for
/// call-site brevity (the artifact ABI is f32/i32 only by design).
pub fn tensor_f32(data: &[f32], shape: &[usize]) -> Result<Tensor> {
    Tensor::f32(data.to_vec(), shape)
}

/// i32 host tensor from a slice (see [`Tensor::i32`]).
pub fn tensor_i32(data: &[i32], shape: &[usize]) -> Result<Tensor> {
    Tensor::i32(data.to_vec(), shape)
}

/// f32 scalar host tensor.
pub fn scalar_f32(v: f32) -> Tensor {
    Tensor::scalar_f32(v)
}

/// i32 scalar host tensor.
pub fn scalar_i32(v: i32) -> Tensor {
    Tensor::scalar_i32(v)
}

/// Copy a tensor's f32 payload out.
pub fn to_f32_vec(t: &Tensor) -> Result<Vec<f32>> {
    t.to_f32_vec()
}

/// Read a scalar tensor's f32 value.
pub fn to_f32_scalar(t: &Tensor) -> Result<f32> {
    t.scalar()
}

/// Open the best available backend for `artifact_dir`:
///
///  - with feature `pjrt` and a built artifact directory, the PJRT CPU
///    backend over the AOT artifacts;
///  - otherwise the pure-Rust [`ReferenceBackend`] (standard roster),
///    which needs no artifacts at all.
pub fn open_backend(artifact_dir: impl AsRef<Path>) -> Result<Box<dyn Backend>> {
    let dir = artifact_dir.as_ref();
    let have_artifacts = dir.join("manifest.json").exists();
    #[cfg(feature = "pjrt")]
    {
        if have_artifacts {
            return Ok(Box::new(PjrtBackend::new(dir)?));
        }
        eprintln!(
            "note: {} has no manifest.json; using the pure-Rust reference backend",
            dir.display()
        );
    }
    #[cfg(not(feature = "pjrt"))]
    {
        if have_artifacts {
            eprintln!(
                "note: artifacts present in {} but the pjrt feature is disabled; \
                 using the pure-Rust reference backend",
                dir.display()
            );
        }
    }
    Ok(Box::new(ReferenceBackend::with_standard_roster()))
}
