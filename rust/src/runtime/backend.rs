//! Backend: the execution API every runtime implementation satisfies.
//!
//! The contract is built around *device residency*: `upload`/`execute`/
//! `download` move opaque [`TensorHandle`]s, so a training loop can keep
//! the full `2 * n_params` master state on the device and only pay host
//! transfers for the tokens it feeds in and the scalars (loss, grad-norm)
//! it reads out. Full-state transfers happen solely at checkpoint / probe
//! boundaries ([`crate::runtime::Session::read_back`]).
//!
//! Implementations must be `Send + Sync`: the sweep engine runs workers as
//! in-process threads over one shared backend handle.
//!
//! Implementations in-tree:
//!  - [`crate::runtime::ReferenceBackend`] — pure-Rust interpreter of small
//!    configs through `fp8::Format` emulation; no AOT artifacts needed.
//!  - `PjrtBackend` (feature `pjrt`) — the AOT HLO-text / PJRT CPU path.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use super::manifest::{ArtifactMeta, Dtype, Manifest};
use super::tensor::Tensor;
use crate::config::ModelConfig;
use crate::err;
use crate::util::error::Result;

/// Cumulative execution statistics for one artifact (or one session).
#[derive(Debug, Clone, Default)]
pub struct ExecStats {
    /// Executions (for a session: steps taken).
    pub calls: usize,
    /// Total device-execution time.
    pub execute_time: Duration,
    /// Host<->device transfer time attributable to this artifact/session.
    pub transfer_time: Duration,
    /// One-time compile/warmup time (PJRT path).
    pub compile_time: Duration,
    /// Bytes moved across the host<->device boundary.
    pub transfer_bytes: u64,
    /// Optimizer + master state bytes held under the session's
    /// [`crate::runtime::StatePrecision`] policy (masters + momenta;
    /// per-tensor scale exponents are O(n_tensors) metadata, counted
    /// where they become real bytes — checkpoints and the wire). Zero
    /// for non-session stats (per-artifact counters).
    pub state_bytes: u64,
    /// [`ExecStats::state_bytes`] per parameter element: 8.0 under f32
    /// state, 3.0 under FP8 state (E4M3 momentum + BF16 masters). Zero
    /// for non-session stats.
    pub state_bytes_per_param: f64,
}

impl ExecStats {
    /// Mean execution time per call (zero before any call).
    pub fn per_call_execute(&self) -> Duration {
        if self.calls == 0 {
            Duration::ZERO
        } else {
            self.execute_time / self.calls as u32
        }
    }

    /// Mean host-transfer time per call.
    pub fn per_call_transfer(&self) -> Duration {
        if self.calls == 0 {
            Duration::ZERO
        } else {
            self.transfer_time / self.calls as u32
        }
    }
}

/// Opaque reference to a device-resident tensor. Cheap to clone; freeing
/// is explicit via [`Backend::free`] (handles are plain ids, not RAII —
/// they must stay movable across the C-ABI-ish trait boundary).
#[derive(Debug, Clone)]
pub struct TensorHandle {
    /// Backend-assigned id (unique per live tensor).
    pub id: u64,
    /// Shape of the referenced tensor.
    pub shape: Vec<usize>,
    /// Element dtype of the referenced tensor.
    pub dtype: Dtype,
}

impl TensorHandle {
    /// Element count implied by the shape.
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    /// Payload bytes (both ABI dtypes are 4 bytes/element).
    pub fn byte_len(&self) -> usize {
        self.elements() * 4
    }
}

/// Shared handle-store implementation for backends whose "device" memory
/// is a host-side map (reference, PJRT-CPU). Payloads are `Arc`ed so
/// handle lookups clone the Arc, not the tensor data — a step's
/// full-state input fetch is O(n_tensors) under the lock.
pub(crate) struct HandleStore {
    store: Mutex<HashMap<u64, Arc<Tensor>>>,
    next_id: AtomicU64,
}

impl HandleStore {
    /// Empty store; ids start at 1.
    pub fn new() -> HandleStore {
        HandleStore { store: Mutex::new(HashMap::new()), next_id: AtomicU64::new(1) }
    }

    /// Take ownership of a tensor; returns its handle.
    pub fn insert(&self, t: Tensor) -> TensorHandle {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let h = TensorHandle { id, shape: t.shape().to_vec(), dtype: t.dtype() };
        self.store.lock().expect("store lock").insert(id, Arc::new(t));
        h
    }

    /// Clone the Arcs (not payloads) for a batch of handles under one
    /// lock acquisition; errors name the artifact for context.
    pub fn fetch(&self, handles: &[TensorHandle], artifact: &str) -> Result<Vec<Arc<Tensor>>> {
        let store = self.store.lock().expect("store lock");
        let mut v = Vec::with_capacity(handles.len());
        for h in handles {
            v.push(
                store
                    .get(&h.id)
                    .cloned()
                    .ok_or_else(|| err!("dangling tensor handle {} for '{artifact}'", h.id))?,
            );
        }
        Ok(v)
    }

    /// Deep-copy a tensor out (the host-transfer boundary).
    pub fn get(&self, h: &TensorHandle) -> Result<Tensor> {
        self.store
            .lock()
            .expect("store lock")
            .get(&h.id)
            .map(|t| t.as_ref().clone())
            .ok_or_else(|| err!("dangling tensor handle {}", h.id))
    }

    /// Drop a tensor (no-op for unknown handles).
    pub fn remove(&self, h: &TensorHandle) {
        self.store.lock().expect("store lock").remove(&h.id);
    }
}

/// Backend-agnostic execution API. Object-safe; call sites hold
/// `&dyn Backend`.
pub trait Backend: Send + Sync {
    /// Human-readable platform name ("reference", "cpu", ...).
    fn platform(&self) -> String;

    /// The artifact catalogue this backend can execute.
    fn manifest(&self) -> &Manifest;

    /// Resolve the artifact of `kind` for a model config. The default uses
    /// the static manifest; the reference backend synthesizes metadata on
    /// demand for any valid config.
    fn resolve(&self, kind: &str, cfg: &ModelConfig) -> Result<ArtifactMeta> {
        self.manifest()
            .find_for(kind, cfg)
            .cloned()
            .ok_or_else(|| err!("no {kind} artifact for config {}", cfg.name()))
    }

    /// Copy a host tensor to the device; returns a device-resident handle.
    fn upload(&self, t: &Tensor) -> Result<TensorHandle>;

    /// Execute an artifact over device-resident inputs. Outputs stay on
    /// the device. Implementations check input arity against the manifest.
    fn execute(&self, name: &str, inputs: &[TensorHandle]) -> Result<Vec<TensorHandle>>;

    /// Transfer one device tensor back to the host.
    fn download(&self, h: &TensorHandle) -> Result<Tensor>;

    /// Release a device tensor. Freeing an unknown handle is a no-op.
    fn free(&self, h: &TensorHandle);

    /// Warm the compile cache (e.g. before timing).
    fn precompile(&self, _name: &str) -> Result<()> {
        Ok(())
    }

    /// Per-artifact execution statistics, if the artifact has run.
    fn stats(&self, name: &str) -> Option<ExecStats>;

    /// Host-level convenience: upload inputs, execute, download every
    /// output, free all intermediates. This is the *full-transfer* path —
    /// step loops should use [`crate::runtime::Session`] instead.
    fn run(&self, name: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let mut handles = Vec::with_capacity(inputs.len());
        for t in inputs {
            handles.push(self.upload(t)?);
        }
        let result = self.execute(name, &handles);
        for h in &handles {
            self.free(h);
        }
        let outs = result?;
        let mut host = Vec::with_capacity(outs.len());
        let mut first_err = None;
        for h in &outs {
            match self.download(h) {
                Ok(t) => host.push(t),
                Err(e) => {
                    first_err.get_or_insert(e);
                }
            }
            self.free(h);
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(host),
        }
    }
}
