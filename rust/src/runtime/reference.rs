//! Reference backend: a pure-Rust interpreter for small µS/SP configs.
//!
//! Exists so the full L3 stack — trainer, session, sweeps, DDP, eval,
//! checkpoints, benches, examples — runs *without AOT artifacts* (fresh
//! clone, offline, no Python). Since the op-level block refactor it
//! executes the paper's actual model shape: a decoder-only transformer
//! whose blocks run RMS-norm → qkv → RoPE → multi-head causal attention
//! → attn-out → scaled residual → RMS-norm → ffn-up → activation →
//! ffn-down → scaled residual, with µS using Res-Post norms and SP
//! Pre norms (see [`super::block`]). What it shares with the AOT path:
//!
//!  - the artifact ABI (`init` / `train_step` / `fwd` tensor lists, state
//!    layout `params ++ momenta`, trailing `loss, gnorm` outputs);
//!  - µS numerics via [`crate::fp8`]: the four hidden linears per block
//!    (qkv, attn-out, ffn-up, ffn-down — paper Tables 1-2) run static
//!    clip-then-cast E4M3 forward / E5M2 backward; the SP+FP8 variant
//!    uses TE-style dynamic per-tensor scaling; attention operands are
//!    BF16-rounded (score/softmax/value arithmetic in f32 — never FP8),
//!    and the embedding, norms, and LM head stay BF16;
//!  - scaling rules: init std, per-op output multipliers, LR/weight-decay
//!    transfer — all consumed from [`crate::scaling::Scheme`] (this file
//!    derives none of them);
//!  - the fixed(τ) / running-mean / standard residual schemes (Eq. 10/11)
//!    applied per branch (2·depth branches);
//!  - Lion with fully decoupled weight decay (App. A.3), norm gains
//!    excluded from decay.
//!
//! Performance: positions within a sequence couple through attention, so
//! the interpreter runs full `[batch·seq, d]` activation matrices through
//! cache-blocked deterministic f32 GEMMs ([`crate::runtime::gemm`]) and
//! parallelizes attention over (batch, head) pairs; activation casts use
//! the bit-twiddling [`crate::fp8::FastCast`]; per-step buffers live in
//! one preallocated [`super::block::Workspace`]; per-step invariants
//! (plan, coefficients, RoPE tables) are resolved once per call into a
//! [`super::block::Prepared`].
//!
//! Determinism: arithmetic is bit-identical for **any** worker-thread
//! count. Chunk boundaries are fixed (never a function of thread count),
//! GEMM and attention accumulation orders are fixed by the kernels, and
//! reductions fold fixed chunks in ascending order
//! ([`crate::util::parallel`]) — tested at trainer level for both FP8
//! lanes across 1/2/4 threads.
//!
//! Telemetry: `execute` interprets on the **calling** thread, so a
//! [`crate::telemetry::capture`] installed around a `Session::step`
//! observes the whole step — per-op RMS and FP8 cast health from the
//! block pipeline's hooks. With no capture active the hooks are inert
//! flag checks and the step path is exactly the uninstrumented one.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use super::backend::{Backend, ExecStats, HandleStore, TensorHandle};
use super::block::{self, Prepared, ELEM_CHUNK};
use super::manifest::{ArtifactMeta, Dtype, Manifest, TensorSpec};
use super::state::{self, StatePrecision};
use super::tensor::Tensor;
use crate::config::ModelConfig;
use crate::fp8::{BF16, E4M3};
use crate::telemetry;
use crate::util::error::{Error, Result};
use crate::util::parallel;
use crate::{bail, err};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Kind {
    Init,
    TrainStep,
    /// `train_step` with quantize-on-write FP8 optimizer state: same ABI
    /// (and the same f32-computed Lion update), but the output masters
    /// land on the BF16 grid and the output momenta on per-tensor
    /// E4M3×2^k grids ([`super::state`]).
    TrainStepFp8State,
    Fwd,
}

impl Kind {
    fn parse(kind: &str) -> Result<Kind> {
        match kind {
            "init" => Ok(Kind::Init),
            "train_step" => Ok(Kind::TrainStep),
            "train_step_fp8state" => Ok(Kind::TrainStepFp8State),
            "fwd" => Ok(Kind::Fwd),
            other => Err(err!("reference backend has no '{other}' artifacts")),
        }
    }

    fn as_str(self) -> &'static str {
        match self {
            Kind::Init => "init",
            Kind::TrainStep => "train_step",
            Kind::TrainStepFp8State => "train_step_fp8state",
            Kind::Fwd => "fwd",
        }
    }

    fn name_for(self, cfg: &ModelConfig) -> String {
        let prefix = match self {
            Kind::Init => "init",
            Kind::TrainStep => "train",
            Kind::TrainStepFp8State => "train8s",
            Kind::Fwd => "fwd",
        };
        format!("{}_{}", prefix, cfg.name())
    }
}

/// Pure-Rust execution backend. Thread-safe: the tensor store and stats
/// are mutex-guarded; the interpreter itself runs outside any lock so
/// sweep workers execute concurrently.
pub struct ReferenceBackend {
    manifest: Manifest,
    registry: Mutex<HashMap<String, (Kind, ModelConfig)>>,
    store: HandleStore,
    stats: Mutex<HashMap<String, ExecStats>>,
}

impl ReferenceBackend {
    /// Backend pre-registered for the given configs (any further valid
    /// config still resolves dynamically via [`Backend::resolve`]).
    pub fn new(configs: &[ModelConfig]) -> Result<ReferenceBackend> {
        let mut artifacts = Vec::new();
        let mut registry = HashMap::new();
        for cfg in configs {
            cfg.validate().map_err(Error::msg)?;
            for kind in [Kind::Init, Kind::TrainStep, Kind::TrainStepFp8State, Kind::Fwd] {
                let meta = meta_for(kind, cfg);
                registry.insert(meta.name.clone(), (kind, cfg.clone()));
                artifacts.push(meta);
            }
        }
        Ok(ReferenceBackend {
            manifest: Manifest { dir: PathBuf::from("(reference)"), artifacts },
            registry: Mutex::new(registry),
            store: HandleStore::new(),
            stats: Mutex::new(HashMap::new()),
        })
    }

    /// Backend covering the repo's standard proxy roster (CLI / examples).
    pub fn with_standard_roster() -> ReferenceBackend {
        ReferenceBackend::new(&standard_roster()).expect("roster configs are valid")
    }

    fn lookup(&self, name: &str) -> Result<(Kind, ModelConfig)> {
        self.registry
            .lock()
            .expect("registry lock")
            .get(name)
            .cloned()
            .ok_or_else(|| err!("artifact '{name}' not registered with the reference backend"))
    }
}

impl Backend for ReferenceBackend {
    fn platform(&self) -> String {
        "reference".to_string()
    }

    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn resolve(&self, kind: &str, cfg: &ModelConfig) -> Result<ArtifactMeta> {
        let k = Kind::parse(kind)?;
        cfg.validate().map_err(Error::msg)?;
        let meta = meta_for(k, cfg);
        self.registry
            .lock()
            .expect("registry lock")
            .insert(meta.name.clone(), (k, cfg.clone()));
        Ok(meta)
    }

    fn upload(&self, t: &Tensor) -> Result<TensorHandle> {
        Ok(self.store.insert(t.clone()))
    }

    fn execute(&self, name: &str, inputs: &[TensorHandle]) -> Result<Vec<TensorHandle>> {
        let (kind, cfg) = self.lookup(name)?;
        let expected = input_arity(kind, &cfg);
        if inputs.len() != expected {
            bail!("artifact '{name}' expects {expected} inputs, got {}", inputs.len());
        }
        // clone Arcs (not payloads) under the lock; interpret outside it
        let host: Vec<Arc<Tensor>> = self.store.fetch(inputs, name)?;
        let t0 = Instant::now();
        let outs = match kind {
            Kind::Init => run_init(&cfg, &host)?,
            Kind::TrainStep => run_train_step(&cfg, &host, StatePrecision::F32)?,
            Kind::TrainStepFp8State => run_train_step(&cfg, &host, StatePrecision::Fp8)?,
            Kind::Fwd => run_fwd(&cfg, &host)?,
        };
        let dt = t0.elapsed();
        let handles: Vec<TensorHandle> = outs.into_iter().map(|t| self.store.insert(t)).collect();
        {
            let mut stats = self.stats.lock().expect("stats lock");
            let s = stats.entry(name.to_string()).or_default();
            s.calls += 1;
            s.execute_time += dt;
        }
        Ok(handles)
    }

    fn download(&self, h: &TensorHandle) -> Result<Tensor> {
        self.store.get(h)
    }

    fn free(&self, h: &TensorHandle) {
        self.store.remove(h);
    }

    fn stats(&self, name: &str) -> Option<ExecStats> {
        self.stats.lock().expect("stats lock").get(name).cloned()
    }
}

/// Configs pre-registered by [`ReferenceBackend::with_standard_roster`]:
/// the repro proxy family, the e2e shape, and the micro test config.
pub fn standard_roster() -> Vec<ModelConfig> {
    let mut out = Vec::new();
    for (w, d) in [(32usize, 4usize), (64, 4), (128, 6), (256, 8), (64, 24)] {
        for (variant, precision) in [("mus", "fp8"), ("mus", "bf16"), ("sp", "bf16"), ("sp", "fp8")]
        {
            let residual = if variant == "mus" { "fixed" } else { "standard" };
            out.push(ModelConfig {
                width: w,
                depth: d,
                variant: variant.into(),
                precision: precision.into(),
                residual: residual.into(),
                ..ModelConfig::default()
            });
        }
    }
    for precision in ["fp8", "bf16"] {
        out.push(ModelConfig {
            width: 384,
            depth: 6,
            head_dim: 64,
            vocab: 2048,
            seq_len: 256,
            batch: 8,
            precision: precision.into(),
            ..ModelConfig::default()
        });
    }
    out.push(micro_config());
    out
}

/// Tiny config for fast CPU tests (fits a debug-build test budget):
/// depth 2, two attention heads.
pub fn micro_config() -> ModelConfig {
    ModelConfig {
        width: 16,
        depth: 2,
        head_dim: 8,
        vocab: 64,
        seq_len: 16,
        batch: 2,
        ..ModelConfig::default()
    }
}

// ---------------------------------------------------------------------------
// ABI metadata

fn n_param_tensors(cfg: &ModelConfig) -> usize {
    block::n_param_tensors(cfg)
}

fn input_arity(kind: Kind, cfg: &ModelConfig) -> usize {
    let n = n_param_tensors(cfg);
    match kind {
        Kind::Init => 1,
        Kind::TrainStep | Kind::TrainStepFp8State => 2 * n + 4,
        Kind::Fwd => n + 2,
    }
}

fn meta_for(kind: Kind, cfg: &ModelConfig) -> ArtifactMeta {
    let params = block::param_specs(cfg);
    let momenta: Vec<TensorSpec> = params
        .iter()
        .map(|s| TensorSpec { name: format!("m_{}", s.name), shape: s.shape.clone(), dtype: s.dtype })
        .collect();
    let tokens = TensorSpec {
        name: "tokens".into(),
        shape: vec![cfg.batch, cfg.seq_len],
        dtype: Dtype::I32,
    };
    let scalar = |name: &str| TensorSpec { name: name.into(), shape: vec![], dtype: Dtype::F32 };
    let (inputs, outputs) = match kind {
        Kind::Init => {
            let seed = TensorSpec { name: "seed".into(), shape: vec![], dtype: Dtype::I32 };
            let mut outs = params.clone();
            outs.extend(momenta);
            (vec![seed], outs)
        }
        Kind::TrainStep | Kind::TrainStepFp8State => {
            let mut ins = params.clone();
            ins.extend(momenta.clone());
            ins.push(tokens);
            ins.extend([scalar("lr"), scalar("wd"), scalar("tau")]);
            let mut outs = params.clone();
            outs.extend(momenta);
            outs.extend([scalar("loss"), scalar("gnorm")]);
            (ins, outs)
        }
        Kind::Fwd => {
            let mut ins = params.clone();
            ins.push(tokens);
            ins.push(scalar("tau"));
            let logits = TensorSpec {
                name: "logits".into(),
                shape: vec![cfg.batch, cfg.seq_len, cfg.vocab],
                dtype: Dtype::F32,
            };
            (ins, vec![logits])
        }
    };
    ArtifactMeta {
        name: kind.name_for(cfg),
        kind: kind.as_str().to_string(),
        file: String::new(),
        config: Some(cfg.clone()),
        inputs,
        outputs,
    }
}

// ---------------------------------------------------------------------------
// Interpreter entry points

fn sign(x: f32) -> f32 {
    if x > 0.0 {
        1.0
    } else if x < 0.0 {
        -1.0
    } else {
        0.0
    }
}

fn run_init(cfg: &ModelConfig, inputs: &[Arc<Tensor>]) -> Result<Vec<Tensor>> {
    // Same boundary check as Prepared::new: init and step must agree on
    // which configs are legal (scheme() would otherwise silently default
    // an unknown variant to the SP family).
    cfg.validate().map_err(Error::msg)?;
    let seed = inputs[0].scalar_i32_value()?;
    let specs = block::param_specs(cfg);
    let params = block::init_params(cfg, seed);
    let mut outs = Vec::with_capacity(2 * specs.len());
    for (data, spec) in params.into_iter().zip(&specs) {
        outs.push(Tensor::f32(data, &spec.shape)?);
    }
    for spec in &specs {
        outs.push(Tensor::zeros_f32(&spec.shape));
    }
    Ok(outs)
}

struct StateView {
    params: Vec<Vec<f32>>,
    momenta: Vec<Vec<f32>>,
    tokens: Vec<i32>,
}

fn unpack_state(cfg: &ModelConfig, inputs: &[Arc<Tensor>], with_momenta: bool) -> Result<StateView> {
    let n = n_param_tensors(cfg);
    let specs = block::param_specs(cfg);
    let mut params = Vec::with_capacity(n);
    for (i, spec) in specs.iter().enumerate() {
        let t = &inputs[i];
        if t.shape() != spec.shape.as_slice() {
            bail!("param tensor '{}' (input {}) has shape {:?}, expected {:?}",
                spec.name, i, t.shape(), spec.shape);
        }
        params.push(t.to_f32_vec()?);
    }
    let mut momenta = Vec::new();
    let tok_idx = if with_momenta {
        for (i, spec) in specs.iter().enumerate() {
            let t = &inputs[n + i];
            if t.shape() != spec.shape.as_slice() {
                bail!("momentum tensor 'm_{}' (input {}) has shape {:?}, expected {:?}",
                    spec.name, n + i, t.shape(), spec.shape);
            }
            momenta.push(t.to_f32_vec()?);
        }
        2 * n
    } else {
        n
    };
    let tokens = inputs[tok_idx].as_i32()?.to_vec();
    if tokens.len() != cfg.batch * cfg.seq_len {
        bail!("tokens length {} != batch*seq = {}", tokens.len(), cfg.batch * cfg.seq_len);
    }
    block::check_tokens(&tokens, cfg.vocab)?;
    Ok(StateView { params, momenta, tokens })
}

fn run_train_step(
    cfg: &ModelConfig,
    inputs: &[Arc<Tensor>],
    precision: StatePrecision,
) -> Result<Vec<Tensor>> {
    let n = n_param_tensors(cfg);
    let mut sv = unpack_state(cfg, inputs, true)?;
    let lr = inputs[2 * n + 1].scalar()?;
    let wd = inputs[2 * n + 2].scalar()?;
    let tau = inputs[2 * n + 3].scalar()?;

    // per-step invariants resolved once (coefficients, plan, activation,
    // RoPE tables, output multipliers)
    let prep = Prepared::new(cfg, tau)?;
    let (grads, loss, gnorm) = block::train_grads(cfg, &prep, &sv.params, &sv.tokens)?;

    // Lion with fully decoupled weight decay (ref.py lion_update):
    //   c = β1·m + (1-β1)·g;  p' = p - lr·sign(c) - wd·p;  m' = β2·m + (1-β2)·g
    // Per-tensor lr/wd multipliers come from the Scheme transfer rules
    // (µS: √(d_base/d) on hidden; SP: d_base/d on all; norm gains do not
    // decay).
    const B1: f32 = 0.9;
    const B2: f32 = 0.99;
    let scheme = cfg.scheme();
    for i in 0..n {
        let kind = block::param_kind(block::role_of(cfg, i));
        let lr_eff = lr * scheme.lr_transfer(kind, cfg.d_base, cfg.width) as f32;
        let wd_eff = wd * scheme.wd_mult(kind) as f32;
        let g = &grads[i];
        let threads = parallel::threads_for(g.len() as u64 * 6);
        parallel::par_join2(
            &mut sv.params[i],
            &mut sv.momenta[i],
            ELEM_CHUNK,
            ELEM_CHUNK,
            threads,
            |ci, p, m| {
                let off = ci * ELEM_CHUNK;
                for j in 0..p.len() {
                    let gj = g[off + j];
                    let c = B1 * m[j] + (1.0 - B1) * gj;
                    p[j] = p[j] - lr_eff * sign(c) - wd_eff * p[j];
                    m[j] = B2 * m[j] + (1.0 - B2) * gj;
                }
            },
        );
    }

    // FP8 state: quantize-on-write. The update above READS grid values
    // (under this policy the incoming state is already on-grid — f32
    // storage IS the dequantized form, no shadow copy) and computes in
    // f32; here each output tensor is rounded back onto its grid: masters
    // RNE onto BF16, momenta RNE onto E4M3×2^k with the per-tensor
    // power-of-two scale chosen so the cast can never saturate
    // ([`state::momentum_scale_exp`]). Cast health is recorded per tensor
    // (read-only, pre-quantize) when a telemetry capture is active; the
    // snap loops are element-wise with no accumulation, so the step stays
    // bit-identical at any thread count.
    if precision == StatePrecision::Fp8 {
        for i in 0..n {
            if telemetry::enabled() {
                telemetry::record_cast(
                    "state_master",
                    i,
                    "bf16",
                    BF16.cast_health(&sv.params[i], 1.0),
                );
                let k = state::momentum_scale(&sv.momenta[i]);
                telemetry::record_cast(
                    "state_mom",
                    i,
                    "e4m3",
                    E4M3.cast_health(&sv.momenta[i], state::pow2(-k)),
                );
            }
            state::snap_master(&mut sv.params[i]);
            state::snap_momentum(&mut sv.momenta[i]);
        }
    }

    let specs = block::param_specs(cfg);
    let mut outs = Vec::with_capacity(2 * n + 2);
    for (i, spec) in specs.iter().enumerate() {
        outs.push(Tensor::f32(std::mem::take(&mut sv.params[i]), &spec.shape)?);
    }
    for (i, spec) in specs.iter().enumerate() {
        outs.push(Tensor::f32(std::mem::take(&mut sv.momenta[i]), &spec.shape)?);
    }
    outs.push(Tensor::scalar_f32(loss));
    outs.push(Tensor::scalar_f32(gnorm));
    Ok(outs)
}

fn run_fwd(cfg: &ModelConfig, inputs: &[Arc<Tensor>]) -> Result<Vec<Tensor>> {
    let n = n_param_tensors(cfg);
    let sv = unpack_state(cfg, inputs, false)?;
    let tau = inputs[n + 1].scalar()?;
    let prep = Prepared::new(cfg, tau)?;
    let logits = block::forward_logits(cfg, &prep, &sv.params, &sv.tokens)?;
    Ok(vec![Tensor::f32(logits, &[cfg.batch, cfg.seq_len, cfg.vocab])?])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::backend::Backend;

    fn micro_backend() -> ReferenceBackend {
        ReferenceBackend::new(&[micro_config()]).unwrap()
    }

    fn init_state(be: &ReferenceBackend, cfg: &ModelConfig, seed: i32) -> Vec<Tensor> {
        let name = Kind::Init.name_for(cfg);
        be.run(&name, &[Tensor::scalar_i32(seed)]).unwrap()
    }

    #[test]
    fn init_is_deterministic_and_unit_variance() {
        let be = micro_backend();
        let cfg = micro_config();
        let a = init_state(&be, &cfg, 7);
        let b = init_state(&be, &cfg, 7);
        assert_eq!(a.len(), 2 * n_param_tensors(&cfg));
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x, y);
        }
        let c = init_state(&be, &cfg, 8);
        assert_ne!(a[0], c[0]);
        // µS init: unit variance embedding
        let e = a[0].as_f32().unwrap();
        let var = e.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>() / e.len() as f64;
        assert!((var - 1.0).abs() < 0.15, "embed var {var}");
        // norm gains start at exactly 1
        let g1 = a[block::idx_g1(0)].as_f32().unwrap();
        assert!(g1.iter().all(|&v| v == 1.0));
        // momenta zero
        let m = a[n_param_tensors(&cfg)].as_f32().unwrap();
        assert!(m.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn train_step_abi_and_loss_near_ln_vocab() {
        let be = micro_backend();
        let cfg = micro_config();
        let state = init_state(&be, &cfg, 0);
        let n = n_param_tensors(&cfg);
        let mut inputs = state;
        let tokens: Vec<i32> = (0..cfg.batch * cfg.seq_len).map(|i| (i % cfg.vocab) as i32).collect();
        inputs.push(Tensor::i32(tokens, &[cfg.batch, cfg.seq_len]).unwrap());
        inputs.push(Tensor::scalar_f32(0.01));
        inputs.push(Tensor::scalar_f32(1e-4));
        inputs.push(Tensor::scalar_f32(0.4));
        let outs = be.run(&Kind::TrainStep.name_for(&cfg), &inputs).unwrap();
        assert_eq!(outs.len(), 2 * n + 2);
        let loss = outs[2 * n].scalar().unwrap();
        let gnorm = outs[2 * n + 1].scalar().unwrap();
        let ln_v = (cfg.vocab as f32).ln();
        assert!((loss - ln_v).abs() < 0.8, "init loss {loss}, ln|V| {ln_v}");
        assert!(gnorm.is_finite() && gnorm > 0.0);
    }

    #[test]
    fn repeated_steps_reduce_loss_on_fixed_batch() {
        let be = micro_backend();
        let cfg = micro_config();
        let n = n_param_tensors(&cfg);
        let mut state = init_state(&be, &cfg, 1);
        // a learnable fixed batch: strict bigram cycle
        let tokens: Vec<i32> =
            (0..cfg.batch * cfg.seq_len).map(|i| ((i * 3) % cfg.vocab) as i32).collect();
        let tok = Tensor::i32(tokens, &[cfg.batch, cfg.seq_len]).unwrap();
        let mut first = None;
        let mut last = 0f32;
        for _ in 0..60 {
            let mut inputs = state.clone();
            inputs.push(tok.clone());
            inputs.push(Tensor::scalar_f32(0.01));
            inputs.push(Tensor::scalar_f32(0.0));
            inputs.push(Tensor::scalar_f32(0.4));
            let mut outs = be.run(&Kind::TrainStep.name_for(&cfg), &inputs).unwrap();
            last = outs[2 * n].scalar().unwrap();
            assert!(last.is_finite());
            first.get_or_insert(last);
            outs.truncate(2 * n);
            state = outs;
        }
        let first = first.unwrap();
        assert!(last < first - 0.02, "no learning: {first} -> {last}");
    }

    #[test]
    fn fwd_logits_shape_and_finiteness() {
        let be = micro_backend();
        let cfg = micro_config();
        let state = init_state(&be, &cfg, 2);
        let n = n_param_tensors(&cfg);
        let mut inputs: Vec<Tensor> = state[..n].to_vec();
        let tokens: Vec<i32> = vec![1; cfg.batch * cfg.seq_len];
        inputs.push(Tensor::i32(tokens, &[cfg.batch, cfg.seq_len]).unwrap());
        inputs.push(Tensor::scalar_f32(0.4));
        let outs = be.run(&Kind::Fwd.name_for(&cfg), &inputs).unwrap();
        assert_eq!(outs.len(), 1);
        assert_eq!(outs[0].shape(), &[cfg.batch, cfg.seq_len, cfg.vocab]);
        assert!(outs[0].as_f32().unwrap().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn execute_checks_arity_and_registration() {
        let be = micro_backend();
        let cfg = micro_config();
        let err = be.run(&Kind::TrainStep.name_for(&cfg), &[Tensor::scalar_f32(1.0)]);
        assert!(err.unwrap_err().to_string().contains("expects"));
        assert!(be.run("train_nonexistent", &[]).is_err());
        // resolve() registers previously-unknown valid configs dynamically
        let cfg2 = ModelConfig { width: 32, depth: 2, ..micro_config() };
        assert!(be.manifest().find_for("train_step", &cfg2).is_none());
        let meta = be.resolve("train_step", &cfg2).unwrap();
        assert_eq!(meta.inputs.len(), 2 * n_param_tensors(&cfg2) + 4);
    }

    /// Drive `steps` train steps on a fixed learnable batch (a strict
    /// bigram cycle) through the given train-step artifact kind; returns
    /// the per-step losses and the final `params ++ momenta` state.
    fn run_lane_kind(
        cfg: &ModelConfig,
        steps: usize,
        lr: f32,
        kind: Kind,
    ) -> (Vec<f32>, Vec<Tensor>) {
        let be = ReferenceBackend::new(&[cfg.clone()]).unwrap();
        let n = n_param_tensors(cfg);
        let mut state = init_state(&be, cfg, 1);
        let tokens: Vec<i32> =
            (0..cfg.batch * cfg.seq_len).map(|i| ((i * 3) % cfg.vocab) as i32).collect();
        let tok = Tensor::i32(tokens, &[cfg.batch, cfg.seq_len]).unwrap();
        let mut losses = Vec::with_capacity(steps);
        for _ in 0..steps {
            let mut inputs = state.clone();
            inputs.push(tok.clone());
            inputs.push(Tensor::scalar_f32(lr));
            inputs.push(Tensor::scalar_f32(0.0));
            inputs.push(Tensor::scalar_f32(0.4));
            let mut outs = be.run(&kind.name_for(cfg), &inputs).unwrap();
            losses.push(outs[2 * n].scalar().unwrap());
            outs.truncate(2 * n);
            state = outs;
        }
        (losses, state)
    }

    fn run_lane(cfg: &ModelConfig, steps: usize, lr: f32) -> Vec<f32> {
        run_lane_kind(cfg, steps, lr, Kind::TrainStep).0
    }

    /// loss-decreases + bit-determinism assertions shared by the
    /// always-run precision-lane tests: the micro lane (depth 2, two
    /// heads) must learn, and must produce bit-identical losses at 1, 2,
    /// and 4 worker threads. Sign descent can oscillate near the optimum,
    /// so the "decreased" check uses the tail minimum.
    fn assert_lane_learns_deterministically(cfg: &ModelConfig, lr: f32, kind: Kind, lane: &str) {
        assert!(cfg.depth >= 2 && cfg.n_heads() >= 2, "{lane}: lane config too small");
        let a = parallel::with_max_threads(1, || run_lane_kind(cfg, 60, lr, kind).0);
        assert!(a.iter().all(|l| l.is_finite()), "{lane}: non-finite loss: {a:?}");
        let tail_min = a[50..].iter().copied().fold(f32::INFINITY, f32::min);
        assert!(tail_min < a[0] - 0.01, "{lane}: no learning: {} -> {tail_min}", a[0]);
        for threads in [2usize, 4] {
            let b = parallel::with_max_threads(threads, || run_lane_kind(cfg, 60, lr, kind).0);
            assert_eq!(a, b, "{lane}: {threads}-thread run is not bit-identical to 1-thread");
        }
    }

    fn mus_fp8_cfg() -> ModelConfig {
        ModelConfig {
            variant: "mus".into(),
            precision: "fp8".into(),
            residual: "fixed".into(),
            ..micro_config()
        }
    }

    fn sp_fp8_cfg() -> ModelConfig {
        ModelConfig {
            variant: "sp".into(),
            precision: "fp8".into(),
            residual: "standard".into(),
            ..micro_config()
        }
    }

    #[test]
    fn mus_fp8_static_lane_learns_and_is_bit_deterministic() {
        assert_lane_learns_deterministically(
            &mus_fp8_cfg(),
            0.01,
            Kind::TrainStep,
            "mus+fp8 (static E4M3/E5M2)",
        );
    }

    #[test]
    fn sp_fp8_dynamic_lane_learns_and_is_bit_deterministic() {
        assert_lane_learns_deterministically(
            &sp_fp8_cfg(),
            1.0 / 256.0,
            Kind::TrainStep,
            "sp+fp8 (dynamic)",
        );
    }

    #[test]
    fn fp8_state_lanes_learn_and_are_bit_deterministic() {
        assert_lane_learns_deterministically(
            &mus_fp8_cfg(),
            0.01,
            Kind::TrainStepFp8State,
            "mus+fp8, fp8 state",
        );
        assert_lane_learns_deterministically(
            &sp_fp8_cfg(),
            1.0 / 256.0,
            Kind::TrainStepFp8State,
            "sp+fp8, fp8 state",
        );
    }

    /// Satellite: loss parity + parameter-direction bound between the f32
    /// and FP8 state lanes, on BOTH FP8 compute lanes. Tolerances are the
    /// documented ones (docs/NUMERICS.md §10): |Δ tail-min loss| ≤ 0.25
    /// and params cosine ≥ 0.98 after 60 steps.
    #[test]
    fn fp8_state_tracks_f32_state_on_both_fp8_lanes() {
        for (cfg, lr, lane) in
            [(mus_fp8_cfg(), 0.01f32, "mus+fp8"), (sp_fp8_cfg(), 1.0 / 256.0, "sp+fp8")]
        {
            let n = n_param_tensors(&cfg);
            let (l32, s32) = run_lane_kind(&cfg, 60, lr, Kind::TrainStep);
            let (l8, s8) = run_lane_kind(&cfg, 60, lr, Kind::TrainStepFp8State);
            let t32 = l32[50..].iter().copied().fold(f32::INFINITY, f32::min);
            let t8 = l8[50..].iter().copied().fold(f32::INFINITY, f32::min);
            assert!(
                (t32 - t8).abs() <= 0.25,
                "{lane}: fp8-state loss {t8} vs f32-state {t32} beyond tolerance"
            );
            let (mut dot, mut n32, mut n8) = (0f64, 0f64, 0f64);
            for i in 0..n {
                let a = s32[i].as_f32().unwrap();
                let b = s8[i].as_f32().unwrap();
                for (x, y) in a.iter().zip(b) {
                    dot += *x as f64 * *y as f64;
                    n32 += *x as f64 * *x as f64;
                    n8 += *y as f64 * *y as f64;
                }
            }
            let cos = dot / (n32.sqrt() * n8.sqrt()).max(1e-30);
            assert!(cos >= 0.98, "{lane}: param cosine {cos} < 0.98");
        }
    }

    /// The policy's no-saturation guarantee, witnessed: under a telemetry
    /// capture every per-tensor momentum/master state cast reports health,
    /// and the minimal power-of-two scale keeps `saturated` at exactly 0.
    #[test]
    fn fp8_state_casts_report_health_and_never_saturate() {
        let cfg = mus_fp8_cfg();
        let (_, report) =
            telemetry::capture(|| run_lane_kind(&cfg, 3, 0.01, Kind::TrainStepFp8State));
        let mom = report.cast_totals("state_mom").expect("momentum casts recorded");
        assert!(mom.total > 0);
        assert_eq!(mom.saturated, 0, "momentum cast saturated despite minimal scale");
        assert_eq!(mom.overflow_nonfinite, 0);
        let master = report.cast_totals("state_master").expect("master casts recorded");
        assert!(master.total > 0);
        assert_eq!(master.saturated, 0);
    }

    /// FP8-state outputs are on-grid: re-snapping masters (BF16) and
    /// momenta (E4M3×2^k) is a bit-exact no-op — the invariant the
    /// checkpoint codec and the native momentum wire lean on.
    #[test]
    fn fp8_state_step_outputs_are_on_grid() {
        let cfg = mus_fp8_cfg();
        let n = n_param_tensors(&cfg);
        let (_, state) = run_lane_kind(&cfg, 2, 0.01, Kind::TrainStepFp8State);
        for (i, t) in state.iter().enumerate() {
            let mut data = t.as_f32().unwrap().to_vec();
            if i < n {
                state::snap_master(&mut data);
            } else {
                state::snap_momentum(&mut data);
            }
            let orig = t.as_f32().unwrap();
            let same = data.iter().zip(orig).all(|(a, b)| a.to_bits() == b.to_bits());
            assert!(same, "tensor {i} not on its grid");
        }
    }

    /// Satellite fix: shape mismatches in `unpack_state` name the tensor
    /// from the spec — for the momentum half too, not just `m_{i}`.
    #[test]
    fn unpack_state_errors_name_the_tensor() {
        let be = micro_backend();
        let cfg = micro_config();
        let n = n_param_tensors(&cfg);
        let specs = block::param_specs(&cfg);
        let state = init_state(&be, &cfg, 4);
        let tokens: Vec<i32> = vec![0; cfg.batch * cfg.seq_len];
        let finish = |mut inputs: Vec<Tensor>| {
            inputs.push(Tensor::i32(tokens.clone(), &[cfg.batch, cfg.seq_len]).unwrap());
            inputs.push(Tensor::scalar_f32(0.01));
            inputs.push(Tensor::scalar_f32(0.0));
            inputs.push(Tensor::scalar_f32(0.4));
            inputs
        };
        // momentum half: wrong shape at momentum index 1
        let mut bad = state.clone();
        bad[n + 1] = Tensor::zeros_f32(&[3, 5]);
        let err = be.run(&Kind::TrainStep.name_for(&cfg), &finish(bad)).unwrap_err().to_string();
        assert!(
            err.contains(&format!("momentum tensor 'm_{}'", specs[1].name)),
            "error does not name the momentum tensor: {err}"
        );
        assert!(err.contains("expected"), "no expected shape in: {err}");
        // param half: wrong shape at param index 0
        let mut bad = state.clone();
        bad[0] = Tensor::zeros_f32(&[2, 2]);
        let err = be.run(&Kind::TrainStep.name_for(&cfg), &finish(bad)).unwrap_err().to_string();
        assert!(
            err.contains(&format!("param tensor '{}'", specs[0].name)),
            "error does not name the param tensor: {err}"
        );
    }

    #[test]
    fn batched_interpreter_is_thread_count_invariant() {
        // Big enough that the GEMMs clear the parallel threshold, so the
        // multi-thread path genuinely runs when allowed to.
        let cfg = ModelConfig {
            width: 64,
            depth: 2,
            head_dim: 8,
            vocab: 128,
            seq_len: 32,
            batch: 4,
            ..ModelConfig::default()
        };
        let run = |threads: usize| {
            parallel::with_max_threads(threads, || {
                let be = ReferenceBackend::new(&[cfg.clone()]).unwrap();
                let n = n_param_tensors(&cfg);
                let mut state = init_state(&be, &cfg, 3);
                let tokens: Vec<i32> =
                    (0..cfg.batch * cfg.seq_len).map(|i| ((i * 5) % cfg.vocab) as i32).collect();
                let tok = Tensor::i32(tokens, &[cfg.batch, cfg.seq_len]).unwrap();
                let mut losses = Vec::new();
                for _ in 0..3 {
                    let mut inputs = state.clone();
                    inputs.push(tok.clone());
                    inputs.push(Tensor::scalar_f32(0.01));
                    inputs.push(Tensor::scalar_f32(1e-4));
                    inputs.push(Tensor::scalar_f32(0.4));
                    let mut outs = be.run(&Kind::TrainStep.name_for(&cfg), &inputs).unwrap();
                    losses.push(outs[2 * n].scalar().unwrap().to_bits());
                    outs.truncate(2 * n);
                    state = outs;
                }
                let final_state: Vec<Vec<f32>> =
                    state.iter().map(|t| t.as_f32().unwrap().to_vec()).collect();
                (losses, final_state)
            })
        };
        let (l1, s1) = run(1);
        for threads in [2usize, 4] {
            let (lt, st) = run(threads);
            assert_eq!(l1, lt, "losses drifted at {threads} threads");
            assert_eq!(s1, st, "state drifted at {threads} threads");
        }
    }

    #[test]
    fn free_releases_store_entries() {
        let be = micro_backend();
        let h = be.upload(&Tensor::scalar_f32(1.0)).unwrap();
        assert_eq!(be.download(&h).unwrap().scalar().unwrap(), 1.0);
        be.free(&h);
        assert!(be.download(&h).is_err());
    }
}
