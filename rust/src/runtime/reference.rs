//! Reference backend: a pure-Rust interpreter for small µS/SP configs.
//!
//! Exists so the full L3 stack — trainer, session, sweeps, DDP, eval,
//! checkpoints, benches, examples — runs *without AOT artifacts* (fresh
//! clone, offline, no Python). It is not the AOT transformer: attention is
//! omitted and the model is a µS-parametrized residual MLP over token
//! embeddings (the synthetic corpus is Markovian, so the bigram structure
//! is genuinely learnable). What it shares with the AOT path, faithfully:
//!
//!  - the artifact ABI (`init` / `train_step` / `fwd` tensor lists, state
//!    layout `params ++ momenta`, trailing `loss, gnorm` outputs);
//!  - µS numerics via [`crate::fp8`]: static clip-then-cast E4M3 on hidden
//!    forward operands, E5M2 on activation gradients, BF16 elsewhere; the
//!    SP+FP8 variant uses TE-style dynamic per-tensor scaling;
//!  - scaling rules: unit-variance init, 1/√fan_in and 1/fan_in output
//!    multipliers, √(d_base/d) (µS) vs d_base/d (SP) LR transfer;
//!  - the fixed(τ) / running-mean / standard residual schemes (Eq. 10/11);
//!  - Lion with fully decoupled weight decay (App. A.3).
//!
//! Determinism: everything is sequential f32/f64 arithmetic seeded from
//! the init seed, so thread-parallel sweep workers produce bit-identical
//! results to the sequential path.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use super::backend::{Backend, ExecStats, HandleStore, TensorHandle};
use super::manifest::{ArtifactMeta, Dtype, Manifest, TensorSpec};
use super::tensor::Tensor;
use crate::config::ModelConfig;
use crate::fp8::{Format, BF16, E4M3, E5M2};
use crate::util::error::{Error, Result};
use crate::util::rng::Rng;
use crate::{bail, err};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Kind {
    Init,
    TrainStep,
    Fwd,
}

impl Kind {
    fn parse(kind: &str) -> Result<Kind> {
        match kind {
            "init" => Ok(Kind::Init),
            "train_step" => Ok(Kind::TrainStep),
            "fwd" => Ok(Kind::Fwd),
            other => Err(err!("reference backend has no '{other}' artifacts")),
        }
    }

    fn as_str(self) -> &'static str {
        match self {
            Kind::Init => "init",
            Kind::TrainStep => "train_step",
            Kind::Fwd => "fwd",
        }
    }

    fn name_for(self, cfg: &ModelConfig) -> String {
        let prefix = match self {
            Kind::Init => "init",
            Kind::TrainStep => "train",
            Kind::Fwd => "fwd",
        };
        format!("{}_{}", prefix, cfg.name())
    }
}

/// Pure-Rust execution backend. Thread-safe: the tensor store and stats
/// are mutex-guarded; the interpreter itself runs outside any lock so
/// sweep workers execute concurrently.
pub struct ReferenceBackend {
    manifest: Manifest,
    registry: Mutex<HashMap<String, (Kind, ModelConfig)>>,
    store: HandleStore,
    stats: Mutex<HashMap<String, ExecStats>>,
}

impl ReferenceBackend {
    /// Backend pre-registered for the given configs (any further valid
    /// config still resolves dynamically via [`Backend::resolve`]).
    pub fn new(configs: &[ModelConfig]) -> Result<ReferenceBackend> {
        let mut artifacts = Vec::new();
        let mut registry = HashMap::new();
        for cfg in configs {
            cfg.validate().map_err(Error::msg)?;
            for kind in [Kind::Init, Kind::TrainStep, Kind::Fwd] {
                let meta = meta_for(kind, cfg);
                registry.insert(meta.name.clone(), (kind, cfg.clone()));
                artifacts.push(meta);
            }
        }
        Ok(ReferenceBackend {
            manifest: Manifest { dir: PathBuf::from("(reference)"), artifacts },
            registry: Mutex::new(registry),
            store: HandleStore::new(),
            stats: Mutex::new(HashMap::new()),
        })
    }

    /// Backend covering the repo's standard proxy roster (CLI / examples).
    pub fn with_standard_roster() -> ReferenceBackend {
        ReferenceBackend::new(&standard_roster()).expect("roster configs are valid")
    }

    fn lookup(&self, name: &str) -> Result<(Kind, ModelConfig)> {
        self.registry
            .lock()
            .expect("registry lock")
            .get(name)
            .cloned()
            .ok_or_else(|| err!("artifact '{name}' not registered with the reference backend"))
    }
}

impl Backend for ReferenceBackend {
    fn platform(&self) -> String {
        "reference".to_string()
    }

    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn resolve(&self, kind: &str, cfg: &ModelConfig) -> Result<ArtifactMeta> {
        let k = Kind::parse(kind)?;
        cfg.validate().map_err(Error::msg)?;
        let meta = meta_for(k, cfg);
        self.registry
            .lock()
            .expect("registry lock")
            .insert(meta.name.clone(), (k, cfg.clone()));
        Ok(meta)
    }

    fn upload(&self, t: &Tensor) -> Result<TensorHandle> {
        Ok(self.store.insert(t.clone()))
    }

    fn execute(&self, name: &str, inputs: &[TensorHandle]) -> Result<Vec<TensorHandle>> {
        let (kind, cfg) = self.lookup(name)?;
        let expected = input_arity(kind, &cfg);
        if inputs.len() != expected {
            bail!("artifact '{name}' expects {expected} inputs, got {}", inputs.len());
        }
        // clone Arcs (not payloads) under the lock; interpret outside it
        let host: Vec<Arc<Tensor>> = self.store.fetch(inputs, name)?;
        let t0 = Instant::now();
        let outs = match kind {
            Kind::Init => run_init(&cfg, &host)?,
            Kind::TrainStep => run_train_step(&cfg, &host)?,
            Kind::Fwd => run_fwd(&cfg, &host)?,
        };
        let dt = t0.elapsed();
        let handles: Vec<TensorHandle> = outs.into_iter().map(|t| self.store.insert(t)).collect();
        {
            let mut stats = self.stats.lock().expect("stats lock");
            let s = stats.entry(name.to_string()).or_default();
            s.calls += 1;
            s.execute_time += dt;
        }
        Ok(handles)
    }

    fn download(&self, h: &TensorHandle) -> Result<Tensor> {
        self.store.get(h)
    }

    fn free(&self, h: &TensorHandle) {
        self.store.remove(h);
    }

    fn stats(&self, name: &str) -> Option<ExecStats> {
        self.stats.lock().expect("stats lock").get(name).cloned()
    }
}

/// Configs pre-registered by [`ReferenceBackend::with_standard_roster`]:
/// the repro proxy family, the e2e shape, and the micro test config.
pub fn standard_roster() -> Vec<ModelConfig> {
    let mut out = Vec::new();
    for (w, d) in [(32usize, 4usize), (64, 4), (128, 6), (256, 8), (64, 24)] {
        for (variant, precision) in [("mus", "fp8"), ("mus", "bf16"), ("sp", "bf16"), ("sp", "fp8")]
        {
            let residual = if variant == "mus" { "fixed" } else { "standard" };
            out.push(ModelConfig {
                width: w,
                depth: d,
                variant: variant.into(),
                precision: precision.into(),
                residual: residual.into(),
                ..ModelConfig::default()
            });
        }
    }
    for precision in ["fp8", "bf16"] {
        out.push(ModelConfig {
            width: 384,
            depth: 6,
            head_dim: 64,
            vocab: 2048,
            seq_len: 256,
            batch: 8,
            precision: precision.into(),
            ..ModelConfig::default()
        });
    }
    out.push(micro_config());
    out
}

/// Tiny config for fast CPU tests (fits a debug-build test budget).
pub fn micro_config() -> ModelConfig {
    ModelConfig {
        width: 16,
        depth: 2,
        head_dim: 8,
        vocab: 64,
        seq_len: 16,
        batch: 2,
        ..ModelConfig::default()
    }
}

// ---------------------------------------------------------------------------
// ABI metadata

/// Reference-model parameter tensors, in state order:
/// `embed [V,D]`, `w0..w{L-1} [D,D]`, `head [D,V]`.
fn param_specs(cfg: &ModelConfig) -> Vec<TensorSpec> {
    let (d, v) = (cfg.width, cfg.vocab);
    let mut specs = vec![TensorSpec { name: "embed".into(), shape: vec![v, d], dtype: Dtype::F32 }];
    for l in 0..cfg.depth {
        specs.push(TensorSpec { name: format!("w{l}"), shape: vec![d, d], dtype: Dtype::F32 });
    }
    specs.push(TensorSpec { name: "head".into(), shape: vec![d, v], dtype: Dtype::F32 });
    specs
}

fn n_param_tensors(cfg: &ModelConfig) -> usize {
    cfg.depth + 2
}

fn input_arity(kind: Kind, cfg: &ModelConfig) -> usize {
    let n = n_param_tensors(cfg);
    match kind {
        Kind::Init => 1,
        Kind::TrainStep => 2 * n + 4,
        Kind::Fwd => n + 2,
    }
}

fn meta_for(kind: Kind, cfg: &ModelConfig) -> ArtifactMeta {
    let params = param_specs(cfg);
    let momenta: Vec<TensorSpec> = params
        .iter()
        .map(|s| TensorSpec { name: format!("m_{}", s.name), shape: s.shape.clone(), dtype: s.dtype })
        .collect();
    let tokens = TensorSpec {
        name: "tokens".into(),
        shape: vec![cfg.batch, cfg.seq_len],
        dtype: Dtype::I32,
    };
    let scalar = |name: &str| TensorSpec { name: name.into(), shape: vec![], dtype: Dtype::F32 };
    let (inputs, outputs) = match kind {
        Kind::Init => {
            let seed = TensorSpec { name: "seed".into(), shape: vec![], dtype: Dtype::I32 };
            let mut outs = params.clone();
            outs.extend(momenta);
            (vec![seed], outs)
        }
        Kind::TrainStep => {
            let mut ins = params.clone();
            ins.extend(momenta.clone());
            ins.push(tokens);
            ins.extend([scalar("lr"), scalar("wd"), scalar("tau")]);
            let mut outs = params.clone();
            outs.extend(momenta);
            outs.extend([scalar("loss"), scalar("gnorm")]);
            (ins, outs)
        }
        Kind::Fwd => {
            let mut ins = params.clone();
            ins.push(tokens);
            ins.push(scalar("tau"));
            let logits = TensorSpec {
                name: "logits".into(),
                shape: vec![cfg.batch, cfg.seq_len, cfg.vocab],
                dtype: Dtype::F32,
            };
            (ins, vec![logits])
        }
    };
    ArtifactMeta {
        name: kind.name_for(cfg),
        kind: kind.as_str().to_string(),
        file: String::new(),
        config: Some(cfg.clone()),
        inputs,
        outputs,
    }
}

// ---------------------------------------------------------------------------
// Numerics: quantization modes, activations, residual coefficients

#[derive(Debug, Clone, Copy)]
enum QuantMode {
    /// BF16 round-trip (the "high precision" lane of the artifact graphs).
    Bf16,
    /// µS static scaling: clip to max_finite, then cast.
    StaticFp8(Format),
    /// TE-style dynamic scaling: rescale to the format's range by the
    /// tensor's amax, cast, rescale back (the overhead µS deletes).
    DynamicFp8(Format),
}

fn quantize_slice(xs: &mut [f32], mode: QuantMode) {
    match mode {
        QuantMode::Bf16 => {
            for x in xs.iter_mut() {
                *x = BF16.quantize(*x);
            }
        }
        QuantMode::StaticFp8(f) => {
            for x in xs.iter_mut() {
                *x = f.quantize(*x);
            }
        }
        QuantMode::DynamicFp8(f) => {
            let amax = xs.iter().fold(0f32, |m, x| m.max(x.abs()));
            if amax == 0.0 || !amax.is_finite() {
                return;
            }
            let scale = f.max_finite() as f32 / amax;
            for x in xs.iter_mut() {
                *x = f.quantize(*x * scale) / scale;
            }
        }
    }
}

/// Quantization plan for a (variant, precision) pair.
struct Plan {
    /// Hidden-layer weights & activations (forward).
    hidden: QuantMode,
    /// Activation gradients (backward).
    grad: QuantMode,
}

fn plan_for(cfg: &ModelConfig) -> Plan {
    match (cfg.variant.as_str(), cfg.precision.as_str()) {
        ("mus", "fp8") => Plan { hidden: QuantMode::StaticFp8(E4M3), grad: QuantMode::StaticFp8(E5M2) },
        ("sp", "fp8") => Plan { hidden: QuantMode::DynamicFp8(E4M3), grad: QuantMode::DynamicFp8(E5M2) },
        _ => Plan { hidden: QuantMode::Bf16, grad: QuantMode::Bf16 },
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Act {
    Gelu,
    Silu,
    Relu,
}

impl Act {
    fn parse(name: &str) -> Result<Act> {
        match name {
            "gelu" => Ok(Act::Gelu),
            "silu" => Ok(Act::Silu),
            "relu" => Ok(Act::Relu),
            other => Err(err!("unknown activation '{other}'")),
        }
    }

    #[inline]
    fn apply(self, z: f32) -> f32 {
        match self {
            Act::Gelu => {
                const K: f32 = 0.797_884_56; // sqrt(2/pi)
                let u = K * (z + 0.044715 * z * z * z);
                0.5 * z * (1.0 + u.tanh())
            }
            Act::Silu => z / (1.0 + (-z).exp()),
            Act::Relu => z.max(0.0),
        }
    }

    #[inline]
    fn deriv(self, z: f32) -> f32 {
        match self {
            Act::Gelu => {
                const K: f32 = 0.797_884_56;
                let u = K * (z + 0.044715 * z * z * z);
                let t = u.tanh();
                0.5 * (1.0 + t) + 0.5 * z * (1.0 - t * t) * K * (1.0 + 3.0 * 0.044715 * z * z)
            }
            Act::Silu => {
                let s = 1.0 / (1.0 + (-z).exp());
                s * (1.0 + z * (1.0 - s))
            }
            Act::Relu => {
                if z > 0.0 {
                    1.0
                } else {
                    0.0
                }
            }
        }
    }
}

/// Residual combination weights (a, b): `x' = a*x + b*branch`.
/// fixed (Eq. 10): a = √(1-τ), b = √τ. running-mean (Eq. 11), branch
/// i (1-based): a = √(i/(i+1)), b = √(1/(i+1)). standard (SP): a = b = 1.
fn residual_coeffs(cfg: &ModelConfig, tau: f32, layer: usize) -> (f32, f32) {
    match cfg.residual.as_str() {
        "standard" => (1.0, 1.0),
        "running_mean" => {
            let i = (layer + 1) as f32;
            ((i / (i + 1.0)).sqrt(), (1.0 / (i + 1.0)).sqrt())
        }
        _ => {
            let t = tau.clamp(0.0, 1.0);
            ((1.0 - t).sqrt(), t.sqrt())
        }
    }
}

fn sign(x: f32) -> f32 {
    if x > 0.0 {
        1.0
    } else if x < 0.0 {
        -1.0
    } else {
        0.0
    }
}

/// Per-tensor LR transfer multiplier (mirrors configs.py lr_mult): µS
/// scales hidden layers by √(d_base/d); SP scales every layer by d_base/d.
fn lr_mult(cfg: &ModelConfig, tensor_idx: usize) -> f32 {
    let n = n_param_tensors(cfg);
    let hidden = tensor_idx > 0 && tensor_idx < n - 1;
    if cfg.variant == "mus" {
        if hidden {
            (cfg.d_base as f32 / cfg.width as f32).sqrt()
        } else {
            1.0
        }
    } else {
        cfg.d_base as f32 / cfg.width as f32
    }
}

// ---------------------------------------------------------------------------
// Interpreter entry points

fn run_init(cfg: &ModelConfig, inputs: &[Arc<Tensor>]) -> Result<Vec<Tensor>> {
    let seed = inputs[0].scalar_i32_value()?;
    let sigma = if cfg.variant == "mus" { 1.0f32 } else { 0.02 };
    let rng = Rng::new(0x5EED_0000_u64 ^ (seed as i64 as u64));
    let specs = param_specs(cfg);
    let mut outs = Vec::with_capacity(2 * specs.len());
    for (i, spec) in specs.iter().enumerate() {
        let mut r = rng.fork(0x9A17 + i as u64);
        let mut data = vec![0f32; spec.elements()];
        r.fill_normal(&mut data, sigma);
        outs.push(Tensor::f32(data, &spec.shape)?);
    }
    for spec in &specs {
        outs.push(Tensor::zeros_f32(&spec.shape));
    }
    Ok(outs)
}

struct StateView {
    params: Vec<Vec<f32>>,
    momenta: Vec<Vec<f32>>,
    tokens: Vec<i32>,
}

fn unpack_state(cfg: &ModelConfig, inputs: &[Arc<Tensor>], with_momenta: bool) -> Result<StateView> {
    let n = n_param_tensors(cfg);
    let specs = param_specs(cfg);
    let mut params = Vec::with_capacity(n);
    for (i, spec) in specs.iter().enumerate() {
        let t = &inputs[i];
        if t.elements() != spec.elements() {
            bail!("param tensor {} ({}) has {} elements, expected {}",
                i, spec.name, t.elements(), spec.elements());
        }
        params.push(t.to_f32_vec()?);
    }
    let mut momenta = Vec::new();
    let tok_idx = if with_momenta {
        for (i, spec) in specs.iter().enumerate() {
            let t = &inputs[n + i];
            if t.elements() != spec.elements() {
                bail!("momentum tensor {} (m_{}) has {} elements, expected {}",
                    i, spec.name, t.elements(), spec.elements());
            }
            momenta.push(t.to_f32_vec()?);
        }
        2 * n
    } else {
        n
    };
    let tokens = inputs[tok_idx].as_i32()?.to_vec();
    if tokens.len() != cfg.batch * cfg.seq_len {
        bail!("tokens length {} != batch*seq = {}", tokens.len(), cfg.batch * cfg.seq_len);
    }
    for &t in &tokens {
        if t < 0 || t as usize >= cfg.vocab {
            bail!("token id {t} out of vocab range 0..{}", cfg.vocab);
        }
    }
    Ok(StateView { params, momenta, tokens })
}

fn run_train_step(cfg: &ModelConfig, inputs: &[Arc<Tensor>]) -> Result<Vec<Tensor>> {
    let n = n_param_tensors(cfg);
    let mut sv = unpack_state(cfg, inputs, true)?;
    let lr = inputs[2 * n + 1].scalar()?;
    let wd = inputs[2 * n + 2].scalar()?;
    let tau = inputs[2 * n + 3].scalar()?;

    let (grads, loss, gnorm) = backprop(cfg, &sv.params, &sv.tokens, tau)?;

    // Lion with fully decoupled weight decay (ref.py lion_update):
    //   c = β1·m + (1-β1)·g;  p' = p - lr·sign(c) - wd·p;  m' = β2·m + (1-β2)·g
    const B1: f32 = 0.9;
    const B2: f32 = 0.99;
    for i in 0..n {
        let lr_eff = lr * lr_mult(cfg, i);
        let (p, m, g) = (&mut sv.params[i], &mut sv.momenta[i], &grads[i]);
        for j in 0..p.len() {
            let c = B1 * m[j] + (1.0 - B1) * g[j];
            p[j] = p[j] - lr_eff * sign(c) - wd * p[j];
            m[j] = B2 * m[j] + (1.0 - B2) * g[j];
        }
    }

    let specs = param_specs(cfg);
    let mut outs = Vec::with_capacity(2 * n + 2);
    for (i, spec) in specs.iter().enumerate() {
        outs.push(Tensor::f32(std::mem::take(&mut sv.params[i]), &spec.shape)?);
    }
    for (i, spec) in specs.iter().enumerate() {
        outs.push(Tensor::f32(std::mem::take(&mut sv.momenta[i]), &spec.shape)?);
    }
    outs.push(Tensor::scalar_f32(loss));
    outs.push(Tensor::scalar_f32(gnorm));
    Ok(outs)
}

fn run_fwd(cfg: &ModelConfig, inputs: &[Arc<Tensor>]) -> Result<Vec<Tensor>> {
    let n = n_param_tensors(cfg);
    let sv = unpack_state(cfg, inputs, false)?;
    let tau = inputs[n + 1].scalar()?;
    let logits = forward_logits(cfg, &sv.params, &sv.tokens, tau)?;
    Ok(vec![Tensor::f32(logits, &[cfg.batch, cfg.seq_len, cfg.vocab])?])
}

// ---------------------------------------------------------------------------
// Model math

/// Quantized copies of the weights for one step's compute.
struct QuantWeights {
    hidden: Vec<Vec<f32>>,
    head: Vec<f32>,
}

fn quantize_weights(cfg: &ModelConfig, params: &[Vec<f32>], plan: &Plan) -> QuantWeights {
    let n = n_param_tensors(cfg);
    let mut hidden = Vec::with_capacity(cfg.depth);
    for w in params.iter().take(n - 1).skip(1) {
        let mut q = w.clone();
        quantize_slice(&mut q, plan.hidden);
        hidden.push(q);
    }
    // Embedding and LM head stay BF16 even in FP8 mode (paper Table 1).
    let mut head = params[n - 1].clone();
    quantize_slice(&mut head, QuantMode::Bf16);
    QuantWeights { hidden, head }
}

/// Hidden-linear output multiplier: µS unit-scaled matmul (1/√fan_in).
fn hidden_mult(cfg: &ModelConfig) -> f32 {
    if cfg.variant == "mus" {
        1.0 / (cfg.width as f32).sqrt()
    } else {
        1.0
    }
}

/// LM-head output multiplier: µS uses 1/fan_in (µP-style).
fn head_mult(cfg: &ModelConfig) -> f32 {
    if cfg.variant == "mus" {
        1.0 / cfg.width as f32
    } else {
        1.0
    }
}

/// Forward one position's residual tower. `x` must hold L+1 buffers of
/// width D; `xq`/`z` hold L buffers (saved operands for backward).
#[allow(clippy::too_many_arguments)]
fn forward_tower(
    cfg: &ModelConfig,
    qw: &QuantWeights,
    act: Act,
    plan: &Plan,
    tau: f32,
    x: &mut [Vec<f32>],
    xq: &mut [Vec<f32>],
    z: &mut [Vec<f32>],
) {
    let d = cfg.width;
    let alpha = hidden_mult(cfg);
    for l in 0..cfg.depth {
        xq[l].copy_from_slice(&x[l]);
        quantize_slice(&mut xq[l], plan.hidden);
        let w = &qw.hidden[l];
        for i in 0..d {
            let row = &w[i * d..(i + 1) * d];
            let mut acc = 0f32;
            for j in 0..d {
                acc += row[j] * xq[l][j];
            }
            z[l][i] = alpha * acc;
        }
        let (ca, cb) = residual_coeffs(cfg, tau, l);
        let (lo, hi) = x.split_at_mut(l + 1);
        let (xl, xn) = (&lo[l], &mut hi[0]);
        for i in 0..d {
            xn[i] = ca * xl[i] + cb * act.apply(z[l][i]);
        }
    }
}

/// RMS-normalize the final residual state: y = x / rms(x). Returns rms.
fn rms_norm(x: &[f32], y: &mut [f32]) -> f32 {
    let d = x.len();
    let ms = x.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>() / d as f64;
    let r = (ms + 1e-6).sqrt() as f32;
    for i in 0..d {
        y[i] = x[i] / r;
    }
    r
}

fn forward_logits(
    cfg: &ModelConfig,
    params: &[Vec<f32>],
    tokens: &[i32],
    tau: f32,
) -> Result<Vec<f32>> {
    let (d, v, s) = (cfg.width, cfg.vocab, cfg.seq_len);
    let act = Act::parse(&cfg.activation)?;
    let plan = plan_for(cfg);
    let qw = quantize_weights(cfg, params, &plan);
    let embed = &params[0];
    let s_out = head_mult(cfg);

    let mut x: Vec<Vec<f32>> = (0..=cfg.depth).map(|_| vec![0f32; d]).collect();
    let mut xq: Vec<Vec<f32>> = (0..cfg.depth).map(|_| vec![0f32; d]).collect();
    let mut z: Vec<Vec<f32>> = (0..cfg.depth).map(|_| vec![0f32; d]).collect();
    let mut y = vec![0f32; d];
    let mut logits = vec![0f32; cfg.batch * s * v];

    for b in 0..cfg.batch {
        for t in 0..s {
            let tok = tokens[b * s + t] as usize;
            x[0].copy_from_slice(&embed[tok * d..(tok + 1) * d]);
            quantize_slice(&mut x[0], QuantMode::Bf16);
            forward_tower(cfg, &qw, act, &plan, tau, &mut x, &mut xq, &mut z);
            rms_norm(&x[cfg.depth], &mut y);
            quantize_slice(&mut y, QuantMode::Bf16);
            let out = &mut logits[(b * s + t) * v..(b * s + t + 1) * v];
            for (dd, &yd) in y.iter().enumerate() {
                if yd == 0.0 {
                    continue;
                }
                let row = &qw.head[dd * v..(dd + 1) * v];
                for (vv, o) in out.iter_mut().enumerate() {
                    *o += yd * row[vv];
                }
            }
            for o in out.iter_mut() {
                *o *= s_out;
            }
        }
    }
    Ok(logits)
}

/// Full forward + backward over all scored positions. Returns per-tensor
/// gradients (state order), mean next-token loss, and the global grad norm.
fn backprop(
    cfg: &ModelConfig,
    params: &[Vec<f32>],
    tokens: &[i32],
    tau: f32,
) -> Result<(Vec<Vec<f32>>, f32, f32)> {
    let (d, v, s, l_n) = (cfg.width, cfg.vocab, cfg.seq_len, cfg.depth);
    let n = n_param_tensors(cfg);
    let act = Act::parse(&cfg.activation)?;
    let plan = plan_for(cfg);
    let qw = quantize_weights(cfg, params, &plan);
    let embed = &params[0];
    let alpha = hidden_mult(cfg);
    let s_out = head_mult(cfg);
    if s < 2 || cfg.batch == 0 {
        bail!("batch {} x seq_len {s} too small to score next-token loss", cfg.batch);
    }
    let scored = cfg.batch * (s - 1);

    let mut grads: Vec<Vec<f32>> = params.iter().map(|p| vec![0f32; p.len()]).collect();
    let mut x: Vec<Vec<f32>> = (0..=l_n).map(|_| vec![0f32; d]).collect();
    let mut xq: Vec<Vec<f32>> = (0..l_n).map(|_| vec![0f32; d]).collect();
    let mut z: Vec<Vec<f32>> = (0..l_n).map(|_| vec![0f32; d]).collect();
    let mut y = vec![0f32; d];
    let mut logits = vec![0f32; v];
    let mut dlogits = vec![0f32; v];
    let mut dy = vec![0f32; d];
    let mut dxn = vec![0f32; d];
    let mut dxl = vec![0f32; d];
    let mut dz = vec![0f32; d];
    let mut loss_sum = 0f64;

    for b in 0..cfg.batch {
        for t in 0..s - 1 {
            let tok = tokens[b * s + t] as usize;
            let tgt = tokens[b * s + t + 1] as usize;
            x[0].copy_from_slice(&embed[tok * d..(tok + 1) * d]);
            quantize_slice(&mut x[0], QuantMode::Bf16);
            forward_tower(cfg, &qw, act, &plan, tau, &mut x, &mut xq, &mut z);
            let r = rms_norm(&x[l_n], &mut y);
            quantize_slice(&mut y, QuantMode::Bf16);

            logits.iter_mut().for_each(|o| *o = 0.0);
            for (dd, &yd) in y.iter().enumerate() {
                if yd == 0.0 {
                    continue;
                }
                let row = &qw.head[dd * v..(dd + 1) * v];
                for (vv, o) in logits.iter_mut().enumerate() {
                    *o += yd * row[vv];
                }
            }
            for o in logits.iter_mut() {
                *o *= s_out;
            }

            // stable cross-entropy + dlogits = (softmax - onehot) / scored
            let m = logits.iter().copied().fold(f32::NEG_INFINITY, f32::max);
            let zden: f64 = logits.iter().map(|&o| ((o - m) as f64).exp()).sum();
            let lse = m as f64 + zden.ln();
            loss_sum += lse - logits[tgt] as f64;
            let inv = 1.0 / scored as f32;
            for vv in 0..v {
                let p = (((logits[vv] - m) as f64).exp() / zden) as f32;
                dlogits[vv] = (p - if vv == tgt { 1.0 } else { 0.0 }) * inv;
            }

            // head backward: g_head += s_out * y ⊗ dlogits; dy = s_out * head @ dlogits
            let g_head = &mut grads[n - 1];
            for dd in 0..d {
                let row = &qw.head[dd * v..(dd + 1) * v];
                let g_row = &mut g_head[dd * v..(dd + 1) * v];
                let yd = y[dd];
                let mut acc = 0f32;
                for vv in 0..v {
                    let dl = dlogits[vv];
                    g_row[vv] += s_out * yd * dl;
                    acc += row[vv] * dl;
                }
                dy[dd] = s_out * acc;
            }

            // RMS-norm backward: dx = (dy - y·mean(dy⊙y)) / r
            let mdot = dy.iter().zip(&y).map(|(&a, &b)| (a as f64) * (b as f64)).sum::<f64>()
                / d as f64;
            for dd in 0..d {
                dxn[dd] = (dy[dd] - y[dd] * mdot as f32) / r;
            }

            // residual tower backward (straight-through quantization)
            for l in (0..l_n).rev() {
                let (ca, cb) = residual_coeffs(cfg, tau, l);
                for i in 0..d {
                    dz[i] = cb * dxn[i] * act.deriv(z[l][i]);
                }
                quantize_slice(&mut dz, plan.grad);
                let w = &qw.hidden[l];
                let g_w = &mut grads[1 + l];
                for i in 0..d {
                    dxl[i] = ca * dxn[i];
                }
                for i in 0..d {
                    let dzi = dz[i];
                    if dzi == 0.0 {
                        continue;
                    }
                    let row = &w[i * d..(i + 1) * d];
                    let g_row = &mut g_w[i * d..(i + 1) * d];
                    let xql = &xq[l];
                    for j in 0..d {
                        g_row[j] += alpha * dzi * xql[j];
                        dxl[j] += alpha * row[j] * dzi;
                    }
                }
                std::mem::swap(&mut dxn, &mut dxl);
            }

            // embedding backward
            let g_embed = &mut grads[0];
            for dd in 0..d {
                g_embed[tok * d + dd] += dxn[dd];
            }
        }
    }

    let gnorm_sq: f64 = grads
        .iter()
        .map(|g| g.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>())
        .sum();
    let loss = (loss_sum / scored as f64) as f32;
    Ok((grads, loss, gnorm_sq.sqrt() as f32))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::backend::Backend;

    fn micro_backend() -> ReferenceBackend {
        ReferenceBackend::new(&[micro_config()]).unwrap()
    }

    fn init_state(be: &ReferenceBackend, cfg: &ModelConfig, seed: i32) -> Vec<Tensor> {
        let name = Kind::Init.name_for(cfg);
        be.run(&name, &[Tensor::scalar_i32(seed)]).unwrap()
    }

    #[test]
    fn init_is_deterministic_and_unit_variance() {
        let be = micro_backend();
        let cfg = micro_config();
        let a = init_state(&be, &cfg, 7);
        let b = init_state(&be, &cfg, 7);
        assert_eq!(a.len(), 2 * n_param_tensors(&cfg));
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x, y);
        }
        let c = init_state(&be, &cfg, 8);
        assert_ne!(a[0], c[0]);
        // µS init: unit variance embedding
        let e = a[0].as_f32().unwrap();
        let var = e.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>() / e.len() as f64;
        assert!((var - 1.0).abs() < 0.15, "embed var {var}");
        // momenta zero
        let m = a[n_param_tensors(&cfg)].as_f32().unwrap();
        assert!(m.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn train_step_abi_and_loss_near_ln_vocab() {
        let be = micro_backend();
        let cfg = micro_config();
        let state = init_state(&be, &cfg, 0);
        let n = n_param_tensors(&cfg);
        let mut inputs = state;
        let tokens: Vec<i32> = (0..cfg.batch * cfg.seq_len).map(|i| (i % cfg.vocab) as i32).collect();
        inputs.push(Tensor::i32(tokens, &[cfg.batch, cfg.seq_len]).unwrap());
        inputs.push(Tensor::scalar_f32(0.01));
        inputs.push(Tensor::scalar_f32(1e-4));
        inputs.push(Tensor::scalar_f32(0.4));
        let outs = be.run(&Kind::TrainStep.name_for(&cfg), &inputs).unwrap();
        assert_eq!(outs.len(), 2 * n + 2);
        let loss = outs[2 * n].scalar().unwrap();
        let gnorm = outs[2 * n + 1].scalar().unwrap();
        let ln_v = (cfg.vocab as f32).ln();
        assert!((loss - ln_v).abs() < 0.8, "init loss {loss}, ln|V| {ln_v}");
        assert!(gnorm.is_finite() && gnorm > 0.0);
    }

    #[test]
    fn repeated_steps_reduce_loss_on_fixed_batch() {
        let be = micro_backend();
        let cfg = micro_config();
        let n = n_param_tensors(&cfg);
        let mut state = init_state(&be, &cfg, 1);
        // a learnable fixed batch: strict bigram cycle
        let tokens: Vec<i32> =
            (0..cfg.batch * cfg.seq_len).map(|i| ((i * 3) % cfg.vocab) as i32).collect();
        let tok = Tensor::i32(tokens, &[cfg.batch, cfg.seq_len]).unwrap();
        let mut first = None;
        let mut last = 0f32;
        for _ in 0..60 {
            let mut inputs = state.clone();
            inputs.push(tok.clone());
            inputs.push(Tensor::scalar_f32(0.01));
            inputs.push(Tensor::scalar_f32(0.0));
            inputs.push(Tensor::scalar_f32(0.4));
            let mut outs = be.run(&Kind::TrainStep.name_for(&cfg), &inputs).unwrap();
            last = outs[2 * n].scalar().unwrap();
            assert!(last.is_finite());
            first.get_or_insert(last);
            outs.truncate(2 * n);
            state = outs;
        }
        let first = first.unwrap();
        assert!(last < first - 0.02, "no learning: {first} -> {last}");
    }

    #[test]
    fn fwd_logits_shape_and_finiteness() {
        let be = micro_backend();
        let cfg = micro_config();
        let state = init_state(&be, &cfg, 2);
        let n = n_param_tensors(&cfg);
        let mut inputs: Vec<Tensor> = state[..n].to_vec();
        let tokens: Vec<i32> = vec![1; cfg.batch * cfg.seq_len];
        inputs.push(Tensor::i32(tokens, &[cfg.batch, cfg.seq_len]).unwrap());
        inputs.push(Tensor::scalar_f32(0.4));
        let outs = be.run(&Kind::Fwd.name_for(&cfg), &inputs).unwrap();
        assert_eq!(outs.len(), 1);
        assert_eq!(outs[0].shape(), &[cfg.batch, cfg.seq_len, cfg.vocab]);
        assert!(outs[0].as_f32().unwrap().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn execute_checks_arity_and_registration() {
        let be = micro_backend();
        let cfg = micro_config();
        let err = be.run(&Kind::TrainStep.name_for(&cfg), &[Tensor::scalar_f32(1.0)]);
        assert!(err.unwrap_err().to_string().contains("expects"));
        assert!(be.run("train_nonexistent", &[]).is_err());
        // resolve() registers previously-unknown valid configs dynamically
        let cfg2 = ModelConfig { width: 32, depth: 2, ..micro_config() };
        assert!(be.manifest().find_for("train_step", &cfg2).is_none());
        let meta = be.resolve("train_step", &cfg2).unwrap();
        assert_eq!(meta.inputs.len(), 2 * n_param_tensors(&cfg2) + 4);
    }

    #[test]
    fn residual_coeffs_preserve_unit_variance() {
        let cfg = micro_config();
        let (a, b) = residual_coeffs(&cfg, 0.4, 0);
        assert!((a * a + b * b - 1.0).abs() < 1e-6);
        let rm = ModelConfig { residual: "running_mean".into(), ..cfg };
        for l in 0..4 {
            let (a, b) = residual_coeffs(&rm, 0.0, l);
            assert!((a * a + b * b - 1.0).abs() < 1e-6, "layer {l}");
        }
    }

    #[test]
    fn free_releases_store_entries() {
        let be = micro_backend();
        let h = be.upload(&Tensor::scalar_f32(1.0)).unwrap();
        assert_eq!(be.download(&h).unwrap().scalar().unwrap(), 1.0);
        be.free(&h);
        assert!(be.download(&h).is_err());
    }
}
