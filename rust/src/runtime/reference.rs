//! Reference backend: a pure-Rust interpreter for small µS/SP configs.
//!
//! Exists so the full L3 stack — trainer, session, sweeps, DDP, eval,
//! checkpoints, benches, examples — runs *without AOT artifacts* (fresh
//! clone, offline, no Python). It is not the AOT transformer: attention is
//! omitted and the model is a µS-parametrized residual MLP over token
//! embeddings (the synthetic corpus is Markovian, so the bigram structure
//! is genuinely learnable). What it shares with the AOT path, faithfully:
//!
//!  - the artifact ABI (`init` / `train_step` / `fwd` tensor lists, state
//!    layout `params ++ momenta`, trailing `loss, gnorm` outputs);
//!  - µS numerics via [`crate::fp8`]: static clip-then-cast E4M3 on hidden
//!    forward operands, E5M2 on activation gradients, BF16 elsewhere; the
//!    SP+FP8 variant uses TE-style dynamic per-tensor scaling;
//!  - scaling rules: unit-variance init, 1/√fan_in and 1/fan_in output
//!    multipliers, √(d_base/d) (µS) vs d_base/d (SP) LR transfer;
//!  - the fixed(τ) / running-mean / standard residual schemes (Eq. 10/11);
//!  - Lion with fully decoupled weight decay (App. A.3).
//!
//! Performance: the model has no attention, so all `batch * seq` token
//! positions are independent — the interpreter runs them as one batched
//! `[rows, d]` activation matrix per layer. Hidden layers, LM head, and
//! every backward product are cache-blocked f32 GEMMs
//! ([`crate::runtime::gemm`]); activation casts use the bit-twiddling
//! [`crate::fp8::FastCast`] (proven bit-exact against `Format::cast`);
//! per-step buffers live in one preallocated [`Workspace`].
//!
//! Determinism: arithmetic is bit-identical for **any** worker-thread
//! count. Row chunking is fixed (never a function of thread count), GEMM
//! accumulation order is fixed by the kernel, and reductions fold fixed
//! chunks in ascending order ([`crate::util::parallel`]) — so
//! thread-parallel sweep workers still produce bit-identical results to
//! the sequential path, and so does the interpreter's internal
//! parallelism (tested). One semantic note: TE-style dynamic scaling
//! computes its per-tensor amax over the whole batched activation tensor
//! (as TE does), not per position.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use super::backend::{Backend, ExecStats, HandleStore, TensorHandle};
use super::gemm::{add_matmul_at_b, matmul_bt, transpose};
use super::manifest::{ArtifactMeta, Dtype, Manifest, TensorSpec};
use super::tensor::Tensor;
use crate::config::ModelConfig;
use crate::fp8::{Format, BF16, E4M3, E5M2};
use crate::util::error::{Error, Result};
use crate::util::parallel;
use crate::util::rng::Rng;
use crate::{bail, err};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Kind {
    Init,
    TrainStep,
    Fwd,
}

impl Kind {
    fn parse(kind: &str) -> Result<Kind> {
        match kind {
            "init" => Ok(Kind::Init),
            "train_step" => Ok(Kind::TrainStep),
            "fwd" => Ok(Kind::Fwd),
            other => Err(err!("reference backend has no '{other}' artifacts")),
        }
    }

    fn as_str(self) -> &'static str {
        match self {
            Kind::Init => "init",
            Kind::TrainStep => "train_step",
            Kind::Fwd => "fwd",
        }
    }

    fn name_for(self, cfg: &ModelConfig) -> String {
        let prefix = match self {
            Kind::Init => "init",
            Kind::TrainStep => "train",
            Kind::Fwd => "fwd",
        };
        format!("{}_{}", prefix, cfg.name())
    }
}

/// Pure-Rust execution backend. Thread-safe: the tensor store and stats
/// are mutex-guarded; the interpreter itself runs outside any lock so
/// sweep workers execute concurrently.
pub struct ReferenceBackend {
    manifest: Manifest,
    registry: Mutex<HashMap<String, (Kind, ModelConfig)>>,
    store: HandleStore,
    stats: Mutex<HashMap<String, ExecStats>>,
}

impl ReferenceBackend {
    /// Backend pre-registered for the given configs (any further valid
    /// config still resolves dynamically via [`Backend::resolve`]).
    pub fn new(configs: &[ModelConfig]) -> Result<ReferenceBackend> {
        let mut artifacts = Vec::new();
        let mut registry = HashMap::new();
        for cfg in configs {
            cfg.validate().map_err(Error::msg)?;
            for kind in [Kind::Init, Kind::TrainStep, Kind::Fwd] {
                let meta = meta_for(kind, cfg);
                registry.insert(meta.name.clone(), (kind, cfg.clone()));
                artifacts.push(meta);
            }
        }
        Ok(ReferenceBackend {
            manifest: Manifest { dir: PathBuf::from("(reference)"), artifacts },
            registry: Mutex::new(registry),
            store: HandleStore::new(),
            stats: Mutex::new(HashMap::new()),
        })
    }

    /// Backend covering the repo's standard proxy roster (CLI / examples).
    pub fn with_standard_roster() -> ReferenceBackend {
        ReferenceBackend::new(&standard_roster()).expect("roster configs are valid")
    }

    fn lookup(&self, name: &str) -> Result<(Kind, ModelConfig)> {
        self.registry
            .lock()
            .expect("registry lock")
            .get(name)
            .cloned()
            .ok_or_else(|| err!("artifact '{name}' not registered with the reference backend"))
    }
}

impl Backend for ReferenceBackend {
    fn platform(&self) -> String {
        "reference".to_string()
    }

    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn resolve(&self, kind: &str, cfg: &ModelConfig) -> Result<ArtifactMeta> {
        let k = Kind::parse(kind)?;
        cfg.validate().map_err(Error::msg)?;
        let meta = meta_for(k, cfg);
        self.registry
            .lock()
            .expect("registry lock")
            .insert(meta.name.clone(), (k, cfg.clone()));
        Ok(meta)
    }

    fn upload(&self, t: &Tensor) -> Result<TensorHandle> {
        Ok(self.store.insert(t.clone()))
    }

    fn execute(&self, name: &str, inputs: &[TensorHandle]) -> Result<Vec<TensorHandle>> {
        let (kind, cfg) = self.lookup(name)?;
        let expected = input_arity(kind, &cfg);
        if inputs.len() != expected {
            bail!("artifact '{name}' expects {expected} inputs, got {}", inputs.len());
        }
        // clone Arcs (not payloads) under the lock; interpret outside it
        let host: Vec<Arc<Tensor>> = self.store.fetch(inputs, name)?;
        let t0 = Instant::now();
        let outs = match kind {
            Kind::Init => run_init(&cfg, &host)?,
            Kind::TrainStep => run_train_step(&cfg, &host)?,
            Kind::Fwd => run_fwd(&cfg, &host)?,
        };
        let dt = t0.elapsed();
        let handles: Vec<TensorHandle> = outs.into_iter().map(|t| self.store.insert(t)).collect();
        {
            let mut stats = self.stats.lock().expect("stats lock");
            let s = stats.entry(name.to_string()).or_default();
            s.calls += 1;
            s.execute_time += dt;
        }
        Ok(handles)
    }

    fn download(&self, h: &TensorHandle) -> Result<Tensor> {
        self.store.get(h)
    }

    fn free(&self, h: &TensorHandle) {
        self.store.remove(h);
    }

    fn stats(&self, name: &str) -> Option<ExecStats> {
        self.stats.lock().expect("stats lock").get(name).cloned()
    }
}

/// Configs pre-registered by [`ReferenceBackend::with_standard_roster`]:
/// the repro proxy family, the e2e shape, and the micro test config.
pub fn standard_roster() -> Vec<ModelConfig> {
    let mut out = Vec::new();
    for (w, d) in [(32usize, 4usize), (64, 4), (128, 6), (256, 8), (64, 24)] {
        for (variant, precision) in [("mus", "fp8"), ("mus", "bf16"), ("sp", "bf16"), ("sp", "fp8")]
        {
            let residual = if variant == "mus" { "fixed" } else { "standard" };
            out.push(ModelConfig {
                width: w,
                depth: d,
                variant: variant.into(),
                precision: precision.into(),
                residual: residual.into(),
                ..ModelConfig::default()
            });
        }
    }
    for precision in ["fp8", "bf16"] {
        out.push(ModelConfig {
            width: 384,
            depth: 6,
            head_dim: 64,
            vocab: 2048,
            seq_len: 256,
            batch: 8,
            precision: precision.into(),
            ..ModelConfig::default()
        });
    }
    out.push(micro_config());
    out
}

/// Tiny config for fast CPU tests (fits a debug-build test budget).
pub fn micro_config() -> ModelConfig {
    ModelConfig {
        width: 16,
        depth: 2,
        head_dim: 8,
        vocab: 64,
        seq_len: 16,
        batch: 2,
        ..ModelConfig::default()
    }
}

// ---------------------------------------------------------------------------
// ABI metadata

/// Reference-model parameter tensors, in state order:
/// `embed [V,D]`, `w0..w{L-1} [D,D]`, `head [D,V]`.
fn param_specs(cfg: &ModelConfig) -> Vec<TensorSpec> {
    let (d, v) = (cfg.width, cfg.vocab);
    let mut specs = vec![TensorSpec { name: "embed".into(), shape: vec![v, d], dtype: Dtype::F32 }];
    for l in 0..cfg.depth {
        specs.push(TensorSpec { name: format!("w{l}"), shape: vec![d, d], dtype: Dtype::F32 });
    }
    specs.push(TensorSpec { name: "head".into(), shape: vec![d, v], dtype: Dtype::F32 });
    specs
}

fn n_param_tensors(cfg: &ModelConfig) -> usize {
    cfg.depth + 2
}

fn input_arity(kind: Kind, cfg: &ModelConfig) -> usize {
    let n = n_param_tensors(cfg);
    match kind {
        Kind::Init => 1,
        Kind::TrainStep => 2 * n + 4,
        Kind::Fwd => n + 2,
    }
}

fn meta_for(kind: Kind, cfg: &ModelConfig) -> ArtifactMeta {
    let params = param_specs(cfg);
    let momenta: Vec<TensorSpec> = params
        .iter()
        .map(|s| TensorSpec { name: format!("m_{}", s.name), shape: s.shape.clone(), dtype: s.dtype })
        .collect();
    let tokens = TensorSpec {
        name: "tokens".into(),
        shape: vec![cfg.batch, cfg.seq_len],
        dtype: Dtype::I32,
    };
    let scalar = |name: &str| TensorSpec { name: name.into(), shape: vec![], dtype: Dtype::F32 };
    let (inputs, outputs) = match kind {
        Kind::Init => {
            let seed = TensorSpec { name: "seed".into(), shape: vec![], dtype: Dtype::I32 };
            let mut outs = params.clone();
            outs.extend(momenta);
            (vec![seed], outs)
        }
        Kind::TrainStep => {
            let mut ins = params.clone();
            ins.extend(momenta.clone());
            ins.push(tokens);
            ins.extend([scalar("lr"), scalar("wd"), scalar("tau")]);
            let mut outs = params.clone();
            outs.extend(momenta);
            outs.extend([scalar("loss"), scalar("gnorm")]);
            (ins, outs)
        }
        Kind::Fwd => {
            let mut ins = params.clone();
            ins.push(tokens);
            ins.push(scalar("tau"));
            let logits = TensorSpec {
                name: "logits".into(),
                shape: vec![cfg.batch, cfg.seq_len, cfg.vocab],
                dtype: Dtype::F32,
            };
            (ins, vec![logits])
        }
    };
    ArtifactMeta {
        name: kind.name_for(cfg),
        kind: kind.as_str().to_string(),
        file: String::new(),
        config: Some(cfg.clone()),
        inputs,
        outputs,
    }
}

// ---------------------------------------------------------------------------
// Numerics: quantization modes, activations, residual coefficients

#[derive(Debug, Clone, Copy)]
enum QuantMode {
    /// BF16 round-trip (the "high precision" lane of the artifact graphs).
    Bf16,
    /// µS static scaling: clip to max_finite, then cast.
    StaticFp8(Format),
    /// TE-style dynamic scaling: rescale to the format's range by the
    /// tensor's amax, cast, rescale back (the overhead µS deletes).
    DynamicFp8(Format),
}

/// Fixed chunk length for parallel elementwise passes. Chunk boundaries
/// are a function of buffer length only, so results are thread-count
/// invariant (see `util::parallel`).
const ELEM_CHUNK: usize = 1 << 14;

/// Quantize one (possibly batched) tensor in place via the fast cast.
fn quantize_slice(xs: &mut [f32], mode: QuantMode) {
    let threads = parallel::threads_for(xs.len() as u64 * 8);
    match mode {
        QuantMode::Bf16 => {
            let fc = BF16.fast_caster();
            parallel::par_chunks_mut(xs, ELEM_CHUNK, threads, |_, c| fc.quantize_slice(c));
        }
        QuantMode::StaticFp8(f) => {
            let fc = f.fast_caster();
            parallel::par_chunks_mut(xs, ELEM_CHUNK, threads, |_, c| fc.quantize_slice(c));
        }
        QuantMode::DynamicFp8(f) => {
            let fc = f.fast_caster();
            // TE-style per-tensor amax (f32::max ignores NaN, like TE's
            // amax reduce; chunked fold keeps it thread-count invariant)
            let amax = parallel::par_map_reduce(
                xs.len(),
                ELEM_CHUNK,
                threads,
                |_, r| xs[r].iter().fold(0f32, |m, x| m.max(x.abs())),
                f32::max,
                0f32,
            );
            if amax == 0.0 {
                return;
            }
            if !amax.is_finite() {
                // No finite scale exists for an inf amax. Raw-cast at
                // scale 1 so the overflow propagates (E4M3 -> NaN, E5M2 ->
                // inf) instead of silently passing inf/NaN activations
                // through unquantized — SP+FP8 divergence must be
                // observable, not masked. (A NaN amax cannot happen: the
                // NaN-ignoring max skips it, and NaN inputs already
                // propagate through the cast below.)
                parallel::par_chunks_mut(xs, ELEM_CHUNK, threads, |_, c| fc.cast_slice(c));
                return;
            }
            // clamp like TE: a deeply-subnormal amax would give an inf
            // scale, and 0.0 * inf = NaN would poison exact zeros
            let scale = (fc.max_finite() / amax).min(f32::MAX);
            let inv = 1.0 / scale; // TE dequant multiplies by the inverse scale
            parallel::par_chunks_mut(xs, ELEM_CHUNK, threads, |_, c| {
                for x in c.iter_mut() {
                    *x = fc.quantize(*x * scale) * inv;
                }
            });
        }
    }
}

/// Quantization plan for a (variant, precision) pair.
struct Plan {
    /// Hidden-layer weights & activations (forward).
    hidden: QuantMode,
    /// Activation gradients (backward).
    grad: QuantMode,
}

fn plan_for(cfg: &ModelConfig) -> Plan {
    match (cfg.variant.as_str(), cfg.precision.as_str()) {
        ("mus", "fp8") => Plan { hidden: QuantMode::StaticFp8(E4M3), grad: QuantMode::StaticFp8(E5M2) },
        ("sp", "fp8") => Plan { hidden: QuantMode::DynamicFp8(E4M3), grad: QuantMode::DynamicFp8(E5M2) },
        _ => Plan { hidden: QuantMode::Bf16, grad: QuantMode::Bf16 },
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Act {
    Gelu,
    Silu,
    Relu,
}

impl Act {
    fn parse(name: &str) -> Result<Act> {
        match name {
            "gelu" => Ok(Act::Gelu),
            "silu" => Ok(Act::Silu),
            "relu" => Ok(Act::Relu),
            other => Err(err!("unknown activation '{other}'")),
        }
    }

    #[inline]
    fn apply(self, z: f32) -> f32 {
        match self {
            Act::Gelu => {
                const K: f32 = 0.797_884_56; // sqrt(2/pi)
                let u = K * (z + 0.044715 * z * z * z);
                0.5 * z * (1.0 + u.tanh())
            }
            Act::Silu => z / (1.0 + (-z).exp()),
            Act::Relu => z.max(0.0),
        }
    }

    #[inline]
    fn deriv(self, z: f32) -> f32 {
        match self {
            Act::Gelu => {
                const K: f32 = 0.797_884_56;
                let u = K * (z + 0.044715 * z * z * z);
                let t = u.tanh();
                0.5 * (1.0 + t) + 0.5 * z * (1.0 - t * t) * K * (1.0 + 3.0 * 0.044715 * z * z)
            }
            Act::Silu => {
                let s = 1.0 / (1.0 + (-z).exp());
                s * (1.0 + z * (1.0 - s))
            }
            Act::Relu => {
                if z > 0.0 {
                    1.0
                } else {
                    0.0
                }
            }
        }
    }
}

/// Residual combination weights (a, b): `x' = a*x + b*branch`.
/// fixed (Eq. 10): a = √(1-τ), b = √τ. running-mean (Eq. 11), branch
/// i (1-based): a = √(i/(i+1)), b = √(1/(i+1)). standard (SP): a = b = 1.
/// Unknown schemes are an error (mirroring `Act::parse`) — a config that
/// bypassed `validate()` must not silently train the wrong scheme.
fn residual_coeffs(cfg: &ModelConfig, tau: f32, layer: usize) -> Result<(f32, f32)> {
    match cfg.residual.as_str() {
        "standard" => Ok((1.0, 1.0)),
        "running_mean" => {
            let i = (layer + 1) as f32;
            Ok(((i / (i + 1.0)).sqrt(), (1.0 / (i + 1.0)).sqrt()))
        }
        "fixed" => {
            let t = tau.clamp(0.0, 1.0);
            Ok(((1.0 - t).sqrt(), t.sqrt()))
        }
        other => Err(err!(
            "unknown residual scheme '{other}' (expected fixed | running_mean | standard)"
        )),
    }
}

/// Coefficients for every layer, resolved once per interpreter call.
fn residual_coeffs_all(cfg: &ModelConfig, tau: f32) -> Result<Vec<(f32, f32)>> {
    (0..cfg.depth).map(|l| residual_coeffs(cfg, tau, l)).collect()
}

fn sign(x: f32) -> f32 {
    if x > 0.0 {
        1.0
    } else if x < 0.0 {
        -1.0
    } else {
        0.0
    }
}

/// Per-tensor LR transfer multiplier (mirrors configs.py lr_mult): µS
/// scales hidden layers by √(d_base/d); SP scales every layer by d_base/d.
fn lr_mult(cfg: &ModelConfig, tensor_idx: usize) -> f32 {
    let n = n_param_tensors(cfg);
    let hidden = tensor_idx > 0 && tensor_idx < n - 1;
    if cfg.variant == "mus" {
        if hidden {
            (cfg.d_base as f32 / cfg.width as f32).sqrt()
        } else {
            1.0
        }
    } else {
        cfg.d_base as f32 / cfg.width as f32
    }
}

// ---------------------------------------------------------------------------
// Interpreter entry points

fn run_init(cfg: &ModelConfig, inputs: &[Arc<Tensor>]) -> Result<Vec<Tensor>> {
    let seed = inputs[0].scalar_i32_value()?;
    let sigma = if cfg.variant == "mus" { 1.0f32 } else { 0.02 };
    let rng = Rng::new(0x5EED_0000_u64 ^ (seed as i64 as u64));
    let specs = param_specs(cfg);
    let mut outs = Vec::with_capacity(2 * specs.len());
    for (i, spec) in specs.iter().enumerate() {
        let mut r = rng.fork(0x9A17 + i as u64);
        let mut data = vec![0f32; spec.elements()];
        r.fill_normal(&mut data, sigma);
        outs.push(Tensor::f32(data, &spec.shape)?);
    }
    for spec in &specs {
        outs.push(Tensor::zeros_f32(&spec.shape));
    }
    Ok(outs)
}

struct StateView {
    params: Vec<Vec<f32>>,
    momenta: Vec<Vec<f32>>,
    tokens: Vec<i32>,
}

fn unpack_state(cfg: &ModelConfig, inputs: &[Arc<Tensor>], with_momenta: bool) -> Result<StateView> {
    let n = n_param_tensors(cfg);
    let specs = param_specs(cfg);
    let mut params = Vec::with_capacity(n);
    for (i, spec) in specs.iter().enumerate() {
        let t = &inputs[i];
        if t.elements() != spec.elements() {
            bail!("param tensor {} ({}) has {} elements, expected {}",
                i, spec.name, t.elements(), spec.elements());
        }
        params.push(t.to_f32_vec()?);
    }
    let mut momenta = Vec::new();
    let tok_idx = if with_momenta {
        for (i, spec) in specs.iter().enumerate() {
            let t = &inputs[n + i];
            if t.elements() != spec.elements() {
                bail!("momentum tensor {} (m_{}) has {} elements, expected {}",
                    i, spec.name, t.elements(), spec.elements());
            }
            momenta.push(t.to_f32_vec()?);
        }
        2 * n
    } else {
        n
    };
    let tokens = inputs[tok_idx].as_i32()?.to_vec();
    if tokens.len() != cfg.batch * cfg.seq_len {
        bail!("tokens length {} != batch*seq = {}", tokens.len(), cfg.batch * cfg.seq_len);
    }
    for &t in &tokens {
        if t < 0 || t as usize >= cfg.vocab {
            bail!("token id {t} out of vocab range 0..{}", cfg.vocab);
        }
    }
    Ok(StateView { params, momenta, tokens })
}

fn run_train_step(cfg: &ModelConfig, inputs: &[Arc<Tensor>]) -> Result<Vec<Tensor>> {
    let n = n_param_tensors(cfg);
    let mut sv = unpack_state(cfg, inputs, true)?;
    let lr = inputs[2 * n + 1].scalar()?;
    let wd = inputs[2 * n + 2].scalar()?;
    let tau = inputs[2 * n + 3].scalar()?;

    let (grads, loss, gnorm) = backprop(cfg, &sv.params, &sv.tokens, tau)?;

    // Lion with fully decoupled weight decay (ref.py lion_update):
    //   c = β1·m + (1-β1)·g;  p' = p - lr·sign(c) - wd·p;  m' = β2·m + (1-β2)·g
    const B1: f32 = 0.9;
    const B2: f32 = 0.99;
    for i in 0..n {
        let lr_eff = lr * lr_mult(cfg, i);
        let g = &grads[i];
        let threads = parallel::threads_for(g.len() as u64 * 6);
        parallel::par_join2(
            &mut sv.params[i],
            &mut sv.momenta[i],
            ELEM_CHUNK,
            ELEM_CHUNK,
            threads,
            |ci, p, m| {
                let off = ci * ELEM_CHUNK;
                for j in 0..p.len() {
                    let gj = g[off + j];
                    let c = B1 * m[j] + (1.0 - B1) * gj;
                    p[j] = p[j] - lr_eff * sign(c) - wd * p[j];
                    m[j] = B2 * m[j] + (1.0 - B2) * gj;
                }
            },
        );
    }

    let specs = param_specs(cfg);
    let mut outs = Vec::with_capacity(2 * n + 2);
    for (i, spec) in specs.iter().enumerate() {
        outs.push(Tensor::f32(std::mem::take(&mut sv.params[i]), &spec.shape)?);
    }
    for (i, spec) in specs.iter().enumerate() {
        outs.push(Tensor::f32(std::mem::take(&mut sv.momenta[i]), &spec.shape)?);
    }
    outs.push(Tensor::scalar_f32(loss));
    outs.push(Tensor::scalar_f32(gnorm));
    Ok(outs)
}

fn run_fwd(cfg: &ModelConfig, inputs: &[Arc<Tensor>]) -> Result<Vec<Tensor>> {
    let n = n_param_tensors(cfg);
    let sv = unpack_state(cfg, inputs, false)?;
    let tau = inputs[n + 1].scalar()?;
    let logits = forward_logits(cfg, &sv.params, &sv.tokens, tau)?;
    Ok(vec![Tensor::f32(logits, &[cfg.batch, cfg.seq_len, cfg.vocab])?])
}

// ---------------------------------------------------------------------------
// Model math

/// Quantized (and pre-transposed) copies of the weights for one step's
/// compute. The transposes exist so every product runs through the
/// contiguous `A @ Bᵀ` kernel.
struct QuantWeights {
    /// Hidden weights `[d,d]`, quantized per the plan; row i = output i.
    hidden: Vec<Vec<f32>>,
    /// Transposes of `hidden` (backward `dz @ W` product); empty when the
    /// weights were prepared for a forward-only call.
    hidden_t: Vec<Vec<f32>>,
    /// LM head `[d,v]` (backward `dlogits @ headᵀ` product).
    head: Vec<f32>,
    /// Transpose of `head`, `[v,d]` (forward logits product).
    head_t: Vec<f32>,
}

fn quantize_weights(
    cfg: &ModelConfig,
    params: &[Vec<f32>],
    plan: &Plan,
    with_backward: bool,
) -> QuantWeights {
    let n = n_param_tensors(cfg);
    let d = cfg.width;
    let mut hidden = Vec::with_capacity(cfg.depth);
    let mut hidden_t = Vec::with_capacity(cfg.depth);
    for w in params.iter().take(n - 1).skip(1) {
        let mut q = w.clone();
        quantize_slice(&mut q, plan.hidden);
        if with_backward {
            let mut t = vec![0f32; q.len()];
            transpose(&q, d, d, &mut t);
            hidden_t.push(t);
        }
        hidden.push(q);
    }
    // Embedding and LM head stay BF16 even in FP8 mode (paper Table 1).
    let mut head = params[n - 1].clone();
    quantize_slice(&mut head, QuantMode::Bf16);
    let mut head_t = vec![0f32; head.len()];
    transpose(&head, d, cfg.vocab, &mut head_t);
    QuantWeights { hidden, hidden_t, head, head_t }
}

/// Hidden-linear output multiplier: µS unit-scaled matmul (1/√fan_in).
fn hidden_mult(cfg: &ModelConfig) -> f32 {
    if cfg.variant == "mus" {
        1.0 / (cfg.width as f32).sqrt()
    } else {
        1.0
    }
}

/// LM-head output multiplier: µS uses 1/fan_in (µP-style).
fn head_mult(cfg: &ModelConfig) -> f32 {
    if cfg.variant == "mus" {
        1.0 / cfg.width as f32
    } else {
        1.0
    }
}

/// Batched activations for one interpreter call. Row `r` of each
/// `[rows, d]` buffer is one (batch, position) residual-stream state —
/// positions are independent (no attention), so the whole batch moves
/// through the tower as matrices. Allocated once per call; the layer loop
/// reuses the buffers instead of churning per-position `Vec`s.
struct Workspace {
    rows: usize,
    /// `x[l]`: stream entering layer l; `x[depth]` is the final state.
    x: Vec<Vec<f32>>,
    /// `xq[l]`: quantized layer-l input operand (saved for backward).
    xq: Vec<Vec<f32>>,
    /// `z[l]`: pre-activation, output multiplier applied (saved for backward).
    z: Vec<Vec<f32>>,
    /// RMS-normalized final state `[rows, d]`.
    y: Vec<f32>,
    /// Per-row RMS divisor `sqrt(mean(x²) + 1e-6)`.
    rms: Vec<f32>,
}

impl Workspace {
    fn new(cfg: &ModelConfig, rows: usize) -> Workspace {
        let d = cfg.width;
        Workspace {
            rows,
            x: (0..=cfg.depth).map(|_| vec![0f32; rows * d]).collect(),
            xq: (0..cfg.depth).map(|_| vec![0f32; rows * d]).collect(),
            z: (0..cfg.depth).map(|_| vec![0f32; rows * d]).collect(),
            y: vec![0f32; rows * d],
            rms: vec![0f32; rows],
        }
    }
}

/// Fixed rows-per-chunk for row-parallel passes.
const ROW_CHUNK: usize = 32;

/// Forward the whole batch through the residual tower and the RMS norm,
/// filling the workspace. `toks[r]` is the input token of row `r`.
#[allow(clippy::too_many_arguments)]
fn forward_tower(
    cfg: &ModelConfig,
    qw: &QuantWeights,
    act: Act,
    plan: &Plan,
    coeffs: &[(f32, f32)],
    embed: &[f32],
    toks: &[i32],
    ws: &mut Workspace,
) {
    let d = cfg.width;
    let rows = ws.rows;
    let alpha = hidden_mult(cfg);
    let row_threads = parallel::threads_for((rows * d) as u64 * 8);

    // token-embedding gather
    parallel::par_chunks_mut(&mut ws.x[0], ROW_CHUNK * d, row_threads, |ci, c| {
        let r0 = ci * ROW_CHUNK;
        for (i, out) in c.chunks_mut(d).enumerate() {
            let tok = toks[r0 + i] as usize;
            out.copy_from_slice(&embed[tok * d..(tok + 1) * d]);
        }
    });
    quantize_slice(&mut ws.x[0], QuantMode::Bf16);

    for l in 0..cfg.depth {
        ws.xq[l].copy_from_slice(&ws.x[l]);
        quantize_slice(&mut ws.xq[l], plan.hidden);
        // z = alpha * xq @ Wᵀ  (W row i = output neuron i)
        matmul_bt(&ws.xq[l], &qw.hidden[l], &mut ws.z[l], rows, d, d, alpha);
        // x' = ca*x + cb*act(z)
        let (ca, cb) = coeffs[l];
        let (lo, hi) = ws.x.split_at_mut(l + 1);
        let (xl, xn) = (&lo[l], &mut hi[0]);
        let z = &ws.z[l];
        parallel::par_chunks_mut(xn, ELEM_CHUNK, row_threads, |ci, c| {
            let off = ci * ELEM_CHUNK;
            for (i, o) in c.iter_mut().enumerate() {
                *o = ca * xl[off + i] + cb * act.apply(z[off + i]);
            }
        });
    }

    // RMS norm: rms = sqrt(mean(x²) + 1e-6); y = x / rms, per row
    let x_last = &ws.x[cfg.depth];
    parallel::par_chunks_mut(&mut ws.rms, ROW_CHUNK, row_threads, |ci, c| {
        let r0 = ci * ROW_CHUNK;
        for (i, o) in c.iter_mut().enumerate() {
            let row = &x_last[(r0 + i) * d..(r0 + i + 1) * d];
            let ms = row.iter().map(|&w| (w as f64) * (w as f64)).sum::<f64>() / d as f64;
            *o = (ms + 1e-6).sqrt() as f32;
        }
    });
    let rms = &ws.rms;
    parallel::par_chunks_mut(&mut ws.y, ROW_CHUNK * d, row_threads, |ci, c| {
        let r0 = ci * ROW_CHUNK;
        for (i, out) in c.chunks_mut(d).enumerate() {
            let r = rms[r0 + i];
            let row = &x_last[(r0 + i) * d..(r0 + i + 1) * d];
            for (o, &w) in out.iter_mut().zip(row) {
                *o = w / r;
            }
        }
    });
    quantize_slice(&mut ws.y, QuantMode::Bf16);
}

fn forward_logits(
    cfg: &ModelConfig,
    params: &[Vec<f32>],
    tokens: &[i32],
    tau: f32,
) -> Result<Vec<f32>> {
    let (d, v) = (cfg.width, cfg.vocab);
    let rows = cfg.batch * cfg.seq_len;
    let act = Act::parse(&cfg.activation)?;
    let plan = plan_for(cfg);
    let coeffs = residual_coeffs_all(cfg, tau)?;
    let qw = quantize_weights(cfg, params, &plan, false);
    let mut ws = Workspace::new(cfg, rows);
    forward_tower(cfg, &qw, act, &plan, &coeffs, &params[0], tokens, &mut ws);
    let mut logits = vec![0f32; rows * v];
    matmul_bt(&ws.y, &qw.head_t, &mut logits, rows, v, d, head_mult(cfg));
    Ok(logits)
}

/// Full forward + backward over all scored positions. Returns per-tensor
/// gradients (state order), mean next-token loss, and the global grad norm.
fn backprop(
    cfg: &ModelConfig,
    params: &[Vec<f32>],
    tokens: &[i32],
    tau: f32,
) -> Result<(Vec<Vec<f32>>, f32, f32)> {
    let (d, v, s, l_n) = (cfg.width, cfg.vocab, cfg.seq_len, cfg.depth);
    let n = n_param_tensors(cfg);
    let act = Act::parse(&cfg.activation)?;
    let plan = plan_for(cfg);
    let coeffs = residual_coeffs_all(cfg, tau)?;
    let qw = quantize_weights(cfg, params, &plan, true);
    let alpha = hidden_mult(cfg);
    let s_out = head_mult(cfg);
    if s < 2 || cfg.batch == 0 {
        bail!("batch {} x seq_len {s} too small to score next-token loss", cfg.batch);
    }
    // scored rows: row (b, t) feeds token (b,t) and predicts token (b,t+1)
    let rows = cfg.batch * (s - 1);
    let mut toks = vec![0i32; rows];
    let mut tgts = vec![0usize; rows];
    for b in 0..cfg.batch {
        for t in 0..s - 1 {
            toks[b * (s - 1) + t] = tokens[b * s + t];
            tgts[b * (s - 1) + t] = tokens[b * s + t + 1] as usize;
        }
    }

    let mut ws = Workspace::new(cfg, rows);
    forward_tower(cfg, &qw, act, &plan, &coeffs, &params[0], &toks, &mut ws);

    // logits, then in place: dlogits = (softmax - onehot) / scored
    let mut dlogits = vec![0f32; rows * v];
    matmul_bt(&ws.y, &qw.head_t, &mut dlogits, rows, v, d, s_out);
    let mut loss_rows = vec![0f64; rows];
    let inv = 1.0 / rows as f32;
    let logit_threads = parallel::threads_for((rows * v) as u64 * 8);
    {
        let tgts = &tgts;
        parallel::par_join2(
            &mut dlogits,
            &mut loss_rows,
            ROW_CHUNK * v,
            ROW_CHUNK,
            logit_threads,
            |ci, lc, loss_c| {
                let r0 = ci * ROW_CHUNK;
                for (i, row) in lc.chunks_mut(v).enumerate() {
                    let tgt = tgts[r0 + i];
                    // stable cross-entropy per row
                    let m = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
                    let zden: f64 = row.iter().map(|&o| ((o - m) as f64).exp()).sum();
                    let lse = m as f64 + zden.ln();
                    loss_c[i] = lse - row[tgt] as f64;
                    for (vv, o) in row.iter_mut().enumerate() {
                        let p = (((*o - m) as f64).exp() / zden) as f32;
                        *o = (p - if vv == tgt { 1.0 } else { 0.0 }) * inv;
                    }
                }
            },
        );
    }

    let mut grads: Vec<Vec<f32>> = params.iter().map(|p| vec![0f32; p.len()]).collect();

    // head backward: g_head += s_out · yᵀ @ dlogits; dy = s_out · dlogits @ headᵀ
    add_matmul_at_b(&ws.y, &dlogits, &mut grads[n - 1], rows, d, v, s_out);
    let mut dy = vec![0f32; rows * d];
    matmul_bt(&dlogits, &qw.head, &mut dy, rows, d, v, s_out);
    drop(dlogits); // the [rows, v] buffer is the largest; release it early

    // RMS-norm backward: dx = (dy - y·mean(dy⊙y)) / rms, per row
    let mut dxn = vec![0f32; rows * d];
    let row_threads = parallel::threads_for((rows * d) as u64 * 8);
    {
        let (y, rms, dy_r) = (&ws.y, &ws.rms, &dy);
        parallel::par_chunks_mut(&mut dxn, ROW_CHUNK * d, row_threads, |ci, c| {
            let r0 = ci * ROW_CHUNK;
            for (i, out) in c.chunks_mut(d).enumerate() {
                let r = r0 + i;
                let yr = &y[r * d..(r + 1) * d];
                let dyr = &dy_r[r * d..(r + 1) * d];
                let mdot = dyr.iter().zip(yr).map(|(&a, &b)| (a as f64) * (b as f64)).sum::<f64>()
                    / d as f64;
                let rr = rms[r];
                for j in 0..d {
                    out[j] = (dyr[j] - yr[j] * mdot as f32) / rr;
                }
            }
        });
    }

    // residual tower backward (straight-through quantization)
    let mut dz = vec![0f32; rows * d];
    let mut dxl = vec![0f32; rows * d];
    for l in (0..l_n).rev() {
        let (ca, cb) = coeffs[l];
        {
            let (dxn_r, z) = (&dxn, &ws.z[l]);
            parallel::par_chunks_mut(&mut dz, ELEM_CHUNK, row_threads, |ci, c| {
                let off = ci * ELEM_CHUNK;
                for (i, o) in c.iter_mut().enumerate() {
                    *o = cb * dxn_r[off + i] * act.deriv(z[off + i]);
                }
            });
        }
        quantize_slice(&mut dz, plan.grad);
        // g_w += alpha · dzᵀ @ xq;  dx = ca·dxn + alpha · dz @ W
        add_matmul_at_b(&dz, &ws.xq[l], &mut grads[1 + l], rows, d, d, alpha);
        matmul_bt(&dz, &qw.hidden_t[l], &mut dxl, rows, d, d, alpha);
        {
            let dxn_r = &dxn;
            parallel::par_chunks_mut(&mut dxl, ELEM_CHUNK, row_threads, |ci, c| {
                let off = ci * ELEM_CHUNK;
                for (i, o) in c.iter_mut().enumerate() {
                    *o += ca * dxn_r[off + i];
                }
            });
        }
        std::mem::swap(&mut dxn, &mut dxl);
    }

    // embedding backward: sequential scatter (rows sharing a token collide,
    // and the row-order accumulation keeps it deterministic)
    let g_embed = &mut grads[0];
    for r in 0..rows {
        let src = &dxn[r * d..(r + 1) * d];
        let tok = toks[r] as usize;
        let dst = &mut g_embed[tok * d..(tok + 1) * d];
        for (o, &x) in dst.iter_mut().zip(src) {
            *o += x;
        }
    }

    // grad norm: fixed-chunk f64 partials folded in chunk order
    let mut gnorm_sq = 0f64;
    for g in &grads {
        gnorm_sq += parallel::par_map_reduce(
            g.len(),
            ELEM_CHUNK,
            parallel::threads_for(g.len() as u64 * 2),
            |_, range| g[range].iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>(),
            |a, b| a + b,
            0f64,
        );
    }
    let loss = (loss_rows.iter().sum::<f64>() / rows as f64) as f32;
    Ok((grads, loss, gnorm_sq.sqrt() as f32))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::backend::Backend;

    fn micro_backend() -> ReferenceBackend {
        ReferenceBackend::new(&[micro_config()]).unwrap()
    }

    fn init_state(be: &ReferenceBackend, cfg: &ModelConfig, seed: i32) -> Vec<Tensor> {
        let name = Kind::Init.name_for(cfg);
        be.run(&name, &[Tensor::scalar_i32(seed)]).unwrap()
    }

    #[test]
    fn init_is_deterministic_and_unit_variance() {
        let be = micro_backend();
        let cfg = micro_config();
        let a = init_state(&be, &cfg, 7);
        let b = init_state(&be, &cfg, 7);
        assert_eq!(a.len(), 2 * n_param_tensors(&cfg));
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x, y);
        }
        let c = init_state(&be, &cfg, 8);
        assert_ne!(a[0], c[0]);
        // µS init: unit variance embedding
        let e = a[0].as_f32().unwrap();
        let var = e.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>() / e.len() as f64;
        assert!((var - 1.0).abs() < 0.15, "embed var {var}");
        // momenta zero
        let m = a[n_param_tensors(&cfg)].as_f32().unwrap();
        assert!(m.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn train_step_abi_and_loss_near_ln_vocab() {
        let be = micro_backend();
        let cfg = micro_config();
        let state = init_state(&be, &cfg, 0);
        let n = n_param_tensors(&cfg);
        let mut inputs = state;
        let tokens: Vec<i32> = (0..cfg.batch * cfg.seq_len).map(|i| (i % cfg.vocab) as i32).collect();
        inputs.push(Tensor::i32(tokens, &[cfg.batch, cfg.seq_len]).unwrap());
        inputs.push(Tensor::scalar_f32(0.01));
        inputs.push(Tensor::scalar_f32(1e-4));
        inputs.push(Tensor::scalar_f32(0.4));
        let outs = be.run(&Kind::TrainStep.name_for(&cfg), &inputs).unwrap();
        assert_eq!(outs.len(), 2 * n + 2);
        let loss = outs[2 * n].scalar().unwrap();
        let gnorm = outs[2 * n + 1].scalar().unwrap();
        let ln_v = (cfg.vocab as f32).ln();
        assert!((loss - ln_v).abs() < 0.8, "init loss {loss}, ln|V| {ln_v}");
        assert!(gnorm.is_finite() && gnorm > 0.0);
    }

    #[test]
    fn repeated_steps_reduce_loss_on_fixed_batch() {
        let be = micro_backend();
        let cfg = micro_config();
        let n = n_param_tensors(&cfg);
        let mut state = init_state(&be, &cfg, 1);
        // a learnable fixed batch: strict bigram cycle
        let tokens: Vec<i32> =
            (0..cfg.batch * cfg.seq_len).map(|i| ((i * 3) % cfg.vocab) as i32).collect();
        let tok = Tensor::i32(tokens, &[cfg.batch, cfg.seq_len]).unwrap();
        let mut first = None;
        let mut last = 0f32;
        for _ in 0..60 {
            let mut inputs = state.clone();
            inputs.push(tok.clone());
            inputs.push(Tensor::scalar_f32(0.01));
            inputs.push(Tensor::scalar_f32(0.0));
            inputs.push(Tensor::scalar_f32(0.4));
            let mut outs = be.run(&Kind::TrainStep.name_for(&cfg), &inputs).unwrap();
            last = outs[2 * n].scalar().unwrap();
            assert!(last.is_finite());
            first.get_or_insert(last);
            outs.truncate(2 * n);
            state = outs;
        }
        let first = first.unwrap();
        assert!(last < first - 0.02, "no learning: {first} -> {last}");
    }

    #[test]
    fn fwd_logits_shape_and_finiteness() {
        let be = micro_backend();
        let cfg = micro_config();
        let state = init_state(&be, &cfg, 2);
        let n = n_param_tensors(&cfg);
        let mut inputs: Vec<Tensor> = state[..n].to_vec();
        let tokens: Vec<i32> = vec![1; cfg.batch * cfg.seq_len];
        inputs.push(Tensor::i32(tokens, &[cfg.batch, cfg.seq_len]).unwrap());
        inputs.push(Tensor::scalar_f32(0.4));
        let outs = be.run(&Kind::Fwd.name_for(&cfg), &inputs).unwrap();
        assert_eq!(outs.len(), 1);
        assert_eq!(outs[0].shape(), &[cfg.batch, cfg.seq_len, cfg.vocab]);
        assert!(outs[0].as_f32().unwrap().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn execute_checks_arity_and_registration() {
        let be = micro_backend();
        let cfg = micro_config();
        let err = be.run(&Kind::TrainStep.name_for(&cfg), &[Tensor::scalar_f32(1.0)]);
        assert!(err.unwrap_err().to_string().contains("expects"));
        assert!(be.run("train_nonexistent", &[]).is_err());
        // resolve() registers previously-unknown valid configs dynamically
        let cfg2 = ModelConfig { width: 32, depth: 2, ..micro_config() };
        assert!(be.manifest().find_for("train_step", &cfg2).is_none());
        let meta = be.resolve("train_step", &cfg2).unwrap();
        assert_eq!(meta.inputs.len(), 2 * n_param_tensors(&cfg2) + 4);
    }

    #[test]
    fn residual_coeffs_preserve_unit_variance() {
        let cfg = micro_config();
        let (a, b) = residual_coeffs(&cfg, 0.4, 0).unwrap();
        assert!((a * a + b * b - 1.0).abs() < 1e-6);
        let rm = ModelConfig { residual: "running_mean".into(), ..cfg };
        for l in 0..4 {
            let (a, b) = residual_coeffs(&rm, 0.0, l).unwrap();
            assert!((a * a + b * b - 1.0).abs() < 1e-6, "layer {l}");
        }
    }

    #[test]
    fn unknown_residual_scheme_is_an_error_not_fixed() {
        // Regression: the old catch-all `_` arm silently trained the
        // "fixed" scheme for any unrecognized string (reachable by configs
        // that bypass validate()).
        let cfg = ModelConfig { residual: "bogus".into(), ..micro_config() };
        let err = residual_coeffs(&cfg, 0.4, 0).unwrap_err().to_string();
        assert!(err.contains("bogus"), "unhelpful error: {err}");
        assert!(residual_coeffs_all(&cfg, 0.4).is_err());
        // and the full step path surfaces it too
        let state: Vec<Vec<f32>> =
            param_specs(&cfg).iter().map(|s| vec![0.01; s.elements()]).collect();
        let tokens: Vec<i32> = vec![1; cfg.batch * cfg.seq_len];
        let err = backprop(&cfg, &state, &tokens, 0.4).unwrap_err().to_string();
        assert!(err.contains("residual"), "unhelpful error: {err}");
    }

    #[test]
    fn dynamic_fp8_propagates_nonfinite_instead_of_masking() {
        // Regression: an inf in the tensor used to make quantize_slice
        // return early, silently skipping quantization in exactly the
        // SP+FP8 divergence experiment the paper is about.
        let mut xs = vec![1.0f32, -2.5, f32::INFINITY, 0.5];
        quantize_slice(&mut xs, QuantMode::DynamicFp8(E4M3));
        assert!(xs[2].is_nan(), "E4M3 overflow must surface as NaN, got {}", xs[2]);
        // finite elements are still cast onto the E4M3 grid (scale 1)
        assert_eq!(xs[0], 1.0);
        assert_eq!(xs[1], -2.5);
        assert_eq!(xs[3], 0.5);

        // E5M2 keeps IEEE-style inf on overflow
        let mut xs = vec![f32::NEG_INFINITY, 3.0f32];
        quantize_slice(&mut xs, QuantMode::DynamicFp8(E5M2));
        assert_eq!(xs[0], f32::NEG_INFINITY);
        assert_eq!(xs[1], 3.0);

        // NaN elements propagate (amax ignores them; the cast keeps them)
        let mut xs = vec![f32::NAN, 1.0f32];
        quantize_slice(&mut xs, QuantMode::DynamicFp8(E4M3));
        assert!(xs[0].is_nan());
        assert!(xs[1].is_finite());

        // all-zero tensors stay untouched (no 0/0 scale)
        let mut xs = vec![0.0f32; 4];
        quantize_slice(&mut xs, QuantMode::DynamicFp8(E4M3));
        assert!(xs.iter().all(|&x| x == 0.0));

        // deeply-subnormal amax: the scale clamps to f32::MAX instead of
        // overflowing to inf, so exact zeros stay zero (not 0*inf = NaN)
        let mut xs = vec![0.0f32, 1e-40, -1e-40];
        quantize_slice(&mut xs, QuantMode::DynamicFp8(E4M3));
        assert_eq!(xs[0], 0.0);
        assert!(xs.iter().all(|x| !x.is_nan()), "tiny-amax tensor produced NaN: {xs:?}");
    }

    /// Drive `steps` train steps on a fixed learnable batch (a strict
    /// bigram cycle); returns the per-step losses.
    fn run_lane(cfg: &ModelConfig, steps: usize, lr: f32) -> Vec<f32> {
        let be = ReferenceBackend::new(&[cfg.clone()]).unwrap();
        let n = n_param_tensors(cfg);
        let mut state = init_state(&be, cfg, 1);
        let tokens: Vec<i32> =
            (0..cfg.batch * cfg.seq_len).map(|i| ((i * 3) % cfg.vocab) as i32).collect();
        let tok = Tensor::i32(tokens, &[cfg.batch, cfg.seq_len]).unwrap();
        let mut losses = Vec::with_capacity(steps);
        for _ in 0..steps {
            let mut inputs = state.clone();
            inputs.push(tok.clone());
            inputs.push(Tensor::scalar_f32(lr));
            inputs.push(Tensor::scalar_f32(0.0));
            inputs.push(Tensor::scalar_f32(0.4));
            let mut outs = be.run(&Kind::TrainStep.name_for(cfg), &inputs).unwrap();
            losses.push(outs[2 * n].scalar().unwrap());
            outs.truncate(2 * n);
            state = outs;
        }
        losses
    }

    /// loss-decreases + bit-determinism assertions shared by the
    /// always-run precision-lane tests. Sign descent can oscillate near
    /// the optimum, so the "decreased" check uses the tail minimum.
    fn assert_lane_learns_deterministically(cfg: &ModelConfig, lr: f32, lane: &str) {
        let a = run_lane(cfg, 60, lr);
        assert!(a.iter().all(|l| l.is_finite()), "{lane}: non-finite loss: {a:?}");
        let tail_min = a[50..].iter().copied().fold(f32::INFINITY, f32::min);
        assert!(tail_min < a[0] - 0.01, "{lane}: no learning: {} -> {tail_min}", a[0]);
        let b = run_lane(cfg, 60, lr);
        assert_eq!(a, b, "{lane}: repeated runs are not bit-identical");
    }

    #[test]
    fn mus_fp8_static_lane_learns_and_is_bit_deterministic() {
        let cfg = ModelConfig {
            variant: "mus".into(),
            precision: "fp8".into(),
            residual: "fixed".into(),
            ..micro_config()
        };
        assert_lane_learns_deterministically(&cfg, 0.01, "mus+fp8 (static E4M3/E5M2)");
    }

    #[test]
    fn sp_fp8_dynamic_lane_learns_and_is_bit_deterministic() {
        let cfg = ModelConfig {
            variant: "sp".into(),
            precision: "fp8".into(),
            residual: "standard".into(),
            ..micro_config()
        };
        assert_lane_learns_deterministically(&cfg, 1.0 / 256.0, "sp+fp8 (dynamic)");
    }

    #[test]
    fn batched_interpreter_is_thread_count_invariant() {
        // Big enough that the GEMMs clear the parallel threshold, so the
        // multi-thread path genuinely runs when allowed to.
        let cfg = ModelConfig {
            width: 64,
            depth: 2,
            head_dim: 8,
            vocab: 128,
            seq_len: 32,
            batch: 4,
            ..ModelConfig::default()
        };
        let run = |threads: usize| {
            parallel::with_max_threads(threads, || {
                let be = ReferenceBackend::new(&[cfg.clone()]).unwrap();
                let n = n_param_tensors(&cfg);
                let mut state = init_state(&be, &cfg, 3);
                let tokens: Vec<i32> =
                    (0..cfg.batch * cfg.seq_len).map(|i| ((i * 5) % cfg.vocab) as i32).collect();
                let tok = Tensor::i32(tokens, &[cfg.batch, cfg.seq_len]).unwrap();
                let mut losses = Vec::new();
                for _ in 0..3 {
                    let mut inputs = state.clone();
                    inputs.push(tok.clone());
                    inputs.push(Tensor::scalar_f32(0.01));
                    inputs.push(Tensor::scalar_f32(1e-4));
                    inputs.push(Tensor::scalar_f32(0.4));
                    let mut outs = be.run(&Kind::TrainStep.name_for(&cfg), &inputs).unwrap();
                    losses.push(outs[2 * n].scalar().unwrap().to_bits());
                    outs.truncate(2 * n);
                    state = outs;
                }
                let final_state: Vec<Vec<f32>> =
                    state.iter().map(|t| t.as_f32().unwrap().to_vec()).collect();
                (losses, final_state)
            })
        };
        let (l1, s1) = run(1);
        for threads in [2usize, 4] {
            let (lt, st) = run(threads);
            assert_eq!(l1, lt, "losses drifted at {threads} threads");
            assert_eq!(s1, st, "state drifted at {threads} threads");
        }
    }

    #[test]
    fn free_releases_store_entries() {
        let be = micro_backend();
        let h = be.upload(&Tensor::scalar_f32(1.0)).unwrap();
        assert_eq!(be.download(&h).unwrap().scalar().unwrap(), 1.0);
        be.free(&h);
        assert!(be.download(&h).is_err());
    }
}
