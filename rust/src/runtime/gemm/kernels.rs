//! Inner microkernels: portable scalar twins and their AVX2/FMA variants.
//!
//! This is the single file in the tree where `core::arch` intrinsics are
//! allowed (enforced by the `stray-intrinsic` lint). Every
//! `#[target_feature]` function here has a portable twin named
//! `*_scalar` in this file (enforced by the `missing-scalar-twin` lint),
//! and the default AVX2 variants are **bit-identical** to their twins:
//!
//!  - the 8-lane accumulator of [`dot_scalar`] is exactly one 256-bit
//!    register, so `acc = add(acc, mul(va, vb))` performs the same
//!    `lanes[l] += a[l] * b[l]` updates in the same order;
//!  - the fixed fold tree `((l0+l4)+(l1+l5)) + ((l2+l6)+(l3+l7))` maps
//!    onto `extractf128` / `shuffle` / `movehl` lane sums with matching
//!    operand order (see [`hsum_scalar`] / `hsum_avx2`);
//!  - tails (`len % 8`) use the same sequential scalar loop.
//!
//! The `*_fma` variants contract `mul`+`add` into a single fused
//! multiply-add (one rounding instead of two). They are **not**
//! bit-identical to the twins and only run in the opt-in `Fast` kernel
//! mode (see `super::dispatch`); their divergence is measured and bounded
//! by the `fast_fma_mode_divergence_is_small_and_bounded` test.

/// Column-block width shared by the panel kernels: keeps the active rows
/// of `B` resident in L1/L2 while a row panel streams past.
pub(super) const COL_BLOCK: usize = 64;

/// Row-register blocking of the panel kernels: rows of `A` processed per
/// pass over a column of `B`, so each loaded `B` vector is reused
/// `MR` times from registers.
pub(super) const MR: usize = 4;

/// Fixed fold tree over the eight dot-product lanes:
/// `((l0+l4)+(l1+l5)) + ((l2+l6)+(l3+l7))`. One 256-bit register wide —
/// the AVX2 horizontal sum reproduces this order exactly.
#[inline]
pub(super) fn hsum_scalar(l: [f32; 8]) -> f32 {
    ((l[0] + l[4]) + (l[1] + l[5])) + ((l[2] + l[6]) + (l[3] + l[7]))
}

/// Fixed-order dot product: eight accumulator lanes over stride-8 blocks,
/// folded by [`hsum_scalar`], then the scalar tail. The lane partition is
/// a function of `a.len()` only.
#[inline]
pub(super) fn dot_scalar(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut lanes = [0f32; 8];
    let n8 = a.len() / 8 * 8;
    let (a8, a_tail) = a.split_at(n8);
    let (b8, b_tail) = b.split_at(n8);
    for (ab, bb) in a8.chunks_exact(8).zip(b8.chunks_exact(8)) {
        for l in 0..8 {
            lanes[l] += ab[l] * bb[l];
        }
    }
    let mut tail = 0f32;
    for (x, y) in a_tail.iter().zip(b_tail) {
        tail += x * y;
    }
    hsum_scalar(lanes) + tail
}

/// Four simultaneous [`dot_scalar`] products against one shared `b` row —
/// the portable register tile. Each output is the plain dot of its row,
/// so blocking changes nothing bitwise.
#[inline]
pub(super) fn dot4_scalar(a0: &[f32], a1: &[f32], a2: &[f32], a3: &[f32], b: &[f32]) -> [f32; 4] {
    [dot_scalar(a0, b), dot_scalar(a1, b), dot_scalar(a2, b), dot_scalar(a3, b)]
}

/// `c[j] += s * b[j]` over the row — the rank-1 update inner loop of the
/// weight-gradient GEMM. Elementwise, so any vectorization of it is
/// bit-identical.
#[inline]
pub(super) fn axpy_scalar(c_row: &mut [f32], b_row: &[f32], s: f32) {
    for (cv, bv) in c_row.iter_mut().zip(b_row) {
        *cv += s * bv;
    }
}

/// Portable row-panel kernel for `C = s · A @ Bᵀ`: `a_panel` is
/// `[rows, k]`, `c_chunk` is `[rows, n]`, `b` is `[n, k]`. Column-blocked
/// and 4-row register-tiled; every element is still `s * dot(a_i, b_j)`
/// in the fixed lane order, so the tiling is bit-neutral.
pub(super) fn panel_bt_scalar(
    a_panel: &[f32],
    b: &[f32],
    c_chunk: &mut [f32],
    n: usize,
    k: usize,
    scale: f32,
) {
    let rows = c_chunk.len() / n;
    for j0 in (0..n).step_by(COL_BLOCK) {
        let j1 = (j0 + COL_BLOCK).min(n);
        let mut i = 0usize;
        while i + MR <= rows {
            for j in j0..j1 {
                let br = &b[j * k..(j + 1) * k];
                let d = dot4_scalar(
                    &a_panel[i * k..(i + 1) * k],
                    &a_panel[(i + 1) * k..(i + 2) * k],
                    &a_panel[(i + 2) * k..(i + 3) * k],
                    &a_panel[(i + 3) * k..(i + 4) * k],
                    br,
                );
                for (r, dv) in d.iter().enumerate() {
                    c_chunk[(i + r) * n + j] = scale * dv;
                }
            }
            i += MR;
        }
        for ii in i..rows {
            let a_row = &a_panel[ii * k..(ii + 1) * k];
            for j in j0..j1 {
                c_chunk[ii * n + j] = scale * dot_scalar(a_row, &b[j * k..(j + 1) * k]);
            }
        }
    }
}

#[cfg(target_arch = "x86_64")]
pub(super) mod x86 {
    //! AVX2 / FMA variants. All functions here require the caller to have
    //! verified the matching CPU features (see `super::super::dispatch`).
    use super::{COL_BLOCK, MR};
    use core::arch::x86_64::*;

    /// Horizontal sum of one 256-bit accumulator in the exact order of
    /// [`hsum_scalar`]: `extractf128` splits the lanes into `(l0..l3)` and
    /// `(l4..l7)`, the `add` forms `l_i + l_{i+4}`, the `0b1011_0001`
    /// shuffle pairs neighbors for `(l0+l4)+(l1+l5)` and
    /// `(l2+l6)+(l3+l7)`, and `movehl`+`add_ss` performs the final outer
    /// add — the same tree, same operand order, bit for bit.
    ///
    /// # Safety
    /// Requires AVX2 at runtime.
    #[target_feature(enable = "avx2")]
    unsafe fn hsum_avx2(acc: __m256) -> f32 {
        let lo = _mm256_castps256_ps128(acc);
        let hi = _mm256_extractf128_ps(acc, 1);
        let s = _mm_add_ps(lo, hi);
        let t = _mm_shuffle_ps(s, s, 0b1011_0001);
        let u = _mm_add_ps(s, t);
        let v = _mm_movehl_ps(u, u);
        _mm_cvtss_f32(_mm_add_ss(u, v))
    }

    /// AVX2 twin of [`dot_scalar`], bit-identical by construction:
    /// mul+add (two roundings, no contraction), one-register lane
    /// accumulator, [`hsum_avx2`] fold, sequential scalar tail.
    ///
    /// # Safety
    /// Requires AVX2 at runtime. `a.len() == b.len()`.
    #[target_feature(enable = "avx2")]
    pub(crate) unsafe fn dot_avx2(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let n8 = a.len() / 8 * 8;
        let mut acc = _mm256_setzero_ps();
        let pa = a.as_ptr();
        let pb = b.as_ptr();
        let mut i = 0usize;
        while i < n8 {
            let va = _mm256_loadu_ps(pa.add(i));
            let vb = _mm256_loadu_ps(pb.add(i));
            acc = _mm256_add_ps(acc, _mm256_mul_ps(va, vb));
            i += 8;
        }
        let mut tail = 0f32;
        for j in n8..a.len() {
            tail += a[j] * b[j];
        }
        hsum_avx2(acc) + tail
    }

    /// FMA variant of [`dot_scalar`]: contracts mul+add into `fmadd` (one
    /// rounding per lane update). Faster, **not** bit-identical — only
    /// reachable in the opt-in `Fast` kernel mode.
    ///
    /// # Safety
    /// Requires AVX2 and FMA at runtime. `a.len() == b.len()`.
    #[target_feature(enable = "avx2,fma")]
    pub(crate) unsafe fn dot_fma(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let n8 = a.len() / 8 * 8;
        let mut acc = _mm256_setzero_ps();
        let pa = a.as_ptr();
        let pb = b.as_ptr();
        let mut i = 0usize;
        while i < n8 {
            let va = _mm256_loadu_ps(pa.add(i));
            let vb = _mm256_loadu_ps(pb.add(i));
            acc = _mm256_fmadd_ps(va, vb, acc);
            i += 8;
        }
        let mut tail = 0f32;
        for j in n8..a.len() {
            tail = a[j].mul_add(b[j], tail);
        }
        hsum_avx2(acc) + tail
    }

    /// AVX2 twin of [`dot4_scalar`]: four row accumulators share each
    /// loaded `B` vector (the 4-row × 8-wide register tile). Per-row
    /// arithmetic is exactly [`dot_avx2`]: each tail accumulates in its
    /// own scalar and is added to the lane fold once at the end —
    /// `hsum + tail`, never `(hsum + t1) + t2` — so the tile is
    /// bit-neutral.
    ///
    /// # Safety
    /// Requires AVX2 at runtime. All `a*` rows and `b` have equal length.
    #[target_feature(enable = "avx2")]
    unsafe fn dot4_avx2(a0: &[f32], a1: &[f32], a2: &[f32], a3: &[f32], b: &[f32]) -> [f32; 4] {
        let len = b.len();
        let n8 = len / 8 * 8;
        let mut acc0 = _mm256_setzero_ps();
        let mut acc1 = _mm256_setzero_ps();
        let mut acc2 = _mm256_setzero_ps();
        let mut acc3 = _mm256_setzero_ps();
        let (p0, p1, p2, p3, pb) = (a0.as_ptr(), a1.as_ptr(), a2.as_ptr(), a3.as_ptr(), b.as_ptr());
        let mut i = 0usize;
        while i < n8 {
            let vb = _mm256_loadu_ps(pb.add(i));
            acc0 = _mm256_add_ps(acc0, _mm256_mul_ps(_mm256_loadu_ps(p0.add(i)), vb));
            acc1 = _mm256_add_ps(acc1, _mm256_mul_ps(_mm256_loadu_ps(p1.add(i)), vb));
            acc2 = _mm256_add_ps(acc2, _mm256_mul_ps(_mm256_loadu_ps(p2.add(i)), vb));
            acc3 = _mm256_add_ps(acc3, _mm256_mul_ps(_mm256_loadu_ps(p3.add(i)), vb));
            i += 8;
        }
        let (mut t0, mut t1, mut t2, mut t3) = (0f32, 0f32, 0f32, 0f32);
        for j in n8..len {
            t0 += a0[j] * b[j];
            t1 += a1[j] * b[j];
            t2 += a2[j] * b[j];
            t3 += a3[j] * b[j];
        }
        [hsum_avx2(acc0) + t0, hsum_avx2(acc1) + t1, hsum_avx2(acc2) + t2, hsum_avx2(acc3) + t3]
    }

    /// FMA variant of [`dot4_scalar`] (Fast mode only).
    ///
    /// # Safety
    /// Requires AVX2 and FMA at runtime. All rows and `b` equal length.
    #[target_feature(enable = "avx2,fma")]
    unsafe fn dot4_fma(a0: &[f32], a1: &[f32], a2: &[f32], a3: &[f32], b: &[f32]) -> [f32; 4] {
        let len = b.len();
        let n8 = len / 8 * 8;
        let mut acc0 = _mm256_setzero_ps();
        let mut acc1 = _mm256_setzero_ps();
        let mut acc2 = _mm256_setzero_ps();
        let mut acc3 = _mm256_setzero_ps();
        let (p0, p1, p2, p3, pb) = (a0.as_ptr(), a1.as_ptr(), a2.as_ptr(), a3.as_ptr(), b.as_ptr());
        let mut i = 0usize;
        while i < n8 {
            let vb = _mm256_loadu_ps(pb.add(i));
            acc0 = _mm256_fmadd_ps(_mm256_loadu_ps(p0.add(i)), vb, acc0);
            acc1 = _mm256_fmadd_ps(_mm256_loadu_ps(p1.add(i)), vb, acc1);
            acc2 = _mm256_fmadd_ps(_mm256_loadu_ps(p2.add(i)), vb, acc2);
            acc3 = _mm256_fmadd_ps(_mm256_loadu_ps(p3.add(i)), vb, acc3);
            i += 8;
        }
        let (mut t0, mut t1, mut t2, mut t3) = (0f32, 0f32, 0f32, 0f32);
        for j in n8..len {
            t0 = a0[j].mul_add(b[j], t0);
            t1 = a1[j].mul_add(b[j], t1);
            t2 = a2[j].mul_add(b[j], t2);
            t3 = a3[j].mul_add(b[j], t3);
        }
        [hsum_avx2(acc0) + t0, hsum_avx2(acc1) + t1, hsum_avx2(acc2) + t2, hsum_avx2(acc3) + t3]
    }

    /// AVX2 twin of [`axpy_scalar`]: `c[j] += s * b[j]`, elementwise and
    /// in ascending `j`, so identical bits per element.
    ///
    /// # Safety
    /// Requires AVX2 at runtime. `c_row.len() == b_row.len()`.
    #[target_feature(enable = "avx2")]
    pub(crate) unsafe fn axpy_avx2(c_row: &mut [f32], b_row: &[f32], s: f32) {
        debug_assert_eq!(c_row.len(), b_row.len());
        let len = c_row.len();
        let n8 = len / 8 * 8;
        let vs = _mm256_set1_ps(s);
        let pc = c_row.as_mut_ptr();
        let pb = b_row.as_ptr();
        let mut j = 0usize;
        while j < n8 {
            let vc = _mm256_loadu_ps(pc.add(j));
            let vb = _mm256_loadu_ps(pb.add(j));
            _mm256_storeu_ps(pc.add(j), _mm256_add_ps(vc, _mm256_mul_ps(vs, vb)));
            j += 8;
        }
        for jj in n8..len {
            c_row[jj] += s * b_row[jj];
        }
    }

    /// FMA variant of [`axpy_scalar`] (Fast mode only).
    ///
    /// # Safety
    /// Requires AVX2 and FMA at runtime. `c_row.len() == b_row.len()`.
    #[target_feature(enable = "avx2,fma")]
    pub(crate) unsafe fn axpy_fma(c_row: &mut [f32], b_row: &[f32], s: f32) {
        debug_assert_eq!(c_row.len(), b_row.len());
        let len = c_row.len();
        let n8 = len / 8 * 8;
        let vs = _mm256_set1_ps(s);
        let pc = c_row.as_mut_ptr();
        let pb = b_row.as_ptr();
        let mut j = 0usize;
        while j < n8 {
            let vc = _mm256_loadu_ps(pc.add(j));
            let vb = _mm256_loadu_ps(pb.add(j));
            _mm256_storeu_ps(pc.add(j), _mm256_fmadd_ps(vs, vb, vc));
            j += 8;
        }
        for jj in n8..len {
            c_row[jj] = s.mul_add(b_row[jj], c_row[jj]);
        }
    }

    /// AVX2 twin of [`panel_bt_scalar`]: same column blocks, same 4-row
    /// register tile, per-element arithmetic delegated to
    /// [`dot4_avx2`] / [`dot_avx2`] — bit-identical to the portable panel.
    ///
    /// # Safety
    /// Requires AVX2 at runtime. Shapes as in [`panel_bt_scalar`].
    #[target_feature(enable = "avx2")]
    pub(crate) unsafe fn panel_bt_avx2(
        a_panel: &[f32],
        b: &[f32],
        c_chunk: &mut [f32],
        n: usize,
        k: usize,
        scale: f32,
    ) {
        let rows = c_chunk.len() / n;
        for j0 in (0..n).step_by(COL_BLOCK) {
            let j1 = (j0 + COL_BLOCK).min(n);
            let mut i = 0usize;
            while i + MR <= rows {
                for j in j0..j1 {
                    let br = &b[j * k..(j + 1) * k];
                    let d = dot4_avx2(
                        &a_panel[i * k..(i + 1) * k],
                        &a_panel[(i + 1) * k..(i + 2) * k],
                        &a_panel[(i + 2) * k..(i + 3) * k],
                        &a_panel[(i + 3) * k..(i + 4) * k],
                        br,
                    );
                    for (r, dv) in d.iter().enumerate() {
                        c_chunk[(i + r) * n + j] = scale * dv;
                    }
                }
                i += MR;
            }
            for ii in i..rows {
                let a_row = &a_panel[ii * k..(ii + 1) * k];
                for j in j0..j1 {
                    c_chunk[ii * n + j] = scale * dot_avx2(a_row, &b[j * k..(j + 1) * k]);
                }
            }
        }
    }

    /// FMA variant of [`panel_bt_scalar`] (Fast mode only).
    ///
    /// # Safety
    /// Requires AVX2 and FMA at runtime. Shapes as in
    /// [`panel_bt_scalar`].
    #[target_feature(enable = "avx2,fma")]
    pub(crate) unsafe fn panel_bt_fma(
        a_panel: &[f32],
        b: &[f32],
        c_chunk: &mut [f32],
        n: usize,
        k: usize,
        scale: f32,
    ) {
        let rows = c_chunk.len() / n;
        for j0 in (0..n).step_by(COL_BLOCK) {
            let j1 = (j0 + COL_BLOCK).min(n);
            let mut i = 0usize;
            while i + MR <= rows {
                for j in j0..j1 {
                    let br = &b[j * k..(j + 1) * k];
                    let d = dot4_fma(
                        &a_panel[i * k..(i + 1) * k],
                        &a_panel[(i + 1) * k..(i + 2) * k],
                        &a_panel[(i + 2) * k..(i + 3) * k],
                        &a_panel[(i + 3) * k..(i + 4) * k],
                        br,
                    );
                    for (r, dv) in d.iter().enumerate() {
                        c_chunk[(i + r) * n + j] = scale * dv;
                    }
                }
                i += MR;
            }
            for ii in i..rows {
                let a_row = &a_panel[ii * k..(ii + 1) * k];
                for j in j0..j1 {
                    c_chunk[ii * n + j] = scale * dot_fma(a_row, &b[j * k..(j + 1) * k]);
                }
            }
        }
    }
}
