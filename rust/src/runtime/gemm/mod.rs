//! Cache-blocked, bit-deterministic f32 GEMM + attention kernels for the
//! reference interpreter's batched hot path — SIMD-dispatched, with FP8
//! quantization fusable into the operand pack step.
//!
//! Two GEMM shapes cover every dense product the interpreter needs:
//!
//!  - [`matmul_bt`]: `C = s · A @ Bᵀ` with the right-hand matrix stored
//!    row-per-output-column, so both operands stream contiguously (the
//!    forward hidden layers, the LM head, and the activation-gradient
//!    products all fit this after a one-time weight transpose);
//!  - [`add_matmul_at_b`]: `C += s · Aᵀ @ B`, accumulated as rank-1
//!    updates in ascending row order (the weight-gradient products).
//!
//! The layer is split into three files:
//!
//!  - `kernels.rs` — the inner microkernels: portable unrolled scalar
//!    twins (`*_scalar`) and their AVX2 / FMA variants, 4-row × 8-wide
//!    register tiles, the only file where `core::arch` intrinsics are
//!    allowed (lint-enforced);
//!  - `dispatch.rs` — runtime CPU-feature detection, the default
//!    [`KernelMode::Deterministic`] vs opt-in [`KernelMode::Fast`] (FMA)
//!    mode, and the one-time `kernel dispatch: path=...` stderr line;
//!  - this module — shape checks, parallel chunking, and the fused
//!    cast-into-GEMM entry points [`matmul_bt_quant`] /
//!    [`quant_transpose`] that run the caller's FP8 rounding closure over
//!    each operand panel exactly once, inside the pack step, instead of
//!    materializing a quantized tensor in a separate pass.
//!
//! Packing, in this layer, is layout-light: `B` is stored transposed
//! (row `j` holds logical column `j`), which *is* the packed layout — row
//! `j` streams contiguously through the register tile with unit stride,
//! so the per-call B "pack" is the identity and costs nothing. `A` is
//! consumed in row panels of [`ROW_CHUNK_BT`] rows; the fused entry
//! points apply the quantization closure to each panel right before the
//! panel's GEMM, while it is hot in cache.
//!
//! [`attn_forward_causal`] / [`attn_backward_causal`] are the per-head
//! causal softmax-attention kernels of the op-level transformer block
//! (`runtime/block.rs`), and [`attn_decode_cached`] is the single-query
//! cached-attention kernel of the KV-cache decode path — all three run
//! their score/softmax/value math through the one shared
//! [`attn_one_query`] routine, so train/prefill and decode share the
//! attention arithmetic by construction. They are deliberately
//! single-threaded: callers parallelize over (batch, head) — or, for
//! decode, (sequence, head) — pairs with fixed chunk boundaries, and each
//! head's math runs in one fixed serial order, so attention inherits the
//! same any-thread-count bit-determinism as the GEMMs.
//!
//! Determinism contract (matches [`crate::util::parallel`]): every output
//! element is produced by exactly one chunk, the inner accumulation order
//! is fixed by the kernel (eight stride-8 lanes folded in a fixed tree,
//! then the tail), and chunk boundaries never depend on the thread count —
//! so results are bit-identical across any number of worker threads. The
//! eight-lane fold tree is exactly one 256-bit register, so the AVX2 path
//! reproduces the scalar reduction order bit for bit (mul+add, no FP
//! contraction) — SIMD changes the speed, never the bits, on the default
//! path. See `docs/KERNELS.md` for the full equivalence argument.

mod dispatch;
mod kernels;

pub use dispatch::{
    force_portable_kernels, kernel_mode, kernel_path, kernel_path_lock, set_kernel_mode,
    KernelMode, KernelPath, KernelPathGuard,
};

use crate::util::parallel;

/// Rows of `A`/`C` per parallel chunk of [`matmul_bt`] and the fused
/// [`matmul_bt_quant`] (which packs `A` in panels of this many rows).
const ROW_CHUNK_BT: usize = 16;
/// Rows of `C` per parallel chunk of [`add_matmul_at_b`].
const ROW_CHUNK_ATB: usize = 8;

/// Fixed-order dot product on the resolved kernel path. All paths share
/// the lane partition and fold tree of the scalar kernel; only the
/// `Fast` (FMA) path may differ bitwise.
#[inline]
fn dot(a: &[f32], b: &[f32]) -> f32 {
    #[cfg(target_arch = "x86_64")]
    {
        match dispatch::kernel_path() {
            // SAFETY: kernel_path() returns these only when the CPU
            // reports the matching features at runtime.
            KernelPath::Avx2 => return unsafe { kernels::x86::dot_avx2(a, b) },
            KernelPath::Avx2Fma => return unsafe { kernels::x86::dot_fma(a, b) },
            KernelPath::Portable => {}
        }
    }
    kernels::dot_scalar(a, b)
}

/// Run the `C = s · A @ Bᵀ` row-panel kernel for one chunk on `path`.
#[cfg_attr(not(target_arch = "x86_64"), allow(unused_variables))]
#[inline]
fn run_panel_bt(
    path: KernelPath,
    a_panel: &[f32],
    b: &[f32],
    c_chunk: &mut [f32],
    n: usize,
    k: usize,
    scale: f32,
) {
    #[cfg(target_arch = "x86_64")]
    {
        match path {
            // SAFETY: `path` came from kernel_path(), which verified the
            // CPU features at runtime.
            KernelPath::Avx2 => {
                return unsafe { kernels::x86::panel_bt_avx2(a_panel, b, c_chunk, n, k, scale) };
            }
            KernelPath::Avx2Fma => {
                return unsafe { kernels::x86::panel_bt_fma(a_panel, b, c_chunk, n, k, scale) };
            }
            KernelPath::Portable => {}
        }
    }
    kernels::panel_bt_scalar(a_panel, b, c_chunk, n, k, scale)
}

/// `c_row[j] += s * b_row[j]` on `path` — elementwise, so every path is
/// bit-identical except opt-in FMA.
#[cfg_attr(not(target_arch = "x86_64"), allow(unused_variables))]
#[inline]
fn run_axpy(path: KernelPath, c_row: &mut [f32], b_row: &[f32], s: f32) {
    #[cfg(target_arch = "x86_64")]
    {
        match path {
            // SAFETY: `path` came from kernel_path(), which verified the
            // CPU features at runtime.
            KernelPath::Avx2 => return unsafe { kernels::x86::axpy_avx2(c_row, b_row, s) },
            KernelPath::Avx2Fma => return unsafe { kernels::x86::axpy_fma(c_row, b_row, s) },
            KernelPath::Portable => {}
        }
    }
    kernels::axpy_scalar(c_row, b_row, s)
}

/// `C[i,j] = scale * Σ_k A[i,k] · B[j,k]` — i.e. `C = scale · A @ Bᵀ`
/// with `B` stored transposed (row `j` of `b` holds logical column `j`).
/// `a` is `[m,k]`, `b` is `[n,k]`, `c` is `[m,n]`, all row-major.
/// Overwrites `c`. Parallel over row chunks of `c`; column blocks keep the
/// active `b` rows hot in cache. The kernel path (AVX2 / portable) is
/// resolved once per call and shared by every worker thread.
pub fn matmul_bt(a: &[f32], b: &[f32], c: &mut [f32], m: usize, n: usize, k: usize, scale: f32) {
    assert_eq!(a.len(), m * k, "matmul_bt: A is not [m,k]");
    assert_eq!(b.len(), n * k, "matmul_bt: B is not [n,k]");
    assert_eq!(c.len(), m * n, "matmul_bt: C is not [m,n]");
    if m == 0 || n == 0 {
        return;
    }
    let path = dispatch::kernel_path();
    dispatch::log_once(path);
    let threads = parallel::threads_for(2 * (m as u64) * (n as u64) * (k as u64));
    parallel::par_chunks_mut(c, ROW_CHUNK_BT * n, threads, |ci, c_chunk| {
        let i0 = ci * ROW_CHUNK_BT;
        let rows = c_chunk.len() / n;
        run_panel_bt(path, &a[i0 * k..(i0 + rows) * k], b, c_chunk, n, k, scale);
    });
}

/// Fused cast-into-GEMM: quantize `a` in place, panel by panel, then
/// `C = scale · A @ Bᵀ` — one pass over the activations instead of a
/// separate full-tensor quantize sweep followed by the GEMM.
///
/// `pack` is applied to each [`ROW_CHUNK_BT`]-row panel of `a` exactly
/// once, immediately before that panel's GEMM, while the panel is hot in
/// cache. It must be **elementwise** (each output element a function of
/// the input element alone — the `fp8::FastCast` rounding closures are),
/// which makes the fused result bit-identical to quantize-then-GEMM
/// regardless of panel boundaries. On return, `a` holds the fully packed
/// (quantized) operand — callers save it for the backward pass.
///
/// Degenerate shapes keep both postconditions: `n == 0` still packs all
/// of `a` (the saved operand feeds the weight-gradient GEMM even when
/// there is no output to compute), and `k == 0` fills `c` exactly like
/// [`matmul_bt`].
#[allow(clippy::too_many_arguments)]
pub fn matmul_bt_quant<P>(
    a: &mut [f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    n: usize,
    k: usize,
    scale: f32,
    pack: P,
) where
    P: Fn(&mut [f32]) + Sync,
{
    assert_eq!(a.len(), m * k, "matmul_bt_quant: A is not [m,k]");
    assert_eq!(b.len(), n * k, "matmul_bt_quant: B is not [n,k]");
    assert_eq!(c.len(), m * n, "matmul_bt_quant: C is not [m,n]");
    if m == 0 || n == 0 || k == 0 {
        // Nothing to fuse: pack whatever `a` holds (the packed operand is
        // a postcondition even without output rows), then defer to the
        // plain GEMM for the `k == 0` fill semantics.
        if !a.is_empty() {
            let threads = parallel::threads_for(a.len() as u64 * 8);
            parallel::par_chunks_mut(a, ROW_CHUNK_BT * k.max(1), threads, |_, panel| pack(panel));
        }
        matmul_bt(a, b, c, m, n, k, scale);
        return;
    }
    let path = dispatch::kernel_path();
    dispatch::log_once(path);
    let threads = parallel::threads_for(2 * (m as u64) * (n as u64) * (k as u64));
    // C chunk i covers the same rows as A panel i, so pack-then-multiply
    // stays a single pass per panel; chunk counts agree by construction
    // (both are ceil(m / ROW_CHUNK_BT)).
    parallel::par_join2(c, a, ROW_CHUNK_BT * n, ROW_CHUNK_BT * k, threads, |_, c_chunk, a_panel| {
        pack(a_panel);
        run_panel_bt(path, a_panel, b, c_chunk, n, k, scale);
    });
}

/// `C[i,j] += scale * Σ_r A[r,i] · B[r,j]` — i.e. `C += scale · Aᵀ @ B`.
/// `a` is `[r,p]`, `b` is `[r,n]`, `c` is `[p,n]`, all row-major.
/// Accumulates into `c` as rank-1 updates in ascending `r` order (each
/// output element's addition sequence is fixed regardless of threading).
/// Rows of `a` whose entry is exactly 0 are skipped — the added term would
/// be `0 * B[r,j]`, and the interpreter's quantized gradients are often
/// sparse enough for this to matter. The row update is elementwise, so
/// the SIMD path is bit-identical per element.
pub fn add_matmul_at_b(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    r: usize,
    p: usize,
    n: usize,
    scale: f32,
) {
    assert_eq!(a.len(), r * p, "add_matmul_at_b: A is not [r,p]");
    assert_eq!(b.len(), r * n, "add_matmul_at_b: B is not [r,n]");
    assert_eq!(c.len(), p * n, "add_matmul_at_b: C is not [p,n]");
    if p == 0 || n == 0 || r == 0 {
        return;
    }
    let path = dispatch::kernel_path();
    dispatch::log_once(path);
    let threads = parallel::threads_for(2 * (r as u64) * (p as u64) * (n as u64));
    parallel::par_chunks_mut(c, ROW_CHUNK_ATB * n, threads, |ci, c_chunk| {
        let i0 = ci * ROW_CHUNK_ATB;
        let rows = c_chunk.len() / n;
        for rr in 0..r {
            let a_row = &a[rr * p..(rr + 1) * p];
            let b_row = &b[rr * n..(rr + 1) * n];
            for i in 0..rows {
                let s = scale * a_row[i0 + i];
                if s == 0.0 {
                    continue;
                }
                run_axpy(path, &mut c_chunk[i * n..(i + 1) * n], b_row, s);
            }
        }
    });
}

/// Softmax attention of ONE query against the first `len` K/V rows —
/// the shared inner kernel of both attention entry points:
/// [`attn_forward_causal`] calls it per row (training / prefill, query
/// `i` with `len = i + 1`) and [`attn_decode_cached`] calls it once per
/// decode step against the gathered KV cache. One implementation, one
/// accumulation order — a decode step is bit-identical to the matching
/// row of the full-sequence forward when its operands are.
///
/// `q` is `[dh]`, `k`/`v` are `[len, dh]` row-major. Writes the
/// post-softmax weights into `scores` (`[len]`) and the attended values
/// into `o` (`[dh]`). Numerically stable (max subtraction); the softmax
/// denominator accumulates in f64 over ascending `j`, so the result is a
/// fixed function of the inputs — single-threaded by design, see module
/// docs.
pub fn attn_one_query(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    len: usize,
    dh: usize,
    scale: f32,
    scores: &mut [f32],
    o: &mut [f32],
) {
    debug_assert_eq!(q.len(), dh);
    debug_assert_eq!(scores.len(), len);
    debug_assert!(k.len() >= len * dh && v.len() >= len * dh);
    let mut m = f32::NEG_INFINITY;
    for j in 0..len {
        let sc = scale * dot(q, &k[j * dh..(j + 1) * dh]);
        scores[j] = sc;
        m = m.max(sc);
    }
    let mut den = 0f64;
    for p in scores.iter_mut() {
        let e = (*p - m).exp();
        *p = e;
        den += e as f64;
    }
    let inv = (1.0 / den) as f32;
    for p in scores.iter_mut() {
        *p *= inv;
    }
    o[..dh].fill(0.0);
    for j in 0..len {
        let p = scores[j];
        if p == 0.0 {
            continue;
        }
        let vj = &v[j * dh..(j + 1) * dh];
        for (ov, &vv) in o[..dh].iter_mut().zip(vj) {
            *ov += p * vv;
        }
    }
}

/// Causal softmax attention, forward, for one (batch, head) pair.
///
/// `q`, `k`, `v` are `[s, dh]` row-major (RoPE already applied to q/k by
/// the caller). Writes the post-softmax weights into `probs` (`[s, s]`,
/// strict upper triangle zeroed — saved for the backward pass) and the
/// attended values into `o` (`[s, dh]`): `o_i = Σ_{j≤i} P_ij · v_j` with
/// `P_i = softmax(scale · q_i · k_{0..=i})`. Each row runs through
/// [`attn_one_query`] — the same kernel the KV-cache decode path uses.
pub fn attn_forward_causal(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    probs: &mut [f32],
    o: &mut [f32],
    s: usize,
    dh: usize,
    scale: f32,
) {
    assert_eq!(q.len(), s * dh, "attn_forward_causal: q is not [s,dh]");
    assert_eq!(k.len(), s * dh, "attn_forward_causal: k is not [s,dh]");
    assert_eq!(v.len(), s * dh, "attn_forward_causal: v is not [s,dh]");
    assert_eq!(probs.len(), s * s, "attn_forward_causal: probs is not [s,s]");
    assert_eq!(o.len(), s * dh, "attn_forward_causal: o is not [s,dh]");
    for i in 0..s {
        let prow = &mut probs[i * s..(i + 1) * s];
        attn_one_query(
            &q[i * dh..(i + 1) * dh],
            k,
            v,
            i + 1,
            dh,
            scale,
            &mut prow[..=i],
            &mut o[i * dh..(i + 1) * dh],
        );
        for p in prow[i + 1..].iter_mut() {
            *p = 0.0;
        }
    }
}

/// Reinterpret BF16 bits as f32 (BF16 is the upper half of an f32).
#[inline]
pub fn bf16_to_f32(bits: u16) -> f32 {
    f32::from_bits((bits as u32) << 16)
}

/// Truncate an f32 that is already on the BF16 grid to its BF16 bits.
/// Lossless for values the interpreter BF16-rounds before caching.
#[inline]
pub fn f32_to_bf16_bits(v: f32) -> u16 {
    (v.to_bits() >> 16) as u16
}

/// How cached K/V page bytes decode back to f32 attention operands —
/// the two storage modes of the paged KV cache (`runtime::kvcache`).
#[derive(Debug, Clone, Copy)]
pub enum KvCodec<'a> {
    /// Two bytes per value: little-endian BF16 bits. Lossless for the
    /// BF16-rounded operands the tower produces, so this codec preserves
    /// the decode-equals-training-forward bit match.
    Bf16,
    /// One byte per value: E4M3 bits at static µS scale 1.0, decoded
    /// through the format's 256-entry table
    /// ([`crate::fp8::Format::decode_lut8`]) — the same oracle the encode
    /// side is verified against. Halves cache bytes; not bit-identical
    /// (the E4M3 grid is coarser than BF16), so callers bound the logit
    /// divergence instead.
    Fp8E4m3(&'a [f32; 256]),
}

impl KvCodec<'_> {
    /// Bytes per stored cache value under this codec.
    pub fn bytes_per_value(&self) -> usize {
        match self {
            KvCodec::Bf16 => 2,
            KvCodec::Fp8E4m3(_) => 1,
        }
    }
}

/// Decode one run of cache bytes into f32 values under `codec`. `src`
/// must hold exactly `dst.len() * codec.bytes_per_value()` bytes.
pub fn decode_kv_bytes(codec: KvCodec<'_>, src: &[u8], dst: &mut [f32]) {
    debug_assert_eq!(src.len(), dst.len() * codec.bytes_per_value());
    match codec {
        KvCodec::Bf16 => {
            for (d, b) in dst.iter_mut().zip(src.chunks_exact(2)) {
                *d = bf16_to_f32(u16::from_le_bytes([b[0], b[1]]));
            }
        }
        KvCodec::Fp8E4m3(lut) => {
            for (d, &b) in dst.iter_mut().zip(src) {
                *d = lut[b as usize];
            }
        }
    }
}

/// Single-query cached attention for one (sequence, head) pair — the
/// decode-path kernel. `q` is `[dh]` (RoPE already applied at the query's
/// absolute position); the K/V history comes as ordered lists of byte
/// pages (each `[page_rows, dh]` row-major under `codec`, see
/// `runtime::kvcache`) whose rows concatenate to the sequence's first
/// `len` cached positions.
///
/// The pages are gathered into the `kf`/`vf` f32 scratch (`[len, dh]`
/// each) via [`decode_kv_bytes`] and scored by [`attn_one_query`] — the
/// same inner kernel the full-sequence causal forward uses, in the same
/// accumulation order, so under the BF16 codec a decode step reproduces
/// the matching training-forward row bit for bit (the cache stores
/// BF16-rounded operands, and BF16 → f32 is exact). Serial by design:
/// callers parallelize over (sequence, head) pairs with fixed chunk
/// boundaries, preserving any-thread-count bit-determinism.
#[allow(clippy::too_many_arguments)]
pub fn attn_decode_cached(
    q: &[f32],
    k_pages: &[&[u8]],
    v_pages: &[&[u8]],
    len: usize,
    dh: usize,
    scale: f32,
    codec: KvCodec<'_>,
    kf: &mut [f32],
    vf: &mut [f32],
    scores: &mut [f32],
    o: &mut [f32],
) {
    assert_eq!(q.len(), dh, "attn_decode_cached: q is not [dh]");
    assert!(kf.len() >= len * dh, "attn_decode_cached: kf scratch too small");
    assert!(vf.len() >= len * dh, "attn_decode_cached: vf scratch too small");
    assert!(scores.len() >= len, "attn_decode_cached: scores scratch too small");
    let bpv = codec.bytes_per_value();
    let mut row = 0usize;
    for (kp, vp) in k_pages.iter().zip(v_pages) {
        debug_assert_eq!(kp.len(), vp.len());
        let n = (kp.len() / (dh * bpv)).min(len - row);
        decode_kv_bytes(codec, &kp[..n * dh * bpv], &mut kf[row * dh..(row + n) * dh]);
        decode_kv_bytes(codec, &vp[..n * dh * bpv], &mut vf[row * dh..(row + n) * dh]);
        row += n;
        if row == len {
            break;
        }
    }
    assert_eq!(row, len, "attn_decode_cached: pages hold {row} rows, need {len}");
    attn_one_query(q, kf, vf, len, dh, scale, &mut scores[..len], o);
}

/// Backward of [`attn_forward_causal`] for one (batch, head) pair.
///
/// Given the upstream gradient `d_o` `[s, dh]` and the saved `probs`,
/// overwrites `dq`, `dk`, `dv` (`[s, dh]` each) with the gradients at the
/// (post-RoPE) q/k and v. Standard softmax-attention backward:
/// `dP_ij = do_i · v_j`, `dS_ij = P_ij (dP_ij − Σ_j P_ij dP_ij)`,
/// `dq_i = scale · Σ_j dS_ij k_j`, `dk_j = scale · Σ_i dS_ij q_i`,
/// `dv_j = Σ_i P_ij do_i`. Accumulation runs in ascending `i` then `j`
/// order — fixed, thread-count independent (callers parallelize over
/// heads only).
#[allow(clippy::too_many_arguments)]
pub fn attn_backward_causal(
    d_o: &[f32],
    probs: &[f32],
    q: &[f32],
    k: &[f32],
    v: &[f32],
    dq: &mut [f32],
    dk: &mut [f32],
    dv: &mut [f32],
    s: usize,
    dh: usize,
    scale: f32,
) {
    assert_eq!(d_o.len(), s * dh, "attn_backward_causal: d_o is not [s,dh]");
    assert_eq!(probs.len(), s * s, "attn_backward_causal: probs is not [s,s]");
    assert_eq!(q.len(), s * dh, "attn_backward_causal: q is not [s,dh]");
    assert_eq!(k.len(), s * dh, "attn_backward_causal: k is not [s,dh]");
    assert_eq!(v.len(), s * dh, "attn_backward_causal: v is not [s,dh]");
    assert_eq!(dq.len(), s * dh, "attn_backward_causal: dq is not [s,dh]");
    assert_eq!(dk.len(), s * dh, "attn_backward_causal: dk is not [s,dh]");
    assert_eq!(dv.len(), s * dh, "attn_backward_causal: dv is not [s,dh]");
    dq.fill(0.0);
    dk.fill(0.0);
    dv.fill(0.0);
    let mut dp = vec![0f32; s];
    for i in 0..s {
        let doi = &d_o[i * dh..(i + 1) * dh];
        let prow = &probs[i * s..(i + 1) * s];
        let mut pdot = 0f64;
        for j in 0..=i {
            let g = dot(doi, &v[j * dh..(j + 1) * dh]);
            dp[j] = g;
            pdot += (prow[j] * g) as f64;
        }
        let pdot = pdot as f32;
        let qi = &q[i * dh..(i + 1) * dh];
        let dqi = &mut dq[i * dh..(i + 1) * dh];
        for j in 0..=i {
            let p = prow[j];
            let ds = scale * p * (dp[j] - pdot);
            let kj = &k[j * dh..(j + 1) * dh];
            for c in 0..dh {
                dqi[c] += ds * kj[c];
            }
            let dkj = &mut dk[j * dh..(j + 1) * dh];
            for c in 0..dh {
                dkj[c] += ds * qi[c];
            }
            if p != 0.0 {
                let dvj = &mut dv[j * dh..(j + 1) * dh];
                for c in 0..dh {
                    dvj[c] += p * doi[c];
                }
            }
        }
    }
}

/// Chunk length for the telemetry reductions below (fixed — boundaries
/// are a function of buffer length only, like every kernel here).
const REDUCE_CHUNK: usize = 1 << 14;

/// Deterministic f64 sum of squares: fixed `REDUCE_CHUNK` chunks mapped
/// (possibly in parallel) and folded in ascending chunk order, so the
/// result is bit-identical at any worker-thread count. This is the
/// reduction behind the telemetry sink's per-op RMS records
/// (`crate::telemetry`) — it shares the determinism contract of the GEMM
/// kernels so enabling telemetry can never observe thread-dependent
/// values.
pub fn sum_sq(xs: &[f32]) -> f64 {
    parallel::par_map_reduce(
        xs.len(),
        REDUCE_CHUNK,
        parallel::threads_for(xs.len() as u64 * 2),
        |_, r| xs[r].iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>(),
        |a, b| a + b,
        0f64,
    )
}

/// Fused deterministic (Σx², max|x|) over one pass — what a telemetry RMS
/// record needs, at half the traversal cost of calling [`sum_sq`] and
/// [`abs_max`] separately. Same fixed-chunk ascending fold; NaN elements
/// are ignored by the max (like a TE amax reduce).
pub fn sum_sq_abs_max(xs: &[f32]) -> (f64, f32) {
    parallel::par_map_reduce(
        xs.len(),
        REDUCE_CHUNK,
        parallel::threads_for(xs.len() as u64 * 3),
        |_, r| {
            let mut ss = 0f64;
            let mut am = 0f32;
            for &x in &xs[r] {
                ss += (x as f64) * (x as f64);
                am = am.max(x.abs());
            }
            (ss, am)
        },
        |(ss_a, am_a), (ss_b, am_b)| (ss_a + ss_b, am_a.max(am_b)),
        (0f64, 0f32),
    )
}

/// Deterministic absolute maximum over a slice (0 for empty; NaN elements
/// are ignored, like a TE amax reduce). Same fixed-chunk fold as
/// [`sum_sq`].
pub fn abs_max(xs: &[f32]) -> f32 {
    parallel::par_map_reduce(
        xs.len(),
        REDUCE_CHUNK,
        parallel::threads_for(xs.len() as u64),
        |_, r| xs[r].iter().fold(0f32, |m, x| m.max(x.abs())),
        f32::max,
        0f32,
    )
}

/// Blocked out-of-place transpose: `dst[c*rows + r] = src[r*cols + c]`.
pub fn transpose(src: &[f32], rows: usize, cols: usize, dst: &mut [f32]) {
    assert_eq!(src.len(), rows * cols, "transpose: src is not [rows,cols]");
    assert_eq!(dst.len(), rows * cols, "transpose: dst is not [cols,rows]");
    const TB: usize = 32;
    for r0 in (0..rows).step_by(TB) {
        let r1 = (r0 + TB).min(rows);
        for c0 in (0..cols).step_by(TB) {
            let c1 = (c0 + TB).min(cols);
            for rr in r0..r1 {
                for cc in c0..c1 {
                    dst[cc * rows + rr] = src[rr * cols + cc];
                }
            }
        }
    }
}

/// Fused cast-and-transpose for the weight path: one blocked pass over
/// `src` (`[rows, cols]` row-major) applies the elementwise `map`
/// (typically an `fp8::FastCast` rounding) and writes both the quantized
/// matrix `q` (`[rows, cols]`) and its transpose `t` (`[cols, rows]`) —
/// replacing the quantize sweep + separate [`transpose`] pass the weight
/// prep used to make. Because `map` is elementwise, the result is
/// bit-identical to quantize-then-transpose.
pub fn quant_transpose<Q>(
    src: &[f32],
    rows: usize,
    cols: usize,
    q: &mut [f32],
    t: &mut [f32],
    map: Q,
) where
    Q: Fn(f32) -> f32,
{
    assert_eq!(src.len(), rows * cols, "quant_transpose: src is not [rows,cols]");
    assert_eq!(q.len(), rows * cols, "quant_transpose: q is not [rows,cols]");
    assert_eq!(t.len(), rows * cols, "quant_transpose: t is not [cols,rows]");
    const TB: usize = 32;
    for r0 in (0..rows).step_by(TB) {
        let r1 = (r0 + TB).min(rows);
        for c0 in (0..cols).step_by(TB) {
            let c1 = (c0 + TB).min(cols);
            for rr in r0..r1 {
                for cc in c0..c1 {
                    let v = map(src[rr * cols + cc]);
                    q[rr * cols + cc] = v;
                    t[cc * rows + rr] = v;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::parallel::with_max_threads;
    use crate::util::rng::Rng;

    fn naive_bt(a: &[f32], b: &[f32], m: usize, n: usize, k: usize, s: f32) -> Vec<f32> {
        let mut c = vec![0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0f64;
                for kk in 0..k {
                    acc += a[i * k + kk] as f64 * b[j * k + kk] as f64;
                }
                c[i * n + j] = s * (acc as f32);
            }
        }
        c
    }

    /// The pre-SIMD scalar kernel, verbatim: eight stride-8 lanes, the
    /// fixed fold tree, then the sequential tail. The reference every
    /// dispatch path must reproduce bit for bit.
    fn legacy_dot(a: &[f32], b: &[f32]) -> f32 {
        let mut lanes = [0f32; 8];
        let n8 = a.len() / 8 * 8;
        let (a8, a_tail) = a.split_at(n8);
        let (b8, b_tail) = b.split_at(n8);
        for (ab, bb) in a8.chunks_exact(8).zip(b8.chunks_exact(8)) {
            for l in 0..8 {
                lanes[l] += ab[l] * bb[l];
            }
        }
        let mut tail = 0f32;
        for (x, y) in a_tail.iter().zip(b_tail) {
            tail += x * y;
        }
        ((lanes[0] + lanes[4]) + (lanes[1] + lanes[5]))
            + ((lanes[2] + lanes[6]) + (lanes[3] + lanes[7]))
            + tail
    }

    fn legacy_matmul_bt(a: &[f32], b: &[f32], m: usize, n: usize, k: usize, s: f32) -> Vec<f32> {
        let mut c = vec![0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                c[i * n + j] = s * legacy_dot(&a[i * k..(i + 1) * k], &b[j * k..(j + 1) * k]);
            }
        }
        c
    }

    fn legacy_add_matmul_at_b(
        a: &[f32],
        b: &[f32],
        c: &mut [f32],
        r: usize,
        p: usize,
        n: usize,
        s: f32,
    ) {
        for rr in 0..r {
            for i in 0..p {
                let sv = s * a[rr * p + i];
                if sv == 0.0 {
                    continue;
                }
                for j in 0..n {
                    c[i * n + j] += sv * b[rr * n + j];
                }
            }
        }
    }

    #[test]
    fn matmul_bt_matches_naive_within_tolerance() {
        let mut rng = Rng::new(1);
        let (m, n, k) = (13, 17, 29);
        let mut a = vec![0f32; m * k];
        let mut b = vec![0f32; n * k];
        rng.fill_normal(&mut a, 1.0);
        rng.fill_normal(&mut b, 1.0);
        let mut c = vec![0f32; m * n];
        matmul_bt(&a, &b, &mut c, m, n, k, 0.5);
        let want = naive_bt(&a, &b, m, n, k, 0.5);
        for (g, w) in c.iter().zip(&want) {
            assert!((g - w).abs() < 1e-4, "{g} vs {w}");
        }
    }

    #[test]
    fn matmul_bt_identity_and_zero_dims() {
        // B = I (stored transposed, identity is symmetric) => C = scale * A
        let (m, k) = (5usize, 4usize);
        let a: Vec<f32> = (0..m * k).map(|i| i as f32).collect();
        let mut eye = vec![0f32; k * k];
        for i in 0..k {
            eye[i * k + i] = 1.0;
        }
        let mut c = vec![0f32; m * k];
        matmul_bt(&a, &eye, &mut c, m, k, k, 2.0);
        for (g, w) in c.iter().zip(&a) {
            assert_eq!(*g, 2.0 * w);
        }
        let mut empty: Vec<f32> = Vec::new();
        matmul_bt(&[], &eye, &mut empty, 0, k, k, 1.0);
    }

    #[test]
    fn add_matmul_at_b_matches_naive_and_accumulates() {
        let mut rng = Rng::new(2);
        let (r, p, n) = (23, 9, 11);
        let mut a = vec![0f32; r * p];
        let mut b = vec![0f32; r * n];
        rng.fill_normal(&mut a, 1.0);
        rng.fill_normal(&mut b, 1.0);
        // sprinkle exact zeros to exercise the skip path
        for i in (0..a.len()).step_by(7) {
            a[i] = 0.0;
        }
        let mut c = vec![1f32; p * n]; // nonzero: checks += not =
        add_matmul_at_b(&a, &b, &mut c, r, p, n, 0.25);
        for i in 0..p {
            for j in 0..n {
                let mut acc = 0f64;
                for rr in 0..r {
                    acc += 0.25 * a[rr * p + i] as f64 * b[rr * n + j] as f64;
                }
                let want = 1.0 + acc as f32;
                let got = c[i * n + j];
                assert!((got - want).abs() < 1e-4, "[{i},{j}] {got} vs {want}");
            }
        }
    }

    /// Randomized-shape sweep (tails with `k % 8 != 0`, rows/cols off the
    /// register tile, empty dims): the portable path, the auto-dispatched
    /// path (AVX2 where the CPU has it), and the verbatim legacy scalar
    /// kernel must agree bit for bit, for both GEMM shapes.
    #[test]
    fn simd_and_portable_paths_bit_identical_on_randomized_shapes() {
        let guard = kernel_path_lock();
        let mut rng = Rng::new(77);
        let mut shapes: Vec<(usize, usize, usize)> = vec![
            (1, 1, 1),
            (3, 5, 7),
            (4, 8, 8),
            (5, 9, 16),
            (16, 64, 32),
            (17, 65, 33),
            (31, 2, 9),
            (2, 1, 250),
            (7, 3, 0),
            (0, 5, 5),
            (5, 0, 5),
        ];
        for round in 0..24 {
            let m = 1 + (rng.next_u64() % 33) as usize;
            let n = 1 + (rng.next_u64() % 67) as usize;
            let k = (rng.next_u64() % 100) as usize + usize::from(round % 3 == 0);
            shapes.push((m, n, k));
        }
        for &(m, n, k) in &shapes {
            let mut a = vec![0f32; m * k];
            let mut b = vec![0f32; n * k];
            rng.fill_normal(&mut a, 1.0);
            rng.fill_normal(&mut b, 1.0);
            let want = legacy_matmul_bt(&a, &b, m, n, k, 0.75);
            let mut c_port = vec![0f32; m * n];
            guard.force_portable(true);
            matmul_bt(&a, &b, &mut c_port, m, n, k, 0.75);
            guard.force_portable(false);
            let mut c_auto = vec![0f32; m * n];
            matmul_bt(&a, &b, &mut c_auto, m, n, k, 0.75);
            let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
            assert_eq!(bits(&c_port), bits(&want), "portable != legacy at {m}x{n}x{k}");
            assert_eq!(bits(&c_auto), bits(&want), "auto path != legacy at {m}x{n}x{k}");

            // Aᵀ @ B accumulation: A is [r=m, p=k], the right operand must
            // be [r=m, n] — fresh buffer, C accumulates into [k, n].
            let mut b2 = vec![0f32; m * n];
            rng.fill_normal(&mut b2, 1.0);
            let mut c1 = vec![0.5f32; k * n];
            let mut c2 = c1.clone();
            let mut c3 = c1.clone();
            legacy_add_matmul_at_b(&a, &b2, &mut c1, m, k, n, 0.3);
            guard.force_portable(true);
            add_matmul_at_b(&a, &b2, &mut c2, m, k, n, 0.3);
            guard.force_portable(false);
            add_matmul_at_b(&a, &b2, &mut c3, m, k, n, 0.3);
            assert_eq!(bits(&c1), bits(&c2), "atb portable != legacy at {m}x{n}x{k}");
            assert_eq!(bits(&c1), bits(&c3), "atb auto != legacy at {m}x{n}x{k}");
        }
    }

    /// Fused pack+GEMM vs quantize-then-GEMM on the exhaustive FP8 grid:
    /// every finite E4M3/E5M2 code point (and off-grid neighbors that
    /// exercise rounding) flows through both pipelines; the packed
    /// operand and the output must be bit-identical on every path.
    #[test]
    fn fused_cast_gemm_bit_equal_on_exhaustive_fp8_grid() {
        let guard = kernel_path_lock();
        for fmt in [crate::fp8::E4M3, crate::fp8::E5M2] {
            let fc = fmt.fast_caster();
            let mut vals: Vec<f32> = (0u16..256)
                .map(|bits| fmt.decode(bits))
                .filter(|v| v.is_finite())
                .collect();
            // off-grid neighbors: exercise round-to-nearest-even both ways
            for i in 0..vals.len() {
                let v = vals[i];
                vals.push(v * 1.0137);
                vals.push(v * 0.9871);
            }
            let k = 24usize; // not a multiple of 8: tail in every row
            let m = vals.len().div_ceil(k);
            vals.resize(m * k, 0.0);
            let n = 19usize;
            let mut rng = Rng::new(5);
            let mut b = vec![0f32; n * k];
            rng.fill_normal(&mut b, 1.0);
            for portable in [true, false] {
                guard.force_portable(portable);
                // reference: full-tensor quantize sweep, then GEMM
                let mut a_ref = vals.clone();
                fc.quantize_slice(&mut a_ref);
                let mut c_ref = vec![0f32; m * n];
                matmul_bt(&a_ref, &b, &mut c_ref, m, n, k, 1.0);
                // fused: quantize per panel inside the GEMM pass
                let mut a_fused = vals.clone();
                let mut c_fused = vec![0f32; m * n];
                matmul_bt_quant(&mut a_fused, &b, &mut c_fused, m, n, k, 1.0, |p| {
                    fc.quantize_slice(p)
                });
                guard.force_portable(false);
                let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
                assert_eq!(bits(&a_ref), bits(&a_fused), "{fmt:?} packed operand diverged");
                assert_eq!(bits(&c_ref), bits(&c_fused), "{fmt:?} fused output diverged");
            }
        }
    }

    /// `n == 0` (no output columns) must still pack all of A — the saved
    /// quantized operand feeds the weight-gradient GEMM — and `k == 0`
    /// must fill C exactly like the plain GEMM.
    #[test]
    fn matmul_bt_quant_packs_a_even_with_no_output() {
        let fc = crate::fp8::E4M3.fast_caster();
        let (m, k) = (21usize, 13usize);
        let mut rng = Rng::new(6);
        let mut a = vec![0f32; m * k];
        rng.fill_normal(&mut a, 1.0);
        let mut a_ref = a.clone();
        fc.quantize_slice(&mut a_ref);
        let mut c: Vec<f32> = Vec::new();
        matmul_bt_quant(&mut a, &[], &mut c, m, 0, k, 1.0, |p| fc.quantize_slice(p));
        assert_eq!(
            a.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            a_ref.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
        // k == 0: C is filled with scale * (empty dot) like matmul_bt
        let n = 4usize;
        let mut c0 = vec![7f32; m * n];
        let mut c1 = vec![7f32; m * n];
        matmul_bt(&[], &[], &mut c0, m, n, 0, 2.0);
        let mut a_empty: Vec<f32> = Vec::new();
        matmul_bt_quant(&mut a_empty, &[], &mut c1, m, n, 0, 2.0, |p| fc.quantize_slice(p));
        assert_eq!(
            c0.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            c1.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
    }

    /// Fused cast-and-transpose vs quantize-then-transpose, bitwise.
    #[test]
    fn quant_transpose_matches_quantize_then_transpose_bitwise() {
        let fc = crate::fp8::E5M2.fast_caster();
        let (r, c) = (37usize, 53usize);
        let mut rng = Rng::new(8);
        let mut src = vec![0f32; r * c];
        rng.fill_normal(&mut src, 1.0);
        let mut q_ref = src.clone();
        fc.quantize_slice(&mut q_ref);
        let mut t_ref = vec![0f32; r * c];
        transpose(&q_ref, r, c, &mut t_ref);
        let mut q = vec![0f32; r * c];
        let mut t = vec![0f32; r * c];
        quant_transpose(&src, r, c, &mut q, &mut t, |x| fc.quantize(x));
        assert_eq!(
            q.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            q_ref.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
        assert_eq!(
            t.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            t_ref.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
    }

    /// The opt-in FMA kernels are *not* bit-identical (they contract
    /// mul+add into one rounding) but must stay within a tight relative
    /// bound of the reference. Measured divergence is ~1e-7 relative for
    /// unit-normal operands; the asserted bound (1e-5 + a small absolute
    /// floor) is documented in docs/KERNELS.md.
    #[cfg(target_arch = "x86_64")]
    #[test]
    fn fast_fma_mode_divergence_is_small_and_bounded() {
        if !is_x86_feature_detected!("avx2") || !is_x86_feature_detected!("fma") {
            return; // nothing to measure on this CPU
        }
        let mut rng = Rng::new(99);
        for k in [8usize, 63, 256, 1000] {
            let mut a = vec![0f32; k];
            let mut b = vec![0f32; k];
            rng.fill_normal(&mut a, 1.0);
            rng.fill_normal(&mut b, 1.0);
            let want = legacy_dot(&a, &b);
            // SAFETY: features checked above.
            let got = unsafe { kernels::x86::dot_fma(&a, &b) };
            let tol = 1e-5f32 * want.abs().max(1.0);
            assert!(
                (got - want).abs() <= tol,
                "k={k}: fma {got} vs scalar {want} beyond bound {tol}"
            );
        }
    }

    #[test]
    fn kernels_are_bit_identical_across_thread_counts() {
        let mut rng = Rng::new(3);
        // big enough to clear the parallel threshold
        let (m, n, k) = (96, 96, 96);
        let mut a = vec![0f32; m * k];
        let mut b = vec![0f32; n * k];
        rng.fill_normal(&mut a, 1.0);
        rng.fill_normal(&mut b, 1.0);
        let run_bt = |threads: usize| {
            with_max_threads(threads, || {
                let mut c = vec![0f32; m * n];
                matmul_bt(&a, &b, &mut c, m, n, k, 1.0);
                c
            })
        };
        let run_atb = |threads: usize| {
            with_max_threads(threads, || {
                let mut c = vec![0f32; k * n];
                add_matmul_at_b(&a, &b, &mut c, m, k, n, 1.0);
                c
            })
        };
        let fc = crate::fp8::E4M3.fast_caster();
        let run_fused = |threads: usize| {
            with_max_threads(threads, || {
                let mut aq = a.clone();
                let mut c = vec![0f32; m * n];
                matmul_bt_quant(&mut aq, &b, &mut c, m, n, k, 1.0, |p| fc.quantize_slice(p));
                (aq, c)
            })
        };
        let (bt1, atb1, fused1) = (run_bt(1), run_atb(1), run_fused(1));
        for threads in [2usize, 4, 5] {
            assert_eq!(bt1, run_bt(threads), "matmul_bt drifted at {threads} threads");
            assert_eq!(atb1, run_atb(threads), "add_matmul_at_b drifted at {threads} threads");
            assert_eq!(fused1, run_fused(threads), "matmul_bt_quant drifted at {threads} threads");
        }
    }

    #[test]
    fn attn_forward_causal_matches_naive_softmax() {
        let (s, dh) = (7usize, 6usize);
        let mut rng = Rng::new(11);
        let mut q = vec![0f32; s * dh];
        let mut k = vec![0f32; s * dh];
        let mut v = vec![0f32; s * dh];
        rng.fill_normal(&mut q, 1.0);
        rng.fill_normal(&mut k, 1.0);
        rng.fill_normal(&mut v, 1.0);
        let scale = 1.0 / (dh as f32).sqrt();
        let mut probs = vec![0f32; s * s];
        let mut o = vec![0f32; s * dh];
        attn_forward_causal(&q, &k, &v, &mut probs, &mut o, s, dh, scale);
        for i in 0..s {
            // naive f64 softmax over j <= i
            let mut logits = vec![0f64; i + 1];
            for j in 0..=i {
                let mut acc = 0f64;
                for c in 0..dh {
                    acc += q[i * dh + c] as f64 * k[j * dh + c] as f64;
                }
                logits[j] = scale as f64 * acc;
            }
            let m = logits.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            let den: f64 = logits.iter().map(|&l| (l - m).exp()).sum();
            let mut row_sum = 0f64;
            for j in 0..s {
                let got = probs[i * s + j] as f64;
                if j <= i {
                    let want = (logits[j] - m).exp() / den;
                    assert!((got - want).abs() < 1e-5, "P[{i},{j}] {got} vs {want}");
                    row_sum += got;
                } else {
                    assert_eq!(got, 0.0, "causal mask leaked at [{i},{j}]");
                }
            }
            assert!((row_sum - 1.0).abs() < 1e-5, "row {i} sums to {row_sum}");
            for c in 0..dh {
                let want: f64 = (0..=i)
                    .map(|j| probs[i * s + j] as f64 * v[j * dh + c] as f64)
                    .sum();
                assert!((o[i * dh + c] as f64 - want).abs() < 1e-5);
            }
        }
        // position 0 attends only to itself
        assert_eq!(probs[0], 1.0);
        for c in 0..dh {
            assert!((o[c] - v[c]).abs() < 1e-6);
        }
    }

    #[test]
    fn attn_backward_causal_matches_finite_difference() {
        // FD through a scalar objective L = Σ w ⊙ attn(q,k,v), checking a
        // few coordinates of each of dq, dk, dv. f32 forward, so the FD
        // tolerance is loose-ish (1e-2 relative).
        let (s, dh) = (5usize, 4usize);
        let mut rng = Rng::new(12);
        let mut q = vec![0f32; s * dh];
        let mut k = vec![0f32; s * dh];
        let mut v = vec![0f32; s * dh];
        let mut w = vec![0f32; s * dh];
        rng.fill_normal(&mut q, 1.0);
        rng.fill_normal(&mut k, 1.0);
        rng.fill_normal(&mut v, 1.0);
        rng.fill_normal(&mut w, 1.0);
        let scale = 1.0 / (dh as f32).sqrt();
        let loss = |q: &[f32], k: &[f32], v: &[f32]| -> f64 {
            let mut probs = vec![0f32; s * s];
            let mut o = vec![0f32; s * dh];
            attn_forward_causal(q, k, v, &mut probs, &mut o, s, dh, scale);
            o.iter().zip(&w).map(|(&a, &b)| a as f64 * b as f64).sum()
        };
        let mut probs = vec![0f32; s * s];
        let mut o = vec![0f32; s * dh];
        attn_forward_causal(&q, &k, &v, &mut probs, &mut o, s, dh, scale);
        let (mut dq, mut dk, mut dv) = (vec![0f32; s * dh], vec![0f32; s * dh], vec![0f32; s * dh]);
        attn_backward_causal(&w, &probs, &q, &k, &v, &mut dq, &mut dk, &mut dv, s, dh, scale);
        let h = 1e-3f32;
        for (which, idx) in
            [(0usize, 1usize), (0, s * dh - 2), (1, 2), (1, s * dh - 1), (2, 0), (2, s * dh / 2)]
        {
            let (base, grad): (&Vec<f32>, &[f32]) = match which {
                0 => (&q, &dq),
                1 => (&k, &dk),
                _ => (&v, &dv),
            };
            let mut bplus = base.clone();
            bplus[idx] += h;
            let mut bminus = base.clone();
            bminus[idx] -= h;
            let g = grad[idx] as f64;
            let (lp, lm) = match which {
                0 => (loss(&bplus, &k, &v), loss(&bminus, &k, &v)),
                1 => (loss(&q, &bplus, &v), loss(&q, &bminus, &v)),
                _ => (loss(&q, &k, &bplus), loss(&q, &k, &bminus)),
            };
            let fd = (lp - lm) / (2.0 * h as f64);
            assert!(
                (fd - g).abs() <= 2e-2 * fd.abs().max(g.abs()) + 2e-3,
                "buf{which}[{idx}]: fd {fd} vs analytic {g}"
            );
        }
    }

    /// The decode kernel against the training kernel, kernel-level: for
    /// BF16-rounded operands (what the tower produces and the cache
    /// stores), a single cached query reproduces the matching causal
    /// row bit for bit — including when the history spans several pages
    /// and the last page is partially filled.
    #[test]
    fn attn_decode_cached_matches_causal_rows_bitwise() {
        let (s, dh, page_rows) = (11usize, 6usize, 4usize);
        let mut rng = Rng::new(21);
        let mut q = vec![0f32; s * dh];
        let mut k = vec![0f32; s * dh];
        let mut v = vec![0f32; s * dh];
        rng.fill_normal(&mut q, 1.0);
        rng.fill_normal(&mut k, 1.0);
        rng.fill_normal(&mut v, 1.0);
        let bf16 = crate::fp8::BF16.fast_caster();
        bf16.quantize_slice(&mut q);
        bf16.quantize_slice(&mut k);
        bf16.quantize_slice(&mut v);
        let scale = 1.0 / (dh as f32).sqrt();
        let mut probs = vec![0f32; s * s];
        let mut o = vec![0f32; s * dh];
        attn_forward_causal(&q, &k, &v, &mut probs, &mut o, s, dh, scale);

        let to_bytes = |xs: &[f32]| -> Vec<u8> {
            xs.iter().flat_map(|&x| f32_to_bf16_bits(x).to_le_bytes()).collect()
        };
        let k_bytes = to_bytes(&k);
        let v_bytes = to_bytes(&v);
        let k_pages: Vec<&[u8]> = k_bytes.chunks(page_rows * dh * 2).collect();
        let v_pages: Vec<&[u8]> = v_bytes.chunks(page_rows * dh * 2).collect();
        let (mut kf, mut vf) = (vec![0f32; s * dh], vec![0f32; s * dh]);
        let mut scores = vec![0f32; s];
        let mut od = vec![0f32; dh];
        for i in [0usize, 3, 4, s - 1] {
            let len = i + 1;
            attn_decode_cached(
                &q[i * dh..(i + 1) * dh],
                &k_pages,
                &v_pages,
                len,
                dh,
                scale,
                KvCodec::Bf16,
                &mut kf,
                &mut vf,
                &mut scores,
                &mut od,
            );
            for c in 0..dh {
                assert_eq!(
                    od[c].to_bits(),
                    o[i * dh + c].to_bits(),
                    "row {i} col {c}: decode {} vs causal {}",
                    od[c],
                    o[i * dh + c]
                );
            }
            // the scores are the causal row's probabilities
            for j in 0..len {
                assert_eq!(scores[j].to_bits(), probs[i * s + j].to_bits());
            }
        }
    }

    /// The FP8 codec decodes cached bytes through exactly the E4M3
    /// oracle: attending over E4M3-rounded history equals running the
    /// shared causal kernel on `decode(encode(x))` operands bitwise.
    #[test]
    fn attn_decode_cached_fp8_codec_matches_e4m3_rounded_operands() {
        let (s, dh, page_rows) = (9usize, 4usize, 4usize);
        let fmt = crate::fp8::E4M3;
        let lut = fmt.decode_lut8();
        let mut rng = Rng::new(33);
        let mut q = vec![0f32; s * dh];
        let mut k = vec![0f32; s * dh];
        let mut v = vec![0f32; s * dh];
        rng.fill_normal(&mut q, 1.0);
        rng.fill_normal(&mut k, 1.0);
        rng.fill_normal(&mut v, 1.0);
        // encode the history the way the FP8 KV cache stores it
        let k_bytes: Vec<u8> = k.iter().map(|&x| fmt.encode(x) as u8).collect();
        let v_bytes: Vec<u8> = v.iter().map(|&x| fmt.encode(x) as u8).collect();
        let k_pages: Vec<&[u8]> = k_bytes.chunks(page_rows * dh).collect();
        let v_pages: Vec<&[u8]> = v_bytes.chunks(page_rows * dh).collect();
        // reference: the shared kernel on explicitly decoded operands
        let k_ref: Vec<f32> = k_bytes.iter().map(|&b| fmt.decode(b as u16)).collect();
        let v_ref: Vec<f32> = v_bytes.iter().map(|&b| fmt.decode(b as u16)).collect();
        let scale = 1.0 / (dh as f32).sqrt();
        let (mut kf, mut vf) = (vec![0f32; s * dh], vec![0f32; s * dh]);
        let mut scores = vec![0f32; s];
        let (mut od, mut oref) = (vec![0f32; dh], vec![0f32; dh]);
        let mut scores_ref = vec![0f32; s];
        for i in [0usize, 4, s - 1] {
            let len = i + 1;
            let qi = &q[i * dh..(i + 1) * dh];
            attn_decode_cached(
                qi,
                &k_pages,
                &v_pages,
                len,
                dh,
                scale,
                KvCodec::Fp8E4m3(&lut),
                &mut kf,
                &mut vf,
                &mut scores,
                &mut od,
            );
            attn_one_query(qi, &k_ref, &v_ref, len, dh, scale, &mut scores_ref[..len], &mut oref);
            for c in 0..dh {
                assert_eq!(od[c].to_bits(), oref[c].to_bits(), "row {i} col {c}");
            }
        }
    }

    #[test]
    fn telemetry_reductions_deterministic_and_correct() {
        let mut rng = Rng::new(9);
        // big enough that the parallel threshold is cleared, so the
        // thread-count assertions exercise the multi-thread path
        let mut xs = vec![0f32; 300_000];
        rng.fill_normal(&mut xs, 1.0);
        xs[7] = -123.5;
        let naive: f64 = xs.iter().map(|&x| (x as f64) * (x as f64)).sum();
        let s1 = with_max_threads(1, || sum_sq(&xs));
        assert!((s1 - naive).abs() < 1e-6 * naive.abs());
        for threads in [2usize, 5] {
            assert_eq!(
                s1.to_bits(),
                with_max_threads(threads, || sum_sq(&xs)).to_bits(),
                "sum_sq drifted at {threads} threads"
            );
        }
        assert_eq!(abs_max(&xs), 123.5);
        assert_eq!(abs_max(&[]), 0.0);
        assert_eq!(abs_max(&[f32::NAN, 2.0]), 2.0, "amax ignores NaN");
        assert_eq!(sum_sq(&[]), 0.0);
        // the fused one-pass reduction is bit-identical to the pair
        let (ss, am) = sum_sq_abs_max(&xs);
        assert_eq!(ss.to_bits(), s1.to_bits());
        assert_eq!(am, 123.5);
        assert_eq!(sum_sq_abs_max(&[]), (0.0, 0.0));
    }

    #[test]
    fn transpose_round_trips() {
        let mut rng = Rng::new(4);
        let (r, c) = (37, 53);
        let mut src = vec![0f32; r * c];
        rng.fill_normal(&mut src, 1.0);
        let mut t = vec![0f32; r * c];
        let mut back = vec![0f32; r * c];
        transpose(&src, r, c, &mut t);
        transpose(&t, c, r, &mut back);
        assert_eq!(src, back);
        assert_eq!(t[3 * r + 5], src[5 * c + 3]);
    }
}
