//! Runtime kernel dispatch: CPU-feature detection, the opt-in `Fast`
//! (FMA) mode, and the bench/test hook that forces the portable path.
//!
//! The resolved path is a pure function of (detected features, mode,
//! portable override) — no entropy sources, no time, and the decision is
//! made **once per kernel entry point call** and copied into the worker
//! closure, so every thread of one GEMM call runs the same path. The
//! first kernel invocation of a process emits a single
//! `kernel dispatch: path=... mode=...` line on stderr (asserted by the
//! CI `kernels` leg) so logs always record which path produced a run.

use std::sync::atomic::{AtomicBool, AtomicU8, Ordering};
use std::sync::{Mutex, MutexGuard, Once, PoisonError};

/// Arithmetic mode of the GEMM microkernels.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelMode {
    /// Default: mul+add (two roundings), bit-identical to the scalar
    /// reference kernels on every path.
    Deterministic,
    /// Opt-in: fused multiply-add (one rounding) where the CPU has FMA.
    /// Faster, *not* bit-identical — divergence is measured and bounded
    /// by the kernel tests and documented in `docs/KERNELS.md`.
    Fast,
}

/// The instruction path a kernel entry point resolved to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelPath {
    /// Portable unrolled scalar kernels (also the non-x86_64 path).
    Portable,
    /// AVX2 mul+add kernels — bit-identical to [`KernelPath::Portable`].
    Avx2,
    /// AVX2+FMA kernels (only in [`KernelMode::Fast`]).
    Avx2Fma,
}

impl KernelPath {
    /// Stable lowercase name, used in logs and BENCH JSON.
    pub fn name(self) -> &'static str {
        match self {
            KernelPath::Portable => "portable",
            KernelPath::Avx2 => "avx2",
            KernelPath::Avx2Fma => "avx2_fma",
        }
    }
}

/// 0 = Deterministic, 1 = Fast.
static MODE: AtomicU8 = AtomicU8::new(0);
/// Bench/test hook: when true, resolve to the portable path even if the
/// CPU has AVX2 (how CI measures the AVX2-vs-portable speedup in one run).
static FORCE_PORTABLE: AtomicBool = AtomicBool::new(false);
static LOG_ONCE: Once = Once::new();

/// Select the kernel arithmetic mode process-wide. The default
/// ([`KernelMode::Deterministic`]) is part of the repo's bit-determinism
/// contract; [`KernelMode::Fast`] is an explicit opt-in for throughput
/// experiments. Takes effect on the next kernel entry-point call.
pub fn set_kernel_mode(mode: KernelMode) {
    MODE.store(mode as u8, Ordering::Relaxed);
}

/// The currently selected kernel arithmetic mode.
pub fn kernel_mode() -> KernelMode {
    if MODE.load(Ordering::Relaxed) == 1 {
        KernelMode::Fast
    } else {
        KernelMode::Deterministic
    }
}

/// Bench/test hook: force the portable kernels regardless of detected CPU
/// features (`true`), or restore feature-based dispatch (`false`).
///
/// The override is a **process-wide global**. Code that may run
/// concurrently with other toggling code — any `#[test]`, since cargo's
/// default harness runs tests on multiple threads — must not call this
/// directly: take [`kernel_path_lock`] and toggle through the guard, so
/// two tests can never observe each other's override. Raw calls are only
/// appropriate in single-threaded drivers (the bench harness).
pub fn force_portable_kernels(force: bool) {
    FORCE_PORTABLE.store(force, Ordering::Relaxed);
}

/// Serializes every scope that toggles the portable-path override (see
/// [`force_portable_kernels`] — the flag is process-global, the test
/// harness is concurrent).
static FORCE_LOCK: Mutex<()> = Mutex::new(());

/// Exclusive, scoped handle on the kernel-path override. Held for as long
/// as a test or bench section needs a specific dispatch outcome; while
/// one guard is alive every other [`kernel_path_lock`] caller blocks, and
/// dropping the guard always restores feature-based dispatch.
pub struct KernelPathGuard {
    _lock: MutexGuard<'static, ()>,
}

/// Take the process-wide kernel-override lock. The scoped, concurrency-
/// safe form of [`force_portable_kernels`]: toggle the override through
/// [`KernelPathGuard::force_portable`] for the guard's lifetime.
pub fn kernel_path_lock() -> KernelPathGuard {
    let lock = FORCE_LOCK.lock().unwrap_or_else(PoisonError::into_inner);
    KernelPathGuard { _lock: lock }
}

impl KernelPathGuard {
    /// Force the portable kernels (`true`) or restore feature-based
    /// dispatch (`false`) while the lock is held.
    pub fn force_portable(&self, force: bool) {
        force_portable_kernels(force);
    }
}

impl Drop for KernelPathGuard {
    fn drop(&mut self) {
        force_portable_kernels(false);
    }
}

/// CPU feature probe, evaluated once per call (the detection macro itself
/// caches internally; this stays out of the per-element hot loop because
/// entry points resolve the path once per GEMM call).
#[cfg(target_arch = "x86_64")]
fn features() -> (bool, bool) {
    (is_x86_feature_detected!("avx2"), is_x86_feature_detected!("fma"))
}

#[cfg(not(target_arch = "x86_64"))]
fn features() -> (bool, bool) {
    (false, false)
}

/// Resolve the path the next kernel call will take: portable if forced or
/// if AVX2 is absent; AVX2+FMA only when `Fast` mode is selected *and*
/// the CPU has FMA; AVX2 (mul+add, bit-exact) otherwise.
pub fn kernel_path() -> KernelPath {
    if FORCE_PORTABLE.load(Ordering::Relaxed) {
        return KernelPath::Portable;
    }
    let (avx2, fma) = features();
    if !avx2 {
        return KernelPath::Portable;
    }
    if fma && kernel_mode() == KernelMode::Fast {
        return KernelPath::Avx2Fma;
    }
    KernelPath::Avx2
}

/// Emit the one-time dispatch log line (first kernel call of the
/// process). Subsequent calls are free.
pub(super) fn log_once(path: KernelPath) {
    LOG_ONCE.call_once(|| {
        let mode = match kernel_mode() {
            KernelMode::Deterministic => "deterministic",
            KernelMode::Fast => "fast",
        };
        eprintln!("kernel dispatch: path={} mode={}", path.name(), mode);
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forced_portable_overrides_detection_and_restores() {
        let guard = kernel_path_lock();
        guard.force_portable(true);
        assert_eq!(kernel_path(), KernelPath::Portable);
        guard.force_portable(false);
        // whatever the CPU is, the resolved path must be a valid variant
        let p = kernel_path();
        assert!(matches!(p, KernelPath::Portable | KernelPath::Avx2 | KernelPath::Avx2Fma));
    }

    #[test]
    fn path_names_are_stable() {
        assert_eq!(KernelPath::Portable.name(), "portable");
        assert_eq!(KernelPath::Avx2.name(), "avx2");
        assert_eq!(KernelPath::Avx2Fma.name(), "avx2_fma");
    }
}
